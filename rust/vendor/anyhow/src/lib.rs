//! Minimal, dependency-free stand-in for the `anyhow` crate.
//!
//! The build image for this repository is fully offline (no crates.io
//! index), so the workspace vendors the small subset of anyhow's API that
//! the codebase actually uses:
//!
//! * [`Error`] — an opaque boxed error with a context chain,
//! * [`Result`] — `Result<T, Error>` with the error type defaulted,
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`,
//! * the `anyhow!`, `bail!`, and `ensure!` macros.
//!
//! Semantics match upstream for this subset: any
//! `std::error::Error + Send + Sync + 'static` converts into [`Error`]
//! through `?`, context layers wrap the source chain, and the `Debug`
//! rendering (what `fn main() -> Result<()>` prints on failure) shows the
//! outermost message followed by a `Caused by:` chain.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Opaque error: a boxed `std::error::Error` plus optional context layers.
pub struct Error {
    inner: Box<dyn StdError + Send + Sync + 'static>,
}

impl Error {
    /// Wrap a concrete error.
    pub fn new<E>(error: E) -> Self
    where
        E: StdError + Send + Sync + 'static,
    {
        Error { inner: Box::new(error) }
    }

    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { inner: Box::new(MessageError(message.to_string())) }
    }

    /// Wrap this error in a new context layer.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error {
            inner: Box::new(ContextError {
                context: context.to_string(),
                source: self.inner,
            }),
        }
    }

    /// The lowest-level error in the context chain.
    pub fn root_cause(&self) -> &(dyn StdError + 'static) {
        let mut current: &(dyn StdError + 'static) = &*self.inner;
        while let Some(source) = current.source() {
            current = source;
        }
        current
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.inner, f)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.inner)?;
        let mut source = self.inner.source();
        if source.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(s) = source {
            write!(f, "\n    {s}")?;
            source = s.source();
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: StdError + Send + Sync + 'static,
{
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

/// Message-only error payload.
#[derive(Debug)]
struct MessageError(String);

impl fmt::Display for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl StdError for MessageError {}

/// One context layer wrapping a source error.
#[derive(Debug)]
struct ContextError {
    context: String,
    source: Box<dyn StdError + Send + Sync + 'static>,
}

impl fmt::Display for ContextError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.context)
    }
}

impl StdError for ContextError {
    fn source(&self) -> Option<&(dyn StdError + 'static)> {
        let source: &(dyn StdError + 'static) = &*self.source;
        Some(source)
    }
}

mod ext {
    use super::{Error, StdError};

    /// Anything that can become an [`Error`] when context is attached.
    /// The blanket impl covers concrete error types; the manual impl lets
    /// `.context(..)` chain on `anyhow::Result` itself (same trick as
    /// upstream anyhow — `Error` never implements `std::error::Error`, so
    /// the impls cannot overlap).
    pub trait IntoError {
        fn into_error(self) -> Error;
    }

    impl<E> IntoError for E
    where
        E: StdError + Send + Sync + 'static,
    {
        fn into_error(self) -> Error {
            Error::new(self)
        }
    }

    impl IntoError for Error {
        fn into_error(self) -> Error {
            self
        }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T>: Sized {
    /// Attach a context message, converting the error to [`Error`].
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Attach a lazily evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for Result<T, E>
where
    E: ext::IntoError,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(::std::format!("{}", $err))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!(
                ::std::concat!("condition failed: ", ::std::stringify!($cond))
            ));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_port(s: &str) -> Result<u16> {
        let port: u16 = s
            .parse()
            .with_context(|| format!("bad port {s:?}"))?;
        ensure!(port != 0, "port must be nonzero");
        Ok(port)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<i32> {
            let n: i32 = "42".parse()?;
            Ok(n)
        }
        assert_eq!(inner().unwrap(), 42);
    }

    #[test]
    fn context_wraps_and_chains() {
        let err = parse_port("not-a-number").unwrap_err();
        assert_eq!(err.to_string(), "bad port \"not-a-number\"");
        let debug = format!("{err:?}");
        assert!(debug.contains("Caused by:"), "{debug}");
        assert!(err.root_cause().to_string().contains("invalid digit"));
    }

    #[test]
    fn ensure_and_bail_fire() {
        assert!(parse_port("0").is_err());
        fn fails() -> Result<()> {
            bail!("boom {}", 7);
        }
        assert_eq!(fails().unwrap_err().to_string(), "boom 7");
    }

    #[test]
    fn option_context() {
        let missing: Option<u8> = None;
        let err = missing.context("nothing there").unwrap_err();
        assert_eq!(err.to_string(), "nothing there");
    }

    #[test]
    fn context_on_anyhow_result_chains_again() {
        let res: Result<()> = Err(Error::msg("inner"));
        let err = res.context("outer").unwrap_err();
        assert_eq!(err.to_string(), "outer");
        assert_eq!(err.root_cause().to_string(), "inner");
    }

    #[test]
    fn ensure_without_message() {
        fn check(x: i32) -> Result<()> {
            ensure!(x > 0);
            Ok(())
        }
        assert!(check(1).is_ok());
        let err = check(-1).unwrap_err();
        assert!(err.to_string().contains("condition failed"), "{err}");
    }
}
