//! Runtime end-to-end tests: load the AOT artifacts through PJRT and drive
//! real train/eval steps — the full L1+L2+L3 composition.
//!
//! These tests require the `xla` cargo feature (the whole file is
//! compile-gated: the PJRT-backed runtime cannot build in the offline
//! image — see ARCHITECTURE.md) and `make artifacts` to have produced
//! `artifacts/`; they are skipped with a notice when the directory is
//! absent so `cargo test --features xla` still passes in a fresh checkout.
#![cfg(feature = "xla")]

use littlebit2::coordinator::{QatDriver, StudentVariant};
use littlebit2::runtime::{lit, Runtime};

fn artifacts_dir() -> Option<&'static str> {
    if std::path::Path::new("artifacts/manifest.json").exists() {
        Some("artifacts")
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn manifest_loads_and_describes_artifacts() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(dir).expect("runtime");
    let m = rt.manifest().expect("manifest");
    for name in [
        "teacher_train_step",
        "student_train_step",
        "student_fp_train_step",
        "teacher_eval",
        "student_eval",
        "student_fp_eval",
        "student_infer",
        "littlebit_layer",
    ] {
        assert!(m.artifacts.contains_key(name), "missing artifact {name}");
    }
    assert!(m.config.vocab > 0 && m.config.d_model > 0);
    assert_eq!(m.teacher_spec.first().map(|(n, _)| n.as_str()), Some("embed"));
}

#[test]
fn littlebit_layer_artifact_matches_rust_packed_forward() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(dir).expect("runtime");
    let m = rt.manifest().expect("manifest");
    let info = &m.artifacts["littlebit_layer"];
    // Shapes: x [b, d_in], u_b [d_out, r], v_b [d_in, r], h, l, g.
    let shapes: Vec<Vec<usize>> = info.input_shapes.iter().map(|(_, s)| s.clone()).collect();
    let (b, d_in) = (shapes[0][0], shapes[0][1]);
    let (d_out, r) = (shapes[1][0], shapes[1][1]);

    use littlebit2::linalg::Mat;
    use littlebit2::packing::TriScaleLayer;
    use littlebit2::rng::Pcg64;
    let mut rng = Pcg64::seed(99);
    let x = Mat::gaussian(b, d_in, &mut rng);
    let ub = Mat::gaussian(d_out, r, &mut rng).signum();
    let vb = Mat::gaussian(d_in, r, &mut rng).signum();
    let mut h = vec![0.0f32; d_out];
    let mut l = vec![0.0f32; r];
    let mut g = vec![0.0f32; d_in];
    rng.fill_uniform(&mut h, 0.5, 1.5);
    rng.fill_uniform(&mut l, 0.1, 1.0);
    rng.fill_uniform(&mut g, 0.5, 1.5);

    let exe = rt.load_checked("littlebit_layer").expect("compile");
    let inputs = vec![
        lit::array_f32(&x.to_vec(), &[b, d_in]).unwrap(),
        lit::array_f32(&ub.to_vec(), &[d_out, r]).unwrap(),
        lit::array_f32(&vb.to_vec(), &[d_in, r]).unwrap(),
        lit::array_f32(&h, &[d_out]).unwrap(),
        lit::array_f32(&l, &[r]).unwrap(),
        lit::array_f32(&g, &[d_in]).unwrap(),
    ];
    let out = exe.run(&inputs).expect("execute");
    let y = lit::to_vec_f32(&out[0]).expect("f32 output");
    assert_eq!(y.len(), b * d_out);

    // Rust packed path must agree with the Pallas-lowered HLO.
    let layer = TriScaleLayer::new(&ub, &vb, h, l, g);
    for i in 0..b {
        let want = layer.forward(x.row(i));
        for (j, w) in want.iter().enumerate() {
            let got = y[i * d_out + j];
            assert!(
                (got - w).abs() < 1e-2 * w.abs().max(1.0),
                "({i},{j}): hlo {got} vs rust {w}"
            );
        }
    }
}

#[test]
fn teacher_step_decreases_loss_through_pjrt() {
    let Some(dir) = artifacts_dir() else { return };
    let driver = QatDriver::new(dir, 555).expect("driver");
    let (_params, losses) = driver
        .train_teacher(6, 3e-3, |_, _| {})
        .expect("teacher steps");
    assert_eq!(losses.len(), 6);
    assert!(
        losses[5] < losses[0],
        "loss did not decrease: {losses:?}"
    );
    assert!(losses.iter().all(|l| l.is_finite()));
}

#[test]
fn student_qakd_step_runs_through_pjrt() {
    let Some(dir) = artifacts_dir() else { return };
    let driver = QatDriver::new(dir, 556).expect("driver");
    let (teacher, _) = driver.train_teacher(3, 3e-3, |_, _| {}).expect("teacher");
    let outcome = driver
        .train_student(
            &teacher,
            StudentVariant::LittleBit2 { itq_iters: 10 },
            4,
            1e-3,
            |_, _, _| {},
        )
        .expect("student steps");
    assert_eq!(outcome.trace.losses.len(), 4);
    assert!(outcome.trace.losses.iter().all(|l| l.is_finite()));
    assert!(outcome.final_eval_ce.is_finite());
    // Some sign movement should occur in early QAT.
    assert!(outcome.trace.flip_ratio.iter().any(|&f| f > 0.0));
}

#[test]
fn fp_student_variant_runs_through_pjrt() {
    let Some(dir) = artifacts_dir() else { return };
    let driver = QatDriver::new(dir, 557).expect("driver");
    let (teacher, _) = driver.train_teacher(2, 3e-3, |_, _| {}).expect("teacher");
    let outcome = driver
        .train_student(&teacher, StudentVariant::TinyRankFp, 2, 1e-3, |_, _, _| {})
        .expect("fp student");
    assert!(outcome.final_eval_ce.is_finite());
}
