//! The PR 4 acceptance contract, pipeline half: `compress --jobs N` must
//! produce **byte-identical** `.lb2` artifacts for any worker count, and
//! the streaming writer must match the batch `save` path byte-for-byte.
//!
//! These tests drive the same library path the CLI uses
//! (`run_compression_jobs_streaming` → `StackStreamWriter`), with the
//! CLI's per-layer derived seeds, so `make roundtrip`'s `cmp` check is
//! covered at unit scope too.

use littlebit2::artifact::StackStreamWriter;
use littlebit2::coordinator::{run_compression_jobs_streaming, CompressionJob, JobInput};
use littlebit2::littlebit::{CompressionConfig, InitStrategy};
use littlebit2::model::PackedStack;
use littlebit2::quant::MethodSpec;
use littlebit2::rng::derive_seed;
use littlebit2::spectral::SynthSpec;
use std::path::PathBuf;

fn jobs(layers: usize, size: usize, base_seed: u64) -> Vec<CompressionJob> {
    let cfg = CompressionConfig {
        bpp: 1.0,
        strategy: InitStrategy::JointItq { iters: 8 },
        residual: true,
        ..Default::default()
    };
    (0..layers)
        .map(|k| CompressionJob {
            name: format!("layer{k}"),
            input: JobInput::Synth {
                spec: SynthSpec { rows: size, cols: size, gamma: 0.3, coherence: 0.7, scale: 1.0 },
                seed: derive_seed(base_seed, 2 * k as u64),
            },
            method: MethodSpec::LittleBit2(cfg.clone()),
            seed: derive_seed(base_seed, 2 * k as u64 + 1),
        })
        .collect()
}

fn shapes_of(jobs: &[CompressionJob]) -> Vec<(usize, usize, usize)> {
    jobs.iter()
        .map(|j| {
            let (d_out, d_in) = j.shape();
            (d_in, d_out, j.n_paths())
        })
        .collect()
}

fn tmp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("lb2_pipeline_{}_{tag}.lb2", std::process::id()))
}

/// Compress `layers` layers on `workers` claim-loops, streaming into a
/// `.lb2` file; return its bytes.
fn stream_artifact(workers: usize, tag: &str) -> Vec<u8> {
    let jobs = jobs(3, 48, 42);
    let path = tmp_path(tag);
    let mut writer = StackStreamWriter::create(&path, &shapes_of(&jobs)).unwrap();
    run_compression_jobs_streaming(jobs, workers, |_, outcome| {
        writer.append(&outcome.result.method, &outcome.layer)?;
        Ok(())
    })
    .unwrap();
    writer.finish().unwrap();
    let bytes = std::fs::read(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    bytes
}

/// Same seed, any worker count → the same artifact, byte for byte.
#[test]
fn jobs_n_is_byte_identical() {
    let base = stream_artifact(1, "w1");
    for workers in [2usize, 7] {
        let got = stream_artifact(workers, &format!("w{workers}"));
        assert_eq!(base, got, "artifact bytes differ at workers={workers}");
    }
    // And the artifact is a valid, loadable stack.
    let stack = PackedStack::from_artifact_bytes(&base).unwrap();
    assert_eq!(stack.depth(), 3);
    assert_eq!(stack.d_in(), 48);
}

/// The streaming writer and the batch `PackedStack::save` encoder must
/// emit identical bytes for the same layers.
#[test]
fn stream_writer_matches_batch_save() {
    let jobs = jobs(2, 40, 7);
    let shapes = shapes_of(&jobs);

    let stream_path = tmp_path("stream");
    let mut writer = StackStreamWriter::create(&stream_path, &shapes).unwrap();
    let mut layers = Vec::new();
    run_compression_jobs_streaming(jobs, 2, |_, outcome| {
        writer.append(&outcome.result.method, &outcome.layer)?;
        layers.push(outcome.layer.into_packed().unwrap());
        Ok(())
    })
    .unwrap();
    writer.finish().unwrap();
    let streamed = std::fs::read(&stream_path).unwrap();
    let _ = std::fs::remove_file(&stream_path);

    let batch = PackedStack::new(layers).to_artifact_bytes().unwrap();
    assert_eq!(streamed, batch, "streamed vs batch-encoded artifact bytes");
}

/// Shape-table enforcement: a layer that does not match the declared
/// shapes is rejected, as is sealing with layers missing; neither leaves
/// a file behind.
#[test]
fn stream_writer_validates_shapes_and_completion() {
    let jobs = jobs(2, 40, 9);
    let shapes = shapes_of(&jobs);

    // Wrong shape table → the first append fails.
    let path = tmp_path("badshape");
    let mut writer =
        StackStreamWriter::create(&path, &[(13, 13, 2), (13, 13, 2)]).unwrap();
    let mut first = None;
    run_compression_jobs_streaming(jobs.clone(), 1, |_, outcome| {
        if first.is_none() {
            first = Some(outcome.layer.into_packed().unwrap());
        }
        Ok(())
    })
    .unwrap();
    let err = writer.append_layer(&first.unwrap()).unwrap_err();
    assert!(err.to_string().contains("shape table"), "{err}");
    drop(writer);
    assert!(!path.exists(), "abandoned stream must not leave {path:?}");

    // Missing layers → finish fails and removes the temp file.
    let path2 = tmp_path("short");
    let writer2 = StackStreamWriter::create(&path2, &shapes).unwrap();
    let err = writer2.finish().unwrap_err();
    assert!(err.to_string().contains("only 0 were appended"), "{err}");
    assert!(!path2.exists());

    // An empty shape table is refused outright.
    assert!(StackStreamWriter::create(tmp_path("empty"), &[]).is_err());
}

/// The CLI's bug regression: with per-layer derived seeds, dropping the
/// first layer must not change the second layer's bytes (the old shared
/// RNG chained layers together).
#[test]
fn layers_are_independent_of_preceding_layers() {
    let all = jobs(3, 48, 42);
    let tail: Vec<CompressionJob> = all[1..].to_vec();

    let collect = |js: Vec<CompressionJob>| {
        let mut out = Vec::new();
        run_compression_jobs_streaming(js, 1, |_, oc| {
            out.push(oc.layer.into_packed().unwrap());
            Ok(())
        })
        .unwrap();
        out
    };
    let full = collect(all);
    let tail = collect(tail);
    // full[1] and tail[0] are the same job — identical packed bits.
    for (a, b) in full[1].paths().iter().zip(tail[0].paths()) {
        assert_eq!(a.ub_bits().padded_words(), b.ub_bits().padded_words());
        assert_eq!(a.vbt_bits().padded_words(), b.vbt_bits().padded_words());
        assert_eq!(a.h(), b.h());
        assert_eq!(a.l(), b.l());
        assert_eq!(a.g(), b.g());
    }
}
