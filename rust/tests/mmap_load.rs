//! The zero-copy loading contract, end to end: a v3 "aligned" `.lb2`
//! served through `load_mmap` is **bit-identical** — in representation and
//! in forwards, on both SIMD lanes — to the same stack eagerly loaded from
//! a v2 file; v1/v2 artifacts still load through the mmap entry point via
//! the copy-and-restride fallback; the resident/mapped byte accounting is
//! disjoint and sums to the eager footprint; and a corrupted v3 file —
//! truncation at every byte, any flipped bit, dirty alignment filler or
//! plane pad words under a recomputed CRC — is `Err`, never a panic.

use littlebit2::artifact::{
    read_method_stack, write_method_stack_aligned, write_stack_v1, ArtifactReader,
    ArtifactWriter, FORMAT_VERSION_V3, TAG_SIGN,
};
use littlebit2::linalg::Mat;
use littlebit2::littlebit::{CompressionConfig, InitStrategy};
use littlebit2::model::{MethodStack, PackedStack};
use littlebit2::packing::{force_scalar, scalar_forced};
use littlebit2::parallel::Pool;
use littlebit2::quant::MethodSpec;
use littlebit2::rng::Pcg64;
use littlebit2::spectral::{synth_weight, SynthSpec};
use std::path::PathBuf;
use std::sync::{Mutex, MutexGuard, OnceLock};

/// Serialize lane-pin manipulation (same pattern as tests/simd_lanes.rs).
fn lane_lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    match LOCK.get_or_init(|| Mutex::new(())).lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

fn with_lane<R>(scalar: bool, f: impl FnOnce() -> R) -> R {
    let pinned = scalar_forced();
    force_scalar(scalar);
    let out = f();
    force_scalar(pinned);
    out
}

/// Synthetic heavy-tailed chain weights; dims deliberately off the 64
/// multiple so bit-planes carry ragged tails (and v3 actually pads).
fn chain_weights(dims: &[usize], seed: u64) -> Vec<Mat> {
    let mut rng = Pcg64::seed(seed);
    dims.windows(2)
        .map(|w| {
            let spec =
                SynthSpec { rows: w[1], cols: w[0], gamma: 0.3, coherence: 0.6, scale: 1.0 };
            synth_weight(&spec, &mut rng)
        })
        .collect()
}

fn method_stack(method: &MethodSpec, dims: &[usize], seed: u64) -> MethodStack {
    let compressor = method.compressor();
    let mut rng = Pcg64::seed(seed ^ 0x5eed);
    let layers = chain_weights(dims, seed)
        .iter()
        .map(|w| compressor.compress_layer(w, Pool::serial(), &mut rng).unwrap())
        .collect();
    MethodStack::uniform(method.name(), layers).unwrap()
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("lb2_mmap_{}_{name}.lb2", std::process::id()))
}

fn assert_forward_bits_equal(a: &Mat, b: &Mat, what: &str) {
    assert_eq!(a.shape(), b.shape(), "{what}: shape");
    for i in 0..a.rows() {
        for (j, (x, y)) in a.row(i).iter().zip(b.row(i)).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: ({i},{j}): {x} vs {y}");
        }
    }
}

/// The headline acceptance case: for a packed (littlebit2), a sign-scaled
/// (onebit), and a dense low-rank (tinyrank) stack, a v3 aligned artifact
/// served via `load_mmap` equals the eager v2 load bit-for-bit — same
/// representation, same batched forwards on the scalar AND the AVX2 lane —
/// and the resident/mapped accounting partitions the eager footprint.
#[test]
fn aligned_mmap_load_is_bit_identical_to_eager_load() {
    for name in ["littlebit2", "onebit", "tinyrank"] {
        let spec = MethodSpec::parse(name, 1.0, InitStrategy::JointItq { iters: 8 }).unwrap();
        let stack = method_stack(&spec, &[44, 70, 44], 91);
        let p2 = tmp(&format!("v2_{name}"));
        let p3 = tmp(&format!("v3_{name}"));
        stack.save(&p2).unwrap();
        stack.save_aligned(&p3).unwrap();
        let eager = MethodStack::load(&p2).unwrap();
        let mapped = MethodStack::load_mmap(&p3).unwrap();
        // Unlinking under a live mapping is fine on unix — the pages stay.
        let _ = std::fs::remove_file(&p2);
        let _ = std::fs::remove_file(&p3);

        assert_eq!(eager, stack, "{name}: eager v2 load must round-trip verbatim");
        // PartialEq compares plane words and scale bits, not backing — the
        // borrowed stack must be indistinguishable content-wise.
        assert_eq!(mapped, stack, "{name}: mmap v3 load must round-trip verbatim");

        // Accounting: eager holds everything on the heap; the mapped stack
        // splits the SAME bytes between heap and page cache, disjointly.
        assert_eq!(eager.mapped_bytes(), 0, "{name}: eager load must not report mappings");
        assert!(eager.resident_bytes() > 0);
        assert_eq!(
            eager.resident_bytes(),
            mapped.resident_bytes() + mapped.mapped_bytes(),
            "{name}: resident+mapped must partition the eager footprint"
        );
        if name == "tinyrank" {
            // Dense low-rank serving forms are always copied into `Mat`s.
            assert_eq!(mapped.mapped_bytes(), 0, "{name}: dense layers never borrow");
        } else if cfg!(unix) {
            assert!(mapped.is_mapped(), "{name}: v3 on unix must borrow the mapping");
            assert!(mapped.mapped_bytes() > 0);
        }

        // Forwards, both lanes: borrowed planes/scales must feed the
        // kernels to the exact bits of the owned copies.
        let mut rng = Pcg64::seed(92);
        let mut x = Mat::zeros(44, 5);
        x.fill_normal(&mut rng);
        let _guard = lane_lock();
        for scalar in [true, false] {
            with_lane(scalar, || {
                let want = eager.forward_batch(&x);
                let got = mapped.forward_batch(&x);
                assert_forward_bits_equal(&want, &got, &format!("{name} scalar={scalar}"));
            });
        }
    }
}

/// `load_mmap` on pre-v3 artifacts: the copy-and-restride fallback loads
/// v2 and v1 files bit-identically, reporting zero mapped bytes.
#[test]
fn mmap_entry_point_reads_v1_and_v2_via_copy_fallback() {
    let mut rng = Pcg64::seed(101);
    let weights = chain_weights(&[70, 90], 101);
    let cfg = CompressionConfig {
        bpp: 1.0,
        strategy: InitStrategy::JointItq { iters: 8 },
        residual: true,
        ..Default::default()
    };
    let packed = PackedStack::compress_chain(&weights, &cfg, &mut rng);

    // v2 through the generic entry point.
    let stack = MethodStack::from(packed.clone());
    let p2 = tmp("fallback_v2");
    stack.save(&p2).unwrap();
    let mapped_v2 = MethodStack::load_mmap(&p2).unwrap();
    let _ = std::fs::remove_file(&p2);
    assert_eq!(mapped_v2, stack, "v2 through load_mmap must match the saved stack");
    assert_eq!(mapped_v2.mapped_bytes(), 0, "v2 payloads are never borrowed");

    // v1 (PR 3/4 era, LAYR-only) through the same entry point.
    let p1 = tmp("fallback_v1");
    let bytes = write_stack_v1(&packed, Vec::new()).unwrap();
    std::fs::write(&p1, &bytes).unwrap();
    let mapped_v1 = MethodStack::load_mmap(&p1).unwrap();
    let _ = std::fs::remove_file(&p1);
    assert_eq!(mapped_v1.mapped_bytes(), 0, "v1 payloads are never borrowed");
    let mut rng = Pcg64::seed(102);
    let mut x = Mat::zeros(70, 3);
    x.fill_normal(&mut rng);
    assert_forward_bits_equal(&packed.forward_batch(&x), &mapped_v1.forward_batch(&x), "v1");

    // And the packed-specific pair: save_aligned → PackedStack::load_mmap.
    let p3 = tmp("fallback_packed_v3");
    packed.save_aligned(&p3).unwrap();
    let packed_mapped = PackedStack::load_mmap(&p3).unwrap();
    let _ = std::fs::remove_file(&p3);
    assert_eq!(packed_mapped, packed, "packed v3 mmap load must round-trip verbatim");
}

/// The corrupt-file matrix on the v3 aligned encoding: truncation at EVERY
/// byte and a flipped bit at every byte must be `Err`, never a panic.
#[test]
fn v3_corruption_matrix_never_panics() {
    let spec = MethodSpec::parse("littlebit2", 1.0, InitStrategy::JointItq { iters: 8 }).unwrap();
    let stack = method_stack(&spec, &[40, 70], 93);
    let bytes = write_method_stack_aligned(&stack, Vec::new()).unwrap();
    assert!(read_method_stack(&bytes).is_ok(), "pristine v3 bytes must load");

    for len in 0..bytes.len() {
        let prefix = bytes[..len].to_vec();
        match std::panic::catch_unwind(|| read_method_stack(&prefix)) {
            Ok(r) => assert!(r.is_err(), "truncation to {len} bytes parsed successfully"),
            Err(_) => panic!("truncation to {len} bytes PANICKED instead of returning Err"),
        }
    }
    for i in 0..bytes.len() {
        let mut bad = bytes.clone();
        bad[i] ^= 0x01;
        match std::panic::catch_unwind(|| read_method_stack(&bad)) {
            Ok(r) => assert!(r.is_err(), "bit flip at byte {i} parsed successfully"),
            Err(_) => panic!("bit flip at byte {i} PANICKED instead of returning Err"),
        }
    }
}

/// The same contract through the real file-backed mmap path, on a coarse
/// grid (the full matrix above runs in-memory): every sampled truncation
/// and bit flip must fail `load_mmap` with `Err`, never a panic.
#[test]
fn v3_corruption_through_mmap_path_errs() {
    let spec = MethodSpec::parse("onebit", 1.0, InitStrategy::Standard).unwrap();
    let stack = method_stack(&spec, &[40, 70], 94);
    let bytes = write_method_stack_aligned(&stack, Vec::new()).unwrap();
    let path = tmp("corrupt");

    for len in (0..bytes.len()).step_by(97) {
        std::fs::write(&path, &bytes[..len]).unwrap();
        assert!(
            MethodStack::load_mmap(&path).is_err(),
            "mmap load of a {len}-byte truncation parsed successfully"
        );
    }
    for i in (0..bytes.len()).step_by(131) {
        let mut bad = bytes.clone();
        bad[i] ^= 0x01;
        std::fs::write(&path, &bad).unwrap();
        assert!(
            MethodStack::load_mmap(&path).is_err(),
            "mmap load with bit flip at byte {i} parsed successfully"
        );
    }
    let _ = std::fs::remove_file(&path);
}

/// Dirty padding under a **valid CRC** (a plain bit flip is caught by the
/// container checksum; this rebuilds the trailer) must still be rejected:
/// the alignment filler between scales and planes, and the per-row pad
/// words of a plane, are required zero by the v3 contract.
#[test]
fn dirty_v3_padding_rejected_even_with_valid_crc() {
    // One onebit layer, 45×70: the SGNS payload is 16 header bytes +
    // 4·(45+70) scale bytes = 476, so 4 filler bytes pad to the plane at
    // 480; the plane rows are padded_stride(70) = 4 words against 2 tight
    // words, so bytes 496..512 of row 0 are pad words.
    let spec = MethodSpec::parse("onebit", 1.0, InitStrategy::Standard).unwrap();
    let stack = method_stack(&spec, &[70, 45], 95);
    let bytes = write_method_stack_aligned(&stack, Vec::new()).unwrap();

    // Re-emit all sections verbatim except a payload edit at `poke`,
    // recomputing the trailer so only the semantic check can object.
    let tamper = |poke: usize, value: u8| -> Vec<u8> {
        let mut r = ArtifactReader::new(&bytes).unwrap();
        let mut w = ArtifactWriter::with_version(Vec::new(), FORMAT_VERSION_V3).unwrap();
        while let Some((tag, body)) = r.next_section() {
            if tag == TAG_SIGN {
                let mut body = body.to_vec();
                assert_eq!(body[poke], 0, "expected to poke a zero pad byte");
                body[poke] = value;
                w.section(tag, &body).unwrap();
            } else {
                w.section(tag, body).unwrap();
            }
        }
        w.finish().unwrap()
    };

    let dirty_filler = tamper(476, 0xFF);
    let err = read_method_stack(&dirty_filler).unwrap_err();
    assert!(format!("{err:?}").contains("alignment filler"), "{err:?}");

    let dirty_pad_word = tamper(480 + 20, 0xFF);
    let err = read_method_stack(&dirty_pad_word).unwrap_err();
    let msg = format!("{err:?}");
    assert!(msg.contains("pad words") || msg.contains("padding"), "{msg}");
}
