//! Chaos soak: the serving stack under seeded fault injection at both
//! boundaries at once — wire faults (short reads/writes, delays, bit
//! corruption, mid-frame resets) on every server connection, and backend
//! faults (panics, stalls, wrong-shape outputs) in every worker — driven
//! by retrying clients until every request is answered.
//!
//! The soak is bounded and fully deterministic on the server/fault side:
//! `LB2_CHAOS_SEED` (default `0xC4A055ED`) fixes the fault schedule, so a
//! CI failure replays locally with one env var. What the soak asserts:
//!
//! - every accepted request is answered exactly once — the final counters
//!   reconcile as `accepted == served + failed + deadline_missed`;
//! - every answer a client accepts is **bit-identical** to the in-process
//!   `MethodStack::forward` (faults are detectable-by-construction: they
//!   can delay or kill an answer, never silently change it);
//! - nothing deadlocks (a watchdog bounds the whole soak);
//! - the drain is clean: `queue_depth == 0` after shutdown.

use littlebit2::coordinator::{MethodStackBackend, ServerConfig};
use littlebit2::faults::{ChaosBackend, FaultPlan, FaultSpec, FaultyStream};
use littlebit2::littlebit::InitStrategy;
use littlebit2::model::MethodStack;
use littlebit2::parallel::Pool;
use littlebit2::quant::MethodSpec;
use littlebit2::rng::{derive_seed, Pcg64};
use littlebit2::serving::{
    RetryPolicy, RetryingClient, ServingConfig, TcpFrontend, WireClient,
};
use littlebit2::spectral::{synth_weight, SynthSpec};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

/// Fixed default so CI runs are replayable; override with
/// `LB2_CHAOS_SEED=<u64>` to explore (a failure prints the seed).
const DEFAULT_SEED: u64 = 0xC4A0_55ED;

fn chaos_seed() -> u64 {
    std::env::var("LB2_CHAOS_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(DEFAULT_SEED)
}

/// A depth-2 48-feature littlebit2 stack (same shape the TCP serving
/// tests use) — small enough to soak quickly, deep enough that an answer
/// exercises the full packed pipeline.
fn method_stack(seed: u64) -> Arc<MethodStack> {
    let mut rng = Pcg64::seed(seed);
    let spec = MethodSpec::parse("littlebit2", 1.0, InitStrategy::JointItq { iters: 10 }).unwrap();
    let layers = (0..2)
        .map(|_| {
            let w = synth_weight(
                &SynthSpec { rows: 48, cols: 48, gamma: 0.3, coherence: 0.6, scale: 1.0 },
                &mut rng,
            );
            spec.compressor().compress_layer(&w, Pool::serial(), &mut rng).unwrap()
        })
        .collect();
    Arc::new(MethodStack::uniform("littlebit2", layers).unwrap())
}

fn assert_bits_eq(got: &[f32], want: &[f32], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length");
    for (j, (a, b)) in got.iter().zip(want).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{ctx}: element {j}: {a} vs {b}");
    }
}

/// The reproducibility contract at the harness level: the soak's own seed
/// (env or default) yields byte-identical fault schedules across plans —
/// what makes a red CI run replayable on a laptop.
#[test]
fn fault_schedule_is_reproducible_from_env_seed() {
    let seed = chaos_seed();
    let a = FaultPlan::new(seed, FaultSpec::moderate());
    let b = FaultPlan::new(seed, FaultSpec::moderate());
    for idx in 0..8u64 {
        assert_eq!(
            a.stream_injector(idx).schedule(1024),
            b.stream_injector(idx).schedule(1024),
            "seed {seed:#x}: stream schedule diverged at index {idx}"
        );
        assert_eq!(
            a.backend_injector(idx).schedule(1024),
            b.backend_injector(idx).schedule(1024),
            "seed {seed:#x}: backend schedule diverged at index {idx}"
        );
    }
}

/// The soak itself: 4 retrying clients × 32 pipelined requests against a
/// server with wire faults on every connection and chaos backends on
/// every worker. Every request must eventually be answered bit-identical
/// to the in-process forward; the counters must reconcile; a watchdog
/// converts any deadlock into a failure.
#[test]
fn soak_under_wire_and_backend_faults() {
    let seed = chaos_seed();
    let stack = method_stack(derive_seed(seed, 1));
    let plan = Arc::new(FaultPlan::new(seed, FaultSpec::moderate()));

    let cfg = ServingConfig {
        poll: Duration::from_millis(5),
        expect_width: Some(stack.d_in()),
        faults: Some(Arc::clone(&plan)),
        batch: ServerConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            queue_depth: 64,
            workers: 2,
            ..Default::default()
        },
        ..Default::default()
    };
    let backend_stack = Arc::clone(&stack);
    let backend_plan = Arc::clone(&plan);
    let front = TcpFrontend::start("127.0.0.1:0", cfg, move |w| {
        ChaosBackend::new(
            MethodStackBackend::new(Arc::clone(&backend_stack), 2),
            backend_plan.backend_injector(w as u64),
        )
    })
    .unwrap();
    let addr = front.local_addr();

    let (done_tx, done_rx) = std::sync::mpsc::channel();
    let mut threads = Vec::new();
    for c in 0..4u64 {
        let stack = Arc::clone(&stack);
        let done_tx = done_tx.clone();
        threads.push(std::thread::spawn(move || {
            let policy = RetryPolicy {
                max_attempts: 64,
                base_backoff: Duration::from_millis(2),
                max_backoff: Duration::from_millis(50),
                budget: None,
                // Short op timeout: a reply dropped by an injected fault
                // costs one timeout, then the round resends it.
                op_timeout: Duration::from_millis(2000),
                jitter_seed: derive_seed(seed, 100 + c),
            };
            let mut client = RetryingClient::connect(addr, policy);
            let mut rng = Pcg64::seed(derive_seed(seed, 200 + c));
            let reqs: Vec<(u64, Vec<f32>)> = (0..32u64)
                .map(|r| {
                    let mut x = vec![0.0f32; stack.d_in()];
                    rng.fill_normal(&mut x);
                    (c * 1_000_000 + r, x)
                })
                .collect();
            let got = client
                .infer_many(&reqs, 0)
                .unwrap_or_else(|e| panic!("seed {seed:#x}: client {c} gave up: {e}"));
            for (i, (_, x)) in reqs.iter().enumerate() {
                assert_bits_eq(
                    &got[i],
                    &stack.forward(x),
                    &format!("seed {seed:#x}: client {c} req {i}"),
                );
            }
            let _ = done_tx.send((client.retried, client.reconnects));
        }));
    }
    drop(done_tx);

    // Watchdog: the soak must make progress — a deadlock anywhere in the
    // fault path fails the test instead of hanging CI.
    let watchdog = Duration::from_secs(120);
    let mut retried = 0u64;
    let mut reconnects = 0u64;
    for _ in 0..4 {
        match done_rx.recv_timeout(watchdog) {
            Ok((r, k)) => {
                retried += r;
                reconnects += k;
            }
            Err(_) => panic!("seed {seed:#x}: chaos soak stalled (> {watchdog:?} per client)"),
        }
    }
    for t in threads {
        t.join().unwrap();
    }
    println!("chaos soak seed {seed:#x}: {retried} request-retries, {reconnects} reconnects");

    let stats = front.shutdown();
    // Exactly-once accounting: every accepted submission was answered as
    // served, failed, or expired — nothing lost, nothing double-counted.
    assert_eq!(
        stats.accepted,
        stats.served + stats.failed + stats.deadline_missed,
        "seed {seed:#x}: accepted != served + failed + deadline_missed ({stats:?})"
    );
    // Clean drain: nothing left in the ingress queue after shutdown.
    assert_eq!(stats.queue_depth, 0, "seed {seed:#x}: queue not drained ({stats:?})");
    // All 128 logical requests got a Result at least once server-side.
    assert!(
        stats.served >= 128,
        "seed {seed:#x}: {} served < 128 logical requests ({stats:?})",
        stats.served
    );
}

/// Client-side faults: a [`RetryingClient`] dialing through
/// [`FaultyStream`]-wrapped connections (corruption, short ops, delays on
/// the client's own wire) completes a full pipelined pass against a clean
/// server, and a sequential replay through the same client returns
/// bit-identical answers — retries and reconnects never change the bits.
#[test]
fn retrying_pipelined_pass_bit_identical_to_sequential_replay() {
    let seed = chaos_seed();
    let stack = method_stack(derive_seed(seed, 2));

    let cfg = ServingConfig {
        poll: Duration::from_millis(5),
        expect_width: Some(stack.d_in()),
        batch: ServerConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(2),
            queue_depth: 64,
            workers: 2,
            ..Default::default()
        },
        ..Default::default()
    };
    let backend_stack = Arc::clone(&stack);
    let front = TcpFrontend::start("127.0.0.1:0", cfg, move |_w| {
        MethodStackBackend::new(Arc::clone(&backend_stack), 2)
    })
    .unwrap();
    let addr = front.local_addr();

    // Wire faults on the client side only; no resets so the exercise is
    // recoverable damage (corruption → CRC → reconnect; shorts/delays →
    // transparent), with the schedule still seed-determined.
    let plan = FaultPlan::new(
        derive_seed(seed, 3),
        FaultSpec { corrupt: 0.01, short: 0.20, delay: 0.05, ..Default::default() },
    );
    let mut dial = 0u64;
    let policy = RetryPolicy {
        max_attempts: 32,
        base_backoff: Duration::from_millis(2),
        op_timeout: Duration::from_millis(1000),
        jitter_seed: derive_seed(seed, 4),
        ..Default::default()
    };
    let mut client = RetryingClient::with_connector(policy, move || {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        stream.set_read_timeout(Some(Duration::from_millis(1000)))?;
        let idx = dial;
        dial += 1;
        Ok(WireClient::over(FaultyStream::new(stream, plan.stream_injector(idx))))
    });

    let mut rng = Pcg64::seed(derive_seed(seed, 5));
    let reqs: Vec<(u64, Vec<f32>)> = (0..24u64)
        .map(|r| {
            let mut x = vec![0.0f32; stack.d_in()];
            rng.fill_normal(&mut x);
            (r, x)
        })
        .collect();

    let pipelined = client
        .infer_many(&reqs, 0)
        .unwrap_or_else(|e| panic!("seed {seed:#x}: pipelined pass gave up: {e}"));

    // Sequential replay through the same faulty client: different batch
    // shapes server-side, fresh fault draws client-side — same bits.
    for (i, (id, x)) in reqs.iter().enumerate() {
        let again = client
            .infer(1_000_000 + id, x, 0)
            .unwrap_or_else(|e| panic!("seed {seed:#x}: replay of req {i} gave up: {e}"));
        assert_bits_eq(&again, &pipelined[i], &format!("seed {seed:#x}: replay req {i}"));
        assert_bits_eq(&again, &stack.forward(x), &format!("seed {seed:#x}: forward req {i}"));
    }

    let stats = front.shutdown();
    assert_eq!(
        stats.accepted,
        stats.served + stats.failed + stats.deadline_missed,
        "seed {seed:#x}: counters did not reconcile ({stats:?})"
    );
}
