//! The method-generic engine contract, end to end: every registered
//! method compresses → saves (`.lb2` v2) → loads → serves **bit-exactly**;
//! a frozen v1 artifact still decodes as an all-`Packed` littlebit2 stack
//! with bit-identical forwards; and every malformed METHOD tag, spliced
//! payload, truncation, or bit flip is an `Err` — never a panic.

use littlebit2::artifact::{
    read_method_stack, write_stack_v1, ArtifactReader, ArtifactWriter, TAG_META, TAG_METHOD,
    TAG_STACK,
};
use littlebit2::coordinator::{InferenceServer, MethodStackBackend, ServerConfig};
use littlebit2::linalg::Mat;
use littlebit2::littlebit::{CompressionConfig, InitStrategy};
use littlebit2::model::{MethodStack, MethodStackLayer, PackedStack};
use littlebit2::parallel::Pool;
use littlebit2::quant::{MethodSpec, METHOD_NAMES};
use littlebit2::rng::Pcg64;
use littlebit2::spectral::{synth_weight, SynthSpec};
use std::sync::Arc;
use std::time::Duration;

/// Synthetic heavy-tailed chain weights; every dim deliberately not a
/// multiple of 64 so the packed variants carry ragged tail words.
fn chain_weights(dims: &[usize], seed: u64) -> Vec<Mat> {
    let mut rng = Pcg64::seed(seed);
    dims.windows(2)
        .map(|w| {
            let spec =
                SynthSpec { rows: w[1], cols: w[0], gamma: 0.3, coherence: 0.6, scale: 1.0 };
            synth_weight(&spec, &mut rng)
        })
        .collect()
}

/// Compress a chain with one method via the `Compressor` registry.
fn method_stack(method: &MethodSpec, dims: &[usize], seed: u64) -> MethodStack {
    let weights = chain_weights(dims, seed);
    let compressor = method.compressor();
    let mut rng = Pcg64::seed(seed ^ 0x5eed);
    let layers = weights
        .iter()
        .map(|w| compressor.compress_layer(w, Pool::serial(), &mut rng).unwrap())
        .collect();
    MethodStack::uniform(method.name(), layers).unwrap()
}

fn all_method_specs() -> Vec<MethodSpec> {
    METHOD_NAMES
        .iter()
        .map(|name| {
            MethodSpec::parse(name, 1.0, InitStrategy::JointItq { iters: 8 }).unwrap()
        })
        .collect()
}

/// The acceptance pipeline, per method: compress → v2 bytes → load →
/// bit-identical representation AND bit-identical batched forwards —
/// then through actual files and the serving pool.
#[test]
fn every_method_roundtrips_bit_exactly() {
    for spec in all_method_specs() {
        let stack = method_stack(&spec, &[44, 70, 44], 11);
        let bytes = stack.to_artifact_bytes().unwrap();
        let loaded = MethodStack::from_artifact_bytes(&bytes).unwrap();
        assert_eq!(loaded, stack, "{}: representation must round-trip verbatim", spec.name());
        assert_eq!(loaded.method_summary(), spec.name());

        let mut rng = Pcg64::seed(12);
        let b = 5;
        let mut x = Mat::zeros(44, b);
        x.fill_normal(&mut rng);
        let want = stack.forward_batch(&x);
        let got = loaded.forward_batch(&x);
        for t in 0..b {
            for i in 0..44 {
                assert_eq!(
                    got.at(i, t).to_bits(),
                    want.at(i, t).to_bits(),
                    "{}: loaded forward differs at ({i},{t})",
                    spec.name()
                );
            }
        }
    }
}

/// compress → save file → load → SERVE, per method: responses off the
/// multi-worker pool running the loaded artifact are bit-identical to the
/// original stack's forwards. (`--method onebit` end-to-end is the
/// issue's named acceptance case; every other method rides the same
/// assertion.)
#[test]
fn every_method_serves_loaded_artifact_bit_exactly() {
    for spec in all_method_specs() {
        let stack = method_stack(&spec, &[40, 56], 21);
        let path = std::env::temp_dir().join(format!(
            "lb2_method_{}_{}.lb2",
            spec.name(),
            std::process::id()
        ));
        stack.save(&path).unwrap();
        let loaded = Arc::new(MethodStack::load(&path).unwrap());
        let _ = std::fs::remove_file(&path);

        let server = InferenceServer::start_pool(
            ServerConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(2),
                queue_depth: 64,
                workers: 2,
                ..Default::default()
            },
            |_worker| MethodStackBackend::new(Arc::clone(&loaded), 2),
        );
        let mut rng = Pcg64::seed(22);
        let mut inputs = Vec::new();
        for _ in 0..8 {
            let mut x = vec![0.0f32; 40];
            rng.fill_normal(&mut x);
            inputs.push(x);
        }
        let rxs: Vec<_> = inputs
            .iter()
            .enumerate()
            .map(|(i, x)| server.submit(i as u64, x.clone()))
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv().unwrap();
            let want = stack.forward(&inputs[i]);
            for (j, (a, b)) in resp.output.iter().zip(&want).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{}: request {i} output {j}",
                    spec.name()
                );
            }
        }
        let stats = server.shutdown();
        assert_eq!(stats.failed, 0, "{}", spec.name());
    }
}

/// A mixed-method chain (one layer per serving-form variant) survives the
/// full artifact roundtrip with bit-exact forwards.
#[test]
fn mixed_method_chain_roundtrips() {
    let weights = chain_weights(&[44, 70, 52, 44, 60], 31);
    let specs = [
        MethodSpec::parse("littlebit2", 1.0, InitStrategy::JointItq { iters: 8 }).unwrap(),
        MethodSpec::OneBit { als_iters: 10 },
        MethodSpec::Rtn { k: 2, group: 32 },
        MethodSpec::TinyRankFp16 { bpp: 1.0 },
    ];
    let mut rng = Pcg64::seed(32);
    let layers: Vec<MethodStackLayer> = weights
        .iter()
        .zip(&specs)
        .map(|(w, spec)| MethodStackLayer {
            method: spec.name().to_string(),
            layer: spec.compressor().compress_layer(w, Pool::serial(), &mut rng).unwrap(),
        })
        .collect();
    let stack = MethodStack::try_new(layers).unwrap();
    assert_eq!(stack.method_summary(), "mixed");

    let loaded = MethodStack::from_artifact_bytes(&stack.to_artifact_bytes().unwrap()).unwrap();
    assert_eq!(loaded, stack);
    let mut x = Mat::zeros(44, 3);
    x.fill_normal(&mut rng);
    assert_eq!(loaded.forward_batch(&x), stack.forward_batch(&x));
    // Methods survive per layer, in order.
    let methods: Vec<&str> = loaded.layers().iter().map(|l| l.method.as_str()).collect();
    assert_eq!(methods, vec!["littlebit2", "onebit", "rtn", "tinyrank"]);
}

/// v1 back-compat: bytes produced by the frozen v1 emitter (what PR 3/4
/// builds wrote) load under the v2 reader as an all-`Packed` littlebit2
/// stack whose forwards are bit-identical — through both the
/// `MethodStack` and the legacy `PackedStack` entry points.
#[test]
fn v1_artifact_loads_as_packed_stack_bit_exactly() {
    let weights = chain_weights(&[70, 90, 70], 41);
    let cfg = CompressionConfig {
        bpp: 1.0,
        strategy: InitStrategy::JointItq { iters: 8 },
        residual: true,
        ..Default::default()
    };
    let mut rng = Pcg64::seed(42);
    let packed = PackedStack::compress_chain(&weights, &cfg, &mut rng);
    let v1_bytes = write_stack_v1(&packed, Vec::new()).unwrap();
    assert_eq!(
        &v1_bytes[4..8],
        1u32.to_le_bytes().as_slice(),
        "fixture must be format v1"
    );

    // v2 reader, method entry point: all layers Packed + littlebit2.
    let via_method = MethodStack::from_artifact_bytes(&v1_bytes).unwrap();
    assert_eq!(via_method.method_summary(), "littlebit2");
    assert_eq!(via_method.depth(), 2);
    // Legacy packed entry point still reads v1 directly.
    let via_packed = PackedStack::from_artifact_bytes(&v1_bytes).unwrap();
    assert_eq!(via_packed, packed, "v1 decode must reproduce the packed representation");

    let mut x = Mat::zeros(70, 4);
    x.fill_normal(&mut rng);
    let want = packed.forward_batch(&x);
    assert_eq!(via_method.forward_batch(&x), want);
    assert_eq!(via_packed.forward_batch(&x), want);

    // And a v1 fixture re-saved through the modern path upgrades to v2
    // with identical numbers.
    let v2_bytes = via_method.to_artifact_bytes().unwrap();
    assert_eq!(&v2_bytes[4..8], 2u32.to_le_bytes().as_slice());
    let upgraded = MethodStack::from_artifact_bytes(&v2_bytes).unwrap();
    assert_eq!(upgraded.forward_batch(&x), want);
}

/// The truncate-every-byte / flip-every-byte harness (from
/// `artifact_roundtrip.rs`), run against a **mixed-method v2** artifact:
/// every prefix and every one-bit corruption is an `Err`, never a panic.
#[test]
fn corrupt_v2_matrix_never_panics() {
    let weights = chain_weights(&[33, 40], 51);
    let specs =
        [MethodSpec::OneBit { als_iters: 5 }, MethodSpec::TinyRankFp16 { bpp: 1.0 }];
    let mut rng = Pcg64::seed(52);
    // Two single-layer stacks → two artifacts exercised; keep sizes tiny
    // because the harness is O(bytes²).
    for spec in specs {
        let layer = spec.compressor().compress_layer(&weights[0], Pool::serial(), &mut rng);
        let stack = MethodStack::uniform(spec.name(), vec![layer.unwrap()]).unwrap();
        let bytes = stack.to_artifact_bytes().unwrap();

        for len in 0..bytes.len() {
            let prefix = bytes[..len].to_vec();
            let result = std::panic::catch_unwind(|| read_method_stack(&prefix));
            match result {
                Ok(r) => {
                    assert!(r.is_err(), "{}: truncation to {len} bytes parsed", spec.name())
                }
                Err(_) => panic!("{}: truncation to {len} bytes PANICKED", spec.name()),
            }
        }
        for i in 0..bytes.len() {
            let mut bad = bytes.clone();
            bad[i] ^= 0x01;
            let result = std::panic::catch_unwind(|| read_method_stack(&bad));
            match result {
                Ok(r) => assert!(r.is_err(), "{}: bit flip at byte {i} parsed", spec.name()),
                Err(_) => panic!("{}: bit flip at byte {i} PANICKED", spec.name()),
            }
        }
    }
}

/// Rebuild a valid artifact with one section's payload swapped — valid
/// CRC and framing, so only the METHOD-tag semantic checks can reject it.
fn resplice(bytes: &[u8], mutate: impl FnOnce(&mut Vec<([u8; 4], Vec<u8>)>)) -> Vec<u8> {
    let mut r = ArtifactReader::new(bytes).unwrap();
    let mut sections = Vec::new();
    while let Some((tag, body)) = r.next_section() {
        sections.push((tag, body.to_vec()));
    }
    mutate(&mut sections);
    let mut w = ArtifactWriter::new(Vec::new()).unwrap();
    for (tag, body) in &sections {
        w.section(*tag, body).unwrap();
    }
    w.finish().unwrap()
}

/// Corrupt METHOD tags — unknown variant code, truncated/lying name
/// length, name/payload tag mismatch, missing METH section — are all
/// `Err` naming the problem, never a panic or a mis-decoded layer.
#[test]
fn corrupt_method_tags_rejected() {
    let spec = MethodSpec::OneBit { als_iters: 5 };
    let stack = method_stack(&spec, &[33, 40], 61);
    let bytes = stack.to_artifact_bytes().unwrap();
    // Sections: [META, STAK, METH, SGNS].
    {
        let mut r = ArtifactReader::new(&bytes).unwrap();
        let tags: Vec<[u8; 4]> = std::iter::from_fn(|| r.next_section().map(|(t, _)| t)).collect();
        assert_eq!(tags, vec![TAG_META, TAG_STACK, TAG_METHOD, *b"SGNS"]);
    }

    // Unknown variant code.
    let bad = resplice(&bytes, |s| s[2].1[0] = 9);
    let err = read_method_stack(&bad).unwrap_err();
    assert!(format!("{err:?}").contains("variant"), "{err:?}");

    // Name length lies about the section size.
    let bad = resplice(&bytes, |s| s[2].1[1] = 200);
    assert!(read_method_stack(&bad).is_err());

    // Non-printable method name bytes.
    let bad = resplice(&bytes, |s| s[2].1[2] = 0x07);
    assert!(read_method_stack(&bad).is_err());

    // Variant code pins the payload tag: claim packed, supply SGNS.
    let bad = resplice(&bytes, |s| s[2].1[0] = 1);
    let err = read_method_stack(&bad).unwrap_err();
    assert!(format!("{err:?}").contains("payload"), "{err:?}");

    // Drop the METH section entirely: v2 requires it before each payload.
    let bad = resplice(&bytes, |s| {
        s.remove(2);
    });
    let err = read_method_stack(&bad).unwrap_err();
    assert!(format!("{err:?}").contains("METH"), "{err:?}");

    // Swap in a DNSE payload whose shape matches the table but whose tag
    // contradicts the sign variant.
    let bad = resplice(&bytes, |s| s[3].0 = *b"DNSE");
    assert!(read_method_stack(&bad).is_err());

    // The intact bytes still load (the resplice harness itself is sound).
    assert!(read_method_stack(&bytes).is_ok());
}

/// The exact v2 layer-payload byte count, derived from the layer's public
/// shape — the independently-written oracle the on-disk audit checks the
/// encoders against (EXPERIMENTS.md §Artifact records the reconciliation
/// between these sizes and the declared App. H bits).
fn expected_payload_bytes(layer: &littlebit2::model::MethodLayer) -> usize {
    use littlebit2::model::MethodLayer;
    match layer {
        MethodLayer::Packed(l) => {
            4 + l
                .paths()
                .iter()
                .map(|p| {
                    12 + 4 * (p.d_out() + p.rank() + p.d_in())
                        + 8 * (p.d_out() * p.rank().div_ceil(64)
                            + p.rank() * p.d_in().div_ceil(64))
                })
                .sum::<usize>()
        }
        MethodLayer::SignScaled(l) => {
            16 + 4 * (l.d_out() + l.d_in()) + 8 * l.d_out() * l.d_in().div_ceil(64)
        }
        MethodLayer::DenseScaled(l) => 16 + 4 * l.d_out() * l.d_in(),
        MethodLayer::LowRankFp(l) => {
            20 + 4 * (l.d_out() * l.rank() + l.rank() * l.d_in())
        }
    }
}

/// Declared-vs-disk accounting audit (the EXPERIMENTS.md §Artifact
/// reconciliation, as a pinned test). Per method: the artifact's size is
/// exactly the per-variant payload (scales at f32, bit-planes word-padded
/// per row) plus bounded container framing — so every byte of drift
/// between `declared_bits()` (App. H / `QuantResult::bpp` accounting) and
/// the file is attributable to the three documented terms: f32-on-disk
/// scales, tail-word padding, and O(sections) framing. Dense-form
/// baselines persist their f32 reconstruction (32 bpp on disk) while
/// their declared accounting stays method-faithful — by design.
#[test]
fn declared_vs_disk_accounting_reconciles() {
    let dims = [60, 100]; // ragged: tail-word padding must be accounted
    let params = (dims[0] * dims[1]) as f64;
    for spec in all_method_specs() {
        let stack = method_stack(&spec, &dims, 71);
        let bytes = stack.to_artifact_bytes().unwrap();
        let payload: usize =
            stack.layers().iter().map(|l| expected_payload_bytes(&l.layer)).sum();
        // Framing: 8 header + META/STAK/METH sections + per-section 12-byte
        // tag+len + 20 trailer — bounded, independent of weight bytes.
        let framing = bytes.len() as i64 - payload as i64;
        assert!(
            (0..=300).contains(&framing),
            "{}: file {} vs payload {payload} (framing {framing})",
            spec.name(),
            bytes.len()
        );
        match spec.name() {
            // Disk adds slack (f32 scales, padding, framing) but never
            // hides bits: declared ≤ disk for these serving forms.
            name @ ("littlebit2" | "onebit" | "tinyrank") => assert!(
                stack.declared_bits() as f64 / 8.0 <= bytes.len() as f64,
                "{name}: declared exceeds disk"
            ),
            // ARB declares the full App. H Eq. 24 structure (residual
            // copies + bitmaps) while this repo serves the collapsed
            // diag(a)·B·diag(b) — declared intentionally exceeds disk
            // (recorded in EXPERIMENTS.md §Artifact).
            "arb" => assert!(
                stack.declared_bits() as f64 / 8.0 > bytes.len() as f64,
                "arb: Eq. 24 accounting should exceed the collapsed serving form"
            ),
            // Dense-form baselines are 32 bpp on disk with
            // method-faithful declared bits (the recorded deviation).
            name => {
                let disk_bpp = bytes.len() as f64 * 8.0 / params;
                assert!(disk_bpp > 32.0 && disk_bpp < 34.0, "{name}: disk bpp {disk_bpp}");
                assert!(stack.declared_bits() as f64 / params < 8.0, "{name}");
            }
        }
    }
}

/// The RTN group-accounting regression at the QuantResult level: per-row
/// ragged groups are charged per row (the quantizer's actual layout), so
/// declared bpp matches a hand count on a ragged shape.
#[test]
fn rtn_bpp_accounts_ragged_groups_per_row() {
    let mut rng = Pcg64::seed(81);
    let w = Mat::gaussian(3, 100, &mut rng);
    let q = littlebit2::quant::rtn(&w, 2, 64);
    // 3 rows × 2 groups each (64 + 36), 32 bits of FP16 scale+zero per
    // group, 2 bits per weight.
    assert_eq!(q.bits, 300 * 2 + 6 * 32);
    assert!((q.bpp() - (600.0 + 192.0) / 300.0).abs() < 1e-12);
}
