//! Cross-module integration tests: synthesis → SVD → ITQ → SVID → packing
//! → serving, plus the theory-vs-measurement consistency checks that span
//! spectral + littlebit + quant.

use littlebit2::coordinator::{
    run_compression_jobs, CompressionJob, InferenceServer, PackedResidualBackend, ServerConfig,
};
use littlebit2::linalg::svd_randomized;
use littlebit2::littlebit::{compress, CompressionConfig, InitStrategy};
use littlebit2::memory::{littlebit_rank_for_budget, tiny_rank_for_budget};
use littlebit2::model::{zoo, ArchSpec, PackedStack};
use littlebit2::quant::{local_distortion, tiny_rank_fp16};
use littlebit2::rng::Pcg64;
use littlebit2::spectral::{
    break_even_gamma, discrete, estimate_gamma, synth_weight, SynthSpec,
};
use std::sync::Arc;
use std::time::Duration;

/// The paper's Fig 6 phase transition, end to end: at γ=0.2 (heavy tail)
/// LittleBit-2 must beat Tiny-Rank FP16 at 1 bpp; at γ=0.8 (light tail)
/// FP16 must win.
#[test]
fn break_even_phase_transition() {
    let size = 256;
    let bpp = 1.0;
    let mse_at = |gamma: f64| {
        let mut rng = Pcg64::seed(1);
        let spec = SynthSpec { rows: size, cols: size, gamma, coherence: 0.7, scale: 1.0 };
        let w = synth_weight(&spec, &mut rng);
        let r_fp = tiny_rank_for_budget(size, size, bpp);
        let fp = tiny_rank_fp16(&w, r_fp, &mut rng).reconstruction.mse(&w);
        let cfg = CompressionConfig {
            bpp,
            strategy: InitStrategy::JointItq { iters: 30 },
            residual: true,
            ..Default::default()
        };
        let itq = compress(&w, &cfg, &mut rng).reconstruct().mse(&w);
        (fp, itq)
    };
    let (fp_heavy, itq_heavy) = mse_at(0.2);
    assert!(itq_heavy < fp_heavy, "heavy tail: itq {itq_heavy} !< fp {fp_heavy}");
    // At 256² the affordable binary/FP rank ratio is ~6 (not the paper's
    // ~16) and the residual+ITQ λ is low, which *extends* the binary-
    // favorable range well past the paper's γ*≈0.51 (see benches/breakeven
    // for the measured crossover); γ=2.2 concentrates ~all energy in the
    // top-8 ranks the FP16 baseline keeps exactly, so FP16 must win there.
    let (fp_light, itq_light) = mse_at(2.2);
    assert!(fp_light < itq_light, "light tail: fp {fp_light} !< itq {itq_light}");
}

/// Theory consistency: the measured strategy-B error on a discrete spectrum
/// must track the Eq. 3 decomposition (trunc + Λ·head) within a small
/// constant factor.
#[test]
fn measured_error_tracks_eq3_decomposition() {
    let size = 256;
    let mut rng = Pcg64::seed(2);
    let spec = SynthSpec { rows: size, cols: size, gamma: 0.3, coherence: 0.7, scale: 1.0 };
    let w = synth_weight(&spec, &mut rng);
    let rank = littlebit_rank_for_budget(size, size, 0.55);

    // Measure the factors' actual mean λ after ITQ.
    let svd = svd_randomized(&w, rank, 10, 2, &mut rng);
    let (u, v) = svd.split_factors();
    let (rot, _) = littlebit2::littlebit::joint_itq(&u, &v, 30, &mut rng);
    let u_rot = u.matmul(&rot);
    let lam: f64 = (0..u_rot.rows())
        .map(|i| local_distortion(u_rot.row(i)))
        .sum::<f64>()
        / u_rot.rows() as f64;
    // Compound both factors (Eq. 5): Λ ≈ 1-(1-λ)².
    let big_lambda = 1.0 - (1.0 - lam) * (1.0 - lam);

    let s: Vec<f32> = svd_randomized(&w, size.min(200), 10, 3, &mut rng).s;
    let predicted = discrete::strategy_b_error(&s, rank, big_lambda) / (size * size) as f64;

    let cfg = CompressionConfig {
        bpp: 0.55,
        strategy: InitStrategy::JointItq { iters: 30 },
        residual: false,
        ..Default::default()
    };
    let mut rng2 = Pcg64::seed(3);
    let measured = littlebit2::littlebit::compress_single(&w, rank, &cfg, &mut rng2)
        .reconstruct()
        .mse(&w);
    assert!(
        measured < predicted * 2.0 && measured > predicted * 0.3,
        "measured {measured:.3e} vs Eq.3 prediction {predicted:.3e}"
    );
}

/// γ* from the continuous model must land inside the plausible band.
#[test]
fn gamma_star_in_empirical_band() {
    let be = break_even_gamma(0.45, 16.0, 256.0, 4096.0);
    assert!((0.25..0.75).contains(&be.gamma_star), "γ*={}", be.gamma_star);
}

/// Zoo → parallel compression → γ estimation, the full analysis pipeline.
#[test]
fn zoo_compression_pipeline() {
    // llama2-7b at ÷32: every layer is ≥128 wide, so the 1.0 bpp budget is
    // feasible (GQA's 32-wide K/V at deeper shrinks bottom out above it).
    let arch = ArchSpec::llama2_7b();
    let layers = zoo::fabricate(&arch, 32, 1, 9);
    let jobs: Vec<CompressionJob> = layers
        .iter()
        .enumerate()
        .map(|(i, l)| {
            CompressionJob::dense(
                l.proj.name(),
                l.weight.clone(),
                CompressionConfig {
                    bpp: 1.0,
                    strategy: InitStrategy::JointItq { iters: 10 },
                    residual: true,
                    ..Default::default()
                },
                i as u64,
            )
        })
        .collect();
    let results = run_compression_jobs(jobs, 2).unwrap();
    assert_eq!(results.len(), 7);
    for r in &results {
        assert!(r.mse.is_finite());
        assert!(r.bpp <= 1.0 + 1e-9, "{}: bpp {}", r.name, r.bpp);
    }
    // γ estimation on a zoo layer matches its target.
    let mut rng = Pcg64::seed(10);
    let svd = svd_randomized(&layers[0].weight, 48, 8, 3, &mut rng);
    let fit = estimate_gamma(&svd.s);
    assert!((fit.gamma - layers[0].gamma).abs() < 0.15);
}

/// Packed serving through the dynamic batcher returns numerically correct
/// results under concurrency.
#[test]
fn serving_pipeline_correctness() {
    let mut rng = Pcg64::seed(11);
    let spec = SynthSpec { rows: 96, cols: 96, gamma: 0.3, coherence: 0.6, scale: 1.0 };
    let w = synth_weight(&spec, &mut rng);
    let cfg = CompressionConfig { bpp: 1.0, ..Default::default() };
    let c = compress(&w, &cfg, &mut rng);
    let recon = c.reconstruct();
    let layers: Vec<_> = c.paths.iter().map(|p| p.pack()).collect();

    let backend = move |batch: &[Vec<f32>]| -> Vec<Vec<f32>> {
        batch
            .iter()
            .map(|x| {
                let mut out = layers[0].forward(x);
                for layer in &layers[1..] {
                    for (o, v) in out.iter_mut().zip(layer.forward(x)) {
                        *o += v;
                    }
                }
                out
            })
            .collect()
    };
    let server = InferenceServer::start(4, Duration::from_millis(2), 64, backend);

    let mut inputs = Vec::new();
    for _ in 0..12 {
        let mut x = vec![0.0f32; 96];
        rng.fill_normal(&mut x);
        inputs.push(x);
    }
    let rxs: Vec<_> = inputs
        .iter()
        .enumerate()
        .map(|(i, x)| server.submit(i as u64, x.clone()))
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().expect("response");
        let want = recon.matvec(&inputs[i]);
        for (a, b) in resp.output.iter().zip(&want) {
            assert!((a - b).abs() < 2e-2, "req {i}: {a} vs {b}");
        }
    }
    let stats = server.shutdown();
    assert_eq!(stats.served, 12);
}

/// The batched serving path end to end: compress → pack once → multi-worker
/// pool → each drained batch executed as ONE matrix through the sign-GEMM
/// backend — outputs numerically correct, batching observed, tokens/s
/// populated.
#[test]
fn batched_serving_pipeline_correctness() {
    let mut rng = Pcg64::seed(21);
    let spec = SynthSpec { rows: 96, cols: 96, gamma: 0.3, coherence: 0.6, scale: 1.0 };
    let w = synth_weight(&spec, &mut rng);
    let cfg = CompressionConfig { bpp: 1.0, ..Default::default() };
    let c = compress(&w, &cfg, &mut rng);
    let recon = c.reconstruct();
    let model = Arc::new(c.pack());

    let server = InferenceServer::start_pool(
        ServerConfig {
            max_batch: 8,
            // Wide straggler window: the batching assertion below must not
            // flake when the submit loop is descheduled on a loaded runner.
            max_wait: Duration::from_millis(250),
            queue_depth: 64,
            workers: 2,
            ..Default::default()
        },
        |_worker| PackedResidualBackend::new(Arc::clone(&model), 2),
    );

    let mut inputs = Vec::new();
    for _ in 0..16 {
        let mut x = vec![0.0f32; 96];
        rng.fill_normal(&mut x);
        inputs.push(x);
    }
    let rxs: Vec<_> = inputs
        .iter()
        .enumerate()
        .map(|(i, x)| server.submit(i as u64, x.clone()))
        .collect();
    let mut max_batch = 0;
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().expect("response");
        max_batch = max_batch.max(resp.batch_size);
        let want = recon.matvec(&inputs[i]);
        for (a, b) in resp.output.iter().zip(&want) {
            assert!((a - b).abs() < 2e-2, "req {i}: {a} vs {b}");
        }
    }
    assert!(max_batch > 1, "no batch reached the backend (max_batch={max_batch})");
    let stats = server.shutdown();
    assert_eq!(stats.served, 16);
    assert!(stats.tokens_per_s > 0.0);
}

/// Zoo FFN chain → compressed → packed stack → a whole batch through every
/// layer without per-request dispatch, matching the per-item path exactly.
#[test]
fn zoo_ffn_stack_batched_forward() {
    let arch = ArchSpec::llama2_7b();
    let weights = zoo::fabricate_ffn_chain(&arch, 32, 17);
    let cfg = CompressionConfig {
        bpp: 1.0,
        strategy: InitStrategy::JointItq { iters: 10 },
        residual: true,
        ..Default::default()
    };
    let mut rng = Pcg64::seed(18);
    let stack = PackedStack::compress_chain(&weights, &cfg, &mut rng);
    assert_eq!(stack.depth(), 2);
    assert_eq!(stack.d_in(), 128);
    assert_eq!(stack.d_out(), 128);

    let b = 6;
    let mut x = littlebit2::linalg::Mat::zeros(stack.d_in(), b);
    x.fill_normal(&mut rng);
    let batched = stack.forward_batch_mt(&x, 2);
    assert_eq!(batched.shape(), (128, b));
    for t in 0..b {
        let want = stack.forward(&x.col(t));
        for i in 0..stack.d_out() {
            assert_eq!(batched.at(i, t).to_bits(), want[i].to_bits(), "({i},{t})");
        }
    }
}

/// Memory model and actual compressed storage agree across budgets and
/// non-square shapes.
#[test]
fn storage_matches_memory_model() {
    let mut rng = Pcg64::seed(12);
    for (rows, cols) in [(128usize, 96usize), (96, 128)] {
        let spec = SynthSpec { rows, cols, gamma: 0.3, coherence: 0.5, scale: 1.0 };
        let w = synth_weight(&spec, &mut rng);
        for bpp in [0.8, 1.2] {
            let cfg = CompressionConfig { bpp, ..Default::default() };
            let c = compress(&w, &cfg, &mut rng);
            let r = c.paths[0].factors.rank();
            assert_eq!(
                c.storage_bits(),
                littlebit2::memory::littlebit_bits(cols, rows, r),
                "{rows}x{cols}@{bpp}"
            );
        }
    }
}
