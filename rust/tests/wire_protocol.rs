//! Adversarial wire-protocol harness (the PR 3 artifact-harness pattern
//! applied to the first *network* untrusted-input surface): truncate a
//! valid frame at every byte, flip every bit of every byte, and declare
//! hostile lengths — decoding must return `Err`, never panic, and a live
//! server fed the same corruptions must never answer with a RESULT frame
//! (a wrong-id or wrong-payload response) and must keep serving honest
//! clients afterwards.

use littlebit2::linalg::Mat;
use littlebit2::serving::{
    frame::frame_crc, Frame, FrameKind, ServingConfig, TcpFrontend, WireClient,
    DEFAULT_MAX_PAYLOAD, HEADER_LEN, WIRE_MAGIC, WIRE_VERSION,
};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

fn sample_frame() -> Frame {
    Frame::infer(0xDEAD_BEEF, &[1.0, -2.5, 3.25, 0.5], 250)
}

/// Truncation at EVERY byte offset: decode must be a typed `Err`
/// (`catch_unwind` proves it never panics).
#[test]
fn decode_truncation_at_every_byte_never_panics() {
    let bytes = sample_frame().encode();
    for len in 0..bytes.len() {
        let prefix = bytes[..len].to_vec();
        let result = std::panic::catch_unwind(|| Frame::decode(&prefix, DEFAULT_MAX_PAYLOAD));
        match result {
            Ok(r) => assert!(r.is_err(), "truncation to {len} bytes decoded successfully"),
            Err(_) => panic!("truncation to {len} bytes PANICKED instead of returning Err"),
        }
    }
}

/// Every bit of every byte flipped: the per-frame CRC (over header and
/// payload alike) must catch all of them — no flip may decode into a
/// frame, which is precisely the "never a wrong-id response" guarantee.
#[test]
fn decode_bit_flip_matrix_never_panics_or_misdecodes() {
    let bytes = sample_frame().encode();
    for i in 0..bytes.len() {
        for bit in 0..8 {
            let mut bad = bytes.clone();
            bad[i] ^= 1 << bit;
            let result = std::panic::catch_unwind(|| Frame::decode(&bad, DEFAULT_MAX_PAYLOAD));
            match result {
                Ok(r) => assert!(
                    r.is_err(),
                    "flip of byte {i} bit {bit} decoded successfully: {:?}",
                    r.unwrap().0
                ),
                Err(_) => panic!("flip of byte {i} bit {bit} PANICKED"),
            }
        }
    }
}

/// A hostile declared length — even with a *valid* CRC over the header —
/// is rejected on the cap alone, before any payload allocation.
#[test]
fn oversize_declared_length_rejected_before_allocation() {
    let mut header = Vec::new();
    header.extend_from_slice(&WIRE_MAGIC);
    header.extend_from_slice(&WIRE_VERSION.to_le_bytes());
    header.extend_from_slice(&(FrameKind::Infer as u16).to_le_bytes());
    header.extend_from_slice(&7u64.to_le_bytes());
    header.extend_from_slice(&0u32.to_le_bytes());
    header.extend_from_slice(&u32::MAX.to_le_bytes()); // declared 4 GiB payload
    let crc = frame_crc(&header, &[]);
    header.extend_from_slice(&crc.to_le_bytes());
    assert_eq!(header.len(), HEADER_LEN);
    let err = Frame::decode(&header, DEFAULT_MAX_PAYLOAD).unwrap_err();
    assert!(
        matches!(err, littlebit2::serving::WireError::Oversize { .. }),
        "{err:?}"
    );
}

fn echo_frontend() -> TcpFrontend {
    let cfg = ServingConfig {
        poll: Duration::from_millis(5),
        batch: littlebit2::coordinator::ServerConfig {
            max_wait: Duration::from_millis(1),
            ..Default::default()
        },
        ..Default::default()
    };
    TcpFrontend::start("127.0.0.1:0", cfg, |_w| |x: &Mat| -> Mat { x.clone() }).unwrap()
}

/// Write raw bytes, half-close, and collect everything the server sends
/// back until it closes (or stops talking).
fn send_raw(addr: std::net::SocketAddr, bytes: &[u8]) -> Vec<u8> {
    let mut stream = TcpStream::connect(addr).unwrap();
    let _ = stream.set_nodelay(true);
    stream.write_all(bytes).unwrap();
    let _ = stream.shutdown(std::net::Shutdown::Write);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(500)));
    let mut out = Vec::new();
    let mut tmp = [0u8; 4096];
    loop {
        match stream.read(&mut tmp) {
            Ok(0) => break,
            Ok(n) => out.extend_from_slice(&tmp[..n]),
            Err(_) => break, // read timeout: server kept quiet — also fine
        }
    }
    out
}

/// Decode every well-formed frame in a raw response byte stream.
fn frames_in(mut bytes: &[u8]) -> Vec<Frame> {
    let mut out = Vec::new();
    while let Ok((f, used)) = Frame::decode(bytes, DEFAULT_MAX_PAYLOAD) {
        out.push(f);
        bytes = &bytes[used..];
    }
    out
}

fn assert_alive(front: &TcpFrontend) {
    let mut client = WireClient::connect(front.local_addr()).unwrap();
    let out = client.infer(99, &[4.0, 5.0], 0).unwrap();
    assert_eq!(out, vec![4.0, 5.0], "server no longer echoes after corruption");
}

/// Garbage that shares no structure with the protocol: the server must
/// error or close — and keep serving a well-behaved client afterwards.
#[test]
fn live_garbage_bytes_do_not_kill_the_server() {
    let front = echo_frontend();
    for garbage in [
        b"GET / HTTP/1.1\r\n\r\n".to_vec(),
        vec![0u8; 64],
        vec![0xFFu8; 64],
        vec![0x89, b'L', b'B', b'2'], // the ARTIFACT magic, not the wire magic
    ] {
        let reply = send_raw(front.local_addr(), &garbage);
        for f in frames_in(&reply) {
            assert_ne!(f.kind, FrameKind::Result, "garbage produced a RESULT: {f:?}");
        }
        assert_alive(&front);
    }
    front.shutdown();
}

/// Every truncation of a valid frame, delivered over a real socket and
/// then half-closed: the server must treat it as a dead/hostile peer —
/// never execute it, never panic, never stop serving others.
#[test]
fn live_truncation_at_every_byte_keeps_server_alive() {
    let front = echo_frontend();
    let bytes = sample_frame().encode();
    for len in 0..bytes.len() {
        let reply = send_raw(front.local_addr(), &bytes[..len]);
        for f in frames_in(&reply) {
            assert_ne!(
                f.kind,
                FrameKind::Result,
                "truncation to {len} bytes produced a RESULT: {f:?}"
            );
        }
    }
    assert_alive(&front);
    front.shutdown();
}

/// Every single-bit flip of a valid frame over a real socket: the CRC
/// must stop all of them — the server may error or close, but it must
/// never answer with a RESULT frame (under any id), and it keeps serving.
#[test]
fn live_bit_flips_never_produce_a_result_frame() {
    let front = echo_frontend();
    let bytes = sample_frame().encode();
    for i in 0..bytes.len() {
        let mut bad = bytes.clone();
        bad[i] ^= 0x01;
        let reply = send_raw(front.local_addr(), &bad);
        for f in frames_in(&reply) {
            assert_ne!(
                f.kind,
                FrameKind::Result,
                "flip at byte {i} produced a RESULT: {f:?}"
            );
        }
    }
    assert_alive(&front);
    front.shutdown();
}

/// The hostile-length frame over a live socket: rejected (error or
/// close) without ballooning memory, and the server keeps serving.
#[test]
fn live_oversize_length_rejected() {
    let front = echo_frontend();
    let mut header = Vec::new();
    header.extend_from_slice(&WIRE_MAGIC);
    header.extend_from_slice(&WIRE_VERSION.to_le_bytes());
    header.extend_from_slice(&(FrameKind::Infer as u16).to_le_bytes());
    header.extend_from_slice(&1u64.to_le_bytes());
    header.extend_from_slice(&0u32.to_le_bytes());
    header.extend_from_slice(&u32::MAX.to_le_bytes());
    let crc = frame_crc(&header, &[]);
    header.extend_from_slice(&crc.to_le_bytes());
    let reply = send_raw(front.local_addr(), &header);
    for f in frames_in(&reply) {
        assert_ne!(f.kind, FrameKind::Result, "oversize frame produced a RESULT: {f:?}");
    }
    assert_alive(&front);
    front.shutdown();
}
