//! TCP serving end to end: batching invariance (responses over sockets
//! are bit-identical to in-process `forward`, however requests land in
//! batches), the loopback `.lb2` acceptance path, and robustness —
//! slow-loris, mid-flight disconnect, deadline expiry, BUSY admission
//! control, and shutdown-under-load draining.

use littlebit2::coordinator::ServerConfig;
use littlebit2::linalg::Mat;
use littlebit2::littlebit::InitStrategy;
use littlebit2::model::MethodStack;
use littlebit2::parallel::Pool;
use littlebit2::quant::MethodSpec;
use littlebit2::rng::Pcg64;
use littlebit2::serving::{
    err_code, FrameKind, ServingConfig, TcpFrontend, WireClient,
};
use littlebit2::spectral::{synth_weight, SynthSpec};
use std::io::{Read, Write};
use std::sync::Arc;
use std::time::Duration;

/// A depth-2 48-feature stack compressed with `method`.
fn method_stack(method: &str, seed: u64) -> Arc<MethodStack> {
    let mut rng = Pcg64::seed(seed);
    let spec = MethodSpec::parse(method, 1.0, InitStrategy::JointItq { iters: 10 }).unwrap();
    let layers = (0..2)
        .map(|_| {
            let w = synth_weight(
                &SynthSpec { rows: 48, cols: 48, gamma: 0.3, coherence: 0.6, scale: 1.0 },
                &mut rng,
            );
            spec.compressor().compress_layer(&w, Pool::serial(), &mut rng).unwrap()
        })
        .collect();
    Arc::new(MethodStack::uniform(method, layers).unwrap())
}

fn stack_frontend(stack: &Arc<MethodStack>, cfg: ServingConfig) -> TcpFrontend {
    let stack = Arc::clone(stack);
    TcpFrontend::start("127.0.0.1:0", cfg, move |_w| {
        littlebit2::coordinator::MethodStackBackend::new(Arc::clone(&stack), 2)
    })
    .unwrap()
}

fn batching_cfg() -> ServingConfig {
    ServingConfig {
        batch: ServerConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(30),
            queue_depth: 1024,
            workers: 2,
            ..Default::default()
        },
        ..Default::default()
    }
}

fn inputs(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Pcg64::seed(seed);
    (0..n)
        .map(|_| {
            let mut x = vec![0.0f32; d];
            rng.fill_normal(&mut x);
            x
        })
        .collect()
}

fn assert_bits_eq(got: &[f32], want: &[f32], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length");
    for (j, (a, b)) in got.iter().zip(want).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{ctx}: element {j}: {a} vs {b}");
    }
}

/// Counter reconciliation: after a drain, every accepted request must be
/// accounted for exactly once — served, failed, or expired. (Rejected and
/// shed requests were never accepted, so they sit outside the identity.)
fn assert_reconciled(stats: &littlebit2::coordinator::ServerStats, ctx: &str) {
    assert_eq!(
        stats.accepted,
        stats.served + stats.failed + stats.deadline_missed,
        "{ctx}: accepted != served + failed + deadline_missed ({stats:?})"
    );
}

/// Batching invariance across every `MethodLayer` variant: the same
/// inputs through (A) one pipelined connection filling batches, (B) many
/// connections racing one request each, and (C) strictly sequential
/// requests (every batch flushed by the deadline at size 1) must all be
/// bit-identical to the in-process `MethodStack::forward`.
#[test]
fn responses_bit_identical_for_every_method_and_batching_shape() {
    for method in ["littlebit2", "onebit", "rtn", "tinyrank"] {
        let stack = method_stack(method, 0xA0);
        let xs = inputs(16, stack.d_in(), 0xB0);
        let want: Vec<Vec<f32>> = xs.iter().map(|x| stack.forward(x)).collect();
        let front = stack_frontend(&stack, batching_cfg());
        let addr = front.local_addr();

        // (A) one client, 16 pipelined requests → coalesced batches.
        let mut client = WireClient::connect(addr).unwrap();
        for (i, x) in xs.iter().enumerate() {
            client.send_infer(i as u64, x, 0).unwrap();
        }
        let mut got = vec![Vec::new(); xs.len()];
        for _ in 0..xs.len() {
            let f = client.recv().unwrap();
            assert_eq!(f.kind, FrameKind::Result, "{method}: {f:?}");
            assert!(f.aux >= 1, "{method}: batch size 0");
            got[f.id as usize] = littlebit2::serving::payload_f32(&f.payload).unwrap();
        }
        for (i, g) in got.iter().enumerate() {
            assert_bits_eq(g, &want[i], &format!("{method} pipelined req {i}"));
        }

        // (B) 16 connections, one request each, racing → cross-connection
        // batches.
        let mut threads = Vec::new();
        for (i, x) in xs.iter().enumerate() {
            let x = x.clone();
            threads.push(std::thread::spawn(move || {
                let mut c = WireClient::connect(addr).unwrap();
                (i, c.infer(i as u64, &x, 0).unwrap())
            }));
        }
        for t in threads {
            let (i, g) = t.join().unwrap();
            assert_bits_eq(&g, &want[i], &format!("{method} concurrent req {i}"));
        }

        // (C) strictly sequential → every batch a deadline-flushed 1.
        let mut client = WireClient::connect(addr).unwrap();
        for (i, x) in xs.iter().enumerate() {
            let g = client.infer(i as u64, x, 0).unwrap();
            assert_bits_eq(&g, &want[i], &format!("{method} sequential req {i}"));
        }

        let stats = front.shutdown();
        assert_eq!(stats.served, 3 * xs.len() as u64, "{method}");
        assert_eq!(stats.failed, 0, "{method}");
        assert_reconciled(&stats, method);
    }
}

/// The acceptance case: compress → save `.lb2` → load → serve over
/// 127.0.0.1 → N concurrent clients get responses bit-identical to the
/// loaded stack's in-process forward; the metrics frame reports the run.
#[test]
fn loopback_lb2_artifact_end_to_end() {
    let stack = method_stack("littlebit2", 0xC0);
    let path = std::env::temp_dir().join(format!("lb2_tcp_e2e_{}.lb2", std::process::id()));
    stack.save(&path).unwrap();
    let loaded = Arc::new(MethodStack::load(&path).unwrap());
    let _ = std::fs::remove_file(&path);

    let front = stack_frontend(&loaded, batching_cfg());
    let addr = front.local_addr();
    let mut threads = Vec::new();
    for c in 0..4u64 {
        let loaded = Arc::clone(&loaded);
        threads.push(std::thread::spawn(move || {
            let xs = inputs(8, loaded.d_in(), 0xD0 + c);
            let mut client = WireClient::connect(addr).unwrap();
            for (i, x) in xs.iter().enumerate() {
                let id = c * 100 + i as u64;
                let got = client.infer(id, x, 0).unwrap();
                assert_bits_eq(&got, &loaded.forward(x), &format!("client {c} req {i}"));
            }
        }));
    }
    for t in threads {
        t.join().unwrap();
    }

    let mut client = WireClient::connect(addr).unwrap();
    let text = client.stats_text().unwrap();
    assert!(text.contains("lb2_requests_served_total 32"), "{text}");
    assert!(text.contains("lb2_batch_fill_bucket"), "{text}");
    assert!(text.contains("lb2_connections"), "{text}");
    drop(client);

    let stats = front.shutdown();
    assert_eq!(stats.served, 32);
    assert_eq!(stats.failed, 0);
    assert_eq!(stats.rejected, 0);
    assert_reconciled(&stats, "loopback e2e");
}

fn echo_cfg() -> ServingConfig {
    ServingConfig {
        poll: Duration::from_millis(5),
        batch: ServerConfig { max_wait: Duration::from_millis(1), ..Default::default() },
        ..Default::default()
    }
}

/// Slow-loris: a connection that dribbles half a header and stalls is cut
/// off by the frame timer — while a concurrent honest client is served.
#[test]
fn slow_loris_partial_frame_is_cut_off() {
    let cfg = ServingConfig { frame_timeout: Duration::from_millis(100), ..echo_cfg() };
    let front =
        TcpFrontend::start("127.0.0.1:0", cfg, |_w| |x: &Mat| -> Mat { x.clone() }).unwrap();
    let addr = front.local_addr();

    let mut loris = std::net::TcpStream::connect(addr).unwrap();
    loris.write_all(&[0x89, b'L', b'B', b'W', 1, 0]).unwrap(); // 6 of 28 header bytes
    // While the loris stalls, an honest client gets served normally.
    let mut honest = WireClient::connect(addr).unwrap();
    assert_eq!(honest.infer(1, &[2.0, 3.0], 0).unwrap(), vec![2.0, 3.0]);

    // Past the frame timeout the server must close the loris connection.
    loris.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let mut buf = [0u8; 64];
    let t0 = std::time::Instant::now();
    loop {
        match loris.read(&mut buf) {
            Ok(0) => break, // server closed: the guard fired
            Ok(_) => continue,
            Err(e) => panic!("expected server-side close, got read error {e}"),
        }
    }
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "loris connection not closed by the frame timer"
    );
    // The server is still healthy afterwards.
    assert_eq!(honest.infer(2, &[4.0], 0).unwrap(), vec![4.0]);
    let stats = front.shutdown();
    assert_reconciled(&stats, "slow loris");
}

/// A client that disconnects with requests in flight fails only itself:
/// the worker's completion lands in a closed funnel and is dropped, and
/// the server keeps serving everyone else.
#[test]
fn client_disconnect_mid_flight_does_not_kill_the_server() {
    let cfg = echo_cfg();
    let front = TcpFrontend::start("127.0.0.1:0", cfg, |_w| {
        |x: &Mat| -> Mat {
            std::thread::sleep(Duration::from_millis(100));
            x.clone()
        }
    })
    .unwrap();
    let addr = front.local_addr();

    {
        let mut doomed = WireClient::connect(addr).unwrap();
        doomed.send_infer(1, &[1.0, 2.0], 0).unwrap();
        // Dropped here — the socket closes while the request executes.
    }
    std::thread::sleep(Duration::from_millis(50));
    let mut honest = WireClient::connect(addr).unwrap();
    assert_eq!(honest.infer(2, &[5.0], 0).unwrap(), vec![5.0]);
    let stats = front.shutdown();
    assert_eq!(stats.served, 2, "the doomed request still executed");
    assert_eq!(stats.failed, 0);
    assert_reconciled(&stats, "mid-flight disconnect");
}

/// Deadline expiry over the wire: with the single worker pinned by a slow
/// batch, a 20 ms-deadline request queued behind it comes back as an
/// ERROR/DEADLINE frame, while an unbounded request queued alongside is
/// served normally.
#[test]
fn deadline_expiry_fails_only_that_request() {
    let cfg = ServingConfig {
        poll: Duration::from_millis(5),
        batch: ServerConfig {
            max_batch: 1,
            max_wait: Duration::from_millis(1),
            queue_depth: 16,
            workers: 1,
            ..Default::default()
        },
        ..Default::default()
    };
    let front = TcpFrontend::start("127.0.0.1:0", cfg, |_w| {
        |x: &Mat| -> Mat {
            std::thread::sleep(Duration::from_millis(150));
            x.clone()
        }
    })
    .unwrap();
    let mut client = WireClient::connect(front.local_addr()).unwrap();

    client.send_infer(1, &[1.0], 0).unwrap();
    std::thread::sleep(Duration::from_millis(50)); // worker is now inside request 1
    client.send_infer(2, &[2.0], 20).unwrap(); // will expire in the queue
    client.send_infer(3, &[3.0], 0).unwrap(); // no deadline: must be served

    let mut outcomes = std::collections::HashMap::new();
    for _ in 0..3 {
        let f = client.recv().unwrap();
        outcomes.insert(f.id, f);
    }
    assert_eq!(outcomes[&1].kind, FrameKind::Result);
    assert_eq!(outcomes[&2].kind, FrameKind::Error, "{:?}", outcomes[&2]);
    assert_eq!(outcomes[&2].aux, err_code::DEADLINE);
    assert_eq!(outcomes[&3].kind, FrameKind::Result);

    let stats = front.shutdown();
    assert_eq!(stats.deadline_missed, 1);
    assert_eq!(stats.served, 2);
    assert_reconciled(&stats, "deadline expiry");
}

/// Admission control: a 1-deep queue behind a slow single worker answers
/// BUSY for the overflow — explicitly, immediately, and without ever
/// failing the requests that were admitted.
#[test]
fn overflow_is_answered_with_busy_frames() {
    let cfg = ServingConfig {
        poll: Duration::from_millis(5),
        batch: ServerConfig {
            max_batch: 1,
            max_wait: Duration::from_millis(1),
            queue_depth: 1,
            workers: 1,
            ..Default::default()
        },
        ..Default::default()
    };
    let front = TcpFrontend::start("127.0.0.1:0", cfg, |_w| {
        |x: &Mat| -> Mat {
            std::thread::sleep(Duration::from_millis(200));
            x.clone()
        }
    })
    .unwrap();
    let mut client = WireClient::connect(front.local_addr()).unwrap();
    for i in 0..5u64 {
        client.send_infer(i, &[i as f32], 0).unwrap();
    }
    let (mut results, mut busy) = (0, 0);
    for _ in 0..5 {
        let f = client.recv().unwrap();
        match f.kind {
            FrameKind::Result => results += 1,
            FrameKind::Busy => busy += 1,
            other => panic!("unexpected {other:?}"),
        }
    }
    assert!(busy >= 1, "no BUSY frames from a 1-deep queue under burst");
    assert!(results >= 1, "nothing served");
    assert_eq!(results + busy, 5);
    let stats = front.shutdown();
    assert_eq!(stats.served as i32, results);
    assert_eq!(stats.rejected as i32, busy);
    assert_reconciled(&stats, "busy overflow");
}

/// Shutdown under load: requests accepted before the SHUTDOWN frame are
/// all answered (the in-flight drain), the ack arrives, and the final
/// stats account for every one of them.
#[test]
fn shutdown_under_load_drains_accepted_requests() {
    let cfg = ServingConfig {
        poll: Duration::from_millis(5),
        batch: ServerConfig {
            max_batch: 2,
            max_wait: Duration::from_millis(1),
            queue_depth: 64,
            workers: 1,
            ..Default::default()
        },
        ..Default::default()
    };
    let front = TcpFrontend::start("127.0.0.1:0", cfg, |_w| {
        |x: &Mat| -> Mat {
            std::thread::sleep(Duration::from_millis(40));
            x.clone()
        }
    })
    .unwrap();
    let mut client = WireClient::connect(front.local_addr()).unwrap();
    for i in 0..6u64 {
        client.send_infer(i, &[i as f32], 0).unwrap();
    }
    client.send(&littlebit2::serving::Frame::shutdown(99)).unwrap();

    let (mut results, mut acked) = (0u32, false);
    for _ in 0..7 {
        let f = client.recv().unwrap();
        match f.kind {
            FrameKind::Result => results += 1,
            FrameKind::ShutdownAck => acked = true,
            other => panic!("unexpected {other:?} during shutdown drain"),
        }
    }
    assert_eq!(results, 6, "accepted requests lost during shutdown");
    assert!(acked, "no SHUTDOWN_ACK");

    let stats = front.shutdown();
    assert_eq!(stats.served, 6);
    assert_eq!(stats.failed, 0);
    assert_reconciled(&stats, "shutdown under load");
}
