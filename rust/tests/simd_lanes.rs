//! SIMD-lane bit-exactness and padded-layout invariants — the acceptance
//! contract of the aligned-layout + runtime-dispatch PR.
//!
//! Every sign kernel dispatches between the scalar oracle (the pre-SIMD
//! body, kept verbatim) and the AVX2 lane at runtime. The AVX2 lanes map
//! the scalar accumulators onto vector lanes without reassociating any
//! reduction, so the two lanes must agree **bit-for-bit** — not within a
//! tolerance — on every shape, including the ragged ones (cols % 64 ∈
//! {0, 1, 63}, rows not a multiple of the 64-row cache tile, batch widths
//! straddling the 8-column strip).
//!
//! On a machine without AVX2 the dispatch resolves to scalar on both sides
//! and the comparisons hold trivially; the CI matrix also runs the whole
//! suite under `LB2_FORCE_SCALAR=1` so the scalar lane stays exercised on
//! AVX2 runners too.
//!
//! The lane pin (`force_scalar`) is process-global, so every test that
//! toggles it serializes on one mutex and restores the prior pin before
//! returning.

use std::sync::{Mutex, MutexGuard, OnceLock};

use littlebit2::linalg::Mat;
use littlebit2::packing::{
    force_scalar, gemm_sign, gemm_sign_scaled, gemv_sign, gemv_sign_scaled, scalar_forced,
    xnor_popcount_gemm, BitMatrix,
};
use littlebit2::rng::Pcg64;

/// Serialize lane-pin manipulation across the test binary's threads.
fn lane_lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    // A test that panicked while holding the lock already failed; the pin
    // state it leaves behind is restored by `with_lane`'s caller pattern,
    // so a poisoned lock is safe to re-enter.
    match LOCK.get_or_init(|| Mutex::new(())).lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Run `f` with the scalar pin set to `scalar`, restoring the prior pin.
fn with_lane<R>(scalar: bool, f: impl FnOnce() -> R) -> R {
    let pinned = scalar_forced();
    force_scalar(scalar);
    let out = f();
    force_scalar(pinned);
    out
}

fn assert_bits_equal(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: element {i}: {x} vs {y}");
    }
}

fn assert_mats_bit_equal(a: &Mat, b: &Mat, what: &str) {
    assert_eq!(a.shape(), b.shape(), "{what}: shape");
    for i in 0..a.rows() {
        assert_bits_equal(a.row(i), b.row(i), &format!("{what}: row {i}"));
    }
}

/// Ragged shapes exercising every tail path: cols % 64 ∈ {0, 1, 63} (full
/// words only, 1-bit tail, 63-bit tail) and rows off the 64-row cache tile.
const SHAPES: [(usize, usize); 6] =
    [(1, 63), (7, 64), (65, 65), (66, 127), (130, 128), (67, 191)];

#[test]
fn gemv_sign_lanes_bit_identical_on_ragged_shapes() {
    let _guard = lane_lock();
    let mut rng = Pcg64::seed(801);
    for (rows, cols) in SHAPES {
        let s = BitMatrix::from_dense(&Mat::gaussian(rows, cols, &mut rng).signum());
        let mut x = vec![0.0f32; cols];
        rng.fill_normal(&mut x);
        let mut y_scalar = vec![0.0f32; rows];
        let mut y_auto = vec![0.0f32; rows];
        with_lane(true, || gemv_sign(&s, &x, &mut y_scalar));
        with_lane(false, || gemv_sign(&s, &x, &mut y_auto));
        assert_bits_equal(&y_scalar, &y_auto, &format!("gemv_sign {rows}x{cols}"));
    }
}

#[test]
fn gemv_sign_scaled_lanes_bit_identical() {
    let _guard = lane_lock();
    let mut rng = Pcg64::seed(802);
    for (rows, cols) in SHAPES {
        let s = BitMatrix::from_dense(&Mat::gaussian(rows, cols, &mut rng).signum());
        let mut x = vec![0.0f32; cols];
        rng.fill_normal(&mut x);
        let mut g = vec![0.0f32; cols];
        let mut h = vec![0.0f32; rows];
        rng.fill_uniform(&mut g, 0.5, 1.5);
        rng.fill_uniform(&mut h, 0.5, 1.5);
        let mut y_scalar = vec![0.0f32; rows];
        let mut y_auto = vec![0.0f32; rows];
        with_lane(true, || gemv_sign_scaled(&s, Some(&g), &x, Some(&h), &mut y_scalar));
        with_lane(false, || gemv_sign_scaled(&s, Some(&g), &x, Some(&h), &mut y_auto));
        assert_bits_equal(&y_scalar, &y_auto, &format!("gemv_sign_scaled {rows}x{cols}"));
    }
}

/// Batch widths straddling the 8-column strip (1, partial, exact, strip+1,
/// multi-strip ragged) on a rows-off-tile shape.
#[test]
fn gemm_sign_lanes_bit_identical_across_batch_widths() {
    let _guard = lane_lock();
    let mut rng = Pcg64::seed(803);
    let (rows, cols) = (130, 191);
    let s = BitMatrix::from_dense(&Mat::gaussian(rows, cols, &mut rng).signum());
    for b in [1usize, 7, 8, 9, 17, 32] {
        let x = Mat::gaussian(cols, b, &mut rng);
        let mut y_scalar = Mat::zeros(rows, b);
        let mut y_auto = Mat::zeros(rows, b);
        with_lane(true, || gemm_sign(&s, &x, &mut y_scalar));
        with_lane(false, || gemm_sign(&s, &x, &mut y_auto));
        assert_mats_bit_equal(&y_scalar, &y_auto, &format!("gemm_sign b={b}"));
        assert!(y_auto.padding_is_clear(), "gemm output stride padding stayed clear");
    }
}

#[test]
fn gemm_sign_scaled_lanes_bit_identical() {
    let _guard = lane_lock();
    let mut rng = Pcg64::seed(804);
    let (rows, cols, b) = (67, 127, 9);
    let s = BitMatrix::from_dense(&Mat::gaussian(rows, cols, &mut rng).signum());
    let x = Mat::gaussian(cols, b, &mut rng);
    let mut g = vec![0.0f32; cols];
    let mut h = vec![0.0f32; rows];
    rng.fill_uniform(&mut g, 0.5, 1.5);
    rng.fill_uniform(&mut h, 0.5, 1.5);
    let mut y_scalar = Mat::zeros(rows, b);
    let mut y_auto = Mat::zeros(rows, b);
    with_lane(true, || gemm_sign_scaled(&s, Some(&g), &x, Some(&h), &mut y_scalar));
    with_lane(false, || gemm_sign_scaled(&s, Some(&g), &x, Some(&h), &mut y_auto));
    assert_mats_bit_equal(&y_scalar, &y_auto, "gemm_sign_scaled");
}

#[test]
fn xnor_popcount_lanes_identical() {
    let _guard = lane_lock();
    let mut rng = Pcg64::seed(805);
    for (rows, cols) in [(5, 63), (33, 64), (66, 129), (17, 191)] {
        let a = BitMatrix::from_dense(&Mat::gaussian(rows, cols, &mut rng).signum());
        let bt = BitMatrix::from_dense(&Mat::gaussian(rows, cols, &mut rng).signum());
        let scalar = with_lane(true, || xnor_popcount_gemm(&a, &bt));
        let auto = with_lane(false, || xnor_popcount_gemm(&a, &bt));
        assert_mats_bit_equal(&scalar, &auto, &format!("xnor {rows}x{cols}"));
    }
}

/// Matrices rebuilt from the tight disk words (the copy-restride path the
/// v1/v2 artifact loaders — and the mmap loader's misalignment fallback —
/// go through) must drive the aligned-load kernels to the same bits as
/// their `from_dense` originals, on both lanes.
#[test]
fn restrided_matrices_hit_identical_kernel_bits() {
    let _guard = lane_lock();
    let mut rng = Pcg64::seed(808);
    for (rows, cols) in [(66usize, 127usize), (67, 191)] {
        let s = BitMatrix::from_dense(&Mat::gaussian(rows, cols, &mut rng).signum());
        let words: Vec<u64> = s.tight_words().collect();
        let r = BitMatrix::from_words(rows, cols, words).expect("restride tight words");
        let x = Mat::gaussian(cols, 9, &mut rng);
        for scalar in [true, false] {
            let mut y_orig = Mat::zeros(rows, 9);
            let mut y_restr = Mat::zeros(rows, 9);
            with_lane(scalar, || {
                gemm_sign(&s, &x, &mut y_orig);
                gemm_sign(&r, &x, &mut y_restr);
            });
            assert_mats_bit_equal(
                &y_orig,
                &y_restr,
                &format!("restride gemm {rows}x{cols} scalar={scalar}"),
            );
        }
    }
}

/// The padded-layout invariants the kernels lean on: 4-word (32-byte) row
/// stride, padding words always zero through every construction path, and
/// a tight on-disk word stream unchanged from the pre-padding format.
#[test]
fn bitmatrix_padded_stride_invariants() {
    let mut rng = Pcg64::seed(806);
    for (rows, cols) in SHAPES {
        let s = BitMatrix::from_dense(&Mat::gaussian(rows, cols, &mut rng).signum());
        let tight = cols.div_ceil(64);
        assert_eq!(s.tight_words_per_row(), tight, "tight stride {rows}x{cols}");
        assert_eq!(s.words_per_row() % 4, 0, "padded stride 32-byte multiple");
        assert!(s.words_per_row() >= tight);
        assert!(s.padding_is_clear(), "from_dense padding {rows}x{cols}");
        assert_eq!(s.padded_words().as_ptr() as usize % 32, 0, "32-byte base alignment");
        // Disk form is the tight ⌈cols/64⌉ layout, byte-identical to the
        // pre-padding format.
        assert_eq!(s.storage_bytes(), rows * tight * 8, "storage reports tight bytes");
        let words: Vec<u64> = s.tight_words().collect();
        assert_eq!(words.len(), rows * tight);
        let back = BitMatrix::from_words(rows, cols, words).expect("re-stride tight words");
        assert!(back.padding_is_clear(), "from_words padding {rows}x{cols}");
        assert_eq!(s.padded_words(), back.padded_words(), "tight roundtrip {rows}x{cols}");

        let t = s.transpose();
        assert!(t.padding_is_clear(), "transpose padding {rows}x{cols}");
        assert_mats_bit_equal(&t.to_dense(), &s.to_dense().transpose(), "transpose dense");
    }
}

#[test]
fn mat_padded_stride_invariants() {
    let mut rng = Pcg64::seed(807);
    for (rows, cols) in [(1usize, 1usize), (3, 7), (9, 8), (65, 130)] {
        let m = Mat::gaussian(rows, cols, &mut rng);
        assert_eq!(m.stride() % 8, 0, "row stride 32-byte multiple");
        assert!(m.stride() >= cols);
        assert!(m.padding_is_clear(), "gaussian padding {rows}x{cols}");
        assert_eq!(m.padded().as_ptr() as usize % 32, 0, "32-byte base alignment");
        assert_eq!(m.to_vec().len(), rows * cols, "to_vec is tight");
        let p = m.matmul(&Mat::gaussian(cols, 5, &mut rng));
        assert!(p.padding_is_clear(), "matmul output padding");
    }
}
