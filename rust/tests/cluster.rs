//! Sharded tracker/peer serving end to end: shard-vs-single bit
//! identity for every method under both shard modes, the stock client
//! protocol against a tracker, and the seeded peer-kill path — a peer
//! dying mid-stream re-shards onto the survivor with every accepted
//! request settled exactly once.
//!
//! Everything runs over real loopback sockets; both CI lanes (runtime
//! SIMD dispatch and `LB2_FORCE_SCALAR`) run this file, so bit identity
//! is asserted for both kernel paths.

use littlebit2::cluster::{
    Peer, PeerConfig, PeerHandle, ShardMode, Tracker, TrackerConfig, TrackerHandle,
};
use littlebit2::coordinator::HealthState;
use littlebit2::littlebit::InitStrategy;
use littlebit2::model::MethodStack;
use littlebit2::parallel::Pool;
use littlebit2::quant::MethodSpec;
use littlebit2::rng::Pcg64;
use littlebit2::serving::WireClient;
use littlebit2::spectral::{synth_weight, SynthSpec};
use std::path::PathBuf;
use std::time::{Duration, Instant};

/// A depth-3 chain with deliberately non-uniform widths (48 → 32 → 40 →
/// 48): pipeline cuts then carry different activation widths per stage,
/// and row-shard partitions differ per layer.
fn build_stack(method: &str, seed: u64) -> MethodStack {
    let mut rng = Pcg64::seed(seed);
    let spec = MethodSpec::parse(method, 1.0, InitStrategy::JointItq { iters: 6 }).unwrap();
    let dims = [(32usize, 48usize), (40, 32), (48, 40)]; // (rows=d_out, cols=d_in)
    let layers = dims
        .iter()
        .map(|&(rows, cols)| {
            let w = synth_weight(
                &SynthSpec { rows, cols, gamma: 0.3, coherence: 0.6, scale: 1.0 },
                &mut rng,
            );
            spec.compressor().compress_layer(&w, Pool::serial(), &mut rng).unwrap()
        })
        .collect();
    MethodStack::uniform(method, layers).unwrap()
}

/// Save `stack` to a unique temp `.lb2` (the caller removes it).
fn save_temp(stack: &MethodStack, tag: &str) -> PathBuf {
    let path = std::env::temp_dir()
        .join(format!("lb2_cluster_{tag}_{}.lb2", std::process::id()));
    stack.save(&path).unwrap();
    path
}

fn inputs(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Pcg64::seed(seed);
    (0..n)
        .map(|_| {
            let mut x = vec![0.0f32; d];
            rng.fill_normal(&mut x);
            x
        })
        .collect()
}

fn assert_bits_eq(got: &[f32], want: &[f32], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length");
    for (j, (a, b)) in got.iter().zip(want).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{ctx}: element {j}: {a} vs {b}");
    }
}

/// Tracker + `n` peers over an artifact at `path`, with fast heartbeats
/// so tests settle quickly. Blocks until the plan is cut and every peer
/// has loaded an assignment.
fn start_cluster(
    path: &PathBuf,
    mode: ShardMode,
    n: usize,
) -> (TrackerHandle, Vec<PeerHandle>) {
    let tracker = Tracker::start(TrackerConfig {
        expect_peers: n,
        heartbeat_timeout: Duration::from_millis(500),
        // Generous replay budget so a slow CI box cannot exhaust the
        // drive attempts while a re-shard is still settling.
        attempts: 25,
        ..TrackerConfig::new(path, mode)
    })
    .unwrap();
    let peers: Vec<PeerHandle> = (0..n)
        .map(|_| {
            Peer::start(PeerConfig {
                heartbeat_interval: Duration::from_millis(50),
                ..PeerConfig::new(tracker.addr().to_string(), path)
            })
            .unwrap()
        })
        .collect();
    assert!(tracker.wait_for_plan(Duration::from_secs(10)), "no plan within 10s");
    let t0 = Instant::now();
    while peers.iter().any(|p| p.epoch().is_none()) {
        assert!(t0.elapsed() < Duration::from_secs(10), "peers never loaded shards");
        std::thread::sleep(Duration::from_millis(20));
    }
    (tracker, peers)
}

/// The acceptance case: compress → `.lb2` → tracker + 2 peers → the
/// ordinary wire client gets responses bit-identical to the in-process
/// `MethodStack::forward`, for every method under both shard modes.
#[test]
fn cluster_bit_identical_to_single_process_for_every_method_and_mode() {
    for (mi, method) in ["littlebit2", "onebit", "tinyrank"].iter().enumerate() {
        let stack = build_stack(method, 0xA0 + mi as u64);
        for mode in [ShardMode::Pipeline, ShardMode::RowShard] {
            let tag = format!("{method}_{}", mode.label());
            let path = save_temp(&stack, &tag);
            let want_src = MethodStack::load(&path).unwrap();
            let xs = inputs(8, want_src.d_in(), 0xB0 + mi as u64);
            let want: Vec<Vec<f32>> = xs.iter().map(|x| want_src.forward(x)).collect();

            let (tracker, peers) = start_cluster(&path, mode, 2);
            let mut client = WireClient::connect(tracker.addr()).unwrap();
            for (i, x) in xs.iter().enumerate() {
                let got = client.infer(i as u64, x, 0).unwrap();
                assert_bits_eq(&got, &want[i], &format!("{tag} req {i}"));
            }
            drop(client);

            for p in peers {
                p.stop();
            }
            let summary = tracker.shutdown();
            assert_eq!(summary.served, xs.len() as u64, "{tag}");
            assert_eq!(summary.failed, 0, "{tag}");
            assert!(summary.reconciled, "{tag}: ledger did not reconcile");
            let _ = std::fs::remove_file(&path);
        }
    }
}

/// The stock client-side protocol works against a tracker unchanged:
/// STATS returns the `lb2_cluster_*` exposition, HEALTH reports healthy
/// while a plan is live, and SHUTDOWN is acked and drains the cluster.
#[test]
fn tracker_speaks_the_stock_client_protocol() {
    let stack = build_stack("littlebit2", 0xC0);
    let path = save_temp(&stack, "protocol");
    let (tracker, peers) = start_cluster(&path, ShardMode::Pipeline, 2);

    let mut client = WireClient::connect(tracker.addr()).unwrap();
    let x = &inputs(1, stack.d_in(), 0xC1)[0];
    client.infer(7, x, 0).unwrap();
    let text = client.stats_text().unwrap();
    for needle in [
        "lb2_cluster_mode{mode=\"pipeline\"} 1",
        "lb2_cluster_epoch 1",
        "lb2_cluster_peers_alive 2",
        "lb2_cluster_served_total 1",
    ] {
        assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
    }
    assert_eq!(client.health().unwrap(), HealthState::Healthy);

    client.shutdown_server().unwrap();
    for p in peers {
        p.wait(); // tracker-sent SHUTDOWN stops the peers
    }
    let summary = tracker.shutdown();
    assert!(summary.reconciled);
    let _ = std::fs::remove_file(&path);
}

/// The seeded kill: pump requests through a 2-peer cluster, stop one
/// peer mid-stream, keep pumping. The tracker must re-shard onto the
/// survivor, every request must come back bit-identical, and the ledger
/// must reconcile — `accepted == served + failed + deadline_missed`
/// with nothing lost.
#[test]
fn peer_kill_mid_stream_reshards_and_loses_nothing() {
    for mode in [ShardMode::Pipeline, ShardMode::RowShard] {
        let stack = build_stack("littlebit2", 0xD0);
        let path = save_temp(&stack, &format!("kill_{}", mode.label()));
        let want_src = MethodStack::load(&path).unwrap();
        let xs = inputs(24, want_src.d_in(), 0xD1);
        let want: Vec<Vec<f32>> = xs.iter().map(|x| want_src.forward(x)).collect();

        let (tracker, mut peers) = start_cluster(&path, mode, 2);
        let mut client = WireClient::connect(tracker.addr()).unwrap();
        for (i, x) in xs.iter().enumerate() {
            if i == 8 {
                // Failure injection: abrupt stop — the registration
                // socket closes and the tracker's EOF path marks the
                // peer dead.
                peers.pop().unwrap().stop();
            }
            let got = client.infer(i as u64, x, 0).unwrap();
            assert_bits_eq(&got, &want[i], &format!("{} req {i}", mode.label()));
        }
        drop(client);

        assert!(tracker.stats().reconciled(), "{}: mid-run ledger", mode.label());
        assert!(
            tracker.stats().reassignments() >= 1,
            "{}: the kill never re-sharded",
            mode.label()
        );
        assert_eq!(tracker.alive_peers(), 1, "{}", mode.label());

        for p in peers {
            p.stop();
        }
        let summary = tracker.shutdown();
        assert_eq!(summary.served, xs.len() as u64, "{}", mode.label());
        assert_eq!(summary.failed, 0, "{}: requests lost to the kill", mode.label());
        assert_eq!(summary.deadline_missed, 0, "{}", mode.label());
        assert!(summary.reconciled, "{}: final ledger", mode.label());
        let _ = std::fs::remove_file(&path);
    }
}

/// Requests sent while the cluster is still FORMING (below quorum) park
/// until quorum instead of failing: the client connects first, then the
/// peers arrive, and the request is served.
#[test]
fn requests_park_until_quorum() {
    let stack = build_stack("littlebit2", 0xE0);
    let path = save_temp(&stack, "forming");
    let tracker = Tracker::start(TrackerConfig {
        expect_peers: 2,
        heartbeat_timeout: Duration::from_millis(500),
        // Generous replay budget: attempts only start burning once quorum
        // is met, but the freshly-assigned peers may still be loading.
        attempts: 25,
        ..TrackerConfig::new(&path, ShardMode::Pipeline)
    })
    .unwrap();

    let x = inputs(1, stack.d_in(), 0xE1).remove(0);
    let want = stack.forward(&x);
    let addr = tracker.addr();
    let pump = {
        let x = x.clone();
        std::thread::spawn(move || {
            let mut client = WireClient::connect(addr).unwrap();
            client.infer(1, &x, 0).unwrap()
        })
    };

    std::thread::sleep(Duration::from_millis(150)); // request parks in FORMING
    let peers: Vec<PeerHandle> = (0..2)
        .map(|_| {
            Peer::start(PeerConfig {
                heartbeat_interval: Duration::from_millis(50),
                ..PeerConfig::new(addr.to_string(), &path)
            })
            .unwrap()
        })
        .collect();

    let got = pump.join().unwrap();
    assert_bits_eq(&got, &want, "parked request");

    for p in peers {
        p.stop();
    }
    let summary = tracker.shutdown();
    assert_eq!(summary.served, 1);
    assert!(summary.reconciled);
    let _ = std::fs::remove_file(&path);
}
