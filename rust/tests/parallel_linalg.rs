//! The PR 4 acceptance contract, linalg half: every pool-parallel kernel
//! under the compression pipeline is **bit-exact** against its serial
//! counterpart for thread counts {1, 2, 7, 64}, on ragged shapes (odd
//! dimensions, non-square, above and below the dispatch threshold).
//!
//! Floating-point addition is not associative, so this only holds because
//! the kernels partition *output rows* and keep a fixed reduction order
//! per element — the property `compress --jobs N` determinism is built on.

use littlebit2::linalg::{
    householder_qr, householder_qr_on, svd_randomized, svd_randomized_on, Mat,
};
use littlebit2::littlebit::{
    compress, compress_on, dual_svid, dual_svid_on, joint_itq, joint_itq_on, CompressionConfig,
};
use littlebit2::parallel::Pool;
use littlebit2::rng::Pcg64;
use littlebit2::spectral::{synth_weight, SynthSpec};

const THREADS: [usize; 4] = [1, 2, 7, 64];

fn assert_mats_bit_equal(a: &Mat, b: &Mat, what: &str) {
    assert_eq!(a.shape(), b.shape(), "{what}: shape");
    for i in 0..a.rows() {
        for (x, y) in a.row(i).iter().zip(b.row(i)) {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: row {i}: {x} vs {y}");
        }
    }
}

/// matmul / t_matmul / matmul_t / matvec on ragged shapes, every thread
/// count, both below and above the inline threshold.
#[test]
fn blocked_products_bit_exact_across_thread_counts() {
    let mut rng = Pcg64::seed(41);
    // (m, k, n): small (inline path) and large (real dispatch) shapes,
    // none a multiple of the 64-wide block.
    for (m, k, n) in [(7, 13, 5), (61, 130, 37), (129, 257, 66), (200, 90, 131)] {
        let a = Mat::gaussian(m, k, &mut rng);
        let b = Mat::gaussian(k, n, &mut rng);
        let bt = Mat::gaussian(n, k, &mut rng);
        let at = Mat::gaussian(k, m, &mut rng);
        let mut x = vec![0.0f32; k];
        rng.fill_normal(&mut x);

        let mm = a.matmul(&b);
        let tm = at.t_matmul(&b);
        let mt = a.matmul_t(&bt);
        let mv = a.matvec(&x);
        for threads in THREADS {
            let pool = Pool::new(threads);
            assert_mats_bit_equal(&mm, &a.matmul_on(&b, &pool), &format!("matmul t={threads}"));
            assert_mats_bit_equal(&tm, &at.t_matmul_on(&b, &pool), &format!("t_matmul t={threads}"));
            assert_mats_bit_equal(&mt, &a.matmul_t_on(&bt, &pool), &format!("matmul_t t={threads}"));
            let mv_p = a.matvec_on(&x, &pool);
            for (p, q) in mv.iter().zip(&mv_p) {
                assert_eq!(p.to_bits(), q.to_bits(), "matvec t={threads}");
            }
        }
    }
}

/// The column-major QR: pooled trailing updates must reproduce the serial
/// factorization bit-for-bit (Q and R both).
#[test]
fn householder_qr_bit_exact_across_thread_counts() {
    let mut rng = Pcg64::seed(42);
    for (m, n) in [(20, 8), (150, 150), (300, 130)] {
        let a = Mat::gaussian(m, n, &mut rng);
        let (q0, r0) = householder_qr(&a);
        for threads in THREADS {
            let pool = Pool::new(threads);
            let (q1, r1) = householder_qr_on(&a, &pool);
            assert_mats_bit_equal(&q0, &q1, &format!("QR.Q {m}x{n} t={threads}"));
            assert_mats_bit_equal(&r0, &r1, &format!("QR.R {m}x{n} t={threads}"));
        }
    }
}

/// Randomized SVD consumes the caller's RNG identically on every pool, so
/// U, S, V must all be bit-identical.
#[test]
fn svd_randomized_bit_exact_across_thread_counts() {
    let mut wrng = Pcg64::seed(43);
    let spec = SynthSpec { rows: 190, cols: 170, gamma: 0.3, coherence: 0.7, scale: 1.0 };
    let w = synth_weight(&spec, &mut wrng);
    let base = svd_randomized_on(&w, 24, 8, 2, &mut Pcg64::seed(5), Pool::serial());
    for threads in THREADS {
        let pool = Pool::new(threads);
        let svd = svd_randomized_on(&w, 24, 8, 2, &mut Pcg64::seed(5), &pool);
        assert_mats_bit_equal(&base.u, &svd.u, &format!("SVD.U t={threads}"));
        assert_mats_bit_equal(&base.v, &svd.v, &format!("SVD.V t={threads}"));
        for (a, b) in base.s.iter().zip(&svd.s) {
            assert_eq!(a.to_bits(), b.to_bits(), "SVD.S t={threads}");
        }
    }
    // The default entry (global pool) agrees too.
    let global = svd_randomized(&w, 24, 8, 2, &mut Pcg64::seed(5));
    assert_mats_bit_equal(&base.u, &global.u, "SVD.U default-vs-serial");
}

/// Joint-ITQ and Dual-SVID: identical rotations, factors, and trajectories
/// on any pool.
#[test]
fn itq_and_svid_bit_exact_across_pools() {
    let mut rng = Pcg64::seed(44);
    let spec = SynthSpec { rows: 140, cols: 120, gamma: 0.3, coherence: 0.8, scale: 1.0 };
    let w = synth_weight(&spec, &mut rng);
    let svd = svd_randomized_on(&w, 20, 8, 2, &mut Pcg64::seed(6), Pool::serial());
    let (u, v) = svd.split_factors();

    let (rot0, rep0) = joint_itq_on(&u, &v, 25, &mut Pcg64::seed(7), Pool::serial());
    for threads in [2usize, 7] {
        let pool = Pool::new(threads);
        let (rot1, rep1) = joint_itq_on(&u, &v, 25, &mut Pcg64::seed(7), &pool);
        assert_mats_bit_equal(&rot0, &rot1, &format!("ITQ rotation t={threads}"));
        for (a, b) in rep0.objective.iter().zip(&rep1.objective) {
            assert_eq!(a.to_bits(), b.to_bits(), "ITQ objective t={threads}");
        }
    }
    let (rotg, _) = joint_itq(&u, &v, 25, &mut Pcg64::seed(7));
    assert_mats_bit_equal(&rot0, &rotg, "ITQ default-vs-serial");

    let f0 = dual_svid_on(&u, &v, Pool::serial());
    let f1 = dual_svid_on(&u, &v, &Pool::new(7));
    let fg = dual_svid(&u, &v);
    for (fa, what) in [(&f1, "pool-7"), (&fg, "default")] {
        assert_eq!(f0.h, fa.h, "SVID h {what}");
        assert_eq!(f0.l, fa.l, "SVID l {what}");
        assert_eq!(f0.g, fa.g, "SVID g {what}");
        assert_mats_bit_equal(&f0.u_b, &fa.u_b, &format!("SVID u_b {what}"));
    }
}

/// End to end: the whole compression of one layer is bit-identical across
/// pools (reconstruction compared element-wise).
#[test]
fn compress_bit_exact_across_pools() {
    let mut rng = Pcg64::seed(45);
    let spec = SynthSpec { rows: 128, cols: 128, gamma: 0.3, coherence: 0.7, scale: 1.0 };
    let w = synth_weight(&spec, &mut rng);
    let cfg = CompressionConfig { bpp: 0.8, ..Default::default() };
    let base = compress_on(&w, &cfg, &mut Pcg64::seed(8), Pool::serial()).reconstruct();
    for threads in [2usize, 7] {
        let pool = Pool::new(threads);
        let got = compress_on(&w, &cfg, &mut Pcg64::seed(8), &pool).reconstruct();
        assert_mats_bit_equal(&base, &got, &format!("compress t={threads}"));
    }
    let default = compress(&w, &cfg, &mut Pcg64::seed(8)).reconstruct();
    assert_mats_bit_equal(&base, &default, "compress default-vs-serial");
}
