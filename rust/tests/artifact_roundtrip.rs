//! The `.lb2` artifact contract, end to end: compress → save → load →
//! serve round-trips bit-exactly, and every malformed-input path — bad
//! magic, bad version, truncation at every byte, any flipped bit, shape
//! lies, empty stacks, trailing garbage — returns `Err`, never a panic.

use littlebit2::artifact::{read_stack, ArtifactReader, ArtifactWriter, TAG_META, TAG_STACK};
use littlebit2::coordinator::{InferenceServer, PackedStackBackend, ServerConfig};
use littlebit2::linalg::Mat;
use littlebit2::littlebit::{CompressionConfig, InitStrategy};
use littlebit2::model::PackedStack;
use littlebit2::rng::Pcg64;
use littlebit2::spectral::{synth_weight, SynthSpec};
use std::sync::Arc;
use std::time::Duration;

/// Compress a chain of synthetic weights into a packed stack. Every dim is
/// deliberately not a multiple of 64, so the bit-planes carry ragged tail
/// words whose padding invariants the artifact must preserve.
fn packed_stack(dims: &[usize], seed: u64) -> PackedStack {
    let mut rng = Pcg64::seed(seed);
    let weights: Vec<Mat> = dims
        .windows(2)
        .map(|w| {
            let spec =
                SynthSpec { rows: w[1], cols: w[0], gamma: 0.3, coherence: 0.6, scale: 1.0 };
            synth_weight(&spec, &mut rng)
        })
        .collect();
    let cfg = CompressionConfig {
        bpp: 1.0,
        strategy: InitStrategy::JointItq { iters: 10 },
        residual: true, // 2-path residual per layer — the paper's deployment
        ..Default::default()
    };
    PackedStack::compress_chain(&weights, &cfg, &mut rng)
}

/// Save→load must reproduce the exact packed representation: every word of
/// every bit-plane, every scale — and therefore bit-identical forwards.
#[test]
fn roundtrip_is_bit_exact() {
    let stack = packed_stack(&[70, 130, 70], 11);
    let bytes = stack.to_artifact_bytes().unwrap();
    let loaded = PackedStack::from_artifact_bytes(&bytes).unwrap();
    assert_eq!(loaded, stack, "packed representation must round-trip verbatim");

    let mut rng = Pcg64::seed(12);
    let b = 5;
    let mut x = Mat::zeros(70, b);
    x.fill_normal(&mut rng);
    let want = stack.forward_batch(&x);
    let got = loaded.forward_batch(&x);
    for t in 0..b {
        for i in 0..70 {
            assert_eq!(
                got.at(i, t).to_bits(),
                want.at(i, t).to_bits(),
                "loaded forward differs at ({i},{t})"
            );
        }
    }
    let x1: Vec<f32> = x.col(0);
    assert_eq!(loaded.forward(&x1), stack.forward(&x1));
}

/// The same contract through actual files — `PackedStack::{save,load}`.
#[test]
fn roundtrip_through_file() {
    let stack = packed_stack(&[70, 90], 21);
    let path = std::env::temp_dir().join(format!("lb2_roundtrip_{}.lb2", std::process::id()));
    stack.save(&path).unwrap();
    let loaded = PackedStack::load(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    assert_eq!(loaded, stack);
}

/// Loading a missing file is an `Err` with the path in the message.
#[test]
fn missing_file_is_err() {
    let err = PackedStack::load("/nonexistent/nope.lb2").unwrap_err();
    assert!(format!("{err:?}").contains("nope.lb2"), "{err:?}");
}

/// The corrupt-file matrix: truncation at EVERY byte offset (which covers
/// every section boundary) and a flipped bit at every byte must fail with
/// `Err` — and must never panic, which `catch_unwind` enforces per case.
#[test]
fn corrupt_file_matrix_never_panics() {
    let bytes = packed_stack(&[40, 70], 31).to_artifact_bytes().unwrap();

    for len in 0..bytes.len() {
        let prefix = bytes[..len].to_vec();
        let result = std::panic::catch_unwind(|| read_stack(&prefix));
        match result {
            Ok(r) => assert!(r.is_err(), "truncation to {len} bytes parsed successfully"),
            Err(_) => panic!("truncation to {len} bytes PANICKED instead of returning Err"),
        }
    }

    for i in 0..bytes.len() {
        let mut bad = bytes.clone();
        bad[i] ^= 0x01;
        let result = std::panic::catch_unwind(|| read_stack(&bad));
        match result {
            Ok(r) => assert!(r.is_err(), "bit flip at byte {i} parsed successfully"),
            Err(_) => panic!("bit flip at byte {i} PANICKED instead of returning Err"),
        }
    }
}

#[test]
fn bad_magic_version_and_trailing_garbage_rejected() {
    let bytes = packed_stack(&[40, 70], 41).to_artifact_bytes().unwrap();

    let mut bad = bytes.clone();
    bad[0] = b'X';
    let err = read_stack(&bad).unwrap_err();
    assert!(err.to_string().contains("magic"), "{err}");

    let mut bad = bytes.clone();
    bad[4] = 99; // format version 99
    let err = read_stack(&bad).unwrap_err();
    assert!(err.to_string().contains("version"), "{err}");

    let mut bad = bytes.clone();
    bad.extend_from_slice(b"junk");
    assert!(read_stack(&bad).is_err());
}

/// An artifact that declares an empty stack must be rejected at load.
#[test]
fn empty_stack_artifact_rejected() {
    let mut w = ArtifactWriter::new(Vec::new()).unwrap();
    w.section(TAG_META, b"test").unwrap();
    w.section(TAG_STACK, &0u32.to_le_bytes()).unwrap(); // depth = 0
    let bytes = w.finish().unwrap();
    let err = read_stack(&bytes).unwrap_err();
    assert!(err.to_string().contains("empty stack"), "{err}");
}

/// A shape header that lies about the layer sections must be rejected —
/// the artifact is rebuilt with a tampered STAK section (valid CRC, valid
/// framing) so only the cross-check can catch it.
#[test]
fn shape_header_lies_rejected() {
    let bytes = packed_stack(&[40, 70], 51).to_artifact_bytes().unwrap();
    let mut r = ArtifactReader::new(&bytes).unwrap();
    let mut sections = Vec::new();
    while let Some((tag, body)) = r.next_section() {
        sections.push((tag, body.to_vec()));
    }
    assert_eq!(sections[1].0, TAG_STACK);
    // STAK payload: depth u32, then (d_in, d_out, n_paths) u32s. Corrupt
    // the declared d_in of layer 0.
    sections[1].1[4..8].copy_from_slice(&41u32.to_le_bytes());
    let mut w = ArtifactWriter::new(Vec::new()).unwrap();
    for (tag, body) in &sections {
        w.section(*tag, body).unwrap();
    }
    let tampered = w.finish().unwrap();
    let err = read_stack(&tampered).unwrap_err();
    assert!(format!("{err:?}").contains("shape header"), "{err:?}");
}

/// A chain whose layers don't compose (layer 0 emits 70, layer 1 consumes
/// 40) must be rejected even when each layer is individually valid.
#[test]
fn broken_chain_rejected() {
    let a = packed_stack(&[40, 70], 61); // 40 -> 70
    let b = packed_stack(&[40, 70], 62); // 40 -> 70 again: 70 -/-> 40
    let bytes_a = a.to_artifact_bytes().unwrap();
    let bytes_b = b.to_artifact_bytes().unwrap();
    let take = |bytes: &[u8]| -> Vec<([u8; 4], Vec<u8>)> {
        let mut r = ArtifactReader::new(bytes).unwrap();
        let mut out = Vec::new();
        while let Some((tag, body)) = r.next_section() {
            out.push((tag, body.to_vec()));
        }
        out
    };
    let sa = take(&bytes_a);
    let sb = take(&bytes_b);
    // Splice: META, STAK claiming depth 2 with both layers' true shapes,
    // then layer A and layer B (each a v2 METH + LAYR section pair) —
    // shapes honest, chain broken.
    let mut head = Vec::new();
    head.extend_from_slice(&2u32.to_le_bytes());
    head.extend_from_slice(&sa[1].1[4..16]); // layer A (d_in, d_out, paths)
    head.extend_from_slice(&sb[1].1[4..16]); // layer B
    let mut w = ArtifactWriter::new(Vec::new()).unwrap();
    w.section(TAG_META, b"test").unwrap();
    w.section(TAG_STACK, &head).unwrap();
    for (tag, body) in [&sa[2], &sa[3], &sb[2], &sb[3]] {
        w.section(*tag, body).unwrap();
    }
    let spliced = w.finish().unwrap();
    let err = read_stack(&spliced).unwrap_err();
    assert!(format!("{err:?}").contains("chain mismatch"), "{err:?}");
}

/// The acceptance pipeline: compress → save → load → SERVE. Responses off
/// the multi-worker pool running the loaded artifact are bit-identical to
/// the original in-memory stack's forwards.
#[test]
fn loaded_artifact_serves_bit_exactly() {
    let stack = packed_stack(&[70, 130, 70], 71);
    let bytes = stack.to_artifact_bytes().unwrap();
    let loaded = Arc::new(PackedStack::from_artifact_bytes(&bytes).unwrap());

    let server = InferenceServer::start_pool(
        ServerConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            queue_depth: 64,
            workers: 2,
            ..Default::default()
        },
        |_worker| PackedStackBackend::new(Arc::clone(&loaded), 2),
    );
    let mut rng = Pcg64::seed(72);
    let mut inputs = Vec::new();
    for _ in 0..12 {
        let mut x = vec![0.0f32; 70];
        rng.fill_normal(&mut x);
        inputs.push(x);
    }
    let rxs: Vec<_> = inputs
        .iter()
        .enumerate()
        .map(|(i, x)| server.submit(i as u64, x.clone()))
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().unwrap();
        let want = stack.forward(&inputs[i]);
        assert_eq!(resp.output.len(), want.len());
        for (j, (a, b)) in resp.output.iter().zip(&want).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "request {i} output {j}");
        }
    }
    let stats = server.shutdown();
    assert_eq!(stats.served, 12);
    assert_eq!(stats.failed, 0);
}

/// Size sanity: the artifact is dominated by the packed weights — a small
/// fixed container overhead over `storage_bytes`, far below the dense FP32
/// footprint. (Scales are serialized as f32 while `storage_bytes` accounts
/// them at their logical f16 width, hence the small slack term.)
#[test]
fn artifact_size_tracks_packed_storage() {
    let stack = packed_stack(&[70, 130, 70], 81);
    let bytes = stack.to_artifact_bytes().unwrap();
    let packed = stack.storage_bytes();
    assert!(bytes.len() >= packed, "artifact smaller than its payload?");
    let dense_f32 = (70 * 130 + 130 * 70) * 4;
    assert!(
        bytes.len() < dense_f32 / 2,
        "artifact {} bytes vs dense {} — not a compressed format",
        bytes.len(),
        dense_f32
    );
}
