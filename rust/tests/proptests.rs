//! Property-based tests over the coordinator/core invariants.
//!
//! proptest is unavailable in this offline build, so this file carries a
//! small seeded-sweep harness (`for_cases`) that generates N randomized
//! cases per property from a deterministic PCG stream — same spirit:
//! random structure, reproducible by seed (no shrinking), and each case
//! prints its seed on failure.

use littlebit2::linalg::{norm1, norm2, orthogonality_defect, svd_randomized, Mat};
use littlebit2::littlebit::{compress, dual_svid, joint_itq, CompressionConfig, InitStrategy};
use littlebit2::packing::{gemv_sign, BitMatrix};
use littlebit2::quant::{binarize_optimal, local_distortion};
use littlebit2::rng::Pcg64;
use littlebit2::spectral::{synth_weight, SynthSpec};

/// Run `prop` against `n` generated cases; panics with the case seed.
fn for_cases(n: u64, prop: impl Fn(&mut Pcg64)) {
    for case in 0..n {
        let seed = 0xBEEF_0000 + case;
        let mut rng = Pcg64::seed(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(e) = result {
            eprintln!("property failed for case seed {seed:#x}");
            std::panic::resume_unwind(e);
        }
    }
}

fn rand_dims(rng: &mut Pcg64, lo: usize, hi: usize) -> usize {
    lo + rng.below((hi - lo) as u64 + 1) as usize
}

/// λ(u) ∈ [0, 1-1/r] for every vector (Lemma 4.2's range).
#[test]
fn prop_distortion_range() {
    for_cases(50, |rng| {
        let r = rand_dims(rng, 2, 96);
        let mut u = vec![0.0f32; r];
        // Mix of spiky and dense vectors.
        rng.fill_normal(&mut u);
        if rng.uniform() < 0.3 {
            for (i, v) in u.iter_mut().enumerate() {
                if i % 7 != 0 {
                    *v *= 0.01;
                }
            }
        }
        let lam = local_distortion(&u);
        assert!(lam >= 0.0 && lam <= 1.0 - 1.0 / r as f64 + 1e-9, "λ={lam} r={r}");
    });
}

/// Binarization error equals λ·‖u‖² exactly (Eq. 13), for random vectors.
#[test]
fn prop_binarize_error_identity() {
    for_cases(50, |rng| {
        let r = rand_dims(rng, 1, 128);
        let mut u = vec![0.0f32; r];
        rng.fill_normal(&mut u);
        let b = binarize_optimal(&u);
        let err: f64 = u
            .iter()
            .zip(&b.reconstruct())
            .map(|(x, y)| ((x - y) as f64).powi(2))
            .sum();
        let n2sq = norm2(&u).powi(2);
        let lam = local_distortion(&u);
        assert!((err - lam * n2sq).abs() <= 1e-4 * n2sq.max(1e-9));
    });
}

/// Rotating by any orthogonal matrix preserves ÛV̂ᵀ (Eq. 7) and row norms.
#[test]
fn prop_rotation_invariance() {
    for_cases(25, |rng| {
        let r = rand_dims(rng, 2, 24);
        let m = rand_dims(rng, r, 80);
        let n = rand_dims(rng, r, 80);
        let u = Mat::gaussian(m, r, rng);
        let v = Mat::gaussian(n, r, rng);
        let q = littlebit2::linalg::random_orthogonal(r, rng);
        let base = u.matmul_t(&v);
        let rot = u.matmul(&q).matmul_t(&v.matmul(&q));
        assert!(rot.fro_dist2(&base) / base.fro_norm().powi(2).max(1e-12) < 1e-6);
        for i in 0..m {
            let a = norm2(u.row(i));
            let b = norm2(u.matmul(&q).row(i));
            assert!((a - b).abs() < 1e-3 * a.max(1e-6));
        }
    });
}

/// Joint-ITQ always returns an orthogonal rotation whose L1 mass is ≥ the
/// starting rotation's (App. A.2 monotonicity), on arbitrary factors.
#[test]
fn prop_itq_monotone_and_orthogonal() {
    for_cases(15, |rng| {
        let r = rand_dims(rng, 2, 16);
        let m = rand_dims(rng, r + 1, 60);
        let n = rand_dims(rng, r + 1, 60);
        let u = Mat::gaussian(m, r, rng);
        let v = Mat::gaussian(n, r, rng);
        let iters = 1 + rng.below(20) as usize;
        let (rot, report) = joint_itq(&u, &v, iters, rng);
        assert!(orthogonality_defect(&rot) < 1e-3);
        for w in report.l1_mass.windows(2) {
            assert!(w[1] >= w[0] * (1.0 - 1e-5), "L1 mass decreased: {w:?}");
        }
    });
}

/// Bit-packing round-trips and sign-GEMV matches the dense product for
/// arbitrary shapes including ragged (non-multiple-of-64) columns.
#[test]
fn prop_packing_roundtrip_and_gemv() {
    for_cases(40, |rng| {
        let m = rand_dims(rng, 1, 70);
        let n = rand_dims(rng, 1, 200);
        let s = Mat::gaussian(m, n, rng).signum();
        let packed = BitMatrix::from_dense(&s);
        assert_eq!(packed.to_dense(), s);
        let mut x = vec![0.0f32; n];
        rng.fill_normal(&mut x);
        let want = s.matvec(&x);
        let mut got = vec![0.0f32; m];
        gemv_sign(&packed, &x, &mut got);
        for (a, b) in want.iter().zip(&got) {
            assert!((a - b).abs() < 1e-3 * (n as f32).sqrt().max(1.0), "{a} vs {b}");
        }
    });
}

/// Dual-SVID is scale-covariant: scaling the inputs by c scales the
/// reconstruction by c (rank-1 scale extraction is 1-homogeneous).
#[test]
fn prop_svid_scale_covariance() {
    for_cases(15, |rng| {
        let r = rand_dims(rng, 1, 12);
        let m = rand_dims(rng, r, 48);
        let n = rand_dims(rng, r, 48);
        let u = Mat::gaussian(m, r, rng);
        let v = Mat::gaussian(n, r, rng);
        let c = 0.25 + 4.0 * rng.uniform_f32();
        let base = dual_svid(&u, &v).reconstruct();
        let scaled = dual_svid(&u.scale(c), &v.scale(c)).reconstruct();
        let want = base.scale(c * c);
        assert!(
            scaled.fro_dist2(&want) / want.fro_norm().powi(2).max(1e-12) < 1e-3,
            "c={c}"
        );
    });
}

/// Compression never exceeds its bit budget when the budget is feasible,
/// and higher budgets never hurt reconstruction (monotonicity).
#[test]
fn prop_budget_respected_and_monotone() {
    for_cases(6, |rng| {
        let size = 192 + 32 * rng.below(3) as usize;
        let gamma = 0.2 + 0.3 * rng.uniform();
        let spec = SynthSpec { rows: size, cols: size, gamma, coherence: 0.6, scale: 1.0 };
        let w = synth_weight(&spec, rng);
        let mut prev_mse = f64::INFINITY;
        for bpp in [0.55, 1.0, 1.5] {
            let cfg = CompressionConfig {
                bpp,
                strategy: InitStrategy::JointItq { iters: 15 },
                residual: true,
                ..Default::default()
            };
            let mut crng = Pcg64::seed(17);
            let c = compress(&w, &cfg, &mut crng);
            let actual = c.storage_bits() as f64 / (size * size) as f64;
            assert!(actual <= bpp + 1e-9, "bpp {actual} > {bpp}");
            let mse = c.reconstruct().mse(&w);
            assert!(
                mse <= prev_mse * 1.05,
                "budget up, error up: {mse} after {prev_mse} at {bpp}"
            );
            prev_mse = mse;
        }
    });
}

/// The L1/L2-norm duality behind Lemma 4.2: ‖u‖₁ ≤ √r·‖u‖₂ with equality
/// iff |u| is constant — checked on random and constant vectors.
#[test]
fn prop_norm_duality() {
    for_cases(40, |rng| {
        let r = rand_dims(rng, 1, 256);
        let mut u = vec![0.0f32; r];
        rng.fill_normal(&mut u);
        assert!(norm1(&u) <= (r as f64).sqrt() * norm2(&u) + 1e-6);
        let c = vec![0.7f32; r];
        let gap = (r as f64).sqrt() * norm2(&c) - norm1(&c);
        assert!(gap.abs() < 1e-3, "equality case violated: {gap}");
    });
}

/// SVD reconstruction error never exceeds the spectrum's tail energy by
/// more than oversampling slack (randomized SVD near-optimality).
#[test]
fn prop_randomized_svd_near_optimal() {
    for_cases(8, |rng| {
        let n = 96;
        let gamma = 0.3 + 0.5 * rng.uniform();
        let spec = SynthSpec { rows: n, cols: n, gamma, coherence: 0.4, scale: 1.0 };
        let w = synth_weight(&spec, rng);
        let r = 8 + rng.below(17) as usize;
        let svd = svd_randomized(&w, r, 10, 3, rng);
        let err = svd.reconstruct().fro_dist2(&w);
        let s_full = svd_randomized(&w, n, 8, 3, rng).s;
        let opt: f64 = s_full[r..].iter().map(|&x| (x as f64).powi(2)).sum();
        assert!(err <= opt * 1.5 + 1e-9, "err={err} opt={opt} r={r}");
    });
}
