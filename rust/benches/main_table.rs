//! Tables 1 (fidelity rows), 2 & 3 — reconstruction-fidelity proxy sweep.
//!
//! The paper's PPL/zero-shot columns require Llama/Gemma checkpoints and
//! the QAKD pipeline (the e2e_qat example covers the trained-model arm at
//! small scale). This bench regenerates the *method ordering* of those
//! tables at matched bit budgets on the synthetic-LLM zoo: per-method
//! mean reconstruction MSE across every layer of each model stand-in, at
//! 1.0 / 0.55 / 0.1 bpp — the initialization-fidelity signal that drives
//! the PPL ordering (§5.2-5.3).

#[path = "common/mod.rs"]
mod common;

use littlebit2::littlebit::{compress, CompressionConfig, InitStrategy};
use littlebit2::memory::tiny_rank_for_budget;
use littlebit2::model::{zoo, ArchSpec};
use littlebit2::quant::{arb_style, billm_style, onebit, rtn, tiny_rank_fp16};
use littlebit2::rng::Pcg64;

fn main() {
    let (shrink, blocks) = if common::full_scale() { (8, 4) } else { (32, 2) };
    println!("# Tables 1/2/3 fidelity proxy: per-method mean layer MSE on the zoo");
    println!("ROW: model bpp method mean_mse");
    for model in ["llama2-7b", "llama3-8b", "llama2-13b", "gemma3-27b"] {
        let arch = ArchSpec::by_name(model).expect("known");
        let layers = zoo::fabricate(&arch, shrink, blocks, 2026);

        // ~1-bit regime baselines (group/format-fixed budgets).
        let mut onebit_mse = 0.0;
        let mut billm_mse = 0.0;
        let mut arb_mse = 0.0;
        let mut rtn2_mse = 0.0;
        for l in &layers {
            onebit_mse += onebit(&l.weight, 20).reconstruction.mse(&l.weight);
            billm_mse += billm_style(&l.weight, 8, 64).reconstruction.mse(&l.weight);
            arb_mse += arb_style(&l.weight, 10).reconstruction.mse(&l.weight);
            rtn2_mse += rtn(&l.weight, 2, 128).reconstruction.mse(&l.weight);
        }
        let n = layers.len() as f64;
        println!("ROW: {model} 2.25 gptq_rtn2 {:.6e}", rtn2_mse / n);
        println!("ROW: {model} 1.1 billm {:.6e}", billm_mse / n);
        println!("ROW: {model} 1.1 arb {:.6e}", arb_mse / n);
        println!("ROW: {model} 1.0 onebit {:.6e}", onebit_mse / n);

        for &bpp in &[1.0, 0.55, 0.1] {
            let mut fp = 0.0;
            let mut lb = 0.0;
            let mut rot = 0.0;
            let mut itq = 0.0;
            for (li, l) in layers.iter().enumerate() {
                let (rows, cols) = l.weight.shape();
                let mut rng = Pcg64::seed(3000 + li as u64);
                let r_fp = tiny_rank_for_budget(cols, rows, bpp);
                fp += tiny_rank_fp16(&l.weight, r_fp, &mut rng)
                    .reconstruction
                    .mse(&l.weight);
                let run = |strategy| {
                    let mut rng = Pcg64::seed(3200 + li as u64);
                    let cfg = CompressionConfig {
                        bpp,
                        strategy,
                        residual: true,
                        ..Default::default()
                    };
                    compress(&l.weight, &cfg, &mut rng).reconstruct().mse(&l.weight)
                };
                lb += run(InitStrategy::Standard);
                rot += run(InitStrategy::RandomRotation);
                itq += run(InitStrategy::JointItq { iters: 30 });
            }
            println!("ROW: {model} {bpp} tinyrank_fp {:.6e}", fp / n);
            println!("ROW: {model} {bpp} littlebit {:.6e}", lb / n);
            println!("ROW: {model} {bpp} littlebit_rot {:.6e}", rot / n);
            println!("ROW: {model} {bpp} littlebit2 {:.6e}", itq / n);
        }
    }
    println!("# expected ordering at each bpp: littlebit2 < littlebit_rot < littlebit; fp collapses at 0.1");
}
