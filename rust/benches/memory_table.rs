//! Tables 1 & 2 — Memory columns (Body/Total GB and % of FP16).
//!
//! These are computed **exactly** (public architecture shapes + the App. H
//! formulas); asserted against the paper's printed values where given.

#[path = "common/mod.rs"]
mod common;

use littlebit2::memory::{model_memory, MethodKind};
use littlebit2::model::ArchSpec;

fn main() {
    let methods = [
        MethodKind::Fp16,
        MethodKind::Rtn { k: 2, group: 128 },
        MethodKind::Billm,
        MethodKind::Arb,
        MethodKind::OneBit,
        MethodKind::LittleBit { bpp: 1.0 },
        MethodKind::LittleBit { bpp: 0.55 },
        MethodKind::LittleBit { bpp: 0.1 },
        MethodKind::TinyRank { bpp: 0.1 },
    ];
    println!("# Table 1/2 memory columns (exact, Eqs. 21-26)");
    println!("ROW: model method body_gb body_pct total_gb total_pct");
    for name in ArchSpec::KNOWN {
        let arch = ArchSpec::by_name(name).expect("known");
        for m in methods {
            let mm = model_memory(&arch, m);
            println!(
                "ROW: {} {} {:.2} {:.1} {:.2} {:.1}",
                arch.name,
                mm.method.replace(' ', "_"),
                mm.body_gb(),
                mm.body_pct(),
                mm.total_gb(),
                mm.total_pct()
            );
        }
    }

    // Spot-assert the paper's printed Table 1 values.
    let checks = [
        ("llama2-7b", MethodKind::Fp16, 13.0, 13.5),
        ("llama2-7b", MethodKind::OneBit, 0.8, 1.4),
        ("llama2-7b", MethodKind::LittleBit { bpp: 0.55 }, 0.5, 1.0),
        ("llama3-8b", MethodKind::Fp16, 14.0, 16.1),
        ("llama3-8b", MethodKind::LittleBit { bpp: 0.1 }, 0.1, 2.2),
        ("llama2-13b", MethodKind::LittleBit { bpp: 1.0 }, 1.6, 2.3),
    ];
    for (model, method, body, total) in checks {
        let mm = model_memory(&ArchSpec::by_name(model).expect("known"), method);
        assert!(
            (mm.body_gb() - body).abs() < 0.11,
            "{model} {method:?}: body {} vs paper {body}",
            mm.body_gb()
        );
        assert!(
            (mm.total_gb() - total).abs() < 0.16,
            "{model} {method:?}: total {} vs paper {total}",
            mm.total_gb()
        );
    }
    println!("# all spot-checks vs the printed Table 1 values passed");
}
