//! Compression throughput — the offline half of the perf story.
//!
//! PRs 1–3 made *serving* fast; this bench tracks the quantization
//! pipeline itself (truncated SVD → Joint-ITQ → Dual-SVID → pack) across
//! the two parallelism axes PR 4 added:
//!
//! 1. **layer-parallel** — `run_compression_jobs_streaming` with one
//!    claim-loop per core (the `compress --jobs N` path), per-layer linalg
//!    serial;
//! 2. **linalg-parallel** — a single layer with its SVD/ITQ/SVID products
//!    row-partitioned over the shared pool (the `--jobs 1` path for one
//!    huge matrix).
//!
//! Reported as layers/s for a synthetic chain (serial vs pooled, with the
//! aggregated per-stage wall-clock split), plus single-layer serial-vs-pool
//! wall-clock. Every configuration is **byte-identical** on the artifact
//! encoding — asserted here, not assumed — so the ratios are pure
//! scheduling measurements.
//!
//! Besides the `ROW:` lines, results are written machine-readable to
//! `BENCH_compress.json` at the repository root (the cross-PR
//! compression-throughput record; methodology in EXPERIMENTS.md
//! #Compression-throughput).

#[path = "common/mod.rs"]
mod common;

use littlebit2::coordinator::{run_compression_jobs_streaming, CompressionJob, JobInput};
use littlebit2::littlebit::{
    compress_pipeline, CompressionConfig, CompressionReport, InitStrategy,
};
use littlebit2::model::PackedStack;
use littlebit2::parallel::Pool;
use littlebit2::quant::MethodSpec;
use littlebit2::rng::{derive_seed, Pcg64};
use littlebit2::spectral::{synth_weight, SynthSpec};

struct ModeRow {
    mode: &'static str,
    jobs: usize,
    wall_s: f64,
    layers_per_s: f64,
    stages: CompressionReport,
}

fn main() {
    let (size, layers) = if common::full_scale() { (512, 12) } else { (160, 8) };
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let cfg = CompressionConfig {
        bpp: 0.55,
        strategy: InitStrategy::JointItq { iters: 20 },
        residual: true,
        ..Default::default()
    };
    let spec = SynthSpec { rows: size, cols: size, gamma: 0.3, coherence: 0.6, scale: 1.0 };
    println!(
        "# compression throughput: {layers} layers of {size}x{size} at 0.55 bpp (ITQ 20), {threads} threads"
    );

    let mk_jobs = || -> Vec<CompressionJob> {
        (0..layers)
            .map(|k| CompressionJob {
                name: format!("layer{k}"),
                input: JobInput::Synth { spec: spec.clone(), seed: derive_seed(7, 2 * k as u64) },
                method: MethodSpec::LittleBit2(cfg.clone()),
                seed: derive_seed(7, 2 * k as u64 + 1),
            })
            .collect()
    };
    // Run a whole chain on `jobs` claim-loops, returning wall-clock, the
    // aggregated stage split, and the artifact bytes (for the determinism
    // assertion).
    let run_chain = |jobs_n: usize| -> (f64, CompressionReport, Vec<u8>) {
        let t0 = std::time::Instant::now();
        let mut stages = CompressionReport::default();
        let mut packed = Vec::with_capacity(layers);
        run_compression_jobs_streaming(mk_jobs(), jobs_n, |_, outcome| {
            stages.accumulate(&outcome.result.report);
            packed.push(outcome.layer.into_packed().expect("littlebit2 layer"));
            Ok(())
        })
        .expect("infallible jobs");
        let wall = t0.elapsed().as_secs_f64();
        let bytes = PackedStack::new(packed).to_artifact_bytes().expect("encode artifact");
        (wall, stages, bytes)
    };

    println!("ROW: mode jobs wall_s layers_per_s svd_ms itq_ms svid_ms pack_ms");
    let mut rows = Vec::new();
    let (serial_wall, serial_stages, serial_bytes) = run_chain(1);
    rows.push(ModeRow {
        mode: "serial",
        jobs: 1,
        wall_s: serial_wall,
        layers_per_s: layers as f64 / serial_wall,
        stages: serial_stages,
    });
    let (pool_wall, pool_stages, pool_bytes) = run_chain(threads);
    rows.push(ModeRow {
        mode: "pooled",
        jobs: threads,
        wall_s: pool_wall,
        layers_per_s: layers as f64 / pool_wall,
        stages: pool_stages,
    });
    // The acceptance contract: worker count must not change a single byte.
    assert_eq!(serial_bytes, pool_bytes, "artifact bytes differ between --jobs 1 and --jobs N");
    for r in &rows {
        println!(
            "ROW: {} {} {:.3} {:.2} {:.0} {:.0} {:.0} {:.0}",
            r.mode,
            r.jobs,
            r.wall_s,
            r.layers_per_s,
            r.stages.svd_ms,
            r.stages.itq_ms,
            r.stages.svid_ms,
            r.stages.pack_ms
        );
    }
    println!(
        "# layer-parallel speedup: {:.2}x on {threads} threads; artifacts byte-identical",
        serial_wall / pool_wall
    );

    // Single-layer axis: same weight, serial vs pooled linalg.
    let w = synth_weight(&spec, &mut Pcg64::seed(91));
    let reps = 3;
    let (single_serial_ms, _) = common::time_ms(reps, || {
        std::hint::black_box(compress_pipeline(&w, &cfg, &mut Pcg64::seed(92), Pool::serial()));
    });
    let (single_pool_ms, _) = common::time_ms(reps, || {
        std::hint::black_box(compress_pipeline(&w, &cfg, &mut Pcg64::seed(92), Pool::global()));
    });
    println!(
        "ROW: single_layer_linalg serial_ms {single_serial_ms:.1} pooled_ms {single_pool_ms:.1} speedup {:.2}",
        single_serial_ms / single_pool_ms
    );

    let json_path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_compress.json");
    match std::fs::write(
        json_path,
        render_json(size, layers, threads, &rows, single_serial_ms, single_pool_ms),
    ) {
        Ok(()) => println!("# wrote {json_path}"),
        Err(e) => eprintln!("# could not write {json_path}: {e}"),
    }
}

/// Hand-rolled JSON (no serde offline): the cross-PR compression-throughput
/// record.
fn render_json(
    size: usize,
    layers: usize,
    threads: usize,
    rows: &[ModeRow],
    single_serial_ms: f64,
    single_pool_ms: f64,
) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"bench\": \"compress_speedup\",\n");
    s.push_str("  \"status\": \"measured\",\n");
    s.push_str(&format!(
        "  \"shape\": {{\"size\": {size}, \"layers\": {layers}}},\n  \"bpp\": 0.55,\n  \"itq_iters\": 20,\n  \"threads\": {threads},\n"
    ));
    s.push_str("  \"modes\": [\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"mode\": \"{}\", \"jobs\": {}, \"wall_s\": {:.3}, \"layers_per_s\": {:.2}, \"stage_ms\": {{\"svd\": {:.1}, \"itq\": {:.1}, \"svid\": {:.1}, \"pack\": {:.1}}}}}{}\n",
            r.mode,
            r.jobs,
            r.wall_s,
            r.layers_per_s,
            r.stages.svd_ms,
            r.stages.itq_ms,
            r.stages.svid_ms,
            r.stages.pack_ms,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    s.push_str("  ],\n");
    s.push_str(&format!(
        "  \"single_layer_linalg\": {{\"serial_ms\": {:.1}, \"pooled_ms\": {:.1}, \"speedup\": {:.2}}},\n",
        single_serial_ms,
        single_pool_ms,
        single_serial_ms / single_pool_ms
    ));
    s.push_str("  \"determinism\": \"artifact bytes identical for jobs in {1, threads} (asserted)\"\n");
    s.push_str("}\n");
    s
}
