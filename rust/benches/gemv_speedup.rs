//! §6.2 — Inference efficiency: MatMul-free kernel speedup.
//!
//! The paper reports a Llama-2 70B MLP layer at 0.1 bpp running 11.6×
//! faster than cuBLAS FP16 (0.288 ms → 0.025 ms) and 90.2M FLOPs → 13M
//! sign-adds at 0.3 bpp. This bench reproduces the *shape* of both claims
//! on CPU: dense f32 GEMV vs the packed tri-scale pipeline across budgets,
//! plus the op-count accounting.

#[path = "common/mod.rs"]
mod common;

use common::time_ms;
use littlebit2::littlebit::{compress, CompressionConfig, InitStrategy};
use littlebit2::packing::gemv_dense;
use littlebit2::rng::Pcg64;
use littlebit2::spectral::{synth_weight, SynthSpec};

fn main() {
    // MLP-shaped layer (d_ff×d_model ratio of Llama-2).
    let (d_out, d_in) = if common::full_scale() { (11008, 4096) } else { (2752, 1024) };
    println!("# §6.2: dense vs packed GEMV, MLP-shaped {d_out}x{d_in}");
    let mut rng = Pcg64::seed(62);
    let spec = SynthSpec { rows: d_out, cols: d_in, gamma: 0.3, coherence: 0.6, scale: 1.0 };
    let w = synth_weight(&spec, &mut rng);
    let mut x = vec![0.0f32; d_in];
    rng.fill_normal(&mut x);
    let mut y = vec![0.0f32; d_out];

    let reps = if common::full_scale() { 20 } else { 50 };
    let (dense_ms, dense_sd) = time_ms(reps, || gemv_dense(&w, &x, &mut y));
    println!("ROW: dense_f32 - {dense_ms:.4} {dense_sd:.4} 1.00");

    println!("ROW: method bpp mean_ms sd_ms speedup sign_adds fp_mults");
    for &bpp in &[1.0, 0.55, 0.3, 0.1] {
        let cfg = CompressionConfig {
            bpp,
            strategy: InitStrategy::JointItq { iters: 20 },
            residual: true,
            ..Default::default()
        };
        let mut crng = Pcg64::seed(63);
        let c = compress(&w, &cfg, &mut crng);
        let layers: Vec<_> = c.paths.iter().map(|p| p.pack()).collect();
        let mut scratch = littlebit2::packing::Scratch::default();
        let mut out = vec![0.0f32; d_out];
        let (ms, sd) = time_ms(reps, || {
            layers[0].forward_into(&x, &mut out, &mut scratch);
            for layer in &layers[1..] {
                layer.forward_accumulate(&x, &mut out, &mut scratch);
            }
            std::hint::black_box(&out);
        });
        let (adds, mults) = layers[0].op_counts();
        let total_adds = adds * layers.len();
        let total_mults = mults * layers.len();
        println!(
            "ROW: packed_tri_scale {bpp} {ms:.4} {sd:.4} {:.2} {total_adds} {total_mults}",
            dense_ms / ms
        );
    }
    println!(
        "# dense op count: {} fp-MACs; paper: 90.2M FLOPs → 13M adds at 0.3bpp on 70B-MLP, 11.6x kernel speedup at 0.1bpp",
        d_out * d_in
    );
}
