//! Figs. 3, 4, 5 — Latent Geometry Misalignment diagnostics.
//!
//! Regenerates: (Fig 3) per-row λ distribution before/after alignment with
//! peak-distortion suppression; (Fig 4) latent histogram Gaussianization
//! under rotation; (Fig 5) bimodal separation under Joint-ITQ, summarized
//! by the zero-margin mass (fraction of latent entries near the decision
//! boundary) and the mean/max λ trajectory quoted in §4.3-4.4.

#[path = "common/mod.rs"]
mod common;

use littlebit2::linalg::{svd_randomized, Mat};
use littlebit2::littlebit::{joint_itq, random_rotation};
use littlebit2::quant::row_distortions;
use littlebit2::rng::Pcg64;
use littlebit2::spectral::{synth_weight, SynthSpec};

fn lambda_stats(m: &Mat) -> (f64, f64) {
    let lam = row_distortions(m);
    let mean = lam.iter().sum::<f64>() / lam.len() as f64;
    let max = lam.iter().fold(0.0f64, |a, &b| a.max(b));
    (mean, max)
}

/// Fraction of entries within ±10% of zero relative to the row scale — the
/// "uncertainty zone" mass of §4.4 / Fig 8's oscillation mechanism.
fn zero_margin_mass(m: &Mat) -> f64 {
    let mut near = 0usize;
    let mut total = 0usize;
    for i in 0..m.rows() {
        let row = m.row(i);
        let scale = littlebit2::linalg::norm2(row) / (row.len() as f64).sqrt();
        for &x in row {
            if (x as f64).abs() < 0.1 * scale {
                near += 1;
            }
            total += 1;
        }
    }
    near as f64 / total as f64
}

fn main() {
    let size = if common::full_scale() { 4096 } else { 1024 };
    let rank = size / 16;
    println!("# Figs 3/4/5: latent geometry, q_proj-shaped {size}x{size}, r={rank}");
    let mut rng = Pcg64::seed(15);
    let spec = SynthSpec { rows: size, cols: size, gamma: 0.32, coherence: 0.85, scale: 1.0 };
    let w = synth_weight(&spec, &mut rng);
    let svd = svd_randomized(&w, rank, 10, 2, &mut rng);
    let (u, v) = svd.split_factors();

    println!("ROW: stage lambda_mean lambda_max zero_margin_mass");
    let (m0, x0) = lambda_stats(&u);
    println!("ROW: svd {m0:.4} {x0:.4} {:.4}", zero_margin_mass(&u));

    let rot = random_rotation(rank, &mut rng);
    let u_rot = u.matmul(&rot);
    let (m1, x1) = lambda_stats(&u_rot);
    println!("ROW: rotation {m1:.4} {x1:.4} {:.4}", zero_margin_mass(&u_rot));

    let (itq_rot, _) = joint_itq(&u, &v, 50, &mut rng);
    let u_itq = u.matmul(&itq_rot);
    let (m2, x2) = lambda_stats(&u_itq);
    println!("ROW: joint_itq {m2:.4} {x2:.4} {:.4}", zero_margin_mass(&u_itq));

    println!("# paper: SVD λ_max≈0.88 kurtosis≈16.8 → rotation mean≈0.36 max≈0.43 → ITQ mean≈0.30");
    println!("# gaussian limit 1-2/π ≈ 0.3634; ITQ must fall below it and shrink zero-margin mass");
    assert!(m1 < m0 && m2 < m1, "alignment hierarchy violated");
}
