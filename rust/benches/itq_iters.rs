//! Fig. 13 (App. F.1) — Joint-ITQ convergence vs overhead.
//!
//! Sweeps the iteration count T ∈ [0, 100] on a q_proj-shaped weight,
//! reporting reconstruction MSE and cumulative wall-clock (SVD + ITQ +
//! SVID), reproducing the dual-axis saturation plot: MSE plateaus near
//! T = 50 while time grows linearly.

#[path = "common/mod.rs"]
mod common;

use littlebit2::linalg::svd_randomized;
use littlebit2::littlebit::{dual_svid, joint_itq};
use littlebit2::memory::littlebit_rank_for_budget;
use littlebit2::rng::Pcg64;
use littlebit2::spectral::{synth_weight, SynthSpec};
use std::time::Instant;

fn main() {
    let size = if common::full_scale() { 4096 } else { 768 };
    let bpp = 0.55;
    let rank = littlebit_rank_for_budget(size, size, bpp);
    println!("# Fig 13: ITQ iterations sweep, q_proj-shaped {size}x{size}, r={rank}");
    let mut rng = Pcg64::seed(15);
    let spec = SynthSpec { rows: size, cols: size, gamma: 0.32, coherence: 0.8, scale: 1.0 };
    let w = synth_weight(&spec, &mut rng);

    let t_svd0 = Instant::now();
    let svd = svd_randomized(&w, rank, 10, 2, &mut rng);
    let (u, v) = svd.split_factors();
    let svd_s = t_svd0.elapsed().as_secs_f64();

    println!("ROW: iters mse wall_s");
    for &iters in &[0usize, 5, 10, 20, 30, 50, 75, 100] {
        let mut rng = Pcg64::seed(16);
        let t0 = Instant::now();
        let (rot, _) = joint_itq(&u, &v, iters, &mut rng);
        let factors = dual_svid(&u.matmul(&rot), &v.matmul(&rot));
        let dt = svd_s + t0.elapsed().as_secs_f64();
        let mse = factors.reconstruct().mse(&w);
        println!("ROW: {iters} {mse:.6e} {dt:.3}");
    }
    println!("# paper: MSE saturates near T=50; T=0 ≈ 4s, T=50 ≈ 7s at 4096²");
}
