//! Fig. 10 (App. E) — Empirical Spectral Break-Even across bit-rates.
//!
//! MSE vs γ for budgets 1.0 → 0.1 bpp, aggregated over the synthetic-LLM
//! zoo (the 8-model substitute), tracking how the FP16-vs-LittleBit-2
//! crossover shifts with compression.

#[path = "common/mod.rs"]
mod common;

use littlebit2::littlebit::{compress, CompressionConfig, InitStrategy};
use littlebit2::memory::tiny_rank_for_budget;
use littlebit2::quant::tiny_rank_fp16;
use littlebit2::rng::Pcg64;
use littlebit2::spectral::{synth_weight, SynthSpec};

fn main() {
    let size = if common::full_scale() { 2048 } else { 384 };
    let bpps = [1.0, 0.55, 0.3, 0.1];
    let gammas: Vec<f64> = (1..=8).map(|i| 0.1 * i as f64).collect();
    println!("# Fig 10: MSE vs gamma across bpp, W {size}x{size}");
    println!("ROW: bpp gamma tinyrank_fp littlebit littlebit2");
    for &bpp in &bpps {
        for (gi, &gamma) in gammas.iter().enumerate() {
            let mut rng = Pcg64::seed(4000 + gi as u64);
            let spec = SynthSpec { rows: size, cols: size, gamma, coherence: 0.7, scale: 1.0 };
            let w = synth_weight(&spec, &mut rng);
            let r_fp = tiny_rank_for_budget(size, size, bpp);
            let fp = tiny_rank_fp16(&w, r_fp, &mut rng).reconstruction.mse(&w);
            let binary = |strategy| {
                let mut rng = Pcg64::seed(5500 + gi as u64);
                let cfg =
                    CompressionConfig { bpp, strategy, residual: true, ..Default::default() };
                compress(&w, &cfg, &mut rng).reconstruct().mse(&w)
            };
            println!(
                "ROW: {bpp} {gamma:.1} {fp:.6e} {:.6e} {:.6e}",
                binary(InitStrategy::Standard),
                binary(InitStrategy::JointItq { iters: 50 })
            );
        }
    }
    println!("# paper: LittleBit-2 dominates γ ≲ 0.5; crossover shifts with bpp");
}
