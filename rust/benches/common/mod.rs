//! Shared bench scaffolding (criterion is unavailable offline, so benches
//! are `harness = false` binaries with a small timing helper; `cargo bench`
//! runs them all). Keep output machine-greppable: one `ROW:`-prefixed line
//! per series point, mirroring the paper table/figure it regenerates.

use std::time::Instant;

/// Time `f` with warmup; returns (mean_ms, std_ms) over `reps`.
pub fn time_ms(reps: usize, mut f: impl FnMut()) -> (f64, f64) {
    f(); // warmup
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    let mean = samples.iter().sum::<f64>() / reps as f64;
    let var = samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>() / reps as f64;
    (mean, var.sqrt())
}

/// Scale knob: `LB2_BENCH_SCALE=full` runs paper-scale shapes; default is a
/// CPU-budget reduction with identical structure.
pub fn full_scale() -> bool {
    std::env::var("LB2_BENCH_SCALE").map(|v| v == "full").unwrap_or(false)
}
