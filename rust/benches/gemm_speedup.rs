//! §6.2 at batch > 1 — the speedup-vs-batch curve of the batched
//! MatMul-free engine, now including fused-vs-unfused and pool-vs-scoped.
//!
//! Sweeps batch size {1, 8, 32, 128} on the MLP-shaped layer and reports
//! rows/s (batch items per second) for five executions of the SAME packed
//! weights:
//!
//! 1. dense f32 GEMV per item (the cuBLAS stand-in),
//! 2. packed tri-scale GEMV per item (fused, scratch-reusing),
//! 3. the **PR 1 baseline**: unfused batched sign-GEMM — three scale
//!    passes with intermediate `Mat`s around plain `gemm_sign`, row ranges
//!    on per-call `std::thread::scope` spawns
//!    (`PackedResidual::forward_batch_scoped`),
//! 4. the fused serial sign-GEMM (`forward_batch`, scales folded into the
//!    kernel), and
//! 5. the fused **pool** path (`forward_batch_into` on the persistent
//!    `SignPool` with a reused `BatchScratch` — the serving hot path).
//!
//! The last column is the tentpole headline: fused-pool rows/s over the
//! PR 1 scoped-unfused rows/s at the same thread count (expected ≥ 1.3× at
//! batch 32 on ≥ 2 threads — acceptance criterion of issue 2). All five
//! paths are bit-identical per column (enforced by the packing tests), so
//! every ratio is a pure overhead measurement. Methodology in
//! EXPERIMENTS.md §Fused.
//!
//! The whole sweep runs once per available SIMD lane (scalar always; AVX2
//! when the machine has it), pinned via `packing::force_scalar` — the
//! lanes are bit-identical (enforced by the packing tests), so the
//! per-lane rows isolate pure kernel throughput.
//!
//! Besides the `ROW:` lines, the sweep is written machine-readable to
//! `BENCH_gemm.json` at the repository root so the perf trajectory is
//! trackable across PRs.

#[path = "common/mod.rs"]
mod common;

use common::time_ms;
use littlebit2::linalg::Mat;
use littlebit2::littlebit::{compress, CompressionConfig, InitStrategy};
use littlebit2::packing::{
    active_lane, force_scalar, gemv_dense, scalar_forced, BatchScratch, Lane, Scratch, SignPool,
};
use littlebit2::rng::Pcg64;
use littlebit2::spectral::{synth_weight, SynthSpec};

struct Row {
    lane: &'static str,
    batch: usize,
    dense: f64,
    gemv: f64,
    scoped: f64,
    fused: f64,
    fused_pool: f64,
}

fn main() {
    // MLP-shaped layer (d_ff×d_model ratio of Llama-2).
    let (d_out, d_in) = if common::full_scale() { (11008, 4096) } else { (2752, 1024) };
    let bpp = 0.55;
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!(
        "# §6.2 batched: dense vs packed GEMV vs sign-GEMM (scoped-unfused vs fused-pool), {d_out}x{d_in} at {bpp} bpp, {threads} threads"
    );

    let mut rng = Pcg64::seed(62);
    let spec = SynthSpec { rows: d_out, cols: d_in, gamma: 0.3, coherence: 0.6, scale: 1.0 };
    let w = synth_weight(&spec, &mut rng);
    let cfg = CompressionConfig {
        bpp,
        strategy: InitStrategy::JointItq { iters: 20 },
        residual: true,
        ..Default::default()
    };
    let mut crng = Pcg64::seed(63);
    let packed = compress(&w, &cfg, &mut crng).pack();
    let pool = SignPool::global();

    println!(
        "ROW: lane batch dense_rows_s gemv_rows_s scoped_mt_rows_s fused_rows_s fused_pool_rows_s fused_pool_vs_scoped"
    );
    // One full sweep per available lane, scalar last so a leftover pin
    // from the environment is preserved faithfully.
    let lanes: &[Lane] =
        if active_lane() == Lane::Avx2 { &[Lane::Avx2, Lane::Scalar] } else { &[Lane::Scalar] };
    let pinned = scalar_forced();
    let mut rows: Vec<Row> = Vec::new();
    for &lane in lanes {
        force_scalar(lane == Lane::Scalar);
        sweep(lane, &w, &packed, pool, threads, &mut rng, &mut rows);
    }
    force_scalar(pinned);
    let (adds, mults) = packed.op_counts();
    println!(
        "# per-item ops: {adds} sign-adds + {mults} fp-mults vs {} dense fp-MACs; fused kernels make zero separate scale passes, pool dispatch spawns zero threads",
        d_out * d_in
    );

    let json_path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_gemm.json");
    match std::fs::write(json_path, render_json(d_out, d_in, bpp, threads, &rows)) {
        Ok(()) => println!("# wrote {json_path}"),
        Err(e) => eprintln!("# could not write {json_path}: {e}"),
    }
}

#[allow(clippy::too_many_arguments)]
fn sweep(
    lane: Lane,
    w: &Mat,
    packed: &littlebit2::packing::PackedResidual,
    pool: &SignPool,
    threads: usize,
    rng: &mut Pcg64,
    rows: &mut Vec<Row>,
) {
    let d_in = packed.d_in();
    for &b in &[1usize, 8, 32, 128] {
        // Feature-major activation block (column t = item t) + per-item views.
        let mut xblock = Mat::zeros(d_in, b);
        xblock.fill_normal(rng);
        let items: Vec<Vec<f32>> = (0..b).map(|t| xblock.col(t)).collect();
        let reps = (256 / b).max(3);

        // Dense f32 GEMV, one pass per item.
        let mut y = vec![0.0f32; packed.d_out()];
        let (dense_ms, _) = time_ms(reps, || {
            for x in &items {
                gemv_dense(w, x, &mut y);
            }
            std::hint::black_box(&y);
        });

        // Packed tri-scale GEMV, one pass per item (fused, scratch reused).
        let mut scratch = Scratch::default();
        let mut out = vec![0.0f32; packed.d_out()];
        let (gemv_ms, _) = time_ms(reps, || {
            for x in &items {
                packed.forward_into(x, &mut out, &mut scratch);
            }
            std::hint::black_box(&out);
        });

        // PR 1 baseline: unfused batched sign-GEMM on scoped spawns.
        let (scoped_ms, _) = time_ms(reps, || {
            std::hint::black_box(packed.forward_batch_scoped(&xblock, threads));
        });

        // Fused serial sign-GEMM: whole block, one thread, no scale passes.
        let (fused_ms, _) = time_ms(reps, || {
            std::hint::black_box(packed.forward_batch(&xblock));
        });

        // Fused pool path: persistent workers + reused BatchScratch — the
        // serving hot loop.
        let mut bscratch = BatchScratch::default();
        let mut yblock = Mat::default();
        let (pool_ms, _) = time_ms(reps, || {
            packed.forward_batch_into(&xblock, &mut yblock, &mut bscratch, pool, threads);
            std::hint::black_box(&yblock);
        });

        let rate = |ms: f64| b as f64 / (ms / 1e3);
        let row = Row {
            lane: lane.name(),
            batch: b,
            dense: rate(dense_ms),
            gemv: rate(gemv_ms),
            scoped: rate(scoped_ms),
            fused: rate(fused_ms),
            fused_pool: rate(pool_ms),
        };
        println!(
            "ROW: {} {b} {:.0} {:.0} {:.0} {:.0} {:.0} {:.2}",
            row.lane,
            row.dense,
            row.gemv,
            row.scoped,
            row.fused,
            row.fused_pool,
            row.fused_pool / row.scoped
        );
        rows.push(row);
    }
}

/// Hand-rolled JSON (no serde offline): the cross-PR perf-trajectory record.
fn render_json(d_out: usize, d_in: usize, bpp: f64, threads: usize, rows: &[Row]) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"bench\": \"gemm_speedup\",\n");
    s.push_str("  \"status\": \"measured\",\n");
    s.push_str(&format!(
        "  \"shape\": {{\"d_out\": {d_out}, \"d_in\": {d_in}}},\n  \"bpp\": {bpp},\n  \"threads\": {threads},\n"
    ));
    s.push_str("  \"rows_per_s\": [\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"lane\": \"{}\", \"batch\": {}, \"dense_gemv\": {:.1}, \"packed_gemv\": {:.1}, \"scoped_mt\": {:.1}, \"fused\": {:.1}, \"fused_pool_mt\": {:.1}, \"fused_pool_vs_scoped\": {:.3}}}{}\n",
            r.lane,
            r.batch,
            r.dense,
            r.gemv,
            r.scoped,
            r.fused,
            r.fused_pool,
            r.fused_pool / r.scoped,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]\n}\n");
    s
}
