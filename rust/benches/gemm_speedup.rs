//! §6.2 at batch > 1 — the speedup-vs-batch curve of the batched
//! MatMul-free engine.
//!
//! Sweeps batch size {1, 8, 32, 128} on the MLP-shaped layer and reports
//! rows/s (batch items per second) for four executions of the SAME packed
//! weights: dense f32 GEMV per item (the cuBLAS stand-in), packed tri-scale
//! GEMV per item, the batched sign-GEMM ([`gemm_sign`]-based
//! `forward_batch`), and the row-parallel sign-GEMM (`forward_batch_mt` at
//! the machine's thread count). The point of the curve: per-item GEMV is
//! flat in batch size, while the GEMM path amortizes each 64-bit sign-word
//! load over 8 batch columns — rows/s at batch 32 should sit well above
//! the batch-1 GEMV rate. Methodology in EXPERIMENTS.md.

#[path = "common/mod.rs"]
mod common;

use common::time_ms;
use littlebit2::linalg::Mat;
use littlebit2::littlebit::{compress, CompressionConfig, InitStrategy};
use littlebit2::packing::{gemv_dense, Scratch};
use littlebit2::rng::Pcg64;
use littlebit2::spectral::{synth_weight, SynthSpec};

fn main() {
    // MLP-shaped layer (d_ff×d_model ratio of Llama-2).
    let (d_out, d_in) = if common::full_scale() { (11008, 4096) } else { (2752, 1024) };
    let bpp = 0.55;
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("# §6.2 batched: dense vs packed GEMV vs sign-GEMM, {d_out}x{d_in} at {bpp} bpp, {threads} threads");

    let mut rng = Pcg64::seed(62);
    let spec = SynthSpec { rows: d_out, cols: d_in, gamma: 0.3, coherence: 0.6, scale: 1.0 };
    let w = synth_weight(&spec, &mut rng);
    let cfg = CompressionConfig {
        bpp,
        strategy: InitStrategy::JointItq { iters: 20 },
        residual: true,
        ..Default::default()
    };
    let mut crng = Pcg64::seed(63);
    let packed = compress(&w, &cfg, &mut crng).pack();

    println!("ROW: batch dense_rows_s gemv_rows_s gemm_rows_s gemm_mt_rows_s gemm_vs_gemv1");
    let mut gemv_rate_b1 = 0.0f64;
    for &b in &[1usize, 8, 32, 128] {
        // Feature-major activation block (column t = item t) + per-item views.
        let mut xblock = Mat::zeros(d_in, b);
        rng.fill_normal(xblock.as_mut_slice());
        let items: Vec<Vec<f32>> = (0..b).map(|t| xblock.col(t)).collect();
        let reps = (256 / b).max(3);

        // Dense f32 GEMV, one pass per item.
        let mut y = vec![0.0f32; d_out];
        let (dense_ms, _) = time_ms(reps, || {
            for x in &items {
                gemv_dense(&w, x, &mut y);
            }
            std::hint::black_box(&y);
        });

        // Packed tri-scale GEMV, one pass per item (scratch reused).
        let mut scratch = Scratch::default();
        let mut out = vec![0.0f32; d_out];
        let (gemv_ms, _) = time_ms(reps, || {
            for x in &items {
                packed.forward_into(x, &mut out, &mut scratch);
            }
            std::hint::black_box(&out);
        });

        // Batched sign-GEMM: the whole block in one forward.
        let (gemm_ms, _) = time_ms(reps, || {
            std::hint::black_box(packed.forward_batch(&xblock));
        });

        // Row-parallel batched sign-GEMM.
        let (gemm_mt_ms, _) = time_ms(reps, || {
            std::hint::black_box(packed.forward_batch_mt(&xblock, threads));
        });

        let rate = |ms: f64| b as f64 / (ms / 1e3);
        if b == 1 {
            gemv_rate_b1 = rate(gemv_ms);
        }
        println!(
            "ROW: {b} {:.0} {:.0} {:.0} {:.0} {:.2}",
            rate(dense_ms),
            rate(gemv_ms),
            rate(gemm_ms),
            rate(gemm_mt_ms),
            rate(gemm_ms) / gemv_rate_b1
        );
    }
    let (adds, mults) = packed.op_counts();
    println!(
        "# per-item ops: {adds} sign-adds + {mults} fp-mults vs {} dense fp-MACs; gemm loads each sign word once per 8 batch columns",
        d_out * d_in
    );
}
