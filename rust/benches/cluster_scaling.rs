//! Cluster scaling: throughput and tail latency vs peer count, for both
//! shard modes.
//!
//! The tentpole question for sharded serving is where each cut pays:
//! pipeline parallelism splits *layers* (activations hop between stages,
//! so per-request latency gains little but stages can overlap distinct
//! requests), row sharding splits *rows* of every layer (each request
//! fans out and gathers per layer, trading one hop for `peers` smaller
//! GEMVs plus gather overhead). This bench drives a tracker + N peers
//! over loopback at peers ∈ {1, 2, 4} per mode and reports, per point:
//!
//! * `tok_s` — serial request throughput (single in-flight client; the
//!   dynamic-batching front-end is bench-marked separately).
//! * `p50_ms` / `p99_ms` — per-request latency quantiles.
//! * `stage_mean_us` — the tracker's own drive-hop timing, isolating
//!   compute + hop time from client-side framing.
//!
//! Everything runs in one process over 127.0.0.1, so numbers measure
//! protocol + kernel cost, not real network transit. Results land in
//! `BENCH_cluster.json` at the repository root.

#[path = "common/mod.rs"]
mod common;

use littlebit2::cluster::{Peer, PeerConfig, PeerHandle, ShardMode, Tracker, TrackerConfig};
use littlebit2::linalg::Mat;
use littlebit2::littlebit::{CompressionConfig, InitStrategy};
use littlebit2::model::{MethodStack, PackedStack};
use littlebit2::rng::Pcg64;
use littlebit2::serving::WireClient;
use littlebit2::spectral::{synth_weight, SynthSpec};
use std::time::{Duration, Instant};

struct Row {
    mode: &'static str,
    peers: usize,
    requests: usize,
    tok_s: f64,
    p50_ms: f64,
    p99_ms: f64,
    stage_mean_us: f64,
}

fn quantile(sorted_ms: &[f64], q: f64) -> f64 {
    if sorted_ms.is_empty() {
        return 0.0;
    }
    let idx = ((sorted_ms.len() - 1) as f64 * q).round() as usize;
    sorted_ms[idx]
}

fn measure(path: &std::path::Path, mode: ShardMode, n_peers: usize, requests: usize) -> Row {
    let tracker = Tracker::start(TrackerConfig {
        expect_peers: n_peers,
        heartbeat_timeout: Duration::from_millis(1000),
        ..TrackerConfig::new(path, mode)
    })
    .expect("tracker");
    let peers: Vec<PeerHandle> = (0..n_peers)
        .map(|_| {
            Peer::start(PeerConfig {
                heartbeat_interval: Duration::from_millis(100),
                ..PeerConfig::new(tracker.addr().to_string(), path)
            })
            .expect("peer")
        })
        .collect();
    assert!(tracker.wait_for_plan(Duration::from_secs(10)), "no plan");
    let t0 = Instant::now();
    while peers.iter().any(|p| p.epoch().is_none()) {
        assert!(t0.elapsed() < Duration::from_secs(10), "peers never loaded");
        std::thread::sleep(Duration::from_millis(20));
    }

    let shapes = littlebit2::artifact::load_stack_shapes(path).expect("shapes");
    let mut rng = Pcg64::seed(4242);
    let mut x = vec![0.0f32; shapes.d_in()];
    rng.fill_normal(&mut x);

    let mut client = WireClient::connect(tracker.addr()).expect("client");
    for i in 0..8u64 {
        client.infer(i, &x, 0).expect("warmup"); // warm conns + page cache
    }
    let mut lat_ms = Vec::with_capacity(requests);
    let run0 = Instant::now();
    for i in 0..requests as u64 {
        let t = Instant::now();
        client.infer(100 + i, &x, 0).expect("infer");
        lat_ms.push(t.elapsed().as_secs_f64() * 1e3);
    }
    let wall_s = run0.elapsed().as_secs_f64();
    drop(client);

    let stats = tracker.stats();
    let stage_mean_us = if stats.bytes_forward() > 0 {
        // Recompute from the exposition totals rather than re-exporting
        // raw counters: same number STATS reports.
        tracker
            .stats_text()
            .lines()
            .find_map(|l| l.strip_prefix("lb2_cluster_stage_mean_us "))
            .and_then(|v| v.trim().parse().ok())
            .unwrap_or(0.0)
    } else {
        0.0
    };
    for p in peers {
        p.stop();
    }
    let summary = tracker.shutdown();
    assert!(summary.reconciled, "ledger did not reconcile");

    lat_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let row = Row {
        mode: mode.label(),
        peers: n_peers,
        requests,
        tok_s: requests as f64 / wall_s,
        p50_ms: quantile(&lat_ms, 0.50),
        p99_ms: quantile(&lat_ms, 0.99),
        stage_mean_us,
    };
    println!(
        "ROW: {} {} {} {:.1} {:.3} {:.3} {:.1}",
        row.mode, row.peers, row.requests, row.tok_s, row.p50_ms, row.p99_ms, row.stage_mean_us
    );
    row
}

fn main() {
    let (size, depth, requests) =
        if common::full_scale() { (1024, 8, 400) } else { (256, 4, 120) };
    println!("# cluster scaling: {depth} layers of {size}x{size}, {requests} requests per point");

    let mut rng = Pcg64::seed(90);
    let dims = vec![size; depth + 1];
    let weights: Vec<Mat> = dims
        .windows(2)
        .map(|w| {
            let spec =
                SynthSpec { rows: w[1], cols: w[0], gamma: 0.3, coherence: 0.6, scale: 1.0 };
            synth_weight(&spec, &mut rng)
        })
        .collect();
    // Scaling is independent of compression quality — cheap init keeps the
    // bench budget on serving, not compressing.
    let cfg = CompressionConfig {
        bpp: 1.0,
        strategy: InitStrategy::Standard,
        residual: true,
        ..Default::default()
    };
    let stack = MethodStack::from(PackedStack::compress_chain(&weights, &cfg, &mut rng));
    let path = std::env::temp_dir().join(format!("lb2_bench_cluster_{}.lb2", std::process::id()));
    stack.save_aligned(&path).expect("save");

    println!("ROW: mode peers requests tok_s p50_ms p99_ms stage_mean_us");
    let mut rows = Vec::new();
    for mode in [ShardMode::Pipeline, ShardMode::RowShard] {
        for n in [1usize, 2, 4] {
            rows.push(measure(&path, mode, n, requests));
        }
    }
    let _ = std::fs::remove_file(&path);

    let json_path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_cluster.json");
    match std::fs::write(json_path, render_json(size, depth, &rows)) {
        Ok(()) => println!("# wrote {json_path}"),
        Err(e) => eprintln!("# could not write {json_path}: {e}"),
    }
}

fn render_json(size: usize, depth: usize, rows: &[Row]) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"bench\": \"cluster_scaling\",\n");
    s.push_str("  \"status\": \"ok\",\n");
    s.push_str(&format!(
        "  \"generated_by\": \"littlebit2 {} benches/cluster_scaling.rs\",\n",
        littlebit2::VERSION
    ));
    s.push_str(&format!("  \"config\": {{\"size\": {size}, \"depth\": {depth}}},\n"));
    s.push_str("  \"note\": \"Single in-flight client over loopback: protocol + kernel cost, no real network transit. tok_s = serial requests per second.\",\n");
    s.push_str("  \"points\": [\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"mode\": \"{}\", \"peers\": {}, \"requests\": {}, \"tok_s\": {:.2}, \"p50_ms\": {:.4}, \"p99_ms\": {:.4}, \"stage_mean_us\": {:.2}}}{}\n",
            r.mode,
            r.peers,
            r.requests,
            r.tok_s,
            r.p50_ms,
            r.p99_ms,
            r.stage_mean_us,
            if i + 1 < rows.len() { "," } else { "" },
        ));
    }
    s.push_str("  ]\n}\n");
    s
}
