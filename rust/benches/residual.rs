//! Fig. 14 (App. G) — Efficacy of the residual architecture.
//!
//! MSE vs memory budget (0.05–1.2 bpp) for residual (solid) vs single-path
//! (dashed) variants of FP16, LittleBit, LittleBit+Rot, and LittleBit-2.
//! Paper hierarchy: FP16 ≈ FP16(NoRes) > LittleBit > RandRot >
//! LittleBit-2(NoRes) ≳ LittleBit-2.

#[path = "common/mod.rs"]
mod common;

use littlebit2::littlebit::{compress, CompressionConfig, InitStrategy};
use littlebit2::memory::tiny_rank_for_budget;
use littlebit2::quant::tiny_rank_fp16;
use littlebit2::rng::Pcg64;
use littlebit2::spectral::{synth_weight, SynthSpec};

fn main() {
    let size = if common::full_scale() { 2048 } else { 512 };
    println!("# Fig 14: residual vs single-path MSE vs budget, W {size}x{size} γ=0.3");
    let mut rng = Pcg64::seed(14);
    let spec = SynthSpec { rows: size, cols: size, gamma: 0.3, coherence: 0.75, scale: 1.0 };
    let w = synth_weight(&spec, &mut rng);

    println!("ROW: bpp fp16 lb_res lb_single rot_res rot_single itq_res itq_single");
    for &bpp in &[0.1, 0.2, 0.4, 0.55, 0.8, 1.0, 1.2] {
        let r_fp = tiny_rank_for_budget(size, size, bpp);
        let fp = tiny_rank_fp16(&w, r_fp, &mut rng).reconstruction.mse(&w);
        let run = |strategy, residual| {
            let mut rng = Pcg64::seed(21);
            let cfg = CompressionConfig { bpp, strategy, residual, ..Default::default() };
            compress(&w, &cfg, &mut rng).reconstruct().mse(&w)
        };
        println!(
            "ROW: {bpp} {fp:.4e} {:.4e} {:.4e} {:.4e} {:.4e} {:.4e} {:.4e}",
            run(InitStrategy::Standard, true),
            run(InitStrategy::Standard, false),
            run(InitStrategy::RandomRotation, true),
            run(InitStrategy::RandomRotation, false),
            run(InitStrategy::JointItq { iters: 50 }, true),
            run(InitStrategy::JointItq { iters: 50 }, false),
        );
    }
    println!("# paper hierarchy: FP16 > LittleBit > RandRot > LittleBit-2(NoRes) ≳ LittleBit-2");
}
