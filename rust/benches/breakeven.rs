//! Fig. 6 (top) — Spectral Break-Even Analysis.
//!
//! Reconstruction MSE vs spectral decay rate γ at a fixed 1.0 bpp budget
//! for Tiny-Rank FP16, LittleBit, LittleBit+Rotation, and LittleBit-2.
//! The paper's claims under test: LittleBit beats FP16 only for γ ≲ 0.36;
//! rotation extends the crossover to ≈0.41 and Joint-ITQ to ≈0.51.

#[path = "common/mod.rs"]
mod common;

use littlebit2::littlebit::{compress, CompressionConfig, InitStrategy};
use littlebit2::memory::tiny_rank_for_budget;
use littlebit2::quant::tiny_rank_fp16;
use littlebit2::rng::Pcg64;
use littlebit2::spectral::{synth_weight, SynthSpec};

fn main() {
    let size = if common::full_scale() { 4096 } else { 512 };
    let bpp = 1.0;
    println!("# Fig 6 (top): MSE vs gamma at {bpp} bpp, W {size}x{size}");
    println!("ROW: gamma tinyrank_fp littlebit lb_rot littlebit2");

    let mut crossings: Vec<(String, Option<f64>)> = Vec::new();
    let mut last: Option<(f64, [f64; 4])> = None;
    let gammas: Vec<f64> = (1..=16).map(|i| 0.05 * i as f64).collect();
    for (gi, &gamma) in gammas.iter().enumerate() {
        let mut rng = Pcg64::seed(6000 + gi as u64);
        let spec = SynthSpec { rows: size, cols: size, gamma, coherence: 0.7, scale: 1.0 };
        let w = synth_weight(&spec, &mut rng);

        let r_fp = tiny_rank_for_budget(size, size, bpp);
        let fp = tiny_rank_fp16(&w, r_fp, &mut rng).reconstruction.mse(&w);
        let binary = |strategy| {
            let mut rng = Pcg64::seed(8800 + gi as u64);
            let cfg = CompressionConfig { bpp, strategy, residual: true, ..Default::default() };
            compress(&w, &cfg, &mut rng).reconstruct().mse(&w)
        };
        let lb = binary(InitStrategy::Standard);
        let rot = binary(InitStrategy::RandomRotation);
        let itq = binary(InitStrategy::JointItq { iters: 50 });
        println!("ROW: {gamma:.2} {fp:.6e} {lb:.6e} {rot:.6e} {itq:.6e}");

        // Detect the FP-vs-method crossovers (the γ* of each curve).
        let cur = [fp, lb, rot, itq];
        if let Some((g_prev, prev)) = last {
            for (idx, name) in [(1usize, "littlebit"), (2, "lb+rot"), (3, "littlebit2")] {
                let was_better = prev[idx] < prev[0];
                let is_better = cur[idx] < cur[0];
                if was_better && !is_better && !crossings.iter().any(|(n, _)| n == name) {
                    // Linear interpolation of the crossing point.
                    let f = |v: [f64; 4]| v[idx] - v[0];
                    let t = f(prev) / (f(prev) - f(cur));
                    crossings.push((name.to_string(), Some(g_prev + t * (gamma - g_prev))));
                }
            }
        }
        last = Some((gamma, cur));
    }
    for (name, g) in crossings {
        match g {
            Some(g) => println!("CROSSOVER: {name} gamma* ≈ {g:.3}"),
            None => println!("CROSSOVER: {name} none in range"),
        }
    }
    println!("# paper: littlebit ≈0.36, +rotation ≈0.41, littlebit2 ≈0.51");
}
