//! Zero-copy load latency: eager `.lb2` read vs mmap-backed serving.
//!
//! The tentpole claim of the v3 aligned format is that serving startup
//! stops paying a weight copy: `load_mmap` on an aligned artifact maps the
//! file and borrows every bit-plane and scale vector straight from the
//! page cache, so load time is O(sections) instead of O(bytes) and the
//! process's own heap stays near-empty. This bench measures, per load
//! mode:
//!
//! * `cold_ms` — the first load in the process. The artifact was just
//!   written, so the page cache is warm; a true cold-cache number needs
//!   `echo 3 > /proc/sys/vm/drop_caches` between runs, which a bench
//!   binary must not do itself.
//! * `warm_ms` (mean ± sd) — repeated loads, page cache hot.
//! * `rss_delta_kb` — RSS growth across the load, **before** any forward
//!   touches the mapping (mapped pages only enter RSS when faulted in).
//! * `ttfr_ms` — time-to-first-response: load + one single-request
//!   forward, the "process start to first token" proxy.
//! * `resident_bytes` / `mapped_bytes` — the stack's own accounting,
//!   disjoint by construction.
//!
//! Modes: `eager_v2` (the pre-mmap baseline), `mmap_v3` (the zero-copy
//! path), `mmap_v2_fallback` (the mmap entry point on a v2 file, which
//! must copy-and-restride — same bits, no borrowing). Results land in
//! `BENCH_load.json` at the repository root.

#[path = "common/mod.rs"]
mod common;

use common::time_ms;
use littlebit2::linalg::Mat;
use littlebit2::littlebit::{CompressionConfig, InitStrategy};
use littlebit2::model::{MethodStack, PackedStack};
use littlebit2::rng::Pcg64;
use littlebit2::spectral::{synth_weight, SynthSpec};

struct Row {
    mode: &'static str,
    cold_ms: f64,
    warm_ms: f64,
    warm_sd: f64,
    rss_delta_kb: i64,
    ttfr_ms: f64,
    resident_bytes: usize,
    mapped_bytes: usize,
}

/// Current RSS in KiB from /proc/self/status (0 where unavailable).
fn rss_kb() -> i64 {
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else {
        return 0;
    };
    status
        .lines()
        .find_map(|l| l.strip_prefix("VmRSS:"))
        .and_then(|l| l.trim().trim_end_matches("kB").trim().parse().ok())
        .unwrap_or(0)
}

fn measure(
    mode: &'static str,
    load: impl Fn() -> MethodStack,
    d_in: usize,
    reps: usize,
) -> Row {
    // Cold-ish: first load in this mode (page cache warm from the write).
    let t0 = std::time::Instant::now();
    let first = load();
    let cold_ms = t0.elapsed().as_secs_f64() * 1e3;
    drop(first);

    // RSS delta across a load, holding the result, before any forward.
    let rss_before = rss_kb();
    let held = load();
    let rss_delta_kb = rss_kb() - rss_before;
    let resident_bytes = held.resident_bytes();
    let mapped_bytes = held.mapped_bytes();
    drop(held);

    let (warm_ms, warm_sd) = time_ms(reps, || {
        std::hint::black_box(load());
    });

    // Time-to-first-response: load + one single-request forward.
    let mut rng = Pcg64::seed(77);
    let mut x = vec![0.0f32; d_in];
    rng.fill_normal(&mut x);
    let t0 = std::time::Instant::now();
    let stack = load();
    std::hint::black_box(stack.forward(&x));
    let ttfr_ms = t0.elapsed().as_secs_f64() * 1e3;

    println!(
        "ROW: {mode} {cold_ms:.3} {warm_ms:.3} {warm_sd:.3} {rss_delta_kb} {ttfr_ms:.3} {resident_bytes} {mapped_bytes}"
    );
    Row { mode, cold_ms, warm_ms, warm_sd, rss_delta_kb, ttfr_ms, resident_bytes, mapped_bytes }
}

fn main() {
    let (size, depth) = if common::full_scale() { (1024, 8) } else { (384, 4) };
    let reps = if common::full_scale() { 5 } else { 10 };
    println!("# zero-copy load latency: {depth} layers of {size}x{size}, reps={reps}");

    let mut rng = Pcg64::seed(70);
    let dims = vec![size; depth + 1];
    let weights: Vec<Mat> = dims
        .windows(2)
        .map(|w| {
            let spec =
                SynthSpec { rows: w[1], cols: w[0], gamma: 0.3, coherence: 0.6, scale: 1.0 };
            synth_weight(&spec, &mut rng)
        })
        .collect();
    // Load latency is independent of compression quality — use the cheap
    // init so the bench spends its time on the thing it measures.
    let cfg = CompressionConfig {
        bpp: 1.0,
        strategy: InitStrategy::Standard,
        residual: true,
        ..Default::default()
    };
    let stack = MethodStack::from(PackedStack::compress_chain(&weights, &cfg, &mut rng));

    let dir = std::env::temp_dir();
    let p2 = dir.join(format!("lb2_bench_load_v2_{}.lb2", std::process::id()));
    let p3 = dir.join(format!("lb2_bench_load_v3_{}.lb2", std::process::id()));
    stack.save(&p2).expect("save v2");
    stack.save_aligned(&p3).expect("save v3 aligned");
    let v2_bytes = std::fs::metadata(&p2).map(|m| m.len()).unwrap_or(0);
    let v3_bytes = std::fs::metadata(&p3).map(|m| m.len()).unwrap_or(0);
    println!("# artifact bytes: v2 {v2_bytes}, v3 aligned {v3_bytes} (alignment padding {:+})",
        v3_bytes as i64 - v2_bytes as i64);
    println!("ROW: mode cold_ms warm_ms warm_sd rss_delta_kb ttfr_ms resident_bytes mapped_bytes");

    let d_in = stack.d_in();
    let rows = [
        measure("eager_v2", || MethodStack::load(&p2).expect("eager v2"), d_in, reps),
        measure("mmap_v3", || MethodStack::load_mmap(&p3).expect("mmap v3"), d_in, reps),
        measure(
            "mmap_v2_fallback",
            || MethodStack::load_mmap(&p2).expect("mmap v2 fallback"),
            d_in,
            reps,
        ),
    ];
    let _ = std::fs::remove_file(&p2);
    let _ = std::fs::remove_file(&p3);

    let json_path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_load.json");
    match std::fs::write(json_path, render_json(size, depth, v2_bytes, v3_bytes, &rows)) {
        Ok(()) => println!("# wrote {json_path}"),
        Err(e) => eprintln!("# could not write {json_path}: {e}"),
    }
}

fn render_json(size: usize, depth: usize, v2_bytes: u64, v3_bytes: u64, rows: &[Row]) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"bench\": \"load_latency\",\n");
    s.push_str("  \"status\": \"ok\",\n");
    s.push_str(&format!(
        "  \"generated_by\": \"littlebit2 {} benches/load_latency.rs\",\n",
        littlebit2::VERSION
    ));
    s.push_str(&format!(
        "  \"config\": {{\"size\": {size}, \"depth\": {depth}, \"v2_artifact_bytes\": {v2_bytes}, \"v3_artifact_bytes\": {v3_bytes}}},\n"
    ));
    s.push_str("  \"note\": \"cold_ms is the first in-process load; the page cache is warm from writing the artifact. Drop caches externally for true cold numbers.\",\n");
    s.push_str("  \"modes\": [\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"mode\": \"{}\", \"cold_ms\": {:.4}, \"warm_ms\": {:.4}, \"warm_sd_ms\": {:.4}, \"rss_delta_kb\": {}, \"ttfr_ms\": {:.4}, \"resident_bytes\": {}, \"mapped_bytes\": {}}}{}\n",
            r.mode,
            r.cold_ms,
            r.warm_ms,
            r.warm_sd,
            r.rss_delta_kb,
            r.ttfr_ms,
            r.resident_bytes,
            r.mapped_bytes,
            if i + 1 < rows.len() { "," } else { "" },
        ));
    }
    s.push_str("  ]\n}\n");
    s
}
