//! The tri-scale compressed layer (Eq. 1) and its residual composition
//! (App. G), plus packed deployment via `packing::TriScaleLayer`.

use crate::linalg::{f16_round, Mat};
use crate::packing::{PackedResidual, TriScaleLayer};
use crate::parallel::Pool;
use crate::quant::row_distortions;

/// Raw Dual-SVID output for one path:
/// `Ŵ = diag(h) · U_b · diag(l) · V_bᵀ · diag(g)`.
#[derive(Clone, Debug)]
pub struct TriScaleFactors {
    /// Binary factor `U_b ∈ {±1}^{d_out×r}` (stored dense here; packed on
    /// deployment).
    pub u_b: Mat,
    /// Binary factor `V_b ∈ {±1}^{d_in×r}`.
    pub v_b: Mat,
    /// Row scale `h ∈ R^{d_out}`.
    pub h: Vec<f32>,
    /// Central latent scale `l ∈ R^r`.
    pub l: Vec<f32>,
    /// Column scale `g ∈ R^{d_in}`.
    pub g: Vec<f32>,
    /// Full-precision latent factors retained for QAT (the STE latents of
    /// App. C; not counted in deployment storage).
    pub latent_u: Mat,
    pub latent_v: Mat,
}

impl TriScaleFactors {
    /// Dense reconstruction of Eq. 1.
    pub fn reconstruct(&self) -> Mat {
        self.reconstruct_on(Pool::serial())
    }

    /// [`reconstruct`](Self::reconstruct) with the `d_out×d_in` product
    /// row-partitioned across `pool` — bit-identical for any thread count.
    /// The compression pipeline uses this for the residual-error matrix
    /// between paths.
    pub fn reconstruct_on(&self, pool: &Pool) -> Mat {
        self.u_b
            .scale_rows(&self.h)
            .scale_cols(&self.l)
            .matmul_t_on(&self.v_b.scale_rows(&self.g), pool)
    }

    pub fn rank(&self) -> usize {
        self.l.len()
    }

    pub fn d_out(&self) -> usize {
        self.u_b.rows()
    }

    pub fn d_in(&self) -> usize {
        self.v_b.rows()
    }
}

/// One deployed path: FP16-rounded scales + binary factors.
#[derive(Clone, Debug)]
pub struct CompressedLinear {
    pub factors: TriScaleFactors,
}

impl CompressedLinear {
    /// Finalize factors for deployment: scales rounded to FP16 precision
    /// (their storage format per App. H).
    pub fn from_factors(mut factors: TriScaleFactors) -> Self {
        for v in factors
            .h
            .iter_mut()
            .chain(factors.l.iter_mut())
            .chain(factors.g.iter_mut())
        {
            *v = f16_round(*v);
        }
        Self { factors }
    }

    pub fn reconstruct(&self) -> Mat {
        self.factors.reconstruct()
    }

    /// Pool-parallel [`reconstruct`](Self::reconstruct) (bit-identical).
    pub fn reconstruct_on(&self, pool: &Pool) -> Mat {
        self.factors.reconstruct_on(pool)
    }

    /// λ of every latent row of Ũ — the Fig. 3 diagnostic.
    pub fn u_distortions(&self) -> Vec<f64> {
        row_distortions(&self.factors.latent_u)
    }

    /// Storage bits for this single path: binary factors + 16-bit scales
    /// (`r(d_in+d_out) + 16(d_in+d_out) + 16r` —
    /// [`crate::memory::littlebit_path_bits`], the shared accounting also
    /// charged by the packed serving view's `declared_bits`).
    pub fn storage_bits(&self) -> u64 {
        crate::memory::littlebit_path_bits(
            self.factors.d_in(),
            self.factors.d_out(),
            self.factors.rank(),
        )
    }

    /// Pack into the bit-level inference layer. The packed layer executes
    /// Eq. 1 through the scale-fused sign kernels: `g` and `l` fold into
    /// the two sign-XOR loops, `h` into the final lane reduction — no
    /// separate element-wise passes at serve time, bit-identical numbers.
    pub fn pack(&self) -> TriScaleLayer {
        TriScaleLayer::new(
            &self.factors.u_b,
            &self.factors.v_b,
            self.factors.h.clone(),
            self.factors.l.clone(),
            self.factors.g.clone(),
        )
    }
}

/// Residual composition `Ŵ = Σ_p Ŵ_p` (App. G; the paper uses 2 paths).
#[derive(Clone, Debug)]
pub struct ResidualCompressed {
    pub paths: Vec<CompressedLinear>,
}

impl ResidualCompressed {
    pub fn new(paths: Vec<CompressedLinear>) -> Self {
        assert!(!paths.is_empty());
        Self { paths }
    }

    pub fn reconstruct(&self) -> Mat {
        self.reconstruct_on(Pool::serial())
    }

    /// Pool-parallel [`reconstruct`](Self::reconstruct) (bit-identical) —
    /// what the job scheduler uses to score per-layer MSE.
    pub fn reconstruct_on(&self, pool: &Pool) -> Mat {
        let mut acc = self.paths[0].reconstruct_on(pool);
        for p in &self.paths[1..] {
            acc = acc.add(&p.reconstruct_on(pool));
        }
        acc
    }

    pub fn storage_bits(&self) -> u64 {
        self.paths.iter().map(|p| p.storage_bits()).sum()
    }

    /// Effective bits-per-parameter of the deployed layer.
    pub fn bpp(&self) -> f64 {
        let f = &self.paths[0].factors;
        self.storage_bits() as f64 / (f.d_out() * f.d_in()) as f64
    }

    /// Pack every path into the bit-level inference composition — the
    /// deployment step. Serving code calls this once at load time and then
    /// drives the returned [`PackedResidual`] directly.
    pub fn pack(&self) -> PackedResidual {
        PackedResidual::new(self.paths.iter().map(|p| p.pack()).collect())
    }

    /// Pack into the artifact-ready deployment form: a single-layer
    /// [`crate::model::PackedStack`], which is what the `.lb2` format
    /// persists — `compress(..).pack_stack().save("model.lb2")` is the
    /// whole quantize-once pipeline for one layer.
    pub fn pack_stack(&self) -> crate::model::PackedStack {
        crate::model::PackedStack::new(vec![self.pack()])
    }

    /// Forward pass through all packed paths (sum of path outputs).
    /// Packs on every call — convenience for tests/oracles; hot paths use
    /// [`pack`](Self::pack) once and reuse the result.
    pub fn forward_packed(&self, x: &[f32]) -> Vec<f32> {
        self.pack().forward(x)
    }

    /// Batched forward through all packed paths: `X` is `d_in × b`
    /// feature-major (column `t` is batch item `t`). Packs on every call —
    /// hot paths use [`pack`](Self::pack) once and call
    /// `PackedResidual::forward_batch` on the result.
    pub fn forward_packed_batch(&self, x: &Mat) -> Mat {
        self.pack().forward_batch(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::littlebit::dual_svid;
    use crate::rng::Pcg64;

    fn sample_factors(seed: u64) -> TriScaleFactors {
        let mut rng = Pcg64::seed(seed);
        let u = Mat::gaussian(48, 8, &mut rng);
        let v = Mat::gaussian(40, 8, &mut rng);
        dual_svid(&u, &v)
    }

    #[test]
    fn reconstruction_shape() {
        let f = sample_factors(1);
        assert_eq!(f.reconstruct().shape(), (48, 40));
    }

    #[test]
    fn packed_forward_matches_dense_reconstruction() {
        let f = sample_factors(2);
        let c = CompressedLinear::from_factors(f);
        let w = c.reconstruct();
        let mut rng = Pcg64::seed(3);
        let mut x = vec![0.0f32; 40];
        rng.fill_normal(&mut x);
        let want = w.matvec(&x);
        let got = c.pack().forward(&x);
        for (a, b) in want.iter().zip(&got) {
            assert!((a - b).abs() < 2e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn residual_forward_matches_residual_reconstruction() {
        let a = CompressedLinear::from_factors(sample_factors(4));
        let b = CompressedLinear::from_factors(sample_factors(5));
        let rc = ResidualCompressed::new(vec![a, b]);
        let w = rc.reconstruct();
        let mut rng = Pcg64::seed(6);
        let mut x = vec![0.0f32; 40];
        rng.fill_normal(&mut x);
        let want = w.matvec(&x);
        let got = rc.forward_packed(&x);
        for (p, q) in want.iter().zip(&got) {
            assert!((p - q).abs() < 4e-3, "{p} vs {q}");
        }
    }

    #[test]
    fn residual_batched_forward_matches_per_item() {
        let a = CompressedLinear::from_factors(sample_factors(9));
        let b = CompressedLinear::from_factors(sample_factors(10));
        let rc = ResidualCompressed::new(vec![a, b]);
        let mut rng = Pcg64::seed(11);
        let batch = 5;
        let mut x = Mat::zeros(40, batch);
        x.fill_normal(&mut rng);
        let batched = rc.forward_packed_batch(&x);
        assert_eq!(batched.shape(), (48, batch));
        for t in 0..batch {
            let want = rc.forward_packed(&x.col(t));
            for i in 0..48 {
                assert_eq!(batched.at(i, t).to_bits(), want[i].to_bits(), "({i},{t})");
            }
        }
    }

    #[test]
    fn storage_matches_memory_formula() {
        // Two equal-rank paths must equal Eq. 25 exactly.
        let a = CompressedLinear::from_factors(sample_factors(7));
        let b = CompressedLinear::from_factors(sample_factors(7));
        let rc = ResidualCompressed::new(vec![a, b]);
        let bits = rc.storage_bits();
        let expect = crate::memory::littlebit_bits(40, 48, 8);
        assert_eq!(bits, expect);
    }

    #[test]
    fn fp16_rounding_applied_to_scales() {
        let c = CompressedLinear::from_factors(sample_factors(8));
        for &s in c.factors.h.iter().chain(&c.factors.l).chain(&c.factors.g) {
            assert_eq!(s, f16_round(s), "scale not f16-representable: {s}");
        }
    }
}
