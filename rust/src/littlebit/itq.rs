//! Internal Latent Rotation and the Joint-ITQ solver (§4.3–4.4, Alg. 1).
//!
//! Joint-ITQ aligns the **concatenated** latent manifold `Z = [Û; V̂]` with
//! the binary hypercube by alternating:
//!
//! 1. code update — `B = sign(Z R)` (project to nearest vertices);
//! 2. rotation update — orthogonal Procrustes: SVD of `BᵀZ = ΦΩΨᵀ`,
//!    `R ← Ψ Φᵀ`.
//!
//! Each step is monotone in the shared objective `‖B − ZR‖²_F`, equivalently
//! monotone *increasing* in `‖ZR‖₁` (App. A.2), so convergence is guaranteed
//! to a local optimum; the report records the trajectory for the Fig. 13
//! sweep.

use crate::linalg::{random_orthogonal, svd_jacobi, Mat};
use crate::parallel::Pool;
use crate::rng::Pcg64;

/// Haar random orthogonal rotation (the §4.3 coarse alignment).
pub fn random_rotation(r: usize, rng: &mut Pcg64) -> Mat {
    random_orthogonal(r, rng)
}

/// Convergence trace of one Joint-ITQ run.
#[derive(Clone, Debug)]
pub struct ItqReport {
    /// Objective ‖B − ZR‖²_F after every iteration.
    pub objective: Vec<f64>,
    /// ‖ZR‖₁ after every iteration (monotone non-decreasing).
    pub l1_mass: Vec<f64>,
    /// Iterations actually run.
    pub iters: usize,
}

/// Solve the joint orthogonal Procrustes problem of Eq. 10.
///
/// `u_hat` is `d_out×r`, `v_hat` is `d_in×r`; returns the optimal rotation
/// `R` (`r×r`) and the convergence report. Callers apply `R` to both factors
/// (`Ũ = ÛR`, `Ṽ = V̂R`), which preserves `ÛV̂ᵀ` exactly (Eq. 7).
///
/// Runs on the process-wide [`Pool::global`] — the two `Z`-sized products
/// per iteration dominate at `d ≈ 4096`, and row-partitioning keeps the
/// trajectory bit-identical for any thread count. Use [`joint_itq_on`] to
/// pin an explicit pool.
pub fn joint_itq(u_hat: &Mat, v_hat: &Mat, iters: usize, rng: &mut Pcg64) -> (Mat, ItqReport) {
    joint_itq_on(u_hat, v_hat, iters, rng, Pool::global())
}

/// [`joint_itq`] on an explicit [`Pool`]. Bit-identical results for any
/// pool; only wall-clock changes.
pub fn joint_itq_on(
    u_hat: &Mat,
    v_hat: &Mat,
    iters: usize,
    rng: &mut Pcg64,
    pool: &Pool,
) -> (Mat, ItqReport) {
    assert_eq!(u_hat.cols(), v_hat.cols(), "latent ranks must match");
    let r = u_hat.cols();
    let z = u_hat.vcat(v_hat); // (d_out + d_in) × r
    let mut rot = random_orthogonal(r, rng);

    let mut report = ItqReport { objective: Vec::new(), l1_mass: Vec::new(), iters: 0 };

    for _t in 0..iters {
        let zr = z.matmul_on(&rot, pool);
        // Step A: project to binary vertices.
        let b = zr.signum();
        // Step B: Procrustes — SVD(BᵀZ) = Φ Ω Ψᵀ, R = Ψ Φᵀ.
        let m = b.t_matmul_on(&z, pool); // r×r
        let svd = svd_jacobi(&m);
        // svd: m = u s vᵀ, with Φ = svd.u, Ψ = svd.v.
        rot = svd.v.matmul_t_on(&svd.u, pool);

        let zr2 = z.matmul_on(&rot, pool);
        report.objective.push(zr2.signum().fro_dist2(&zr2));
        report.l1_mass.push(zr2.l1_norm());
        report.iters += 1;
    }

    (rot, report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::orthogonality_defect;
    use crate::quant::row_distortions;
    use crate::spectral::{synth_weight, SynthSpec};

    fn factors(seed: u64, coherence: f64, r: usize) -> (Mat, Mat) {
        let mut rng = Pcg64::seed(seed);
        let spec = SynthSpec { rows: 96, cols: 80, gamma: 0.3, coherence, scale: 1.0 };
        let w = synth_weight(&spec, &mut rng);
        let svd = crate::linalg::svd_randomized(&w, r, 8, 2, &mut rng);
        svd.split_factors()
    }

    #[test]
    fn rotation_stays_orthogonal() {
        let (u, v) = factors(1, 0.7, 16);
        let mut rng = Pcg64::seed(2);
        let (r, _) = joint_itq(&u, &v, 30, &mut rng);
        assert!(orthogonality_defect(&r) < 1e-3);
    }

    #[test]
    fn l1_mass_monotone_nondecreasing() {
        let (u, v) = factors(3, 0.7, 16);
        let mut rng = Pcg64::seed(4);
        let (_, report) = joint_itq(&u, &v, 40, &mut rng);
        for w in report.l1_mass.windows(2) {
            assert!(w[1] >= w[0] - 1e-4 * w[0].abs(), "L1 decreased: {w:?}");
        }
    }

    #[test]
    fn objective_monotone_nonincreasing() {
        let (u, v) = factors(5, 0.8, 16);
        let mut rng = Pcg64::seed(6);
        let (_, report) = joint_itq(&u, &v, 40, &mut rng);
        for w in report.objective.windows(2) {
            assert!(w[1] <= w[0] + 1e-6 * w[0].abs(), "objective rose: {w:?}");
        }
    }

    #[test]
    fn itq_beats_random_rotation_on_distortion() {
        let (u, v) = factors(7, 0.85, 24);
        let mut rng = Pcg64::seed(8);
        let rot = random_rotation(24, &mut rng);
        let (itq_rot, _) = joint_itq(&u, &v, 50, &mut rng);
        let mean = |m: &Mat| {
            let d = row_distortions(m);
            d.iter().sum::<f64>() / d.len() as f64
        };
        let z = u.vcat(&v);
        assert!(mean(&z.matmul(&itq_rot)) < mean(&z.matmul(&rot)));
    }

    #[test]
    fn converges_within_50_iters() {
        // Paper (App. F.1): MSE saturates near T=50. Check the objective
        // plateau: last-10-iteration improvement below 1% of total drop.
        let (u, v) = factors(9, 0.8, 32);
        let mut rng = Pcg64::seed(10);
        let (_, report) = joint_itq(&u, &v, 60, &mut rng);
        let total_drop = report.objective[0] - *report.objective.last().unwrap();
        let late_drop = report.objective[49] - report.objective[59];
        assert!(
            late_drop <= 0.02 * total_drop + 1e-12,
            "late={late_drop} total={total_drop}"
        );
    }

    #[test]
    fn perfect_alignment_reaches_zero_distortion() {
        // If Z's rows are already hypercube vertices (times a scale), some
        // rotation achieves λ = 0; ITQ should find (close to) it.
        let mut rng = Pcg64::seed(11);
        let r = 8;
        let signs = Mat::gaussian(40, r, &mut rng).signum();
        let q = random_orthogonal(r, &mut rng);
        let u = signs.matmul(&q).scale(0.5); // rotated vertices
        let v = Mat::gaussian(30, r, &mut rng).signum().matmul(&q).scale(0.5);
        let (rot, _) = joint_itq(&u, &v, 80, &mut rng);
        let aligned = u.matmul(&rot);
        let lam = row_distortions(&aligned);
        let mean: f64 = lam.iter().sum::<f64>() / lam.len() as f64;
        // ITQ is a local-optimum method: it should land far below the
        // Gaussian limit (0.36), near but not exactly at zero.
        assert!(mean < 0.15, "mean λ={mean}");
    }

    #[test]
    fn zero_iterations_returns_initial_random_rotation() {
        let (u, v) = factors(13, 0.5, 8);
        let mut rng = Pcg64::seed(14);
        let (r, report) = joint_itq(&u, &v, 0, &mut rng);
        assert_eq!(report.iters, 0);
        assert!(orthogonality_defect(&r) < 1e-3);
    }
}
