//! The staged compression pipeline: SVD → rotation (Joint-ITQ) →
//! Dual-SVID → pack, instrumented per stage and threaded over a
//! [`Pool`].
//!
//! `littlebit::compress` used to be an opaque call; quantizing a
//! Llama-scale stack spends minutes inside it, so the coordinator needs
//! to know *where* (is ITQ the bottleneck, or the truncated SVD?) and the
//! scheduler needs the packed deployment form without re-walking the
//! factors. [`compress_pipeline`] returns all three: the FP-diagnostics
//! view ([`ResidualCompressed`]), the serving/artifact view
//! ([`PackedResidual`]), and the per-stage wall-clock
//! ([`CompressionReport`]). Stage times are *accumulated across residual
//! paths* (the App. G architecture runs every stage twice), so the report
//! answers "where did this layer's seconds go" directly.
//!
//! Determinism: the pipeline consumes the caller's RNG exactly like the
//! original `compress` (same draws, same order) and every pooled kernel is
//! bit-exact against its serial form, so results are bit-identical across
//! pool sizes — only the report's timings change.

use super::layer::{CompressedLinear, ResidualCompressed};
use super::{dual_svid_on, joint_itq_on, random_rotation, CompressionConfig, InitStrategy};
use crate::linalg::{svd_randomized_on, Mat};
use crate::memory;
use crate::packing::PackedResidual;
use crate::parallel::Pool;
use crate::rng::Pcg64;
use std::time::Instant;

/// Per-stage wall-clock of one layer's compression, in milliseconds.
/// `svd/itq/svid` accumulate across residual paths; `pack` is the final
/// bit-plane packing; `total` covers the whole pipeline (including the
/// residual-error reconstruction between paths, which is why it exceeds
/// the stage sum).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CompressionReport {
    /// Truncated randomized SVD (range finding + power iterations + Jacobi).
    pub svd_ms: f64,
    /// Rotation stage: Joint-ITQ iterations (or the random rotation; ~0 for
    /// the Standard strategy).
    pub itq_ms: f64,
    /// Dual-SVID scale extraction (two rank-1 magnitude decompositions).
    pub svid_ms: f64,
    /// Bit-plane packing into the deployment layout.
    pub pack_ms: f64,
    /// End-to-end wall-clock for the layer.
    pub total_ms: f64,
}

impl CompressionReport {
    /// Field-wise accumulation — used to aggregate a whole model's stage
    /// profile across layers.
    pub fn accumulate(&mut self, other: &CompressionReport) {
        self.svd_ms += other.svd_ms;
        self.itq_ms += other.itq_ms;
        self.svid_ms += other.svid_ms;
        self.pack_ms += other.pack_ms;
        self.total_ms += other.total_ms;
    }

    /// Sum of the four named stages (`total_ms` minus residual
    /// reconstruction and bookkeeping).
    pub fn stage_ms(&self) -> f64 {
        self.svd_ms + self.itq_ms + self.svid_ms + self.pack_ms
    }
}

/// Everything one layer's compression produces: the full-precision
/// diagnostics view, the packed deployment view (what `.lb2` artifacts
/// persist), and the stage profile.
pub struct CompressedLayer {
    pub compressed: ResidualCompressed,
    pub packed: PackedResidual,
    pub report: CompressionReport,
}

/// Compress one weight matrix through the full staged pipeline on `pool`.
///
/// Equivalent to `compress(w, cfg, rng)` followed by `.pack()`, with the
/// per-stage wall-clock recorded — and bit-identical to it, for any pool.
///
/// # Examples
///
/// ```
/// use littlebit2::littlebit::{compress_pipeline, CompressionConfig};
/// use littlebit2::parallel::Pool;
/// use littlebit2::rng::Pcg64;
/// use littlebit2::spectral::{synth_weight, SynthSpec};
///
/// let mut rng = Pcg64::seed(0);
/// let spec = SynthSpec { rows: 64, cols: 64, ..Default::default() };
/// let w = synth_weight(&spec, &mut rng);
/// let cfg = CompressionConfig { bpp: 1.0, ..Default::default() };
/// let layer = compress_pipeline(&w, &cfg, &mut Pcg64::seed(7), Pool::serial());
/// assert_eq!(layer.packed.d_in(), 64);
/// assert!(layer.report.total_ms >= layer.report.stage_ms() - 1e-6);
/// ```
pub fn compress_pipeline(
    w: &Mat,
    cfg: &CompressionConfig,
    rng: &mut Pcg64,
    pool: &Pool,
) -> CompressedLayer {
    let t0 = Instant::now();
    let mut report = CompressionReport::default();
    let compressed = compress_residual(w, cfg, rng, pool, &mut report);
    let tp = Instant::now();
    let packed = compressed.pack();
    report.pack_ms = ms_since(tp);
    report.total_ms = ms_since(t0);
    CompressedLayer { compressed, packed, report }
}

/// The residual-composition driver (App. G): path 1 compresses `w`, path 2
/// compresses path 1's reconstruction error. Stage times accumulate into
/// `report`.
pub(super) fn compress_residual(
    w: &Mat,
    cfg: &CompressionConfig,
    rng: &mut Pcg64,
    pool: &Pool,
    report: &mut CompressionReport,
) -> ResidualCompressed {
    let (d_out, d_in) = w.shape();
    if cfg.residual {
        let r = memory::littlebit_rank_for_budget(d_in, d_out, cfg.bpp);
        let primary = compress_single_staged(w, r, cfg, rng, pool, report);
        let err = w.sub(&primary.reconstruct_on(pool));
        let residual = compress_single_staged(&err, r, cfg, rng, pool, report);
        ResidualCompressed::new(vec![primary, residual])
    } else {
        let r = memory::littlebit_single_rank_for_budget(d_in, d_out, cfg.bpp);
        ResidualCompressed::new(vec![compress_single_staged(w, r, cfg, rng, pool, report)])
    }
}

/// One path through the stage graph:
/// SVD → (strategy rotation) → Dual-SVID → tri-scale layer.
pub(super) fn compress_single_staged(
    w: &Mat,
    rank: usize,
    cfg: &CompressionConfig,
    rng: &mut Pcg64,
    pool: &Pool,
    report: &mut CompressionReport,
) -> CompressedLinear {
    let rank = rank.max(1).min(w.rows().min(w.cols()));
    let t = Instant::now();
    let svd = svd_randomized_on(w, rank, cfg.oversample.min(rank + 8), cfg.power_iters, rng, pool);
    let (u_hat, v_hat) = svd.split_factors();
    report.svd_ms += ms_since(t);

    let t = Instant::now();
    let (u_rot, v_rot) = match cfg.strategy {
        InitStrategy::Standard => (u_hat, v_hat),
        InitStrategy::RandomRotation => {
            let r = random_rotation(rank, rng);
            (u_hat.matmul_on(&r, pool), v_hat.matmul_on(&r, pool))
        }
        InitStrategy::JointItq { iters } => {
            let (r, _report) = joint_itq_on(&u_hat, &v_hat, iters, rng, pool);
            (u_hat.matmul_on(&r, pool), v_hat.matmul_on(&r, pool))
        }
    };
    report.itq_ms += ms_since(t);

    let t = Instant::now();
    let factors = dual_svid_on(&u_rot, &v_rot, pool);
    report.svid_ms += ms_since(t);
    CompressedLinear::from_factors(factors)
}

#[inline]
fn ms_since(t: Instant) -> f64 {
    t.elapsed().as_secs_f64() * 1e3
}

#[cfg(test)]
mod tests {
    use super::super::compress;
    use super::*;
    use crate::spectral::{synth_weight, SynthSpec};

    fn weight(seed: u64) -> Mat {
        let mut rng = Pcg64::seed(seed);
        let spec = SynthSpec { rows: 96, cols: 96, gamma: 0.3, coherence: 0.7, scale: 1.0 };
        synth_weight(&spec, &mut rng)
    }

    /// The staged pipeline must be bit-identical to plain `compress` +
    /// `.pack()` — same RNG draws, same kernels — on any pool.
    #[test]
    fn pipeline_matches_compress_bit_exactly() {
        let w = weight(31);
        let cfg = CompressionConfig { bpp: 1.0, ..Default::default() };
        let plain = compress(&w, &cfg, &mut Pcg64::seed(5));
        for pool in [Pool::serial(), Pool::global()] {
            let staged = compress_pipeline(&w, &cfg, &mut Pcg64::seed(5), pool);
            assert_eq!(plain.reconstruct(), staged.compressed.reconstruct());
            // Packed view serves identical numbers.
            let mut rng = Pcg64::seed(9);
            let mut x = vec![0.0f32; w.cols()];
            rng.fill_normal(&mut x);
            let a = plain.pack().forward(&x);
            let b = staged.packed.forward(&x);
            for (p, q) in a.iter().zip(&b) {
                assert_eq!(p.to_bits(), q.to_bits());
            }
        }
    }

    /// Stage accounting: all stages ran (residual ⇒ twice), times are
    /// finite and total covers the stage sum.
    #[test]
    fn report_accounts_for_all_stages() {
        let w = weight(32);
        let cfg = CompressionConfig { bpp: 0.8, ..Default::default() };
        let layer = compress_pipeline(&w, &cfg, &mut Pcg64::seed(6), Pool::serial());
        let r = &layer.report;
        for v in [r.svd_ms, r.itq_ms, r.svid_ms, r.pack_ms, r.total_ms] {
            assert!(v.is_finite() && v >= 0.0, "{r:?}");
        }
        assert!(r.svd_ms > 0.0, "{r:?}");
        assert!(r.total_ms + 1e-9 >= r.stage_ms(), "{r:?}");
        // Accumulation is field-wise.
        let mut acc = CompressionReport::default();
        acc.accumulate(r);
        acc.accumulate(r);
        assert!((acc.svd_ms - 2.0 * r.svd_ms).abs() < 1e-12);
        assert!((acc.total_ms - 2.0 * r.total_ms).abs() < 1e-12);
    }

    /// The Standard strategy has no rotation stage: its itq_ms must be
    /// (near) zero while ITQ's is not.
    #[test]
    fn itq_stage_reflects_strategy() {
        let w = weight(33);
        let std_cfg = CompressionConfig {
            bpp: 1.0,
            strategy: InitStrategy::Standard,
            ..Default::default()
        };
        let itq_cfg = CompressionConfig {
            bpp: 1.0,
            strategy: InitStrategy::JointItq { iters: 30 },
            ..Default::default()
        };
        let std_l = compress_pipeline(&w, &std_cfg, &mut Pcg64::seed(7), Pool::serial());
        let itq_l = compress_pipeline(&w, &itq_cfg, &mut Pcg64::seed(7), Pool::serial());
        assert!(
            itq_l.report.itq_ms > std_l.report.itq_ms,
            "itq {:?} vs std {:?}",
            itq_l.report,
            std_l.report
        );
    }
}
