//! The LittleBit / LittleBit-2 core: tri-scale latent factorization with
//! geometric initialization.
//!
//! Pipeline (Fig. 2 / Algorithm 1):
//!
//! ```text
//! W ──truncated SVD──▶ (Û, V̂) ──[rotation: none | random | Joint-ITQ]──▶
//! (Ũ, Ṽ) ──Dual-SVID──▶ scales (h, l, g) + binary factors (U_b, V_b)
//! ```
//!
//! * [`itq`] — Internal Latent Rotation + the Joint-ITQ solver (Alg. 1).
//! * [`svid`] — Dual-SVID scale extraction (Alg. 2 / App. C).
//! * [`layer`] — the tri-scale layer (Eq. 1), residual 2-path composition
//!   (App. G), reconstruction and λ diagnostics.
//! * [`pipeline`] — the staged driver: per-stage wall-clock
//!   ([`CompressionReport`]) and the packed deployment view in one call
//!   ([`compress_pipeline`]); this is what the L3 coordinator schedules.
//! * [`compress`] — one-call compression of a weight matrix at a bpp budget
//!   with any [`InitStrategy`] (the pipeline minus the instrumentation).
//!
//! Every stage runs its heavy linalg on a [`crate::parallel::Pool`]
//! (`compress` defaults to the process-wide pool; `compress_on` pins one)
//! and is bit-exact for any thread count, so compression results never
//! depend on parallelism.

mod itq;
mod layer;
mod pipeline;
mod svid;

pub use itq::{joint_itq, joint_itq_on, random_rotation, ItqReport};
pub use layer::{CompressedLinear, ResidualCompressed, TriScaleFactors};
pub use pipeline::{compress_pipeline, CompressedLayer, CompressionReport};
pub use svid::{dual_svid, dual_svid_on, rank_one_decompose, rank_one_decompose_on};

use crate::linalg::Mat;
use crate::parallel::Pool;
use crate::rng::Pcg64;

/// Initialization strategy — the paper's ablation axis (Table 3).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum InitStrategy {
    /// Standard LittleBit: Dual-SVID directly on the SVD factors.
    Standard,
    /// LittleBit + Internal Random Rotation (§4.3).
    RandomRotation,
    /// LittleBit-2: Joint-ITQ alignment (§4.4, Algorithm 1).
    JointItq { iters: usize },
}

impl InitStrategy {
    pub fn label(&self) -> &'static str {
        match self {
            InitStrategy::Standard => "littlebit",
            InitStrategy::RandomRotation => "littlebit+rot",
            InitStrategy::JointItq { .. } => "littlebit2",
        }
    }
}

/// Configuration for compressing one weight matrix.
#[derive(Clone, Debug)]
pub struct CompressionConfig {
    /// Bit budget in bits-per-parameter; rank follows from Eq. 26.
    pub bpp: f64,
    pub strategy: InitStrategy,
    /// Residual (2-path) architecture per App. G. When false a single path
    /// uses the whole budget.
    pub residual: bool,
    /// Randomized-SVD oversampling and power iterations.
    pub oversample: usize,
    pub power_iters: usize,
}

impl Default for CompressionConfig {
    fn default() -> Self {
        Self {
            bpp: 1.0,
            strategy: InitStrategy::JointItq { iters: 50 },
            residual: true,
            oversample: 10,
            power_iters: 2,
        }
    }
}

/// Compress `w` under `cfg`, returning the residual composition. The rank
/// per path follows App. H: the residual architecture stores two paths, so
/// each path gets the Eq. 26 rank at the given budget.
///
/// Runs the staged pipeline on the process-wide [`Pool::global`]; use
/// [`compress_on`] to pin a pool or [`compress_pipeline`] for the
/// per-stage wall-clock and the packed deployment view.
pub fn compress(w: &Mat, cfg: &CompressionConfig, rng: &mut Pcg64) -> ResidualCompressed {
    compress_on(w, cfg, rng, Pool::global())
}

/// [`compress`] on an explicit [`Pool`]. Bit-identical results for any
/// pool.
pub fn compress_on(
    w: &Mat,
    cfg: &CompressionConfig,
    rng: &mut Pcg64,
    pool: &Pool,
) -> ResidualCompressed {
    pipeline::compress_residual(w, cfg, rng, pool, &mut CompressionReport::default())
}

/// One path: SVD → (strategy rotation) → Dual-SVID → tri-scale layer.
/// Runs on [`Pool::global`]; [`compress_single_on`] pins a pool.
pub fn compress_single(
    w: &Mat,
    rank: usize,
    cfg: &CompressionConfig,
    rng: &mut Pcg64,
) -> CompressedLinear {
    compress_single_on(w, rank, cfg, rng, Pool::global())
}

/// [`compress_single`] on an explicit [`Pool`].
pub fn compress_single_on(
    w: &Mat,
    rank: usize,
    cfg: &CompressionConfig,
    rng: &mut Pcg64,
    pool: &Pool,
) -> CompressedLinear {
    pipeline::compress_single_staged(w, rank, cfg, rng, pool, &mut CompressionReport::default())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::svd_randomized;
    use crate::quant::local_distortion;
    use crate::spectral::{synth_weight, SynthSpec};

    fn spiky_weight(seed: u64) -> Mat {
        let mut rng = Pcg64::seed(seed);
        let spec = SynthSpec { rows: 128, cols: 128, gamma: 0.3, coherence: 0.8, scale: 1.0 };
        synth_weight(&spec, &mut rng)
    }

    /// The paper's headline ordering (Table 3, Fig. 14): at a fixed budget,
    /// ITQ < Rotation < Standard in reconstruction MSE on coherent weights.
    #[test]
    fn initialization_hierarchy_on_coherent_weights() {
        let w = spiky_weight(11);
        let mut mses = Vec::new();
        for strategy in [
            InitStrategy::Standard,
            InitStrategy::RandomRotation,
            InitStrategy::JointItq { iters: 50 },
        ] {
            let mut rng = Pcg64::seed(99);
            let cfg = CompressionConfig { bpp: 1.0, strategy, residual: true, ..Default::default() };
            let c = compress(&w, &cfg, &mut rng);
            mses.push((strategy.label(), c.reconstruct().mse(&w)));
        }
        assert!(
            mses[2].1 < mses[1].1 && mses[1].1 < mses[0].1,
            "hierarchy violated: {mses:?}"
        );
    }

    /// Rotation invariance (Eq. 7): rotating the latent factors must leave
    /// the FP reconstruction ÛV̂ᵀ unchanged.
    #[test]
    fn rotation_preserves_fp_reconstruction() {
        let w = spiky_weight(5);
        let mut rng = Pcg64::seed(1);
        let svd = svd_randomized(&w, 16, 8, 2, &mut rng);
        let (u, v) = svd.split_factors();
        let base = u.matmul_t(&v);
        let r = random_rotation(16, &mut rng);
        let rotated = u.matmul(&r).matmul_t(&v.matmul(&r));
        assert!(rotated.fro_dist2(&base) / base.fro_norm().powi(2) < 1e-6);
    }

    /// λ statistics across strategies (§4.3-4.4): rotation drives mean λ to
    /// the Gaussian limit ≈0.36; Joint-ITQ pushes below it.
    #[test]
    fn mean_distortion_ordering() {
        let w = spiky_weight(21);
        let mut rng = Pcg64::seed(2);
        let svd = svd_randomized(&w, 32, 10, 2, &mut rng);
        let (u, v) = svd.split_factors();

        let mean_lambda = |m: &Mat| -> f64 {
            let ls: Vec<f64> = (0..m.rows()).map(|i| local_distortion(m.row(i))).collect();
            ls.iter().sum::<f64>() / ls.len() as f64
        };

        let lam_svd = mean_lambda(&u);
        let rot = random_rotation(32, &mut rng);
        let lam_rot = mean_lambda(&u.matmul(&rot));
        let (r_itq, _) = joint_itq(&u, &v, 50, &mut rng);
        let lam_itq = mean_lambda(&u.matmul(&r_itq));

        assert!(lam_rot < lam_svd, "rot {lam_rot} !< svd {lam_svd}");
        assert!(lam_itq < lam_rot, "itq {lam_itq} !< rot {lam_rot}");
        // Gaussian limit check (±0.06 tolerance at r=32).
        assert!((lam_rot - 0.3634).abs() < 0.08, "lam_rot={lam_rot}");
    }

    /// Residual path must help binary quantization (App. G): the second
    /// path explicitly approximates the first path's quantization noise.
    /// (Measured on the Standard init; with Joint-ITQ the single wide path
    /// is already so well aligned that the split roughly ties — recorded as
    /// a deviation in EXPERIMENTS.md and explored by `benches/residual`.)
    #[test]
    fn residual_beats_single_path_binary() {
        let w = spiky_weight(31);
        let mut rng_a = Pcg64::seed(3);
        let mut rng_b = Pcg64::seed(3);
        let base = CompressionConfig {
            bpp: 0.8,
            strategy: InitStrategy::Standard,
            residual: true,
            ..Default::default()
        };
        let single = CompressionConfig { residual: false, ..base.clone() };
        let res = compress(&w, &base, &mut rng_a).reconstruct().mse(&w);
        let sin = compress(&w, &single, &mut rng_b).reconstruct().mse(&w);
        assert!(res < sin, "residual {res} !< single {sin}");
    }

    /// Budget accounting: storage bits must respect the bpp budget.
    /// (At tiny matrix sizes the fixed I/O scales dominate and the minimum
    /// feasible footprint can exceed very low budgets — matching the
    /// paper's observation that the fixed LM head dominates at 0.1 bpp —
    /// so this uses a 256² layer where both budgets are feasible.)
    #[test]
    fn compressed_respects_budget() {
        let mut srng = Pcg64::seed(41);
        let spec = SynthSpec { rows: 256, cols: 256, gamma: 0.3, coherence: 0.7, scale: 1.0 };
        let w = synth_weight(&spec, &mut srng);
        for bpp in [0.55, 1.0] {
            let mut rng = Pcg64::seed(4);
            let cfg = CompressionConfig { bpp, ..Default::default() };
            let c = compress(&w, &cfg, &mut rng);
            let bits = c.storage_bits();
            let n = (w.rows() * w.cols()) as f64;
            assert!(
                bits as f64 / n <= bpp + 1e-9,
                "bpp={} budget={bpp}",
                bits as f64 / n
            );
        }
    }

    /// Deterministic compression for fixed seeds.
    #[test]
    fn compression_is_deterministic() {
        let w = spiky_weight(51);
        let cfg = CompressionConfig::default();
        let mut r1 = Pcg64::seed(7);
        let mut r2 = Pcg64::seed(7);
        let a = compress(&w, &cfg, &mut r1).reconstruct();
        let b = compress(&w, &cfg, &mut r2).reconstruct();
        assert_eq!(a, b);
    }
}
