//! Dual-SVID: scale extraction via rank-1 magnitude decomposition
//! (Algorithm 2 / Appendix C, and Listing 1–2 of Appendix J).

use super::TriScaleFactors;
use crate::linalg::{svd_randomized_on, Mat};
use crate::parallel::Pool;
use crate::rng::Pcg64;

/// Rank-1 approximation of a non-negative magnitude matrix `X ≈ u·vᵀ`
/// (Listing 1). Uses the power method, appropriate because the dominant
/// singular triplet of a non-negative matrix is non-negative
/// (Perron–Frobenius); signs are fixed positive on output. Runs on the
/// process-wide [`Pool::global`]; [`rank_one_decompose_on`] pins a pool.
pub fn rank_one_decompose(x: &Mat, rng: &mut Pcg64) -> (Vec<f32>, Vec<f32>) {
    rank_one_decompose_on(x, rng, Pool::global())
}

/// [`rank_one_decompose`] on an explicit [`Pool`] (bit-identical for any
/// pool).
pub fn rank_one_decompose_on(x: &Mat, rng: &mut Pcg64, pool: &Pool) -> (Vec<f32>, Vec<f32>) {
    let svd = svd_randomized_on(x, 1, 6, 3, rng, pool);
    let s0 = svd.s[0].max(0.0);
    let sqrt_s0 = s0.sqrt();
    let mut u: Vec<f32> = svd.u.col(0).iter().map(|&a| a * sqrt_s0).collect();
    let mut v: Vec<f32> = svd.v.col(0).iter().map(|&a| a * sqrt_s0).collect();
    // Perron vector sign fix: flip both if mass is negative.
    let mass: f64 = u.iter().map(|&a| a as f64).sum();
    if mass < 0.0 {
        for a in u.iter_mut() {
            *a = -*a;
        }
        for a in v.iter_mut() {
            *a = -*a;
        }
    }
    // Clamp tiny negatives from round-off: scales must be non-negative.
    for a in u.iter_mut().chain(v.iter_mut()) {
        *a = a.max(0.0);
    }
    (u, v)
}

/// Dual-SVID (Alg. 2): from (possibly rotated) latent factors
/// `Ũ (d_out×r)`, `Ṽ (d_in×r)`, extract
///
/// * binary factors `U_b = sign(Ũ)`, `V_b = sign(Ṽ)`,
/// * scales from rank-1 decompositions `|Ũ| ≈ h·ℓ_uᵀ`, `|Ṽ| ≈ g·ℓ_vᵀ`,
/// * central scale `l = ℓ_u ⊙ ℓ_v`.
///
/// Runs on the process-wide [`Pool::global`]; [`dual_svid_on`] pins a
/// pool. Either way the factors are bit-identical — SVID stays a pure
/// function of its inputs.
pub fn dual_svid(u_tilde: &Mat, v_tilde: &Mat) -> TriScaleFactors {
    dual_svid_on(u_tilde, v_tilde, Pool::global())
}

/// [`dual_svid`] on an explicit [`Pool`].
pub fn dual_svid_on(u_tilde: &Mat, v_tilde: &Mat, pool: &Pool) -> TriScaleFactors {
    assert_eq!(u_tilde.cols(), v_tilde.cols());
    // Deterministic internal stream: SVID must be a pure function of its
    // inputs so compression results are reproducible independent of caller
    // RNG state.
    let mut rng = Pcg64::seed(0x5f1d);
    let (h, l_u) = rank_one_decompose_on(&u_tilde.abs(), &mut rng, pool);
    let (g, l_v) = rank_one_decompose_on(&v_tilde.abs(), &mut rng, pool);
    let l: Vec<f32> = l_u.iter().zip(&l_v).map(|(a, b)| a * b).collect();
    TriScaleFactors {
        u_b: u_tilde.signum(),
        v_b: v_tilde.signum(),
        h,
        l,
        g,
        latent_u: u_tilde.clone(),
        latent_v: v_tilde.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_one_exact_on_separable() {
        let mut rng = Pcg64::seed(1);
        let u0: Vec<f32> = (0..20).map(|i| 0.5 + 0.1 * i as f32).collect();
        let v0: Vec<f32> = (0..12).map(|j| 1.0 + 0.2 * j as f32).collect();
        let x = Mat::from_fn(20, 12, |i, j| u0[i] * v0[j]);
        let (u, v) = rank_one_decompose(&x, &mut rng);
        let back = Mat::from_fn(20, 12, |i, j| u[i] * v[j]);
        assert!(back.fro_dist2(&x) / x.fro_norm().powi(2) < 1e-6);
    }

    #[test]
    fn rank_one_scales_nonnegative() {
        let mut rng = Pcg64::seed(2);
        let x = Mat::gaussian(30, 16, &mut rng).abs();
        let (u, v) = rank_one_decompose(&x, &mut rng);
        assert!(u.iter().all(|&a| a >= 0.0));
        assert!(v.iter().all(|&a| a >= 0.0));
    }

    #[test]
    fn dual_svid_exact_on_separable_magnitudes() {
        // Ũ = diag(h)·S·diag(ℓ) with S ∈ {±1} is exactly representable.
        let mut rng = Pcg64::seed(3);
        let (d, r) = (24, 6);
        let h0: Vec<f32> = (0..d).map(|i| 0.5 + 0.05 * i as f32).collect();
        let l0: Vec<f32> = (0..r).map(|j| 1.0 - 0.1 * j as f32).collect();
        let s_u = Mat::gaussian(d, r, &mut rng).signum();
        let s_v = Mat::gaussian(d, r, &mut rng).signum();
        let u = s_u.scale_rows(&h0).scale_cols(&l0);
        let v = s_v.scale_rows(&h0).scale_cols(&l0);
        let f = dual_svid(&u, &v);
        // Per-factor reconstruction |Ũ| ≈ h·ℓᵀ ⇒ Û ≈ diag(h)·U_b·diag(ℓ_u).
        // Verify the full tri-scale product matches Ũ·Ṽᵀ.
        let target = u.matmul_t(&v);
        let approx = f.reconstruct();
        assert!(
            approx.fro_dist2(&target) / target.fro_norm().powi(2) < 1e-4,
            "rel={}",
            approx.fro_dist2(&target) / target.fro_norm().powi(2)
        );
    }

    #[test]
    fn dual_svid_is_deterministic() {
        let mut rng = Pcg64::seed(4);
        let u = Mat::gaussian(40, 8, &mut rng);
        let v = Mat::gaussian(32, 8, &mut rng);
        let a = dual_svid(&u, &v);
        let b = dual_svid(&u, &v);
        assert_eq!(a.reconstruct(), b.reconstruct());
    }

    #[test]
    fn binary_factors_are_signs() {
        let mut rng = Pcg64::seed(5);
        let u = Mat::gaussian(20, 4, &mut rng);
        let v = Mat::gaussian(16, 4, &mut rng);
        let f = dual_svid(&u, &v);
        assert_eq!(f.u_b, u.signum());
        assert_eq!(f.v_b, v.signum());
        assert!(f.u_b.to_vec().iter().all(|&x| x == 1.0 || x == -1.0));
    }
}
