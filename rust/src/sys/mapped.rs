//! Reference-counted artifact backing and the typed zero-copy views the
//! data layer borrows through it.
//!
//! [`MappedArtifact`] owns the bytes of one `.lb2` file — page-cache
//! pages via [`Mmap`] on the zero-copy path, or a 32-byte-aligned heap
//! buffer on the eager/fallback path — and hands out windows into them:
//! [`MappedWords`] (packed `u64` bit-plane, 32-byte-aligned) and
//! [`MappedF32s`] (scale vector, 4-byte-aligned). A view holds an
//! `Arc<MappedArtifact>`, so the mapping lives exactly as long as any
//! weight borrowed from it; every `serve` worker thread shares the one
//! `Arc`, so N workers cost one mapping, not N weight copies.
//!
//! View constructors validate **everything** before the first dereference:
//! element-count overflow, bounds against the backing, the alignment the
//! unsafe slice cast relies on, and (for the raw reinterpret to be the
//! identity) that the target is little-endian like the file format. A
//! failed validation is an `Err` the caller downgrades to the
//! copy-and-restride path — never a panic, never a misaligned load.

use super::mmap::Mmap;
use anyhow::{bail, Context, Result};
use std::path::Path;
use std::sync::Arc;

/// 32-byte-aligned heap bytes — the eager backing, matching the alignment
/// guarantees of the mapped one so borrowed views work identically over
/// both (tests exercise the borrow path without touching the filesystem).
struct AlignedBytes {
    blocks: Vec<Block>,
    len: usize,
}

#[repr(C, align(32))]
#[derive(Clone, Copy)]
struct Block([u8; 32]);

impl AlignedBytes {
    fn from_vec(bytes: &[u8]) -> Self {
        let n_blocks = bytes.len().div_ceil(32);
        let mut blocks = vec![Block([0u8; 32]); n_blocks];
        // SAFETY: Block is repr(C) with no padding; the block array is at
        // least bytes.len() bytes long.
        unsafe {
            std::ptr::copy_nonoverlapping(
                bytes.as_ptr(),
                blocks.as_mut_ptr() as *mut u8,
                bytes.len(),
            );
        }
        Self { blocks, len: bytes.len() }
    }

    #[inline]
    fn as_slice(&self) -> &[u8] {
        // SAFETY: the blocks are contiguous and len ≤ blocks.len()·32.
        unsafe { std::slice::from_raw_parts(self.blocks.as_ptr() as *const u8, self.len) }
    }
}

enum Backing {
    /// Page-cache pages; resident cost ~0, the kernel pages in on demand.
    Map(Mmap),
    /// Heap copy (eager open, or non-unix): counts as resident bytes.
    Heap(AlignedBytes),
}

/// One open `.lb2` file's bytes, shared by every view borrowed from it.
pub struct MappedArtifact {
    backing: Backing,
}

impl MappedArtifact {
    /// Map `path` read-only. Falls back to an aligned heap read when the
    /// mapping syscall fails (or on non-mmap platforms), so `open` always
    /// yields a servable artifact — only [`is_mapped`](Self::is_mapped)
    /// and the byte accounting differ.
    pub fn open(path: impl AsRef<Path>) -> Result<Arc<Self>> {
        let path = path.as_ref();
        let file = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let backing = match Mmap::map(&file) {
            Ok(m) if cfg!(unix) => Backing::Map(m),
            // Non-unix Mmap is an eager read in disguise; account it as
            // heap so mapped_bytes never lies.
            Ok(m) => Backing::Heap(AlignedBytes::from_vec(m.as_slice())),
            Err(_) => {
                let bytes = std::fs::read(path)
                    .with_context(|| format!("reading {}", path.display()))?;
                Backing::Heap(AlignedBytes::from_vec(&bytes))
            }
        };
        Ok(Arc::new(Self { backing }))
    }

    /// Aligned heap backing over bytes already in memory — the test and
    /// fallback entry point; views borrow from it exactly as from a
    /// mapping, but the bytes count as resident.
    pub fn from_bytes(bytes: &[u8]) -> Arc<Self> {
        Arc::new(Self { backing: Backing::Heap(AlignedBytes::from_vec(bytes)) })
    }

    /// Whole-file bytes. 32-byte-aligned base on both backings (page
    /// alignment for the mapping, `repr(align(32))` for the heap).
    #[inline]
    pub fn bytes(&self) -> &[u8] {
        match &self.backing {
            Backing::Map(m) => m.as_slice(),
            Backing::Heap(b) => b.as_slice(),
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.bytes().len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// True when the bytes live in the page cache rather than this
    /// process's heap.
    pub fn is_mapped(&self) -> bool {
        matches!(self.backing, Backing::Map(_))
    }

    /// File bytes backed by the page cache (0 for heap backing).
    pub fn mapped_bytes(&self) -> usize {
        match &self.backing {
            Backing::Map(m) => m.len(),
            Backing::Heap(_) => 0,
        }
    }

    /// File bytes held on this process's heap (0 when mapped).
    pub fn resident_bytes(&self) -> usize {
        match &self.backing {
            Backing::Map(_) => 0,
            Backing::Heap(b) => b.len,
        }
    }
}

impl std::fmt::Debug for MappedArtifact {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MappedArtifact")
            .field("len", &self.len())
            .field("mapped", &self.is_mapped())
            .finish()
    }
}

/// Validate one typed window: bounds, element alignment, endianness.
/// Returns the validated byte offset for the view to store.
fn validate_view(
    art: &MappedArtifact,
    offset: usize,
    byte_len: usize,
    align: usize,
    what: &str,
) -> Result<()> {
    if !cfg!(target_endian = "little") {
        bail!("zero-copy {what} views require a little-endian target (the .lb2 byte order)");
    }
    let end = offset.checked_add(byte_len).context("view range overflow")?;
    if end > art.len() {
        bail!(
            "{what} view [{offset}, {end}) out of bounds of the {}-byte artifact",
            art.len()
        );
    }
    let addr = art.bytes().as_ptr() as usize + offset;
    if addr % align != 0 {
        bail!("{what} view at file offset {offset} is not {align}-byte aligned in memory");
    }
    Ok(())
}

/// A borrowed, 32-byte-aligned `u64` window into a [`MappedArtifact`] —
/// the zero-copy backing of a [`crate::packing::BitMatrix`] bit-plane.
/// Cheap to clone (Arc + two integers).
#[derive(Clone)]
pub struct MappedWords {
    art: Arc<MappedArtifact>,
    offset: usize,
    words: usize,
}

impl MappedWords {
    /// 32-byte alignment, not just `u64`'s 8: a plane row must be a valid
    /// AVX2 `load` operand, same as the owned padded buffers.
    pub fn new(art: &Arc<MappedArtifact>, offset: usize, words: usize) -> Result<Self> {
        let byte_len = words.checked_mul(8).context("word view length overflow")?;
        validate_view(art, offset, byte_len, 32, "bit-plane")?;
        Ok(Self { art: Arc::clone(art), offset, words })
    }

    #[inline]
    pub fn as_slice(&self) -> &[u64] {
        // SAFETY: new() validated bounds, 32-byte (⊇ 8-byte) alignment,
        // and the LE layout; the backing is immutable and outlives self
        // via the Arc.
        unsafe {
            std::slice::from_raw_parts(
                self.art.bytes().as_ptr().add(self.offset) as *const u64,
                self.words,
            )
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.words
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.words == 0
    }

    /// The artifact this view keeps alive.
    pub fn artifact(&self) -> &Arc<MappedArtifact> {
        &self.art
    }

    /// True when the backing artifact is page-cache mapped (false for the
    /// aligned-heap fallback backing).
    pub fn is_mapped(&self) -> bool {
        self.art.is_mapped()
    }
}

impl std::ops::Deref for MappedWords {
    type Target = [u64];
    #[inline]
    fn deref(&self) -> &[u64] {
        self.as_slice()
    }
}

impl PartialEq for MappedWords {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl std::fmt::Debug for MappedWords {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MappedWords")
            .field("offset", &self.offset)
            .field("words", &self.words)
            .field("mapped", &self.is_mapped())
            .finish()
    }
}

/// A borrowed, 4-byte-aligned `f32` window into a [`MappedArtifact`] —
/// the zero-copy backing of a scale vector.
#[derive(Clone)]
pub struct MappedF32s {
    art: Arc<MappedArtifact>,
    offset: usize,
    count: usize,
}

impl MappedF32s {
    pub fn new(art: &Arc<MappedArtifact>, offset: usize, count: usize) -> Result<Self> {
        let byte_len = count.checked_mul(4).context("f32 view length overflow")?;
        validate_view(art, offset, byte_len, 4, "scale-vector")?;
        Ok(Self { art: Arc::clone(art), offset, count })
    }

    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        // SAFETY: new() validated bounds, 4-byte alignment, and the LE
        // layout; the backing is immutable and outlives self via the Arc.
        unsafe {
            std::slice::from_raw_parts(
                self.art.bytes().as_ptr().add(self.offset) as *const f32,
                self.count,
            )
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.count
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn is_mapped(&self) -> bool {
        self.art.is_mapped()
    }
}

impl std::ops::Deref for MappedF32s {
    type Target = [f32];
    #[inline]
    fn deref(&self) -> &[f32] {
        self.as_slice()
    }
}

impl PartialEq for MappedF32s {
    fn eq(&self, other: &Self) -> bool {
        // Bit compare, not float compare: two views are equal iff their
        // stored bytes are (NaN-safe, matching the bit-identity contract).
        self.as_slice().len() == other.as_slice().len()
            && self
                .as_slice()
                .iter()
                .zip(other.as_slice())
                .all(|(a, b)| a.to_bits() == b.to_bits())
    }
}

impl std::fmt::Debug for MappedF32s {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MappedF32s")
            .field("offset", &self.offset)
            .field("count", &self.count)
            .field("mapped", &self.is_mapped())
            .finish()
    }
}

/// A scale vector with owned-or-borrowed backing — `Cow<[f32]>` whose
/// borrowed arm carries the artifact lifetime in an `Arc` instead of a
/// lifetime parameter, so layers stay `'static` and pool-shareable.
/// Derefs to `[f32]`, so kernel call sites are backing-agnostic.
#[derive(Clone, Debug)]
pub enum ScaleVec {
    Owned(Vec<f32>),
    Mapped(MappedF32s),
}

impl ScaleVec {
    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        match self {
            ScaleVec::Owned(v) => v,
            ScaleVec::Mapped(m) => m.as_slice(),
        }
    }

    /// Heap bytes this vector reads from (0 when borrowed from a real
    /// mapping; borrowed-from-heap-fallback still counts — those bytes
    /// are in this process's RAM).
    pub fn resident_bytes(&self) -> usize {
        match self {
            ScaleVec::Owned(v) => v.len() * 4,
            ScaleVec::Mapped(m) if m.is_mapped() => 0,
            ScaleVec::Mapped(m) => m.len() * 4,
        }
    }

    /// Page-cache bytes this vector reads through (0 when owned).
    pub fn mapped_bytes(&self) -> usize {
        match self {
            ScaleVec::Owned(_) => 0,
            ScaleVec::Mapped(m) if m.is_mapped() => m.len() * 4,
            ScaleVec::Mapped(_) => 0,
        }
    }

    pub fn is_mapped(&self) -> bool {
        matches!(self, ScaleVec::Mapped(m) if m.is_mapped())
    }
}

impl std::ops::Deref for ScaleVec {
    type Target = [f32];
    #[inline]
    fn deref(&self) -> &[f32] {
        self.as_slice()
    }
}

impl From<Vec<f32>> for ScaleVec {
    fn from(v: Vec<f32>) -> Self {
        ScaleVec::Owned(v)
    }
}

impl From<MappedF32s> for ScaleVec {
    fn from(m: MappedF32s) -> Self {
        ScaleVec::Mapped(m)
    }
}

impl PartialEq for ScaleVec {
    fn eq(&self, other: &Self) -> bool {
        // Bit compare: backing is irrelevant, stored values decide.
        self.as_slice().len() == other.as_slice().len()
            && self
                .as_slice()
                .iter()
                .zip(other.as_slice())
                .all(|(a, b)| a.to_bits() == b.to_bits())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn heap_backing_is_32_byte_aligned() {
        let art = MappedArtifact::from_bytes(&[1u8; 100]);
        assert_eq!(art.bytes().as_ptr() as usize % 32, 0);
        assert_eq!(art.len(), 100);
        assert!(!art.is_mapped());
        assert_eq!(art.resident_bytes(), 100);
        assert_eq!(art.mapped_bytes(), 0);
    }

    #[test]
    fn word_view_reads_le_words() {
        let mut bytes = Vec::new();
        for w in [0x0123_4567_89AB_CDEFu64, u64::MAX, 0, 42] {
            bytes.extend_from_slice(&w.to_le_bytes());
        }
        let art = MappedArtifact::from_bytes(&bytes);
        let v = MappedWords::new(&art, 0, 4).unwrap();
        assert_eq!(v.as_slice(), &[0x0123_4567_89AB_CDEF, u64::MAX, 0, 42]);
    }

    #[test]
    fn f32_view_reads_le_floats() {
        let mut bytes = Vec::new();
        for f in [1.5f32, -0.25, f32::MIN_POSITIVE] {
            bytes.extend_from_slice(&f.to_le_bytes());
        }
        let art = MappedArtifact::from_bytes(&bytes);
        let v = MappedF32s::new(&art, 4, 2).unwrap();
        assert_eq!(v.as_slice(), &[-0.25, f32::MIN_POSITIVE]);
    }

    #[test]
    fn views_reject_misalignment_and_overrun() {
        let art = MappedArtifact::from_bytes(&[0u8; 256]);
        // Word views demand 32-byte alignment.
        assert!(MappedWords::new(&art, 8, 1).is_err());
        assert!(MappedWords::new(&art, 32, 1).is_ok());
        // f32 views demand 4-byte alignment.
        assert!(MappedF32s::new(&art, 2, 1).is_err());
        assert!(MappedF32s::new(&art, 4, 1).is_ok());
        // Out of bounds, including the overflow path.
        assert!(MappedWords::new(&art, 224, 5).is_err());
        assert!(MappedWords::new(&art, 0, usize::MAX / 8 + 1).is_err());
        assert!(MappedF32s::new(&art, 256, 1).is_err());
    }

    #[test]
    fn view_keeps_artifact_alive() {
        let v = {
            let art = MappedArtifact::from_bytes(&7u64.to_le_bytes());
            MappedWords::new(&art, 0, 1).unwrap()
            // art's Arc binding drops here; the view's clone keeps it.
        };
        assert_eq!(v.as_slice(), &[7]);
    }

    #[test]
    fn scale_vec_backing_is_transparent() {
        let owned = ScaleVec::from(vec![1.0f32, 2.0, 3.0]);
        let mut bytes = Vec::new();
        for f in [1.0f32, 2.0, 3.0] {
            bytes.extend_from_slice(&f.to_le_bytes());
        }
        let art = MappedArtifact::from_bytes(&bytes);
        let borrowed = ScaleVec::from(MappedF32s::new(&art, 0, 3).unwrap());
        assert_eq!(owned, borrowed);
        assert_eq!(&owned[..], &borrowed[..]);
        assert_eq!(owned.resident_bytes(), 12);
        // Heap-backed artifact: the borrowed bytes are still in this
        // process's RAM, so they count as resident, not mapped.
        assert_eq!(borrowed.resident_bytes(), 12);
        assert_eq!(borrowed.mapped_bytes(), 0);
        assert!(!borrowed.is_mapped());
    }

    #[test]
    fn open_maps_a_real_file() {
        let mut path = std::env::temp_dir();
        path.push(format!("lb2_mapped_art_{}.bin", std::process::id()));
        let payload: Vec<u8> = (0u8..=63).collect();
        std::fs::write(&path, &payload).unwrap();
        let art = MappedArtifact::open(&path).unwrap();
        assert_eq!(art.bytes(), &payload[..]);
        if art.is_mapped() {
            assert_eq!(art.mapped_bytes(), payload.len());
            assert_eq!(art.resident_bytes(), 0);
        }
        drop(art);
        std::fs::remove_file(&path).unwrap();
    }
}
