//! Read-only file mapping over raw libc prototypes — no `libc` crate.
//!
//! The build image has no crates.io access, so instead of pulling in
//! `memmap2` this module declares the exact two symbols it needs via
//! `extern "C"` and keeps the constants it passes to a portable subset:
//! `PROT_READ` is 1 and `MAP_PRIVATE` is 2 on every Tier-1 unix target
//! (Linux, macOS, the BSDs). The mapping is private and read-only, so the
//! returned pages can never be written back to the file and a `&[u8]`
//! over them is sound for the life of the [`Mmap`].
//!
//! Kernel-guaranteed base alignment: `mmap(NULL, …)` returns a
//! page-aligned address (≥ 4096 bytes), so any file offset that is
//! 32-byte aligned lands at a 32-byte-aligned memory address — the
//! invariant the `.lb2` v3 "aligned" encoding builds on (see
//! `artifact`'s module docs).
//!
//! Contract: the caller must not truncate or rewrite the underlying file
//! while the mapping is live (a concurrent truncation makes reads fault —
//! the same rule every mmap consumer lives under). The serve path holds
//! the artifact open only through this mapping and never writes it.

use anyhow::{bail, Context, Result};
use std::fs::File;

#[cfg(unix)]
mod raw {
    use std::ffi::{c_int, c_void};

    pub const PROT_READ: c_int = 1;
    pub const MAP_PRIVATE: c_int = 2;
    /// `MAP_FAILED` is `(void*)-1`, not NULL.
    pub const MAP_FAILED: *mut c_void = usize::MAX as *mut c_void;

    extern "C" {
        /// `off_t` is declared `i64`; correct on every 64-bit unix target
        /// (the only ones this crate ships on — see the workspace docs).
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
    }
}

/// A read-only, private, whole-file memory mapping.
///
/// Unmapped on drop. `Send + Sync`: the pages are immutable for the
/// mapping's lifetime (PROT_READ, MAP_PRIVATE), so shared cross-thread
/// reads are data-race-free.
#[cfg(unix)]
pub struct Mmap {
    ptr: *mut std::ffi::c_void,
    len: usize,
}

#[cfg(unix)]
impl Mmap {
    /// Map all `len` bytes of an open file read-only. The length is taken
    /// from the caller (typically `File::metadata`) and validated against
    /// a fresh `metadata()` call so a file that shrank between stat and
    /// map fails loudly instead of faulting later.
    pub fn map(file: &File) -> Result<Self> {
        use std::os::unix::io::AsRawFd;

        let len = file.metadata().context("stat for mmap")?.len();
        let len = usize::try_from(len).context("file too large to map")?;
        if len == 0 {
            // mmap(len = 0) is EINVAL; an empty mapping needs no pages.
            return Ok(Self { ptr: std::ptr::null_mut(), len: 0 });
        }
        // SAFETY: NULL hint, read-only private mapping of a file we hold
        // open; the kernel picks the address. Failure is MAP_FAILED.
        let ptr = unsafe {
            raw::mmap(
                std::ptr::null_mut(),
                len,
                raw::PROT_READ,
                raw::MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr == raw::MAP_FAILED || ptr.is_null() {
            bail!("mmap of {len} bytes failed (errno {})", std::io::Error::last_os_error());
        }
        Ok(Self { ptr, len })
    }

    /// The mapped bytes. Page-aligned base for non-empty mappings.
    #[inline]
    pub fn as_slice(&self) -> &[u8] {
        if self.len == 0 {
            return &[];
        }
        // SAFETY: ptr..ptr+len is a live PROT_READ mapping owned by self;
        // no &mut ever exists.
        unsafe { std::slice::from_raw_parts(self.ptr as *const u8, self.len) }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

// SAFETY: the pages are read-only for the mapping's whole lifetime and the
// fd is not retained, so sending or sharing the handle across threads
// cannot race.
#[cfg(unix)]
unsafe impl Send for Mmap {}
#[cfg(unix)]
unsafe impl Sync for Mmap {}

#[cfg(unix)]
impl Drop for Mmap {
    fn drop(&mut self) {
        if self.len > 0 {
            // SAFETY: exactly the region mmap returned; double-unmap is
            // impossible (Drop runs once, the struct is not Clone).
            unsafe {
                raw::munmap(self.ptr, self.len);
            }
        }
    }
}

#[cfg(unix)]
impl std::fmt::Debug for Mmap {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Mmap").field("len", &self.len).finish()
    }
}

/// Non-unix stand-in: no mapping syscall to call, so "mapping" a file is
/// an eager read. [`super::MappedArtifact`] treats this backing as
/// resident (not mapped) in its byte accounting, so the metrics stay
/// honest on platforms without the real thing.
#[cfg(not(unix))]
pub struct Mmap {
    bytes: Vec<u8>,
}

#[cfg(not(unix))]
impl Mmap {
    pub fn map(file: &File) -> Result<Self> {
        use std::io::Read;
        let mut f = file;
        let mut bytes = Vec::new();
        f.read_to_end(&mut bytes).context("reading file (no mmap on this platform)")?;
        Ok(Self { bytes })
    }

    #[inline]
    pub fn as_slice(&self) -> &[u8] {
        &self.bytes
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.bytes.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.bytes.is_empty()
    }
}

#[cfg(not(unix))]
impl std::fmt::Debug for Mmap {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Mmap").field("len", &self.len()).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn temp_path(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("lb2_mmap_{}_{name}", std::process::id()));
        p
    }

    #[test]
    fn maps_file_bytes_verbatim() {
        let path = temp_path("verbatim");
        let payload: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        std::fs::File::create(&path).unwrap().write_all(&payload).unwrap();
        let file = File::open(&path).unwrap();
        let m = Mmap::map(&file).unwrap();
        assert_eq!(m.len(), payload.len());
        assert_eq!(m.as_slice(), &payload[..]);
        drop(m);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn empty_file_maps_to_empty_slice() {
        let path = temp_path("empty");
        std::fs::File::create(&path).unwrap();
        let file = File::open(&path).unwrap();
        let m = Mmap::map(&file).unwrap();
        assert!(m.is_empty());
        assert_eq!(m.as_slice(), &[] as &[u8]);
        drop(m);
        std::fs::remove_file(&path).unwrap();
    }

    #[cfg(unix)]
    #[test]
    fn base_is_page_aligned() {
        let path = temp_path("aligned");
        std::fs::File::create(&path).unwrap().write_all(&[7u8; 64]).unwrap();
        let file = File::open(&path).unwrap();
        let m = Mmap::map(&file).unwrap();
        // Page alignment implies the 32-byte alignment the v3 layout uses.
        assert_eq!(m.as_slice().as_ptr() as usize % 4096, 0);
        drop(m);
        std::fs::remove_file(&path).unwrap();
    }
}
