//! Platform-interface layer: the lowest rung of the crate, below
//! `linalg`/`packing` — nothing here may depend on any other module.
//!
//! The vendored-offline constraint rules out the `libc`/`memmap2` crates,
//! so [`mmap`] declares the two raw prototypes it needs (`mmap`/`munmap`)
//! directly against the platform C library and wraps them in a safe,
//! read-only [`Mmap`]. [`mapped`] builds the typed zero-copy views the
//! data layer borrows its weights through: a reference-counted
//! [`MappedArtifact`] plus alignment-validated `u64`/`f32` windows into
//! it ([`MappedWords`], [`MappedF32s`]) and the owned-or-mapped scale
//! vector [`ScaleVec`].

pub mod mapped;
pub mod mmap;

pub use mapped::{MappedArtifact, MappedF32s, MappedWords, ScaleVec};
pub use mmap::Mmap;
