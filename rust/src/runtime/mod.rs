//! Artifact runtime: the manifest contract with `python/compile/aot.py`,
//! plus (feature-gated) PJRT execution of the compiled HLO graphs.
//!
//! Artifacts are produced once by `python/compile/aot.py` (`make
//! artifacts`); at run time this module is the **only** bridge between the
//! rust coordinator and the compiled L2/L1 graphs — Python is never on the
//! request path.
//!
//! The manifest tooling (`Manifest`, JSON parsing, artifact/spec metadata)
//! is always available. The PJRT execution half wraps the `xla` crate
//! (PJRT C API, CPU client), which cannot be fetched in the offline build
//! image — it is compile-gated behind the `xla` cargo feature, along with
//! everything that calls it (`coordinator::trainer`, the `train` CLI
//! subcommand, `examples/e2e_qat`, `tests/runtime_e2e`).

mod manifest;
#[cfg(feature = "xla")]
mod pjrt;

pub use manifest::{ArtifactInfo, Manifest, ModelConfigInfo};
#[cfg(feature = "xla")]
pub use pjrt::{lit, Executable, Runtime};
