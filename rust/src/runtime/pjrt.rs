//! PJRT execution of AOT-compiled HLO text artifacts (the `xla` crate).
//!
//! Compile-gated behind the `xla` cargo feature: the `xla` crate links the
//! PJRT C API and cannot be fetched or built in the offline build image, so
//! the default build ships the manifest tooling only and this module (plus
//! `coordinator::trainer`) lights up when the crate is vendored and the
//! feature enabled. See ARCHITECTURE.md for the layer contract.
//!
//! The flow mirrors /opt/xla-example/load_hlo:
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. All exported graphs return tuples
//! (`return_tuple=True` at lowering), unpacked here into literal vectors.

use super::Manifest;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// A PJRT CPU session holding compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
    artifact_dir: PathBuf,
}

/// One compiled artifact ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
    pub num_inputs: usize,
}

impl Runtime {
    /// Create a CPU PJRT client rooted at an artifact directory.
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client, artifact_dir: artifact_dir.as_ref().to_path_buf() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.artifact_dir
    }

    /// Load the artifact manifest written by aot.py.
    pub fn manifest(&self) -> Result<Manifest> {
        Manifest::load(self.artifact_dir.join("manifest.json"))
    }

    /// Load + compile `<name>.hlo.txt` from the artifact directory.
    pub fn load(&self, name: &str) -> Result<Executable> {
        let path = self.artifact_dir.join(format!("{name}.hlo.txt"));
        let path_str = path
            .to_str()
            .context("artifact path not valid UTF-8")?
            .to_string();
        let proto = xla::HloModuleProto::from_text_file(&path_str)
            .with_context(|| format!("parsing HLO text {path_str}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {name}"))?;
        Ok(Executable { exe, name: name.to_string(), num_inputs: 0 })
    }

    /// Load an artifact and record its expected arity from the manifest.
    pub fn load_checked(&self, name: &str) -> Result<Executable> {
        let manifest = self.manifest()?;
        let info = manifest
            .artifacts
            .get(name)
            .with_context(|| format!("artifact {name} not in manifest"))?;
        let mut exe = self.load(name)?;
        exe.num_inputs = info.num_inputs;
        Ok(exe)
    }
}

impl Executable {
    /// Execute with literal inputs; returns the flattened tuple elements.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        if self.num_inputs != 0 && inputs.len() != self.num_inputs {
            anyhow::bail!(
                "artifact {} expects {} inputs, got {}",
                self.name,
                self.num_inputs,
                inputs.len()
            );
        }
        let mut result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.name))?[0][0]
            .to_literal_sync()?;
        // Lowered with return_tuple=True: decompose the 1-level tuple.
        let parts = result.decompose_tuple()?;
        Ok(parts)
    }
}

/// Helpers for marshalling between rust buffers and XLA literals.
pub mod lit {
    use anyhow::Result;

    /// f32 vector → rank-1 literal.
    pub fn vec_f32(data: &[f32]) -> xla::Literal {
        xla::Literal::vec1(data)
    }

    /// f32 buffer + shape → literal.
    pub fn array_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
        let n: usize = dims.iter().product();
        anyhow::ensure!(n == data.len(), "shape/data mismatch");
        if dims.is_empty() {
            return Ok(xla::Literal::from(data[0]));
        }
        let d: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
        Ok(xla::Literal::vec1(data).reshape(&d)?)
    }

    /// i32 buffer + shape → literal.
    pub fn array_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
        let n: usize = dims.iter().product();
        anyhow::ensure!(n == data.len(), "shape/data mismatch");
        let d: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
        Ok(xla::Literal::vec1(data).reshape(&d)?)
    }

    /// Scalar f32 literal.
    pub fn scalar_f32(x: f32) -> xla::Literal {
        xla::Literal::from(x)
    }

    /// Literal → f32 vector.
    pub fn to_vec_f32(l: &xla::Literal) -> Result<Vec<f32>> {
        Ok(l.to_vec::<f32>()?)
    }

    /// Scalar literal → f32.
    pub fn to_scalar_f32(l: &xla::Literal) -> Result<f32> {
        Ok(l.get_first_element::<f32>()?)
    }
}
