//! Artifact manifest parsing.
//!
//! `aot.py` writes `manifest.json` describing every exported HLO artifact
//! (input arity + shapes), the parameter specs (the positional contract for
//! train/eval steps), and the model configuration. No serde in this build
//! environment, so this file carries a small recursive-descent JSON parser —
//! sufficient for the manifest subset (objects, arrays, strings, numbers,
//! bools, null).

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Minimal JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).with_context(|| format!("missing key {key:?}")),
            _ => bail!("not an object"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("not a number: {self:?}"),
        }
    }

    /// The value as a `usize` — strictly. A negative number, a fraction,
    /// or a non-finite/oversized value is an `Err`: `"d_model": -64` or
    /// `3.5` must fail the manifest load, not silently truncate to a wrong
    /// shape (the old behavior of `as f64 as usize`, which maps -64 → 0
    /// and 3.5 → 3).
    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if !x.is_finite() || x < 0.0 || x.fract() != 0.0 {
            bail!("not a non-negative integer: {x}");
        }
        // f64 represents integers exactly only below 2^53; anything at or
        // past that (or past the platform word) is out of contract.
        if x >= 9_007_199_254_740_992.0 || x > usize::MAX as f64 {
            bail!("integer out of range: {x}");
        }
        Ok(x as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }
}

/// Parse a JSON document.
pub fn parse_json(src: &str) -> Result<Json> {
    parse_json_bytes(src.as_bytes())
}

/// Parse a JSON document from raw bytes (what [`Manifest::load`] reads off
/// disk — no up-front UTF-8 pass; string contents are validated in place
/// and malformed byte sequences are an `Err`, never a slice panic).
pub fn parse_json_bytes(bytes: &[u8]) -> Result<Json> {
    let mut pos = 0usize;
    let v = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        bail!("trailing garbage at byte {pos}");
    }
    Ok(v)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        bail!("unexpected end of input");
    }
    match b[*pos] {
        b'{' => parse_object(b, pos),
        b'[' => parse_array(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => {
            expect(b, pos, "true")?;
            Ok(Json::Bool(true))
        }
        b'f' => {
            expect(b, pos, "false")?;
            Ok(Json::Bool(false))
        }
        b'n' => {
            expect(b, pos, "null")?;
            Ok(Json::Null)
        }
        _ => parse_number(b, pos),
    }
}

fn expect(b: &[u8], pos: &mut usize, lit: &str) -> Result<()> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(())
    } else {
        bail!("expected {lit} at byte {pos}")
    }
}

fn parse_object(b: &[u8], pos: &mut usize) -> Result<Json> {
    *pos += 1; // {
    let mut map = BTreeMap::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b'}' {
        *pos += 1;
        return Ok(Json::Obj(map));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        if *pos >= b.len() || b[*pos] != b':' {
            bail!("expected ':' at byte {pos}");
        }
        *pos += 1;
        let val = parse_value(b, pos)?;
        map.insert(key, val);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(map));
            }
            _ => bail!("expected ',' or '}}' at byte {pos}"),
        }
    }
}

fn parse_array(b: &[u8], pos: &mut usize) -> Result<Json> {
    *pos += 1; // [
    let mut arr = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b']' {
        *pos += 1;
        return Ok(Json::Arr(arr));
    }
    loop {
        arr.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(arr));
            }
            _ => bail!("expected ',' or ']' at byte {pos}"),
        }
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String> {
    if *pos >= b.len() || b[*pos] != b'"' {
        bail!("expected string at byte {pos}");
    }
    *pos += 1;
    let mut out = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let hi = read_hex4(b, *pos + 1)?;
                        *pos += 4; // now on the last hex digit
                        let code = if (0xD800..=0xDBFF).contains(&hi) {
                            // High surrogate: JSON encodes astral-plane
                            // characters as a pair (e.g. U+1F600 arrives
                            // as \uD83D\uDE00); the low half must follow.
                            if b.len() < *pos + 3 || b[*pos + 1] != b'\\' || b[*pos + 2] != b'u' {
                                bail!("high surrogate \\u{hi:04X} not followed by \\u escape");
                            }
                            let lo = read_hex4(b, *pos + 3)?;
                            if !(0xDC00..=0xDFFF).contains(&lo) {
                                bail!("high surrogate \\u{hi:04X} followed by non-low \\u{lo:04X}");
                            }
                            *pos += 6; // now on the pair's last hex digit
                            0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                        } else if (0xDC00..=0xDFFF).contains(&hi) {
                            bail!("unpaired low surrogate \\u{hi:04X}");
                        } else {
                            hi
                        };
                        out.push(char::from_u32(code).context("bad \\u escape")?);
                    }
                    _ => bail!("bad escape at byte {pos}"),
                }
                *pos += 1;
            }
            c => {
                // Copy one UTF-8 scalar through, validating as we go: an
                // invalid first byte, a sequence running past the buffer,
                // or bad continuation bytes are all `Err` — the old code
                // trusted the first byte and sliced `start + len` straight
                // past the end of truncated input.
                let start = *pos;
                let len = utf8_len(c)
                    .with_context(|| format!("invalid UTF-8 first byte {c:#04x} at byte {start}"))?;
                if start + len > b.len() {
                    bail!("truncated UTF-8 sequence at byte {start}");
                }
                out.push_str(
                    std::str::from_utf8(&b[start..start + len])
                        .with_context(|| format!("invalid UTF-8 sequence at byte {start}"))?,
                );
                *pos += len;
            }
        }
    }
    bail!("unterminated string")
}

/// Exactly four hex digits at `b[at..at + 4]`, bounds-checked.
/// (`from_str_radix` alone would accept a leading sign, letting invalid
/// JSON like `\u+041` slip through as `A`.)
fn read_hex4(b: &[u8], at: usize) -> Result<u32> {
    if b.len() < at + 4 {
        bail!("truncated \\u escape");
    }
    let digits = &b[at..at + 4];
    if !digits.iter().all(|d| d.is_ascii_hexdigit()) {
        bail!("non-hex digit in \\u escape at byte {at}");
    }
    let hex = std::str::from_utf8(digits).expect("hex digits are ASCII");
    Ok(u32::from_str_radix(hex, 16)?)
}

/// Length of the UTF-8 sequence introduced by `first`, or `None` when
/// `first` cannot start a sequence (continuation bytes 0x80–0xBF, the
/// overlong-encoding leads 0xC0/0xC1, and everything past 0xF4).
fn utf8_len(first: u8) -> Option<usize> {
    match first {
        0x00..=0x7F => Some(1),
        0xC2..=0xDF => Some(2),
        0xE0..=0xEF => Some(3),
        0xF0..=0xF4 => Some(4),
        _ => None,
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let s = std::str::from_utf8(&b[start..*pos])?;
    Ok(Json::Num(s.parse::<f64>().with_context(|| format!("bad number {s:?}"))?))
}

// ---------------------------------------------------------------------------
// Typed manifest views
// ---------------------------------------------------------------------------

/// One artifact's metadata.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub path: String,
    pub num_inputs: usize,
    /// (dtype, shape) per input.
    pub input_shapes: Vec<(String, Vec<usize>)>,
}

/// The exported model configuration (mirror of python ModelConfig).
#[derive(Clone, Debug)]
pub struct ModelConfigInfo {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq: usize,
    pub batch: usize,
    pub bpp: f64,
    pub residual_paths: usize,
    pub kd_alpha: f64,
    pub kd_temperature: f64,
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub config: ModelConfigInfo,
    pub preset: String,
    /// (name, shape) in positional order.
    pub teacher_spec: Vec<(String, Vec<usize>)>,
    pub student_spec: Vec<(String, Vec<usize>)>,
    pub student_fp_spec: Vec<(String, Vec<usize>)>,
    pub artifacts: BTreeMap<String, ArtifactInfo>,
    pub teacher_init_dir: String,
}

/// A `[name, shape]` pair out of a spec/shape entry — length-checked, so a
/// malformed manifest errors instead of panicking on `pair[1]`.
fn as_pair(e: &Json) -> Result<(&Json, &Json)> {
    match e.as_arr()? {
        [a, b] => Ok((a, b)),
        other => bail!("expected a [name, shape] pair, got {} elements", other.len()),
    }
}

fn parse_spec(v: &Json) -> Result<Vec<(String, Vec<usize>)>> {
    v.as_arr()?
        .iter()
        .map(|e| {
            let (name, shape) = as_pair(e)?;
            let name = name.as_str()?.to_string();
            let shape = shape
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<Vec<_>>>()?;
            Ok((name, shape))
        })
        .collect()
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        // Read raw bytes: UTF-8 validation happens inside the parser,
        // byte-by-byte with real error messages, instead of an up-front
        // `read_to_string` rejection.
        let bytes = std::fs::read(path.as_ref())
            .with_context(|| format!("reading {:?}", path.as_ref()))?;
        Self::from_json(&parse_json_bytes(&bytes)?)
    }

    pub fn parse(text: &str) -> Result<Self> {
        Self::from_json(&parse_json(text)?)
    }

    fn from_json(root: &Json) -> Result<Self> {
        let cfg = root.get("config")?;
        let config = ModelConfigInfo {
            vocab: cfg.get("vocab")?.as_usize()?,
            d_model: cfg.get("d_model")?.as_usize()?,
            n_layers: cfg.get("n_layers")?.as_usize()?,
            n_heads: cfg.get("n_heads")?.as_usize()?,
            d_ff: cfg.get("d_ff")?.as_usize()?,
            seq: cfg.get("seq")?.as_usize()?,
            batch: cfg.get("batch")?.as_usize()?,
            bpp: cfg.get("bpp")?.as_f64()?,
            residual_paths: cfg.get("residual_paths")?.as_usize()?,
            kd_alpha: cfg.get("kd_alpha")?.as_f64()?,
            kd_temperature: cfg.get("kd_temperature")?.as_f64()?,
        };
        let mut artifacts = BTreeMap::new();
        for (name, info) in root.get("artifacts")?.as_obj()? {
            let shapes = info
                .get("input_shapes")?
                .as_arr()?
                .iter()
                .map(|e| {
                    let (dt, shape) = as_pair(e)?;
                    let dt = dt.as_str()?.to_string();
                    let shape = shape
                        .as_arr()?
                        .iter()
                        .map(|d| d.as_usize())
                        .collect::<Result<Vec<_>>>()?;
                    Ok((dt, shape))
                })
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactInfo {
                    path: info.get("path")?.as_str()?.to_string(),
                    num_inputs: info.get("num_inputs")?.as_usize()?,
                    input_shapes: shapes,
                },
            );
        }
        Ok(Self {
            config,
            preset: root.get("preset")?.as_str()?.to_string(),
            teacher_spec: parse_spec(root.get("teacher_spec")?)?,
            student_spec: parse_spec(root.get("student_spec")?)?,
            student_fp_spec: parse_spec(root.get("student_fp_spec")?)?,
            artifacts,
            teacher_init_dir: root.get("teacher_init_dir")?.as_str()?.to_string(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse_json("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse_json("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(parse_json("true").unwrap(), Json::Bool(true));
        assert_eq!(parse_json("null").unwrap(), Json::Null);
        assert_eq!(parse_json("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse_json(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b").unwrap().as_str().unwrap(), "c");
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(parse_json("\"\\u00e9\"").unwrap(), Json::Str("é".into()));
    }

    /// Regression: astral-plane characters arrive as surrogate pairs in
    /// valid JSON and used to be rejected ("bad \u escape").
    #[test]
    fn parse_surrogate_pair() {
        assert_eq!(
            parse_json("\"\\uD83D\\uDE00\"").unwrap(),
            Json::Str("\u{1F600}".into())
        );
    }

    /// `\u` escapes take exactly four hex digits — a sign is not a digit
    /// (`from_str_radix` alone would have accepted `\u+041` as `A`).
    #[test]
    fn signed_unicode_escape_rejected() {
        assert!(parse_json("\"\\u+041\"").is_err());
        assert!(parse_json("\"\\u-041\"").is_err());
        assert!(parse_json("\"\\u00 9\"").is_err());
    }

    /// Lone or mis-ordered surrogate halves are malformed, not panics.
    #[test]
    fn lone_surrogates_rejected() {
        for doc in [
            "\"\\uD83D\"",         // unpaired high
            "\"\\uDE00\"",         // unpaired low
            "\"\\uD83D\\u0041\"",  // high followed by non-surrogate
            "\"\\uD83Dx\"",        // high followed by a plain char
            "\"\\uD83D\\",         // high then truncation
        ] {
            assert!(parse_json_bytes(doc.as_bytes()).is_err(), "{doc:?}");
        }
    }

    /// Regression: `utf8_len` trusted the first byte and `parse_string`
    /// sliced past the buffer on truncated multi-byte input — these must
    /// all be `Err`, never an out-of-bounds panic.
    #[test]
    fn malformed_utf8_bytes_rejected() {
        let cases: &[&[u8]] = &[
            b"\"\xe2\x82",         // truncated 3-byte sequence at EOF
            b"\"\xe2\x82\"",       // truncated sequence swallowing the quote
            b"\"\xf0\x9f\x98\"",   // truncated 4-byte sequence
            b"\"\x80\"",           // bare continuation byte
            b"\"\xc0\xaf\"",       // overlong-encoding lead
            b"\"\xff\"",           // invalid byte
            b"\"\xed\xa0\xbd\"",   // UTF-8-encoded surrogate (invalid scalar)
        ];
        for &case in cases {
            assert!(parse_json_bytes(case).is_err(), "{case:?}");
        }
        // Well-formed multi-byte text still round-trips byte-exactly.
        assert_eq!(
            parse_json_bytes("\"héllo \u{1F600}\"".as_bytes()).unwrap(),
            Json::Str("héllo \u{1F600}".into())
        );
    }

    /// Regression: `as_usize` was `as_f64 as usize`, silently mapping
    /// negatives to 0 and truncating fractions — a manifest with
    /// `"d_model": -64` or `3.5` loaded as a wrong shape.
    #[test]
    fn as_usize_requires_nonnegative_integer() {
        assert_eq!(Json::Num(64.0).as_usize().unwrap(), 64);
        assert_eq!(Json::Num(0.0).as_usize().unwrap(), 0);
        assert!(Json::Num(-64.0).as_usize().is_err());
        assert!(Json::Num(3.5).as_usize().is_err());
        assert!(Json::Num(-0.5).as_usize().is_err());
        assert!(Json::Num(f64::NAN).as_usize().is_err());
        assert!(Json::Num(f64::INFINITY).as_usize().is_err());
        assert!(Json::Num(1e300).as_usize().is_err());
        assert!(Json::Str("64".into()).as_usize().is_err());
    }

    /// A negative or fractional dimension anywhere in a manifest fails the
    /// whole load instead of producing a wrong shape.
    #[test]
    fn manifest_with_negative_dim_rejected() {
        let doc = r#"{"config": {"vocab": 256, "d_model": -64}}"#;
        let root = parse_json(doc).unwrap();
        assert!(root.get("config").unwrap().get("d_model").unwrap().as_usize().is_err());
    }

    /// Malformed spec entries (not a [name, shape] pair) are `Err`, not an
    /// index panic.
    #[test]
    fn short_spec_pair_rejected() {
        let v = parse_json(r#"[["embed"]]"#).unwrap();
        assert!(parse_spec(&v).is_err());
        let v = parse_json(r#"[["embed", [4, 4], "extra"]]"#).unwrap();
        assert!(parse_spec(&v).is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_json("{").is_err());
        assert!(parse_json("[1,]").is_err());
        assert!(parse_json("1 2").is_err());
    }

    #[test]
    fn parse_manifest_document() {
        let doc = r#"{
          "config": {"vocab": 256, "d_model": 64, "n_layers": 2, "n_heads": 2,
                     "d_ff": 172, "seq": 32, "batch": 4, "bpp": 1.0,
                     "residual_paths": 2, "fp_latent": false,
                     "kd_alpha": 0.5, "kd_temperature": 2.0},
          "preset": "tiny",
          "teacher_spec": [["embed", [256, 64]], ["head", [256, 64]]],
          "student_spec": [["embed", [256, 64]]],
          "student_fp_spec": [["embed", [256, 64]]],
          "artifacts": {
            "teacher_eval": {"path": "teacher_eval.hlo.txt", "num_inputs": 2,
                             "input_shapes": [["float32", [256, 64]], ["int32", [4, 33]]]}
          },
          "teacher_init_dir": "params"
        }"#;
        let m = Manifest::parse(doc).unwrap();
        assert_eq!(m.config.vocab, 256);
        assert_eq!(m.teacher_spec.len(), 2);
        let a = &m.artifacts["teacher_eval"];
        assert_eq!(a.num_inputs, 2);
        assert_eq!(a.input_shapes[1].0, "int32");
    }
}
