//! Artifact manifest parsing.
//!
//! `aot.py` writes `manifest.json` describing every exported HLO artifact
//! (input arity + shapes), the parameter specs (the positional contract for
//! train/eval steps), and the model configuration. No serde in this build
//! environment, so this file carries a small recursive-descent JSON parser —
//! sufficient for the manifest subset (objects, arrays, strings, numbers,
//! bools, null).

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Minimal JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).with_context(|| format!("missing key {key:?}")),
            _ => bail!("not an object"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }
}

/// Parse a JSON document.
pub fn parse_json(src: &str) -> Result<Json> {
    let bytes = src.as_bytes();
    let mut pos = 0usize;
    let v = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        bail!("trailing garbage at byte {pos}");
    }
    Ok(v)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        bail!("unexpected end of input");
    }
    match b[*pos] {
        b'{' => parse_object(b, pos),
        b'[' => parse_array(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => {
            expect(b, pos, "true")?;
            Ok(Json::Bool(true))
        }
        b'f' => {
            expect(b, pos, "false")?;
            Ok(Json::Bool(false))
        }
        b'n' => {
            expect(b, pos, "null")?;
            Ok(Json::Null)
        }
        _ => parse_number(b, pos),
    }
}

fn expect(b: &[u8], pos: &mut usize, lit: &str) -> Result<()> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(())
    } else {
        bail!("expected {lit} at byte {pos}")
    }
}

fn parse_object(b: &[u8], pos: &mut usize) -> Result<Json> {
    *pos += 1; // {
    let mut map = BTreeMap::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b'}' {
        *pos += 1;
        return Ok(Json::Obj(map));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        if *pos >= b.len() || b[*pos] != b':' {
            bail!("expected ':' at byte {pos}");
        }
        *pos += 1;
        let val = parse_value(b, pos)?;
        map.insert(key, val);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(map));
            }
            _ => bail!("expected ',' or '}}' at byte {pos}"),
        }
    }
}

fn parse_array(b: &[u8], pos: &mut usize) -> Result<Json> {
    *pos += 1; // [
    let mut arr = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b']' {
        *pos += 1;
        return Ok(Json::Arr(arr));
    }
    loop {
        arr.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(arr));
            }
            _ => bail!("expected ',' or ']' at byte {pos}"),
        }
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String> {
    if *pos >= b.len() || b[*pos] != b'"' {
        bail!("expected string at byte {pos}");
    }
    *pos += 1;
    let mut out = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        if *pos + 5 > b.len() {
                            bail!("truncated \\u escape");
                        }
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])?;
                        let code = u32::from_str_radix(hex, 16)?;
                        out.push(char::from_u32(code).context("bad \\u escape")?);
                        *pos += 4;
                    }
                    _ => bail!("bad escape at byte {pos}"),
                }
                *pos += 1;
            }
            c => {
                // Copy raw UTF-8 bytes through.
                let start = *pos;
                let len = utf8_len(c);
                out.push_str(std::str::from_utf8(&b[start..start + len])?);
                *pos += len;
            }
        }
    }
    bail!("unterminated string")
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let s = std::str::from_utf8(&b[start..*pos])?;
    Ok(Json::Num(s.parse::<f64>().with_context(|| format!("bad number {s:?}"))?))
}

// ---------------------------------------------------------------------------
// Typed manifest views
// ---------------------------------------------------------------------------

/// One artifact's metadata.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub path: String,
    pub num_inputs: usize,
    /// (dtype, shape) per input.
    pub input_shapes: Vec<(String, Vec<usize>)>,
}

/// The exported model configuration (mirror of python ModelConfig).
#[derive(Clone, Debug)]
pub struct ModelConfigInfo {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq: usize,
    pub batch: usize,
    pub bpp: f64,
    pub residual_paths: usize,
    pub kd_alpha: f64,
    pub kd_temperature: f64,
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub config: ModelConfigInfo,
    pub preset: String,
    /// (name, shape) in positional order.
    pub teacher_spec: Vec<(String, Vec<usize>)>,
    pub student_spec: Vec<(String, Vec<usize>)>,
    pub student_fp_spec: Vec<(String, Vec<usize>)>,
    pub artifacts: BTreeMap<String, ArtifactInfo>,
    pub teacher_init_dir: String,
}

fn parse_spec(v: &Json) -> Result<Vec<(String, Vec<usize>)>> {
    v.as_arr()?
        .iter()
        .map(|e| {
            let pair = e.as_arr()?;
            let name = pair[0].as_str()?.to_string();
            let shape = pair[1]
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<Vec<_>>>()?;
            Ok((name, shape))
        })
        .collect()
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {:?}", path.as_ref()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let root = parse_json(text)?;
        let cfg = root.get("config")?;
        let config = ModelConfigInfo {
            vocab: cfg.get("vocab")?.as_usize()?,
            d_model: cfg.get("d_model")?.as_usize()?,
            n_layers: cfg.get("n_layers")?.as_usize()?,
            n_heads: cfg.get("n_heads")?.as_usize()?,
            d_ff: cfg.get("d_ff")?.as_usize()?,
            seq: cfg.get("seq")?.as_usize()?,
            batch: cfg.get("batch")?.as_usize()?,
            bpp: cfg.get("bpp")?.as_f64()?,
            residual_paths: cfg.get("residual_paths")?.as_usize()?,
            kd_alpha: cfg.get("kd_alpha")?.as_f64()?,
            kd_temperature: cfg.get("kd_temperature")?.as_f64()?,
        };
        let mut artifacts = BTreeMap::new();
        for (name, info) in root.get("artifacts")?.as_obj()? {
            let shapes = info
                .get("input_shapes")?
                .as_arr()?
                .iter()
                .map(|e| {
                    let pair = e.as_arr()?;
                    let dt = pair[0].as_str()?.to_string();
                    let shape = pair[1]
                        .as_arr()?
                        .iter()
                        .map(|d| d.as_usize())
                        .collect::<Result<Vec<_>>>()?;
                    Ok((dt, shape))
                })
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactInfo {
                    path: info.get("path")?.as_str()?.to_string(),
                    num_inputs: info.get("num_inputs")?.as_usize()?,
                    input_shapes: shapes,
                },
            );
        }
        Ok(Self {
            config,
            preset: root.get("preset")?.as_str()?.to_string(),
            teacher_spec: parse_spec(root.get("teacher_spec")?)?,
            student_spec: parse_spec(root.get("student_spec")?)?,
            student_fp_spec: parse_spec(root.get("student_fp_spec")?)?,
            artifacts,
            teacher_init_dir: root.get("teacher_init_dir")?.as_str()?.to_string(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse_json("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse_json("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(parse_json("true").unwrap(), Json::Bool(true));
        assert_eq!(parse_json("null").unwrap(), Json::Null);
        assert_eq!(parse_json("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse_json(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b").unwrap().as_str().unwrap(), "c");
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(parse_json("\"\\u00e9\"").unwrap(), Json::Str("é".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_json("{").is_err());
        assert!(parse_json("[1,]").is_err());
        assert!(parse_json("1 2").is_err());
    }

    #[test]
    fn parse_manifest_document() {
        let doc = r#"{
          "config": {"vocab": 256, "d_model": 64, "n_layers": 2, "n_heads": 2,
                     "d_ff": 172, "seq": 32, "batch": 4, "bpp": 1.0,
                     "residual_paths": 2, "fp_latent": false,
                     "kd_alpha": 0.5, "kd_temperature": 2.0},
          "preset": "tiny",
          "teacher_spec": [["embed", [256, 64]], ["head", [256, 64]]],
          "student_spec": [["embed", [256, 64]]],
          "student_fp_spec": [["embed", [256, 64]]],
          "artifacts": {
            "teacher_eval": {"path": "teacher_eval.hlo.txt", "num_inputs": 2,
                             "input_shapes": [["float32", [256, 64]], ["int32", [4, 33]]]}
          },
          "teacher_init_dir": "params"
        }"#;
        let m = Manifest::parse(doc).unwrap();
        assert_eq!(m.config.vocab, 256);
        assert_eq!(m.teacher_spec.len(), 2);
        let a = &m.artifacts["teacher_eval"];
        assert_eq!(a.num_inputs, 2);
        assert_eq!(a.input_shapes[1].0, "int32");
    }
}
