//! Synthetic corpus generation and batching (the WikiText-2 substitute).
//!
//! The evaluation corpus must exercise the same code path as the paper's
//! PPL measurements: a token stream with heavy-tailed unigram statistics and
//! learnable sequential structure. We generate a second-order Markov chain
//! over a Zipfian vocabulary: unigram frequencies follow Zipf(s≈1.1) like
//! natural text, and each (prev, cur) context deterministically biases the
//! next-token distribution, giving a transformer signal to learn (PPL well
//! below the unigram entropy) while remaining fully synthetic and seedable.

use crate::rng::{Pcg64, ZipfSampler};

/// Corpus generator configuration.
#[derive(Clone, Debug)]
pub struct CorpusConfig {
    pub vocab: usize,
    /// Zipf exponent of the unigram distribution.
    pub zipf_s: f64,
    /// Markov interpolation: probability of sampling from the context-
    /// dependent component rather than the unigram background.
    pub structure: f64,
    /// Branching factor of each context's preferred continuation set.
    pub branch: usize,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        Self { vocab: 512, zipf_s: 1.1, structure: 0.75, branch: 4 }
    }
}

/// Deterministic synthetic token stream.
pub struct Corpus {
    cfg: CorpusConfig,
    zipf: ZipfSampler,
    rng: Pcg64,
    prev: usize,
    cur: usize,
    /// Hash salt fixing the corpus's latent transition structure.
    salt: u64,
}

impl Corpus {
    /// New stream where both the latent transition structure (salt) and the
    /// sampling stream derive from `seed`.
    pub fn new(cfg: CorpusConfig, seed: u64) -> Self {
        Self::with_salt(cfg, seed, seed)
    }

    /// New stream over an **existing language**: `salt_seed` fixes the
    /// transition structure, `stream_seed` the sampling randomness. Train
    /// and held-out eval streams share `salt_seed` and differ in
    /// `stream_seed` — same distribution, disjoint samples.
    pub fn with_salt(cfg: CorpusConfig, salt_seed: u64, stream_seed: u64) -> Self {
        let zipf = ZipfSampler::new(cfg.vocab, cfg.zipf_s);
        let salt = Pcg64::seed(salt_seed).next_u64();
        let mut rng = Pcg64::seed(stream_seed ^ 0x9bd1_e7a3_55aa_cc11);
        let prev = zipf.sample(&mut rng);
        let cur = zipf.sample(&mut rng);
        Self { cfg, zipf, rng, prev, cur, salt }
    }

    /// The k-th preferred continuation of context (a, b): a fixed hash of
    /// the context mapped through the Zipf quantile, so the structured
    /// component is stable across the stream *and* preserves the
    /// heavy-tailed unigram marginal.
    #[inline]
    fn preferred(&self, a: usize, b: usize, k: usize) -> usize {
        let mut h = self.salt ^ (a as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
        h ^= (b as u64).wrapping_mul(0xc2b2_ae3d_27d4_eb4f);
        h ^= (k as u64).wrapping_mul(0x1656_67b1_9e37_79f9);
        h ^= h >> 29;
        h = h.wrapping_mul(0xbf58_476d_1ce4_e5b9);
        h ^= h >> 32;
        let u = (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        self.zipf.quantile(u)
    }

    /// Next token.
    pub fn next_token(&mut self) -> usize {
        let t = if self.rng.uniform() < self.cfg.structure {
            let k = self.rng.below(self.cfg.branch as u64) as usize;
            self.preferred(self.prev, self.cur, k)
        } else {
            self.zipf.sample(&mut self.rng)
        };
        self.prev = self.cur;
        self.cur = t;
        t
    }

    /// Fill a `[batch, seq+1]` token block: inputs are `[.., :seq]`, labels
    /// `[.., 1..]` — the standard next-token setup the L2 train step expects.
    pub fn next_block(&mut self, batch: usize, seq: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(batch * (seq + 1));
        for _ in 0..batch * (seq + 1) {
            out.push(self.next_token() as i32);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_in_vocab() {
        let mut c = Corpus::new(CorpusConfig::default(), 1);
        for _ in 0..10_000 {
            assert!(c.next_token() < 512);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Corpus::new(CorpusConfig::default(), 7);
        let mut b = Corpus::new(CorpusConfig::default(), 7);
        for _ in 0..1000 {
            assert_eq!(a.next_token(), b.next_token());
        }
    }

    #[test]
    fn unigram_distribution_is_heavy_tailed() {
        let mut c = Corpus::new(CorpusConfig::default(), 3);
        let mut counts = vec![0u32; 512];
        for _ in 0..200_000 {
            counts[c.next_token()] += 1;
        }
        counts.sort_unstable_by(|a, b| b.cmp(a));
        // Top-16 tokens should carry a large share but not everything.
        let head: u32 = counts[..16].iter().sum();
        assert!(head > 40_000, "head={head}");
        assert!(head < 190_000, "head={head}");
    }

    #[test]
    fn structure_is_learnable() {
        // Bigram predictability: with structure=0.75 and branch=4, knowing
        // (prev, cur) should concentrate the next token into ≤ branch
        // preferred values far above chance.
        let mut c = Corpus::new(CorpusConfig::default(), 5);
        let mut hits = 0usize;
        let mut total = 0usize;
        for _ in 0..20_000 {
            let (a, b) = (c.prev, c.cur);
            let preferred: Vec<usize> = (0..c.cfg.branch).map(|k| c.preferred(a, b, k)).collect();
            let t = c.next_token();
            if preferred.contains(&t) {
                hits += 1;
            }
            total += 1;
        }
        let rate = hits as f64 / total as f64;
        assert!(rate > 0.6, "preferred-continuation rate={rate}");
    }

    #[test]
    fn block_shape() {
        let mut c = Corpus::new(CorpusConfig::default(), 9);
        let block = c.next_block(4, 32);
        assert_eq!(block.len(), 4 * 33);
        assert!(block.iter().all(|&t| t >= 0 && (t as usize) < 512));
    }
}
