//! Scalar binarization primitives and the distortion geometry of §4.2.

use crate::linalg::{norm1, norm2, Mat};

/// Optimal rank-respecting binarization of a vector: `u ≈ α·sign(u)` with
/// `α* = ‖u‖₁ / r` (Eq. 12 in Appendix A.1).
#[derive(Clone, Debug)]
pub struct BinVec {
    /// Signs in {±1}.
    pub signs: Vec<f32>,
    /// Optimal scalar scale α*.
    pub alpha: f32,
}

impl BinVec {
    pub fn reconstruct(&self) -> Vec<f32> {
        self.signs.iter().map(|s| s * self.alpha).collect()
    }
}

/// `argmin_α ‖u − α·sign(u)‖²`.
pub fn binarize_optimal(u: &[f32]) -> BinVec {
    let r = u.len() as f64;
    let alpha = (norm1(u) / r) as f32;
    let signs = u.iter().map(|&x| if x < 0.0 { -1.0 } else { 1.0 }).collect();
    BinVec { signs, alpha }
}

/// Local distortion coefficient λ(u) = 1 − (‖u‖₁/‖u‖₂)²/r
/// (Lemma 4.2). Returns 0 for the zero vector (nothing to lose).
pub fn local_distortion(u: &[f32]) -> f64 {
    let n2 = norm2(u);
    if n2 == 0.0 {
        return 0.0;
    }
    let r = u.len() as f64;
    let ratio = norm1(u) / n2;
    (1.0 - ratio * ratio / r).max(0.0)
}

/// λ for every row of a latent factor — the series plotted in Fig. 3.
pub fn row_distortions(u: &Mat) -> Vec<f64> {
    (0..u.rows()).map(|i| local_distortion(u.row(i))).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn alpha_is_mean_absolute_value() {
        let u = [1.0f32, -2.0, 3.0, -4.0];
        let b = binarize_optimal(&u);
        assert!((b.alpha - 2.5).abs() < 1e-6);
        assert_eq!(b.signs, vec![1.0, -1.0, 1.0, -1.0]);
    }

    #[test]
    fn optimal_alpha_minimizes_error() {
        let mut rng = Pcg64::seed(1);
        let mut u = vec![0.0f32; 64];
        rng.fill_normal(&mut u);
        let b = binarize_optimal(&u);
        let err = |alpha: f32| -> f64 {
            u.iter()
                .zip(&b.signs)
                .map(|(x, s)| ((x - alpha * s) as f64).powi(2))
                .sum()
        };
        let best = err(b.alpha);
        for d in [-0.05f32, 0.05] {
            assert!(err(b.alpha + d) >= best);
        }
    }

    #[test]
    fn distortion_equals_normalized_error() {
        // λ(u)·‖u‖² must equal the actual optimal quantization error (Eq 13).
        let mut rng = Pcg64::seed(2);
        let mut u = vec![0.0f32; 128];
        rng.fill_normal(&mut u);
        let b = binarize_optimal(&u);
        let err: f64 = u
            .iter()
            .zip(&b.reconstruct())
            .map(|(x, y)| ((x - y) as f64).powi(2))
            .sum();
        let lam = local_distortion(&u);
        let n2 = crate::linalg::dot(&u, &u);
        assert!((lam * n2 - err).abs() / err < 1e-4, "{} vs {}", lam * n2, err);
    }

    #[test]
    fn distortion_extremes() {
        // Axis-aligned spike: λ → 1 − 1/r (worst case, ≈1 for large r).
        let mut spike = vec![0.0f32; 100];
        spike[3] = 5.0;
        let lam = local_distortion(&spike);
        assert!((lam - 0.99).abs() < 1e-6, "spike λ={lam}");
        // Dense ±c vector: λ = 0 (perfectly binarizable).
        let dense = vec![0.7f32; 100];
        assert!(local_distortion(&dense) < 1e-6);
    }

    #[test]
    fn gaussian_vector_near_gaussian_limit() {
        // E[λ] → 1 − 2/π ≈ 0.3634 for gaussian coordinates (Theorem 4.4).
        let mut rng = Pcg64::seed(3);
        let mut acc = 0.0;
        let trials = 200;
        for _ in 0..trials {
            let mut u = vec![0.0f32; 512];
            rng.fill_normal(&mut u);
            acc += local_distortion(&u);
        }
        let mean = acc / trials as f64;
        let limit = 1.0 - 2.0 / std::f64::consts::PI;
        assert!((mean - limit).abs() < 0.01, "mean={mean} limit={limit}");
    }

    #[test]
    fn zero_vector_is_harmless() {
        assert_eq!(local_distortion(&[0.0; 8]), 0.0);
        let b = binarize_optimal(&[0.0; 8]);
        assert_eq!(b.alpha, 0.0);
    }

    #[test]
    fn row_distortions_in_unit_interval() {
        let mut rng = Pcg64::seed(4);
        let m = Mat::gaussian(50, 32, &mut rng);
        for lam in row_distortions(&m) {
            assert!((0.0..=1.0).contains(&lam));
        }
    }
}
