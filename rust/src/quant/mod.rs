//! Quantization baselines the paper compares against (Table 1) plus the
//! scalar binarization primitives shared with the LittleBit core.
//!
//! Implemented from the methods' defining equations (and App. H memory
//! formulas):
//!
//! * [`binarize_optimal`] — `min_α ‖u − α·sign(u)‖²` with `α* = ‖u‖₁/r`
//!   (Lemma 4.2 / Eq. 12) and the local distortion λ(u) it induces.
//! * [`rtn`] — round-to-nearest group quantization (k-bit, group 128): the
//!   GPTQ / EfficientQAT storage-format stand-in for reconstruction-error
//!   comparisons.
//! * [`onebit`] — OneBit (Xu et al., 2024): `Ŵ = diag(a)·sign(W)·diag(b)`
//!   with scale fitting by alternating least squares.
//! * [`billm_style`] — salient-column split binarization (BiLLM-like):
//!   top-c salient columns get second-order (residual) binarization, the
//!   rest first-order, per-row scales.
//! * [`arb_style`] — alternating refined binarization (ARB-LLM-like):
//!   iteratively refit row+column scales and the binary code.
//! * [`tiny_rank_fp16`] — Strategy A: truncated SVD stored at FP16.
//!
//! Since PR 5 every method — LittleBit-2 included — also implements the
//! method-generic [`Compressor`] trait (weight in, servable
//! [`crate::model::MethodLayer`] out); [`MethodSpec`] is the cloneable
//! registry form behind `compress --method ...` and the `eval` sweep. See
//! ARCHITECTURE.md "Method registry".

mod baselines;
mod binary;
mod compressor;

pub use baselines::{arb_style, billm_style, onebit, rtn, tiny_rank_fp16, QuantResult};
pub use binary::{binarize_optimal, local_distortion, row_distortions, BinVec};
pub use compressor::{Compressor, LittleBit2Compressor, MethodSpec, METHOD_NAMES};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::rng::Pcg64;
    use crate::spectral::{synth_weight, SynthSpec};

    /// Every baseline must beat the trivial zero approximation on a
    /// heavy-tailed synthetic weight and report a positive bit count.
    #[test]
    fn all_baselines_beat_zero_and_report_storage() {
        let mut rng = Pcg64::seed(7);
        let spec = SynthSpec { rows: 128, cols: 128, gamma: 0.3, coherence: 0.5, scale: 1.0 };
        let w = synth_weight(&spec, &mut rng);
        let zero_mse = w.mse(&Mat::zeros(128, 128));

        for (name, res) in [
            ("rtn4", rtn(&w, 4, 128)),
            ("onebit", onebit(&w, 30)),
            ("billm", billm_style(&w, 16, 64)),
            ("arb", arb_style(&w, 15)),
            ("tiny", tiny_rank_fp16(&w, 8, &mut rng)),
        ] {
            let mse = res.reconstruction.mse(&w);
            assert!(mse < zero_mse, "{name}: mse {mse} !< zero {zero_mse}");
            assert!(res.bits > 0, "{name} reports no storage");
        }
        // 2-bit RTN on spiky heavy-tailed weights can be *worse than
        // zeroing* — the collapse Table 1 shows for GPTQ-2bit (PPL 52-1480).
        let rtn2 = rtn(&w, 2, 128).reconstruction.mse(&w);
        assert!(rtn2 < 4.0 * zero_mse, "rtn2 unbounded: {rtn2}");
    }

    /// More precision must not hurt RTN.
    #[test]
    fn rtn_error_monotone_in_bits() {
        let mut rng = Pcg64::seed(8);
        let w = Mat::gaussian(64, 128, &mut rng);
        let e2 = rtn(&w, 2, 64).reconstruction.mse(&w);
        let e4 = rtn(&w, 4, 64).reconstruction.mse(&w);
        let e8 = rtn(&w, 8, 64).reconstruction.mse(&w);
        assert!(e4 < e2 && e8 < e4, "e2={e2} e4={e4} e8={e8}");
    }
}
