//! Baseline quantizers for the Table 1 comparison.
//!
//! These are *reconstruction-level* reimplementations: each returns the
//! dequantized weight and its exact storage cost per App. H, so the break-even
//! and main-table benches can compare methods at matched bit budgets without
//! the authors' CUDA codebases.

use crate::linalg::{f16_round, svd_randomized, Mat};
use crate::memory;
use crate::rng::Pcg64;

/// Output of a baseline quantizer.
#[derive(Clone, Debug)]
pub struct QuantResult {
    /// Dequantized (reconstructed) weight.
    pub reconstruction: Mat,
    /// Exact storage in bits per the method's App. H formula.
    pub bits: u64,
    /// Method label for reports.
    pub method: &'static str,
}

impl QuantResult {
    /// Effective bits-per-parameter.
    pub fn bpp(&self) -> f64 {
        self.bits as f64 / (self.reconstruction.rows() * self.reconstruction.cols()) as f64
    }
}

/// Round-to-nearest k-bit group quantization (GPTQ/EfficientQAT storage
/// format): per group of `group` consecutive in-row weights, an FP16
/// scale+zero pair; codes in `[0, 2^k)`.
pub fn rtn(w: &Mat, k: u32, group: usize) -> QuantResult {
    assert!(k >= 1 && k <= 8);
    let levels = (1u32 << k) - 1;
    let mut out = Mat::zeros(w.rows(), w.cols());
    for i in 0..w.rows() {
        let row = w.row(i);
        for g0 in (0..w.cols()).step_by(group) {
            let g1 = (g0 + group).min(w.cols());
            let chunk = &row[g0..g1];
            let mut lo = f32::INFINITY;
            let mut hi = f32::NEG_INFINITY;
            for &v in chunk {
                lo = lo.min(v);
                hi = hi.max(v);
            }
            let scale = f16_round(if hi > lo { (hi - lo) / levels as f32 } else { 1.0 });
            let zero = f16_round(lo);
            for (j, &v) in chunk.iter().enumerate() {
                let q = (((v - zero) / scale).round()).clamp(0.0, levels as f32);
                *out.at_mut(i, g0 + j) = zero + q * scale;
            }
        }
    }
    QuantResult {
        reconstruction: out,
        bits: memory::rtn_bits(w.rows(), w.cols(), k, group),
        method: "rtn",
    }
}

/// The OneBit ALS core: fit `|W_ij| ≈ a_i·b_j` by alternating least
/// squares and return the FP16-rounded `(a, b)` scale vectors. Shared by
/// the reconstruction-level [`onebit`] baseline and the serving-form
/// `quant::Compressor` implementation, so both produce identical numbers.
pub(crate) fn onebit_scales(w: &Mat, als_iters: usize) -> (Vec<f32>, Vec<f32>) {
    let (m, n) = w.shape();
    let absw = w.abs();
    // ALS for rank-1 non-negative factorization of |W|.
    let mut a = vec![1.0f32; m];
    let mut b: Vec<f32> = (0..n)
        .map(|j| absw.col(j).iter().sum::<f32>() / m as f32)
        .collect();
    for _ in 0..als_iters {
        // a_i = Σ_j |W_ij| b_j / Σ_j b_j²
        let bb: f64 = b.iter().map(|&x| (x as f64).powi(2)).sum();
        for i in 0..m {
            let num: f64 = absw
                .row(i)
                .iter()
                .zip(&b)
                .map(|(&x, &y)| x as f64 * y as f64)
                .sum();
            a[i] = (num / bb.max(1e-30)) as f32;
        }
        let aa: f64 = a.iter().map(|&x| (x as f64).powi(2)).sum();
        for (j, bj) in b.iter_mut().enumerate() {
            let mut num = 0.0f64;
            for i in 0..m {
                num += absw.at(i, j) as f64 * a[i] as f64;
            }
            *bj = (num / aa.max(1e-30)) as f32;
        }
    }
    for v in a.iter_mut() {
        *v = f16_round(*v);
    }
    for v in b.iter_mut() {
        *v = f16_round(*v);
    }
    (a, b)
}

/// OneBit: `Ŵ = diag(a) · sign(W) · diag(b)` — a 1-bit sign matrix plus FP16
/// row/column value vectors, fitted by alternating least squares on the
/// element-wise model `|W_ij| ≈ a_i·b_j` (the SVID of the OneBit paper).
pub fn onebit(w: &Mat, als_iters: usize) -> QuantResult {
    let (m, n) = w.shape();
    let (a, b) = onebit_scales(w, als_iters);
    let recon = w.signum().scale_rows(&a).scale_cols(&b);
    QuantResult {
        reconstruction: recon,
        bits: memory::onebit_bits(m, n),
        method: "onebit",
    }
}

/// BiLLM-style salient-column split binarization.
///
/// Salient columns (top `c` by energy) receive *second-order* binarization
/// (binary base + binary residual, two per-row scales); the remainder
/// receives first-order binarization with per-row scales over `block`-column
/// blocks. Metadata (bitmap) costs are charged per App. H Eq. 23.
pub fn billm_style(w: &Mat, c: usize, block: usize) -> QuantResult {
    let (m, n) = w.shape();
    let c = c.min(n);
    // Rank columns by energy.
    let mut energy: Vec<(usize, f64)> = (0..n)
        .map(|j| {
            let col = w.col(j);
            (j, crate::linalg::dot(&col, &col))
        })
        .collect();
    energy.sort_by(|x, y| y.1.partial_cmp(&x.1).expect("finite"));
    let salient: Vec<usize> = energy[..c].iter().map(|&(j, _)| j).collect();
    let mut is_salient = vec![false; n];
    for &j in &salient {
        is_salient[j] = true;
    }

    let mut out = Mat::zeros(m, n);
    for i in 0..m {
        let row = w.row(i).to_vec();
        // Second-order on salient entries of this row.
        let sal: Vec<f32> = salient.iter().map(|&j| row[j]).collect();
        if !sal.is_empty() {
            let b1 = super::binarize_optimal(&sal);
            let resid: Vec<f32> = sal
                .iter()
                .zip(&b1.signs)
                .map(|(x, s)| x - b1.alpha * s)
                .collect();
            let b2 = super::binarize_optimal(&resid);
            let a1 = f16_round(b1.alpha);
            let a2 = f16_round(b2.alpha);
            for (k, &j) in salient.iter().enumerate() {
                *out.at_mut(i, j) = a1 * b1.signs[k] + a2 * b2.signs[k];
            }
        }
        // First-order on the rest, block-wise scales.
        let rest: Vec<usize> = (0..n).filter(|&j| !is_salient[j]).collect();
        for blk in rest.chunks(block) {
            let vals: Vec<f32> = blk.iter().map(|&j| row[j]).collect();
            let b = super::binarize_optimal(&vals);
            let alpha = f16_round(b.alpha);
            for (k, &j) in blk.iter().enumerate() {
                *out.at_mut(i, j) = alpha * b.signs[k];
            }
        }
    }
    QuantResult {
        reconstruction: out,
        bits: memory::billm_bits(m, n, c, block),
        method: "billm",
    }
}

/// The ARB alternating-refinement core: return the FP16-rounded `(a, b)`
/// scale vectors of `Ŵ = diag(a)·sign(W)·diag(b)` after `iters` rounds of
/// alternating least-squares scale refits. Shared by the
/// reconstruction-level [`arb_style`] baseline and the serving-form
/// `quant::Compressor` implementation.
pub(crate) fn arb_scales(w: &Mat, iters: usize) -> (Vec<f32>, Vec<f32>) {
    let (m, n) = w.shape();
    let mut a = vec![0.0f32; m];
    for (i, ai) in a.iter_mut().enumerate() {
        *ai = (crate::linalg::norm1(w.row(i)) / n as f64) as f32;
    }
    let mut b = vec![1.0f32; n];
    // B = sign(W) is optimal given positive scales and stays fixed:
    // sign(W_ij / (a_i b_j)) = sign(W_ij) for positive scales, so ARB's
    // refinement bites via the row/column scale updates below.
    let signs = w.signum();
    for _ in 0..iters {
        // B = sign(W) is optimal given positive scales; keep but refit scales
        // against the current residual structure.
        // a_i = Σ_j W_ij·s_ij·b_j / Σ_j b_j²  (least squares row scale)
        let bb: f64 = b.iter().map(|&x| (x as f64).powi(2)).sum();
        for i in 0..m {
            let mut num = 0.0f64;
            for j in 0..n {
                num += w.at(i, j) as f64 * signs.at(i, j) as f64 * b[j] as f64;
            }
            a[i] = (num / bb.max(1e-30)).max(0.0) as f32;
        }
        // b_j = Σ_i W_ij·s_ij·a_i / Σ_i a_i²
        let aa: f64 = a.iter().map(|&x| (x as f64).powi(2)).sum();
        for j in 0..n {
            let mut num = 0.0f64;
            for i in 0..m {
                num += w.at(i, j) as f64 * signs.at(i, j) as f64 * a[i] as f64;
            }
            b[j] = (num / aa.max(1e-30)).max(0.0) as f32;
        }
    }
    for v in a.iter_mut() {
        *v = f16_round(*v);
    }
    for v in b.iter_mut() {
        *v = f16_round(*v);
    }
    (a, b)
}

/// ARB-LLM-style alternating refined binarization (RC variant):
/// `Ŵ = diag(a) · B · diag(b)` with B=sign refit against the scaled
/// residual each iteration — alternate (B | a | b) updates to a local optimum.
pub fn arb_style(w: &Mat, iters: usize) -> QuantResult {
    let (m, n) = w.shape();
    let (a, b) = arb_scales(w, iters);
    let recon = w.signum().scale_rows(&a).scale_cols(&b);
    QuantResult {
        reconstruction: recon,
        bits: memory::arb_bits(m, n, 128, 128),
        method: "arb",
    }
}

/// The Strategy A core: rank-`rank` randomized SVD (oversample/power
/// constants fixed here, nowhere else) split into balanced factors and
/// rounded to FP16 — `Ŵ = U·Vᵀ`. Shared by the reconstruction-level
/// [`tiny_rank_fp16`] baseline and the serving-form `quant::Compressor`
/// implementation, so the two views cannot drift.
pub(crate) fn tiny_rank_factors(w: &Mat, rank: usize, rng: &mut Pcg64) -> (Mat, Mat) {
    let svd = svd_randomized(w, rank, 8.min(rank + 4), 2, rng);
    let (u, v) = svd.split_factors();
    (u.to_f16_precision(), v.to_f16_precision())
}

/// Strategy A: truncated SVD stored in FP16 — `U_r·diag(σ)·V_rᵀ` with all
/// three factors rounded to half precision.
pub fn tiny_rank_fp16(w: &Mat, rank: usize, rng: &mut Pcg64) -> QuantResult {
    let (u, v) = tiny_rank_factors(w, rank, rng);
    QuantResult {
        reconstruction: u.matmul_t(&v),
        bits: memory::tiny_rank_fp16_bits(w.rows(), w.cols(), rank),
        method: "tiny_rank_fp16",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rtn_is_exact_for_two_level_rows() {
        // A row containing only two values is exactly representable at 1 bit.
        let w = Mat::from_vec(1, 8, vec![-1., 1., -1., 1., 1., -1., 1., -1.]);
        let q = rtn(&w, 1, 8);
        assert!(q.reconstruction.fro_dist2(&w) < 1e-6);
    }

    #[test]
    fn onebit_exact_on_separable_magnitudes() {
        // W = a·bᵀ ⊙ signs is exactly representable by OneBit.
        let mut rng = Pcg64::seed(1);
        let (m, n) = (24, 18);
        let a: Vec<f32> = (0..m).map(|i| 0.5 + 0.05 * i as f32).collect();
        let b: Vec<f32> = (0..n).map(|j| 1.0 + 0.1 * j as f32).collect();
        let signs = Mat::gaussian(m, n, &mut rng).signum();
        let w = signs.scale_rows(&a).scale_cols(&b);
        let q = onebit(&w, 50);
        assert!(
            q.reconstruction.fro_dist2(&w) / w.fro_norm().powi(2) < 1e-4,
            "rel={}",
            q.reconstruction.fro_dist2(&w) / w.fro_norm().powi(2)
        );
    }

    #[test]
    fn onebit_beats_naive_sign_times_mean() {
        let mut rng = Pcg64::seed(2);
        let w = Mat::gaussian(64, 64, &mut rng).scale_rows(
            &(0..64).map(|i| 1.0 + i as f32 * 0.1).collect::<Vec<_>>(),
        );
        let q = onebit(&w, 30);
        // Naive: sign(W) * global mean |W|.
        let mean = w.l1_norm() as f32 / (64.0 * 64.0);
        let naive = w.signum().scale(mean);
        assert!(q.reconstruction.fro_dist2(&w) < naive.fro_dist2(&w));
    }

    #[test]
    fn billm_salient_columns_get_lower_error() {
        let mut rng = Pcg64::seed(3);
        // Construct weight with 8 high-energy columns.
        let mut w = Mat::gaussian(64, 96, &mut rng);
        for j in 0..8 {
            for i in 0..64 {
                *w.at_mut(i, j) *= 8.0;
            }
        }
        let q = billm_style(&w, 8, 32);
        // Per-column relative error: salient should beat non-salient.
        let col_err = |j: usize| {
            let mut num = 0.0f64;
            let mut den = 0.0f64;
            for i in 0..64 {
                num += ((w.at(i, j) - q.reconstruction.at(i, j)) as f64).powi(2);
                den += (w.at(i, j) as f64).powi(2);
            }
            num / den
        };
        let sal: f64 = (0..8).map(col_err).sum::<f64>() / 8.0;
        let rest: f64 = (8..96).map(col_err).sum::<f64>() / 88.0;
        assert!(sal < rest, "salient={sal} rest={rest}");
    }

    #[test]
    fn arb_refinement_reduces_error_vs_single_shot() {
        let mut rng = Pcg64::seed(4);
        let w = Mat::gaussian(48, 48, &mut rng)
            .scale_rows(&(0..48).map(|i| 0.2 + 0.1 * i as f32).collect::<Vec<_>>())
            .scale_cols(&(0..48).map(|j| 0.5 + 0.05 * j as f32).collect::<Vec<_>>());
        let one = arb_style(&w, 1);
        let many = arb_style(&w, 20);
        assert!(
            many.reconstruction.fro_dist2(&w) <= one.reconstruction.fro_dist2(&w) * 1.001
        );
    }

    #[test]
    fn tiny_rank_fp16_matches_eckart_young_up_to_f16() {
        let mut rng = Pcg64::seed(5);
        let q1 = crate::linalg::random_orthogonal(64, &mut rng);
        let q2 = crate::linalg::random_orthogonal(64, &mut rng);
        let s: Vec<f32> = (1..=64).map(|k| (k as f32).powf(-0.6)).collect();
        let w = q1.scale_cols(&s).matmul_t(&q2);
        let r = 8;
        let q = tiny_rank_fp16(&w, r, &mut rng);
        let opt: f64 = s[r..].iter().map(|&x| (x as f64).powi(2)).sum();
        let err = q.reconstruction.fro_dist2(&w);
        assert!(err < opt * 1.1 + 1e-6, "err={err} opt={opt}");
    }

    #[test]
    fn bpp_reporting_is_sane() {
        let mut rng = Pcg64::seed(6);
        let w = Mat::gaussian(256, 256, &mut rng);
        assert!((rtn(&w, 2, 128).bpp() - 2.25).abs() < 0.01);
        let ob = onebit(&w, 5).bpp();
        assert!(ob > 1.0 && ob < 1.2, "onebit bpp={ob}");
    }
}
