//! The method-generic compression engine: one [`Compressor`] trait from
//! quantizer to artifact to server.
//!
//! Every quantizer the repo reproduces — LittleBit-2 and the five Table 1
//! baselines — implements the same contract: dense weight in,
//! [`MethodLayer`] out. The produced layer is the *serving* form (packed
//! sign planes, scale vectors, or FP factors), so anything a compressor
//! emits can be chained into a [`crate::model::MethodStack`], streamed
//! into a `.lb2` v2 artifact, and served by the batching worker pool —
//! the apples-to-apples fidelity/throughput pipeline behind the paper's
//! baseline table (OneBit, arXiv:2402.11295; BTC-LLM, arXiv:2506.12040).
//!
//! [`MethodSpec`] is the cloneable configuration form the job scheduler
//! and CLI carry; [`MethodSpec::compressor`] instantiates the trait
//! object, and [`MethodSpec::parse`] is the CLI registry
//! (`compress --method littlebit2|onebit|rtn|billm|arb|tinyrank`).
//!
//! Determinism: every compressor is a pure function of `(w, rng)` — pool
//! size never changes an output bit (the littlebit pipeline inherits the
//! PR 4 pooled-linalg guarantee; the baselines are serial numerics).

use super::baselines::{arb_scales, billm_style, onebit_scales, rtn, tiny_rank_factors};
use crate::linalg::Mat;
use crate::littlebit::{compress_pipeline, CompressionConfig, InitStrategy};
use crate::memory;
use crate::model::{DenseScaledLayer, LowRankFpLayer, MethodLayer, SignScaledLayer};
use crate::packing::BitMatrix;
use crate::parallel::Pool;
use crate::rng::Pcg64;
use anyhow::{bail, Result};

/// One compression method, end to end: weight matrix in, serving-form
/// [`MethodLayer`] out.
///
/// # Examples
///
/// ```
/// use littlebit2::quant::MethodSpec;
/// use littlebit2::parallel::Pool;
/// use littlebit2::rng::Pcg64;
/// use littlebit2::spectral::{synth_weight, SynthSpec};
///
/// let mut rng = Pcg64::seed(0);
/// let w = synth_weight(&SynthSpec { rows: 64, cols: 64, ..Default::default() }, &mut rng);
/// let compressor = MethodSpec::OneBit { als_iters: 10 }.compressor();
/// let layer = compressor.compress_layer(&w, Pool::serial(), &mut rng).unwrap();
/// assert_eq!((layer.d_out(), layer.d_in()), (64, 64));
/// assert!(layer.bpp() < 1.6, "onebit is a ~1-bit method");
/// ```
pub trait Compressor: Send + Sync {
    /// Stable method name — the `.lb2` v2 METHOD tag and the CLI
    /// `--method` value.
    fn name(&self) -> &str;

    /// Compress one weight matrix into its serving form. Heavy linalg may
    /// fan out over `pool` (bit-identically for any pool); `rng` drives
    /// any randomized stage (truncated SVD, ITQ init).
    fn compress_layer(&self, w: &Mat, pool: &Pool, rng: &mut Pcg64)
        -> Result<MethodLayer>;
}

/// Cloneable description of a [`Compressor`] — what jobs, artifacts
/// metadata, and the CLI carry around.
#[derive(Clone, Debug, PartialEq)]
pub enum MethodSpec {
    /// LittleBit / LittleBit-2 tri-scale residual path (the strategy knob
    /// inside the config selects standard / rotation / Joint-ITQ).
    LittleBit2(CompressionConfig),
    /// OneBit-style `diag(a)·sign(W)·diag(b)` fitted by ALS (Eq. 22).
    OneBit { als_iters: usize },
    /// k-bit group round-to-nearest (GPTQ/EfficientQAT storage, Eq. 21).
    Rtn { k: u32, group: usize },
    /// BiLLM-style salient/binary split (`salient` top-energy columns get
    /// second-order binarization; `block`-column scales elsewhere, Eq. 23).
    Billm { salient: usize, block: usize },
    /// ARB-LLM-style alternating refined binarization (RC variant, Eq. 24
    /// accounting).
    Arb { iters: usize },
    /// Strategy A: truncated SVD at FP16, rank from the bpp budget.
    TinyRankFp16 { bpp: f64 },
}

/// Every CLI-addressable method name, in the canonical sweep order.
pub const METHOD_NAMES: [&str; 6] = ["littlebit2", "onebit", "rtn", "billm", "arb", "tinyrank"];

impl MethodSpec {
    /// The stable method name (matches [`Compressor::name`]).
    pub fn name(&self) -> &'static str {
        match self {
            MethodSpec::LittleBit2(_) => "littlebit2",
            MethodSpec::OneBit { .. } => "onebit",
            MethodSpec::Rtn { .. } => "rtn",
            MethodSpec::Billm { .. } => "billm",
            MethodSpec::Arb { .. } => "arb",
            MethodSpec::TinyRankFp16 { .. } => "tinyrank",
        }
    }

    /// Whether this method consumes the bpp budget knob (littlebit2 and
    /// tinyrank sweep it; the 1-bit baselines are fixed-rate). The single
    /// source for "should the CLI echo / sweep --bpp".
    pub fn is_budgeted(&self) -> bool {
        matches!(self, MethodSpec::LittleBit2(_) | MethodSpec::TinyRankFp16 { .. })
    }

    /// Residual path count of the produced layer — what the `.lb2` shape
    /// table declares up front (0 for non-packed serving forms).
    pub fn n_paths(&self) -> usize {
        match self {
            MethodSpec::LittleBit2(cfg) => {
                if cfg.residual {
                    2
                } else {
                    1
                }
            }
            _ => 0,
        }
    }

    /// CLI registry: build the spec for `--method name` at a bpp budget.
    /// Method-specific knobs get the paper's defaults (documented in
    /// README "Method registry"); `strategy` only applies to `littlebit2`.
    pub fn parse(name: &str, bpp: f64, strategy: InitStrategy) -> Result<Self> {
        Ok(match name {
            "littlebit2" => MethodSpec::LittleBit2(CompressionConfig {
                bpp,
                strategy,
                residual: true,
                ..Default::default()
            }),
            "onebit" => MethodSpec::OneBit { als_iters: 30 },
            "rtn" => MethodSpec::Rtn { k: 2, group: 128 },
            "billm" => MethodSpec::Billm { salient: 0, block: 64 },
            "arb" => MethodSpec::Arb { iters: 15 },
            "tinyrank" => MethodSpec::TinyRankFp16 { bpp },
            other => bail!("unknown method {other:?}; expected one of {METHOD_NAMES:?}"),
        })
    }

    /// Instantiate the trait object this spec describes.
    pub fn compressor(&self) -> Box<dyn Compressor> {
        match self.clone() {
            MethodSpec::LittleBit2(cfg) => Box::new(LittleBit2Compressor { cfg }),
            MethodSpec::OneBit { als_iters } => Box::new(OneBitCompressor { als_iters }),
            MethodSpec::Rtn { k, group } => Box::new(RtnCompressor { k, group }),
            MethodSpec::Billm { salient, block } => {
                Box::new(BillmCompressor { salient, block })
            }
            MethodSpec::Arb { iters } => Box::new(ArbCompressor { iters }),
            MethodSpec::TinyRankFp16 { bpp } => Box::new(TinyRankCompressor { bpp }),
        }
    }
}

/// LittleBit-2 (and its standard/rotation ablations) as a [`Compressor`]:
/// a thin wrapper over [`compress_pipeline`], so the trait path and the
/// job scheduler's fast path produce bit-identical packed layers.
pub struct LittleBit2Compressor {
    pub cfg: CompressionConfig,
}

impl Compressor for LittleBit2Compressor {
    fn name(&self) -> &str {
        "littlebit2"
    }

    fn compress_layer(&self, w: &Mat, pool: &Pool, rng: &mut Pcg64) -> Result<MethodLayer> {
        Ok(MethodLayer::Packed(compress_pipeline(w, &self.cfg, rng, pool).packed))
    }
}

struct OneBitCompressor {
    als_iters: usize,
}

impl Compressor for OneBitCompressor {
    fn name(&self) -> &str {
        "onebit"
    }

    fn compress_layer(&self, w: &Mat, _pool: &Pool, _rng: &mut Pcg64) -> Result<MethodLayer> {
        let (m, n) = w.shape();
        let (a, b) = onebit_scales(w, self.als_iters);
        // Pack w directly: `from_dense` sets a bit for v ≥ 0, which equals
        // packing signum(w) for every finite weight — no O(N) dense ±1
        // intermediate.
        let layer = SignScaledLayer::try_new(
            BitMatrix::from_dense(w),
            a,
            b,
            memory::onebit_bits(m, n),
        )?;
        Ok(MethodLayer::SignScaled(layer))
    }
}

struct ArbCompressor {
    iters: usize,
}

impl Compressor for ArbCompressor {
    fn name(&self) -> &str {
        "arb"
    }

    fn compress_layer(&self, w: &Mat, _pool: &Pool, _rng: &mut Pcg64) -> Result<MethodLayer> {
        let (m, n) = w.shape();
        let (a, b) = arb_scales(w, self.iters);
        // Pack w directly: `from_dense` sets a bit for v ≥ 0, which equals
        // packing signum(w) for every finite weight — no O(N) dense ±1
        // intermediate.
        let layer = SignScaledLayer::try_new(
            BitMatrix::from_dense(w),
            a,
            b,
            memory::arb_bits(m, n, 128, 128),
        )?;
        Ok(MethodLayer::SignScaled(layer))
    }
}

struct RtnCompressor {
    k: u32,
    group: usize,
}

impl Compressor for RtnCompressor {
    fn name(&self) -> &str {
        "rtn"
    }

    fn compress_layer(&self, w: &Mat, _pool: &Pool, _rng: &mut Pcg64) -> Result<MethodLayer> {
        if !(1..=8).contains(&self.k) {
            bail!("rtn bit width must be in 1..=8, got {}", self.k);
        }
        if self.group == 0 {
            bail!("rtn group size must be positive");
        }
        let q = rtn(w, self.k, self.group);
        Ok(MethodLayer::DenseScaled(DenseScaledLayer::try_new(q.reconstruction, q.bits)?))
    }
}

struct BillmCompressor {
    /// Salient column count; 0 means the default `d_in/8` heuristic.
    salient: usize,
    block: usize,
}

impl Compressor for BillmCompressor {
    fn name(&self) -> &str {
        "billm"
    }

    fn compress_layer(&self, w: &Mat, _pool: &Pool, _rng: &mut Pcg64) -> Result<MethodLayer> {
        if self.block == 0 {
            bail!("billm block size must be positive");
        }
        let c = if self.salient == 0 { (w.cols() / 8).max(1) } else { self.salient };
        let q = billm_style(w, c, self.block);
        Ok(MethodLayer::DenseScaled(DenseScaledLayer::try_new(q.reconstruction, q.bits)?))
    }
}

struct TinyRankCompressor {
    bpp: f64,
}

impl Compressor for TinyRankCompressor {
    fn name(&self) -> &str {
        "tinyrank"
    }

    fn compress_layer(&self, w: &Mat, _pool: &Pool, rng: &mut Pcg64) -> Result<MethodLayer> {
        let (d_out, d_in) = w.shape();
        let rank = memory::tiny_rank_for_budget(d_in, d_out, self.bpp)
            .min(d_in.min(d_out))
            .max(1);
        let (u, v) = tiny_rank_factors(w, rank, rng);
        let layer = LowRankFpLayer::try_new(
            u,
            v.transpose(),
            memory::tiny_rank_fp16_bits(d_in, d_out, rank),
        )?;
        Ok(MethodLayer::LowRankFp(layer))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spectral::{synth_weight, SynthSpec};

    fn weight(seed: u64, rows: usize, cols: usize) -> Mat {
        let mut rng = Pcg64::seed(seed);
        synth_weight(
            &SynthSpec { rows, cols, gamma: 0.3, coherence: 0.6, scale: 1.0 },
            &mut rng,
        )
    }

    /// Every registered method compresses a ragged heavy-tailed weight
    /// into a layer that (a) beats the zero approximation, (b) reports a
    /// plausible bpp, and (c) serves the right shape.
    #[test]
    fn every_method_produces_a_servable_layer() {
        let w = weight(1, 72, 56);
        let zero = w.mse(&Mat::zeros(72, 56));
        for name in METHOD_NAMES {
            let spec = MethodSpec::parse(name, 1.0, InitStrategy::JointItq { iters: 10 })
                .unwrap();
            assert_eq!(spec.name(), name);
            let c = spec.compressor();
            assert_eq!(c.name(), name);
            let layer = c.compress_layer(&w, Pool::serial(), &mut Pcg64::seed(7)).unwrap();
            assert_eq!((layer.d_out(), layer.d_in()), (72, 56), "{name}");
            let mse = layer.reconstruct_on(Pool::serial()).mse(&w);
            // 2-bit RTN on spiky heavy-tailed weights can be worse than
            // zeroing (the Table 1 GPTQ-2bit collapse) — bounded, not beaten.
            let bound = if name == "rtn" { 4.0 * zero } else { zero };
            assert!(mse < bound, "{name}: mse {mse} !< bound {bound}");
            let bpp = layer.bpp();
            assert!(bpp > 0.0 && bpp < 34.0, "{name}: bpp {bpp}");
            let y = layer.forward(&[1.0; 56]);
            assert_eq!(y.len(), 72, "{name}");
        }
        assert!(MethodSpec::parse("gptq", 1.0, InitStrategy::Standard).is_err());
    }

    /// The trait impl of littlebit2 must produce exactly the layer the
    /// direct pipeline produces — the bit-identity that lets the job
    /// scheduler keep its instrumented fast path.
    #[test]
    fn littlebit2_trait_matches_pipeline_bit_exactly() {
        let w = weight(2, 64, 64);
        let cfg = CompressionConfig { bpp: 1.0, ..Default::default() };
        let via_trait = LittleBit2Compressor { cfg: cfg.clone() }
            .compress_layer(&w, Pool::serial(), &mut Pcg64::seed(9))
            .unwrap();
        let direct = compress_pipeline(&w, &cfg, &mut Pcg64::seed(9), Pool::serial()).packed;
        assert_eq!(via_trait.as_packed().unwrap(), &direct);
    }

    /// OneBit through the trait must reconstruct exactly like the
    /// reconstruction-level baseline (`quant::onebit`) — the serving form
    /// changes, the numbers don't.
    #[test]
    fn onebit_trait_matches_quant_result() {
        let w = weight(3, 48, 40);
        let layer = MethodSpec::OneBit { als_iters: 25 }
            .compressor()
            .compress_layer(&w, Pool::serial(), &mut Pcg64::seed(1))
            .unwrap();
        let q = super::onebit(&w, 25);
        let recon = layer.reconstruct_on(Pool::serial());
        assert_eq!(recon, q.reconstruction, "serving form must not change the numbers");
        assert_eq!(layer.declared_bits(), q.bits);
    }

    /// TinyRank through the trait must carry exactly the baseline's
    /// FP16-rounded factors (same shared core, same RNG draws) — the
    /// factor-level pin that keeps `eval` and `quant::tiny_rank_fp16`
    /// from drifting.
    #[test]
    fn tinyrank_trait_shares_the_baseline_factors() {
        let w = weight(5, 64, 64);
        let rank = memory::tiny_rank_for_budget(64, 64, 2.0).min(64).max(1);
        let (u, v) = tiny_rank_factors(&w, rank, &mut Pcg64::seed(3));
        let layer = MethodSpec::TinyRankFp16 { bpp: 2.0 }
            .compressor()
            .compress_layer(&w, Pool::serial(), &mut Pcg64::seed(3))
            .unwrap();
        match layer {
            MethodLayer::LowRankFp(l) => {
                assert_eq!(l.rank(), rank);
                assert_eq!(l.u(), &u);
                assert_eq!(l.vt(), &v.transpose());
            }
            other => panic!("expected LowRankFp, got {}", other.variant_label()),
        }
    }

    /// Methods that honor the bpp budget must respect it in their
    /// declared accounting.
    #[test]
    fn budgeted_methods_respect_bpp() {
        let w = weight(4, 128, 128);
        for (name, budget) in [("littlebit2", 1.0), ("tinyrank", 0.8)] {
            let spec =
                MethodSpec::parse(name, budget, InitStrategy::JointItq { iters: 5 }).unwrap();
            let layer = spec
                .compressor()
                .compress_layer(&w, Pool::serial(), &mut Pcg64::seed(11))
                .unwrap();
            assert!(layer.bpp() <= budget + 1e-9, "{name}: {} > {budget}", layer.bpp());
        }
    }
}
