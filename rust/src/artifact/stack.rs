//! `PackedStack` ⇄ `.lb2` payload encoding.
//!
//! The encoding is the kernel-native representation verbatim: packed
//! bit-plane `u64` words ([`BitMatrix::words`]) and `f32` scale vectors,
//! so save→load round-trips are straight copies and the loaded stack's
//! forwards are bit-identical to the saved one's. Decoding validates
//! every length against the section size *before* allocating, rejects
//! set padding bits, and re-checks path/chain shape consistency — a
//! corrupt or truncated artifact is an `Err`, never a panic or garbage
//! weights.

use super::{ArtifactReader, ArtifactWriter, TAG_LAYER, TAG_META, TAG_STACK};
use crate::model::PackedStack;
use crate::packing::{BitMatrix, PackedResidual, TriScaleLayer};
use anyhow::{bail, Context, Result};
use std::io::Write;
use std::path::Path;

/// Serialize a stack into `.lb2` container bytes on `sink`.
///
/// Byte-identical to streaming the same layers through
/// [`StackStreamWriter`] — both paths share the header and layer encoders.
pub fn write_stack<W: Write>(stack: &PackedStack, sink: W) -> Result<W> {
    let layers = stack.layers();
    let shapes: Vec<(usize, usize, usize)> = layers
        .iter()
        .map(|l| (l.d_in(), l.d_out(), l.paths().len()))
        .collect();
    let mut w = begin_stack(sink, &shapes)?;
    for layer in layers {
        w.section(TAG_LAYER, &encode_layer(layer)?)?;
    }
    w.finish()
}

/// Open an `.lb2` container on `sink` and emit the META + STAK sections
/// for a stack with the given per-layer `(d_in, d_out, n_paths)` shapes.
/// Shared by [`write_stack`] and [`StackStreamWriter`] so the two paths
/// cannot drift byte-wise.
fn begin_stack<W: Write>(sink: W, shapes: &[(usize, usize, usize)]) -> Result<ArtifactWriter<W>> {
    let mut w = ArtifactWriter::new(sink)?;
    w.section(TAG_META, format!("littlebit2 {}", crate::VERSION).as_bytes())?;
    let mut head = Vec::with_capacity(4 + shapes.len() * 12);
    head.extend_from_slice(&u32_of(shapes.len(), "depth")?.to_le_bytes());
    for &(d_in, d_out, n_paths) in shapes {
        head.extend_from_slice(&u32_of(d_in, "d_in")?.to_le_bytes());
        head.extend_from_slice(&u32_of(d_out, "d_out")?.to_le_bytes());
        head.extend_from_slice(&u32_of(n_paths, "path count")?.to_le_bytes());
    }
    w.section(TAG_STACK, &head)?;
    Ok(w)
}

/// Deserialize a stack from `.lb2` container bytes.
pub fn read_stack(bytes: &[u8]) -> Result<PackedStack> {
    let mut r = ArtifactReader::new(bytes)?;

    let (tag, _meta) = r.next_section().context("empty artifact: no META section")?;
    if tag != TAG_META {
        bail!("expected META as first section, found {tag:?}");
    }
    let (tag, head) = r.next_section().context("missing STAK section")?;
    if tag != TAG_STACK {
        bail!("expected STAK as second section, found {tag:?}");
    }

    let mut cur = Cur::new(head);
    let depth = cur.u32()? as usize;
    if depth == 0 {
        bail!("artifact declares an empty stack (depth 0)");
    }
    // Pin the declared depth to the actual shape-table size before any
    // depth-proportional allocation: a forged depth field cannot cost more
    // memory than the file already spends.
    if head.len() != 4 + depth * 12 {
        bail!(
            "shape header is {} bytes but depth {depth} requires {}",
            head.len(),
            4 + depth * 12
        );
    }
    let mut shapes = Vec::with_capacity(depth);
    for _ in 0..depth {
        let d_in = cur.u32()? as usize;
        let d_out = cur.u32()? as usize;
        let n_paths = cur.u32()? as usize;
        shapes.push((d_in, d_out, n_paths));
    }
    cur.done("STAK")?;

    let mut layers = Vec::with_capacity(depth);
    for (k, &(d_in, d_out, n_paths)) in shapes.iter().enumerate() {
        let (tag, body) = r
            .next_section()
            .with_context(|| format!("missing LAYR section for layer {k}"))?;
        if tag != TAG_LAYER {
            bail!("expected LAYR section for layer {k}, found {tag:?}");
        }
        let layer = decode_layer(body).with_context(|| format!("layer {k}"))?;
        if layer.d_in() != d_in || layer.d_out() != d_out || layer.paths().len() != n_paths {
            bail!(
                "layer {k} is {}x{} with {} paths but the shape header says {d_out}x{d_in} with {n_paths}",
                layer.d_out(),
                layer.d_in(),
                layer.paths().len()
            );
        }
        layers.push(layer);
    }
    if r.next_section().is_some() {
        bail!("unexpected extra sections after layer {depth}");
    }
    PackedStack::try_new(layers)
}

/// Save a stack to a `.lb2` file (written via a temp file + rename, so a
/// crash mid-write never leaves a half-written artifact at `path`; a
/// failed write removes its temp file).
pub fn save_stack(stack: &PackedStack, path: impl AsRef<Path>) -> Result<()> {
    let path = path.as_ref();
    // Append ".tmp" to the whole file name (with_extension would *replace*
    // the last extension, making "model.v1" and "model.lb2" collide on the
    // same temp path).
    let mut tmp_name = path.as_os_str().to_os_string();
    tmp_name.push(".tmp");
    let tmp = std::path::PathBuf::from(tmp_name);
    let write = || -> Result<()> {
        let mut file = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        write_stack(stack, std::io::BufWriter::new(&mut file))?;
        file.sync_all().with_context(|| format!("syncing {}", tmp.display()))?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming {} to {}", tmp.display(), path.display()))?;
        Ok(())
    };
    let result = write();
    if result.is_err() {
        let _ = std::fs::remove_file(&tmp);
    }
    result
}

/// Streams a `.lb2` model artifact to disk **one layer at a time** — the
/// bounded-memory half of `compress --jobs N`: the shape table is known up
/// front (from the job list), so each finished layer is appended the
/// moment the in-order committer hands it over, encoded, written, and
/// dropped. Peak memory is one encoded layer plus the scheduler's packed
/// reorder buffer (typically O(workers) layers; see
/// `coordinator::jobs` for the exact bound).
///
/// Produces **byte-identical** files to [`save_stack`] on the same layers
/// (both share [`write_stack`]'s encoders; asserted by
/// `tests/compress_pipeline.rs`), with the same durability contract: the
/// container is written to `<path>.tmp`, fsynced, and renamed into place
/// by [`finish`](Self::finish); an abandoned or failed write removes its
/// temp file and never touches `path`.
///
/// Appended layers are validated against the declared shape table — a
/// mismatched layer fails fast instead of sealing a container the loader
/// would reject.
pub struct StackStreamWriter {
    writer: Option<ArtifactWriter<std::io::BufWriter<std::fs::File>>>,
    shapes: Vec<(usize, usize, usize)>,
    written: usize,
    path: std::path::PathBuf,
    tmp: std::path::PathBuf,
}

impl StackStreamWriter {
    /// Open `<path>.tmp` and write the container header + shape table for
    /// a stack of `shapes = [(d_in, d_out, n_paths); depth]`.
    pub fn create(path: impl AsRef<Path>, shapes: &[(usize, usize, usize)]) -> Result<Self> {
        let path = path.as_ref().to_path_buf();
        if shapes.is_empty() {
            bail!("refusing to stream an empty stack (no layer shapes)");
        }
        // Same temp-name scheme as save_stack: append ".tmp" to the whole
        // file name so "model.v1" and "model.lb2" cannot collide.
        let mut tmp_name = path.as_os_str().to_os_string();
        tmp_name.push(".tmp");
        let tmp = std::path::PathBuf::from(tmp_name);
        let file = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        let writer = match begin_stack(std::io::BufWriter::new(file), shapes) {
            Ok(w) => w,
            Err(e) => {
                let _ = std::fs::remove_file(&tmp);
                return Err(e);
            }
        };
        Ok(Self { writer: Some(writer), shapes: shapes.to_vec(), written: 0, path, tmp })
    }

    /// Append the next layer (layers must arrive in chain order). The
    /// layer's shape is checked against the declared table.
    pub fn append_layer(&mut self, layer: &PackedResidual) -> Result<()> {
        let k = self.written;
        let Some(&(d_in, d_out, n_paths)) = self.shapes.get(k) else {
            bail!("layer {k} appended but the shape table declares only {}", self.shapes.len());
        };
        if layer.d_in() != d_in || layer.d_out() != d_out || layer.paths().len() != n_paths {
            bail!(
                "layer {k} is {}x{} with {} paths but the shape table says {d_out}x{d_in} with {n_paths}",
                layer.d_out(),
                layer.d_in(),
                layer.paths().len()
            );
        }
        let w = self.writer.as_mut().expect("writer live until finish");
        w.section(TAG_LAYER, &encode_layer(layer)?)?;
        self.written += 1;
        Ok(())
    }

    /// Layers appended so far.
    pub fn layers_written(&self) -> usize {
        self.written
    }

    /// Seal the container (trailer + CRC), fsync, and rename the temp file
    /// into place. Fails — leaving no file at `path` — if any declared
    /// layer is missing.
    pub fn finish(mut self) -> Result<()> {
        if self.written != self.shapes.len() {
            bail!(
                "artifact declares {} layers but only {} were appended",
                self.shapes.len(),
                self.written
            );
        }
        let w = self.writer.take().expect("writer live until finish");
        let seal = || -> Result<()> {
            let buf = w.finish()?;
            let file = buf
                .into_inner()
                .map_err(|e| anyhow::anyhow!("flushing {}: {}", self.tmp.display(), e.error()))?;
            file.sync_all().with_context(|| format!("syncing {}", self.tmp.display()))?;
            std::fs::rename(&self.tmp, &self.path).with_context(|| {
                format!("renaming {} to {}", self.tmp.display(), self.path.display())
            })?;
            Ok(())
        };
        let result = seal();
        if result.is_err() {
            let _ = std::fs::remove_file(&self.tmp);
        }
        result
    }
}

impl Drop for StackStreamWriter {
    fn drop(&mut self) {
        // Abandoned mid-stream (error or unwind before finish): never leave
        // a half-written temp file behind.
        if self.writer.take().is_some() {
            let _ = std::fs::remove_file(&self.tmp);
        }
    }
}

/// Load a stack from a `.lb2` file.
pub fn load_stack(path: impl AsRef<Path>) -> Result<PackedStack> {
    let path = path.as_ref();
    let bytes =
        std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    read_stack(&bytes).with_context(|| format!("loading {}", path.display()))
}

fn u32_of(v: usize, what: &str) -> Result<u32> {
    u32::try_from(v).map_err(|_| anyhow::anyhow!("{what} {v} exceeds the u32 format field"))
}

fn encode_layer(layer: &PackedResidual) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    out.extend_from_slice(&u32_of(layer.paths().len(), "path count")?.to_le_bytes());
    for p in layer.paths() {
        out.extend_from_slice(&u32_of(p.d_out(), "d_out")?.to_le_bytes());
        out.extend_from_slice(&u32_of(p.d_in(), "d_in")?.to_le_bytes());
        out.extend_from_slice(&u32_of(p.rank(), "rank")?.to_le_bytes());
        for &v in p.h().iter().chain(p.l()).chain(p.g()) {
            out.extend_from_slice(&v.to_le_bytes());
        }
        for &w in p.ub_bits().words().iter().chain(p.vbt_bits().words()) {
            out.extend_from_slice(&w.to_le_bytes());
        }
    }
    Ok(out)
}

fn decode_layer(body: &[u8]) -> Result<PackedResidual> {
    let mut cur = Cur::new(body);
    let n_paths = cur.u32()? as usize;
    if n_paths == 0 {
        bail!("layer declares zero residual paths");
    }
    let mut paths = Vec::with_capacity(n_paths.min(64));
    for p in 0..n_paths {
        paths.push(decode_path(&mut cur).with_context(|| format!("path {p}"))?);
    }
    cur.done("LAYR")?;
    PackedResidual::try_new(paths)
}

fn decode_path(cur: &mut Cur<'_>) -> Result<TriScaleLayer> {
    let d_out = cur.u32()? as usize;
    let d_in = cur.u32()? as usize;
    let rank = cur.u32()? as usize;
    if d_out == 0 || d_in == 0 || rank == 0 {
        bail!("degenerate path shape {d_out}x{d_in} rank {rank}");
    }
    let h = cur.f32s(d_out)?;
    let l = cur.f32s(rank)?;
    let g = cur.f32s(d_in)?;
    let ub = BitMatrix::from_words(d_out, rank, cur.u64s(d_out * rank.div_ceil(64))?)?;
    let vbt = BitMatrix::from_words(rank, d_in, cur.u64s(rank * d_in.div_ceil(64))?)?;
    TriScaleLayer::from_parts(ub, vbt, h, l, g)
}

/// Bounds-checked little-endian cursor over one section payload. Vector
/// reads verify the byte count against the remaining payload *before*
/// allocating, so a corrupt length field cannot trigger a huge allocation.
struct Cur<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn new(b: &'a [u8]) -> Self {
        Self { b, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if n > self.b.len() - self.pos {
            bail!(
                "section payload truncated: need {n} bytes at offset {}, have {}",
                self.pos,
                self.b.len() - self.pos
            );
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let raw = self.take(n.checked_mul(4).context("f32 vector length overflow")?)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().expect("4 bytes")))
            .collect())
    }

    fn u64s(&mut self, n: usize) -> Result<Vec<u64>> {
        let raw = self.take(n.checked_mul(8).context("u64 vector length overflow")?)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().expect("8 bytes")))
            .collect())
    }

    fn done(&self, what: &str) -> Result<()> {
        if self.pos != self.b.len() {
            bail!("{what} section has {} undeclared trailing bytes", self.b.len() - self.pos);
        }
        Ok(())
    }
}
