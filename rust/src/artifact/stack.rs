//! Model stacks ⇄ `.lb2` payload encoding — method-generic since format
//! v2.
//!
//! The encoding is the serving representation verbatim: packed bit-plane
//! `u64` words ([`BitMatrix::words`]) and `f32` vectors, so save→load
//! round-trips are straight copies and the loaded stack's forwards are
//! bit-identical to the saved one's — **for every method variant**, not
//! just the packed tri-scale path. Decoding validates every length
//! against the section size *before* allocating, rejects set padding
//! bits, pins every METHOD tag to its payload section, and re-checks
//! path/chain shape consistency — a corrupt or truncated artifact is an
//! `Err`, never a panic or garbage weights.
//!
//! A format-v1 artifact (PR 3/4 era: packed layers only, no METHOD
//! sections) decodes as an all-`Packed` `littlebit2` [`MethodStack`],
//! bit-identically; [`write_stack_v1`] keeps that encoding producible so
//! back-compat fixtures never rot.

use super::{
    ArtifactReader, ArtifactWriter, TAG_DENSE, TAG_LAYER, TAG_LOWRANK, TAG_META, TAG_METHOD,
    TAG_PAD, TAG_SIGN, TAG_STACK,
};
use crate::linalg::Mat;
use crate::model::{
    DenseScaledLayer, LowRankFpLayer, MethodLayer, MethodStack, MethodStackLayer, PackedStack,
    SignScaledLayer,
};
use crate::packing::{BitMatrix, PackedResidual, TriScaleLayer};
use crate::sys::{MappedArtifact, MappedF32s, MappedWords, ScaleVec};
use anyhow::{bail, Context, Result};
use std::io::Write;
use std::ops::Range;
use std::path::Path;
use std::sync::Arc;

/// Per-layer METHOD variant codes (the first byte of a METH section).
const VARIANT_PACKED: u8 = 1;
const VARIANT_SIGN: u8 = 2;
const VARIANT_DENSE: u8 = 3;
const VARIANT_LOWRANK: u8 = 4;

/// Serialize a packed stack into `.lb2` **v2** container bytes on `sink`
/// (every layer tagged `littlebit2`).
///
/// Byte-identical to streaming the same layers through
/// [`StackStreamWriter`] — both paths share the header and layer encoders.
pub fn write_stack<W: Write>(stack: &PackedStack, sink: W) -> Result<W> {
    let shapes: Vec<(usize, usize, usize)> = stack
        .layers()
        .iter()
        .map(|l| (l.d_in(), l.d_out(), l.paths().len()))
        .collect();
    let mut w = begin_stack(sink, &shapes)?;
    for layer in stack.layers() {
        emit_packed_layer(&mut w, "littlebit2", layer)?;
    }
    w.finish()
}

/// Emit one packed layer's v2 METH + LAYR section pair — the single wire
/// emitter shared by the batch writers and the streaming
/// [`StackStreamWriter`], so the two paths cannot drift byte-wise.
fn emit_packed_layer<W: Write>(
    w: &mut ArtifactWriter<W>,
    method: &str,
    layer: &PackedResidual,
) -> Result<()> {
    w.section(TAG_METHOD, &encode_method_header(VARIANT_PACKED, method)?)?;
    w.section(TAG_LAYER, &encode_layer(layer)?)
}

/// Serialize a method-generic stack into `.lb2` v2 container bytes.
pub fn write_method_stack<W: Write>(stack: &MethodStack, sink: W) -> Result<W> {
    let shapes: Vec<(usize, usize, usize)> =
        stack.layers().iter().map(|l| shape_of(&l.layer)).collect();
    let mut w = begin_stack(sink, &shapes)?;
    for l in stack.layers() {
        append_method_layer(&mut w, &l.method, &l.layer)?;
    }
    w.finish()
}

/// Serialize a packed stack in the **frozen v1** encoding (no METHOD
/// sections) — byte-identical to what PR 3/4 builds wrote. Kept as a pub
/// emitter so back-compat tests can fabricate v1 fixtures forever; new
/// artifacts are always v2.
pub fn write_stack_v1<W: Write>(stack: &PackedStack, sink: W) -> Result<W> {
    let shapes: Vec<(usize, usize, usize)> = stack
        .layers()
        .iter()
        .map(|l| (l.d_in(), l.d_out(), l.paths().len()))
        .collect();
    let mut w = ArtifactWriter::with_version(sink, super::FORMAT_VERSION_V1)?;
    write_stack_header(&mut w, &shapes)?;
    for layer in stack.layers() {
        w.section(TAG_LAYER, &encode_layer(layer)?)?;
    }
    w.finish()
}

/// Serialize a method-generic stack in the **v3 aligned** encoding:
/// same sections in the same order as [`write_method_stack`], but
/// bit-planes at the padded in-memory stride and every LAYR/SGNS payload
/// (and every plane within it) 32-byte aligned in the file, so
/// [`read_method_stack_mapped`] can borrow kernel operands straight out
/// of a mapping. Decodes to the same stack as the v2 encoding,
/// bit-identically.
pub fn write_method_stack_aligned<W: Write>(stack: &MethodStack, sink: W) -> Result<W> {
    let shapes: Vec<(usize, usize, usize)> =
        stack.layers().iter().map(|l| shape_of(&l.layer)).collect();
    let mut w = ArtifactWriter::with_version(sink, super::FORMAT_VERSION_V3)?;
    write_stack_header(&mut w, &shapes)?;
    for l in stack.layers() {
        append_method_layer_aligned(&mut w, &l.method, &l.layer)?;
    }
    w.finish()
}

/// Emit a `PADD` filler section (0–31 zero bytes) so the *next* section's
/// payload — which starts 12 bytes (tag + u64 length) after the section
/// header — lands at a 32-byte-aligned file offset. No-op when it already
/// would.
fn pad_to_32<W: Write>(w: &mut ArtifactWriter<W>) -> Result<()> {
    if (w.offset() + 12) % 32 == 0 {
        return Ok(());
    }
    // A PADD section occupies 12 + L bytes, so the following payload
    // starts at offset + 24 + L; pick L ∈ [0, 31] to make that ≡ 0 (mod 32).
    let l = (32 - (w.offset() + 24) % 32) % 32;
    const ZEROS: [u8; 31] = [0; 31];
    w.section(TAG_PAD, &ZEROS[..l])
}

/// v3 twin of [`append_method_layer`]: METH, then an aligned payload for
/// the bit-plane variants (DNSE/LOWR decode into owned matrices either
/// way, so their payloads stay byte-identical to v2).
fn append_method_layer_aligned<W: Write>(
    w: &mut ArtifactWriter<W>,
    method: &str,
    layer: &MethodLayer,
) -> Result<()> {
    match layer {
        MethodLayer::Packed(l) => emit_packed_layer_aligned(w, method, l)?,
        MethodLayer::SignScaled(l) => {
            w.section(TAG_METHOD, &encode_method_header(VARIANT_SIGN, method)?)?;
            pad_to_32(w)?;
            w.section(TAG_SIGN, &encode_sign_layer_aligned(l)?)?;
        }
        MethodLayer::DenseScaled(l) => {
            w.section(TAG_METHOD, &encode_method_header(VARIANT_DENSE, method)?)?;
            w.section(TAG_DENSE, &encode_dense_layer(l)?)?;
        }
        MethodLayer::LowRankFp(l) => {
            w.section(TAG_METHOD, &encode_method_header(VARIANT_LOWRANK, method)?)?;
            w.section(TAG_LOWRANK, &encode_lowrank_layer(l)?)?;
        }
    }
    Ok(())
}

/// v3 twin of [`emit_packed_layer`].
fn emit_packed_layer_aligned<W: Write>(
    w: &mut ArtifactWriter<W>,
    method: &str,
    layer: &PackedResidual,
) -> Result<()> {
    w.section(TAG_METHOD, &encode_method_header(VARIANT_PACKED, method)?)?;
    pad_to_32(w)?;
    w.section(TAG_LAYER, &encode_layer_aligned(layer)?)
}

/// `(d_in, d_out, n_paths)` as the STAK shape table declares it: residual
/// path count for packed layers, 0 for every other serving form.
fn shape_of(layer: &MethodLayer) -> (usize, usize, usize) {
    let n_paths = match layer {
        MethodLayer::Packed(p) => p.paths().len(),
        _ => 0,
    };
    (layer.d_in(), layer.d_out(), n_paths)
}

/// Open a v2 `.lb2` container on `sink` and emit the META + STAK sections
/// for a stack with the given per-layer `(d_in, d_out, n_paths)` shapes.
/// Shared by every batch writer and [`StackStreamWriter`] so the paths
/// cannot drift byte-wise.
fn begin_stack<W: Write>(sink: W, shapes: &[(usize, usize, usize)]) -> Result<ArtifactWriter<W>> {
    begin_stack_at(sink, shapes, super::FORMAT_VERSION)
}

fn begin_stack_at<W: Write>(
    sink: W,
    shapes: &[(usize, usize, usize)],
    version: u32,
) -> Result<ArtifactWriter<W>> {
    let mut w = ArtifactWriter::with_version(sink, version)?;
    write_stack_header(&mut w, shapes)?;
    Ok(w)
}

fn write_stack_header<W: Write>(
    w: &mut ArtifactWriter<W>,
    shapes: &[(usize, usize, usize)],
) -> Result<()> {
    w.section(TAG_META, format!("littlebit2 {}", crate::VERSION).as_bytes())?;
    let mut head = Vec::with_capacity(4 + shapes.len() * 12);
    head.extend_from_slice(&u32_of(shapes.len(), "depth")?.to_le_bytes());
    for &(d_in, d_out, n_paths) in shapes {
        head.extend_from_slice(&u32_of(d_in, "d_in")?.to_le_bytes());
        head.extend_from_slice(&u32_of(d_out, "d_out")?.to_le_bytes());
        head.extend_from_slice(&u32_of(n_paths, "path count")?.to_le_bytes());
    }
    w.section(TAG_STACK, &head)?;
    Ok(())
}

/// Emit one layer's METH + payload section pair.
fn append_method_layer<W: Write>(
    w: &mut ArtifactWriter<W>,
    method: &str,
    layer: &MethodLayer,
) -> Result<()> {
    match layer {
        MethodLayer::Packed(l) => emit_packed_layer(w, method, l)?,
        MethodLayer::SignScaled(l) => {
            w.section(TAG_METHOD, &encode_method_header(VARIANT_SIGN, method)?)?;
            w.section(TAG_SIGN, &encode_sign_layer(l)?)?;
        }
        MethodLayer::DenseScaled(l) => {
            w.section(TAG_METHOD, &encode_method_header(VARIANT_DENSE, method)?)?;
            w.section(TAG_DENSE, &encode_dense_layer(l)?)?;
        }
        MethodLayer::LowRankFp(l) => {
            w.section(TAG_METHOD, &encode_method_header(VARIANT_LOWRANK, method)?)?;
            w.section(TAG_LOWRANK, &encode_lowrank_layer(l)?)?;
        }
    }
    Ok(())
}

/// Deserialize a **packed** stack from `.lb2` bytes (any version). An
/// artifact containing any non-packed method layer is an `Err` naming the
/// offending layer — use [`read_method_stack`] for those.
pub fn read_stack(bytes: &[u8]) -> Result<PackedStack> {
    read_method_stack(bytes)?.try_into_packed()
}

/// Deserialize a method-generic stack from `.lb2` bytes — v1, v2, or v3
/// (v3 payloads are copied-and-restrided here; use
/// [`read_method_stack_mapped`] to borrow them from a mapping instead).
pub fn read_method_stack(bytes: &[u8]) -> Result<MethodStack> {
    read_method_stack_impl(bytes, None, None)
}

/// Deserialize a method-generic stack **out of a mapped artifact**: for a
/// v3 aligned container, bit-planes and scale vectors borrow the mapping
/// (each view holds an `Arc` clone, so the mapping outlives the stack);
/// v1/v2 containers — and any payload that lands misaligned — fall back
/// to the owned copy path. Forwards are bit-identical either way.
pub fn read_method_stack_mapped(art: &Arc<MappedArtifact>) -> Result<MethodStack> {
    read_method_stack_impl(art.bytes(), Some(art), None)
}

/// Deserialize only layers `range` (half-open, chain order) of a stack —
/// the partial-load primitive behind pipeline-parallel serving: a peer
/// assigned layers `lo..hi` decodes exactly those payloads and walks past
/// the rest without touching their bytes beyond the section framing. The
/// returned stack is the contiguous sub-chain, so its `forward` is
/// bit-identical to running those layers inside the full stack.
pub fn read_method_stack_range(bytes: &[u8], range: Range<usize>) -> Result<MethodStack> {
    read_method_stack_impl(bytes, None, Some(range))
}

/// [`read_method_stack_range`] out of a mapped artifact: in-range v3
/// payloads borrow the mapping (so a peer pages in only its shard's
/// weights — skipped payloads are never dereferenced), everything else
/// falls back to the owned copy path.
pub fn read_method_stack_range_mapped(
    art: &Arc<MappedArtifact>,
    range: Range<usize>,
) -> Result<MethodStack> {
    read_method_stack_impl(art.bytes(), Some(art), Some(range))
}

/// A stack's shape table, decoded from META/STAK alone — what a cluster
/// tracker loads: enough to plan layer-range and row-shard assignments
/// without decoding (or paging in) a single weight byte.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StackShapes {
    /// Container format version (1, 2, or 3).
    pub version: u32,
    /// Per-layer `(d_in, d_out, n_paths)` in chain order.
    pub shapes: Vec<(usize, usize, usize)>,
}

impl StackShapes {
    /// Chain depth.
    pub fn depth(&self) -> usize {
        self.shapes.len()
    }

    /// The chain's input width (first layer's `d_in`).
    pub fn d_in(&self) -> usize {
        self.shapes.first().map(|&(d_in, _, _)| d_in).unwrap_or(0)
    }

    /// The chain's output width (last layer's `d_out`).
    pub fn d_out(&self) -> usize {
        self.shapes.last().map(|&(_, d_out, _)| d_out).unwrap_or(0)
    }
}

/// Decode a stack's [`StackShapes`] from container bytes without decoding
/// any layer payload. The container is still fully CRC-validated (that is
/// `ArtifactReader::new`'s contract), but no weight bytes are parsed,
/// copied, or shape-checked.
pub fn read_stack_shapes(bytes: &[u8]) -> Result<StackShapes> {
    let mut r = ArtifactReader::new(bytes)?;
    let version = r.version();
    let shapes = read_shape_table(&mut r)?;
    Ok(StackShapes { version, shapes })
}

/// [`read_stack_shapes`] from a file via mmap — the tracker's load path.
pub fn load_stack_shapes(path: impl AsRef<Path>) -> Result<StackShapes> {
    let path = path.as_ref();
    let art =
        MappedArtifact::open(path).with_context(|| format!("mapping {}", path.display()))?;
    read_stack_shapes(art.bytes()).with_context(|| format!("loading {}", path.display()))
}

/// Walk META + STAK at the reader's cursor and return the validated
/// per-layer shape table. Shared by the full decoder and the shapes-only
/// reader so the two cannot disagree on header validation.
fn read_shape_table(r: &mut ArtifactReader<'_>) -> Result<Vec<(usize, usize, usize)>> {
    let (tag, _meta, _) = next_nonpad(r).context("empty artifact: no META section")?;
    if tag != TAG_META {
        bail!("expected META as first section, found {tag:?}");
    }
    let (tag, head, _) = next_nonpad(r).context("missing STAK section")?;
    if tag != TAG_STACK {
        bail!("expected STAK as second section, found {tag:?}");
    }
    let mut cur = Cur::new(head);
    let depth = cur.u32()? as usize;
    if depth == 0 {
        bail!("artifact declares an empty stack (depth 0)");
    }
    // Pin the declared depth to the actual shape-table size before any
    // depth-proportional allocation: a forged depth field cannot cost more
    // memory than the file already spends.
    if head.len() != 4 + depth * 12 {
        bail!(
            "shape header is {} bytes but depth {depth} requires {}",
            head.len(),
            4 + depth * 12
        );
    }
    let mut shapes = Vec::with_capacity(depth);
    for _ in 0..depth {
        let d_in = cur.u32()? as usize;
        let d_out = cur.u32()? as usize;
        let n_paths = cur.u32()? as usize;
        shapes.push((d_in, d_out, n_paths));
    }
    cur.done("STAK")?;
    Ok(shapes)
}

/// The next non-filler section: `PADD` sections are pure file-offset
/// alignment and may appear anywhere, in any version.
fn next_nonpad<'a>(r: &mut ArtifactReader<'a>) -> Option<([u8; 4], &'a [u8], Range<usize>)> {
    loop {
        let (tag, body, range) = r.next_section_range()?;
        if tag != TAG_PAD {
            return Some((tag, body, range));
        }
    }
}

fn read_method_stack_impl(
    bytes: &[u8],
    art: Option<&Arc<MappedArtifact>>,
    want: Option<Range<usize>>,
) -> Result<MethodStack> {
    let mut r = ArtifactReader::new(bytes)?;
    let shapes = read_shape_table(&mut r)?;
    let depth = shapes.len();
    if let Some(w) = &want {
        if w.start >= w.end || w.end > depth {
            bail!(
                "layer range {}..{} is invalid for a depth-{depth} stack",
                w.start,
                w.end
            );
        }
    }

    let v1 = r.version() == super::FORMAT_VERSION_V1;
    let v3 = r.version() == super::FORMAT_VERSION_V3;
    let mut layers = Vec::with_capacity(want.as_ref().map(Range::len).unwrap_or(depth));
    for (k, &(d_in, d_out, n_paths)) in shapes.iter().enumerate() {
        // A skipped layer's sections are still walked (the framing and
        // tag pinning stay validated) but its payload is never decoded —
        // and, on the mmap path, never dereferenced, so skipped weights
        // are never paged in.
        let skip = want.as_ref().is_some_and(|w| !w.contains(&k));
        let (method, layer) = if v1 {
            // v1: packed layers only, no METHOD sections.
            let (tag, body, _) = next_nonpad(&mut r)
                .with_context(|| format!("missing LAYR section for layer {k}"))?;
            if tag != TAG_LAYER {
                bail!("expected LAYR section for layer {k}, found {tag:?}");
            }
            if skip {
                continue;
            }
            let layer = decode_layer(body).with_context(|| format!("layer {k}"))?;
            ("littlebit2".to_string(), MethodLayer::Packed(layer))
        } else {
            let (tag, body, _) = next_nonpad(&mut r)
                .with_context(|| format!("missing METH section for layer {k}"))?;
            if tag != TAG_METHOD {
                bail!("expected METH section for layer {k}, found {tag:?}");
            }
            let (variant, method) =
                decode_method_header(body).with_context(|| format!("layer {k}"))?;
            let (tag, body, range) = next_nonpad(&mut r)
                .with_context(|| format!("missing payload section for layer {k}"))?;
            if skip {
                let expect = expect_tag(variant).with_context(|| format!("layer {k}"))?;
                if tag != expect {
                    bail!(
                        "METHOD variant {variant} requires a {expect:?} payload section, found {tag:?}"
                    );
                }
                continue;
            }
            let layer = if v3 {
                decode_variant_payload_v3(variant, tag, body, range.start, art)
            } else {
                decode_variant_payload(variant, tag, body)
            }
            .with_context(|| format!("layer {k} ({method})"))?;
            (method, layer)
        };
        if layer.d_in() != d_in || layer.d_out() != d_out {
            bail!(
                "layer {k} is {}x{} but the shape header says {d_out}x{d_in}",
                layer.d_out(),
                layer.d_in()
            );
        }
        let layer_paths = match &layer {
            MethodLayer::Packed(p) => p.paths().len(),
            _ => 0,
        };
        if layer_paths != n_paths {
            bail!(
                "layer {k} carries {layer_paths} residual paths but the shape header declares {n_paths}"
            );
        }
        layers.push(MethodStackLayer { method, layer });
    }
    if next_nonpad(&mut r).is_some() {
        bail!("unexpected extra sections after layer {depth}");
    }
    MethodStack::try_new(layers)
}

/// The payload tag a METH variant code pins its following section to.
fn expect_tag(variant: u8) -> Result<[u8; 4]> {
    Ok(match variant {
        VARIANT_PACKED => TAG_LAYER,
        VARIANT_SIGN => TAG_SIGN,
        VARIANT_DENSE => TAG_DENSE,
        VARIANT_LOWRANK => TAG_LOWRANK,
        other => bail!("unknown METHOD variant code {other}"),
    })
}

/// Dispatch a METH variant code to its payload decoder, pinning the
/// payload section's tag to the declared variant first.
fn decode_variant_payload(variant: u8, tag: [u8; 4], body: &[u8]) -> Result<MethodLayer> {
    let expect = expect_tag(variant)?;
    if tag != expect {
        bail!("METHOD variant {variant} requires a {expect:?} payload section, found {tag:?}");
    }
    Ok(match variant {
        VARIANT_PACKED => MethodLayer::Packed(decode_layer(body)?),
        VARIANT_SIGN => MethodLayer::SignScaled(decode_sign_layer(body)?),
        VARIANT_DENSE => MethodLayer::DenseScaled(decode_dense_layer(body)?),
        VARIANT_LOWRANK => MethodLayer::LowRankFp(decode_lowrank_layer(body)?),
        _ => unreachable!("variant validated above"),
    })
}

/// [`decode_variant_payload`] for the v3 aligned encoding: LAYR/SGNS
/// payloads decode through the borrow-or-copy cursor (`base` is the
/// payload's absolute offset in the container, `art` the mapping to
/// borrow from — `None` decodes owned); DNSE/LOWR are byte-identical to
/// v2 and always owned.
fn decode_variant_payload_v3(
    variant: u8,
    tag: [u8; 4],
    body: &[u8],
    base: usize,
    art: Option<&Arc<MappedArtifact>>,
) -> Result<MethodLayer> {
    let expect = expect_tag(variant)?;
    if tag != expect {
        bail!("METHOD variant {variant} requires a {expect:?} payload section, found {tag:?}");
    }
    Ok(match variant {
        VARIANT_PACKED => MethodLayer::Packed(decode_layer_v3(body, base, art)?),
        VARIANT_SIGN => MethodLayer::SignScaled(decode_sign_layer_v3(body, base, art)?),
        VARIANT_DENSE => MethodLayer::DenseScaled(decode_dense_layer(body)?),
        VARIANT_LOWRANK => MethodLayer::LowRankFp(decode_lowrank_layer(body)?),
        _ => unreachable!("variant validated above"),
    })
}

/// Save a packed stack to a `.lb2` v2 file (temp file + rename, so a
/// crash mid-write never leaves a half-written artifact at `path`; a
/// failed write removes its temp file).
pub fn save_stack(stack: &PackedStack, path: impl AsRef<Path>) -> Result<()> {
    save_via(path.as_ref(), |sink| write_stack(stack, sink).map(|_| ()))
}

/// Save a method-generic stack to a `.lb2` v2 file (same durability
/// contract as [`save_stack`]).
pub fn save_method_stack(stack: &MethodStack, path: impl AsRef<Path>) -> Result<()> {
    save_via(path.as_ref(), |sink| write_method_stack(stack, sink).map(|_| ()))
}

/// Save a method-generic stack as a **v3 aligned** `.lb2` file (same
/// durability contract as [`save_stack`]) — the `compress --aligned`
/// output, servable zero-copy via [`load_method_stack_mmap`].
pub fn save_method_stack_aligned(stack: &MethodStack, path: impl AsRef<Path>) -> Result<()> {
    save_via(path.as_ref(), |sink| write_method_stack_aligned(stack, sink).map(|_| ()))
}

/// Save a packed stack as a **v3 aligned** `.lb2` file (every layer
/// tagged `littlebit2`; same durability contract as [`save_stack`]).
pub fn save_stack_aligned(stack: &PackedStack, path: impl AsRef<Path>) -> Result<()> {
    save_via(path.as_ref(), |sink| {
        let shapes: Vec<(usize, usize, usize)> = stack
            .layers()
            .iter()
            .map(|l| (l.d_in(), l.d_out(), l.paths().len()))
            .collect();
        let mut w = ArtifactWriter::with_version(sink, super::FORMAT_VERSION_V3)?;
        write_stack_header(&mut w, &shapes)?;
        for layer in stack.layers() {
            emit_packed_layer_aligned(&mut w, "littlebit2", layer)?;
        }
        w.finish().map(|_| ())
    })
}

/// Shared temp-file + fsync + rename save path.
fn save_via(
    path: &Path,
    write: impl FnOnce(std::io::BufWriter<&mut std::fs::File>) -> Result<()>,
) -> Result<()> {
    // Append ".tmp" to the whole file name (with_extension would *replace*
    // the last extension, making "model.v1" and "model.lb2" collide on the
    // same temp path).
    let mut tmp_name = path.as_os_str().to_os_string();
    tmp_name.push(".tmp");
    let tmp = std::path::PathBuf::from(tmp_name);
    let run = || -> Result<()> {
        let mut file = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        write(std::io::BufWriter::new(&mut file))?;
        file.sync_all().with_context(|| format!("syncing {}", tmp.display()))?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming {} to {}", tmp.display(), path.display()))?;
        Ok(())
    };
    let result = run();
    if result.is_err() {
        let _ = std::fs::remove_file(&tmp);
    }
    result
}

/// Streams a `.lb2` v2 model artifact to disk **one layer at a time** —
/// the bounded-memory half of `compress --jobs N`: the shape table is
/// known up front (from the job list), so each finished layer is appended
/// the moment the in-order committer hands it over, encoded, written, and
/// dropped. Peak memory is one encoded layer plus the scheduler's packed
/// reorder buffer (typically O(workers) layers; see `coordinator::jobs`
/// for the exact bound).
///
/// Produces **byte-identical** files to [`save_stack`] /
/// [`save_method_stack`] on the same layers (all paths share the header
/// and layer encoders; asserted by `tests/compress_pipeline.rs`), with
/// the same durability contract: the container is written to
/// `<path>.tmp`, fsynced, and renamed into place by
/// [`finish`](Self::finish); an abandoned or failed write removes its
/// temp file and never touches `path`.
///
/// Appended layers are validated against the declared shape table — a
/// mismatched layer (or a non-packed layer where the table declared
/// residual paths) fails fast instead of sealing a container the loader
/// would reject.
pub struct StackStreamWriter {
    writer: Option<ArtifactWriter<std::io::BufWriter<std::fs::File>>>,
    shapes: Vec<(usize, usize, usize)>,
    written: usize,
    path: std::path::PathBuf,
    tmp: std::path::PathBuf,
    aligned: bool,
}

impl StackStreamWriter {
    /// Open `<path>.tmp` and write the container header + shape table for
    /// a stack of `shapes = [(d_in, d_out, n_paths); depth]` (`n_paths` is
    /// 0 for layers whose method has a non-packed serving form).
    pub fn create(path: impl AsRef<Path>, shapes: &[(usize, usize, usize)]) -> Result<Self> {
        Self::create_at(path, shapes, false)
    }

    /// [`create`](Self::create) in the **v3 aligned** encoding — the
    /// streaming half of `compress --aligned --jobs N`. Byte-identical to
    /// [`save_method_stack_aligned`] on the same layers.
    pub fn create_aligned(
        path: impl AsRef<Path>,
        shapes: &[(usize, usize, usize)],
    ) -> Result<Self> {
        Self::create_at(path, shapes, true)
    }

    fn create_at(
        path: impl AsRef<Path>,
        shapes: &[(usize, usize, usize)],
        aligned: bool,
    ) -> Result<Self> {
        let path = path.as_ref().to_path_buf();
        if shapes.is_empty() {
            bail!("refusing to stream an empty stack (no layer shapes)");
        }
        // Same temp-name scheme as save_stack: append ".tmp" to the whole
        // file name so "model.v1" and "model.lb2" cannot collide.
        let mut tmp_name = path.as_os_str().to_os_string();
        tmp_name.push(".tmp");
        let tmp = std::path::PathBuf::from(tmp_name);
        let file = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        let version =
            if aligned { super::FORMAT_VERSION_V3 } else { super::FORMAT_VERSION };
        let writer = match begin_stack_at(std::io::BufWriter::new(file), shapes, version) {
            Ok(w) => w,
            Err(e) => {
                let _ = std::fs::remove_file(&tmp);
                return Err(e);
            }
        };
        Ok(Self { writer: Some(writer), shapes: shapes.to_vec(), written: 0, path, tmp, aligned })
    }

    /// Check the next layer's shape tuple against the declared table.
    /// Does NOT advance the append cursor — `written` is only bumped
    /// after the layer's sections hit the sink, so a failed append can
    /// never satisfy [`finish`](Self::finish)'s completeness check.
    fn admit(&self, got: (usize, usize, usize)) -> Result<()> {
        let k = self.written;
        let Some(&(d_in, d_out, n_paths)) = self.shapes.get(k) else {
            bail!("layer {k} appended but the shape table declares only {}", self.shapes.len());
        };
        if got != (d_in, d_out, n_paths) {
            bail!(
                "layer {k} is {}x{} with {} paths but the shape table says {d_out}x{d_in} with {n_paths}",
                got.1,
                got.0,
                got.2
            );
        }
        Ok(())
    }

    /// Append the next layer under its METHOD tag (layers must arrive in
    /// chain order). The layer's shape — including its packed path count
    /// or 0 — is checked against the declared table.
    pub fn append(&mut self, method: &str, layer: &MethodLayer) -> Result<()> {
        self.admit(shape_of(layer))?;
        let w = self.writer.as_mut().expect("writer live until finish");
        if self.aligned {
            append_method_layer_aligned(w, method, layer)?;
        } else {
            append_method_layer(w, method, layer)?;
        }
        self.written += 1;
        Ok(())
    }

    /// [`append`](Self::append) sugar for the packed `littlebit2` path —
    /// encodes straight from the borrowed layer (no clone of the
    /// bit-planes; this is the bounded-memory streaming path).
    pub fn append_layer(&mut self, layer: &PackedResidual) -> Result<()> {
        self.admit((layer.d_in(), layer.d_out(), layer.paths().len()))?;
        let w = self.writer.as_mut().expect("writer live until finish");
        if self.aligned {
            emit_packed_layer_aligned(w, "littlebit2", layer)?;
        } else {
            emit_packed_layer(w, "littlebit2", layer)?;
        }
        self.written += 1;
        Ok(())
    }

    /// Layers appended so far.
    pub fn layers_written(&self) -> usize {
        self.written
    }

    /// Seal the container (trailer + CRC), fsync, and rename the temp file
    /// into place. Fails — leaving no file at `path` — if any declared
    /// layer is missing.
    pub fn finish(mut self) -> Result<()> {
        if self.written != self.shapes.len() {
            bail!(
                "artifact declares {} layers but only {} were appended",
                self.shapes.len(),
                self.written
            );
        }
        let w = self.writer.take().expect("writer live until finish");
        let seal = || -> Result<()> {
            let buf = w.finish()?;
            let file = buf
                .into_inner()
                .map_err(|e| anyhow::anyhow!("flushing {}: {}", self.tmp.display(), e.error()))?;
            file.sync_all().with_context(|| format!("syncing {}", self.tmp.display()))?;
            std::fs::rename(&self.tmp, &self.path).with_context(|| {
                format!("renaming {} to {}", self.tmp.display(), self.path.display())
            })?;
            Ok(())
        };
        let result = seal();
        if result.is_err() {
            let _ = std::fs::remove_file(&self.tmp);
        }
        result
    }
}

impl Drop for StackStreamWriter {
    fn drop(&mut self) {
        // Abandoned mid-stream (error or unwind before finish): never leave
        // a half-written temp file behind.
        if self.writer.take().is_some() {
            let _ = std::fs::remove_file(&self.tmp);
        }
    }
}

/// Load a packed stack from a `.lb2` file (any version; every layer must
/// be packed).
pub fn load_stack(path: impl AsRef<Path>) -> Result<PackedStack> {
    let path = path.as_ref();
    let bytes =
        std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    read_stack(&bytes).with_context(|| format!("loading {}", path.display()))
}

/// Load a method-generic stack from a `.lb2` file, any version (eager:
/// the whole file is read and every plane copied onto the heap).
pub fn load_method_stack(path: impl AsRef<Path>) -> Result<MethodStack> {
    let path = path.as_ref();
    let bytes =
        std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    read_method_stack(&bytes).with_context(|| format!("loading {}", path.display()))
}

/// Load a method-generic stack by **mapping** the `.lb2` file: a v3
/// aligned artifact's bit-planes and scale vectors borrow the mapping
/// (one page-cache copy of the weights, shared across every worker and
/// process that maps the same file); v1/v2 or misaligned payloads fall
/// back to copy-and-restride. Bit-identical forwards either way.
pub fn load_method_stack_mmap(path: impl AsRef<Path>) -> Result<MethodStack> {
    let path = path.as_ref();
    let art =
        MappedArtifact::open(path).with_context(|| format!("mapping {}", path.display()))?;
    read_method_stack_mapped(&art).with_context(|| format!("loading {}", path.display()))
}

/// [`load_method_stack_mmap`] for all-packed stacks.
pub fn load_stack_mmap(path: impl AsRef<Path>) -> Result<PackedStack> {
    let path = path.as_ref();
    let art =
        MappedArtifact::open(path).with_context(|| format!("mapping {}", path.display()))?;
    read_method_stack_mapped(&art)
        .and_then(MethodStack::try_into_packed)
        .with_context(|| format!("loading {}", path.display()))
}

fn u32_of(v: usize, what: &str) -> Result<u32> {
    u32::try_from(v).map_err(|_| anyhow::anyhow!("{what} {v} exceeds the u32 format field"))
}

/// METH payload: `[variant code][name length][name bytes]`.
fn encode_method_header(variant: u8, method: &str) -> Result<Vec<u8>> {
    let name = method.as_bytes();
    if name.is_empty() || name.len() > u8::MAX as usize {
        bail!("method name must be 1-255 bytes, got {}", name.len());
    }
    if !name.iter().all(|b| b.is_ascii_graphic()) {
        bail!("method name {method:?} contains non-printable or non-ASCII bytes");
    }
    let mut out = Vec::with_capacity(2 + name.len());
    out.push(variant);
    out.push(name.len() as u8);
    out.extend_from_slice(name);
    Ok(out)
}

fn decode_method_header(body: &[u8]) -> Result<(u8, String)> {
    if body.len() < 2 {
        bail!("METH section is {} bytes; need at least variant + name length", body.len());
    }
    let variant = body[0];
    let name_len = body[1] as usize;
    if name_len == 0 {
        bail!("METH section declares an empty method name");
    }
    if body.len() != 2 + name_len {
        bail!(
            "METH section is {} bytes but declares a {name_len}-byte method name",
            body.len()
        );
    }
    let name = &body[2..];
    if !name.iter().all(|b| b.is_ascii_graphic()) {
        bail!("method name contains non-printable or non-ASCII bytes");
    }
    Ok((variant, String::from_utf8(name.to_vec()).expect("ASCII validated")))
}

fn encode_layer(layer: &PackedResidual) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    out.extend_from_slice(&u32_of(layer.paths().len(), "path count")?.to_le_bytes());
    for p in layer.paths() {
        out.extend_from_slice(&u32_of(p.d_out(), "d_out")?.to_le_bytes());
        out.extend_from_slice(&u32_of(p.d_in(), "d_in")?.to_le_bytes());
        out.extend_from_slice(&u32_of(p.rank(), "rank")?.to_le_bytes());
        for &v in p.h().iter().chain(p.l()).chain(p.g()) {
            out.extend_from_slice(&v.to_le_bytes());
        }
        // tight_words strips the in-memory stride padding: the on-disk
        // encoding stays ⌈cols/64⌉ words per row, byte-identical to the
        // pre-padding format.
        for w in p.ub_bits().tight_words().chain(p.vbt_bits().tight_words()) {
            out.extend_from_slice(&w.to_le_bytes());
        }
    }
    Ok(out)
}

fn decode_layer(body: &[u8]) -> Result<PackedResidual> {
    let mut cur = Cur::new(body);
    let n_paths = cur.u32()? as usize;
    if n_paths == 0 {
        bail!("layer declares zero residual paths");
    }
    let mut paths = Vec::with_capacity(n_paths.min(64));
    for p in 0..n_paths {
        paths.push(decode_path(&mut cur).with_context(|| format!("path {p}"))?);
    }
    cur.done("LAYR")?;
    PackedResidual::try_new(paths)
}

fn decode_path(cur: &mut Cur<'_>) -> Result<TriScaleLayer> {
    let d_out = cur.u32()? as usize;
    let d_in = cur.u32()? as usize;
    let rank = cur.u32()? as usize;
    if d_out == 0 || d_in == 0 || rank == 0 {
        bail!("degenerate path shape {d_out}x{d_in} rank {rank}");
    }
    let h = cur.f32s(d_out)?;
    let l = cur.f32s(rank)?;
    let g = cur.f32s(d_in)?;
    let ub = BitMatrix::from_words(d_out, rank, cur.u64s(d_out * rank.div_ceil(64))?)?;
    let vbt = BitMatrix::from_words(rank, d_in, cur.u64s(rank * d_in.div_ceil(64))?)?;
    TriScaleLayer::from_parts(ub, vbt, h, l, g)
}

/// Zero-pad a v3 payload-in-progress to the next 32-byte boundary
/// (relative to the payload start, which the `PADD` filler sections pin
/// to a 32-aligned file offset).
fn pad32(out: &mut Vec<u8>) {
    let l = (32 - out.len() % 32) % 32;
    out.extend(std::iter::repeat(0u8).take(l));
}

/// v3 LAYR payload: v2's fields, but each bit-plane is preceded by zero
/// padding to a 32-byte boundary and stored at the **padded in-memory
/// stride** (`BitMatrix::padded_words` verbatim — a padded plane is
/// itself a multiple of 32 bytes, so consecutive planes stay aligned).
fn encode_layer_aligned(layer: &PackedResidual) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    out.extend_from_slice(&u32_of(layer.paths().len(), "path count")?.to_le_bytes());
    for p in layer.paths() {
        out.extend_from_slice(&u32_of(p.d_out(), "d_out")?.to_le_bytes());
        out.extend_from_slice(&u32_of(p.d_in(), "d_in")?.to_le_bytes());
        out.extend_from_slice(&u32_of(p.rank(), "rank")?.to_le_bytes());
        for &v in p.h().iter().chain(p.l()).chain(p.g()) {
            out.extend_from_slice(&v.to_le_bytes());
        }
        for plane in [p.ub_bits(), p.vbt_bits()] {
            pad32(&mut out);
            for &w in plane.padded_words() {
                out.extend_from_slice(&w.to_le_bytes());
            }
        }
    }
    Ok(out)
}

/// v3 twin of [`decode_layer`]: planes and scales go through the
/// borrow-or-copy cursor.
fn decode_layer_v3(
    body: &[u8],
    base: usize,
    art: Option<&Arc<MappedArtifact>>,
) -> Result<PackedResidual> {
    let mut cur = Cur::borrowing(body, base, art);
    let n_paths = cur.u32()? as usize;
    if n_paths == 0 {
        bail!("layer declares zero residual paths");
    }
    let mut paths = Vec::with_capacity(n_paths.min(64));
    for p in 0..n_paths {
        paths.push(decode_path_v3(&mut cur).with_context(|| format!("path {p}"))?);
    }
    cur.done("LAYR")?;
    PackedResidual::try_new(paths)
}

fn decode_path_v3(cur: &mut Cur<'_>) -> Result<TriScaleLayer> {
    let d_out = cur.u32()? as usize;
    let d_in = cur.u32()? as usize;
    let rank = cur.u32()? as usize;
    if d_out == 0 || d_in == 0 || rank == 0 {
        bail!("degenerate path shape {d_out}x{d_in} rank {rank}");
    }
    let h = cur.scales(d_out)?;
    let l = cur.scales(rank)?;
    let g = cur.scales(d_in)?;
    let ub = cur.padded_plane(d_out, rank)?;
    let vbt = cur.padded_plane(rank, d_in)?;
    TriScaleLayer::from_parts(ub, vbt, h, l, g)
}

fn encode_sign_layer(layer: &SignScaledLayer) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    out.extend_from_slice(&u32_of(layer.d_out(), "d_out")?.to_le_bytes());
    out.extend_from_slice(&u32_of(layer.d_in(), "d_in")?.to_le_bytes());
    out.extend_from_slice(&layer.declared_bits().to_le_bytes());
    for &v in layer.row_scale().iter().chain(layer.col_scale()) {
        out.extend_from_slice(&v.to_le_bytes());
    }
    for w in layer.bits().tight_words() {
        out.extend_from_slice(&w.to_le_bytes());
    }
    Ok(out)
}

fn decode_sign_layer(body: &[u8]) -> Result<SignScaledLayer> {
    let mut cur = Cur::new(body);
    let d_out = cur.u32()? as usize;
    let d_in = cur.u32()? as usize;
    let declared_bits = cur.u64()?;
    if d_out == 0 || d_in == 0 {
        bail!("degenerate sign layer shape {d_out}x{d_in}");
    }
    let row = cur.f32s(d_out)?;
    let col = cur.f32s(d_in)?;
    let words = d_out
        .checked_mul(d_in.div_ceil(64))
        .context("sign word count overflow")?;
    let bits = BitMatrix::from_words(d_out, d_in, cur.u64s(words)?)?;
    cur.done("SGNS")?;
    SignScaledLayer::try_new(bits, row, col, declared_bits)
}

/// v3 SGNS payload: v2's fields with the sign plane 32-padded and at the
/// padded stride (see [`encode_layer_aligned`]).
fn encode_sign_layer_aligned(layer: &SignScaledLayer) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    out.extend_from_slice(&u32_of(layer.d_out(), "d_out")?.to_le_bytes());
    out.extend_from_slice(&u32_of(layer.d_in(), "d_in")?.to_le_bytes());
    out.extend_from_slice(&layer.declared_bits().to_le_bytes());
    for &v in layer.row_scale().iter().chain(layer.col_scale()) {
        out.extend_from_slice(&v.to_le_bytes());
    }
    pad32(&mut out);
    for &w in layer.bits().padded_words() {
        out.extend_from_slice(&w.to_le_bytes());
    }
    Ok(out)
}

fn decode_sign_layer_v3(
    body: &[u8],
    base: usize,
    art: Option<&Arc<MappedArtifact>>,
) -> Result<SignScaledLayer> {
    let mut cur = Cur::borrowing(body, base, art);
    let d_out = cur.u32()? as usize;
    let d_in = cur.u32()? as usize;
    let declared_bits = cur.u64()?;
    if d_out == 0 || d_in == 0 {
        bail!("degenerate sign layer shape {d_out}x{d_in}");
    }
    let row = cur.scales(d_out)?;
    let col = cur.scales(d_in)?;
    let bits = cur.padded_plane(d_out, d_in)?;
    cur.done("SGNS")?;
    SignScaledLayer::try_new(bits, row, col, declared_bits)
}

fn encode_dense_layer(layer: &DenseScaledLayer) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    out.extend_from_slice(&u32_of(layer.d_out(), "d_out")?.to_le_bytes());
    out.extend_from_slice(&u32_of(layer.d_in(), "d_in")?.to_le_bytes());
    out.extend_from_slice(&layer.declared_bits().to_le_bytes());
    let w = layer.weight();
    for i in 0..w.rows() {
        for &v in w.row(i) {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    Ok(out)
}

fn decode_dense_layer(body: &[u8]) -> Result<DenseScaledLayer> {
    let mut cur = Cur::new(body);
    let d_out = cur.u32()? as usize;
    let d_in = cur.u32()? as usize;
    let declared_bits = cur.u64()?;
    if d_out == 0 || d_in == 0 {
        bail!("degenerate dense layer shape {d_out}x{d_in}");
    }
    let n = d_out.checked_mul(d_in).context("dense element count overflow")?;
    let data = cur.f32s(n)?;
    cur.done("DNSE")?;
    DenseScaledLayer::try_new(Mat::from_vec(d_out, d_in, data), declared_bits)
}

fn encode_lowrank_layer(layer: &LowRankFpLayer) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    out.extend_from_slice(&u32_of(layer.d_out(), "d_out")?.to_le_bytes());
    out.extend_from_slice(&u32_of(layer.d_in(), "d_in")?.to_le_bytes());
    out.extend_from_slice(&u32_of(layer.rank(), "rank")?.to_le_bytes());
    out.extend_from_slice(&layer.declared_bits().to_le_bytes());
    for m in [layer.u(), layer.vt()] {
        for i in 0..m.rows() {
            for &v in m.row(i) {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
    }
    Ok(out)
}

fn decode_lowrank_layer(body: &[u8]) -> Result<LowRankFpLayer> {
    let mut cur = Cur::new(body);
    let d_out = cur.u32()? as usize;
    let d_in = cur.u32()? as usize;
    let rank = cur.u32()? as usize;
    let declared_bits = cur.u64()?;
    if d_out == 0 || d_in == 0 || rank == 0 {
        bail!("degenerate low-rank layer shape {d_out}x{d_in} rank {rank}");
    }
    let u_n = d_out.checked_mul(rank).context("U element count overflow")?;
    let vt_n = rank.checked_mul(d_in).context("Vᵀ element count overflow")?;
    let u = cur.f32s(u_n)?;
    let vt = cur.f32s(vt_n)?;
    cur.done("LOWR")?;
    LowRankFpLayer::try_new(
        Mat::from_vec(d_out, rank, u),
        Mat::from_vec(rank, d_in, vt),
        declared_bits,
    )
}

/// Bounds-checked little-endian cursor over one section payload. Vector
/// reads verify the byte count against the remaining payload *before*
/// allocating, so a corrupt length field cannot trigger a huge allocation.
///
/// In **borrowing** mode ([`borrowing`](Self::borrowing)) the cursor also
/// knows the payload's absolute container offset and (optionally) the
/// mapping it came from, so [`scales`](Self::scales) and
/// [`padded_plane`](Self::padded_plane) can hand out views that borrow
/// the mapped bytes in place, copying only when no mapping is available
/// or the bytes land misaligned.
struct Cur<'a> {
    b: &'a [u8],
    pos: usize,
    /// Absolute offset of `b[0]` within the container (= within the
    /// mapping, since the reader sees the whole mapped file).
    base: usize,
    art: Option<&'a Arc<MappedArtifact>>,
}

impl<'a> Cur<'a> {
    fn new(b: &'a [u8]) -> Self {
        Self { b, pos: 0, base: 0, art: None }
    }

    fn borrowing(b: &'a [u8], base: usize, art: Option<&'a Arc<MappedArtifact>>) -> Self {
        Self { b, pos: 0, base, art }
    }

    /// Skip to the next 32-byte boundary relative to the payload start,
    /// requiring the skipped filler to be zero (matching the encoder).
    fn align32(&mut self) -> Result<()> {
        let skip = (32 - self.pos % 32) % 32;
        let at = self.pos;
        if self.take(skip)?.iter().any(|&b| b != 0) {
            bail!("nonzero alignment filler at payload offset {at}");
        }
        Ok(())
    }

    /// An `n`-float scale vector: borrowed from the mapping when one is
    /// attached (file f32s are little-endian and 4-aligned by the v3
    /// layout), copied otherwise.
    fn scales(&mut self, n: usize) -> Result<ScaleVec> {
        if let Some(art) = self.art {
            let need = n.checked_mul(4).context("f32 vector length overflow")?;
            if need <= self.b.len() - self.pos {
                if let Ok(v) = MappedF32s::new(art, self.base + self.pos, n) {
                    self.pos += need;
                    return Ok(ScaleVec::Mapped(v));
                }
            }
        }
        Ok(self.f32s(n)?.into())
    }

    /// A `rows × cols` bit-plane stored at the padded in-memory stride
    /// behind a 32-byte alignment boundary: borrowed from the mapping
    /// when attached and aligned (the plane bytes *are* the kernel
    /// operand), copied-and-restrided otherwise. Pad words and pad bits
    /// must be zero on both paths — dirty padding is corruption, not a
    /// fallback trigger.
    fn padded_plane(&mut self, rows: usize, cols: usize) -> Result<BitMatrix> {
        self.align32()?;
        let stride = BitMatrix::padded_stride(cols);
        let n_words = rows.checked_mul(stride).context("bit-plane word count overflow")?;
        let need = n_words.checked_mul(8).context("bit-plane byte count overflow")?;
        if let Some(art) = self.art {
            if need <= self.b.len() - self.pos {
                if let Ok(mw) = MappedWords::new(art, self.base + self.pos, n_words) {
                    let m = BitMatrix::from_mapped(rows, cols, mw)?;
                    self.pos += need;
                    return Ok(m);
                }
            }
        }
        let words = self.u64s(n_words)?;
        let tight = cols.div_ceil(64);
        let mut out = Vec::with_capacity(rows * tight);
        for r in 0..rows {
            let row = &words[r * stride..(r + 1) * stride];
            if row[tight..].iter().any(|&w| w != 0) {
                bail!("padded bit-plane {rows}x{cols} has nonzero pad words in row {r}");
            }
            out.extend_from_slice(&row[..tight]);
        }
        // from_words re-checks the in-word padding bits past `cols`.
        BitMatrix::from_words(rows, cols, out)
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if n > self.b.len() - self.pos {
            bail!(
                "section payload truncated: need {n} bytes at offset {}, have {}",
                self.pos,
                self.b.len() - self.pos
            );
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let raw = self.take(n.checked_mul(4).context("f32 vector length overflow")?)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().expect("4 bytes")))
            .collect())
    }

    fn u64s(&mut self, n: usize) -> Result<Vec<u64>> {
        let raw = self.take(n.checked_mul(8).context("u64 vector length overflow")?)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().expect("8 bytes")))
            .collect())
    }

    fn done(&self, what: &str) -> Result<()> {
        if self.pos != self.b.len() {
            bail!("{what} section has {} undeclared trailing bytes", self.b.len() - self.pos);
        }
        Ok(())
    }
}
