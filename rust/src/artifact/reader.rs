//! Validating `.lb2` section reader.

use super::{
    crc_finish, crc_update, CRC_INIT, FORMAT_VERSION, FORMAT_VERSION_V1, FORMAT_VERSION_V3, MAGIC,
    TAG_END,
};
use anyhow::{bail, Result};
use std::ops::Range;

/// Reads a `.lb2` container from a byte slice.
///
/// All validation happens in [`new`](Self::new), before any section is
/// handed out: magic, format version (1 or 2 — payload decoding dispatches
/// on [`version`](Self::version)), every section length bounds-checked
/// against the buffer, the trailer's section count, the CRC32 of every
/// byte preceding the CRC field, and absence of trailing garbage. A file
/// truncated at *any* byte or with *any* bit flipped fails here with
/// `Err` — never a panic, never silently-wrong sections.
pub struct ArtifactReader<'a> {
    buf: &'a [u8],
    version: u32,
    sections: Vec<([u8; 4], Range<usize>)>,
    next: usize,
}

impl<'a> ArtifactReader<'a> {
    /// Open and fully validate a container.
    pub fn new(buf: &'a [u8]) -> Result<Self> {
        if buf.len() < MAGIC.len() + 4 {
            bail!("artifact truncated: {} bytes is shorter than the header", buf.len());
        }
        if buf[..4] != MAGIC {
            bail!("bad magic {:02x?} (not a .lb2 artifact)", &buf[..4]);
        }
        let version = u32::from_le_bytes(buf[4..8].try_into().expect("4 bytes"));
        if version != FORMAT_VERSION && version != FORMAT_VERSION_V1 && version != FORMAT_VERSION_V3
        {
            bail!(
                "unsupported .lb2 format version {version} (this build reads {FORMAT_VERSION_V1}-{FORMAT_VERSION_V3})"
            );
        }

        let mut sections = Vec::new();
        let mut pos = 8usize;
        loop {
            if buf.len() - pos < 12 {
                bail!("artifact truncated at byte {pos}: missing section header");
            }
            let tag: [u8; 4] = buf[pos..pos + 4].try_into().expect("4 bytes");
            let len = u64::from_le_bytes(buf[pos + 4..pos + 12].try_into().expect("8 bytes"));
            let body = pos + 12;
            let Ok(len) = usize::try_from(len) else {
                bail!("section {tag:?} at byte {pos} declares an impossible length {len}");
            };
            if len > buf.len() - body {
                bail!(
                    "artifact truncated at byte {pos}: section {} declares {len} bytes but only {} remain",
                    tag_name(tag),
                    buf.len() - body
                );
            }
            if tag == TAG_END {
                if len != 8 {
                    bail!("trailer length must be 8, got {len}");
                }
                let count = u32::from_le_bytes(buf[body..body + 4].try_into().expect("4 bytes"));
                if count as usize != sections.len() {
                    bail!(
                        "trailer section count {count} disagrees with the {} sections present",
                        sections.len()
                    );
                }
                let crc_at = body + 4;
                let stored = u32::from_le_bytes(buf[crc_at..crc_at + 4].try_into().expect("4 bytes"));
                let computed = crc_finish(crc_update(CRC_INIT, &buf[..crc_at]));
                if stored != computed {
                    bail!("CRC mismatch: stored {stored:#010x}, computed {computed:#010x}");
                }
                if crc_at + 4 != buf.len() {
                    bail!("{} trailing bytes after the trailer", buf.len() - crc_at - 4);
                }
                break;
            }
            sections.push((tag, body..body + len));
            pos = body + len;
        }
        Ok(Self { buf, version, sections, next: 0 })
    }

    /// The container's declared format version (1, 2, or 3) — payload
    /// decoders dispatch on this.
    pub fn version(&self) -> u32 {
        self.version
    }

    /// Number of sections (trailer excluded).
    pub fn section_count(&self) -> usize {
        self.sections.len()
    }

    /// The next `(tag, payload)` pair, in file order; `None` when done.
    pub fn next_section(&mut self) -> Option<([u8; 4], &'a [u8])> {
        let (tag, range) = self.sections.get(self.next)?;
        self.next += 1;
        Some((*tag, &self.buf[range.clone()]))
    }

    /// Like [`next_section`](Self::next_section), but also yields the
    /// payload's **absolute byte range** in the container — the mmap load
    /// path builds borrowed views from these offsets (file offset ≡
    /// mapping offset, since the reader sees the whole mapped file).
    pub fn next_section_range(&mut self) -> Option<([u8; 4], &'a [u8], Range<usize>)> {
        let (tag, range) = self.sections.get(self.next)?;
        self.next += 1;
        Some((*tag, &self.buf[range.clone()], range.clone()))
    }

    /// The validated section table, in file order, without consuming the
    /// cursor: one [`SectionEntry`] per section (trailer excluded), each
    /// carrying the tag, the payload's absolute byte offset, and its
    /// length. This is the primitive range loading builds on — a tracker
    /// walks the table to plan shards without decoding a single payload,
    /// and a peer seeks straight to its layer range. The 12-byte section
    /// header (tag + u64 length) sits at `offset - 12`.
    pub fn sections(&self) -> impl ExactSizeIterator<Item = SectionEntry> + '_ {
        self.sections
            .iter()
            .map(|(tag, r)| SectionEntry { tag: *tag, offset: r.start, len: r.len() })
    }
}

/// One row of the `.lb2` section table as exposed by
/// [`ArtifactReader::sections`]: where a section's payload lives and how
/// big it is, with no payload bytes attached.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SectionEntry {
    /// Four-byte section tag (`META`, `STAK`, `METH`, `PADD`, ...).
    pub tag: [u8; 4],
    /// Absolute byte offset of the payload within the container.
    pub offset: usize,
    /// Payload length in bytes (zero-length sections are legal).
    pub len: usize,
}

impl SectionEntry {
    /// The payload's absolute byte range in the container.
    pub fn range(&self) -> Range<usize> {
        self.offset..self.offset + self.len
    }
}

/// Printable form of a section tag for error messages.
fn tag_name(tag: [u8; 4]) -> String {
    tag.iter()
        .map(|&b| {
            if b.is_ascii_graphic() {
                (b as char).to_string()
            } else {
                format!("\\x{b:02x}")
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::super::ArtifactWriter;
    use super::*;

    fn tiny() -> Vec<u8> {
        let mut w = ArtifactWriter::new(Vec::new()).unwrap();
        w.section(*b"AAAA", b"first").unwrap();
        w.section(*b"BBBB", &[]).unwrap();
        w.finish().unwrap()
    }

    #[test]
    fn writer_reader_roundtrip() {
        let bytes = tiny();
        let mut r = ArtifactReader::new(&bytes).unwrap();
        assert_eq!(r.section_count(), 2);
        assert_eq!(r.next_section().unwrap(), (*b"AAAA", &b"first"[..]));
        assert_eq!(r.next_section().unwrap(), (*b"BBBB", &b""[..]));
        assert!(r.next_section().is_none());
    }

    #[test]
    fn every_truncation_errs() {
        let bytes = tiny();
        for len in 0..bytes.len() {
            assert!(ArtifactReader::new(&bytes[..len]).is_err(), "prefix of {len} bytes");
        }
    }

    #[test]
    fn every_bit_flip_errs() {
        let bytes = tiny();
        for i in 0..bytes.len() {
            let mut bad = bytes.clone();
            bad[i] ^= 0x01;
            assert!(ArtifactReader::new(&bad).is_err(), "flip at byte {i}");
        }
    }

    #[test]
    fn trailing_garbage_errs() {
        let mut bytes = tiny();
        bytes.push(0);
        assert!(ArtifactReader::new(&bytes).is_err());
    }

    #[test]
    fn end_tag_is_reserved_for_the_trailer() {
        let mut w = ArtifactWriter::new(Vec::new()).unwrap();
        assert!(w.section(TAG_END, b"nope").is_err());
    }

    /// Section-table offset arithmetic, checked against hand-computed file
    /// layout at every format version, with a PADD filler in the middle.
    /// Layout: magic(4) + version(4), then per section tag(4) + len(8) +
    /// payload — so section k's payload starts 12 bytes after its header.
    #[test]
    fn sections_offset_arithmetic_across_versions() {
        use super::super::{FORMAT_VERSION_V1, FORMAT_VERSION_V3, TAG_PAD};
        for version in [FORMAT_VERSION_V1, FORMAT_VERSION, FORMAT_VERSION_V3] {
            let mut w = ArtifactWriter::with_version(Vec::new(), version).unwrap();
            w.section(*b"AAAA", b"abcde").unwrap(); // 5 bytes
            w.section(TAG_PAD, &[0u8; 7]).unwrap(); // filler, 7 bytes
            w.section(*b"BBBB", &[]).unwrap(); // zero-length
            w.section(*b"CCCC", &[9u8; 32]).unwrap();
            let bytes = w.finish().unwrap();
            let r = ArtifactReader::new(&bytes).unwrap();
            assert_eq!(r.version(), version);
            let table: Vec<SectionEntry> = r.sections().collect();
            // Hand-computed: header is 8 bytes, each payload starts 12
            // bytes after the previous payload's end.
            let expected = [
                (*b"AAAA", 8 + 12, 5),
                (TAG_PAD, 8 + 12 + 5 + 12, 7),
                (*b"BBBB", 8 + 12 + 5 + 12 + 7 + 12, 0),
                (*b"CCCC", 8 + 12 + 5 + 12 + 7 + 12 + 12, 32),
            ];
            assert_eq!(table.len(), expected.len());
            for (got, (tag, offset, len)) in table.iter().zip(expected) {
                assert_eq!((got.tag, got.offset, got.len), (tag, offset, len), "v{version}");
                assert_eq!(got.range(), offset..offset + len);
                // The table's offsets index the real payload bytes.
                assert_eq!(&bytes[got.range()], {
                    let mut rr = ArtifactReader::new(&bytes).unwrap();
                    let mut payload = None;
                    while let Some((t, p)) = rr.next_section() {
                        if t == tag && payload.is_none() && p.len() == len {
                            payload = Some(p);
                        }
                    }
                    payload.expect("section present")
                });
            }
            // The trailer is excluded and the last payload ends 12 bytes
            // (END header) + 8 (count+crc) before EOF.
            let last = table.last().unwrap();
            assert_eq!(last.offset + last.len + 12 + 8, bytes.len());
        }
    }

    /// `sections()` does not consume the cursor: the table can be walked
    /// before, during, and after `next_section` iteration.
    #[test]
    fn sections_is_cursor_independent() {
        let bytes = tiny();
        let mut r = ArtifactReader::new(&bytes).unwrap();
        assert_eq!(r.sections().len(), 2);
        r.next_section().unwrap();
        assert_eq!(r.sections().len(), 2);
        let tags: Vec<[u8; 4]> = r.sections().map(|s| s.tag).collect();
        assert_eq!(tags, vec![*b"AAAA", *b"BBBB"]);
    }
}
