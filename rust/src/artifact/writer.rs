//! Streaming `.lb2` section writer.

use super::{crc_finish, crc_update, CRC_INIT, FORMAT_VERSION, MAGIC, TAG_END};
use anyhow::{bail, Result};
use std::io::Write;

/// Writes a `.lb2` container one section at a time — the whole artifact is
/// never materialized in memory; only the largest single section payload
/// is. The running CRC32 covers every byte emitted (magic and version
/// included), so the trailer written by [`finish`](Self::finish) seals the
/// exact byte stream the sink received.
///
/// # Examples
///
/// ```
/// use littlebit2::artifact::{ArtifactReader, ArtifactWriter};
///
/// let mut w = ArtifactWriter::new(Vec::new()).unwrap();
/// w.section(*b"DEMO", b"payload").unwrap();
/// let bytes = w.finish().unwrap();
/// let mut r = ArtifactReader::new(&bytes).unwrap();
/// assert_eq!(r.next_section().unwrap(), (*b"DEMO", &b"payload"[..]));
/// ```
pub struct ArtifactWriter<W: Write> {
    sink: W,
    crc: u32,
    sections: u32,
    offset: usize,
}

impl<W: Write> ArtifactWriter<W> {
    /// Start a container: writes the magic and the current format version
    /// ([`FORMAT_VERSION`]).
    pub fn new(sink: W) -> Result<Self> {
        Self::with_version(sink, FORMAT_VERSION)
    }

    /// Start a container at an explicit format version — the legacy-v1
    /// emitter ([`super::write_stack_v1`]) and the aligned-v3 emitter
    /// ([`super::write_method_stack_aligned`]) use this; everything else
    /// writes the current version via [`new`](Self::new).
    pub fn with_version(sink: W, version: u32) -> Result<Self> {
        if version != FORMAT_VERSION
            && version != super::FORMAT_VERSION_V1
            && version != super::FORMAT_VERSION_V3
        {
            anyhow::bail!("cannot write unknown .lb2 format version {version}");
        }
        let mut w = Self { sink, crc: CRC_INIT, sections: 0, offset: 0 };
        w.emit(&MAGIC)?;
        w.emit(&version.to_le_bytes())?;
        Ok(w)
    }

    fn emit(&mut self, bytes: &[u8]) -> Result<()> {
        self.sink.write_all(bytes)?;
        self.crc = crc_update(self.crc, bytes);
        self.offset += bytes.len();
        Ok(())
    }

    /// File offset of the next byte to be written (bytes emitted so far).
    /// The aligned-v3 emitter sizes its `PADD` filler from this so that
    /// the following section's payload (which starts 12 bytes after the
    /// section itself: tag + u64 length) lands 32-byte aligned.
    pub fn offset(&self) -> usize {
        self.offset
    }

    /// Append one section. `TAG_END` is reserved for the trailer.
    pub fn section(&mut self, tag: [u8; 4], payload: &[u8]) -> Result<()> {
        if tag == TAG_END {
            bail!("section tag {:?} is reserved for the trailer", TAG_END);
        }
        self.emit(&tag)?;
        self.emit(&(payload.len() as u64).to_le_bytes())?;
        self.emit(payload)?;
        self.sections = self
            .sections
            .checked_add(1)
            .ok_or_else(|| anyhow::anyhow!("section count overflow"))?;
        Ok(())
    }

    /// Seal the container: writes the trailer (section count + CRC32 of
    /// everything before the CRC field) and returns the sink.
    pub fn finish(mut self) -> Result<W> {
        self.emit(&TAG_END)?;
        self.emit(&8u64.to_le_bytes())?;
        let count = self.sections;
        self.emit(&count.to_le_bytes())?;
        let crc = crc_finish(self.crc);
        self.sink.write_all(&crc.to_le_bytes())?;
        self.sink.flush()?;
        Ok(self.sink)
    }
}
