//! Versioned binary model artifacts — the `.lb2` format.
//!
//! PR 1–2 made the engine fast; this module makes it *deployable*: a
//! compressed model is quantized **once** (`littlebit2 compress --out
//! model.lb2`), persisted as a durable artifact, and then served from any
//! number of worker processes (`littlebit2 serve --model model.lb2`) — the
//! OneBit/BTC-LLM-style sign-matrix + scale artifact contract, specialized
//! to the tri-scale residual stack this reproduction deploys.
//!
//! ## Container layout (versions 1–2, all integers little-endian)
//!
//! ```text
//! ┌──────────────────────────────────────────────────────────────────┐
//! │ magic   4 B   89 4C 42 32  ("\x89LB2" — high bit catches text    │
//! │                             mangling, PNG-style)                 │
//! │ version 4 B   u32 = 1 or 2                                       │
//! ├──────────────────────────────────────────────────────────────────┤
//! │ section ×N:   tag 4 B │ len u64 │ payload len B                  │
//! ├──────────────────────────────────────────────────────────────────┤
//! │ trailer:      tag "END\0" │ len u64 = 8 │ section count u32      │
//! │               │ CRC32 u32                                        │
//! └──────────────────────────────────────────────────────────────────┘
//! ```
//!
//! The tag+length framing *is* the section table: [`ArtifactReader`] walks
//! it once at open, bounds-checking every length, verifies the trailer's
//! section count, and checks the IEEE CRC32 of **every byte before the CRC
//! field itself** (magic and version included). Truncation at any byte,
//! a flipped bit anywhere, unknown framing, or trailing garbage after the
//! trailer all fail with `Err` before a single section is handed out —
//! never a panic, never silently-wrong weights.
//!
//! ## Model payload, format v2 (what [`crate::model::MethodStack::save`]
//! and [`crate::model::PackedStack::save`] write)
//!
//! ```text
//! "META"  tool-info bytes (crate version string; informational only)
//! "STAK"  shape header: u32 depth, then depth × (u32 d_in, u32 d_out,
//!         u32 n_paths) — n_paths is the residual path count for packed
//!         layers and 0 for every other serving form; cross-checked
//!         against the layer sections on load
//! per layer, in chain order:
//!   "METH"  u8 variant code │ u8 name_len │ method name (ASCII,
//!           e.g. "littlebit2", "onebit") — codes: 1 = packed,
//!           2 = sign-scaled, 3 = dense-scaled, 4 = lowrank-fp; the
//!           code pins the tag of the payload section that follows
//!   then exactly one payload section:
//!   "LAYR"  (code 1) packed tri-scale residual — identical encoding to
//!           format v1:
//!             u32 n_paths
//!             per path: u32 d_out │ u32 d_in │ u32 rank
//!                       h  d_out × f32   (row scale)
//!                       l  rank  × f32   (latent scale)
//!                       g  d_in  × f32   (column scale)
//!                       U_b   d_out·⌈rank/64⌉ × u64  (packed bit-plane,
//!                                                     BitMatrix words verbatim)
//!                       V_bᵀ  rank·⌈d_in/64⌉  × u64  (pre-transposed)
//!   "SGNS"  (code 2) one-level sign layer (OneBit / ARB family):
//!             u32 d_out │ u32 d_in │ u64 declared_bits
//!             row  d_out × f32 │ col  d_in × f32
//!             S    d_out·⌈d_in/64⌉ × u64  (packed sign(W), verbatim)
//!   "DNSE"  (code 3) dense reconstruction (RTN / BiLLM):
//!             u32 d_out │ u32 d_in │ u64 declared_bits
//!             W    d_out·d_in × f32  (row-major)
//!   "LOWR"  (code 4) FP16 truncated-SVD factors (Strategy A):
//!             u32 d_out │ u32 d_in │ u32 rank │ u64 declared_bits
//!             U    d_out·rank × f32 │ Vᵀ  rank·d_in × f32  (row-major)
//! ```
//!
//! A **format v1** payload is the v2 layout minus the METH sections (LAYR
//! only — the PR 3/4 era wrote packed stacks exclusively); the reader
//! decodes it as an all-`Packed` `littlebit2` stack with bit-identical
//! forwards, and [`write_stack_v1`] keeps the v1 encoding producible for
//! back-compat fixtures.
//!
//! ## Format v3: the "aligned" encoding (`compress --aligned`)
//!
//! v3 carries the **same sections in the same order** as v2 and decodes to
//! the same stack; what changes is only *where bytes sit* so that a
//! memory-mapped file region can be handed to the kernels as-is:
//!
//! * Bit-planes inside LAYR/SGNS payloads are stored at the **padded
//!   in-memory row stride** (`BitMatrix::padded_stride(cols)` u64 words
//!   per row, pad words zero) instead of the tight `⌈cols/64⌉` stride, so
//!   a plane's file bytes are byte-for-byte the kernel operand.
//! * Inside a v3 LAYR/SGNS payload, each bit-plane is preceded by zero
//!   bytes padding its offset (relative to the payload start) to a
//!   multiple of 32. Padded-stride planes are themselves a multiple of
//!   32 bytes, so consecutive planes stay aligned.
//! * Before each LAYR/SGNS section the writer emits a `PADD` section
//!   (zero bytes, length 0–31) whenever needed so that the *next*
//!   section's payload starts at a file offset that is a multiple of 32.
//!   Since `mmap` bases are page-aligned, a 32-aligned file offset is a
//!   32-aligned address. Readers skip `PADD` sections wherever they
//!   appear, in every version.
//! * DNSE/LOWR payloads are unchanged (they decode into owned matrices
//!   regardless), as are META/STAK/METH.
//!
//! An eager load of a v3 artifact copies the padded planes verbatim; an
//! mmap load ([`load_method_stack_mmap`]) borrows planes and scale vectors
//! straight out of the mapping (falling back to copy-and-restride for
//! v1/v2 or any payload that lands misaligned), so all serving workers —
//! and all serving *processes* — share one page-cache copy of the weights.
//!
//! Bit-planes are stored as the kernel-native packed `u64` words, so
//! loading is a straight copy — no re-packing, no float round-trips — and
//! a loaded stack's `forward_batch` is **bit-identical** to the stack that
//! was saved (asserted by `tests/artifact_roundtrip.rs` and
//! `tests/method_stack.rs`, the latter per method; `tests/mmap_load.rs`
//! extends the contract across v3 and the borrowed load path).

mod reader;
mod stack;
mod writer;

pub use reader::{ArtifactReader, SectionEntry};
pub use stack::{
    load_method_stack, load_method_stack_mmap, load_stack, load_stack_mmap, load_stack_shapes,
    read_method_stack, read_method_stack_mapped, read_method_stack_range,
    read_method_stack_range_mapped, read_stack, read_stack_shapes, save_method_stack,
    save_method_stack_aligned, save_stack, save_stack_aligned, write_method_stack,
    write_method_stack_aligned, write_stack, write_stack_v1, StackShapes, StackStreamWriter,
};
pub use writer::ArtifactWriter;

/// File magic: `\x89LB2`. The non-ASCII lead byte makes accidental
/// text-mode transcoding fail the very first check.
pub const MAGIC: [u8; 4] = [0x89, b'L', b'B', b'2'];

/// Container format version written by this build (v2: method-generic
/// stacks — a METHOD tag plus a per-variant payload section per layer).
pub const FORMAT_VERSION: u32 = 2;

/// The "aligned" encoding (`compress --aligned`): v2's sections with
/// bit-planes at the padded in-memory stride and every plane/payload
/// 32-byte aligned in the file, so an mmap of the artifact is directly
/// servable. See the module docs for the exact padding rules.
pub const FORMAT_VERSION_V3: u32 = 3;

/// The PR 3/4 era format: packed tri-scale layers only, no METHOD tags.
/// Still fully readable (a v1 artifact loads as an all-`Packed`
/// `littlebit2` stack, bit-identically); [`write_stack_v1`] keeps the
/// encoding producible for back-compat fixtures.
pub const FORMAT_VERSION_V1: u32 = 1;

/// Tool-info section (informational bytes; content is not validated).
pub const TAG_META: [u8; 4] = *b"META";
/// Shape-header section: depth + per-layer `(d_in, d_out, n_paths)`.
pub const TAG_STACK: [u8; 4] = *b"STAK";
/// One packed tri-scale layer (v1: repeated `depth` times; v2: the
/// payload section of a `Packed` METHOD entry).
pub const TAG_LAYER: [u8; 4] = *b"LAYR";
/// v2 per-layer method header: variant code + method name. Each METH
/// section is immediately followed by its variant's payload section.
pub const TAG_METHOD: [u8; 4] = *b"METH";
/// v2 payload: one-level sign-GEMM layer (`row ⊙ (S · (col ⊙ x))`).
pub const TAG_SIGN: [u8; 4] = *b"SGNS";
/// v2 payload: dense f32 reconstruction with declared storage bits.
pub const TAG_DENSE: [u8; 4] = *b"DNSE";
/// v2 payload: FP16-rounded low-rank factors (`U`, `Vᵀ`).
pub const TAG_LOWRANK: [u8; 4] = *b"LOWR";
/// v3 alignment filler: a zero-byte payload (length 0–31) emitted so the
/// next section's payload starts at a 32-byte-aligned file offset.
/// Carries no data; readers of every version skip it wherever it appears.
pub const TAG_PAD: [u8; 4] = *b"PADD";
/// Trailer: section count + CRC32. Always last; nothing may follow it.
pub const TAG_END: [u8; 4] = *b"END\0";

/// IEEE CRC32 lookup table (reflected, polynomial `0xEDB88320`).
const CRC_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// Feed `bytes` into a running CRC32 state (start from
/// [`CRC_INIT`], finish with [`crc_finish`]).
pub(crate) fn crc_update(mut state: u32, bytes: &[u8]) -> u32 {
    for &b in bytes {
        state = CRC_TABLE[((state ^ b as u32) & 0xFF) as usize] ^ (state >> 8);
    }
    state
}

pub(crate) const CRC_INIT: u32 = 0xFFFF_FFFF;

pub(crate) fn crc_finish(state: u32) -> u32 {
    state ^ 0xFFFF_FFFF
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The IEEE CRC32 check value: crc32(b"123456789") = 0xCBF43926.
    #[test]
    fn crc32_check_value() {
        let crc = crc_finish(crc_update(CRC_INIT, b"123456789"));
        assert_eq!(crc, 0xCBF4_3926);
    }

    #[test]
    fn crc32_is_incremental() {
        let whole = crc_finish(crc_update(CRC_INIT, b"hello world"));
        let split = crc_finish(crc_update(crc_update(CRC_INIT, b"hello "), b"world"));
        assert_eq!(whole, split);
    }
}
