//! Versioned binary model artifacts — the `.lb2` format.
//!
//! PR 1–2 made the engine fast; this module makes it *deployable*: a
//! compressed model is quantized **once** (`littlebit2 compress --out
//! model.lb2`), persisted as a durable artifact, and then served from any
//! number of worker processes (`littlebit2 serve --model model.lb2`) — the
//! OneBit/BTC-LLM-style sign-matrix + scale artifact contract, specialized
//! to the tri-scale residual stack this reproduction deploys.
//!
//! ## Container layout (version 1, all integers little-endian)
//!
//! ```text
//! ┌──────────────────────────────────────────────────────────────────┐
//! │ magic   4 B   89 4C 42 32  ("\x89LB2" — high bit catches text    │
//! │                             mangling, PNG-style)                 │
//! │ version 4 B   u32 = 1                                            │
//! ├──────────────────────────────────────────────────────────────────┤
//! │ section ×N:   tag 4 B │ len u64 │ payload len B                  │
//! ├──────────────────────────────────────────────────────────────────┤
//! │ trailer:      tag "END\0" │ len u64 = 8 │ section count u32      │
//! │               │ CRC32 u32                                        │
//! └──────────────────────────────────────────────────────────────────┘
//! ```
//!
//! The tag+length framing *is* the section table: [`ArtifactReader`] walks
//! it once at open, bounds-checking every length, verifies the trailer's
//! section count, and checks the IEEE CRC32 of **every byte before the CRC
//! field itself** (magic and version included). Truncation at any byte,
//! a flipped bit anywhere, unknown framing, or trailing garbage after the
//! trailer all fail with `Err` before a single section is handed out —
//! never a panic, never silently-wrong weights.
//!
//! ## Model payload (what [`crate::model::PackedStack::save`] writes)
//!
//! ```text
//! "META"  tool-info bytes (crate version string; informational only)
//! "STAK"  shape header: u32 depth, then depth × (u32 d_in, u32 d_out,
//!         u32 n_paths) — the ArchSpec-style shape table, cross-checked
//!         against the layer sections on load
//! "LAYR"  × depth, in chain order:
//!           u32 n_paths
//!           per path: u32 d_out │ u32 d_in │ u32 rank
//!                     h  d_out × f32   (row scale)
//!                     l  rank  × f32   (latent scale)
//!                     g  d_in  × f32   (column scale)
//!                     U_b   d_out·⌈rank/64⌉ × u64  (packed bit-plane,
//!                                                   BitMatrix words verbatim)
//!                     V_bᵀ  rank·⌈d_in/64⌉  × u64  (pre-transposed, verbatim)
//! ```
//!
//! Bit-planes are stored as the kernel-native packed `u64` words, so
//! loading is a straight copy — no re-packing, no float round-trips — and
//! a loaded stack's `forward_batch` is **bit-identical** to the stack that
//! was saved (asserted by `tests/artifact_roundtrip.rs`).

mod reader;
mod stack;
mod writer;

pub use reader::ArtifactReader;
pub use stack::{load_stack, read_stack, save_stack, write_stack, StackStreamWriter};
pub use writer::ArtifactWriter;

/// File magic: `\x89LB2`. The non-ASCII lead byte makes accidental
/// text-mode transcoding fail the very first check.
pub const MAGIC: [u8; 4] = [0x89, b'L', b'B', b'2'];

/// Container format version written by this build.
pub const FORMAT_VERSION: u32 = 1;

/// Tool-info section (informational bytes; content is not validated).
pub const TAG_META: [u8; 4] = *b"META";
/// Shape-header section: depth + per-layer `(d_in, d_out, n_paths)`.
pub const TAG_STACK: [u8; 4] = *b"STAK";
/// One packed layer (repeated `depth` times, in chain order).
pub const TAG_LAYER: [u8; 4] = *b"LAYR";
/// Trailer: section count + CRC32. Always last; nothing may follow it.
pub const TAG_END: [u8; 4] = *b"END\0";

/// IEEE CRC32 lookup table (reflected, polynomial `0xEDB88320`).
const CRC_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// Feed `bytes` into a running CRC32 state (start from
/// [`CRC_INIT`], finish with [`crc_finish`]).
pub(crate) fn crc_update(mut state: u32, bytes: &[u8]) -> u32 {
    for &b in bytes {
        state = CRC_TABLE[((state ^ b as u32) & 0xFF) as usize] ^ (state >> 8);
    }
    state
}

pub(crate) const CRC_INIT: u32 = 0xFFFF_FFFF;

pub(crate) fn crc_finish(state: u32) -> u32 {
    state ^ 0xFFFF_FFFF
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The IEEE CRC32 check value: crc32(b"123456789") = 0xCBF43926.
    #[test]
    fn crc32_check_value() {
        let crc = crc_finish(crc_update(CRC_INIT, b"123456789"));
        assert_eq!(crc, 0xCBF4_3926);
    }

    #[test]
    fn crc32_is_incremental() {
        let whole = crc_finish(crc_update(CRC_INIT, b"hello world"));
        let split = crc_finish(crc_update(crc_update(CRC_INIT, b"hello "), b"world"));
        assert_eq!(whole, split);
    }
}
