//! Per-layer storage formulas, Eqs. 21–26 of Appendix H.

/// Eq. 21 — k-bit group RTN (GPTQ / EfficientQAT): `k·N + groups·(16+16)`
/// bits (FP16 scale + zero per group). Groups are **per row** — the
/// quantizer scopes each group to `group` consecutive in-row weights, so a
/// ragged final group exists in *every* row: `groups = d_out·⌈d_in/g⌉`.
/// (The accounting previously pooled the tail across rows as `⌈N/g⌉`,
/// undercounting one scale pair per row whenever `d_in % g ≠ 0`; identical
/// for the divisible shapes of Table 1. See EXPERIMENTS.md §Artifact.)
pub fn rtn_bits(d_out: usize, d_in: usize, k: u32, group: usize) -> u64 {
    let n = (d_out * d_in) as u64;
    let groups = d_out as u64 * (d_in as u64).div_ceil(group as u64);
    n * k as u64 + groups * 32
}

/// Eq. 22 — OneBit: `N + 16·(d_in + d_out)`.
pub fn onebit_bits(d_out: usize, d_in: usize) -> u64 {
    (d_out * d_in) as u64 + 16 * (d_in + d_out) as u64
}

/// Eq. 23 — BiLLM with salient columns `c`, block size `k`
/// (second-order on salient, first-order elsewhere, plus bitmaps):
/// `2nc + ⌈m/k⌉·3n·16 + n(m−c) + ⌈m/k⌉·2n·16·2 + n·m + m`
/// with `n = d_out`, `m = d_in`.
pub fn billm_bits(d_out: usize, d_in: usize, c: usize, k: usize) -> u64 {
    let n = d_out as u64;
    let m = d_in as u64;
    let c = (c as u64).min(m);
    let blocks = m.div_ceil(k as u64);
    let second_order = 2 * n * c + blocks * 3 * n * 16;
    let first_order = n * (m - c) + blocks * 2 * n * 16 * 2;
    let bitmaps = n * m + m;
    second_order + first_order + bitmaps
}

/// Eq. 24 — ARB-LLM (RC variant):
/// `2nc + (⌈m/k⌉·2n + 2c)·16 + n(m−c) + (⌈m/k⌉·n + (m−c))·16·2 + n·m + m`.
pub fn arb_bits(d_out: usize, d_in: usize, c: usize, k: usize) -> u64 {
    let n = d_out as u64;
    let m = d_in as u64;
    let c = (c as u64).min(m);
    let blocks = m.div_ceil(k as u64);
    let second_order = 2 * n * c + (blocks * 2 * n + 2 * c) * 16;
    let first_order = n * (m - c) + (blocks * n + (m - c)) * 16 * 2;
    let bitmaps = n * m + m;
    second_order + first_order + bitmaps
}

/// One LittleBit tri-scale path: `r(d_in + d_out)` binary bits plus FP16
/// scales `16(d_in + d_out) + 16r`. The single source of the per-path
/// accounting — `CompressedLinear::storage_bits` (FP side) and
/// `MethodLayer::declared_bits` (packed serving side) both charge this,
/// so the two views can never drift.
pub fn littlebit_path_bits(d_in: usize, d_out: usize, r: usize) -> u64 {
    (r * (d_in + d_out)) as u64 + (16 * (d_in + d_out)) as u64 + (16 * r) as u64
}

/// Eq. 25 — LittleBit / LittleBit-2 (identical storage), residual (2-path)
/// architecture: `2r(d_in + d_out + 16) + 32(d_in + d_out)`.
pub fn littlebit_bits(d_in: usize, d_out: usize, r: usize) -> u64 {
    2 * littlebit_path_bits(d_in, d_out, r)
}

/// Eq. 26 — maximum rank under a bpp budget `B`:
/// `r = ⌊(B·N − 32(d_in+d_out)) / (2(d_in+d_out+16))⌋`, clamped at 1.
pub fn littlebit_rank_for_budget(d_in: usize, d_out: usize, bpp: f64) -> usize {
    let n = (d_in * d_out) as f64;
    let num = bpp * n - 32.0 * (d_in + d_out) as f64;
    let den = 2.0 * (d_in + d_out + 16) as f64;
    (num / den).floor().max(1.0) as usize
}

/// Single-path (non-residual) LittleBit variant used by the App. G ablation:
/// `r(d_in + d_out + 16) + 16(d_in + d_out)`.
pub fn littlebit_single_path_bits(d_in: usize, d_out: usize, r: usize) -> u64 {
    (r * (d_in + d_out + 16)) as u64 + (16 * (d_in + d_out)) as u64
}

/// Max single-path rank under a bpp budget.
pub fn littlebit_single_rank_for_budget(d_in: usize, d_out: usize, bpp: f64) -> usize {
    let n = (d_in * d_out) as f64;
    let num = bpp * n - 16.0 * (d_in + d_out) as f64;
    let den = (d_in + d_out + 16) as f64;
    (num / den).floor().max(1.0) as usize
}

/// Strategy A — tiny-rank FP16 factors: `16·r·(d_in + d_out)` bits.
pub fn tiny_rank_fp16_bits(d_in: usize, d_out: usize, r: usize) -> u64 {
    (16 * r * (d_in + d_out)) as u64
}

/// Maximum FP16 rank under a bpp budget.
pub fn tiny_rank_for_budget(d_in: usize, d_out: usize, bpp: f64) -> usize {
    let n = (d_in * d_out) as f64;
    ((bpp * n) / (16.0 * (d_in + d_out) as f64)).floor().max(1.0) as usize
}

/// FP16 dense: `16·N`.
pub fn fp16_bits(d_out: usize, d_in: usize) -> u64 {
    16 * (d_out * d_in) as u64
}

/// The ≈16× rank-expansion factor of §4.1: binary rank affordable per FP16
/// rank at the same budget.
pub fn rank_expansion_factor(d_in: usize, d_out: usize, bpp: f64) -> f64 {
    littlebit_rank_for_budget(d_in, d_out, bpp) as f64
        / tiny_rank_for_budget(d_in, d_out, bpp) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_expansion_near_16x() {
        // 2 paths at 1 bit + scales vs FP16: r_bin/r_fp ≈ 16/2 = 8 per path
        // pair ⇒ the single-path comparison of §4.1 gives ≈16.
        let f = littlebit_single_rank_for_budget(4096, 4096, 0.55) as f64
            / tiny_rank_for_budget(4096, 4096, 0.55) as f64;
        assert!(f > 12.0 && f < 17.0, "expansion={f}");
    }

    #[test]
    fn fp16_sanity() {
        assert_eq!(fp16_bits(2, 3), 96);
    }

    /// Ragged-row regression for the per-row group accounting: 3 rows of
    /// 100 columns at group 64 quantize as 3 × 2 = 6 groups, not ⌈300/64⌉.
    #[test]
    fn rtn_groups_are_scoped_per_row() {
        assert_eq!(rtn_bits(3, 100, 2, 64), 300 * 2 + 6 * 32);
        // Divisible shapes are unchanged by the fix.
        assert_eq!(rtn_bits(256, 256, 2, 128), 256 * 256 * 2 + 512 * 32);
    }

    #[test]
    fn budget_monotonicity() {
        let r1 = littlebit_rank_for_budget(4096, 4096, 0.1);
        let r2 = littlebit_rank_for_budget(4096, 4096, 0.55);
        let r3 = littlebit_rank_for_budget(4096, 4096, 1.0);
        assert!(r1 < r2 && r2 < r3, "{r1} {r2} {r3}");
    }

    #[test]
    fn paper_rank_scale_at_0_1_bpp() {
        // At 0.1 bpp on a 4096x4096 layer the affordable residual rank is
        // ~90-100 (body compressed to <1%: consistent with Table 1).
        let r = littlebit_rank_for_budget(4096, 4096, 0.1);
        assert!(r > 60 && r < 130, "r={r}");
    }
}
