//! Model-level memory aggregation (App. H, "Model-Level Aggregation").
//!
//! Applies the per-layer formulas to every linear layer of an [`ArchSpec`]
//! and reports Body / Total footprints in GB, reproducing the Mem columns of
//! Table 1 exactly. Non-linear parameters (norms, embeddings, LM head) are
//! charged at FP16.

use super::formulas::*;
use crate::model::ArchSpec;

/// Quantization method selector for aggregation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MethodKind {
    Fp16,
    /// k-bit group RTN (GPTQ / EfficientQAT storage format).
    Rtn { k: u32, group: usize },
    Billm,
    Arb,
    OneBit,
    /// LittleBit / LittleBit-2 at a bpp budget (identical storage).
    LittleBit { bpp: f64 },
    /// Tiny-rank FP16 at a bpp budget.
    TinyRank { bpp: f64 },
}

impl MethodKind {
    pub fn label(&self) -> String {
        match self {
            MethodKind::Fp16 => "FP16".into(),
            MethodKind::Rtn { k, .. } => format!("RTN-{k}bit(g128)"),
            MethodKind::Billm => "BiLLM".into(),
            MethodKind::Arb => "ARB-LLM".into(),
            MethodKind::OneBit => "OneBit".into(),
            MethodKind::LittleBit { bpp } => format!("LittleBit(-2) {bpp}bpp"),
            MethodKind::TinyRank { bpp } => format!("TinyRankFP16 {bpp}bpp"),
        }
    }

    /// Bits for one `d_out × d_in` linear layer.
    pub fn layer_bits(&self, d_out: usize, d_in: usize) -> u64 {
        match *self {
            MethodKind::Fp16 => fp16_bits(d_out, d_in),
            MethodKind::Rtn { k, group } => rtn_bits(d_out, d_in, k, group),
            MethodKind::Billm => billm_bits(d_out, d_in, 128, 128),
            MethodKind::Arb => arb_bits(d_out, d_in, 128, 128),
            MethodKind::OneBit => onebit_bits(d_out, d_in),
            MethodKind::LittleBit { bpp } => {
                littlebit_bits(d_in, d_out, littlebit_rank_for_budget(d_in, d_out, bpp))
            }
            MethodKind::TinyRank { bpp } => {
                tiny_rank_fp16_bits(d_in, d_out, tiny_rank_for_budget(d_in, d_out, bpp))
            }
        }
    }
}

/// Aggregated footprint of one (model, method) pair.
#[derive(Clone, Debug)]
pub struct ModelMemory {
    pub model: &'static str,
    pub method: String,
    /// Linear-layer (body) bytes.
    pub body_bytes: u64,
    /// Body + embeddings + head + norms (FP16) bytes.
    pub total_bytes: u64,
    /// FP16 reference body/total, for the percentage columns.
    pub fp16_body_bytes: u64,
    pub fp16_total_bytes: u64,
}

impl ModelMemory {
    pub fn body_gb(&self) -> f64 {
        self.body_bytes as f64 / 1e9
    }

    pub fn total_gb(&self) -> f64 {
        self.total_bytes as f64 / 1e9
    }

    pub fn body_pct(&self) -> f64 {
        100.0 * self.body_bytes as f64 / self.fp16_body_bytes as f64
    }

    pub fn total_pct(&self) -> f64 {
        100.0 * self.total_bytes as f64 / self.fp16_total_bytes as f64
    }
}

/// Aggregate a method over every body linear layer of `arch`, charging
/// embeddings + LM head + norms at FP16 (paper convention).
pub fn model_memory(arch: &ArchSpec, method: MethodKind) -> ModelMemory {
    let mut body_bits = 0u64;
    for (_, _, d_out, d_in) in arch.body_layers() {
        body_bits += method.layer_bits(d_out, d_in);
    }
    let fixed_bits =
        16 * (arch.embedding_params() + arch.head_params() + arch.norm_params());
    let fp16_body_bits = 16 * arch.body_params();
    ModelMemory {
        model: arch.name,
        method: method.label(),
        body_bytes: body_bits / 8,
        total_bytes: (body_bits + fixed_bits) / 8,
        fp16_body_bytes: fp16_body_bits / 8,
        fp16_total_bytes: (fp16_body_bits + fixed_bits) / 8,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table 1 FP16 row: Llama-2 7B body 13.0, total 13.5 GB.
    #[test]
    fn table1_fp16_llama2_7b() {
        let m = model_memory(&ArchSpec::llama2_7b(), MethodKind::Fp16);
        assert!((m.body_gb() - 13.0).abs() < 0.15, "body={}", m.body_gb());
        assert!((m.total_gb() - 13.5).abs() < 0.15, "total={}", m.total_gb());
    }

    /// Table 1 FP16 row: Llama-3 8B body 14.0, total 16.1 GB.
    #[test]
    fn table1_fp16_llama3_8b() {
        let m = model_memory(&ArchSpec::llama3_8b(), MethodKind::Fp16);
        assert!((m.body_gb() - 14.0).abs() < 0.15, "body={}", m.body_gb());
        assert!((m.total_gb() - 16.1).abs() < 0.15, "total={}", m.total_gb());
    }

    /// Table 1 FP16 row: Llama-2 13B body 25.4, total 26.1 GB.
    #[test]
    fn table1_fp16_llama2_13b() {
        let m = model_memory(&ArchSpec::llama2_13b(), MethodKind::Fp16);
        assert!((m.body_gb() - 25.4).abs() < 0.3, "body={}", m.body_gb());
        assert!((m.total_gb() - 26.1).abs() < 0.3, "total={}", m.total_gb());
    }

    /// Table 1 OneBit row on Llama-2 7B: body 0.8 GB (6.4%), total 1.4 GB.
    #[test]
    fn table1_onebit_llama2_7b() {
        let m = model_memory(&ArchSpec::llama2_7b(), MethodKind::OneBit);
        assert!((m.body_gb() - 0.8).abs() < 0.05, "body={}", m.body_gb());
        assert!((m.total_gb() - 1.4).abs() < 0.1, "total={}", m.total_gb());
        assert!((m.body_pct() - 6.4).abs() < 0.3, "pct={}", m.body_pct());
    }

    /// Table 1 LittleBit 1.0 bpp on Llama-2 7B: body 0.8 GB (6.3%).
    #[test]
    fn table1_littlebit_1bpp_llama2_7b() {
        let m = model_memory(&ArchSpec::llama2_7b(), MethodKind::LittleBit { bpp: 1.0 });
        assert!((m.body_gb() - 0.8).abs() < 0.05, "body={}", m.body_gb());
        assert!((m.body_pct() - 6.3).abs() < 0.3, "pct={}", m.body_pct());
    }

    /// Table 1 LittleBit 0.1 bpp on Llama-2 7B: body 0.1 GB (0.7%), total 0.6.
    #[test]
    fn table1_littlebit_01bpp_llama2_7b() {
        let m = model_memory(&ArchSpec::llama2_7b(), MethodKind::LittleBit { bpp: 0.1 });
        assert!(m.body_gb() < 0.12, "body={}", m.body_gb());
        assert!((m.total_gb() - 0.6).abs() < 0.1, "total={}", m.total_gb());
        assert!(m.body_pct() < 1.0, "pct={}", m.body_pct());
    }

    /// Table 1 LittleBit 0.1 bpp Llama-3 8B: total 2.2 GB — head+embedding
    /// dominated (the paper's point about fixed footprint).
    #[test]
    fn table1_littlebit_01bpp_llama3_8b() {
        let m = model_memory(&ArchSpec::llama3_8b(), MethodKind::LittleBit { bpp: 0.1 });
        assert!((m.total_gb() - 2.2).abs() < 0.15, "total={}", m.total_gb());
        // Fixed FP16 part dominates:
        assert!(m.body_bytes * 4 < m.total_bytes);
    }

    /// Table 1 GPTQ 2-bit rows: Llama-2 7B body 1.8 GB (14.2%).
    #[test]
    fn table1_gptq_llama2_7b() {
        let m = model_memory(
            &ArchSpec::llama2_7b(),
            MethodKind::Rtn { k: 2, group: 128 },
        );
        assert!((m.body_gb() - 1.8).abs() < 0.05, "body={}", m.body_gb());
        assert!((m.body_pct() - 14.2).abs() < 0.3);
    }

    /// Table 1 BiLLM rows: Llama-2 7B body 2.4 GB (18.2%).
    #[test]
    fn table1_billm_llama2_7b() {
        let m = model_memory(&ArchSpec::llama2_7b(), MethodKind::Billm);
        assert!((m.body_gb() - 2.4).abs() < 0.1, "body={}", m.body_gb());
    }

    /// Table 1 ARB rows: paper reports Llama-2 7B body 2.3 GB (17.5%); the
    /// literal Eq. 24 yields 2.05 GB (15.8%) — a ~0.25 GB gap we attribute
    /// to aggregation conventions in the ARB supplement (documented in
    /// EXPERIMENTS.md). Assert the computed value stays stable.
    #[test]
    fn table1_arb_llama2_7b() {
        let m = model_memory(&ArchSpec::llama2_7b(), MethodKind::Arb);
        assert!((m.body_gb() - 2.05).abs() < 0.15, "body={}", m.body_gb());
    }
}
