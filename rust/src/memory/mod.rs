//! Memory-requirement analysis (Appendix H).
//!
//! Exact per-layer bit accounting for every method in Table 1, plus
//! model-level aggregation over real architecture shapes. Because the
//! Llama/Gemma architectures are public, the **Mem (GB)** columns of
//! Table 1/2 are reproduced *exactly* — no simulation involved.
//!
//! Conventions follow App. H: all scales/zero-points are FP16 (16 bits),
//! `N = d_in·d_out`, group size `k = 128`, salient columns `c = 128`.

mod aggregate;
mod formulas;

pub use aggregate::{model_memory, MethodKind, ModelMemory};
pub use formulas::*;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gptq_is_2_25_bits_per_param() {
        // Eq. 21: 2N + (N/128)·32 = 2.25·N.
        let bits = rtn_bits(4096, 4096, 2, 128);
        let n = 4096u64 * 4096;
        assert_eq!(bits, n * 2 + (n / 128) * 32);
        assert!((bits as f64 / n as f64 - 2.25).abs() < 1e-9);
    }

    #[test]
    fn onebit_formula() {
        // Eq. 22: N + 16(d_in + d_out).
        assert_eq!(
            onebit_bits(11008, 4096),
            11008 * 4096 + 16 * (11008 + 4096)
        );
    }

    #[test]
    fn littlebit_formula_and_inversion() {
        // Eq. 25 and Eq. 26 must be mutually consistent: for the rank given
        // by the inversion at budget B, actual bpp ≤ B and rank+1 exceeds it.
        for (d_in, d_out) in [(4096usize, 4096usize), (4096, 11008), (14336, 4096)] {
            for bpp in [0.1f64, 0.55, 1.0] {
                let r = littlebit_rank_for_budget(d_in, d_out, bpp);
                let n = (d_in * d_out) as f64;
                let bits = littlebit_bits(d_in, d_out, r) as f64;
                assert!(bits / n <= bpp + 1e-9, "bpp over budget: {} > {bpp}", bits / n);
                let bits_next = littlebit_bits(d_in, d_out, r + 1) as f64;
                assert!(bits_next / n > bpp, "rank not maximal at {bpp}");
            }
        }
    }

    #[test]
    fn littlebit_components_breakdown() {
        // 2r(d_in+d_out+16) + 32(d_in+d_out).
        let (din, dout, r) = (100usize, 200usize, 10usize);
        let expect = 2 * 10 * (100 + 200 + 16) + 32 * (100 + 200);
        assert_eq!(littlebit_bits(din, dout, r), expect as u64);
    }

    /// BiLLM's *storage* bpp far exceeds its nominal 1.1 bits because of
    /// scale + bitmap metadata: Eq. 23 gives ≈2.9 bpp on a 4096² layer —
    /// exactly Table 1's 18.2%-of-FP16 body column (0.182·16 = 2.91).
    #[test]
    fn billm_metadata_overhead_matches_table1_pct() {
        let bits = billm_bits(4096, 4096, 128, 128) as f64;
        let bpp = bits / (4096f64 * 4096.0);
        assert!((bpp - 2.91).abs() < 0.1, "billm bpp={bpp}");
    }

    /// ARB-RC per Eq. 24: ≈2.5 bpp on a square 4096 layer (Table 1 reports
    /// 17.5% ⇒ 2.8 bpp model-wide; the difference comes from the paper's
    /// aggregation over non-square layers — see EXPERIMENTS.md notes).
    #[test]
    fn arb_metadata_overhead() {
        let bits = arb_bits(4096, 4096, 128, 128) as f64;
        let bpp = bits / (4096f64 * 4096.0);
        assert!(bpp > 2.3 && bpp < 2.9, "arb bpp={bpp}");
    }

    #[test]
    fn tiny_rank_budget_inversion() {
        for bpp in [0.55f64, 1.0, 2.0] {
            let r = tiny_rank_for_budget(4096, 4096, bpp);
            let bits = tiny_rank_fp16_bits(4096, 4096, r) as f64;
            assert!(bits / (4096f64 * 4096.0) <= bpp + 1e-9);
        }
    }
}
