//! Spectral analysis: power-law weight synthesis, decay-rate (γ) estimation,
//! and the Spectral Break-Even Condition of Proposition 4.1.
//!
//! The paper models LLM weight spectra as σ_k ≈ C·k^{−γ} (Martin & Mahoney,
//! 2021), classifying γ ≤ 0.5 as heavy-tailed. Under a fixed bit budget,
//! Strategy B (low-rank binary, rank r_B ≈ 16·r_A) beats Strategy A
//! (tiny-rank FP16, rank r_A) iff the tail energy gained by rank expansion
//! exceeds the quantization cost Λ·Σ_{k≤r_B} σ_k² (Eq. 3).

mod breakeven;
mod gamma;
mod synth;

pub use breakeven::{
    advantage, break_even_gamma, discrete, quant_cost, tail_energy, tail_gain, BreakEven,
};
pub use gamma::{estimate_gamma, GammaFit};
pub use synth::{power_law_singular_values, synth_weight, SynthSpec};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::svd_randomized;
    use crate::rng::Pcg64;

    #[test]
    fn synth_then_estimate_roundtrips_gamma() {
        let mut rng = Pcg64::seed(42);
        for &gamma in &[0.2f64, 0.4, 0.7] {
            let spec = SynthSpec { rows: 128, cols: 128, gamma, coherence: 0.0, scale: 1.0 };
            let w = synth_weight(&spec, &mut rng);
            let svd = svd_randomized(&w, 96, 10, 3, &mut rng);
            let fit = estimate_gamma(&svd.s);
            assert!(
                (fit.gamma - gamma).abs() < 0.08,
                "target={gamma} estimated={}",
                fit.gamma
            );
        }
    }
}
