//! Power-law decay-rate estimation by log-log linear regression,
//! matching the paper's "all gammas are calculated by log linear regression
//! of real weights" (§5.1).

/// Result of fitting log σ_k = log C − γ·log k.
#[derive(Clone, Copy, Debug)]
pub struct GammaFit {
    /// Estimated decay rate γ (positive = decaying spectrum).
    pub gamma: f64,
    /// Estimated log-amplitude log C.
    pub log_c: f64,
    /// Coefficient of determination of the fit.
    pub r2: f64,
}

impl GammaFit {
    /// Heavy-tailed per Martin & Mahoney's classification used in §4.1.
    pub fn is_heavy_tailed(&self) -> bool {
        self.gamma <= 0.5
    }
}

/// Fit γ over the interior of the spectrum. The head (k < `skip`) is
/// dominated by a few outlier directions and the far tail by numerical
/// noise, so the fit uses k ∈ [skip, n·tail_frac] — mirroring standard
/// practice for ESD power-law fits.
pub fn estimate_gamma_windowed(s: &[f32], skip: usize, tail_frac: f64) -> GammaFit {
    let n = s.len();
    let hi = ((n as f64 * tail_frac) as usize).clamp(skip + 2, n);
    let mut xs = Vec::with_capacity(hi - skip);
    let mut ys = Vec::with_capacity(hi - skip);
    for k in skip..hi {
        let sv = s[k] as f64;
        if sv <= 0.0 {
            break; // spectrum is sorted; zeros only occur at the tail
        }
        xs.push(((k + 1) as f64).ln());
        ys.push(sv.ln());
    }
    let m = xs.len() as f64;
    assert!(m >= 2.0, "need at least 2 positive singular values");
    let mean_x: f64 = xs.iter().sum::<f64>() / m;
    let mean_y: f64 = ys.iter().sum::<f64>() / m;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (x, y) in xs.iter().zip(&ys) {
        let dx = x - mean_x;
        let dy = y - mean_y;
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    let slope = sxy / sxx;
    let r2 = if syy > 0.0 { (sxy * sxy) / (sxx * syy) } else { 1.0 };
    GammaFit { gamma: -slope, log_c: mean_y - slope * mean_x, r2 }
}

/// Default windowing: skip the top 1% (min 1), fit to the 90th percentile.
pub fn estimate_gamma(s: &[f32]) -> GammaFit {
    let skip = (s.len() / 100).max(1);
    estimate_gamma_windowed(s, skip, 0.9)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_power_law_recovers_gamma() {
        for &g in &[0.1f64, 0.36, 0.8, 1.5] {
            let s: Vec<f32> = (1..=500).map(|k| (k as f64).powf(-g) as f32).collect();
            let fit = estimate_gamma(&s);
            assert!((fit.gamma - g).abs() < 1e-3, "g={g} got={}", fit.gamma);
            assert!(fit.r2 > 0.999);
        }
    }

    #[test]
    fn amplitude_recovered() {
        let c = 3.0f64;
        let s: Vec<f32> = (1..=300).map(|k| (c * (k as f64).powf(-0.4)) as f32).collect();
        let fit = estimate_gamma(&s);
        assert!((fit.log_c - c.ln()).abs() < 1e-2);
    }

    #[test]
    fn heavy_tail_classification() {
        let heavy: Vec<f32> = (1..=100).map(|k| (k as f64).powf(-0.3) as f32).collect();
        let light: Vec<f32> = (1..=100).map(|k| (k as f64).powf(-0.9) as f32).collect();
        assert!(estimate_gamma(&heavy).is_heavy_tailed());
        assert!(!estimate_gamma(&light).is_heavy_tailed());
    }

    #[test]
    fn noisy_spectrum_fit_tolerance() {
        // Multiplicative noise should perturb γ only slightly.
        let mut rng = crate::rng::Pcg64::seed(1);
        let s: Vec<f32> = (1..=400)
            .map(|k| ((k as f64).powf(-0.5) * (1.0 + 0.05 * rng.normal())) as f32)
            .collect();
        let fit = estimate_gamma(&s);
        assert!((fit.gamma - 0.5).abs() < 0.05, "got={}", fit.gamma);
    }

    #[test]
    fn zero_tail_is_ignored() {
        let mut s: Vec<f32> = (1..=100).map(|k| (k as f64).powf(-0.4) as f32).collect();
        s.extend([0.0f32; 20]);
        let fit = estimate_gamma(&s);
        assert!((fit.gamma - 0.4).abs() < 0.02);
    }
}
