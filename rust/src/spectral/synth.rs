//! Synthetic weight fabrication with controlled spectrum and controlled
//! singular-vector coherence.
//!
//! This is the checkpoint substitute (ARCHITECTURE.md §Substitutions #1): since no
//! Llama/Gemma weights are available, experiments run on matrices whose
//! *spectral decay* matches the paper's measurements (γ median 0.26–0.33,
//! 90% within [0.19, 0.47], Fig 11) and whose singular vectors reproduce the
//! *high-coherence "spiky" geometry* of §3.2 — the property LittleBit-2's
//! Joint-ITQ exists to fix. Coherence is tunable so experiments can sweep
//! from delocalized (Haar) to near-axis-aligned (worst case) bases.

use crate::linalg::{householder_qr, Mat};
use crate::rng::Pcg64;

/// Specification of one synthetic weight matrix.
#[derive(Clone, Debug)]
pub struct SynthSpec {
    pub rows: usize,
    pub cols: usize,
    /// Power-law decay rate γ of σ_k ∝ k^{−γ}.
    pub gamma: f64,
    /// Singular-vector coherence in [0, 1): 0 = Haar basis (delocalized),
    /// →1 = identity-dominated basis (axis-aligned spikes, worst case for
    /// binarization). Real LLM latent factors behave like ≈0.6–0.9
    /// (kurtosis ≈ 17 on Llama-2 q_proj per §4.2).
    pub coherence: f64,
    /// Overall Frobenius scale multiplier.
    pub scale: f64,
}

impl Default for SynthSpec {
    fn default() -> Self {
        Self { rows: 512, cols: 512, gamma: 0.27, coherence: 0.7, scale: 1.0 }
    }
}

/// σ_k = k^{−γ} for k = 1..=n (unnormalized; `scale` applied by the caller).
pub fn power_law_singular_values(n: usize, gamma: f64) -> Vec<f32> {
    (1..=n).map(|k| (k as f64).powf(-gamma) as f32).collect()
}

/// Sample an orthogonal basis with tunable coordinate coherence:
/// QR of `(1−c)·G + c·√n·D` where `G` is gaussian and `D` a random signed
/// permutation. At `c=0` this is Haar; as `c→1` columns align with
/// coordinate axes, exactly the spiky geometry of Definition 4.3.
pub fn coherent_basis(n: usize, r: usize, coherence: f64, rng: &mut Pcg64) -> Mat {
    assert!((0.0..1.0).contains(&coherence), "coherence in [0,1)");
    let mut g = Mat::gaussian(n, r, rng);
    if coherence > 0.0 {
        // Random signed injection of r distinct axes.
        let mut axes: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut axes);
        let spike = (coherence * (n as f64).sqrt()) as f32 * 3.0;
        let damp = (1.0 - coherence) as f32;
        for i in 0..g.rows() {
            for v in g.row_mut(i) {
                *v *= damp;
            }
        }
        for j in 0..r {
            let i = axes[j];
            *g.at_mut(i, j) += spike * rng.sign();
        }
    }
    let (q, _) = householder_qr(&g);
    q
}

/// Fabricate `W = U · diag(σ) · Vᵀ` with power-law σ and coherence-controlled
/// bases. Deterministic given `rng` state.
pub fn synth_weight(spec: &SynthSpec, rng: &mut Pcg64) -> Mat {
    let d = spec.rows.min(spec.cols);
    let mut s = power_law_singular_values(d, spec.gamma);
    for v in s.iter_mut() {
        *v *= spec.scale as f32;
    }
    let u = coherent_basis(spec.rows, d, spec.coherence, rng);
    let v = coherent_basis(spec.cols, d, spec.coherence, rng);
    u.scale_cols(&s).matmul_t(&v)
}

/// Coordinate incoherence μ(U) = √d · max|U_ij| (Definition 4.3).
pub fn coordinate_incoherence(u: &Mat) -> f64 {
    let max = (0..u.rows())
        .flat_map(|i| u.row(i))
        .fold(0.0f32, |m, &x| m.max(x.abs()));
    (u.rows() as f64).sqrt() * max as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::svd_randomized;

    #[test]
    fn singular_values_follow_power_law() {
        let s = power_law_singular_values(100, 0.5);
        assert!((s[0] - 1.0).abs() < 1e-6);
        assert!((s[3] - 0.5).abs() < 1e-6); // 4^-0.5
        assert!((s[99] - 0.1).abs() < 1e-6); // 100^-0.5
    }

    #[test]
    fn coherent_basis_is_orthonormal_at_all_coherences() {
        let mut rng = Pcg64::seed(1);
        for &c in &[0.0, 0.5, 0.9] {
            let q = coherent_basis(64, 16, c, &mut rng);
            assert!(crate::linalg::orthogonality_defect(&q) < 1e-4, "c={c}");
        }
    }

    #[test]
    fn coherence_knob_raises_mu() {
        let mut rng = Pcg64::seed(2);
        let lo = coordinate_incoherence(&coherent_basis(256, 32, 0.0, &mut rng));
        let hi = coordinate_incoherence(&coherent_basis(256, 32, 0.9, &mut rng));
        assert!(hi > 2.0 * lo, "lo={lo} hi={hi}");
    }

    #[test]
    fn synth_weight_has_requested_spectrum() {
        let mut rng = Pcg64::seed(3);
        let spec = SynthSpec { rows: 96, cols: 96, gamma: 0.4, coherence: 0.5, scale: 2.0 };
        let w = synth_weight(&spec, &mut rng);
        let svd = svd_randomized(&w, 8, 8, 3, &mut rng);
        // Top singular value should be scale * 1^-γ = 2.0.
        assert!((svd.s[0] - 2.0).abs() < 0.05, "s0={}", svd.s[0]);
        // Ratios follow k^-γ.
        let expect = (4f32).powf(-0.4);
        assert!((svd.s[3] / svd.s[0] - expect).abs() < 0.05);
    }
}
