//! The Spectral Break-Even Condition (Proposition 4.1).
//!
//! Under a bit budget B, Strategy A (tiny-rank FP16, rank r_A) pays pure
//! truncation error; Strategy B (low-rank binary, rank r_B ≈ 16·r_A) trades
//! truncation for quantization noise Λ·(head energy). B wins iff
//!
//! ```text
//! ∫_{r_A}^{r_B} σ(x)² dx  >  Λ ∫_0^{r_B} σ(x)² dx           (Eq. 3)
//! ```
//!
//! With σ(x) = C·x^{−γ}, both sides are incomplete power integrals; this
//! module evaluates them in closed form (continuous model) and on discrete
//! spectra (exact sums), and solves for the critical γ*.

/// Tail energy ∫_r^n σ(x)² dx of the continuous power-law model σ = x^{−γ}.
/// For γ = 0.5 the integral is logarithmic.
pub fn tail_energy(gamma: f64, r: f64, n: f64) -> f64 {
    assert!(r >= 1.0 && n >= r);
    let e = 1.0 - 2.0 * gamma;
    if e.abs() < 1e-12 {
        (n / r).ln()
    } else {
        (n.powf(e) - r.powf(e)) / e
    }
}

/// Tail gain of Eq. 3: energy recovered by expanding rank from r_a to r_b.
pub fn tail_gain(gamma: f64, r_a: f64, r_b: f64, n: f64) -> f64 {
    tail_energy(gamma, r_a, n) - tail_energy(gamma, r_b, n)
}

/// Quantization cost of Eq. 3: Λ · ∫_1^{r_b} σ(x)² dx.
pub fn quant_cost(gamma: f64, lambda: f64, r_b: f64) -> f64 {
    lambda * tail_energy(gamma, 1.0, r_b)
}

/// Outcome of a break-even analysis at fixed budget.
#[derive(Clone, Copy, Debug)]
pub struct BreakEven {
    /// Critical decay rate γ*: Strategy B superior for γ < γ*.
    pub gamma_star: f64,
    /// Distortion coefficient Λ used.
    pub lambda: f64,
    /// FP16 rank r_A and binary rank r_B compared.
    pub r_a: f64,
    pub r_b: f64,
}

/// Net advantage of Strategy B at a given γ (positive ⇒ B wins).
pub fn advantage(gamma: f64, lambda: f64, r_a: f64, r_b: f64, n: f64) -> f64 {
    tail_gain(gamma, r_a, r_b, n) - quant_cost(gamma, lambda, r_b)
}

/// Solve for γ* by bisection on [1e-3, 3]. The advantage is monotonically
/// decreasing in γ in the regime of interest (heavier tails → bigger gain
/// from rank expansion), so a single crossing exists when Λ ∈ (0, 1).
pub fn break_even_gamma(lambda: f64, r_a: f64, r_b: f64, n: f64) -> BreakEven {
    let (mut lo, mut hi) = (1e-3, 3.0);
    let f = |g: f64| advantage(g, lambda, r_a, r_b, n);
    // If B wins everywhere (tiny Λ) or nowhere, clamp to the bracket edge.
    let gamma_star = if f(lo) <= 0.0 {
        lo
    } else if f(hi) >= 0.0 {
        hi
    } else {
        for _ in 0..200 {
            let mid = 0.5 * (lo + hi);
            if f(mid) > 0.0 {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        0.5 * (lo + hi)
    };
    BreakEven { gamma_star, lambda, r_a, r_b }
}

/// Discrete-spectrum versions over measured singular values.
pub mod discrete {
    /// Σ_{k>r} σ_k² — exact truncation error of rank-r SVD (Eckart–Young).
    pub fn truncation_error(s: &[f32], r: usize) -> f64 {
        s[r.min(s.len())..].iter().map(|&x| (x as f64).powi(2)).sum()
    }

    /// Λ·Σ_{k≤r} σ_k² — quantization noise with distortion Λ.
    pub fn quantization_error(s: &[f32], r: usize, lambda: f64) -> f64 {
        lambda
            * s[..r.min(s.len())]
                .iter()
                .map(|&x| (x as f64).powi(2))
                .sum::<f64>()
    }

    /// Total error of Strategy B at rank r_b with distortion Λ.
    pub fn strategy_b_error(s: &[f32], r_b: usize, lambda: f64) -> f64 {
        truncation_error(s, r_b) + quantization_error(s, r_b, lambda)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tail_energy_closed_form_matches_quadrature() {
        for &g in &[0.2, 0.5, 0.8] {
            let (r, n) = (4.0, 1000.0);
            let closed = tail_energy(g, r, n);
            // Midpoint quadrature.
            let steps = 200_000;
            let h = (n - r) / steps as f64;
            let quad: f64 = (0..steps)
                .map(|i| {
                    let x = r + (i as f64 + 0.5) * h;
                    x.powf(-2.0 * g) * h
                })
                .sum();
            assert!((closed - quad).abs() / quad < 1e-3, "g={g}");
        }
    }

    #[test]
    fn heavier_tail_larger_gain() {
        let g_heavy = tail_gain(0.2, 16.0, 256.0, 4096.0);
        let g_light = tail_gain(0.8, 16.0, 256.0, 4096.0);
        // Normalize by head energy so scales are comparable.
        let h_heavy = tail_energy(0.2, 1.0, 4096.0);
        let h_light = tail_energy(0.8, 1.0, 4096.0);
        assert!(g_heavy / h_heavy > g_light / h_light);
    }

    #[test]
    fn gamma_star_increases_as_lambda_decreases() {
        // Minimizing Λ shifts γ* higher — the paper's central claim (§4.1).
        let be_svd = break_even_gamma(0.7, 16.0, 256.0, 4096.0);
        let be_rot = break_even_gamma(0.36, 16.0, 256.0, 4096.0);
        let be_itq = break_even_gamma(0.30, 16.0, 256.0, 4096.0);
        assert!(be_rot.gamma_star > be_svd.gamma_star);
        assert!(be_itq.gamma_star > be_rot.gamma_star);
    }

    #[test]
    fn paper_scale_break_even_in_plausible_range() {
        // With Λ≈0.5 (SVD-coherent factors after rank-1 scale recovery) and
        // 16x rank expansion, γ* should land in the paper's ~0.3-0.5 window.
        let be = break_even_gamma(0.5, 16.0, 256.0, 4096.0);
        assert!(
            (0.2..0.7).contains(&be.gamma_star),
            "gamma_star={}",
            be.gamma_star
        );
    }

    #[test]
    fn advantage_sign_consistency() {
        let be = break_even_gamma(0.4, 16.0, 256.0, 4096.0);
        let g = be.gamma_star;
        assert!(advantage(g - 0.05, 0.4, 16.0, 256.0, 4096.0) > 0.0);
        assert!(advantage(g + 0.05, 0.4, 16.0, 256.0, 4096.0) < 0.0);
    }

    #[test]
    fn discrete_matches_continuous_shape() {
        let s: Vec<f32> = (1..=4096).map(|k| (k as f64).powf(-0.3) as f32).collect();
        let cont = tail_energy(0.3, 256.0, 4096.0);
        let disc = discrete::truncation_error(&s, 256);
        assert!((cont - disc).abs() / disc < 0.02, "cont={cont} disc={disc}");
    }

    #[test]
    fn eckart_young_truncation_is_exact_sum() {
        let s = vec![2.0f32, 1.0, 0.5];
        assert!((discrete::truncation_error(&s, 1) - 1.25).abs() < 1e-9);
        assert!((discrete::strategy_b_error(&s, 3, 0.1)
            - 0.1 * (4.0 + 1.0 + 0.25))
            .abs()
            < 1e-6);
    }
}
