//! Backend-boundary fault injection: a [`BatchBackend`] wrapper that
//! injects panics, stalls, and wrong-shape outputs into the worker drain
//! loop — exactly the faults the coordinator's `catch_unwind` isolation
//! and output-shape check exist to absorb.

use super::{draw_delay, FaultSpec};
use crate::coordinator::BatchBackend;
use crate::linalg::Mat;
use crate::rng::Pcg64;
use std::time::Duration;

/// One drawn fault for one batch execution.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum BackendFault {
    /// Execute the batch normally.
    Pass,
    /// Panic instead of executing — the worker's `catch_unwind` must fail
    /// the whole group, not the process.
    Panic,
    /// Sleep, then execute normally. Models a GC pause / page-fault storm;
    /// requests queued behind it may miss their deadlines.
    Stall(Duration),
    /// Execute normally, then truncate one column from the output so the
    /// coordinator's shape check rejects the batch. The surviving columns
    /// are never value-corrupted — any answer that *does* reach a client
    /// stays bit-identical to the clean forward.
    WrongShape,
}

/// A seeded per-worker fault source, same determinism contract as
/// [`StreamInjector`](super::StreamInjector): the schedule is a pure
/// function of the seed and the batch count.
#[derive(Clone, Debug)]
pub struct BackendInjector {
    spec: FaultSpec,
    rng: Pcg64,
}

impl BackendInjector {
    pub(super) fn new(spec: FaultSpec, rng: Pcg64) -> Self {
        Self { spec, rng }
    }

    fn rate_sum(&self) -> f64 {
        self.spec.backend_panic + self.spec.backend_stall + self.spec.backend_wrong_shape
    }

    /// Draw the fault for the next batch. Cumulative thresholds over
    /// (panic, stall, wrong_shape) in that fixed order.
    pub fn next(&mut self) -> BackendFault {
        if self.rate_sum() <= 0.0 {
            return BackendFault::Pass;
        }
        let s = &self.spec;
        let u = self.rng.uniform();
        let mut t = s.backend_panic;
        if u < t {
            return BackendFault::Panic;
        }
        t += s.backend_stall;
        if u < t {
            return BackendFault::Stall(draw_delay(&mut self.rng, s.backend_stall_ms));
        }
        t += s.backend_wrong_shape;
        if u < t {
            return BackendFault::WrongShape;
        }
        BackendFault::Pass
    }

    /// Record the next `n` draws — the replayable fault schedule.
    pub fn schedule(mut self, n: usize) -> Vec<BackendFault> {
        (0..n).map(|_| self.next()).collect()
    }
}

/// Wraps any [`BatchBackend`] with injected execution faults. Only built
/// by an explicit chaos factory — the production worker loop never sees
/// this type, so the no-fault drain path is untouched.
pub struct ChaosBackend<B> {
    inner: B,
    injector: BackendInjector,
}

impl<B: BatchBackend> ChaosBackend<B> {
    pub fn new(inner: B, injector: BackendInjector) -> Self {
        Self { inner, injector }
    }
}

impl<B: BatchBackend> BatchBackend for ChaosBackend<B> {
    fn forward_batch_into(&mut self, x: &Mat, y: &mut Mat) {
        match self.injector.next() {
            BackendFault::Pass => self.inner.forward_batch_into(x, y),
            BackendFault::Panic => panic!("injected backend panic"),
            BackendFault::Stall(d) => {
                std::thread::sleep(d);
                self.inner.forward_batch_into(x, y);
            }
            BackendFault::WrongShape => {
                self.inner.forward_batch_into(x, y);
                // Drop one column (or fabricate one if the batch was a
                // single request) so the coordinator's `cols() == batch`
                // check fires and the group fails loudly.
                let r = y.rows();
                let c = y.cols();
                if c > 1 {
                    let mut t = Mat::zeros(r, c - 1);
                    for j in 0..c - 1 {
                        for (i, v) in y.col(j).iter().enumerate() {
                            *t.at_mut(i, j) = *v;
                        }
                    }
                    *y = t;
                } else {
                    y.resize(r.max(1), c + 1);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{FaultPlan, FaultSpec};
    use super::*;
    use std::panic::AssertUnwindSafe;

    fn double_backend() -> impl BatchBackend {
        |x: &Mat| -> Mat { x.scale(2.0) }
    }

    /// A zero-fault chaos wrapper is computationally transparent: outputs
    /// are bit-identical to the bare backend's.
    #[test]
    fn zero_fault_backend_is_bit_exact() {
        let plan = FaultPlan::new(3, FaultSpec::default());
        let mut bare = double_backend();
        let mut chaos = ChaosBackend::new(double_backend(), plan.backend_injector(0));

        let mut x = Mat::zeros(4, 3);
        for j in 0..3 {
            for i in 0..4 {
                *x.at_mut(i, j) = (i * 3 + j) as f32 * 0.25 - 1.0;
            }
        }
        let mut y0 = Mat::zeros(0, 0);
        let mut y1 = Mat::zeros(0, 0);
        bare.forward_batch_into(&x, &mut y0);
        chaos.forward_batch_into(&x, &mut y1);
        assert_eq!(y0.rows(), y1.rows());
        assert_eq!(y0.cols(), y1.cols());
        for j in 0..y0.cols() {
            for (a, b) in y0.col(j).iter().zip(y1.col(j)) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    /// Injected panics actually unwind out of `forward_batch_into`, and a
    /// wrong-shape injection changes the column count but never the bits
    /// of surviving columns.
    #[test]
    fn panic_and_wrong_shape_fire_as_drawn() {
        let plan = FaultPlan::new(44, FaultSpec { backend_panic: 1.0, ..FaultSpec::default() });
        let mut chaos = ChaosBackend::new(double_backend(), plan.backend_injector(0));
        let x = Mat::zeros(2, 2);
        let mut y = Mat::zeros(0, 0);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| chaos.forward_batch_into(&x, &mut y)));
        assert!(r.is_err(), "injected panic must unwind");

        let plan =
            FaultPlan::new(44, FaultSpec { backend_wrong_shape: 1.0, ..FaultSpec::default() });
        let mut chaos = ChaosBackend::new(double_backend(), plan.backend_injector(0));
        let mut x = Mat::zeros(2, 3);
        for j in 0..3 {
            for i in 0..2 {
                *x.at_mut(i, j) = (j + 1) as f32;
            }
        }
        let mut y = Mat::zeros(0, 0);
        chaos.forward_batch_into(&x, &mut y);
        assert_eq!(y.cols(), 2, "one column dropped");
        for j in 0..2 {
            for (i, v) in y.col(j).iter().enumerate() {
                assert_eq!(v.to_bits(), (x.col(j)[i] * 2.0).to_bits(), "survivors unaltered");
            }
        }
    }
}
