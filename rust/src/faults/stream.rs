//! Wire-boundary fault injection: a `Read`/`Write` wrapper that applies a
//! seeded schedule of short ops, delays, bit flips, and resets.

use super::{draw_delay, FaultSpec};
use crate::rng::Pcg64;
use std::io::{self, Read, Write};
use std::time::Duration;

/// One drawn fault for one stream operation. `Delay` is resolved to a
/// concrete duration at draw time so a recorded schedule (`schedule`) is
/// comparable across runs.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum StreamFault {
    /// No fault: delegate the op unchanged.
    Pass,
    /// Sleep, then delegate the op unchanged.
    Delay(Duration),
    /// Truncate the op to at most `max` bytes (never below 1, so progress
    /// is still guaranteed and `read_exact`/`write_all` loops terminate).
    Short { max: usize },
    /// Flip bit `bit % 8` of byte `at % len` of the transferred bytes.
    /// Downstream the frame CRC rejects the frame — corruption is loud.
    Corrupt { at: usize, bit: u32 },
    /// Kill the op: `ConnectionReset` on read, `BrokenPipe` on write.
    Reset,
}

/// A seeded per-stream fault source. Draws exactly one `u64` plus any
/// fault parameters per operation, so the schedule is a pure function of
/// the seed and the op count — independent of payload sizes or timing.
#[derive(Clone, Debug)]
pub struct StreamInjector {
    spec: FaultSpec,
    rng: Pcg64,
}

impl StreamInjector {
    pub(super) fn new(spec: FaultSpec, rng: Pcg64) -> Self {
        Self { spec, rng }
    }

    /// Draw the fault for the next operation. Thresholds are cumulative
    /// over (reset, corrupt, short, delay) in that fixed order; anything
    /// past the sum is `Pass`.
    pub fn next(&mut self) -> StreamFault {
        let s = &self.spec;
        if s.stream_rate_sum() <= 0.0 {
            // Keep the zero-spec stream cheap *and* schedule-stable: no
            // uniform is burned, so later raising one rate does not shift
            // unrelated draws.
            return StreamFault::Pass;
        }
        let u = self.rng.uniform();
        let mut t = s.reset;
        if u < t {
            return StreamFault::Reset;
        }
        t += s.corrupt;
        if u < t {
            return StreamFault::Corrupt {
                at: self.rng.below(u64::MAX) as usize,
                bit: (self.rng.next_u64() % 8) as u32,
            };
        }
        t += s.short;
        if u < t {
            return StreamFault::Short { max: 1 + self.rng.below(s.short_max.max(1) as u64) as usize };
        }
        t += s.delay;
        if u < t {
            return StreamFault::Delay(draw_delay(&mut self.rng, s.delay_ms));
        }
        StreamFault::Pass
    }

    /// Record the next `n` draws — the replayable fault schedule. Consumes
    /// the injector's stream exactly like `n` live operations would, which
    /// is what makes "same seed → same schedule" directly testable.
    pub fn schedule(mut self, n: usize) -> Vec<StreamFault> {
        (0..n).map(|_| self.next()).collect()
    }
}

/// A fault-injecting wrapper over any byte stream. With `injector: None`
/// every call is a plain delegate (one branch, no allocation, no extra
/// syscall); with an injector, one fault is drawn per `read`/`write` and
/// applied to that op.
///
/// A drawn fault that cannot be applied because the underlying op would
/// not have transferred bytes (`WouldBlock`/`Interrupted`/`TimedOut`, as
/// the front-end's polled reads produce constantly) is stashed and retried
/// on the next call, so poll ticks don't silently burn the schedule.
pub struct FaultyStream<S> {
    inner: S,
    injector: Option<StreamInjector>,
    pending: Option<StreamFault>,
}

impl<S> FaultyStream<S> {
    /// Wrap `inner` with a fault source.
    pub fn new(inner: S, injector: StreamInjector) -> Self {
        Self { inner, injector: Some(injector), pending: None }
    }

    /// A transparent wrapper: every op is a straight delegate. Exists so
    /// call sites can be generic over `FaultyStream<S>` without paying for
    /// injection.
    pub fn passthrough(inner: S) -> Self {
        Self { inner, injector: None, pending: None }
    }

    pub fn get_ref(&self) -> &S {
        &self.inner
    }

    pub fn get_mut(&mut self) -> &mut S {
        &mut self.inner
    }

    pub fn into_inner(self) -> S {
        self.inner
    }

    /// Take the fault for this op: the stashed one from a no-progress
    /// retry if present, else a fresh draw.
    fn draw(&mut self) -> StreamFault {
        if let Some(f) = self.pending.take() {
            return f;
        }
        match self.injector.as_mut() {
            Some(inj) => inj.next(),
            None => StreamFault::Pass,
        }
    }

    /// `WouldBlock`-family errors mean the op transferred nothing; keep
    /// the drawn fault for the retry instead of dropping it.
    fn stash_if_no_progress(&mut self, fault: StreamFault, err: &io::Error) {
        if matches!(
            err.kind(),
            io::ErrorKind::WouldBlock | io::ErrorKind::Interrupted | io::ErrorKind::TimedOut
        ) {
            self.pending = Some(fault);
        }
    }
}

fn flip_bit(buf: &mut [u8], at: usize, bit: u32) {
    if !buf.is_empty() {
        buf[at % buf.len()] ^= 1u8 << (bit % 8);
    }
}

impl<S: Read> Read for FaultyStream<S> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        if self.injector.is_none() && self.pending.is_none() {
            return self.inner.read(buf);
        }
        let fault = self.draw();
        match fault {
            StreamFault::Pass => self.inner.read(buf),
            StreamFault::Delay(d) => {
                std::thread::sleep(d);
                self.inner.read(buf)
            }
            StreamFault::Short { max } => {
                let cap = max.min(buf.len()).max(1.min(buf.len()));
                match self.inner.read(&mut buf[..cap]) {
                    Ok(n) => Ok(n),
                    Err(e) => {
                        self.stash_if_no_progress(StreamFault::Short { max }, &e);
                        Err(e)
                    }
                }
            }
            StreamFault::Corrupt { at, bit } => match self.inner.read(buf) {
                Ok(n) => {
                    flip_bit(&mut buf[..n], at, bit);
                    Ok(n)
                }
                Err(e) => {
                    self.stash_if_no_progress(StreamFault::Corrupt { at, bit }, &e);
                    Err(e)
                }
            },
            StreamFault::Reset => {
                Err(io::Error::new(io::ErrorKind::ConnectionReset, "injected connection reset"))
            }
        }
    }
}

impl<S: Write> Write for FaultyStream<S> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        if self.injector.is_none() && self.pending.is_none() {
            return self.inner.write(buf);
        }
        let fault = self.draw();
        match fault {
            StreamFault::Pass => self.inner.write(buf),
            StreamFault::Delay(d) => {
                std::thread::sleep(d);
                self.inner.write(buf)
            }
            StreamFault::Short { max } => {
                let cap = max.min(buf.len()).max(1.min(buf.len()));
                match self.inner.write(&buf[..cap]) {
                    Ok(n) => Ok(n),
                    Err(e) => {
                        self.stash_if_no_progress(StreamFault::Short { max }, &e);
                        Err(e)
                    }
                }
            }
            StreamFault::Corrupt { at, bit } => {
                // The only allocating path, and it only exists when a
                // corruption fault actually fires.
                let mut poisoned = buf.to_vec();
                flip_bit(&mut poisoned, at, bit);
                match self.inner.write(&poisoned) {
                    Ok(n) => Ok(n),
                    Err(e) => {
                        self.stash_if_no_progress(StreamFault::Corrupt { at, bit }, &e);
                        Err(e)
                    }
                }
            }
            StreamFault::Reset => {
                Err(io::Error::new(io::ErrorKind::BrokenPipe, "injected broken pipe"))
            }
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        self.inner.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::super::{FaultPlan, FaultSpec};
    use super::*;
    use std::io::Cursor;

    /// Zero-fault plan = transparent passthrough: reading a buffer through
    /// the wrapper is bit-exact against reading the plain stream, and
    /// writes come out byte-identical.
    #[test]
    fn zero_fault_plan_is_bit_exact_passthrough() {
        let data: Vec<u8> = (0..4096u32).map(|i| (i * 2654435761 >> 13) as u8).collect();

        let plan = FaultPlan::new(7, FaultSpec::default());
        let mut wrapped = FaultyStream::new(Cursor::new(data.clone()), plan.stream_injector(0));
        let mut via_wrapper = Vec::new();
        wrapped.read_to_end(&mut via_wrapper).unwrap();
        assert_eq!(via_wrapper, data);

        let mut sink = FaultyStream::new(Cursor::new(Vec::new()), plan.stream_injector(1));
        sink.write_all(&data).unwrap();
        sink.flush().unwrap();
        assert_eq!(sink.into_inner().into_inner(), data);

        // The explicit passthrough constructor behaves identically.
        let mut plain = FaultyStream::passthrough(Cursor::new(data.clone()));
        let mut out = Vec::new();
        plain.read_to_end(&mut out).unwrap();
        assert_eq!(out, data);
    }

    /// Short faults still make progress, so `read_exact`/`write_all`
    /// loops over a shortened stream terminate with the full payload.
    #[test]
    fn short_ops_preserve_payload_under_read_exact_and_write_all() {
        let spec = FaultSpec { short: 1.0, short_max: 3, ..FaultSpec::default() };
        let plan = FaultPlan::new(21, spec);
        let data: Vec<u8> = (0..777u32).map(|i| (i % 251) as u8).collect();

        let mut rd = FaultyStream::new(Cursor::new(data.clone()), plan.stream_injector(0));
        let mut got = vec![0u8; data.len()];
        rd.read_exact(&mut got).unwrap();
        assert_eq!(got, data);

        let mut wr = FaultyStream::new(Cursor::new(Vec::new()), plan.stream_injector(1));
        wr.write_all(&data).unwrap();
        assert_eq!(wr.into_inner().into_inner(), data);
    }

    /// A corrupting write changes exactly one bit of the payload — loud to
    /// a CRC, but deterministic: the same seed flips the same bit.
    #[test]
    fn corruption_flips_exactly_one_bit_deterministically() {
        let spec = FaultSpec { corrupt: 1.0, ..FaultSpec::default() };
        let data = vec![0u8; 64];

        let flipped: Vec<Vec<u8>> = (0..2)
            .map(|_| {
                let plan = FaultPlan::new(33, spec.clone());
                let mut wr = FaultyStream::new(Cursor::new(Vec::new()), plan.stream_injector(0));
                wr.write_all(&data).unwrap();
                wr.into_inner().into_inner()
            })
            .collect();
        assert_eq!(flipped[0], flipped[1], "same seed must corrupt the same bit");
        let diff_bits: u32 = flipped[0].iter().map(|b| b.count_ones()).sum();
        assert_eq!(diff_bits, 1, "exactly one bit flipped in one write op");
    }

    /// Reset faults surface as the right error kind per direction.
    #[test]
    fn reset_maps_to_connection_reset_and_broken_pipe() {
        let spec = FaultSpec { reset: 1.0, ..FaultSpec::default() };
        let plan = FaultPlan::new(5, spec);

        let mut rd = FaultyStream::new(Cursor::new(vec![1, 2, 3]), plan.stream_injector(0));
        let mut buf = [0u8; 3];
        assert_eq!(rd.read(&mut buf).unwrap_err().kind(), io::ErrorKind::ConnectionReset);

        let mut wr = FaultyStream::new(Cursor::new(Vec::new()), plan.stream_injector(1));
        assert_eq!(wr.write(&[1, 2, 3]).unwrap_err().kind(), io::ErrorKind::BrokenPipe);
    }

    /// A fault drawn against an op that made no progress (`WouldBlock`) is
    /// replayed on the retry, not dropped — poll ticks don't consume the
    /// schedule.
    #[test]
    fn no_progress_ops_do_not_burn_the_schedule() {
        struct Flaky {
            blocks_left: usize,
            data: Cursor<Vec<u8>>,
        }
        impl Read for Flaky {
            fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
                if self.blocks_left > 0 {
                    self.blocks_left -= 1;
                    return Err(io::Error::new(io::ErrorKind::WouldBlock, "not ready"));
                }
                self.data.read(buf)
            }
        }

        // One guaranteed Short fault per op; the first three underlying
        // reads block. The short cap must still apply to the read that
        // finally succeeds.
        let spec = FaultSpec { short: 1.0, short_max: 2, ..FaultSpec::default() };
        let plan = FaultPlan::new(11, spec);
        let flaky = Flaky { blocks_left: 3, data: Cursor::new(vec![9u8; 64]) };
        let mut rd = FaultyStream::new(flaky, plan.stream_injector(0));

        let mut buf = [0u8; 64];
        let mut blocked = 0;
        let n = loop {
            match rd.read(&mut buf) {
                Ok(n) => break n,
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => blocked += 1,
                Err(e) => panic!("unexpected error: {e}"),
            }
        };
        assert_eq!(blocked, 3);
        assert!(n >= 1 && n <= 2, "short cap survived the WouldBlock retries, got {n}");
    }
}
