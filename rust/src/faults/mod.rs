//! Deterministic fault injection for the serving stack.
//!
//! Chaos testing is only useful when a failure found once can be found
//! again: every fault this module injects is drawn from a seeded
//! [`Pcg64`](crate::rng::Pcg64) stream, so one `u64` seed fully determines
//! the fault *schedule* — which operation gets a short read, which frame
//! gets a flipped bit, which batch panics. Re-running with the same seed
//! replays the same schedule byte for byte (`LB2_CHAOS_SEED` in `make
//! chaos` carries it into CI and back to a laptop).
//!
//! Two injection boundaries, matching where real deployments fail:
//!
//! - **The wire** ([`FaultyStream`]): a `Read`/`Write` wrapper over any
//!   stream (a `TcpStream` half in production, a `Cursor` in unit tests)
//!   that injects short reads/writes, delays, single-bit corruption, and
//!   mid-frame connection resets. [`TcpFrontend`](crate::serving::TcpFrontend)
//!   wraps each accepted connection's halves when
//!   [`ServingConfig::faults`](crate::serving::ServingConfig) is set;
//!   [`WireClient`](crate::serving::WireClient) can be constructed over one
//!   directly.
//! - **The backend** ([`ChaosBackend`]): a
//!   [`BatchBackend`](crate::coordinator::BatchBackend) wrapper that
//!   injects panics, stalls, and wrong-shape outputs into the worker drain
//!   loop — the faults the server's panic isolation and shape check are
//!   supposed to absorb.
//!
//! Injected faults are *detectable-by-construction*: corruption is caught
//! by the frame CRC, wrong shapes by the server's column check, panics by
//! `catch_unwind` — so a chaos soak can still assert that every answer
//! that does come back is bit-identical to the in-process forward. The
//! injectors never silently alter a payload that passes validation.
//!
//! **Zero-cost when disabled.** Fault injection is opt-in at construction:
//! the server's no-fault path never builds a [`FaultyStream`] (streams are
//! used bare), a `FaultyStream` with no injector is a branch-only
//! passthrough, and a backend is only wrapped in [`ChaosBackend`] by an
//! explicit factory. No allocation or syscall is added to frame
//! encode/decode or the worker drain loop when faults are off.

mod backend;
mod stream;

pub use backend::{BackendFault, BackendInjector, ChaosBackend};
pub use stream::{FaultyStream, StreamFault, StreamInjector};

use crate::rng::{derive_seed, Pcg64};
use std::time::Duration;

/// Per-operation fault rates. All rates are probabilities in `[0, 1]`
/// drawn against one uniform per operation, so at most one fault fires per
/// read/write/batch; the default is all-zero (fully transparent).
#[derive(Clone, Debug)]
pub struct FaultSpec {
    /// P(connection reset) per stream op (`ConnectionReset` on read,
    /// `BrokenPipe` on write) — the mid-frame socket death.
    pub reset: f64,
    /// P(flip one bit of the transferred bytes) per stream op. Always
    /// caught by the frame CRC downstream.
    pub corrupt: f64,
    /// P(truncate the op to 1..=`short_max` bytes) per stream op.
    pub short: f64,
    /// Cap on the bytes a shortened op may transfer.
    pub short_max: usize,
    /// P(sleep before the op) per stream op.
    pub delay: f64,
    /// Cap on an injected delay (uniform in `1..=delay_ms` milliseconds).
    pub delay_ms: u64,
    /// P(panic) per backend batch execution.
    pub backend_panic: f64,
    /// P(stall before executing) per backend batch execution.
    pub backend_stall: f64,
    /// Cap on an injected backend stall (uniform in `1..=backend_stall_ms`).
    pub backend_stall_ms: u64,
    /// P(return a wrong-column-count output) per backend batch execution.
    pub backend_wrong_shape: f64,
}

impl Default for FaultSpec {
    fn default() -> Self {
        Self {
            reset: 0.0,
            corrupt: 0.0,
            short: 0.0,
            short_max: 16,
            delay: 0.0,
            delay_ms: 5,
            backend_panic: 0.0,
            backend_stall: 0.0,
            backend_stall_ms: 20,
            backend_wrong_shape: 0.0,
        }
    }
}

impl FaultSpec {
    /// The preset the chaos soak and `serve --chaos-seed` use: frequent
    /// partial I/O, occasional corruption/resets/panics — aggressive
    /// enough to exercise every recovery path, bounded enough that a
    /// retrying client converges in a handful of attempts.
    pub fn moderate() -> Self {
        Self {
            reset: 0.01,
            corrupt: 0.01,
            short: 0.10,
            short_max: 16,
            delay: 0.05,
            delay_ms: 3,
            backend_panic: 0.04,
            backend_stall: 0.04,
            backend_stall_ms: 15,
            backend_wrong_shape: 0.02,
        }
    }

    fn stream_rate_sum(&self) -> f64 {
        self.reset + self.corrupt + self.short + self.delay
    }
}

/// A seeded, reproducible fault schedule factory. One plan covers a whole
/// server run; each connection half and each worker backend derives its
/// own independent sub-stream from `(seed, index)`, so schedules do not
/// depend on accept order or worker interleaving — connection `k` sees the
/// same faults no matter what the other connections are doing.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    seed: u64,
    spec: FaultSpec,
}

/// Domain separators so stream and backend injectors with equal indices
/// never share an RNG stream.
const STREAM_DOMAIN: u64 = 1;
const BACKEND_DOMAIN: u64 = 2;

impl FaultPlan {
    pub fn new(seed: u64, spec: FaultSpec) -> Self {
        Self { seed, spec }
    }

    /// The seed the plan was built from (logged so failures are replayable).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Injector for stream sub-stream `index`. The TCP front-end uses
    /// `2*conn` for connection `conn`'s read half and `2*conn + 1` for its
    /// write half.
    pub fn stream_injector(&self, index: u64) -> StreamInjector {
        StreamInjector::new(
            self.spec.clone(),
            Pcg64::seed(derive_seed(derive_seed(self.seed, STREAM_DOMAIN), index)),
        )
    }

    /// Injector for worker backend `index`.
    pub fn backend_injector(&self, index: u64) -> BackendInjector {
        BackendInjector::new(
            self.spec.clone(),
            Pcg64::seed(derive_seed(derive_seed(self.seed, BACKEND_DOMAIN), index)),
        )
    }
}

/// Draw an injected delay duration: uniform in `1..=cap_ms` milliseconds.
fn draw_delay(rng: &mut Pcg64, cap_ms: u64) -> Duration {
    Duration::from_millis(1 + rng.below(cap_ms.max(1)))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The acceptance contract: one seed fully determines the fault
    /// schedule — two plans built from the same seed replay identical
    /// schedules at every injector index, for both boundaries.
    #[test]
    fn same_seed_replays_identical_schedules() {
        let a = FaultPlan::new(0xC4A0, FaultSpec::moderate());
        let b = FaultPlan::new(0xC4A0, FaultSpec::moderate());
        for idx in [0u64, 1, 7, 63] {
            assert_eq!(
                a.stream_injector(idx).schedule(512),
                b.stream_injector(idx).schedule(512),
                "stream schedule diverged at index {idx}"
            );
            assert_eq!(
                a.backend_injector(idx).schedule(512),
                b.backend_injector(idx).schedule(512),
                "backend schedule diverged at index {idx}"
            );
        }
    }

    /// Distinct seeds and distinct injector indices produce distinct
    /// schedules (independent sub-streams, not one shared clock).
    #[test]
    fn distinct_seeds_and_indices_diverge() {
        let a = FaultPlan::new(1, FaultSpec::moderate());
        let b = FaultPlan::new(2, FaultSpec::moderate());
        assert_ne!(a.stream_injector(0).schedule(512), b.stream_injector(0).schedule(512));
        assert_ne!(a.stream_injector(0).schedule(512), a.stream_injector(1).schedule(512));
        // Stream and backend domains are separated even at equal indices.
        let s: Vec<String> =
            a.stream_injector(3).schedule(64).iter().map(|f| format!("{f:?}")).collect();
        let k: Vec<String> =
            a.backend_injector(3).schedule(64).iter().map(|f| format!("{f:?}")).collect();
        assert_ne!(s, k);
    }

    /// An all-zero spec draws only `Pass`: the plan exists but is inert.
    #[test]
    fn zero_spec_is_all_pass() {
        let plan = FaultPlan::new(9, FaultSpec::default());
        for f in plan.stream_injector(0).schedule(256) {
            assert_eq!(f, StreamFault::Pass);
        }
        for f in plan.backend_injector(0).schedule(256) {
            assert_eq!(f, BackendFault::Pass);
        }
    }

    /// The moderate preset actually fires every fault kind within a
    /// bounded window (rates are not accidentally zeroed by the cumulative
    /// threshold arithmetic).
    #[test]
    fn moderate_preset_covers_every_fault_kind() {
        let plan = FaultPlan::new(0x5EED, FaultSpec::moderate());
        let stream = plan.stream_injector(0).schedule(4096);
        assert!(stream.iter().any(|f| matches!(f, StreamFault::Reset)));
        assert!(stream.iter().any(|f| matches!(f, StreamFault::Corrupt { .. })));
        assert!(stream.iter().any(|f| matches!(f, StreamFault::Short { .. })));
        assert!(stream.iter().any(|f| matches!(f, StreamFault::Delay(_))));
        let backend = plan.backend_injector(0).schedule(4096);
        assert!(backend.iter().any(|f| matches!(f, BackendFault::Panic)));
        assert!(backend.iter().any(|f| matches!(f, BackendFault::Stall(_))));
        assert!(backend.iter().any(|f| matches!(f, BackendFault::WrongShape)));
    }
}
