//! L3 coordinator: compression job scheduling, the QAKD training driver,
//! evaluation, and batched serving.
//!
//! The paper's contribution is an initialization algorithm, so per the
//! architecture contract L3 is a *driver-plus-substrate*: it owns process
//! lifecycle, the parallel layer-compression pipeline, the training loop
//! that executes the AOT `*_train_step` artifacts through PJRT, metrics,
//! and the CLI. All numerics (SVD → rotation → Joint-ITQ → Dual-SVID) run
//! natively in rust (`littlebit::compress`) — the student initialization
//! pipeline needs no Python at run time.
//!
//! Serving runs each drained dynamic batch as **one matrix** through a
//! [`BatchBackend`] on a configurable multi-worker pool
//! ([`ServerConfig::workers`]), reporting tokens/s next to the latency
//! percentiles. The QAKD trainer requires the PJRT runtime and is
//! compile-gated behind the `xla` cargo feature (absent in the offline
//! build image).

mod jobs;
mod metrics;
mod params;
mod server;
#[cfg(feature = "xla")]
mod trainer;

pub use jobs::{
    run_compression_jobs, run_compression_jobs_streaming, CompressionJob, JobInput, JobResult,
    LayerOutcome,
};
pub use metrics::Metrics;
pub use params::ParamStore;
pub use server::{
    BatchBackend, HealthPolicy, HealthState, InferenceServer, MethodStackBackend,
    PackedResidualBackend, PackedStackBackend, ReplySink, Request, RequestOutcome, Response,
    ServerConfig, ServerStats, SubmitHandle, TrySubmitError, FILL_BUCKETS, FILL_BUCKET_COUNT,
};
#[cfg(feature = "xla")]
pub use trainer::{QakdOutcome, QatDriver, StudentVariant, TrainTrace};
