//! The QAKD training driver: executes the AOT train/eval artifacts through
//! PJRT, owning the optimizer state, the corpus stream, and the metric
//! traces (loss curve for Fig. 7, sign-flip ratio for Fig. 8).

use super::params::{init_student, ParamStore};
use crate::data::{Corpus, CorpusConfig};
use crate::littlebit::InitStrategy;
use crate::runtime::{lit, Executable, Manifest, Runtime};
use anyhow::Result;

/// Which student architecture/initialization arm to train (the Fig. 7 /
/// Table 3 axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StudentVariant {
    /// Strategy A: tiny-rank FP latents.
    TinyRankFp,
    /// LittleBit baseline (standard Dual-SVID init).
    LittleBit,
    /// + Internal Random Rotation.
    RandomRotation,
    /// LittleBit-2 (Joint-ITQ init).
    LittleBit2 { itq_iters: usize },
}

impl StudentVariant {
    pub fn label(&self) -> &'static str {
        match self {
            StudentVariant::TinyRankFp => "tinyrank-fp",
            StudentVariant::LittleBit => "littlebit",
            StudentVariant::RandomRotation => "littlebit+rot",
            StudentVariant::LittleBit2 { .. } => "littlebit2",
        }
    }

    fn strategy(&self) -> InitStrategy {
        match self {
            StudentVariant::TinyRankFp | StudentVariant::LittleBit => InitStrategy::Standard,
            StudentVariant::RandomRotation => InitStrategy::RandomRotation,
            StudentVariant::LittleBit2 { itq_iters } => {
                InitStrategy::JointItq { iters: *itq_iters }
            }
        }
    }

    fn is_fp(&self) -> bool {
        matches!(self, StudentVariant::TinyRankFp)
    }
}

/// Per-step training trace.
#[derive(Clone, Debug, Default)]
pub struct TrainTrace {
    pub losses: Vec<f32>,
    /// Fraction of binary latent parameters that flipped sign each step
    /// (empty for the FP variant).
    pub flip_ratio: Vec<f32>,
}

/// Result of one full QAKD run.
pub struct QakdOutcome {
    pub variant: StudentVariant,
    pub trace: TrainTrace,
    pub final_eval_ce: f32,
    pub params: ParamStore,
}

/// Training driver bound to a runtime + manifest. Compiled executables are
/// cached per artifact name — the student graphs take minutes to compile on
/// this CPU, and the Fig 7 sweep reuses each one across variants.
pub struct QatDriver {
    runtime: Runtime,
    pub manifest: Manifest,
    corpus_seed: u64,
    exe_cache: std::cell::RefCell<std::collections::HashMap<String, std::rc::Rc<Executable>>>,
}

impl QatDriver {
    pub fn new(artifact_dir: &str, corpus_seed: u64) -> Result<Self> {
        let runtime = Runtime::new(artifact_dir)?;
        let manifest = runtime.manifest()?;
        Ok(Self {
            runtime,
            manifest,
            corpus_seed,
            exe_cache: Default::default(),
        })
    }

    /// Load (or fetch from cache) a compiled artifact.
    fn exe(&self, name: &str) -> Result<std::rc::Rc<Executable>> {
        if let Some(e) = self.exe_cache.borrow().get(name) {
            return Ok(std::rc::Rc::clone(e));
        }
        let e = std::rc::Rc::new(self.runtime.load_checked(name)?);
        self.exe_cache
            .borrow_mut()
            .insert(name.to_string(), std::rc::Rc::clone(&e));
        Ok(e)
    }

    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }

    /// Streams share the language (salt) and differ only in position:
    /// offset 0/1 = teacher/student training, 2 = held-out eval.
    fn corpus(&self, offset: u64) -> Corpus {
        let cfg = CorpusConfig { vocab: self.manifest.config.vocab, ..Default::default() };
        Corpus::with_salt(cfg, self.corpus_seed, self.corpus_seed + 1000 * offset)
    }

    fn tokens_literal(&self, corpus: &mut Corpus, seq: usize) -> Result<xla::Literal> {
        let b = self.manifest.config.batch;
        let toks = corpus.next_block(b, seq);
        lit::array_i32(&toks, &[b, seq + 1])
    }

    /// Load the teacher initialization written by aot.py.
    pub fn teacher_init(&self) -> Result<ParamStore> {
        let dir = self
            .runtime
            .artifact_dir()
            .join(&self.manifest.teacher_init_dir);
        ParamStore::load_bins(&self.manifest.teacher_spec, dir)
    }

    /// Pretrain the teacher with plain CE. Returns (params, loss trace).
    pub fn train_teacher(
        &self,
        steps: usize,
        lr: f32,
        mut log: impl FnMut(usize, f32),
    ) -> Result<(ParamStore, Vec<f32>)> {
        let exe = self.exe("teacher_train_step")?;
        let mut params = self.teacher_init()?;
        let mut m = ParamStore::zeros(&self.manifest.teacher_spec);
        let mut v = ParamStore::zeros(&self.manifest.teacher_spec);
        let mut corpus = self.corpus(0);
        let mut losses = Vec::with_capacity(steps);
        let n = params.values.len();
        for step in 0..steps {
            let mut inputs = params.to_literals()?;
            inputs.extend(m.to_literals()?);
            inputs.extend(v.to_literals()?);
            inputs.push(lit::scalar_f32(step as f32));
            inputs.push(self.tokens_literal(&mut corpus, self.manifest.config.seq)?);
            inputs.push(lit::scalar_f32(lr));
            let out = exe.run(&inputs)?;
            params.update_from_literals(&out[..n])?;
            m.update_from_literals(&out[n..2 * n])?;
            v.update_from_literals(&out[2 * n..3 * n])?;
            let loss = lit::to_scalar_f32(&out[3 * n])?;
            losses.push(loss);
            log(step, loss);
        }
        Ok((params, losses))
    }

    /// Initialize a student from teacher weights (rust-native compression).
    pub fn init_student(
        &self,
        teacher: &ParamStore,
        variant: StudentVariant,
        seed: u64,
    ) -> Result<ParamStore> {
        let spec = if variant.is_fp() {
            &self.manifest.student_fp_spec
        } else {
            &self.manifest.student_spec
        };
        init_student(teacher, spec, variant.strategy(), variant.is_fp(), seed)
    }

    /// One QAKD run: init from teacher, train `steps`, eval on held-out
    /// stream. `log(step, loss, flip_ratio)`.
    pub fn train_student(
        &self,
        teacher: &ParamStore,
        variant: StudentVariant,
        steps: usize,
        lr: f32,
        mut log: impl FnMut(usize, f32, f32),
    ) -> Result<QakdOutcome> {
        let (step_name, eval_name) = if variant.is_fp() {
            ("student_fp_train_step", "student_fp_eval")
        } else {
            ("student_train_step", "student_eval")
        };
        let exe = self.exe(step_name)?;
        let spec = if variant.is_fp() {
            &self.manifest.student_fp_spec
        } else {
            &self.manifest.student_spec
        };

        let mut params = self.init_student(teacher, variant, 0xA11CE)?;
        let mut m = ParamStore::zeros(spec);
        let mut v = ParamStore::zeros(spec);
        let mut corpus = self.corpus(1);
        let n = params.values.len();
        let latent_total: usize = spec
            .iter()
            .filter(|(name, _)| name.contains(".lat_"))
            .map(|(_, s)| s.iter().product::<usize>())
            .sum();

        let mut trace = TrainTrace::default();
        for step in 0..steps {
            let mut inputs = params.to_literals()?;
            inputs.extend(teacher.to_literals()?);
            inputs.extend(m.to_literals()?);
            inputs.extend(v.to_literals()?);
            inputs.push(lit::scalar_f32(step as f32));
            inputs.push(self.tokens_literal(&mut corpus, self.manifest.config.seq)?);
            inputs.push(lit::scalar_f32(lr));
            let out = exe.run(&inputs)?;
            params.update_from_literals(&out[..n])?;
            m.update_from_literals(&out[n..2 * n])?;
            v.update_from_literals(&out[2 * n..3 * n])?;
            let loss = lit::to_scalar_f32(&out[3 * n])?;
            let flips = lit::to_scalar_f32(&out[3 * n + 1])?;
            let ratio = if latent_total > 0 { flips / latent_total as f32 } else { 0.0 };
            trace.losses.push(loss);
            trace.flip_ratio.push(ratio);
            log(step, loss, ratio);
        }

        let final_eval_ce = self.eval_ce(eval_name, &params, 8)?;
        Ok(QakdOutcome { variant, trace, final_eval_ce, params })
    }

    /// Held-out mean CE over `n_batches` fresh batches (PPL = exp(CE)).
    pub fn eval_ce(&self, eval_name: &str, params: &ParamStore, n_batches: usize) -> Result<f32> {
        let exe = self.exe(eval_name)?;
        // Held-out stream: a corpus seed far from the training offsets but
        // with the SAME latent structure salt → same distribution.
        let mut corpus = self.corpus(2);
        let mut acc = 0.0f32;
        for _ in 0..n_batches {
            let mut inputs = params.to_literals()?;
            inputs.push(self.tokens_literal(&mut corpus, self.manifest.config.seq)?);
            let out = exe.run(&inputs)?;
            acc += lit::to_scalar_f32(&out[0])?;
        }
        Ok(acc / n_batches as f32)
    }

    /// Load the Pallas-kernel inference executable.
    pub fn load_infer(&self) -> Result<Executable> {
        self.runtime.load_checked("student_infer")
    }
}

/// Perplexity from mean CE.
pub fn ppl(ce: f32) -> f32 {
    ce.exp()
}
