//! Batched inference serving.
//!
//! A minimal vLLM-router-style front: requests enter a bounded queue; a
//! worker drains up to `max_batch` at a time (waiting at most `max_wait`
//! for stragglers — classic dynamic batching) and executes the batch
//! through a pluggable backend (the packed MatMul-free tri-scale stack in
//! `examples/serve.rs`, or a compiled `student_infer` artifact).
//!
//! Latency percentiles and batch-size statistics are tracked for the §6.2
//! throughput experiments.

use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// One inference request.
pub struct Request {
    pub id: u64,
    pub input: Vec<f32>,
    /// Filled with the output and latency on completion.
    pub reply: SyncSender<Response>,
    enqueued: Instant,
}

/// Completed response.
pub struct Response {
    pub id: u64,
    pub output: Vec<f32>,
    pub latency: Duration,
    pub batch_size: usize,
}

/// Aggregate serving statistics.
#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    pub served: u64,
    pub batches: u64,
    pub mean_batch: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
}

/// The server: owns the queue and worker thread. `tx` is an Option so
/// shutdown/drop can disconnect the queue *before* joining the worker
/// (joining first would deadlock: the worker blocks on `recv`).
pub struct InferenceServer {
    tx: Option<SyncSender<Request>>,
    worker: Option<JoinHandle<()>>,
    stats: Arc<Mutex<StatsInner>>,
}

#[derive(Default)]
struct StatsInner {
    served: u64,
    batches: u64,
    batch_total: u64,
    latencies_ms: Vec<f64>,
}

impl InferenceServer {
    /// `backend(batch_inputs) -> batch_outputs` runs a whole batch; it is
    /// moved onto the worker thread.
    pub fn start(
        max_batch: usize,
        max_wait: Duration,
        queue_depth: usize,
        backend: impl FnMut(&[Vec<f32>]) -> Vec<Vec<f32>> + Send + 'static,
    ) -> Self {
        let (tx, rx) = sync_channel::<Request>(queue_depth);
        let stats: Arc<Mutex<StatsInner>> = Arc::default();
        let worker_stats = Arc::clone(&stats);
        let worker = std::thread::spawn(move || {
            Self::worker_loop(rx, max_batch, max_wait, backend, worker_stats)
        });
        Self { tx: Some(tx), worker: Some(worker), stats }
    }

    fn worker_loop(
        rx: Receiver<Request>,
        max_batch: usize,
        max_wait: Duration,
        mut backend: impl FnMut(&[Vec<f32>]) -> Vec<Vec<f32>>,
        stats: Arc<Mutex<StatsInner>>,
    ) {
        loop {
            // Block for the first request of a batch.
            let first = match rx.recv() {
                Ok(r) => r,
                Err(_) => return, // all senders dropped: shut down
            };
            let deadline = Instant::now() + max_wait;
            let mut batch = vec![first];
            while batch.len() < max_batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(r) => batch.push(r),
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }

            let inputs: Vec<Vec<f32>> = batch.iter().map(|r| r.input.clone()).collect();
            let outputs = backend(&inputs);
            debug_assert_eq!(outputs.len(), batch.len());
            let bsize = batch.len();
            let done = Instant::now();
            {
                let mut s = stats.lock().expect("stats lock");
                s.batches += 1;
                s.batch_total += bsize as u64;
                for req in &batch {
                    s.served += 1;
                    s.latencies_ms
                        .push(done.duration_since(req.enqueued).as_secs_f64() * 1e3);
                }
            }
            for (req, output) in batch.into_iter().zip(outputs) {
                let latency = done.duration_since(req.enqueued);
                let _ = req.reply.send(Response {
                    id: req.id,
                    output,
                    latency,
                    batch_size: bsize,
                });
            }
        }
    }

    /// Submit a request; returns the receiver for its response.
    pub fn submit(&self, id: u64, input: Vec<f32>) -> Receiver<Response> {
        let (reply, rx) = sync_channel(1);
        let req = Request { id, input, reply, enqueued: Instant::now() };
        self.tx
            .as_ref()
            .expect("server not shut down")
            .send(req)
            .expect("server worker alive");
        rx
    }

    /// Snapshot statistics.
    pub fn stats(&self) -> ServerStats {
        let s = self.stats.lock().expect("stats lock");
        let mut lat = s.latencies_ms.clone();
        lat.sort_by(|a, b| a.partial_cmp(b).expect("finite"));
        let pct = |p: f64| -> f64 {
            if lat.is_empty() {
                0.0
            } else {
                lat[((lat.len() as f64 - 1.0) * p) as usize]
            }
        };
        ServerStats {
            served: s.served,
            batches: s.batches,
            mean_batch: if s.batches > 0 {
                s.batch_total as f64 / s.batches as f64
            } else {
                0.0
            },
            p50_ms: pct(0.5),
            p99_ms: pct(0.99),
        }
    }

    /// Graceful shutdown: drop the sender, join the worker.
    pub fn shutdown(mut self) -> ServerStats {
        let stats = self.stats();
        self.tx.take(); // disconnect the queue; worker's recv errors out
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        stats
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        self.tx.take(); // must disconnect BEFORE joining
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_backend(xs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        xs.iter().map(|x| x.iter().map(|v| v * 2.0).collect()).collect()
    }

    #[test]
    fn serves_single_request() {
        let server = InferenceServer::start(4, Duration::from_millis(1), 16, echo_backend);
        let rx = server.submit(1, vec![1.0, 2.0]);
        let resp = rx.recv().unwrap();
        assert_eq!(resp.output, vec![2.0, 4.0]);
        assert_eq!(resp.id, 1);
        let stats = server.shutdown();
        assert_eq!(stats.served, 1);
    }

    #[test]
    fn batches_concurrent_requests() {
        let server = InferenceServer::start(8, Duration::from_millis(20), 64, echo_backend);
        let rxs: Vec<_> = (0..8).map(|i| server.submit(i, vec![i as f32])).collect();
        let mut max_batch = 0;
        for rx in rxs {
            let resp = rx.recv().unwrap();
            max_batch = max_batch.max(resp.batch_size);
        }
        // With a 20ms window the requests should coalesce into few batches.
        assert!(max_batch >= 2, "no batching observed (max_batch={max_batch})");
        let stats = server.shutdown();
        assert_eq!(stats.served, 8);
        assert!(stats.mean_batch >= 1.0);
    }

    #[test]
    fn responses_match_requests() {
        let server = InferenceServer::start(4, Duration::from_millis(5), 64, echo_backend);
        let rxs: Vec<_> = (0..20).map(|i| server.submit(i, vec![i as f32])).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.id, i as u64);
            assert_eq!(resp.output, vec![2.0 * i as f32]);
        }
    }

    #[test]
    fn stats_percentiles_populated() {
        let server = InferenceServer::start(2, Duration::from_millis(1), 16, echo_backend);
        for i in 0..10 {
            let _ = server.submit(i, vec![0.0]).recv().unwrap();
        }
        let stats = server.stats();
        assert_eq!(stats.served, 10);
        assert!(stats.p99_ms >= stats.p50_ms);
    }
}
