//! Batched inference serving.
//!
//! A minimal vLLM-router-style front: requests enter a bounded queue; a
//! configurable pool of workers drains up to `max_batch` at a time (waiting
//! at most `max_wait` for stragglers — classic dynamic batching) and
//! executes each drained batch as **one matrix** through a pluggable
//! [`BatchBackend`] — the packed MatMul-free sign-GEMM stack in production
//! ([`PackedResidualBackend`]), or anything implementing the trait.
//!
//! Activations cross the backend boundary **feature-major** (`d × b`,
//! column `t` = request `t`) — the native layout of the sign-GEMM pipeline,
//! so the production path runs with zero transposes between queue and
//! kernels. Each worker owns one backend plus one reused output buffer, and
//! the production backend carries a [`BatchScratch`] — steady-state batch
//! execution is allocation-free end to end, with kernel row ranges
//! dispatched to the persistent [`SignPool`] instead of per-call spawns.
//!
//! Latency percentiles, batch-size statistics, and throughput (tokens/s —
//! one request = one token-step here) are tracked for the §6.2 experiments.

use crate::linalg::Mat;
use crate::model::{MethodStack, PackedStack};
use crate::packing::{BatchScratch, PackedResidual, SignPool};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Latency reservoir size: percentiles are computed over the most recent
/// `LAT_CAP` samples so `StatsInner` stays bounded on long-running servers.
const LAT_CAP: usize = 16_384;

/// One inference request.
pub struct Request {
    pub id: u64,
    pub input: Vec<f32>,
    /// Completion route: a per-request channel (in-process [`submit`]
    /// path) or a shared per-connection sink (the TCP front-end's
    /// response funnel).
    ///
    /// [`submit`]: InferenceServer::submit
    reply: ReplyTx,
    enqueued: Instant,
    /// Queue-time deadline: a request still waiting when this passes is
    /// dropped at drain time with [`RequestOutcome::Expired`] instead of
    /// spending a batch slot on an answer nobody is waiting for.
    deadline: Option<Instant>,
}

/// Completed response.
pub struct Response {
    pub id: u64,
    pub output: Vec<f32>,
    pub latency: Duration,
    pub batch_size: usize,
}

/// How a request left the server — the precise completion signal the
/// sink-based submit path receives. (The legacy channel path keeps its
/// original contract: only `Ok` is delivered; `Expired`/`Failed` surface
/// as the caller's `RecvError` when the reply sender drops.)
#[derive(Debug)]
pub enum RequestOutcome {
    /// Served: the batched forward produced this request's column.
    Ok(Response),
    /// The queue-time deadline passed before a worker drained it.
    Expired,
    /// The backend panicked or returned the wrong shape for its batch.
    Failed,
}

/// Completion sink for [`SubmitHandle::try_submit`]. The TCP front-end
/// hands every request of one connection the same funnel, so completions
/// from any worker serialize onto that connection's writer thread without
/// a per-request channel. `complete` is called exactly once per request,
/// from a worker thread; implementations must not block (the worker is
/// holding up its whole batch).
pub trait ReplySink: Send {
    fn complete(&self, id: u64, outcome: RequestOutcome);
}

/// Internal completion route (see [`Request::reply`]).
enum ReplyTx {
    /// [`InferenceServer::submit`]: one bounded channel per request.
    Channel(SyncSender<Response>),
    /// [`SubmitHandle::try_submit`]: shared sink, precise outcome.
    Sink(Box<dyn ReplySink>),
}

impl ReplyTx {
    fn complete(&self, id: u64, outcome: RequestOutcome) {
        match self {
            ReplyTx::Channel(tx) => {
                // Expired/Failed deliberately send nothing: dropping the
                // sender (with the Request) is the pre-TCP failure signal.
                if let RequestOutcome::Ok(resp) = outcome {
                    let _ = tx.send(resp);
                }
            }
            ReplyTx::Sink(sink) => sink.complete(id, outcome),
        }
    }
}

/// Why [`SubmitHandle::try_submit`] rejected a request at admission.
/// The rejecting variants carry a retry-after hint (milliseconds, ≥ 1)
/// derived from the observed batch-execution EMA — the TCP front-end
/// forwards it in the BUSY frame's `aux` so well-behaved clients back off
/// for roughly as long as the queue actually needs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrySubmitError {
    /// The bounded ingress queue is full — admission control says BUSY
    /// now rather than unbounded memory later.
    QueueFull { retry_after_ms: u32 },
    /// Load shedding: the request's deadline has already passed, or the
    /// estimated queue wait exceeds the time it has left — rejecting now
    /// is strictly better than accepting work guaranteed to expire.
    DeadlineUnmeetable { retry_after_ms: u32 },
    /// The server is shutting down (ingress disconnected).
    Closed,
}

impl TrySubmitError {
    /// The retry-after hint, if this rejection carries one.
    pub fn retry_after_ms(&self) -> Option<u32> {
        match self {
            TrySubmitError::QueueFull { retry_after_ms }
            | TrySubmitError::DeadlineUnmeetable { retry_after_ms } => Some(*retry_after_ms),
            TrySubmitError::Closed => None,
        }
    }
}

impl std::fmt::Display for TrySubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TrySubmitError::QueueFull { retry_after_ms } => {
                write!(f, "ingress queue full (retry after {retry_after_ms}ms)")
            }
            TrySubmitError::DeadlineUnmeetable { retry_after_ms } => {
                write!(f, "deadline unmeetable at current load (retry after {retry_after_ms}ms)")
            }
            TrySubmitError::Closed => write!(f, "server shutting down"),
        }
    }
}

impl std::error::Error for TrySubmitError {}

/// Coarse server health, the degradation state machine the HEALTH frame
/// and the `lb2_health` gauge expose. Driven by queue occupancy and the
/// recent failure rate (see [`HealthPolicy`]); `Draining` is entered
/// explicitly at shutdown and never left.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum HealthState {
    /// Accepting traffic, failure rate nominal.
    #[default]
    Healthy = 0,
    /// Still serving, but the queue is deep or recent failures are
    /// elevated — clients should back off and operators should look.
    Degraded = 1,
    /// Shutdown has begun: in-flight work drains, new work is refused.
    Draining = 2,
}

impl HealthState {
    /// Numeric code carried in the HEALTH_REPORT frame's `aux` and the
    /// `lb2_health` gauge.
    pub fn code(&self) -> u32 {
        *self as u32
    }

    pub fn name(&self) -> &'static str {
        match self {
            HealthState::Healthy => "healthy",
            HealthState::Degraded => "degraded",
            HealthState::Draining => "draining",
        }
    }

    pub fn from_code(code: u32) -> Option<Self> {
        Some(match code {
            0 => HealthState::Healthy,
            1 => HealthState::Degraded,
            2 => HealthState::Draining,
            _ => return None,
        })
    }
}

/// When the server reports [`HealthState::Degraded`]. Both triggers are
/// recoverable observations, so health flaps back to `Healthy` as soon as
/// the queue drains / the failure window clears.
#[derive(Clone, Debug)]
pub struct HealthPolicy {
    /// Degraded when the ingress queue holds at least this fraction of
    /// `queue_depth`.
    pub degraded_queue_frac: f64,
    /// Degraded when the recent-window failure rate (failed + expired over
    /// completed) exceeds this.
    pub degraded_failure_rate: f64,
    /// Minimum completions in the window before the failure-rate trigger
    /// may fire (a 1-for-1 start must not flag a fresh server).
    pub min_window: u64,
}

impl Default for HealthPolicy {
    fn default() -> Self {
        Self { degraded_queue_frac: 0.5, degraded_failure_rate: 0.10, min_window: 32 }
    }
}

/// Executes one drained batch as a single batched forward call.
///
/// `x` is `d_in × batch` **feature-major** — column `t` is request `t`'s
/// input; the backend must leave `y` as `d_out × batch` with the same
/// column order (`y` arrives in an unspecified shape and must be resized —
/// the server reuses one output buffer per worker so steady-state serving
/// allocates nothing in the backend). Every worker of the pool owns one
/// backend instance (hence `&mut self`: scratch buffers and counters need
/// no synchronization).
///
/// # Examples
///
/// ```
/// use littlebit2::coordinator::{InferenceServer, ServerConfig};
/// use littlebit2::linalg::Mat;
///
/// // Closures `FnMut(&Mat) -> Mat` implement BatchBackend (the returned
/// // matrix replaces the worker's output buffer).
/// let cfg = ServerConfig { workers: 2, ..Default::default() };
/// let server = InferenceServer::start_pool(cfg, |_worker| {
///     |x: &Mat| -> Mat { x.scale(2.0) }
/// });
/// let reply = server.submit(7, vec![1.0, 2.0]);
/// assert_eq!(reply.recv().unwrap().output, vec![2.0, 4.0]);
/// let stats = server.shutdown();
/// assert_eq!(stats.served, 1);
/// assert!(stats.tokens_per_s > 0.0);
/// ```
pub trait BatchBackend: Send + 'static {
    fn forward_batch_into(&mut self, x: &Mat, y: &mut Mat);
}

impl<F> BatchBackend for F
where
    F: FnMut(&Mat) -> Mat + Send + 'static,
{
    fn forward_batch_into(&mut self, x: &Mat, y: &mut Mat) {
        *y = self(x);
    }
}

/// Boxed backends work too, so a factory can pick a backend (or a chaos
/// wrapper around one) at run time.
impl BatchBackend for Box<dyn BatchBackend> {
    fn forward_batch_into(&mut self, x: &Mat, y: &mut Mat) {
        (**self).forward_batch_into(x, y);
    }
}

/// The production backend: a packed residual tri-scale layer driven through
/// the **fused** batched sign-GEMM pipeline on the persistent
/// [`SignPool`], with a per-worker thread knob for the row-range
/// partitioning. The server hands activations over feature-major — exactly
/// what the pipeline consumes — and each worker's backend carries its own
/// [`BatchScratch`], so a steady-state batch execution performs zero heap
/// allocations: no transposes, no spawns, no intermediate `Mat`s.
pub struct PackedResidualBackend {
    model: Arc<PackedResidual>,
    threads: usize,
    scratch: BatchScratch,
}

impl PackedResidualBackend {
    /// `threads` is the row-parallelism *inside* one batch execution
    /// (1 = serial kernels; > 1 = row ranges on the shared
    /// [`SignPool::global`]); worker-level parallelism is
    /// [`ServerConfig::workers`].
    pub fn new(model: Arc<PackedResidual>, threads: usize) -> Self {
        Self { model, threads, scratch: BatchScratch::default() }
    }
}

impl BatchBackend for PackedResidualBackend {
    fn forward_batch_into(&mut self, x: &Mat, y: &mut Mat) {
        let pool = SignPool::for_threads(self.threads);
        self.model.forward_batch_into(x, y, &mut self.scratch, pool, self.threads);
    }
}

/// The whole-model production backend: a packed layer *chain*
/// ([`PackedStack`] — typically loaded from a `.lb2` artifact) driven
/// through the same fused, allocation-free batched pipeline as
/// [`PackedResidualBackend`]. Every drained batch flows through every
/// layer feature-major with zero per-request dispatch in between; each
/// worker's backend owns one [`BatchScratch`] whose ping/pong blocks carry
/// the chain activations.
pub struct PackedStackBackend {
    model: Arc<PackedStack>,
    threads: usize,
    scratch: BatchScratch,
}

impl PackedStackBackend {
    /// `threads` is the row-parallelism inside one batch execution (1 =
    /// serial kernels); worker-level parallelism is
    /// [`ServerConfig::workers`].
    pub fn new(model: Arc<PackedStack>, threads: usize) -> Self {
        Self { model, threads, scratch: BatchScratch::default() }
    }
}

impl BatchBackend for PackedStackBackend {
    fn forward_batch_into(&mut self, x: &Mat, y: &mut Mat) {
        let pool = SignPool::for_threads(self.threads);
        self.model.forward_batch_into(x, y, &mut self.scratch, pool, self.threads);
    }
}

/// The method-generic production backend: a [`MethodStack`] chain —
/// typically loaded from a `.lb2` v2 artifact, possibly mixing methods
/// per layer — driven through the uniform batched pipeline. Serving
/// dispatches on each layer's serving form (packed tri-scale, one-level
/// sign, dense, low-rank) with the same feature-major zero-dispatch
/// contract as [`PackedStackBackend`]; this is what `serve --model`
/// runs, so every Table 1 baseline is servable, not just LittleBit-2.
pub struct MethodStackBackend {
    model: Arc<MethodStack>,
    threads: usize,
    scratch: BatchScratch,
}

impl MethodStackBackend {
    /// `threads` is the row-parallelism inside one batch execution (1 =
    /// serial kernels); worker-level parallelism is
    /// [`ServerConfig::workers`].
    pub fn new(model: Arc<MethodStack>, threads: usize) -> Self {
        Self { model, threads, scratch: BatchScratch::default() }
    }
}

impl BatchBackend for MethodStackBackend {
    fn forward_batch_into(&mut self, x: &Mat, y: &mut Mat) {
        let pool = SignPool::for_threads(self.threads);
        self.model.forward_batch_into(x, y, &mut self.scratch, pool, self.threads);
    }
}

/// Serving pool configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Largest batch one worker drains per execution.
    pub max_batch: usize,
    /// How long a worker waits for stragglers after the first request.
    pub max_wait: Duration,
    /// Bound of the ingress queue (backpressure on `submit`).
    pub queue_depth: usize,
    /// Worker threads draining the queue; each owns one backend instance.
    pub workers: usize,
    /// When the server self-reports [`HealthState::Degraded`].
    pub health: HealthPolicy,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            max_batch: 16,
            max_wait: Duration::from_millis(2),
            queue_depth: 1024,
            workers: 1,
            health: HealthPolicy::default(),
        }
    }
}

/// Upper bounds of the batch-fill histogram buckets; the implicit last
/// bucket is +Inf. Power-of-two spacing: batching pays off in doublings.
pub const FILL_BUCKETS: [usize; 7] = [1, 2, 4, 8, 16, 32, 64];

/// Number of batch-fill buckets ([`FILL_BUCKETS`] plus the +Inf bucket).
pub const FILL_BUCKET_COUNT: usize = FILL_BUCKETS.len() + 1;

/// Histogram bucket index for a batch of `bsize` requests: bucket `i`
/// covers `(FILL_BUCKETS[i-1], FILL_BUCKETS[i]]`, the last bucket is
/// everything above 64.
fn fill_bucket(bsize: usize) -> usize {
    (usize::BITS - bsize.saturating_sub(1).leading_zeros())
        .min(FILL_BUCKET_COUNT as u32 - 1) as usize
}

/// Aggregate serving statistics.
#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    pub served: u64,
    pub batches: u64,
    pub mean_batch: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// Aggregate throughput since the server started (requests ≡ tokens).
    pub tokens_per_s: f64,
    /// Mean of per-batch execution throughput: batch size over backend
    /// execution time, i.e. the kernel-level rate batching buys.
    pub mean_batch_tokens_per_s: f64,
    /// Requests whose batch execution panicked or returned the wrong shape
    /// (their reply channels are dropped; clients observe a recv error).
    pub failed: u64,
    /// Requests rejected at admission (bounded queue full → BUSY).
    pub rejected: u64,
    /// Requests shed at admission because their deadline was already
    /// unmeetable (also BUSY on the wire, with a retry-after hint).
    pub shed: u64,
    /// Requests dropped at drain time because their deadline had passed.
    pub deadline_missed: u64,
    /// Requests admitted to the ingress queue. The reconciliation
    /// invariant the chaos soak pins:
    /// `accepted == served + failed + deadline_missed` once drained.
    pub accepted: u64,
    /// Requests currently waiting in the ingress queue (gauge).
    pub queue_depth: usize,
    /// Current health (gauge; see [`HealthState`]).
    pub health: HealthState,
    /// Live TCP connection-handler threads (gauge; populated by the TCP
    /// front-end, 0 on the in-process path).
    pub conn_threads: usize,
    /// Model weight bytes held on this process's heap (gauge; populated
    /// by the CLI/front-end from the loaded stack — disjoint from
    /// [`model_mapped_bytes`](Self::model_mapped_bytes), so the pair sums
    /// to the serving footprint without double-counting).
    pub model_resident_bytes: u64,
    /// Model weight bytes served from the page cache through a live
    /// `.lb2` mapping (gauge; 0 for eager loads).
    pub model_mapped_bytes: u64,
    /// Batch-fill histogram (non-cumulative counts per [`fill_bucket`]
    /// bucket: ≤1, ≤2, ≤4, … ≤64, +Inf).
    pub batch_fill: [u64; FILL_BUCKET_COUNT],
}

impl ServerStats {
    /// Plain-text metrics dump (Prometheus-style exposition format) — the
    /// payload of the wire protocol's STATS frame, also printed by the
    /// CLI after a `serve --listen` run.
    pub fn render_metrics(&self) -> String {
        use std::fmt::Write;
        let mut s = String::new();
        let _ = writeln!(s, "lb2_requests_accepted_total {}", self.accepted);
        let _ = writeln!(s, "lb2_requests_served_total {}", self.served);
        let _ = writeln!(s, "lb2_requests_failed_total {}", self.failed);
        let _ = writeln!(s, "lb2_requests_rejected_total {}", self.rejected);
        let _ = writeln!(s, "lb2_requests_shed_total {}", self.shed);
        let _ = writeln!(s, "lb2_requests_deadline_missed_total {}", self.deadline_missed);
        let _ = writeln!(s, "# lb2_health: 0=healthy 1=degraded 2=draining");
        let _ = writeln!(s, "lb2_health {}", self.health.code());
        let _ = writeln!(s, "lb2_conn_threads {}", self.conn_threads);
        let _ = writeln!(s, "lb2_model_resident_bytes {}", self.model_resident_bytes);
        let _ = writeln!(s, "lb2_model_mapped_bytes {}", self.model_mapped_bytes);
        let _ = writeln!(s, "lb2_queue_depth {}", self.queue_depth);
        let _ = writeln!(s, "lb2_batches_total {}", self.batches);
        let _ = writeln!(s, "lb2_batch_mean_size {:.3}", self.mean_batch);
        let mut cum = 0u64;
        for (i, &count) in self.batch_fill.iter().enumerate() {
            cum += count;
            match FILL_BUCKETS.get(i) {
                Some(le) => {
                    let _ = writeln!(s, "lb2_batch_fill_bucket{{le=\"{le}\"}} {cum}");
                }
                None => {
                    let _ = writeln!(s, "lb2_batch_fill_bucket{{le=\"+Inf\"}} {cum}");
                }
            }
        }
        let _ = writeln!(s, "lb2_latency_p50_ms {:.4}", self.p50_ms);
        let _ = writeln!(s, "lb2_latency_p99_ms {:.4}", self.p99_ms);
        let _ = writeln!(s, "lb2_tokens_per_s {:.1}", self.tokens_per_s);
        let _ = writeln!(s, "lb2_batch_tokens_per_s {:.1}", self.mean_batch_tokens_per_s);
        s
    }
}

/// The server: owns the queue and worker pool. `tx` is an Option so
/// shutdown/drop can disconnect the queue *before* joining the workers
/// (joining first would deadlock: idle workers block on `recv`).
pub struct InferenceServer {
    tx: Option<SyncSender<Request>>,
    workers: Vec<JoinHandle<()>>,
    stats: Arc<Mutex<StatsInner>>,
    queue_depth: Arc<AtomicUsize>,
    accepted: Arc<AtomicU64>,
}

/// Cloneable ingress handle — what the TCP front-end's connection threads
/// hold. Submission through a handle never blocks: the bounded queue is
/// the admission-control boundary ([`TrySubmitError::QueueFull`] → BUSY on
/// the wire). Every clone keeps the ingress channel alive, so drop all
/// handles before expecting [`InferenceServer::shutdown`]'s workers to
/// observe disconnection.
#[derive(Clone)]
pub struct SubmitHandle {
    tx: SyncSender<Request>,
    stats: Arc<Mutex<StatsInner>>,
    queue_depth: Arc<AtomicUsize>,
    accepted: Arc<AtomicU64>,
}

impl SubmitHandle {
    /// Non-blocking submit with an optional queue-time deadline and a
    /// completion sink. On success the sink's `complete` fires exactly
    /// once (from a worker thread) with the request's
    /// [`RequestOutcome`]; on `Err` the sink is returned to the caller
    /// unused (inside the dropped request) and nothing fires.
    pub fn try_submit(
        &self,
        id: u64,
        input: Vec<f32>,
        deadline: Option<Instant>,
        sink: Box<dyn ReplySink>,
    ) -> Result<(), TrySubmitError> {
        let now = Instant::now();
        // Load shedding: refuse work whose deadline is already unmeetable
        // — either outright passed, or shorter than the estimated queue
        // wait at current occupancy. Conservative while the batch-time EMA
        // is cold (estimate 0 ⇒ only an already-passed deadline sheds).
        if let Some(d) = deadline {
            let remaining_ms = d.saturating_duration_since(now).as_secs_f64() * 1e3;
            let mut s = self.stats.lock().expect("stats lock");
            let est_ms = s.estimated_wait_ms(self.queue_depth.load(Ordering::SeqCst));
            if d <= now || remaining_ms < est_ms {
                s.shed += 1;
                return Err(TrySubmitError::DeadlineUnmeetable {
                    retry_after_ms: s.retry_after_ms(),
                });
            }
        }
        let req = Request { id, input, reply: ReplyTx::Sink(sink), enqueued: now, deadline };
        // Gauge before send: the worker-side decrement can never observe
        // a count it outruns.
        self.queue_depth.fetch_add(1, Ordering::SeqCst);
        match self.tx.try_send(req) {
            Ok(()) => {
                self.accepted.fetch_add(1, Ordering::SeqCst);
                Ok(())
            }
            Err(e) => {
                self.queue_depth.fetch_sub(1, Ordering::SeqCst);
                match e {
                    TrySendError::Full(_) => {
                        let mut s = self.stats.lock().expect("stats lock");
                        s.rejected += 1;
                        Err(TrySubmitError::QueueFull { retry_after_ms: s.retry_after_ms() })
                    }
                    TrySendError::Disconnected(_) => Err(TrySubmitError::Closed),
                }
            }
        }
    }

    /// Snapshot statistics (same numbers as [`InferenceServer::stats`]).
    pub fn stats(&self) -> ServerStats {
        snapshot(&self.stats, &self.queue_depth, &self.accepted)
    }

    /// Current health, computed from live queue depth and the recent
    /// failure window — the HEALTH frame handler's one call.
    pub fn health(&self) -> HealthState {
        let s = self.stats.lock().expect("stats lock");
        s.health(self.queue_depth.load(Ordering::SeqCst))
    }

    /// Mark the server draining: health reports [`HealthState::Draining`]
    /// from now on. Called by the front-end when shutdown begins.
    pub fn set_draining(&self) {
        self.stats.lock().expect("stats lock").draining = true;
    }
}

/// Completions in the failure-rate window before it is halved — recent
/// history dominates, old incidents age out.
const FAIL_WINDOW: u64 = 512;

struct StatsInner {
    started: Instant,
    served: u64,
    failed: u64,
    rejected: u64,
    shed: u64,
    deadline_missed: u64,
    batches: u64,
    batch_total: u64,
    fill_hist: [u64; FILL_BUCKET_COUNT],
    /// Ring buffer of the most recent `LAT_CAP` request latencies —
    /// bounded memory; percentiles reflect the recent window.
    latencies_ms: Vec<f64>,
    lat_next: usize,
    /// Running (sum, count) of per-batch execution throughput samples
    /// (batch size / exec seconds) — O(1) memory on long-running servers.
    rate_sum: f64,
    rate_count: u64,
    /// EMA of batch execution time — the queue-wait estimator behind
    /// deadline load shedding and BUSY retry-after hints. 0.0 until the
    /// first batch completes (shedding stays conservative while cold).
    ema_batch_ms: f64,
    /// Decayed completion window for the failure-rate health trigger:
    /// (completions, failed-or-expired completions), both halved at
    /// [`FAIL_WINDOW`].
    win_total: u64,
    win_failed: u64,
    /// Set once at shutdown; health reports Draining from then on.
    draining: bool,
    /// Copied from [`ServerConfig`] so health can be computed at snapshot.
    policy: HealthPolicy,
    queue_cap: usize,
    max_batch: usize,
    workers: usize,
}

impl StatsInner {
    fn new(cfg: &ServerConfig) -> Self {
        Self {
            started: Instant::now(),
            served: 0,
            failed: 0,
            rejected: 0,
            shed: 0,
            deadline_missed: 0,
            batches: 0,
            batch_total: 0,
            fill_hist: [0; FILL_BUCKET_COUNT],
            latencies_ms: Vec::new(),
            lat_next: 0,
            rate_sum: 0.0,
            rate_count: 0,
            ema_batch_ms: 0.0,
            win_total: 0,
            win_failed: 0,
            draining: false,
            policy: cfg.health.clone(),
            queue_cap: cfg.queue_depth,
            max_batch: cfg.max_batch,
            workers: cfg.workers,
        }
    }

    fn push_latency(&mut self, ms: f64) {
        if self.latencies_ms.len() < LAT_CAP {
            self.latencies_ms.push(ms);
        } else {
            self.latencies_ms[self.lat_next] = ms;
        }
        self.lat_next = (self.lat_next + 1) % LAT_CAP;
    }

    /// Record `n` completions, `bad` of them failed/expired, into the
    /// decayed failure window.
    fn window_complete(&mut self, n: u64, bad: u64) {
        self.win_total += n;
        self.win_failed += bad;
        if self.win_total >= FAIL_WINDOW {
            self.win_total /= 2;
            self.win_failed /= 2;
        }
    }

    /// Expected milliseconds until a newly admitted request would start
    /// executing, from the batch-time EMA and current queue occupancy.
    /// 0.0 while the EMA is cold — shedding never fires before the server
    /// has executed a single batch.
    fn estimated_wait_ms(&self, depth: usize) -> f64 {
        let lanes = (self.max_batch * self.workers).max(1);
        self.ema_batch_ms * (depth as f64 / lanes as f64 + 1.0)
    }

    /// Retry-after hint: roughly one batch period, clamped to [1, 30000]
    /// ms; a 5ms default while the EMA is cold.
    fn retry_after_ms(&self) -> u32 {
        if self.ema_batch_ms > 0.0 {
            (self.ema_batch_ms.ceil() as u32).clamp(1, 30_000)
        } else {
            5
        }
    }

    fn health(&self, depth: usize) -> HealthState {
        if self.draining {
            return HealthState::Draining;
        }
        let deep = self.queue_cap > 0
            && depth as f64 >= self.policy.degraded_queue_frac * self.queue_cap as f64;
        let failing = self.win_total >= self.policy.min_window.max(1)
            && self.win_failed as f64 / self.win_total as f64 > self.policy.degraded_failure_rate;
        if deep || failing {
            HealthState::Degraded
        } else {
            HealthState::Healthy
        }
    }
}

impl InferenceServer {
    /// Single-worker convenience constructor kept for existing callers:
    /// `backend(batch_inputs) -> batch_outputs` runs a whole batch, one
    /// `Vec` per request. Internally adapted onto the matrix-based
    /// [`BatchBackend`] path.
    pub fn start(
        max_batch: usize,
        max_wait: Duration,
        queue_depth: usize,
        backend: impl FnMut(&[Vec<f32>]) -> Vec<Vec<f32>> + Send + 'static,
    ) -> Self {
        let cfg = ServerConfig { max_batch, max_wait, queue_depth, workers: 1, ..Default::default() };
        // The factory is FnMut but runs exactly once (workers = 1); move the
        // backend out through an Option.
        let mut backend = Some(backend);
        Self::start_pool(cfg, move |_worker| {
            let mut backend = backend.take().expect("legacy adapter is single-worker");
            // Adapter: matrix columns → per-request vecs → closure → matrix.
            move |x: &Mat| -> Mat {
                let items: Vec<Vec<f32>> = (0..x.cols()).map(|t| x.col(t)).collect();
                let outs = backend(&items);
                assert_eq!(outs.len(), x.cols(), "backend returned wrong batch size");
                let d_out = outs.first().map(|o| o.len()).unwrap_or(0);
                let mut y = Mat::zeros(d_out, outs.len());
                for (t, o) in outs.iter().enumerate() {
                    assert_eq!(o.len(), d_out, "ragged backend outputs");
                    for (j, v) in o.iter().enumerate() {
                        *y.at_mut(j, t) = *v;
                    }
                }
                y
            }
        })
    }

    /// Start a multi-worker serving pool. `factory(worker_index)` builds
    /// one [`BatchBackend`] per worker; workers drain the shared queue
    /// independently, so distinct batches execute concurrently.
    pub fn start_pool<B: BatchBackend>(
        cfg: ServerConfig,
        mut factory: impl FnMut(usize) -> B,
    ) -> Self {
        assert!(cfg.workers >= 1, "need at least one worker");
        assert!(cfg.max_batch >= 1, "need max_batch >= 1");
        let (tx, rx) = sync_channel::<Request>(cfg.queue_depth);
        let rx = Arc::new(Mutex::new(rx));
        let stats = Arc::new(Mutex::new(StatsInner::new(&cfg)));
        let queue_depth = Arc::new(AtomicUsize::new(0));
        let accepted = Arc::new(AtomicU64::new(0));
        let mut workers = Vec::with_capacity(cfg.workers);
        for w in 0..cfg.workers {
            let rx = Arc::clone(&rx);
            let stats = Arc::clone(&stats);
            let queue_depth = Arc::clone(&queue_depth);
            let mut backend = factory(w);
            let cfg = cfg.clone();
            workers.push(std::thread::spawn(move || {
                Self::worker_loop(&rx, &cfg, &mut backend, &stats, &queue_depth)
            }));
        }
        Self { tx: Some(tx), workers, stats, queue_depth, accepted }
    }

    fn worker_loop<B: BatchBackend>(
        rx: &Mutex<Receiver<Request>>,
        cfg: &ServerConfig,
        backend: &mut B,
        stats: &Mutex<StatsInner>,
        queue_depth: &AtomicUsize,
    ) {
        // Per-worker output buffer, reused across batches so the backend
        // hot path stays allocation-free (`Mat::resize` keeps capacity).
        let mut ybuf = Mat::default();
        loop {
            // Hold the receiver only while draining one batch, so other
            // workers can start on the next batch while this one executes.
            let batch = {
                let rx = rx.lock().expect("rx lock");
                let first = match rx.recv() {
                    Ok(r) => r,
                    Err(_) => return, // all senders dropped: shut down
                };
                queue_depth.fetch_sub(1, Ordering::SeqCst);
                let deadline = Instant::now() + cfg.max_wait;
                let mut batch = vec![first];
                while batch.len() < cfg.max_batch {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(r) => {
                            queue_depth.fetch_sub(1, Ordering::SeqCst);
                            batch.push(r);
                        }
                        Err(RecvTimeoutError::Timeout) => break,
                        Err(RecvTimeoutError::Disconnected) => break,
                    }
                }
                batch
            };

            // Per-request deadlines are a queue-time contract: anything
            // that expired while waiting is completed as `Expired` here —
            // never executed — so live requests get its batch slot and a
            // stalled client cannot make the whole batch late.
            let now = Instant::now();
            let mut live = Vec::with_capacity(batch.len());
            let mut expired = 0u64;
            for req in batch {
                match req.deadline {
                    Some(d) if d <= now => {
                        expired += 1;
                        req.reply.complete(req.id, RequestOutcome::Expired);
                    }
                    _ => live.push(req),
                }
            }
            if expired > 0 {
                let mut s = stats.lock().expect("stats lock");
                s.deadline_missed += expired;
                s.window_complete(expired, expired);
            }
            let batch = live;

            // Requests of one drained batch may have different input widths
            // (legal since the beginning of this API); execute each maximal
            // run of equal width as ONE feature-major matrix. Uniform
            // traffic — the common case — is exactly one run.
            let mut start = 0;
            while start < batch.len() {
                let d_in = batch[start].input.len();
                let mut end = start + 1;
                while end < batch.len() && batch[end].input.len() == d_in {
                    end += 1;
                }
                let group = &batch[start..end];
                Self::execute_group(group, backend, stats, &mut ybuf);
                start = end;
            }
        }
    }

    /// Run one equal-width group as a single feature-major matrix, writing
    /// into the worker's reused output buffer.
    fn execute_group<B: BatchBackend>(
        group: &[Request],
        backend: &mut B,
        stats: &Mutex<StatsInner>,
        y: &mut Mat,
    ) {
        let bsize = group.len();
        let d_in = group[0].input.len();
        // Column t = request t (feature-major, the kernel-native layout).
        let mut x = Mat::zeros(d_in, bsize);
        for (t, req) in group.iter().enumerate() {
            for (j, v) in req.input.iter().enumerate() {
                *x.at_mut(j, t) = *v;
            }
        }
        // Clear the reused buffer's shape first: a backend that panics
        // BEFORE resizing must leave a shape that fails the check below,
        // never a stale previous batch that happens to have `bsize` columns.
        y.resize(0, 0);
        let t_exec = Instant::now();
        // Panic isolation: a backend that rejects this group's shape (or has
        // a bug) must fail THESE requests, not kill the worker and with it
        // the whole server. Our backends hold no invariants across calls
        // (Arc'd read-only weights + scratch blocks that every call fully
        // rewrites), so continuing after an unwind is sound.
        let result = catch_unwind(AssertUnwindSafe(|| backend.forward_batch_into(&x, y)));
        let exec_s = t_exec.elapsed().as_secs_f64();
        match result {
            Ok(()) if y.cols() == bsize => {}
            Ok(()) => {
                eprintln!(
                    "serving: backend left {} columns for a {bsize}-request group; failing the group",
                    y.cols()
                );
                let mut s = stats.lock().expect("stats lock");
                s.failed += bsize as u64;
                s.window_complete(bsize as u64, bsize as u64);
                drop(s);
                for req in group {
                    // Channel replies drop (clients observe RecvError);
                    // sinks get the precise Failed outcome.
                    req.reply.complete(req.id, RequestOutcome::Failed);
                }
                return;
            }
            Err(_) => {
                eprintln!("serving: backend panicked on a {bsize}x{d_in} group; failing the group");
                let mut s = stats.lock().expect("stats lock");
                s.failed += bsize as u64;
                s.window_complete(bsize as u64, bsize as u64);
                drop(s);
                for req in group {
                    req.reply.complete(req.id, RequestOutcome::Failed);
                }
                return;
            }
        };

        let done = Instant::now();
        {
            let mut s = stats.lock().expect("stats lock");
            s.batches += 1;
            s.batch_total += bsize as u64;
            s.rate_sum += bsize as f64 / exec_s.max(1e-9);
            s.rate_count += 1;
            s.fill_hist[fill_bucket(bsize)] += 1;
            // Batch-time EMA feeding the load-shedding wait estimate.
            let exec_ms = exec_s * 1e3;
            s.ema_batch_ms =
                if s.ema_batch_ms > 0.0 { 0.8 * s.ema_batch_ms + 0.2 * exec_ms } else { exec_ms };
            s.window_complete(bsize as u64, 0);
            for req in group {
                s.served += 1;
                s.push_latency(done.duration_since(req.enqueued).as_secs_f64() * 1e3);
            }
        }
        for (t, req) in group.iter().enumerate() {
            let latency = done.duration_since(req.enqueued);
            req.reply.complete(
                req.id,
                RequestOutcome::Ok(Response {
                    id: req.id,
                    output: y.col(t),
                    latency,
                    batch_size: bsize,
                }),
            );
        }
    }

    /// Submit a request; returns the receiver for its response. If the
    /// backend fails the request's batch (panic or wrong output shape),
    /// the reply channel is dropped and `recv` returns an error — the
    /// server itself keeps running (see [`ServerStats::failed`]).
    pub fn submit(&self, id: u64, input: Vec<f32>) -> Receiver<Response> {
        let (reply, rx) = sync_channel(1);
        let req = Request {
            id,
            input,
            reply: ReplyTx::Channel(reply),
            enqueued: Instant::now(),
            deadline: None,
        };
        self.queue_depth.fetch_add(1, Ordering::SeqCst);
        let sent = self.tx.as_ref().expect("server not shut down").send(req);
        if sent.is_err() {
            self.queue_depth.fetch_sub(1, Ordering::SeqCst);
            panic!("server worker alive");
        }
        self.accepted.fetch_add(1, Ordering::SeqCst);
        rx
    }

    /// Cloneable non-blocking ingress handle for the TCP front-end's
    /// connection threads (see [`SubmitHandle`]).
    pub fn handle(&self) -> SubmitHandle {
        SubmitHandle {
            tx: self.tx.as_ref().expect("server not shut down").clone(),
            stats: Arc::clone(&self.stats),
            queue_depth: Arc::clone(&self.queue_depth),
            accepted: Arc::clone(&self.accepted),
        }
    }

    /// Snapshot statistics.
    pub fn stats(&self) -> ServerStats {
        snapshot(&self.stats, &self.queue_depth, &self.accepted)
    }

    /// Current health (see [`SubmitHandle::health`]).
    pub fn health(&self) -> HealthState {
        let s = self.stats.lock().expect("stats lock");
        s.health(self.queue_depth.load(Ordering::SeqCst))
    }

    /// Mark the server draining (health-only; ingress stays connected so
    /// already-accepted work still drains — actual disconnection happens
    /// in [`shutdown`](Self::shutdown)).
    pub fn begin_drain(&self) {
        self.stats.lock().expect("stats lock").draining = true;
    }

    /// Graceful shutdown: drop the sender, join the workers, then snapshot —
    /// requests still queued at shutdown are drained and served by the
    /// workers before they exit, and the returned stats include them.
    pub fn shutdown(mut self) -> ServerStats {
        self.tx.take(); // disconnect the queue; workers' recv errors out
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.stats()
    }
}

/// Build a [`ServerStats`] snapshot from the shared counters — the one
/// implementation behind [`InferenceServer::stats`] and
/// [`SubmitHandle::stats`].
fn snapshot(
    stats: &Mutex<StatsInner>,
    queue_depth: &AtomicUsize,
    accepted: &AtomicU64,
) -> ServerStats {
    let s = stats.lock().expect("stats lock");
    let mut lat = s.latencies_ms.clone();
    lat.sort_by(|a, b| a.partial_cmp(b).expect("finite"));
    let pct = |p: f64| -> f64 {
        if lat.is_empty() {
            0.0
        } else {
            lat[((lat.len() as f64 - 1.0) * p) as usize]
        }
    };
    let elapsed = s.started.elapsed().as_secs_f64();
    ServerStats {
        served: s.served,
        batches: s.batches,
        mean_batch: if s.batches > 0 { s.batch_total as f64 / s.batches as f64 } else { 0.0 },
        p50_ms: pct(0.5),
        p99_ms: pct(0.99),
        tokens_per_s: if elapsed > 0.0 { s.served as f64 / elapsed } else { 0.0 },
        mean_batch_tokens_per_s: if s.rate_count > 0 {
            s.rate_sum / s.rate_count as f64
        } else {
            0.0
        },
        failed: s.failed,
        rejected: s.rejected,
        shed: s.shed,
        deadline_missed: s.deadline_missed,
        accepted: accepted.load(Ordering::SeqCst),
        queue_depth: queue_depth.load(Ordering::SeqCst),
        health: s.health(queue_depth.load(Ordering::SeqCst)),
        conn_threads: 0,
        model_resident_bytes: 0,
        model_mapped_bytes: 0,
        batch_fill: s.fill_hist,
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        self.tx.take(); // must disconnect BEFORE joining
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn echo_backend(xs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        xs.iter().map(|x| x.iter().map(|v| v * 2.0).collect()).collect()
    }

    #[test]
    fn serves_single_request() {
        let server = InferenceServer::start(4, Duration::from_millis(1), 16, echo_backend);
        let rx = server.submit(1, vec![1.0, 2.0]);
        let resp = rx.recv().unwrap();
        assert_eq!(resp.output, vec![2.0, 4.0]);
        assert_eq!(resp.id, 1);
        let stats = server.shutdown();
        assert_eq!(stats.served, 1);
    }

    #[test]
    fn batches_concurrent_requests() {
        let server = InferenceServer::start(8, Duration::from_millis(150), 64, echo_backend);
        let rxs: Vec<_> = (0..8).map(|i| server.submit(i, vec![i as f32])).collect();
        let mut max_batch = 0;
        for rx in rxs {
            let resp = rx.recv().unwrap();
            max_batch = max_batch.max(resp.batch_size);
        }
        // With a 150ms window the requests should coalesce into few batches
        // even when the submit loop gets descheduled on a loaded runner.
        assert!(max_batch >= 2, "no batching observed (max_batch={max_batch})");
        let stats = server.shutdown();
        assert_eq!(stats.served, 8);
        assert!(stats.mean_batch >= 1.0);
    }

    #[test]
    fn responses_match_requests() {
        let server = InferenceServer::start(4, Duration::from_millis(5), 64, echo_backend);
        let rxs: Vec<_> = (0..20).map(|i| server.submit(i, vec![i as f32])).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.id, i as u64);
            assert_eq!(resp.output, vec![2.0 * i as f32]);
        }
    }

    /// Requests with different input widths may share a drained batch; the
    /// server must serve all of them (as equal-width runs), not die.
    #[test]
    fn ragged_batch_is_served() {
        let server = InferenceServer::start(8, Duration::from_millis(30), 64, echo_backend);
        let rx_a = server.submit(0, vec![1.0; 10]);
        let rx_b = server.submit(1, vec![2.0; 3]);
        let rx_c = server.submit(2, vec![3.0; 10]);
        assert_eq!(rx_a.recv().unwrap().output, vec![2.0; 10]);
        assert_eq!(rx_b.recv().unwrap().output, vec![4.0; 3]);
        assert_eq!(rx_c.recv().unwrap().output, vec![6.0; 10]);
        let stats = server.shutdown();
        assert_eq!(stats.served, 3);
    }

    #[test]
    fn stats_percentiles_populated() {
        let server = InferenceServer::start(2, Duration::from_millis(1), 16, echo_backend);
        for i in 0..10 {
            let _ = server.submit(i, vec![0.0]).recv().unwrap();
        }
        let stats = server.stats();
        assert_eq!(stats.served, 10);
        assert!(stats.p99_ms >= stats.p50_ms);
    }

    /// The acceptance contract: a drained batch with more than one request
    /// reaches the backend as ONE matrix with batch_size > 1 columns, and
    /// the server reports tokens/s.
    #[test]
    fn pool_executes_drained_batch_as_single_matrix() {
        let max_cols = Arc::new(AtomicUsize::new(0));
        let calls = Arc::new(AtomicUsize::new(0));
        let cfg = ServerConfig {
            max_batch: 8,
            // Generous straggler window so a descheduled submit loop on a
            // loaded CI runner cannot split the batch and flake the test.
            max_wait: Duration::from_millis(250),
            queue_depth: 64,
            workers: 2,
            ..Default::default()
        };
        let server = InferenceServer::start_pool(cfg, |_worker| {
            let max_cols = Arc::clone(&max_cols);
            let calls = Arc::clone(&calls);
            move |x: &Mat| -> Mat {
                max_cols.fetch_max(x.cols(), Ordering::SeqCst);
                calls.fetch_add(1, Ordering::SeqCst);
                x.clone()
            }
        });
        let rxs: Vec<_> = (0..8).map(|i| server.submit(i, vec![i as f32])).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let stats = server.shutdown();
        assert_eq!(stats.served, 8);
        assert!(
            max_cols.load(Ordering::SeqCst) > 1,
            "backend never saw a batch > 1 (calls={})",
            calls.load(Ordering::SeqCst)
        );
        assert!(stats.tokens_per_s > 0.0, "tokens/s not populated");
        assert!(stats.mean_batch_tokens_per_s > 0.0);
    }

    /// Multiple workers all make progress on a shared queue.
    #[test]
    fn multi_worker_pool_serves_everything() {
        let cfg = ServerConfig {
            max_batch: 2,
            max_wait: Duration::from_millis(1),
            queue_depth: 64,
            workers: 4,
            ..Default::default()
        };
        let server = InferenceServer::start_pool(cfg, |_worker| {
            |x: &Mat| -> Mat { x.clone() }
        });
        let rxs: Vec<_> = (0..32).map(|i| server.submit(i, vec![i as f32; 3])).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.output, vec![i as f32; 3]);
        }
        let stats = server.shutdown();
        assert_eq!(stats.served, 32);
        assert!(stats.batches >= 1);
    }

    /// A request whose width the packed backend rejects must fail only that
    /// request (recv error + failed counter), never kill the worker: the
    /// server keeps serving correct-width requests afterwards.
    #[test]
    fn wrong_width_request_fails_without_killing_the_server() {
        use crate::littlebit::{compress, CompressionConfig};
        use crate::rng::Pcg64;
        use crate::spectral::{synth_weight, SynthSpec};

        let mut rng = Pcg64::seed(78);
        let spec = SynthSpec { rows: 48, cols: 48, gamma: 0.3, coherence: 0.6, scale: 1.0 };
        let w = synth_weight(&spec, &mut rng);
        let cfg = CompressionConfig { bpp: 1.0, ..Default::default() };
        let model = Arc::new(compress(&w, &cfg, &mut rng).pack());

        let server = InferenceServer::start_pool(
            ServerConfig { workers: 1, max_wait: Duration::from_millis(1), ..Default::default() },
            |_worker| PackedResidualBackend::new(Arc::clone(&model), 1),
        );
        // d_in is 48; submit a 16-wide request — the backend asserts on it.
        let bad = server.submit(0, vec![0.0f32; 16]);
        assert!(bad.recv().is_err(), "wrong-width request must fail, not hang");
        // The worker survived: a correct request is still served.
        let good = server.submit(1, vec![0.0f32; 48]);
        assert_eq!(good.recv().unwrap().output.len(), 48);
        let stats = server.shutdown();
        assert_eq!(stats.failed, 1);
        assert_eq!(stats.served, 1);
    }

    /// A worker's backend reuses its scratch and output buffers across
    /// batches of varying width; results must stay bit-identical to the
    /// fresh-allocation path every time.
    #[test]
    fn packed_backend_buffer_reuse_is_deterministic() {
        use crate::littlebit::{compress, CompressionConfig};
        use crate::rng::Pcg64;
        use crate::spectral::{synth_weight, SynthSpec};

        let mut rng = Pcg64::seed(79);
        let spec = SynthSpec { rows: 56, cols: 56, gamma: 0.3, coherence: 0.6, scale: 1.0 };
        let w = synth_weight(&spec, &mut rng);
        let cfg = CompressionConfig { bpp: 1.0, ..Default::default() };
        let model = Arc::new(compress(&w, &cfg, &mut rng).pack());

        let mut backend = PackedResidualBackend::new(Arc::clone(&model), 2);
        let mut y = Mat::default();
        for b in [3usize, 1, 7, 3] {
            let mut x = Mat::zeros(56, b);
            x.fill_normal(&mut rng);
            backend.forward_batch_into(&x, &mut y);
            assert_eq!(y, model.forward_batch(&x), "b={b}");
        }
    }

    /// The whole-model stack backend (what `serve --model model.lb2`
    /// runs) must stay bit-identical to `PackedStack::forward_batch`
    /// across reused buffers and varying batch widths.
    #[test]
    fn packed_stack_backend_buffer_reuse_is_deterministic() {
        use crate::littlebit::CompressionConfig;
        use crate::rng::Pcg64;
        use crate::spectral::{synth_weight, SynthSpec};

        let mut rng = Pcg64::seed(81);
        let weights: Vec<Mat> = [(64, 48), (48, 64)]
            .iter()
            .map(|&(rows, cols)| {
                let spec = SynthSpec { rows, cols, gamma: 0.3, coherence: 0.6, scale: 1.0 };
                synth_weight(&spec, &mut rng)
            })
            .collect();
        let cfg = CompressionConfig { bpp: 1.0, ..Default::default() };
        let stack = Arc::new(PackedStack::compress_chain(&weights, &cfg, &mut rng));

        let mut backend = PackedStackBackend::new(Arc::clone(&stack), 2);
        let mut y = Mat::default();
        for b in [3usize, 1, 7, 3] {
            let mut x = Mat::zeros(48, b);
            x.fill_normal(&mut rng);
            backend.forward_batch_into(&x, &mut y);
            assert_eq!(y, stack.forward_batch(&x), "b={b}");
        }
    }

    /// The method-generic stack backend (what `serve --model model.lb2`
    /// runs since format v2) must serve a non-LittleBit-2 method
    /// bit-identically to the stack's direct batched forward.
    #[test]
    fn method_stack_backend_serves_baseline_methods_bit_exactly() {
        use crate::model::MethodStack;
        use crate::parallel::Pool;
        use crate::quant::MethodSpec;
        use crate::rng::Pcg64;
        use crate::spectral::{synth_weight, SynthSpec};

        let mut rng = Pcg64::seed(91);
        let spec = SynthSpec { rows: 56, cols: 56, gamma: 0.3, coherence: 0.6, scale: 1.0 };
        let w = synth_weight(&spec, &mut rng);
        let layer = MethodSpec::OneBit { als_iters: 10 }
            .compressor()
            .compress_layer(&w, Pool::serial(), &mut rng)
            .unwrap();
        let stack = Arc::new(MethodStack::uniform("onebit", vec![layer]).unwrap());

        let server = InferenceServer::start_pool(
            ServerConfig { workers: 2, max_wait: Duration::from_millis(1), ..Default::default() },
            |_worker| MethodStackBackend::new(Arc::clone(&stack), 2),
        );
        let mut inputs = Vec::new();
        for _ in 0..8 {
            let mut x = vec![0.0f32; 56];
            rng.fill_normal(&mut x);
            inputs.push(x);
        }
        let rxs: Vec<_> = inputs
            .iter()
            .enumerate()
            .map(|(i, x)| server.submit(i as u64, x.clone()))
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv().unwrap();
            let want = stack.forward(&inputs[i]);
            for (j, (a, b)) in resp.output.iter().zip(&want).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "request {i} output {j}");
            }
        }
        let stats = server.shutdown();
        assert_eq!(stats.served, 8);
        assert_eq!(stats.failed, 0);
    }

    /// The packed backend returns the same numbers the dense reconstruction
    /// produces, through the full pool path.
    #[test]
    fn packed_backend_matches_dense_reconstruction() {
        use crate::littlebit::{compress, CompressionConfig};
        use crate::rng::Pcg64;
        use crate::spectral::{synth_weight, SynthSpec};

        let mut rng = Pcg64::seed(77);
        let spec = SynthSpec { rows: 64, cols: 64, gamma: 0.3, coherence: 0.6, scale: 1.0 };
        let w = synth_weight(&spec, &mut rng);
        let cfg = CompressionConfig { bpp: 1.0, ..Default::default() };
        let c = compress(&w, &cfg, &mut rng);
        let recon = c.reconstruct();
        let model = Arc::new(c.pack());

        let server = InferenceServer::start_pool(
            ServerConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(2),
                queue_depth: 64,
                workers: 2,
                ..Default::default()
            },
            |_worker| PackedResidualBackend::new(Arc::clone(&model), 1),
        );
        let mut inputs = Vec::new();
        for _ in 0..10 {
            let mut x = vec![0.0f32; 64];
            rng.fill_normal(&mut x);
            inputs.push(x);
        }
        let rxs: Vec<_> = inputs
            .iter()
            .enumerate()
            .map(|(i, x)| server.submit(i as u64, x.clone()))
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv().unwrap();
            let want = recon.matvec(&inputs[i]);
            for (a, b) in resp.output.iter().zip(&want) {
                assert!((a - b).abs() < 2e-2, "req {i}: {a} vs {b}");
            }
        }
        server.shutdown();
    }

    /// Test sink: funnels every completion into one channel, like the TCP
    /// front-end's per-connection writer funnel.
    struct CaptureSink {
        tx: std::sync::mpsc::Sender<(u64, RequestOutcome)>,
    }

    impl ReplySink for CaptureSink {
        fn complete(&self, id: u64, outcome: RequestOutcome) {
            let _ = self.tx.send((id, outcome));
        }
    }

    /// Gate backend: signals `started` when a batch reaches it, then blocks
    /// until the test releases `gate` — makes queue occupancy deterministic.
    fn gated_backend(
        started: std::sync::mpsc::Sender<()>,
        gate: std::sync::mpsc::Receiver<()>,
    ) -> impl FnMut(&Mat) -> Mat + Send + 'static {
        move |x: &Mat| -> Mat {
            started.send(()).unwrap();
            gate.recv().unwrap();
            x.clone()
        }
    }

    /// Admission control: with a 1-deep queue and the single worker pinned
    /// inside the backend, the third submit must be rejected as QueueFull —
    /// never block, never queue unboundedly — and the rejection is counted.
    #[test]
    fn try_submit_reports_queue_full() {
        let (started_tx, started_rx) = std::sync::mpsc::channel();
        let (gate_tx, gate_rx) = std::sync::mpsc::channel();
        let cfg = ServerConfig {
            max_batch: 1,
            max_wait: Duration::from_millis(1),
            queue_depth: 1,
            workers: 1,
            ..Default::default()
        };
        let mut backend = Some(gated_backend(started_tx, gate_rx));
        let server = InferenceServer::start_pool(cfg, move |_w| backend.take().unwrap());
        let handle = server.handle();
        let (cap_tx, cap_rx) = std::sync::mpsc::channel();
        let sink = |tx: &std::sync::mpsc::Sender<(u64, RequestOutcome)>| {
            Box::new(CaptureSink { tx: tx.clone() })
        };

        // A occupies the worker; B occupies the only queue slot; C bounces.
        handle.try_submit(1, vec![1.0], None, sink(&cap_tx)).unwrap();
        started_rx.recv().unwrap();
        handle.try_submit(2, vec![2.0], None, sink(&cap_tx)).unwrap();
        assert_eq!(handle.stats().queue_depth, 1, "B should be queued");
        let err = handle.try_submit(3, vec![3.0], None, sink(&cap_tx)).unwrap_err();
        assert!(matches!(err, TrySubmitError::QueueFull { .. }), "{err:?}");

        gate_tx.send(()).unwrap(); // release A
        started_rx.recv().unwrap(); // B reached the backend
        gate_tx.send(()).unwrap(); // release B
        let mut ok_ids: Vec<u64> = (0..2)
            .map(|_| match cap_rx.recv().unwrap() {
                (id, RequestOutcome::Ok(resp)) => {
                    assert_eq!(resp.id, id);
                    id
                }
                (id, other) => panic!("request {id}: unexpected outcome {other:?}"),
            })
            .collect();
        ok_ids.sort_unstable();
        assert_eq!(ok_ids, vec![1, 2], "rejected request must never complete");

        drop(handle); // handles keep ingress alive; drop before shutdown
        let stats = server.shutdown();
        assert_eq!(stats.rejected, 1);
        assert_eq!(stats.served, 2);
        assert_eq!(stats.queue_depth, 0);
    }

    /// A request whose deadline passes while queued is completed as
    /// Expired at drain time; requests sharing its batch are still served.
    #[test]
    fn expired_request_fails_only_itself() {
        let (started_tx, started_rx) = std::sync::mpsc::channel();
        let (gate_tx, gate_rx) = std::sync::mpsc::channel();
        let cfg = ServerConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            queue_depth: 16,
            workers: 1,
            ..Default::default()
        };
        let mut backend = Some(gated_backend(started_tx, gate_rx));
        let server = InferenceServer::start_pool(cfg, move |_w| backend.take().unwrap());
        let handle = server.handle();
        let (cap_tx, cap_rx) = std::sync::mpsc::channel();

        // A pins the worker; B (10ms deadline) and C wait in the queue past
        // B's deadline; the next drain expires B and serves C.
        handle
            .try_submit(1, vec![1.0], None, Box::new(CaptureSink { tx: cap_tx.clone() }))
            .unwrap();
        started_rx.recv().unwrap();
        let deadline = Instant::now() + Duration::from_millis(10);
        handle
            .try_submit(2, vec![2.0], Some(deadline), Box::new(CaptureSink { tx: cap_tx.clone() }))
            .unwrap();
        handle
            .try_submit(3, vec![3.0], None, Box::new(CaptureSink { tx: cap_tx.clone() }))
            .unwrap();
        std::thread::sleep(Duration::from_millis(40));
        gate_tx.send(()).unwrap(); // release A
        started_rx.recv().unwrap(); // C's batch reached the backend
        gate_tx.send(()).unwrap(); // release C

        let mut outcomes = std::collections::HashMap::new();
        for _ in 0..3 {
            let (id, outcome) = cap_rx.recv().unwrap();
            outcomes.insert(id, outcome);
        }
        assert!(matches!(outcomes[&1], RequestOutcome::Ok(_)), "A served");
        assert!(matches!(outcomes[&2], RequestOutcome::Expired), "B expired");
        assert!(matches!(outcomes[&3], RequestOutcome::Ok(_)), "C served");

        drop(handle);
        let stats = server.shutdown();
        assert_eq!(stats.deadline_missed, 1);
        assert_eq!(stats.served, 2);
        assert_eq!(stats.failed, 0);
    }

    /// Bucket layout contract: bucket i covers (FILL_BUCKETS[i-1],
    /// FILL_BUCKETS[i]], last bucket is +Inf.
    #[test]
    fn fill_bucket_boundaries() {
        assert_eq!(fill_bucket(1), 0);
        assert_eq!(fill_bucket(2), 1);
        assert_eq!(fill_bucket(3), 2);
        assert_eq!(fill_bucket(4), 2);
        assert_eq!(fill_bucket(5), 3);
        assert_eq!(fill_bucket(8), 3);
        assert_eq!(fill_bucket(64), 6);
        assert_eq!(fill_bucket(65), 7);
        assert_eq!(fill_bucket(10_000), 7);
    }

    /// The metrics exposition carries every counter the ops story needs,
    /// with the histogram rendered cumulatively.
    #[test]
    fn render_metrics_exposes_counters() {
        let mut stats = ServerStats {
            served: 12,
            failed: 1,
            rejected: 2,
            shed: 6,
            deadline_missed: 3,
            accepted: 16,
            queue_depth: 4,
            batches: 5,
            health: HealthState::Degraded,
            conn_threads: 7,
            ..Default::default()
        };
        stats.batch_fill[0] = 3; // three 1-request batches
        stats.batch_fill[2] = 2; // two batches of 3..=4
        let text = stats.render_metrics();
        assert!(text.contains("lb2_requests_accepted_total 16"), "{text}");
        assert!(text.contains("lb2_requests_served_total 12"), "{text}");
        assert!(text.contains("lb2_requests_failed_total 1"), "{text}");
        assert!(text.contains("lb2_requests_rejected_total 2"), "{text}");
        assert!(text.contains("lb2_requests_shed_total 6"), "{text}");
        assert!(text.contains("lb2_requests_deadline_missed_total 3"), "{text}");
        assert!(text.contains("lb2_health 1"), "{text}");
        assert!(text.contains("lb2_conn_threads 7"), "{text}");
        assert!(text.contains("lb2_queue_depth 4"), "{text}");
        assert!(text.contains("lb2_batches_total 5"), "{text}");
        assert!(text.contains("lb2_batch_fill_bucket{le=\"1\"} 3"), "{text}");
        assert!(text.contains("lb2_batch_fill_bucket{le=\"4\"} 5"), "{text}");
        assert!(text.contains("lb2_batch_fill_bucket{le=\"+Inf\"} 5"), "{text}");
    }

    /// Health state machine: a fresh server is Healthy; a burst of
    /// backend failures past the window threshold flips it to Degraded;
    /// successes age the window back out; `begin_drain` pins Draining.
    #[test]
    fn health_degrades_on_failure_rate_and_drains_on_shutdown() {
        let bad = Arc::new(std::sync::atomic::AtomicBool::new(true));
        let cfg = ServerConfig {
            max_batch: 1,
            max_wait: Duration::from_millis(1),
            queue_depth: 64,
            workers: 1,
            health: HealthPolicy {
                degraded_failure_rate: 0.5,
                min_window: 4,
                ..Default::default()
            },
        };
        let bad_flag = Arc::clone(&bad);
        let server = InferenceServer::start_pool(cfg, move |_w| {
            let bad = Arc::clone(&bad_flag);
            move |x: &Mat| -> Mat {
                if bad.load(Ordering::SeqCst) {
                    panic!("injected");
                }
                x.clone()
            }
        });
        assert_eq!(server.health(), HealthState::Healthy);

        // 8 failures: window (8, 8) → rate 1.0 > 0.5 with ≥ 4 samples.
        for i in 0..8 {
            let _ = server.submit(i, vec![1.0]).recv();
        }
        assert_eq!(server.health(), HealthState::Degraded);

        // A long run of successes dilutes the window below the threshold.
        bad.store(false, Ordering::SeqCst);
        for i in 8..32 {
            server.submit(i, vec![1.0]).recv().unwrap();
        }
        assert_eq!(server.health(), HealthState::Healthy);

        server.begin_drain();
        assert_eq!(server.health(), HealthState::Draining);
        let stats = server.shutdown();
        assert_eq!(stats.accepted, 32);
        assert_eq!(stats.accepted, stats.served + stats.failed + stats.deadline_missed);
    }

    /// Queue-occupancy health trigger: pin the worker and stack requests
    /// past the configured fraction of queue_depth.
    #[test]
    fn health_degrades_on_queue_depth() {
        let (started_tx, started_rx) = std::sync::mpsc::channel();
        let (gate_tx, gate_rx) = std::sync::mpsc::channel();
        let cfg = ServerConfig {
            max_batch: 1,
            max_wait: Duration::from_millis(1),
            queue_depth: 4,
            workers: 1,
            health: HealthPolicy { degraded_queue_frac: 0.5, ..Default::default() },
        };
        let mut backend = Some(gated_backend(started_tx, gate_rx));
        let server = InferenceServer::start_pool(cfg, move |_w| backend.take().unwrap());
        let handle = server.handle();
        let (cap_tx, cap_rx) = std::sync::mpsc::channel();

        // One pins the worker, two occupy half the 4-deep queue.
        for id in 0..3 {
            handle
                .try_submit(id, vec![1.0], None, Box::new(CaptureSink { tx: cap_tx.clone() }))
                .unwrap();
        }
        started_rx.recv().unwrap();
        assert_eq!(handle.health(), HealthState::Degraded, "queue half full");

        gate_tx.send(()).unwrap(); // release request 0
        started_rx.recv().unwrap(); // request 1 reached the backend
        gate_tx.send(()).unwrap(); // release request 1
        started_rx.recv().unwrap(); // request 2 reached the backend
        gate_tx.send(()).unwrap(); // release request 2
        for _ in 0..3 {
            cap_rx.recv().unwrap();
        }
        assert_eq!(handle.health(), HealthState::Healthy, "queue drained");
        drop(handle);
        server.shutdown();
    }

    /// Load shedding: a deadline that has already passed is refused at
    /// admission as DeadlineUnmeetable (never queued, counted as shed),
    /// and once the batch-time EMA is warm, a deadline shorter than the
    /// estimated queue wait is refused too — with a retry-after hint.
    #[test]
    fn unmeetable_deadlines_are_shed_at_admission() {
        let cfg = ServerConfig {
            max_batch: 1,
            max_wait: Duration::from_millis(1),
            queue_depth: 16,
            workers: 1,
            ..Default::default()
        };
        // Slow backend: ~40ms per batch, so the EMA warms to ~40ms.
        let server = InferenceServer::start_pool(cfg, |_w| {
            |x: &Mat| -> Mat {
                std::thread::sleep(Duration::from_millis(40));
                x.clone()
            }
        });
        let handle = server.handle();
        let (cap_tx, cap_rx) = std::sync::mpsc::channel();

        // Already-passed deadline: shed even with a cold EMA.
        let past = Instant::now() - Duration::from_millis(1);
        let err = handle
            .try_submit(0, vec![1.0], Some(past), Box::new(CaptureSink { tx: cap_tx.clone() }))
            .unwrap_err();
        assert!(matches!(err, TrySubmitError::DeadlineUnmeetable { .. }), "{err:?}");
        assert!(err.retry_after_ms().unwrap() >= 1);

        // Warm the EMA with one served request...
        handle
            .try_submit(1, vec![1.0], None, Box::new(CaptureSink { tx: cap_tx.clone() }))
            .unwrap();
        match cap_rx.recv().unwrap() {
            (1, RequestOutcome::Ok(_)) => {}
            other => panic!("unexpected {other:?}"),
        }
        // ...then a 2ms deadline against a ~40ms estimated wait is shed.
        let tight = Instant::now() + Duration::from_millis(2);
        let err = handle
            .try_submit(2, vec![1.0], Some(tight), Box::new(CaptureSink { tx: cap_tx.clone() }))
            .unwrap_err();
        assert!(matches!(err, TrySubmitError::DeadlineUnmeetable { .. }), "{err:?}");
        // The hint tracks the EMA: roughly one batch period.
        assert!(err.retry_after_ms().unwrap() >= 10, "{err:?}");

        // A generous deadline is still admitted and served.
        let ok = Instant::now() + Duration::from_secs(10);
        handle
            .try_submit(3, vec![1.0], Some(ok), Box::new(CaptureSink { tx: cap_tx.clone() }))
            .unwrap();
        match cap_rx.recv().unwrap() {
            (3, RequestOutcome::Ok(_)) => {}
            other => panic!("unexpected {other:?}"),
        }

        drop(handle);
        let stats = server.shutdown();
        assert_eq!(stats.shed, 2);
        assert_eq!(stats.accepted, 2);
        assert_eq!(stats.served, 2);
        assert_eq!(stats.accepted, stats.served + stats.failed + stats.deadline_missed);
    }
}
