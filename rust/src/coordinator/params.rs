//! Parameter storage and the rust-native student initialization.
//!
//! Parameters are positional `Vec<Vec<f32>>` matching the manifest spec
//! order — the contract with the AOT artifacts. Teacher initials are read
//! from the `.bin` blobs `aot.py` wrote; students are initialized by
//! compressing the (trained) teacher's body weights with the selected
//! strategy — SVD, optional rotation/Joint-ITQ, Dual-SVID — all in rust.

use crate::linalg::Mat;
use crate::littlebit::{compress_single, CompressionConfig, InitStrategy};
use crate::rng::Pcg64;
#[cfg(feature = "xla")]
use crate::runtime::lit;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// A positional parameter set bound to its spec.
#[derive(Clone)]
pub struct ParamStore {
    pub spec: Vec<(String, Vec<usize>)>,
    pub values: Vec<Vec<f32>>,
}

impl ParamStore {
    /// All-zero store with the given spec (Adam moment buffers).
    pub fn zeros(spec: &[(String, Vec<usize>)]) -> Self {
        let values = spec
            .iter()
            .map(|(_, shape)| vec![0.0f32; shape.iter().product()])
            .collect();
        Self { spec: spec.to_vec(), values }
    }

    /// Load teacher initials from `<dir>/<name with . → _>.bin` (little-
    /// endian f32, row-major), as written by aot.py.
    pub fn load_bins(spec: &[(String, Vec<usize>)], dir: impl AsRef<Path>) -> Result<Self> {
        let mut values = Vec::with_capacity(spec.len());
        for (name, shape) in spec {
            let file = dir.as_ref().join(format!("{}.bin", name.replace('.', "_")));
            let bytes = std::fs::read(&file).with_context(|| format!("reading {file:?}"))?;
            let want: usize = shape.iter().product();
            if bytes.len() != want * 4 {
                bail!("{file:?}: {} bytes, expected {}", bytes.len(), want * 4);
            }
            let mut v = Vec::with_capacity(want);
            for chunk in bytes.chunks_exact(4) {
                v.push(f32::from_le_bytes(chunk.try_into().expect("chunk of 4")));
            }
            values.push(v);
        }
        Ok(Self { spec: spec.to_vec(), values })
    }

    /// Convert every tensor to a literal, in spec order.
    #[cfg(feature = "xla")]
    pub fn to_literals(&self) -> Result<Vec<xla::Literal>> {
        self.spec
            .iter()
            .zip(&self.values)
            .map(|((_, shape), data)| lit::array_f32(data, shape))
            .collect()
    }

    /// Replace values from a slice of literals (artifact outputs).
    #[cfg(feature = "xla")]
    pub fn update_from_literals(&mut self, lits: &[xla::Literal]) -> Result<()> {
        anyhow::ensure!(lits.len() == self.values.len(), "literal count mismatch");
        for (v, l) in self.values.iter_mut().zip(lits) {
            *v = lit::to_vec_f32(l)?;
        }
        Ok(())
    }

    /// Look up a tensor by name.
    pub fn get(&self, name: &str) -> Option<(&[usize], &[f32])> {
        self.spec
            .iter()
            .position(|(n, _)| n == name)
            .map(|i| (self.spec[i].1.as_slice(), self.values[i].as_slice()))
    }

    /// Total parameter count.
    pub fn num_params(&self) -> usize {
        self.values.iter().map(|v| v.len()).sum()
    }
}

/// Initialize a student store by compressing the teacher's body weights.
///
/// `student_spec` comes from the manifest; per-layer ranks are read off the
/// `lat_u` shapes so rust and the lowered HLO can never disagree.
/// `strategy` selects the Table 3 ablation arm. The FP (tiny-rank) student
/// uses plain truncated-SVD factors with unit scales.
pub fn init_student(
    teacher: &ParamStore,
    student_spec: &[(String, Vec<usize>)],
    strategy: InitStrategy,
    fp_latent: bool,
    seed: u64,
) -> Result<ParamStore> {
    let mut rng = Pcg64::seed(seed);
    // Group the tri-scale entries per layer: "b0.q.p0.lat_u" → layer "b0.q".
    let mut values: Vec<Option<Vec<f32>>> = vec![None; student_spec.len()];
    let mut layer_fields: HashMap<String, Vec<(usize, usize, String)>> = HashMap::new();
    for (i, (name, _)) in student_spec.iter().enumerate() {
        if let Some(pos) = name.find(".p") {
            let rest = &name[pos + 2..];
            if let Some(dot) = rest.find('.') {
                if let Ok(pidx) = rest[..dot].parse::<usize>() {
                    let layer = name[..pos].to_string();
                    let field = rest[dot + 1..].to_string();
                    layer_fields.entry(layer).or_default().push((i, pidx, field));
                    continue;
                }
            }
        }
        // FP passthrough tensors: copy from the teacher.
        let (_, data) = teacher
            .get(name)
            .with_context(|| format!("teacher missing {name}"))?;
        values[i] = Some(data.to_vec());
    }

    let mut layers: Vec<(String, Vec<(usize, usize, String)>)> =
        layer_fields.into_iter().collect();
    layers.sort();
    for (layer, fields) in layers {
        let (shape, data) = teacher
            .get(&layer)
            .with_context(|| format!("teacher missing layer {layer}"))?;
        let w = Mat::from_vec(shape[0], shape[1], data.to_vec());
        // Rank from the lat_u spec of path 0.
        let rank = fields
            .iter()
            .find(|(_, p, f)| *p == 0 && f == "lat_u")
            .map(|(i, _, _)| student_spec[*i].1[1])
            .context("lat_u missing from spec")?;
        let n_paths = 1 + fields.iter().map(|(_, p, _)| *p).max().unwrap_or(0);

        let cfg = CompressionConfig {
            bpp: 0.0, // rank supplied explicitly below
            strategy,
            residual: n_paths > 1,
            ..Default::default()
        };

        // Residual loop at fixed rank (matches python compress_layer_init).
        let mut target = w.clone();
        let mut paths = Vec::new();
        for _ in 0..n_paths {
            if fp_latent {
                let svd = crate::linalg::svd_randomized(&target, rank, 10.min(rank + 4), 2, &mut rng);
                let (u, v) = svd.split_factors();
                let recon = u.matmul_t(&v);
                target = target.sub(&recon);
                paths.push((
                    u.to_vec(),
                    v.to_vec(),
                    vec![1.0f32; shape[0]],
                    vec![1.0f32; rank],
                    vec![1.0f32; shape[1]],
                ));
            } else {
                let c = compress_single(&target, rank, &cfg, &mut rng);
                let recon = c.reconstruct();
                target = target.sub(&recon);
                let f = &c.factors;
                paths.push((
                    f.latent_u.to_vec(),
                    f.latent_v.to_vec(),
                    f.h.clone(),
                    f.l.clone(),
                    f.g.clone(),
                ));
            }
        }

        for (i, pidx, field) in fields {
            let (lat_u, lat_v, h, l, g) = &paths[pidx];
            values[i] = Some(match field.as_str() {
                "lat_u" => lat_u.clone(),
                "lat_v" => lat_v.clone(),
                "h" => h.clone(),
                "l" => l.clone(),
                "g" => g.clone(),
                other => bail!("unknown tri-scale field {other}"),
            });
        }
    }

    let values: Vec<Vec<f32>> = values
        .into_iter()
        .enumerate()
        .map(|(i, v)| v.with_context(|| format!("uninitialized param {i}")))
        .collect::<Result<_>>()?;
    // Shape check.
    for ((name, shape), v) in student_spec.iter().zip(&values) {
        let want: usize = shape.iter().product();
        anyhow::ensure!(v.len() == want, "{name}: {} != {}", v.len(), want);
    }
    Ok(ParamStore { spec: student_spec.to_vec(), values })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_specs() -> (Vec<(String, Vec<usize>)>, Vec<(String, Vec<usize>)>) {
        let teacher = vec![
            ("embed".to_string(), vec![16, 8]),
            ("b0.q".to_string(), vec![8, 8]),
            ("head".to_string(), vec![16, 8]),
        ];
        let mut student = vec![("embed".to_string(), vec![16, 8])];
        for p in 0..2 {
            student.push((format!("b0.q.p{p}.lat_u"), vec![8, 2]));
            student.push((format!("b0.q.p{p}.lat_v"), vec![8, 2]));
            student.push((format!("b0.q.p{p}.h"), vec![8]));
            student.push((format!("b0.q.p{p}.l"), vec![2]));
            student.push((format!("b0.q.p{p}.g"), vec![8]));
        }
        student.push(("head".to_string(), vec![16, 8]));
        (teacher, student)
    }

    fn fake_teacher(spec: &[(String, Vec<usize>)]) -> ParamStore {
        let mut rng = Pcg64::seed(1);
        let values = spec
            .iter()
            .map(|(_, shape)| {
                let mut v = vec![0.0f32; shape.iter().product()];
                rng.fill_normal(&mut v);
                v
            })
            .collect();
        ParamStore { spec: spec.to_vec(), values }
    }

    #[test]
    fn student_init_shapes_and_passthrough() {
        let (t_spec, s_spec) = tiny_specs();
        let teacher = fake_teacher(&t_spec);
        let student = init_student(
            &teacher,
            &s_spec,
            InitStrategy::JointItq { iters: 10 },
            false,
            7,
        )
        .unwrap();
        assert_eq!(student.values.len(), s_spec.len());
        // FP tensors copied verbatim.
        assert_eq!(student.get("embed").unwrap().1, teacher.get("embed").unwrap().1);
        assert_eq!(student.get("head").unwrap().1, teacher.get("head").unwrap().1);
    }

    #[test]
    fn student_init_approximates_teacher_layer() {
        let (t_spec, s_spec) = tiny_specs();
        let teacher = fake_teacher(&t_spec);
        let student =
            init_student(&teacher, &s_spec, InitStrategy::Standard, false, 7).unwrap();
        // Reconstruct b0.q from the two tri-scale paths and compare.
        let (shape, data) = teacher.get("b0.q").unwrap();
        let w = Mat::from_vec(shape[0], shape[1], data.to_vec());
        let mut recon = Mat::zeros(8, 8);
        for p in 0..2 {
            let lu = student.get(&format!("b0.q.p{p}.lat_u")).unwrap().1;
            let lv = student.get(&format!("b0.q.p{p}.lat_v")).unwrap().1;
            let h = student.get(&format!("b0.q.p{p}.h")).unwrap().1;
            let l = student.get(&format!("b0.q.p{p}.l")).unwrap().1;
            let g = student.get(&format!("b0.q.p{p}.g")).unwrap().1;
            let ub = Mat::from_vec(8, 2, lu.to_vec()).signum();
            let vb = Mat::from_vec(8, 2, lv.to_vec()).signum();
            recon = recon.add(
                &ub.scale_rows(h).scale_cols(l).matmul_t(&vb.scale_rows(g)),
            );
        }
        // Rank-2x2 binary approx of an 8x8 gaussian: should capture some
        // energy (MSE below the zero-approximation baseline).
        let zero = Mat::zeros(8, 8);
        assert!(recon.mse(&w) < zero.mse(&w));
    }

    #[test]
    fn fp_student_uses_unit_scales() {
        let (t_spec, s_spec) = tiny_specs();
        let teacher = fake_teacher(&t_spec);
        let student =
            init_student(&teacher, &s_spec, InitStrategy::Standard, true, 7).unwrap();
        let h = student.get("b0.q.p0.h").unwrap().1;
        assert!(h.iter().all(|&x| x == 1.0));
    }

    #[test]
    fn zeros_store() {
        let (_, s_spec) = tiny_specs();
        let z = ParamStore::zeros(&s_spec);
        assert_eq!(z.num_params(), s_spec.iter().map(|(_, s)| s.iter().product::<usize>()).sum());
        assert!(z.values.iter().all(|v| v.iter().all(|&x| x == 0.0)));
    }
}
