//! Layer-compression scheduling on the shared worker pool.
//!
//! Compressing a model is embarrassingly parallel across layers. This
//! scheduler fans a job list out as claim-loops on the process-wide
//! [`Pool`] (no per-call OS-thread spawns — the PR 2 serving pool and the
//! offline pipeline share one resident worker set) and hands finished
//! layers to a caller-supplied sink **in job order** while later layers
//! are still compressing — the streaming half of `compress --jobs N`,
//! where the sink appends straight into the `.lb2`
//! [`StackStreamWriter`](crate::artifact::StackStreamWriter).
//!
//! # Determinism
//!
//! Each job owns an independent RNG stream (its `seed`; derive per-layer
//! seeds with [`crate::rng::derive_seed`], never by advancing one shared
//! generator across the layer loop) and every pooled kernel is bit-exact,
//! so a layer's bytes never depend on worker count or claim order. Commits
//! are reordered to strict job order before reaching the sink, so the
//! artifact byte stream is identical for any `workers`.
//!
//! # Inner parallelism
//!
//! With `workers == 1` the single claim-loop runs on the caller and each
//! layer's linalg fans out across [`Pool::global`] (the d≈4096 single-layer
//! case). With `workers > 1` layer-parallelism owns the cores: claim-loops
//! run *on* pool workers, where nested dispatch inlines (see `parallel`),
//! so per-layer linalg is serial by construction — the right trade at
//! model scale, with no deadlock risk either way.
//!
//! Because claim-loops occupy the shared global workers until the job
//! queue drains, compressing and *serving* from the same process at the
//! same time makes serving's row-range jobs queue behind compression —
//! whole-model latency, not microseconds. That mirrors the deployment
//! contract (quantize once, then serve; no binary in this repo does
//! both concurrently); a process that genuinely needs both should give
//! the server its own `SignPool::new(..)` instead of the global one.
//!
//! # Failure semantics
//!
//! A panicking layer no longer tears down the batch blindly: every other
//! in-flight layer completes, layers *before* the panic still reach the
//! sink in order, and then the original panic payload is re-raised on the
//! caller (the old implementation lost all completed results to a
//! `join().expect` and leaked the panic message). A sink error cancels
//! the remaining queue, drains in-flight work, and returns the error.

use crate::linalg::Mat;
use crate::littlebit::{compress_pipeline, CompressionConfig, CompressionReport};
use crate::model::MethodLayer;
use crate::parallel::{Pool, ScopedJob};
use crate::quant::MethodSpec;
use crate::rng::Pcg64;
use crate::spectral::{synth_weight, SynthSpec};
use anyhow::Context;
use std::any::Any;
use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::Mutex;
use std::time::Instant;

/// Where a job's weight matrix comes from. `Synth` keeps the dense matrix
/// out of the job list entirely (it is fabricated inside the worker and
/// dropped with the job), so a long synthetic chain streams at bounded
/// memory; real pipelines hand in `Dense` weights they already hold.
#[derive(Clone, Debug)]
pub enum JobInput {
    /// An explicit dense weight matrix.
    Dense(Mat),
    /// Fabricate `synth_weight(&spec, seed)` inside the job.
    Synth { spec: SynthSpec, seed: u64 },
}

impl JobInput {
    /// `(d_out, d_in)` of the weight this input will produce.
    pub fn shape(&self) -> (usize, usize) {
        match self {
            JobInput::Dense(w) => w.shape(),
            JobInput::Synth { spec, .. } => (spec.rows, spec.cols),
        }
    }
}

/// One unit of work: compress the input weight under `method`.
#[derive(Clone, Debug)]
pub struct CompressionJob {
    /// Stable identifier (e.g. "b12.q_proj").
    pub name: String,
    pub input: JobInput,
    /// Which quantizer runs (LittleBit-2 or any Table 1 baseline) and its
    /// knobs — see [`MethodSpec`].
    pub method: MethodSpec,
    /// Seed of this job's independent RNG stream
    /// (see [`crate::rng::derive_seed`]).
    pub seed: u64,
}

impl CompressionJob {
    /// Convenience constructor for an explicit weight matrix compressed
    /// with the LittleBit-2 pipeline (the pre-method-registry call shape).
    pub fn dense(name: impl Into<String>, weight: Mat, cfg: CompressionConfig, seed: u64) -> Self {
        Self {
            name: name.into(),
            input: JobInput::Dense(weight),
            method: MethodSpec::LittleBit2(cfg),
            seed,
        }
    }

    /// `(d_out, d_in)` of the layer this job produces.
    pub fn shape(&self) -> (usize, usize) {
        self.input.shape()
    }

    /// Residual paths the produced layer will carry (fixed by the method;
    /// 0 for non-packed serving forms), so artifact shape tables can be
    /// written before any layer finishes.
    pub fn n_paths(&self) -> usize {
        self.method.n_paths()
    }
}

/// Per-layer metrics.
#[derive(Clone, Debug)]
pub struct JobResult {
    pub name: String,
    /// Method name (`"littlebit2"`, `"onebit"`, …).
    pub method: String,
    pub mse: f64,
    /// Relative Frobenius error `‖W − Ŵ‖²_F / ‖W‖²_F` — the
    /// method-comparable fidelity number the `eval` sweep reports.
    pub rel_err: f64,
    /// Declared bits-per-parameter (App. H accounting).
    pub bpp: f64,
    /// Latent rank where the method has one (packed path 0 / low-rank
    /// factor width); 0 for full-matrix serving forms.
    pub rank: usize,
    /// Mean / max λ over path 0's latent rows (the Fig. 3 diagnostic) —
    /// only the littlebit pipeline exposes FP latents, so baselines
    /// report `None`.
    pub lambda_mean: Option<f64>,
    pub lambda_max: Option<f64>,
    /// End-to-end wall-clock of the job (compression + scoring).
    pub wall_ms: f64,
    /// Per-stage wall-clock of the compression itself (baselines fill
    /// only `total_ms`).
    pub report: CompressionReport,
}

/// Everything the sink receives per layer: metrics plus the serving-form
/// [`MethodLayer`] ready to stream into an artifact. The full-precision
/// factors are dropped inside the job, so in-flight memory is the packed
/// reorder buffer: typically O(workers) layers (layers of one model are
/// near-uniform cost), degrading toward the model tail only if an early
/// layer is pathologically slower than its successors.
pub struct LayerOutcome {
    pub result: JobResult,
    pub layer: MethodLayer,
}

/// Compress one job on `pool` and score it. The LittleBit-2 arm keeps the
/// instrumented `compress_pipeline` fast path (per-stage wall-clock, λ
/// diagnostics from the FP latents); every other method goes through its
/// [`crate::quant::Compressor`]. Both arms are bit-identical to the trait
/// path (asserted by `quant::compressor` tests), so the scheduler's
/// determinism contract is method-independent.
fn run_job(job: CompressionJob, pool: &Pool) -> anyhow::Result<LayerOutcome> {
    let t0 = Instant::now();
    let w = match job.input {
        JobInput::Dense(w) => w,
        JobInput::Synth { spec, seed } => synth_weight(&spec, &mut Pcg64::seed(seed)),
    };
    let mut rng = Pcg64::seed(job.seed);
    let (layer, report, lambda, recon) = match &job.method {
        MethodSpec::LittleBit2(cfg) => {
            let out = compress_pipeline(&w, cfg, &mut rng, pool);
            let recon = out.compressed.reconstruct_on(pool);
            let lams = out.compressed.paths[0].u_distortions();
            let mean = lams.iter().sum::<f64>() / lams.len().max(1) as f64;
            let max = lams.iter().fold(0.0f64, |m, &x| m.max(x));
            (MethodLayer::Packed(out.packed), out.report, Some((mean, max)), recon)
        }
        spec => {
            let t = Instant::now();
            let layer = spec
                .compressor()
                .compress_layer(&w, pool, &mut rng)
                .with_context(|| format!("compressing {:?} with {}", job.name, spec.name()))?;
            let report = CompressionReport {
                total_ms: t.elapsed().as_secs_f64() * 1e3,
                ..Default::default()
            };
            let recon = layer.reconstruct_on(pool);
            (layer, report, None, recon)
        }
    };
    // One pass over the recon-vs-w pairs scores both metrics (mse is
    // dist²/N by definition — same bits as Mat::mse).
    let dist2 = recon.fro_dist2(&w);
    let fro = w.fro_norm().powi(2);
    let rel_err = if fro > 0.0 { dist2 / fro } else { 0.0 };
    Ok(LayerOutcome {
        result: JobResult {
            name: job.name,
            method: job.method.name().to_string(),
            mse: dist2 / (w.rows() * w.cols()) as f64,
            rel_err,
            bpp: layer.bpp(),
            rank: layer.rank(),
            lambda_mean: lambda.map(|(m, _)| m),
            lambda_max: lambda.map(|(_, m)| m),
            wall_ms: t0.elapsed().as_secs_f64() * 1e3,
            report,
        },
        layer,
    })
}

type JobPayload = Box<dyn Any + Send + 'static>;
/// Outer `Err` = the job panicked (payload re-raised on the caller);
/// inner `Err` = the compressor returned an error (surfaced as the run's
/// `Err` after earlier layers committed and in-flight work drained).
type Slot = Result<anyhow::Result<LayerOutcome>, JobPayload>;
type JobQueue = Mutex<std::iter::Enumerate<std::vec::IntoIter<CompressionJob>>>;

/// Run all jobs across `workers` claim-loops on the shared pool, invoking
/// `sink(index, outcome)` **in job order** as layers complete. Returns
/// when every layer has been committed (or on the first sink error, after
/// in-flight work drains). See the module docs for the determinism,
/// panic, and inner-parallelism contracts.
pub fn run_compression_jobs_streaming(
    jobs: Vec<CompressionJob>,
    workers: usize,
    mut sink: impl FnMut(usize, LayerOutcome) -> anyhow::Result<()>,
) -> anyhow::Result<()> {
    let n = jobs.len();
    if n == 0 {
        return Ok(());
    }
    let workers = workers.clamp(1, n);
    let pool = Pool::for_threads(workers);
    // With one claim-loop the caller owns every layer and each layer fans
    // its linalg across the global pool; with several, the loops own the
    // cores and per-layer linalg stays serial (nested dispatch would
    // inline anyway — this just skips the queue round-trip).
    let inner: &Pool = if workers == 1 { Pool::global() } else { Pool::serial() };

    let queue: JobQueue = Mutex::new(jobs.into_iter().enumerate());
    let cancel = AtomicBool::new(false);
    let (tx, rx) = mpsc::channel::<(usize, Slot)>();

    let claim = |queue: &JobQueue| queue.lock().expect("job queue lock").next();
    // One claim-loop body, shared by the caller and the pool workers.
    let work = |tx: mpsc::Sender<(usize, Slot)>| {
        while !cancel.load(Ordering::Relaxed) {
            let Some((idx, job)) = claim(&queue) else { break };
            let slot = catch_unwind(AssertUnwindSafe(|| run_job(job, inner)));
            if tx.send((idx, slot)).is_err() {
                break;
            }
        }
    };

    let loops: Vec<ScopedJob<'_>> = (1..workers)
        .map(|_| {
            let tx = tx.clone();
            let work = &work;
            Box::new(move || work(tx)) as ScopedJob<'_>
        })
        .collect();
    let guard = pool.dispatch(loops);

    // The caller is claim-loop 0 — and also the committer: between its own
    // layers it drains finished ones and hands them to the sink in strict
    // job order (the streaming path that keeps memory bounded by the
    // reorder buffer instead of the model depth).
    let mut pending: BTreeMap<usize, Slot> = BTreeMap::new();
    let mut next = 0usize;
    // First sink *or* compressor error: either cancels the queue and
    // suppresses further commits (a stream sink must never receive layer
    // k+1 after layer k failed — the artifact would be mis-ordered).
    let mut first_err: Option<anyhow::Error> = None;
    let mut commit_ready = |pending: &mut BTreeMap<usize, Slot>,
                            next: &mut usize,
                            first_err: &mut Option<anyhow::Error>|
     -> Option<JobPayload> {
        while let Some(slot) = pending.remove(next) {
            *next += 1;
            match slot {
                Ok(Ok(outcome)) => {
                    if first_err.is_none() {
                        if let Err(e) = sink(*next - 1, outcome) {
                            *first_err = Some(e);
                            cancel.store(true, Ordering::Relaxed);
                        }
                    }
                }
                Ok(Err(e)) => {
                    if first_err.is_none() {
                        *first_err = Some(e);
                        cancel.store(true, Ordering::Relaxed);
                    }
                }
                // Completed layers before this one are already committed;
                // re-raise the original payload (after in-flight work
                // drains at the caller).
                Err(payload) => return Some(payload),
            }
        }
        None
    };

    let mut panic_payload: Option<JobPayload> = None;
    loop {
        if cancel.load(Ordering::Relaxed) {
            break;
        }
        let Some((idx, job)) = claim(&queue) else { break };
        let slot = catch_unwind(AssertUnwindSafe(|| run_job(job, inner)));
        pending.insert(idx, slot);
        while let Ok((i, s)) = rx.try_recv() {
            pending.insert(i, s);
        }
        if panic_payload.is_none() {
            panic_payload = commit_ready(&mut pending, &mut next, &mut first_err);
            if panic_payload.is_some() {
                cancel.store(true, Ordering::Relaxed);
            }
        }
    }

    // Wait for the worker loops, then drain everything still in flight.
    guard.wait();
    drop(tx);
    for (i, s) in rx {
        pending.insert(i, s);
    }
    if panic_payload.is_none() {
        panic_payload = commit_ready(&mut pending, &mut next, &mut first_err);
    }
    if let Some(payload) = panic_payload {
        std::panic::resume_unwind(payload);
    }
    if let Some(e) = first_err {
        return Err(e);
    }
    Ok(())
}

/// Run all jobs on `workers` claim-loops; results return in job order.
/// The collect-everything convenience over
/// [`run_compression_jobs_streaming`] — serving-form layers are dropped,
/// only the metrics survive. `Err` on the first compressor failure.
pub fn run_compression_jobs(
    jobs: Vec<CompressionJob>,
    workers: usize,
) -> anyhow::Result<Vec<JobResult>> {
    let mut out = Vec::with_capacity(jobs.len());
    run_compression_jobs_streaming(jobs, workers, |_, outcome| {
        out.push(outcome.result);
        Ok(())
    })?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::littlebit::InitStrategy;
    use crate::rng::derive_seed;

    fn jobs(n: usize) -> Vec<CompressionJob> {
        (0..n)
            .map(|i| {
                let spec = SynthSpec { rows: 64, cols: 64, gamma: 0.3, coherence: 0.6, scale: 1.0 };
                CompressionJob {
                    name: format!("layer{i}"),
                    input: JobInput::Synth { spec, seed: derive_seed(5, i as u64) },
                    method: MethodSpec::LittleBit2(CompressionConfig {
                        bpp: 1.2,
                        strategy: InitStrategy::JointItq { iters: 10 },
                        residual: true,
                        ..Default::default()
                    }),
                    seed: 100 + i as u64,
                }
            })
            .collect()
    }

    #[test]
    fn results_in_job_order() {
        let res = run_compression_jobs(jobs(6), 3).unwrap();
        let names: Vec<_> = res.iter().map(|r| r.name.as_str()).collect();
        assert_eq!(names, vec!["layer0", "layer1", "layer2", "layer3", "layer4", "layer5"]);
    }

    /// The acceptance contract: identical layers for any worker count —
    /// including byte-identical packed bit-planes, not just close metrics.
    #[test]
    fn deterministic_across_worker_counts() {
        let collect = |workers: usize| {
            let mut packed = Vec::new();
            let mut results = Vec::new();
            run_compression_jobs_streaming(jobs(4), workers, |_, oc| {
                packed.push(oc.layer.into_packed().expect("littlebit2 layer"));
                results.push(oc.result);
                Ok(())
            })
            .unwrap();
            (packed, results)
        };
        let (p1, r1) = collect(1);
        for workers in [2usize, 4, 7] {
            let (pn, rn) = collect(workers);
            for (a, b) in r1.iter().zip(&rn) {
                assert_eq!(a.name, b.name, "workers={workers}");
                assert_eq!(a.mse.to_bits(), b.mse.to_bits(), "workers={workers}");
                assert_eq!(a.rank, b.rank);
            }
            for (la, lb) in p1.iter().zip(&pn) {
                for (pa, pb) in la.paths().iter().zip(lb.paths()) {
                    assert_eq!(pa.ub_bits().padded_words(), pb.ub_bits().padded_words(), "workers={workers}");
                    assert_eq!(pa.vbt_bits().padded_words(), pb.vbt_bits().padded_words());
                    assert_eq!(pa.h(), pb.h());
                    assert_eq!(pa.l(), pb.l());
                    assert_eq!(pa.g(), pb.g());
                }
            }
        }
    }

    /// Dense and Synth inputs with the same underlying weight + seed must
    /// produce identical layers (Synth is just the lazy form).
    #[test]
    fn dense_and_synth_inputs_agree() {
        let spec = SynthSpec { rows: 48, cols: 48, gamma: 0.3, coherence: 0.6, scale: 1.0 };
        let w = synth_weight(&spec, &mut Pcg64::seed(77));
        let cfg = CompressionConfig { bpp: 1.0, ..Default::default() };
        let dense = run_compression_jobs(
            vec![CompressionJob::dense("l", w, cfg.clone(), 9)],
            1,
        )
        .unwrap();
        let synth = run_compression_jobs(
            vec![CompressionJob {
                name: "l".into(),
                input: JobInput::Synth { spec, seed: 77 },
                method: MethodSpec::LittleBit2(cfg),
                seed: 9,
            }],
            1,
        )
        .unwrap();
        assert_eq!(dense[0].mse.to_bits(), synth[0].mse.to_bits());
    }

    /// Streaming: the sink must see indices in strict order, with metrics
    /// attached, for any worker count.
    #[test]
    fn streaming_commits_in_order() {
        let mut seen = Vec::new();
        run_compression_jobs_streaming(jobs(5), 4, |idx, oc| {
            seen.push((idx, oc.result.name.clone()));
            assert!(oc.result.mse.is_finite());
            Ok(())
        })
        .unwrap();
        let want: Vec<(usize, String)> =
            (0..5).map(|i| (i, format!("layer{i}"))).collect();
        assert_eq!(seen, want);
    }

    /// A sink error cancels the rest of the queue and surfaces as Err —
    /// not a hang, not a panic.
    #[test]
    fn sink_error_cancels_cleanly() {
        let mut calls = 0usize;
        let err = run_compression_jobs_streaming(jobs(6), 2, |idx, _| {
            calls += 1;
            if idx == 1 {
                anyhow::bail!("sink full")
            }
            Ok(())
        })
        .unwrap_err();
        assert!(err.to_string().contains("sink full"), "{err}");
        assert!(calls >= 2);
    }

    #[test]
    fn empty_job_list() {
        assert!(run_compression_jobs(Vec::new(), 4).unwrap().is_empty());
    }

    #[test]
    fn reports_sane_metrics() {
        let res = run_compression_jobs(jobs(2), 2).unwrap();
        for r in res {
            assert_eq!(r.method, "littlebit2");
            assert!(r.mse.is_finite() && r.mse >= 0.0);
            assert!(r.rel_err.is_finite() && r.rel_err >= 0.0 && r.rel_err < 1.0);
            assert!(r.bpp > 0.0 && r.bpp <= 1.3);
            assert!(r.rank >= 1);
            let (lm, lx) = (r.lambda_mean.unwrap(), r.lambda_max.unwrap());
            assert!(lm > 0.0 && lx >= lm);
            assert!(r.report.svd_ms > 0.0 && r.wall_ms >= r.report.total_ms);
            assert!(r.report.total_ms + 1e-9 >= r.report.stage_ms());
        }
    }

    /// Mixed-method job lists flow through one scheduler run: every
    /// method's layer arrives in order, tagged, with baseline λ = None.
    #[test]
    fn mixed_method_jobs_stream_in_order() {
        let spec = SynthSpec { rows: 48, cols: 48, gamma: 0.3, coherence: 0.6, scale: 1.0 };
        let methods = [
            MethodSpec::LittleBit2(CompressionConfig { bpp: 1.0, ..Default::default() }),
            MethodSpec::OneBit { als_iters: 10 },
            MethodSpec::Rtn { k: 2, group: 32 },
            MethodSpec::TinyRankFp16 { bpp: 1.0 },
        ];
        let jobs: Vec<CompressionJob> = methods
            .iter()
            .enumerate()
            .map(|(i, m)| CompressionJob {
                name: format!("l{i}"),
                input: JobInput::Synth { spec: spec.clone(), seed: derive_seed(3, i as u64) },
                method: m.clone(),
                seed: derive_seed(4, i as u64),
            })
            .collect();
        let mut seen = Vec::new();
        run_compression_jobs_streaming(jobs, 3, |idx, oc| {
            // rel_err can exceed 1 only for the known 2-bit RTN collapse
            // on spiky weights; everything stays finite and bounded.
            assert!(oc.result.rel_err < 4.0, "{}: rel_err {}", oc.result.method, oc.result.rel_err);
            if oc.result.method != "littlebit2" {
                assert!(oc.result.lambda_mean.is_none());
            }
            seen.push((idx, oc.result.method.clone()));
            Ok(())
        })
        .unwrap();
        let want: Vec<(usize, String)> = ["littlebit2", "onebit", "rtn", "tinyrank"]
            .iter()
            .enumerate()
            .map(|(i, m)| (i, m.to_string()))
            .collect();
        assert_eq!(seen, want);
    }

    /// A compressor error (not a panic) surfaces as the run's `Err` after
    /// earlier layers committed — and never reaches the sink out of order.
    #[test]
    fn compressor_error_surfaces_as_err() {
        let spec = SynthSpec { rows: 32, cols: 32, gamma: 0.3, coherence: 0.6, scale: 1.0 };
        let mk = |i: usize, method: MethodSpec| CompressionJob {
            name: format!("l{i}"),
            input: JobInput::Synth { spec: spec.clone(), seed: i as u64 },
            method,
            seed: 10 + i as u64,
        };
        let jobs = vec![
            mk(0, MethodSpec::OneBit { als_iters: 5 }),
            mk(1, MethodSpec::Rtn { k: 0, group: 128 }), // invalid bit width
            mk(2, MethodSpec::OneBit { als_iters: 5 }),
        ];
        let mut committed = Vec::new();
        let err = run_compression_jobs_streaming(jobs, 1, |idx, _| {
            committed.push(idx);
            Ok(())
        })
        .unwrap_err();
        assert!(err.to_string().contains("l1"), "{err}");
        assert_eq!(committed, vec![0], "only the layer before the failure commits");
    }
}
