//! Parallel layer-compression scheduler.
//!
//! Compressing a model is embarrassingly parallel across layers; this
//! scheduler fans a job list out over a worker pool (std threads + channel
//! work queue — no external runtime in this build), collecting per-layer
//! results with deterministic per-job RNG streams so the output is
//! independent of scheduling order.

use crate::linalg::Mat;
use crate::littlebit::{compress, CompressionConfig};
use crate::rng::Pcg64;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

/// One unit of work: compress `weight` under `cfg`.
pub struct CompressionJob {
    /// Stable identifier (e.g. "b12.q_proj").
    pub name: String,
    pub weight: Mat,
    pub cfg: CompressionConfig,
    /// Seed for this job's deterministic RNG stream.
    pub seed: u64,
}

/// Per-layer outcome.
#[derive(Clone, Debug)]
pub struct JobResult {
    pub name: String,
    pub mse: f64,
    pub bpp: f64,
    pub rank: usize,
    pub wall_ms: f64,
}

/// Run all jobs on `workers` threads; results return in job order.
pub fn run_compression_jobs(jobs: Vec<CompressionJob>, workers: usize) -> Vec<JobResult> {
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    let queue: Arc<Mutex<std::vec::IntoIter<(usize, CompressionJob)>>> = Arc::new(Mutex::new(
        jobs.into_iter().enumerate().collect::<Vec<_>>().into_iter(),
    ));
    let (tx, rx) = mpsc::channel::<(usize, JobResult)>();

    let mut handles = Vec::new();
    for _ in 0..workers {
        let queue = Arc::clone(&queue);
        let tx = tx.clone();
        handles.push(thread::spawn(move || loop {
            let job = { queue.lock().expect("queue lock").next() };
            let Some((idx, job)) = job else { break };
            let t0 = std::time::Instant::now();
            let mut rng = Pcg64::seed(job.seed);
            let compressed = compress(&job.weight, &job.cfg, &mut rng);
            let recon = compressed.reconstruct();
            let result = JobResult {
                name: job.name,
                mse: recon.mse(&job.weight),
                bpp: compressed.bpp(),
                rank: compressed.paths[0].factors.rank(),
                wall_ms: t0.elapsed().as_secs_f64() * 1e3,
            };
            if tx.send((idx, result)).is_err() {
                break;
            }
        }));
    }
    drop(tx);

    let mut out: Vec<Option<JobResult>> = (0..n).map(|_| None).collect();
    for (idx, res) in rx {
        out[idx] = Some(res);
    }
    for h in handles {
        h.join().expect("worker panicked");
    }
    out.into_iter().map(|r| r.expect("job lost")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::littlebit::InitStrategy;
    use crate::spectral::{synth_weight, SynthSpec};

    fn jobs(n: usize) -> Vec<CompressionJob> {
        let mut rng = Pcg64::seed(5);
        (0..n)
            .map(|i| {
                let spec = SynthSpec { rows: 64, cols: 64, gamma: 0.3, coherence: 0.6, scale: 1.0 };
                CompressionJob {
                    name: format!("layer{i}"),
                    weight: synth_weight(&spec, &mut rng),
                    cfg: CompressionConfig {
                        bpp: 1.2,
                        strategy: InitStrategy::JointItq { iters: 10 },
                        residual: true,
                        ..Default::default()
                    },
                    seed: 100 + i as u64,
                }
            })
            .collect()
    }

    #[test]
    fn results_in_job_order() {
        let res = run_compression_jobs(jobs(6), 3);
        let names: Vec<_> = res.iter().map(|r| r.name.as_str()).collect();
        assert_eq!(names, vec!["layer0", "layer1", "layer2", "layer3", "layer4", "layer5"]);
    }

    #[test]
    fn deterministic_across_worker_counts() {
        let a = run_compression_jobs(jobs(4), 1);
        let b = run_compression_jobs(jobs(4), 4);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.name, y.name);
            assert!((x.mse - y.mse).abs() < 1e-12, "{} vs {}", x.mse, y.mse);
        }
    }

    #[test]
    fn empty_job_list() {
        assert!(run_compression_jobs(Vec::new(), 4).is_empty());
    }

    #[test]
    fn reports_sane_metrics() {
        let res = run_compression_jobs(jobs(2), 2);
        for r in res {
            assert!(r.mse.is_finite() && r.mse >= 0.0);
            assert!(r.bpp > 0.0 && r.bpp <= 1.3);
            assert!(r.rank >= 1);
        }
    }
}
