//! Layer-compression scheduling on the shared worker pool.
//!
//! Compressing a model is embarrassingly parallel across layers. This
//! scheduler fans a job list out as claim-loops on the process-wide
//! [`Pool`] (no per-call OS-thread spawns — the PR 2 serving pool and the
//! offline pipeline share one resident worker set) and hands finished
//! layers to a caller-supplied sink **in job order** while later layers
//! are still compressing — the streaming half of `compress --jobs N`,
//! where the sink appends straight into the `.lb2`
//! [`StackStreamWriter`](crate::artifact::StackStreamWriter).
//!
//! # Determinism
//!
//! Each job owns an independent RNG stream (its `seed`; derive per-layer
//! seeds with [`crate::rng::derive_seed`], never by advancing one shared
//! generator across the layer loop) and every pooled kernel is bit-exact,
//! so a layer's bytes never depend on worker count or claim order. Commits
//! are reordered to strict job order before reaching the sink, so the
//! artifact byte stream is identical for any `workers`.
//!
//! # Inner parallelism
//!
//! With `workers == 1` the single claim-loop runs on the caller and each
//! layer's linalg fans out across [`Pool::global`] (the d≈4096 single-layer
//! case). With `workers > 1` layer-parallelism owns the cores: claim-loops
//! run *on* pool workers, where nested dispatch inlines (see `parallel`),
//! so per-layer linalg is serial by construction — the right trade at
//! model scale, with no deadlock risk either way.
//!
//! Because claim-loops occupy the shared global workers until the job
//! queue drains, compressing and *serving* from the same process at the
//! same time makes serving's row-range jobs queue behind compression —
//! whole-model latency, not microseconds. That mirrors the deployment
//! contract (quantize once, then serve; no binary in this repo does
//! both concurrently); a process that genuinely needs both should give
//! the server its own `SignPool::new(..)` instead of the global one.
//!
//! # Failure semantics
//!
//! A panicking layer no longer tears down the batch blindly: every other
//! in-flight layer completes, layers *before* the panic still reach the
//! sink in order, and then the original panic payload is re-raised on the
//! caller (the old implementation lost all completed results to a
//! `join().expect` and leaked the panic message). A sink error cancels
//! the remaining queue, drains in-flight work, and returns the error.

use crate::linalg::Mat;
use crate::littlebit::{compress_pipeline, CompressionConfig, CompressionReport};
use crate::packing::PackedResidual;
use crate::parallel::{Pool, ScopedJob};
use crate::rng::Pcg64;
use crate::spectral::{synth_weight, SynthSpec};
use std::any::Any;
use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::Mutex;

/// Where a job's weight matrix comes from. `Synth` keeps the dense matrix
/// out of the job list entirely (it is fabricated inside the worker and
/// dropped with the job), so a long synthetic chain streams at bounded
/// memory; real pipelines hand in `Dense` weights they already hold.
#[derive(Clone, Debug)]
pub enum JobInput {
    /// An explicit dense weight matrix.
    Dense(Mat),
    /// Fabricate `synth_weight(&spec, seed)` inside the job.
    Synth { spec: SynthSpec, seed: u64 },
}

impl JobInput {
    /// `(d_out, d_in)` of the weight this input will produce.
    pub fn shape(&self) -> (usize, usize) {
        match self {
            JobInput::Dense(w) => w.shape(),
            JobInput::Synth { spec, .. } => (spec.rows, spec.cols),
        }
    }
}

/// One unit of work: compress the input weight under `cfg`.
#[derive(Clone, Debug)]
pub struct CompressionJob {
    /// Stable identifier (e.g. "b12.q_proj").
    pub name: String,
    pub input: JobInput,
    pub cfg: CompressionConfig,
    /// Seed of this job's independent RNG stream
    /// (see [`crate::rng::derive_seed`]).
    pub seed: u64,
}

impl CompressionJob {
    /// Convenience constructor for an explicit weight matrix.
    pub fn dense(name: impl Into<String>, weight: Mat, cfg: CompressionConfig, seed: u64) -> Self {
        Self { name: name.into(), input: JobInput::Dense(weight), cfg, seed }
    }

    /// `(d_out, d_in)` of the layer this job produces.
    pub fn shape(&self) -> (usize, usize) {
        self.input.shape()
    }

    /// Residual paths the compressed layer will carry (fixed by the
    /// config), so artifact headers can be written before any layer
    /// finishes.
    pub fn n_paths(&self) -> usize {
        if self.cfg.residual {
            2
        } else {
            1
        }
    }
}

/// Per-layer metrics.
#[derive(Clone, Debug)]
pub struct JobResult {
    pub name: String,
    pub mse: f64,
    pub bpp: f64,
    pub rank: usize,
    /// Mean / max λ over path 0's latent rows (the Fig. 3 diagnostic).
    pub lambda_mean: f64,
    pub lambda_max: f64,
    /// End-to-end wall-clock of the job (compression + scoring).
    pub wall_ms: f64,
    /// Per-stage wall-clock of the compression itself.
    pub report: CompressionReport,
}

/// Everything the sink receives per layer: metrics plus the packed
/// deployment form ready to stream into an artifact. The full-precision
/// factors are dropped inside the job, so in-flight memory is the packed
/// reorder buffer: typically O(workers) layers (layers of one model are
/// near-uniform cost), degrading toward the model tail only if an early
/// layer is pathologically slower than its successors.
pub struct LayerOutcome {
    pub result: JobResult,
    pub packed: PackedResidual,
}

/// Compress one job on `pool` and score it.
fn run_job(job: CompressionJob, pool: &Pool) -> LayerOutcome {
    let t0 = std::time::Instant::now();
    let w = match job.input {
        JobInput::Dense(w) => w,
        JobInput::Synth { spec, seed } => synth_weight(&spec, &mut Pcg64::seed(seed)),
    };
    let mut rng = Pcg64::seed(job.seed);
    let layer = compress_pipeline(&w, &job.cfg, &mut rng, pool);
    let recon = layer.compressed.reconstruct_on(pool);
    let lams = layer.compressed.paths[0].u_distortions();
    let lambda_mean = lams.iter().sum::<f64>() / lams.len().max(1) as f64;
    let lambda_max = lams.iter().fold(0.0f64, |m, &x| m.max(x));
    LayerOutcome {
        result: JobResult {
            name: job.name,
            mse: recon.mse(&w),
            bpp: layer.compressed.bpp(),
            rank: layer.compressed.paths[0].factors.rank(),
            lambda_mean,
            lambda_max,
            wall_ms: t0.elapsed().as_secs_f64() * 1e3,
            report: layer.report,
        },
        packed: layer.packed,
    }
}

type JobPayload = Box<dyn Any + Send + 'static>;
type Slot = Result<LayerOutcome, JobPayload>;
type JobQueue = Mutex<std::iter::Enumerate<std::vec::IntoIter<CompressionJob>>>;

/// Run all jobs across `workers` claim-loops on the shared pool, invoking
/// `sink(index, outcome)` **in job order** as layers complete. Returns
/// when every layer has been committed (or on the first sink error, after
/// in-flight work drains). See the module docs for the determinism,
/// panic, and inner-parallelism contracts.
pub fn run_compression_jobs_streaming(
    jobs: Vec<CompressionJob>,
    workers: usize,
    mut sink: impl FnMut(usize, LayerOutcome) -> anyhow::Result<()>,
) -> anyhow::Result<()> {
    let n = jobs.len();
    if n == 0 {
        return Ok(());
    }
    let workers = workers.clamp(1, n);
    let pool = Pool::for_threads(workers);
    // With one claim-loop the caller owns every layer and each layer fans
    // its linalg across the global pool; with several, the loops own the
    // cores and per-layer linalg stays serial (nested dispatch would
    // inline anyway — this just skips the queue round-trip).
    let inner: &Pool = if workers == 1 { Pool::global() } else { Pool::serial() };

    let queue: JobQueue = Mutex::new(jobs.into_iter().enumerate());
    let cancel = AtomicBool::new(false);
    let (tx, rx) = mpsc::channel::<(usize, Slot)>();

    let claim = |queue: &JobQueue| queue.lock().expect("job queue lock").next();
    // One claim-loop body, shared by the caller and the pool workers.
    let work = |tx: mpsc::Sender<(usize, Slot)>| {
        while !cancel.load(Ordering::Relaxed) {
            let Some((idx, job)) = claim(&queue) else { break };
            let slot = catch_unwind(AssertUnwindSafe(|| run_job(job, inner)));
            if tx.send((idx, slot)).is_err() {
                break;
            }
        }
    };

    let loops: Vec<ScopedJob<'_>> = (1..workers)
        .map(|_| {
            let tx = tx.clone();
            let work = &work;
            Box::new(move || work(tx)) as ScopedJob<'_>
        })
        .collect();
    let guard = pool.dispatch(loops);

    // The caller is claim-loop 0 — and also the committer: between its own
    // layers it drains finished ones and hands them to the sink in strict
    // job order (the streaming path that keeps memory bounded by the
    // reorder buffer instead of the model depth).
    let mut pending: BTreeMap<usize, Slot> = BTreeMap::new();
    let mut next = 0usize;
    let mut sink_err: Option<anyhow::Error> = None;
    let mut commit_ready = |pending: &mut BTreeMap<usize, Slot>,
                            next: &mut usize,
                            sink_err: &mut Option<anyhow::Error>|
     -> Option<JobPayload> {
        while let Some(slot) = pending.remove(next) {
            *next += 1;
            match slot {
                Ok(outcome) => {
                    if sink_err.is_none() {
                        if let Err(e) = sink(*next - 1, outcome) {
                            *sink_err = Some(e);
                            cancel.store(true, Ordering::Relaxed);
                        }
                    }
                }
                // Completed layers before this one are already committed;
                // re-raise the original payload (after in-flight work
                // drains at the caller).
                Err(payload) => return Some(payload),
            }
        }
        None
    };

    let mut panic_payload: Option<JobPayload> = None;
    loop {
        if cancel.load(Ordering::Relaxed) {
            break;
        }
        let Some((idx, job)) = claim(&queue) else { break };
        let slot = catch_unwind(AssertUnwindSafe(|| run_job(job, inner)));
        pending.insert(idx, slot);
        while let Ok((i, s)) = rx.try_recv() {
            pending.insert(i, s);
        }
        if panic_payload.is_none() {
            panic_payload = commit_ready(&mut pending, &mut next, &mut sink_err);
            if panic_payload.is_some() {
                cancel.store(true, Ordering::Relaxed);
            }
        }
    }

    // Wait for the worker loops, then drain everything still in flight.
    guard.wait();
    drop(tx);
    for (i, s) in rx {
        pending.insert(i, s);
    }
    if panic_payload.is_none() {
        panic_payload = commit_ready(&mut pending, &mut next, &mut sink_err);
    }
    if let Some(payload) = panic_payload {
        std::panic::resume_unwind(payload);
    }
    if let Some(e) = sink_err {
        return Err(e);
    }
    Ok(())
}

/// Run all jobs on `workers` claim-loops; results return in job order.
/// The collect-everything convenience over
/// [`run_compression_jobs_streaming`] — packed layers are dropped, only
/// the metrics survive.
pub fn run_compression_jobs(jobs: Vec<CompressionJob>, workers: usize) -> Vec<JobResult> {
    let mut out = Vec::with_capacity(jobs.len());
    run_compression_jobs_streaming(jobs, workers, |_, outcome| {
        out.push(outcome.result);
        Ok(())
    })
    .expect("infallible sink");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::littlebit::InitStrategy;
    use crate::rng::derive_seed;

    fn jobs(n: usize) -> Vec<CompressionJob> {
        (0..n)
            .map(|i| {
                let spec = SynthSpec { rows: 64, cols: 64, gamma: 0.3, coherence: 0.6, scale: 1.0 };
                CompressionJob {
                    name: format!("layer{i}"),
                    input: JobInput::Synth { spec, seed: derive_seed(5, i as u64) },
                    cfg: CompressionConfig {
                        bpp: 1.2,
                        strategy: InitStrategy::JointItq { iters: 10 },
                        residual: true,
                        ..Default::default()
                    },
                    seed: 100 + i as u64,
                }
            })
            .collect()
    }

    #[test]
    fn results_in_job_order() {
        let res = run_compression_jobs(jobs(6), 3);
        let names: Vec<_> = res.iter().map(|r| r.name.as_str()).collect();
        assert_eq!(names, vec!["layer0", "layer1", "layer2", "layer3", "layer4", "layer5"]);
    }

    /// The acceptance contract: identical layers for any worker count —
    /// including byte-identical packed bit-planes, not just close metrics.
    #[test]
    fn deterministic_across_worker_counts() {
        let collect = |workers: usize| {
            let mut packed = Vec::new();
            let mut results = Vec::new();
            run_compression_jobs_streaming(jobs(4), workers, |_, oc| {
                packed.push(oc.packed);
                results.push(oc.result);
                Ok(())
            })
            .unwrap();
            (packed, results)
        };
        let (p1, r1) = collect(1);
        for workers in [2usize, 4, 7] {
            let (pn, rn) = collect(workers);
            for (a, b) in r1.iter().zip(&rn) {
                assert_eq!(a.name, b.name, "workers={workers}");
                assert_eq!(a.mse.to_bits(), b.mse.to_bits(), "workers={workers}");
                assert_eq!(a.rank, b.rank);
            }
            for (la, lb) in p1.iter().zip(&pn) {
                for (pa, pb) in la.paths().iter().zip(lb.paths()) {
                    assert_eq!(pa.ub_bits().words(), pb.ub_bits().words(), "workers={workers}");
                    assert_eq!(pa.vbt_bits().words(), pb.vbt_bits().words());
                    assert_eq!(pa.h(), pb.h());
                    assert_eq!(pa.l(), pb.l());
                    assert_eq!(pa.g(), pb.g());
                }
            }
        }
    }

    /// Dense and Synth inputs with the same underlying weight + seed must
    /// produce identical layers (Synth is just the lazy form).
    #[test]
    fn dense_and_synth_inputs_agree() {
        let spec = SynthSpec { rows: 48, cols: 48, gamma: 0.3, coherence: 0.6, scale: 1.0 };
        let w = synth_weight(&spec, &mut Pcg64::seed(77));
        let cfg = CompressionConfig { bpp: 1.0, ..Default::default() };
        let dense = run_compression_jobs(
            vec![CompressionJob::dense("l", w, cfg.clone(), 9)],
            1,
        );
        let synth = run_compression_jobs(
            vec![CompressionJob {
                name: "l".into(),
                input: JobInput::Synth { spec, seed: 77 },
                cfg,
                seed: 9,
            }],
            1,
        );
        assert_eq!(dense[0].mse.to_bits(), synth[0].mse.to_bits());
    }

    /// Streaming: the sink must see indices in strict order, with metrics
    /// attached, for any worker count.
    #[test]
    fn streaming_commits_in_order() {
        let mut seen = Vec::new();
        run_compression_jobs_streaming(jobs(5), 4, |idx, oc| {
            seen.push((idx, oc.result.name.clone()));
            assert!(oc.result.mse.is_finite());
            Ok(())
        })
        .unwrap();
        let want: Vec<(usize, String)> =
            (0..5).map(|i| (i, format!("layer{i}"))).collect();
        assert_eq!(seen, want);
    }

    /// A sink error cancels the rest of the queue and surfaces as Err —
    /// not a hang, not a panic.
    #[test]
    fn sink_error_cancels_cleanly() {
        let mut calls = 0usize;
        let err = run_compression_jobs_streaming(jobs(6), 2, |idx, _| {
            calls += 1;
            if idx == 1 {
                anyhow::bail!("sink full")
            }
            Ok(())
        })
        .unwrap_err();
        assert!(err.to_string().contains("sink full"), "{err}");
        assert!(calls >= 2);
    }

    #[test]
    fn empty_job_list() {
        assert!(run_compression_jobs(Vec::new(), 4).is_empty());
    }

    #[test]
    fn reports_sane_metrics() {
        let res = run_compression_jobs(jobs(2), 2);
        for r in res {
            assert!(r.mse.is_finite() && r.mse >= 0.0);
            assert!(r.bpp > 0.0 && r.bpp <= 1.3);
            assert!(r.rank >= 1);
            assert!(r.lambda_mean > 0.0 && r.lambda_max >= r.lambda_mean);
            assert!(r.report.svd_ms > 0.0 && r.wall_ms >= r.report.total_ms);
            assert!(r.report.total_ms + 1e-9 >= r.report.stage_ms());
        }
    }
}
