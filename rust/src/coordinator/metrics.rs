//! Lightweight metrics registry: counters, gauges, and wall-clock timers,
//! dumped as aligned text for experiment logs (EXPERIMENTS.md provenance).

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

/// Thread-safe metrics sink.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    timers: BTreeMap<String, (f64, u64)>, // (total seconds, samples)
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn incr(&self, name: &str, by: u64) {
        let mut m = self.inner.lock().expect("metrics lock");
        *m.counters.entry(name.to_string()).or_default() += by;
    }

    pub fn gauge(&self, name: &str, value: f64) {
        let mut m = self.inner.lock().expect("metrics lock");
        m.gauges.insert(name.to_string(), value);
    }

    /// Time a closure, accumulating under `name`.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        let dt = t0.elapsed().as_secs_f64();
        let mut m = self.inner.lock().expect("metrics lock");
        let e = m.timers.entry(name.to_string()).or_insert((0.0, 0));
        e.0 += dt;
        e.1 += 1;
        out
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.inner
            .lock()
            .expect("metrics lock")
            .counters
            .get(name)
            .copied()
            .unwrap_or(0)
    }

    pub fn report(&self) -> String {
        let m = self.inner.lock().expect("metrics lock");
        let mut out = String::new();
        for (k, v) in &m.counters {
            out.push_str(&format!("counter {k:40} {v}\n"));
        }
        for (k, v) in &m.gauges {
            out.push_str(&format!("gauge   {k:40} {v:.6}\n"));
        }
        for (k, (total, n)) in &m.timers {
            let mean = if *n > 0 { total / *n as f64 } else { 0.0 };
            out.push_str(&format!(
                "timer   {k:40} total={total:.3}s n={n} mean={:.3}ms\n",
                mean * 1e3
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.incr("requests", 1);
        m.incr("requests", 2);
        assert_eq!(m.counter("requests"), 3);
    }

    #[test]
    fn timer_records() {
        let m = Metrics::new();
        let x = m.time("work", || 41 + 1);
        assert_eq!(x, 42);
        assert!(m.report().contains("timer   work"));
    }

    #[test]
    fn gauge_overwrites() {
        let m = Metrics::new();
        m.gauge("loss", 2.0);
        m.gauge("loss", 1.0);
        assert!(m.report().contains("1.000000"));
        assert!(!m.report().contains("2.000000"));
    }

    #[test]
    fn thread_safety() {
        let m = std::sync::Arc::new(Metrics::new());
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        m.incr("n", 1);
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(m.counter("n"), 4000);
    }
}
