//! The shared persistent worker pool behind every row-parallel kernel and
//! the layer-parallel compression scheduler.
//!
//! PR 2 built this machinery privately inside `packing::pool` for the two
//! sign kernels; this module promotes it to a general substrate so the
//! *offline* pipeline (blocked dense matmuls, Householder QR trailing
//! updates, randomized SVD, Joint-ITQ, Dual-SVID, per-layer compression
//! jobs) runs on the same resident threads as serving, instead of naive
//! single-threaded triple loops. `packing::SignPool` is now a thin client.
//!
//! # Execution model
//!
//! A [`Pool`] owns `threads − 1` long-lived workers blocked on a shared
//! MPSC job channel (zero CPU when idle). A dispatching caller ships
//! scoped closures as jobs, always keeps one share of the work for itself
//! (so a 1-thread pool is purely serial and spawns nothing), and blocks on
//! per-job acknowledgements before its borrows end. The primitives:
//!
//! * [`Pool::run`] — execute a batch of jobs; job 0 runs inline on the
//!   caller, the rest go to the workers.
//! * `Pool::dispatch` (crate-private; the guard must not be forgettable
//!   by safe downstream code) — ship jobs and return the ack guard; the
//!   caller does its own (different) work, then waits. This is what the
//!   compression scheduler uses: workers run claim-loops while the caller
//!   claims layers *and* commits finished ones in order.
//! * [`Pool::run_row_chunks`] — the common shape: split a `rows × width`
//!   output buffer into at most `parts` contiguous row ranges and run a
//!   kernel on each. The partition depends only on `(rows, parts)` —
//!   never on pool occupancy.
//!
//! # Determinism / bit-exactness
//!
//! Every parallel kernel in this codebase is "a row range of the exact
//! serial kernel": partitioning output rows changes no per-element
//! reduction order, and ranges are disjoint, so assembled outputs are
//! bit-identical to the serial kernel for **any** thread count, pool size,
//! or scheduling order — asserted across thread counts {1, 2, 7, 64} by
//! the linalg and packing tests. Work that is *scheduled* through the pool
//! (compression jobs) gets determinism from per-job derived RNG seeds
//! ([`crate::rng::derive_seed`]) plus in-order result commits.
//!
//! # Nested dispatch
//!
//! Dispatching from *inside* a pool worker would deadlock the moment every
//! worker blocks on acks for sub-jobs that sit unpopped in the queue. The
//! pool therefore never queues from a worker thread: [`Pool::dispatch`]
//! (and everything built on it) detects that the current thread is a pool
//! worker and runs the jobs inline instead. Layer-compression jobs can
//! call pool-parallel linalg unconditionally; on a worker it degrades to
//! the serial kernel, bit-identically.
//!
//! # Safety model
//!
//! Jobs are `'scope` closures (they borrow the caller's operands and
//! disjoint `&mut` output ranges), lifetime-erased to cross the channel.
//! The dispatching call does not release those borrows until every job
//! has acknowledged: on the happy path it blocks in
//! [`DispatchGuard::wait`], and on **any unwind** (a panic in the caller's
//! inline share, or a propagated worker panic) the guard's `Drop` blocks
//! until all outstanding jobs finish — so no job ever outlives the
//! borrows it captured. If a worker panics mid-job, the job's ack sender
//! is dropped unsent; the caller observes the disconnect after all other
//! jobs drained and panics itself rather than returning partial output.

use std::cell::Cell;
use std::marker::PhantomData;
use std::ops::Range;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, OnceLock};
use std::thread::JoinHandle;

/// The pure partition behind [`Pool::run_row_chunks`]: split `rows` into
/// at most `parts` contiguous, disjoint, in-order ranges covering
/// `0..rows`. Depends only on `(rows, parts)` — never on pool occupancy —
/// so anything partitioned with it (in-process row kernels, cluster row
/// shards) agrees on byte boundaries across processes and machines.
///
/// `rows == 0` yields no ranges; `parts` is clamped to `1..=rows`; every
/// range but possibly the last has exactly `rows.div_ceil(parts)` rows, so
/// fewer than `parts` ranges can come back (e.g. `rows=5, parts=4` →
/// `[0..2, 2..4, 4..5]` — three ranges of ceil width, not four ragged
/// ones). This matches `chunks_mut(chunk_rows * width)` exactly, which is
/// what keeps shard-concatenated outputs bit-identical to the serial
/// kernel.
pub fn row_partition(rows: usize, parts: usize) -> Vec<Range<usize>> {
    if rows == 0 {
        return Vec::new();
    }
    let parts = parts.clamp(1, rows);
    let chunk = rows.div_ceil(parts);
    let mut ranges = Vec::with_capacity(parts);
    let mut lo = 0;
    while lo < rows {
        let hi = (lo + chunk).min(rows);
        ranges.push(lo..hi);
        lo = hi;
    }
    ranges
}

/// One unit of caller-scoped work. Jobs may borrow from the dispatching
/// caller's stack; the dispatch protocol guarantees they never outlive it.
pub type ScopedJob<'scope> = Box<dyn FnOnce() + Send + 'scope>;

/// The lifetime-erased form that crosses the worker channel.
type StaticJob = Box<dyn FnOnce() + Send + 'static>;

struct Envelope {
    job: StaticJob,
    /// Dropped unsent on panic — the caller turns that into its own panic.
    ack: Sender<()>,
}

thread_local! {
    /// True on pool-worker threads; used to inline nested dispatch.
    static IN_POOL_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// Whether the current thread is a pool worker (nested dispatch inlines).
pub fn in_pool_worker() -> bool {
    IN_POOL_WORKER.with(|c| c.get())
}

fn worker_loop(rx: &Mutex<Receiver<Envelope>>) {
    IN_POOL_WORKER.with(|c| c.set(true));
    loop {
        // Hold the lock only to pop one job so co-workers drain in parallel.
        let envelope = {
            let rx = rx.lock().expect("pool rx lock");
            match rx.recv() {
                Ok(e) => e,
                Err(_) => return, // pool dropped: shut down
            }
        };
        // catch_unwind keeps the worker alive if a job panics; the ack is
        // only sent on success, so the caller never mistakes a
        // partially-run job for a completed one.
        let ok = catch_unwind(AssertUnwindSafe(envelope.job)).is_ok();
        if ok {
            let _ = envelope.ack.send(());
        }
    }
}

/// Persistent worker pool for caller-scoped jobs.
///
/// `Pool::new(threads)` targets `threads` total parallelism: it spawns
/// `threads − 1` long-lived workers and the dispatching caller always
/// executes one share of the work itself (so a 1-thread pool is purely
/// serial and spawns nothing). [`Pool::global`] is the process-wide
/// instance sized to `available_parallelism`, shared by the sign kernels
/// (via `packing::SignPool`), the pooled linalg kernels, and the
/// compression job scheduler.
///
/// # Examples
///
/// ```
/// use littlebit2::parallel::Pool;
///
/// let pool = Pool::new(4);
/// let mut out = vec![0u64; 1000];
/// // Square each "row" (width 1) across the pool; the partition is
/// // deterministic, so the result never depends on the thread count.
/// pool.run_row_chunks(&mut out, 1, pool.threads(), |row0, chunk| {
///     for (i, v) in chunk.iter_mut().enumerate() {
///         *v = ((row0 + i) as u64).pow(2);
///     }
/// });
/// assert_eq!(out[31], 31 * 31);
/// ```
pub struct Pool {
    tx: Mutex<Option<Sender<Envelope>>>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
}

impl Pool {
    /// Build a pool targeting `threads` total parallelism (clamped to ≥ 1):
    /// `threads − 1` worker threads plus the calling thread per dispatch.
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let (tx, rx) = channel::<Envelope>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads - 1)
            .map(|_| {
                let rx = Arc::clone(&rx);
                std::thread::spawn(move || worker_loop(&rx))
            })
            .collect();
        Self { tx: Mutex::new(Some(tx)), workers, threads }
    }

    /// The process-wide pool, created on first use and sized to
    /// `std::thread::available_parallelism`. Never torn down (workers are
    /// idle blocked between calls and die with the process).
    pub fn global() -> &'static Pool {
        static POOL: OnceLock<Pool> = OnceLock::new();
        POOL.get_or_init(|| {
            let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            Pool::new(n)
        })
    }

    /// A zero-worker pool: every call runs serially on the calling thread.
    /// Exists so serial wrappers never instantiate [`global`](Self::global)
    /// — and its `available_parallelism − 1` resident worker threads — as a
    /// side effect of a purely serial call.
    pub fn serial() -> &'static Pool {
        static SERIAL: OnceLock<Pool> = OnceLock::new();
        SERIAL.get_or_init(|| Pool::new(1))
    }

    /// Pool selection for a `threads` knob: the shared
    /// [`global`](Self::global) pool when actual parallelism is requested,
    /// the spawn-free [`serial`](Self::serial) pool otherwise.
    pub fn for_threads(threads: usize) -> &'static Pool {
        if threads > 1 {
            Self::global()
        } else {
            Self::serial()
        }
    }

    /// Total parallelism this pool targets (workers + dispatching caller).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Ship every job to the workers and return the guard the caller must
    /// wait on before its borrows end. The caller is free to do its own
    /// work between `dispatch` and [`DispatchGuard::wait`] — that is the
    /// "caller keeps one share" pattern every `run_*` helper builds on.
    ///
    /// With no workers (a 1-thread pool), or when called from a pool
    /// worker thread (nested dispatch), the jobs run inline, in order,
    /// before this returns — never queued, so nesting cannot deadlock.
    ///
    /// Crate-private on purpose: the guard pattern is only sound if the
    /// guard is actually waited on (or dropped), and safe downstream code
    /// could `mem::forget` it — releasing the `'scope` borrows while the
    /// lifetime-erased jobs still run. The public surface (`run`,
    /// `run_row_chunks`) never lets the guard escape.
    pub(crate) fn dispatch<'scope>(&self, jobs: Vec<ScopedJob<'scope>>) -> DispatchGuard<'scope> {
        let (ack_tx, ack_rx) = channel::<()>();
        let mut remaining = 0usize;
        if jobs.is_empty() {
            // Nothing outstanding; the guard is a no-op.
        } else if self.workers.is_empty() || in_pool_worker() {
            for job in jobs {
                job();
            }
        } else {
            let tx = self.tx.lock().expect("pool tx lock");
            let tx = tx.as_ref().expect("pool not shut down");
            for job in jobs {
                // SAFETY: the returned guard blocks — in `wait` on the
                // happy path, in `Drop` on every unwind path — until each
                // job acknowledges or provably finished (ack channel
                // disconnect after a job's own unwind), and the guard
                // carries `'scope`, so no job outlives the borrows it
                // captured. Output ranges are disjoint by construction of
                // the callers.
                let job = unsafe { std::mem::transmute::<ScopedJob<'scope>, StaticJob>(job) };
                tx.send(Envelope { job, ack: ack_tx.clone() }).expect("pool workers alive");
                remaining += 1;
            }
        }
        // The caller's ack sender is dropped here so a worker panic (its
        // clone dropped unsent) disconnects the channel instead of hanging
        // the guard.
        DispatchGuard { rx: ack_rx, remaining, _scope: PhantomData }
    }

    /// Execute a batch of jobs across the pool: job 0 runs inline on the
    /// calling thread, jobs 1.. on the workers; returns once every job has
    /// finished. Worker panics propagate to the caller after all other
    /// jobs drain — never partial silence.
    pub fn run(&self, mut jobs: Vec<ScopedJob<'_>>) {
        if jobs.is_empty() {
            return;
        }
        let rest = jobs.split_off(1);
        let first = jobs.pop().expect("one job");
        let guard = self.dispatch(rest);
        first();
        guard.wait();
    }

    /// Split `data` — `rows` records of `width` elements each — into at
    /// most `parts` contiguous row ranges and run
    /// `kernel(first_row, range)` for each across the pool (range 0 inline
    /// on the caller). The partition depends only on `(rows, parts)`;
    /// because ranges are disjoint and each range is computed exactly as
    /// the serial kernel would compute those rows, output is bit-identical
    /// for every `parts`. `parts <= 1`, an empty pool, a nested call from
    /// a worker, or a single range all run serially inline.
    pub fn run_row_chunks<T: Send, F: Fn(usize, &mut [T]) + Sync>(
        &self,
        data: &mut [T],
        width: usize,
        parts: usize,
        kernel: F,
    ) {
        if data.is_empty() {
            return;
        }
        assert!(width > 0, "run_row_chunks on non-empty data needs width > 0");
        assert_eq!(data.len() % width, 0, "data must be whole rows");
        let rows = data.len() / width;
        // One source of truth for the split: the same pure partition the
        // cluster layer uses for row-shard assignment, so in-process and
        // sharded outputs land on identical range boundaries.
        let ranges = row_partition(rows, parts);
        if ranges.len() == 1 || self.workers.is_empty() || in_pool_worker() {
            kernel(0, data);
            return;
        }
        let chunk_rows = ranges[0].len();
        let mut chunks = data.chunks_mut(chunk_rows * width);
        let first = chunks.next().expect("rows > 0");
        let kernel = &kernel;
        let jobs: Vec<ScopedJob<'_>> = chunks
            .enumerate()
            .map(|(i, range)| {
                Box::new(move || kernel((i + 1) * chunk_rows, range)) as ScopedJob<'_>
            })
            .collect();
        let guard = self.dispatch(jobs);
        kernel(0, first);
        guard.wait();
    }
}

/// Ack collector for one dispatch. The lifetime-erased jobs shipped to the
/// workers are only valid while the caller's borrows live, so the guard
/// blocks until every outstanding job is finished — on the happy path via
/// [`wait`](DispatchGuard::wait), and on **any unwind** via `Drop`, which
/// keeps the "no job outlives the call" safety contract even when the call
/// does not return normally.
#[must_use = "the dispatch is only complete after wait()"]
pub(crate) struct DispatchGuard<'scope> {
    rx: Receiver<()>,
    remaining: usize,
    _scope: PhantomData<&'scope ()>,
}

impl DispatchGuard<'_> {
    /// Drain every ack; propagate worker panics instead of returning with
    /// partial output.
    pub(crate) fn wait(mut self) {
        while self.remaining > 0 {
            self.remaining -= 1;
            self.rx.recv().expect("pool worker panicked mid-job");
        }
    }
}

impl Drop for DispatchGuard<'_> {
    fn drop(&mut self) {
        // A `recv` error means every remaining ack sender is gone — all
        // outstanding jobs have completed (or were abandoned after their
        // own unwind), so no worker can still touch the caller's borrows.
        while self.remaining > 0 {
            self.remaining -= 1;
            if self.rx.recv().is_err() {
                break;
            }
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        // Disconnect the job channel first so idle workers' recv errors
        // out; then join them. Tolerate a poisoned lock — panicking in
        // Drop would abort.
        match self.tx.lock() {
            Ok(mut tx) => drop(tx.take()),
            Err(poisoned) => drop(poisoned.into_inner().take()),
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    /// `row_partition` boundary cases: more parts than rows, ragged
    /// division, zero rows, one row, exact division.
    #[test]
    fn row_partition_boundaries() {
        // parts > rows: clamps to one range per row.
        assert_eq!(row_partition(3, 64), vec![0..1, 1..2, 2..3]);
        // rows % parts != 0: ceil-width ranges, possibly fewer than parts.
        assert_eq!(row_partition(5, 4), vec![0..2, 2..4, 4..5]);
        assert_eq!(row_partition(61, 7), {
            let mut v = Vec::new();
            let mut lo = 0;
            while lo < 61 {
                v.push(lo..(lo + 9).min(61));
                lo += 9;
            }
            v
        });
        // Zero rows: no ranges at all (not one empty range).
        assert!(row_partition(0, 8).is_empty());
        // parts == 0 clamps to 1.
        assert_eq!(row_partition(4, 0), vec![0..4]);
        // Exact division.
        assert_eq!(row_partition(8, 4), vec![0..2, 2..4, 4..6, 6..8]);
        // Ranges always tile 0..rows in order, disjoint and complete.
        for rows in [1usize, 2, 5, 31, 64, 100] {
            for parts in [1usize, 2, 3, 7, 64, 1000] {
                let ranges = row_partition(rows, parts);
                assert!(ranges.len() <= parts.max(1));
                let mut next = 0;
                for r in &ranges {
                    assert_eq!(r.start, next, "rows={rows} parts={parts}");
                    assert!(r.end > r.start);
                    next = r.end;
                }
                assert_eq!(next, rows, "rows={rows} parts={parts}");
            }
        }
    }

    /// The partition must agree with what `run_row_chunks` actually does:
    /// each kernel invocation's (row0, len) is exactly one partition range.
    #[test]
    fn row_partition_matches_run_row_chunks() {
        let pool = Pool::new(4);
        for (rows, parts) in [(61usize, 7usize), (5, 4), (8, 4), (3, 64)] {
            let expected = row_partition(rows, parts);
            let seen = Mutex::new(Vec::new());
            let mut data = vec![0u8; rows * 2];
            pool.run_row_chunks(&mut data, 2, parts, |row0, chunk| {
                seen.lock().unwrap().push(row0..row0 + chunk.len() / 2);
            });
            let mut seen = seen.into_inner().unwrap();
            seen.sort_by_key(|r| r.start);
            assert_eq!(seen, expected, "rows={rows} parts={parts}");
        }
    }

    #[test]
    fn run_executes_every_job_exactly_once() {
        let pool = Pool::new(4);
        let hits = AtomicUsize::new(0);
        let jobs: Vec<ScopedJob<'_>> = (0..17)
            .map(|_| {
                Box::new(|| {
                    hits.fetch_add(1, Ordering::SeqCst);
                }) as ScopedJob<'_>
            })
            .collect();
        pool.run(jobs);
        assert_eq!(hits.load(Ordering::SeqCst), 17);
    }

    /// The determinism contract: identical bytes for thread counts
    /// {1, 2, 7, 64} on a ragged row count.
    #[test]
    fn row_chunks_bit_deterministic_across_thread_counts() {
        let width = 3;
        let rows = 61;
        let kernel = |row0: usize, chunk: &mut [f64]| {
            for (i, row) in chunk.chunks_mut(width).enumerate() {
                let r = (row0 + i) as f64;
                // Deliberately order-sensitive float math.
                row[0] = (r + 0.1).sin();
                row[1] = row[0] * 1.00001 + r;
                row[2] = row[1] / (r + 3.0);
            }
        };
        let mut want = vec![0.0f64; rows * width];
        kernel(0, &mut want);
        for threads in [1usize, 2, 7, 64] {
            let pool = Pool::new(threads);
            let mut got = vec![0.0f64; rows * width];
            pool.run_row_chunks(&mut got, width, threads, kernel);
            for (a, b) in want.iter().zip(&got) {
                assert_eq!(a.to_bits(), b.to_bits(), "threads={threads}");
            }
        }
    }

    /// More partitions than rows, a single row, and empty data all degrade
    /// gracefully.
    #[test]
    fn row_chunks_edge_cases() {
        let pool = Pool::new(3);
        let mut one = vec![0u32; 5];
        pool.run_row_chunks(&mut one, 5, 64, |row0, chunk| {
            assert_eq!(row0, 0);
            chunk.fill(7);
        });
        assert_eq!(one, vec![7; 5]);
        let mut empty: Vec<u32> = Vec::new();
        pool.run_row_chunks(&mut empty, 4, 8, |_, _| panic!("no rows"));
    }

    /// Nested dispatch from inside a pool job must inline, not deadlock:
    /// every job here re-enters the pool for its own row split.
    #[test]
    fn nested_dispatch_inlines_without_deadlock() {
        let pool = Pool::new(2); // one worker: trivially deadlocks if nested jobs queue
        let mut out = vec![0usize; 8 * 4];
        pool.run_row_chunks(&mut out, 4, 8, |row0, chunk| {
            // Worker-side nested call — must run inline on this thread.
            let inner = Pool::global();
            inner.run_row_chunks(chunk, 1, 64, |i0, cells| {
                for (i, c) in cells.iter_mut().enumerate() {
                    *c = row0 * 100 + i0 + i;
                }
            });
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, (i / 4) * 100 + i % 4);
        }
    }

    /// A panicking job propagates to the caller after the others drain —
    /// and the pool survives for the next call.
    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let pool = Pool::new(4);
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let jobs: Vec<ScopedJob<'_>> = (0..6)
                .map(|i| {
                    Box::new(move || {
                        if i == 3 {
                            panic!("job {i} exploded");
                        }
                    }) as ScopedJob<'_>
                })
                .collect();
            pool.run(jobs);
        }));
        assert!(caught.is_err(), "panic must propagate");
        // Pool still works.
        let hits = AtomicUsize::new(0);
        pool.run(
            (0..5)
                .map(|_| {
                    Box::new(|| {
                        hits.fetch_add(1, Ordering::SeqCst);
                    }) as ScopedJob<'_>
                })
                .collect(),
        );
        assert_eq!(hits.load(Ordering::SeqCst), 5);
    }

    /// dispatch + caller-side work: the caller can interleave its own
    /// processing while workers run.
    #[test]
    fn dispatch_then_wait_supports_caller_work() {
        let pool = Pool::new(3);
        let worker_sum = AtomicUsize::new(0);
        let jobs: Vec<ScopedJob<'_>> = (1..=10)
            .map(|i| {
                Box::new(move || {
                    worker_sum.fetch_add(i, Ordering::SeqCst);
                }) as ScopedJob<'_>
            })
            .collect();
        let guard = pool.dispatch(jobs);
        let caller_side = 100usize; // the caller's own share
        guard.wait();
        assert_eq!(worker_sum.load(Ordering::SeqCst) + caller_side, 155);
    }

    #[test]
    fn drop_shuts_down_cleanly() {
        let pool = Pool::new(5);
        let mut out = vec![0u8; 64];
        pool.run_row_chunks(&mut out, 1, 5, |_, c| c.fill(1));
        drop(pool); // must not deadlock
    }

    #[test]
    fn global_and_serial_pools_are_usable() {
        assert!(Pool::global().threads() >= 1);
        assert_eq!(Pool::serial().threads(), 1);
        assert_eq!(Pool::for_threads(1).threads(), 1);
        let mut out = vec![0u16; 9];
        Pool::global().run_row_chunks(&mut out, 1, 4, |r0, c| {
            for (i, v) in c.iter_mut().enumerate() {
                *v = (r0 + i) as u16;
            }
        });
        assert_eq!(out[8], 8);
    }
}
