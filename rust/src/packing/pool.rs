//! `SignPool` — the sign-kernel client of the shared worker pool.
//!
//! PR 2 built the persistent pool (workers, acks, panic propagation)
//! privately in this module; PR 4 promoted that machinery to
//! [`crate::parallel::Pool`] so the offline compression pipeline can share
//! the same resident threads. `SignPool` is now a thin client: it keeps
//! the sign-GEMM/GEMV-specific contract (input scale applied **once per
//! call** into a reused thread-local block before rows are partitioned,
//! output scale folded into each row's lane reduction) and delegates the
//! partitioned execution to [`Pool::run_row_chunks`].
//!
//! **Determinism / bit-exactness.** A job is a row range of the exact
//! serial kernel ([`gemm_sign_out_rows`] and its GEMV twin); row
//! partitioning never changes any per-element reduction order, and ranges
//! are disjoint, so the assembled output is bit-identical to the serial
//! kernel **regardless of thread count, pool size, or which worker runs
//! which range** — asserted across thread counts {1, 2, 7, 64} by the
//! tests below. The safety story (jobs never outlive the caller's
//! borrows, worker panics propagate, unwinds block on outstanding jobs)
//! lives with the pool — see `parallel`'s module docs.

use super::gemm::{gemm_sign_out_rows, with_scaled_block};
use super::gemv::{gemv_sign_out_rows, with_scaled_vec};
use super::BitMatrix;
use crate::linalg::Mat;
use crate::parallel::Pool;
use std::sync::OnceLock;

/// Owned or process-shared backing pool — lets `SignPool::global()` reuse
/// [`Pool::global`]'s workers instead of spawning a second resident set.
enum PoolRef {
    Owned(Pool),
    Shared(&'static Pool),
}

impl PoolRef {
    #[inline]
    fn get(&self) -> &Pool {
        match self {
            PoolRef::Owned(p) => p,
            PoolRef::Shared(p) => *p,
        }
    }
}

/// Row-parallel dispatcher for the sign kernels, backed by a persistent
/// [`Pool`].
///
/// `SignPool::new(threads)` owns a private pool targeting `threads` total
/// parallelism (the dispatching caller always executes the first row range
/// itself, so a 1-thread pool is purely serial and spawns nothing).
/// [`SignPool::global`] shares the process-wide [`Pool::global`] workers
/// with the pooled linalg kernels and the compression scheduler.
///
/// # Examples
///
/// ```
/// use littlebit2::linalg::Mat;
/// use littlebit2::packing::{gemm_sign_scaled, BitMatrix, SignPool};
///
/// let pool = SignPool::new(4);
/// let s = BitMatrix::ones(3, 2);
/// let x = Mat::from_vec(2, 1, vec![1.0, 2.0]);
/// let mut pooled = Mat::zeros(3, 1);
/// pool.gemm_sign_scaled(&s, Some(&[2.0, 2.0]), &x, None, &mut pooled);
/// // Bit-identical to the serial fused kernel.
/// let mut serial = Mat::zeros(3, 1);
/// gemm_sign_scaled(&s, Some(&[2.0, 2.0]), &x, None, &mut serial);
/// assert_eq!(pooled, serial);
/// ```
pub struct SignPool {
    pool: PoolRef,
}

impl SignPool {
    /// Build a client over a private pool targeting `threads` total
    /// parallelism (clamped to ≥ 1).
    pub fn new(threads: usize) -> Self {
        Self { pool: PoolRef::Owned(Pool::new(threads)) }
    }

    /// The process-wide instance, sharing [`Pool::global`]'s workers —
    /// used by `gemm_sign_mt`, `gemv_sign_mt`, and every batched
    /// `forward_batch_mt`/`_into` path.
    pub fn global() -> &'static SignPool {
        static POOL: OnceLock<SignPool> = OnceLock::new();
        POOL.get_or_init(|| SignPool { pool: PoolRef::Shared(Pool::global()) })
    }

    /// A zero-worker client: every call runs serially on the calling
    /// thread, and [`global`](Self::global)'s resident workers are never
    /// instantiated as a side effect of a purely serial call.
    pub fn serial() -> &'static SignPool {
        static SERIAL: OnceLock<SignPool> = OnceLock::new();
        SERIAL.get_or_init(|| SignPool { pool: PoolRef::Shared(Pool::serial()) })
    }

    /// Pool selection for a `threads` knob: the shared
    /// [`global`](Self::global) pool when actual parallelism is requested,
    /// the spawn-free [`serial`](Self::serial) pool otherwise.
    pub fn for_threads(threads: usize) -> &'static SignPool {
        if threads > 1 {
            Self::global()
        } else {
            Self::serial()
        }
    }

    /// Total parallelism this pool targets (workers + dispatching caller).
    pub fn threads(&self) -> usize {
        self.pool.get().threads()
    }

    /// The backing [`Pool`] — for dense kernels (blocked matmul) that run
    /// alongside the sign kernels in a method-generic serving chain, so
    /// every layer variant shares one resident worker set.
    pub fn backing(&self) -> &Pool {
        self.pool.get()
    }

    /// Pool-dispatched [`gemm_sign_scaled`](super::gemm_sign_scaled),
    /// partitioned into [`threads`](Self::threads) row ranges. Bit-exact
    /// against the serial kernel for any pool size.
    pub fn gemm_sign_scaled(
        &self,
        s: &BitMatrix,
        in_scale: Option<&[f32]>,
        x: &Mat,
        out_scale: Option<&[f32]>,
        y: &mut Mat,
    ) {
        self.run_gemm(s, in_scale, x, out_scale, y, self.threads());
    }

    /// Pool-dispatched [`gemm_sign`](super::gemm_sign) (no scales).
    pub fn gemm_sign(&self, s: &BitMatrix, x: &Mat, y: &mut Mat) {
        self.run_gemm(s, None, x, None, y, self.threads());
    }

    /// Partition `S X` (with optional fused scales) into `parts` contiguous
    /// row ranges and execute them across the pool. The input scale is
    /// applied ONCE per call — never once per job — into the reused
    /// thread-local block; every row range (workers and the caller's
    /// inline range alike) then reads it like it would read `x`. The
    /// partition depends only on (`rows`, `parts`), so output is bit-exact
    /// against the serial kernel for every `parts`.
    ///
    /// `y` is partitioned over its **padded** backing at its row stride —
    /// jobs land on aligned row starts and never write the padding tail.
    pub(crate) fn run_gemm(
        &self,
        s: &BitMatrix,
        in_scale: Option<&[f32]>,
        x: &Mat,
        out_scale: Option<&[f32]>,
        y: &mut Mat,
        parts: usize,
    ) {
        let rows = s.rows();
        let b = x.cols();
        assert_eq!(s.cols(), x.rows(), "inner dims: S is m×n, X is n×b");
        assert_eq!(y.rows(), rows, "output rows");
        assert_eq!(y.cols(), b, "batch width");
        if let Some(g) = in_scale {
            assert_eq!(g.len(), s.cols(), "in_scale length");
        }
        if let Some(h) = out_scale {
            assert_eq!(h.len(), s.rows(), "out_scale length");
        }
        if rows == 0 || b == 0 {
            return;
        }
        let stride = y.stride();
        let ys = y.padded_mut();
        let run = |xs: &Mat| {
            self.pool.get().run_row_chunks(ys, stride, parts, |row0, range| {
                gemm_sign_out_rows(s, xs, out_scale, range, stride, row0);
            });
        };
        match in_scale {
            Some(g) => with_scaled_block(x, g, run),
            None => run(x),
        }
    }

    /// GEMV twin of [`run_gemm`](Self::run_gemm): `ys` is a plain vector
    /// split into `parts` contiguous ranges.
    pub(crate) fn run_gemv(
        &self,
        s: &BitMatrix,
        in_scale: Option<&[f32]>,
        x: &[f32],
        out_scale: Option<&[f32]>,
        ys: &mut [f32],
        parts: usize,
    ) {
        let rows = s.rows();
        assert_eq!(s.cols(), x.len(), "inner dims");
        assert_eq!(ys.len(), rows, "output length");
        if let Some(g) = in_scale {
            assert_eq!(g.len(), s.cols(), "in_scale length");
        }
        if let Some(h) = out_scale {
            assert_eq!(h.len(), s.rows(), "out_scale length");
        }
        if rows == 0 {
            return;
        }
        let run = |xs: &[f32]| {
            self.pool.get().run_row_chunks(ys, 1, parts, |row0, range| {
                gemv_sign_out_rows(s, xs, out_scale, range, row0);
            });
        };
        match in_scale {
            Some(g) => with_scaled_vec(x, g, run),
            None => run(x),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::packing::{gemm_sign, gemm_sign_scaled, gemv_sign};
    use crate::rng::Pcg64;

    fn random_setup(
        m: usize,
        n: usize,
        b: usize,
        seed: u64,
    ) -> (BitMatrix, Mat, Vec<f32>, Vec<f32>) {
        let mut rng = Pcg64::seed(seed);
        let s = BitMatrix::from_dense(&Mat::gaussian(m, n, &mut rng).signum());
        let mut x = Mat::zeros(n, b);
        x.fill_normal(&mut rng);
        let mut g = vec![0.0f32; n];
        let mut h = vec![0.0f32; m];
        rng.fill_uniform(&mut g, 0.2, 1.8);
        rng.fill_uniform(&mut h, 0.2, 1.8);
        (s, x, g, h)
    }

    /// The pool determinism contract of the issue: identical bits for
    /// thread counts {1, 2, 7, 64}, on ragged shapes whose columns span
    /// multiple words plus a tail, for plain and fused kernels alike.
    #[test]
    fn pool_is_bit_deterministic_across_thread_counts() {
        for (m, n, b) in [(61, 130, 12), (7, 200, 9), (33, 63, 1), (16, 191, 5)] {
            let (s, x, g, h) = random_setup(m, n, b, 61);
            let mut serial_plain = Mat::zeros(m, b);
            gemm_sign(&s, &x, &mut serial_plain);
            let mut serial_fused = Mat::zeros(m, b);
            gemm_sign_scaled(&s, Some(&g), &x, Some(&h), &mut serial_fused);
            for threads in [1usize, 2, 7, 64] {
                let pool = SignPool::new(threads);
                let mut plain = Mat::zeros(m, b);
                pool.gemm_sign(&s, &x, &mut plain);
                assert_eq!(serial_plain, plain, "plain {m}x{n} b={b} threads={threads}");
                let mut fused = Mat::zeros(m, b);
                pool.gemm_sign_scaled(&s, Some(&g), &x, Some(&h), &mut fused);
                assert_eq!(serial_fused, fused, "fused {m}x{n} b={b} threads={threads}");
            }
        }
    }

    /// More partitions than rows, more partitions than workers, and a
    /// single-row matrix all degrade gracefully.
    #[test]
    fn partition_edge_cases() {
        let (s, x, g, h) = random_setup(3, 70, 4, 62);
        let mut serial = Mat::zeros(3, 4);
        gemm_sign_scaled(&s, Some(&g), &x, Some(&h), &mut serial);
        let pool = SignPool::new(2);
        for parts in [1usize, 3, 64] {
            let mut y = Mat::zeros(3, 4);
            pool.run_gemm(&s, Some(&g), &x, Some(&h), &mut y, parts);
            assert_eq!(serial, y, "parts={parts}");
        }
        let (s1, x1, _, _) = random_setup(1, 70, 2, 63);
        let mut serial1 = Mat::zeros(1, 2);
        gemm_sign(&s1, &x1, &mut serial1);
        let mut y1 = Mat::zeros(1, 2);
        pool.gemm_sign(&s1, &x1, &mut y1);
        assert_eq!(serial1, y1);
    }

    /// The pooled GEMV path matches the serial GEMV bit-for-bit, scaled and
    /// plain.
    #[test]
    fn pooled_gemv_matches_serial() {
        let (s, xm, g, h) = random_setup(77, 190, 1, 64);
        let x = xm.col(0);
        let mut serial = vec![0.0f32; 77];
        gemv_sign(&s, &x, &mut serial);
        let pool = SignPool::new(3);
        let mut y = vec![0.0f32; 77];
        pool.run_gemv(&s, None, &x, None, &mut y, 3);
        for (a, c) in serial.iter().zip(&y) {
            assert_eq!(a.to_bits(), c.to_bits());
        }
        // Scaled: against the unfused composition.
        let xg: Vec<f32> = x.iter().zip(&g).map(|(a, b)| a * b).collect();
        let mut want = vec![0.0f32; 77];
        gemv_sign(&s, &xg, &mut want);
        for (w, &hi) in want.iter_mut().zip(&h) {
            *w *= hi;
        }
        let mut got = vec![0.0f32; 77];
        pool.run_gemv(&s, Some(&g), &x, Some(&h), &mut got, 7);
        for (a, c) in want.iter().zip(&got) {
            assert_eq!(a.to_bits(), c.to_bits());
        }
    }

    /// Concurrent dispatch from several caller threads onto ONE shared pool
    /// must keep every caller's result bit-exact (jobs interleave in the
    /// queue; acks are per-call).
    #[test]
    fn concurrent_callers_share_the_pool() {
        let pool = SignPool::new(4);
        let (s, x, g, h) = random_setup(48, 96, 6, 65);
        let mut serial = Mat::zeros(48, 6);
        gemm_sign_scaled(&s, Some(&g), &x, Some(&h), &mut serial);
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(|| {
                    for _ in 0..8 {
                        let mut y = Mat::zeros(48, 6);
                        pool.gemm_sign_scaled(&s, Some(&g), &x, Some(&h), &mut y);
                        assert_eq!(serial, y);
                    }
                });
            }
        });
    }

    /// Dropping a pool joins its workers without hanging.
    #[test]
    fn drop_shuts_down_cleanly() {
        let pool = SignPool::new(5);
        let (s, x, _, _) = random_setup(8, 64, 2, 66);
        let mut y = Mat::zeros(8, 2);
        pool.gemm_sign(&s, &x, &mut y);
        drop(pool); // must not deadlock
    }

    /// The global pool exists, reports at least one thread, and shares the
    /// process-wide `parallel::Pool` workers.
    #[test]
    fn global_pool_is_usable() {
        let pool = SignPool::global();
        assert!(pool.threads() >= 1);
        assert_eq!(pool.threads(), Pool::global().threads());
        let (s, x, _, _) = random_setup(5, 30, 3, 67);
        let mut serial = Mat::zeros(5, 3);
        gemm_sign(&s, &x, &mut serial);
        let mut y = Mat::zeros(5, 3);
        pool.gemm_sign(&s, &x, &mut y);
        assert_eq!(serial, y);
    }
}
