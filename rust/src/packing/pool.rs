//! `SignPool` — the persistent worker pool behind every row-parallel sign
//! kernel.
//!
//! PR 1's `*_mt` kernels spawned fresh OS threads on **every call**
//! (`std::thread::scope`), which at serving batch sizes costs more than the
//! sign-GEMM itself for small row ranges. The pool spawns its threads once;
//! each call partitions the output rows into deterministic contiguous
//! ranges, ships all but the first range to the workers as jobs over an
//! MPSC channel, computes the first range on the calling thread, and blocks
//! on per-job acknowledgements. Dispatch cost is a few channel sends
//! instead of thread creations.
//!
//! **Determinism / bit-exactness.** A job is a row range of the exact
//! serial kernel ([`gemm_sign_out_rows`] and its GEMV twin; any input
//! scale is applied once per call *before* partitioning, so jobs share the
//! identical scaled activations). Row partitioning never changes any
//! per-element reduction order, and ranges are disjoint, so the assembled
//! output is bit-identical to the serial kernel **regardless of thread
//! count, pool size, or which worker runs which range** — asserted across
//! thread counts {1, 2, 7, 64} by the tests below.
//!
//! **Safety model.** Jobs carry raw pointers into the caller's operands
//! (weights, activations, disjoint output sub-slices). The dispatching call
//! does not release the operands' borrows until every job has
//! acknowledged: on the happy path it blocks on one ack per job, and on an
//! unwind (a panic in the caller's inline range, or a propagated worker
//! panic) the [`AckGuard`] drop blocks until all outstanding jobs finish
//! before the unwind continues — so job pointers never dangle. If a worker
//! panics mid-job (impossible for valid shapes — the public entries
//! validate first), the job's ack sender is dropped unsent; the caller
//! then observes a disconnected ack channel after all other jobs drained
//! and panics itself rather than returning a partially-written output.
//!
//! Workers block on the shared job channel when idle — zero CPU between
//! calls — and exit when the pool is dropped. Concurrent dispatch from
//! multiple threads (e.g. several server workers sharing
//! [`SignPool::global`]) is supported: jobs interleave in the queue and
//! each caller waits only on its own acks.

use super::gemm::{gemm_sign_out_rows, with_scaled_block};
use super::gemv::{gemv_sign_out_rows, with_scaled_vec};
use super::BitMatrix;
use crate::linalg::Mat;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, OnceLock};
use std::thread::JoinHandle;

/// `*const T` that may cross threads. Safety: the pointee is `Sync`, lives
/// on the dispatching caller's stack, and the caller blocks until every job
/// acknowledges — see the module-level safety model.
struct SendConst<T: ?Sized>(*const T);
unsafe impl<T: ?Sized + Sync> Send for SendConst<T> {}

/// `*mut T` that may cross threads. Safety: each job's pointer targets a
/// disjoint output sub-slice (no aliasing) under the same lifetime
/// guarantee as [`SendConst`].
struct SendMutPtr<T: ?Sized>(*mut T);
unsafe impl<T: ?Sized + Send> Send for SendMutPtr<T> {}

/// One row-range kernel execution. Jobs always see **post-input-scale**
/// activations: the dispatching caller applies `in_scale` once per call
/// (into a reused thread-local block shared read-only by every job), so
/// scale work never multiplies with the partition count.
enum Task {
    Gemm {
        s: SendConst<BitMatrix>,
        x: SendConst<Mat>,
        out_scale: Option<SendConst<[f32]>>,
        ys: SendMutPtr<[f32]>,
        row0: usize,
    },
    Gemv {
        s: SendConst<BitMatrix>,
        x: SendConst<[f32]>,
        out_scale: Option<SendConst<[f32]>>,
        ys: SendMutPtr<[f32]>,
        row0: usize,
    },
}

struct Job {
    task: Task,
    /// Dropped unsent on panic — the caller turns that into its own panic.
    ack: Sender<()>,
}

/// Execute one task: the shared row-range loop with the output scale (if
/// any) folded into the lane reduction.
///
/// # Safety
/// Every pointer in `task` must be live and (for `ys`) unaliased for the
/// duration of the call — guaranteed by the dispatch protocol (the caller
/// blocks on acks before its borrows end).
unsafe fn run_task(task: &Task) {
    match task {
        Task::Gemm { s, x, out_scale, ys, row0 } => {
            let s = unsafe { &*s.0 };
            let x = unsafe { &*x.0 };
            let ys = unsafe { &mut *ys.0 };
            let outs = out_scale.as_ref().map(|p| unsafe { &*p.0 });
            gemm_sign_out_rows(s, x, outs, ys, *row0);
        }
        Task::Gemv { s, x, out_scale, ys, row0 } => {
            let s = unsafe { &*s.0 };
            let x = unsafe { &*x.0 };
            let ys = unsafe { &mut *ys.0 };
            let outs = out_scale.as_ref().map(|p| unsafe { &*p.0 });
            gemv_sign_out_rows(s, x, outs, ys, *row0);
        }
    }
}

fn worker_loop(rx: &Mutex<Receiver<Job>>) {
    loop {
        // Hold the lock only to pop one job so co-workers drain in parallel.
        let job = {
            let rx = rx.lock().expect("sign-pool rx lock");
            match rx.recv() {
                Ok(j) => j,
                Err(_) => return, // pool dropped: shut down
            }
        };
        // catch_unwind keeps the worker alive if a kernel panics; the ack
        // is only sent on success, so the caller never mistakes a
        // partially-written range for a completed one.
        let ok = catch_unwind(AssertUnwindSafe(|| unsafe { run_task(&job.task) })).is_ok();
        if ok {
            let _ = job.ack.send(());
        }
    }
}

/// Persistent worker pool for the row-parallel sign kernels.
///
/// `SignPool::new(threads)` targets `threads` total parallelism: it spawns
/// `threads − 1` long-lived workers and the dispatching caller always
/// executes the first row range itself (so a 1-thread pool is purely
/// serial and spawns nothing). [`SignPool::global`] is the process-wide
/// instance sized to `available_parallelism`, shared by `gemm_sign_mt`,
/// `gemv_sign_mt`, and every batched `forward_batch_mt`/`_into` path.
///
/// # Examples
///
/// ```
/// use littlebit2::linalg::Mat;
/// use littlebit2::packing::{gemm_sign_scaled, BitMatrix, SignPool};
///
/// let pool = SignPool::new(4);
/// let s = BitMatrix::ones(3, 2);
/// let x = Mat::from_vec(2, 1, vec![1.0, 2.0]);
/// let mut pooled = Mat::zeros(3, 1);
/// pool.gemm_sign_scaled(&s, Some(&[2.0, 2.0]), &x, None, &mut pooled);
/// // Bit-identical to the serial fused kernel.
/// let mut serial = Mat::zeros(3, 1);
/// gemm_sign_scaled(&s, Some(&[2.0, 2.0]), &x, None, &mut serial);
/// assert_eq!(pooled, serial);
/// ```
pub struct SignPool {
    tx: Mutex<Option<Sender<Job>>>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
}

impl SignPool {
    /// Build a pool targeting `threads` total parallelism (clamped to ≥ 1):
    /// `threads − 1` worker threads plus the calling thread per dispatch.
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads - 1)
            .map(|_| {
                let rx = Arc::clone(&rx);
                std::thread::spawn(move || worker_loop(&rx))
            })
            .collect();
        Self { tx: Mutex::new(Some(tx)), workers, threads }
    }

    /// The process-wide pool, created on first use and sized to
    /// `std::thread::available_parallelism`. Never torn down (workers are
    /// idle blocked between calls and die with the process).
    pub fn global() -> &'static SignPool {
        static POOL: OnceLock<SignPool> = OnceLock::new();
        POOL.get_or_init(|| {
            let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            SignPool::new(n)
        })
    }

    /// A zero-worker pool: every call runs serially on the calling thread.
    /// Exists so serial convenience wrappers (`forward_batch`,
    /// `*_mt(.., 1)`) never instantiate [`global`](Self::global) — and its
    /// `available_parallelism − 1` resident worker threads — as a side
    /// effect of a purely serial call.
    pub fn serial() -> &'static SignPool {
        static SERIAL: OnceLock<SignPool> = OnceLock::new();
        SERIAL.get_or_init(|| SignPool::new(1))
    }

    /// Pool selection for a `threads` knob: the shared
    /// [`global`](Self::global) pool when actual parallelism is requested,
    /// the spawn-free [`serial`](Self::serial) pool otherwise.
    pub fn for_threads(threads: usize) -> &'static SignPool {
        if threads > 1 {
            Self::global()
        } else {
            Self::serial()
        }
    }

    /// Total parallelism this pool targets (workers + dispatching caller).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Pool-dispatched [`gemm_sign_scaled`](super::gemm_sign_scaled),
    /// partitioned into [`threads`](Self::threads) row ranges. Bit-exact
    /// against the serial kernel for any pool size.
    pub fn gemm_sign_scaled(
        &self,
        s: &BitMatrix,
        in_scale: Option<&[f32]>,
        x: &Mat,
        out_scale: Option<&[f32]>,
        y: &mut Mat,
    ) {
        assert_eq!(s.rows(), y.rows(), "output rows");
        assert_eq!(x.cols(), y.cols(), "batch width");
        self.run_gemm(s, in_scale, x, out_scale, y.as_mut_slice(), self.threads);
    }

    /// Pool-dispatched [`gemm_sign`](super::gemm_sign) (no scales).
    pub fn gemm_sign(&self, s: &BitMatrix, x: &Mat, y: &mut Mat) {
        assert_eq!(s.rows(), y.rows(), "output rows");
        assert_eq!(x.cols(), y.cols(), "batch width");
        self.run_gemm(s, None, x, None, y.as_mut_slice(), self.threads);
    }

    /// Partition `S X` (with optional fused scales) into `parts` contiguous
    /// row ranges and execute them across the pool: ranges 1.. go to the
    /// workers, range 0 runs on the calling thread, then the call blocks
    /// until every worker range acknowledges. `parts <= 1`, an empty pool,
    /// or a single row range all run serially inline. The partition depends
    /// only on (`rows`, `parts`) — never on pool occupancy — and row ranges
    /// cannot change per-element reduction order, so output is bit-exact
    /// against the serial kernel for every `parts`.
    pub(crate) fn run_gemm(
        &self,
        s: &BitMatrix,
        in_scale: Option<&[f32]>,
        x: &Mat,
        out_scale: Option<&[f32]>,
        ys: &mut [f32],
        parts: usize,
    ) {
        let rows = s.rows();
        let b = x.cols();
        assert_eq!(s.cols(), x.rows(), "inner dims: S is m×n, X is n×b");
        assert_eq!(ys.len(), rows * b, "output block size");
        if let Some(g) = in_scale {
            assert_eq!(g.len(), s.cols(), "in_scale length");
        }
        if let Some(h) = out_scale {
            assert_eq!(h.len(), s.rows(), "out_scale length");
        }
        if rows == 0 || b == 0 {
            return;
        }
        // Apply the input scale ONCE per call — never once per job — into
        // the reused thread-local block; every row range (workers and the
        // caller's inline range alike) then reads it like it would read x.
        match in_scale {
            Some(g) => {
                with_scaled_block(x, g, |xg| self.run_gemm_ranges(s, xg, out_scale, ys, parts))
            }
            None => self.run_gemm_ranges(s, x, out_scale, ys, parts),
        }
    }

    /// Partitioned execution over post-input-scale activations.
    fn run_gemm_ranges(
        &self,
        s: &BitMatrix,
        x: &Mat,
        out_scale: Option<&[f32]>,
        ys: &mut [f32],
        parts: usize,
    ) {
        let rows = s.rows();
        let b = x.cols();
        let parts = parts.clamp(1, rows);
        if parts == 1 || self.workers.is_empty() {
            gemm_sign_out_rows(s, x, out_scale, ys, 0);
            return;
        }
        let chunk = rows.div_ceil(parts);
        let mut ranges = ys.chunks_mut(chunk * b);
        let first = ranges.next().expect("rows > 0");
        let acks = self.dispatch(ranges, |ys_range, ti| Task::Gemm {
            s: SendConst(s),
            x: SendConst(x),
            out_scale: out_scale.map(|v| SendConst(v as *const [f32])),
            ys: SendMutPtr(ys_range),
            row0: (ti + 1) * chunk,
        });
        gemm_sign_out_rows(s, x, out_scale, first, 0);
        acks.wait();
    }

    /// GEMV twin of [`run_gemm`](Self::run_gemm): `ys` is a plain vector
    /// split into `parts` contiguous ranges.
    pub(crate) fn run_gemv(
        &self,
        s: &BitMatrix,
        in_scale: Option<&[f32]>,
        x: &[f32],
        out_scale: Option<&[f32]>,
        ys: &mut [f32],
        parts: usize,
    ) {
        let rows = s.rows();
        assert_eq!(s.cols(), x.len(), "inner dims");
        assert_eq!(ys.len(), rows, "output length");
        if let Some(g) = in_scale {
            assert_eq!(g.len(), s.cols(), "in_scale length");
        }
        if let Some(h) = out_scale {
            assert_eq!(h.len(), s.rows(), "out_scale length");
        }
        if rows == 0 {
            return;
        }
        // Same hoist as run_gemm: the input scale is applied once per
        // call, never once per job.
        match in_scale {
            Some(g) => {
                with_scaled_vec(x, g, |xs| self.run_gemv_ranges(s, xs, out_scale, ys, parts))
            }
            None => self.run_gemv_ranges(s, x, out_scale, ys, parts),
        }
    }

    /// Partitioned execution over post-input-scale activations.
    fn run_gemv_ranges(
        &self,
        s: &BitMatrix,
        x: &[f32],
        out_scale: Option<&[f32]>,
        ys: &mut [f32],
        parts: usize,
    ) {
        let rows = s.rows();
        let parts = parts.clamp(1, rows);
        if parts == 1 || self.workers.is_empty() {
            gemv_sign_out_rows(s, x, out_scale, ys, 0);
            return;
        }
        let chunk = rows.div_ceil(parts);
        let mut ranges = ys.chunks_mut(chunk);
        let first = ranges.next().expect("rows > 0");
        let acks = self.dispatch(ranges, |ys_range, ti| Task::Gemv {
            s: SendConst(s),
            x: SendConst(x as *const [f32]),
            out_scale: out_scale.map(|v| SendConst(v as *const [f32])),
            ys: SendMutPtr(ys_range),
            row0: (ti + 1) * chunk,
        });
        gemv_sign_out_rows(s, x, out_scale, first, 0);
        acks.wait();
    }

    /// Ship one job per remaining range; returns the guard that must
    /// collect every acknowledgement before the operands' borrows end.
    fn dispatch<'a>(
        &self,
        ranges: impl Iterator<Item = &'a mut [f32]>,
        mut make_task: impl FnMut(*mut [f32], usize) -> Task,
    ) -> AckGuard {
        let (ack_tx, ack_rx) = channel::<()>();
        let mut remaining = 0usize;
        {
            let tx = self.tx.lock().expect("sign-pool tx lock");
            let tx = tx.as_ref().expect("sign-pool not shut down");
            for (ti, ys_range) in ranges.enumerate() {
                let job = Job {
                    task: make_task(ys_range as *mut [f32], ti),
                    ack: ack_tx.clone(),
                };
                tx.send(job).expect("sign-pool workers alive");
                remaining += 1;
            }
        }
        // Drop the caller's ack sender so a worker panic (its clone dropped
        // unsent) disconnects the channel instead of hanging the guard.
        drop(ack_tx);
        AckGuard { rx: ack_rx, remaining }
    }
}

/// Ack collector for one dispatch. The raw pointers shipped to the workers
/// are only valid while the caller's borrows live, so the guard blocks
/// until every outstanding job is finished — on the happy path via
/// [`wait`](AckGuard::wait), and on **any unwind** (a caller-side panic in
/// the inline range, or a propagated worker panic) via `Drop`, which keeps
/// the "no job outlives the call" safety contract even when the call does
/// not return normally.
struct AckGuard {
    rx: Receiver<()>,
    remaining: usize,
}

impl AckGuard {
    /// Drain every ack; propagate worker panics instead of returning
    /// partial output.
    fn wait(mut self) {
        while self.remaining > 0 {
            self.remaining -= 1;
            self.rx.recv().expect("sign-pool worker panicked mid-job");
        }
    }
}

impl Drop for AckGuard {
    fn drop(&mut self) {
        // A `recv` error means every remaining ack sender is gone — all
        // outstanding jobs have completed (or were abandoned after their
        // own unwind), so no worker can still touch the caller's buffers.
        while self.remaining > 0 {
            self.remaining -= 1;
            if self.rx.recv().is_err() {
                break;
            }
        }
    }
}

impl Drop for SignPool {
    fn drop(&mut self) {
        // Disconnect the job channel first so idle workers' recv errors
        // out; then join them (same shutdown shape as InferenceServer).
        // Tolerate a poisoned lock — panicking in Drop would abort.
        match self.tx.lock() {
            Ok(mut tx) => drop(tx.take()),
            Err(poisoned) => drop(poisoned.into_inner().take()),
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::packing::{gemm_sign, gemm_sign_scaled, gemv_sign};
    use crate::rng::Pcg64;

    fn random_setup(
        m: usize,
        n: usize,
        b: usize,
        seed: u64,
    ) -> (BitMatrix, Mat, Vec<f32>, Vec<f32>) {
        let mut rng = Pcg64::seed(seed);
        let s = BitMatrix::from_dense(&Mat::gaussian(m, n, &mut rng).signum());
        let mut x = Mat::zeros(n, b);
        rng.fill_normal(x.as_mut_slice());
        let mut g = vec![0.0f32; n];
        let mut h = vec![0.0f32; m];
        rng.fill_uniform(&mut g, 0.2, 1.8);
        rng.fill_uniform(&mut h, 0.2, 1.8);
        (s, x, g, h)
    }

    /// The pool determinism contract of the issue: identical bits for
    /// thread counts {1, 2, 7, 64}, on ragged shapes whose columns span
    /// multiple words plus a tail, for plain and fused kernels alike.
    #[test]
    fn pool_is_bit_deterministic_across_thread_counts() {
        for (m, n, b) in [(61, 130, 12), (7, 200, 9), (33, 63, 1), (16, 191, 5)] {
            let (s, x, g, h) = random_setup(m, n, b, 61);
            let mut serial_plain = Mat::zeros(m, b);
            gemm_sign(&s, &x, &mut serial_plain);
            let mut serial_fused = Mat::zeros(m, b);
            gemm_sign_scaled(&s, Some(&g), &x, Some(&h), &mut serial_fused);
            for threads in [1usize, 2, 7, 64] {
                let pool = SignPool::new(threads);
                let mut plain = Mat::zeros(m, b);
                pool.gemm_sign(&s, &x, &mut plain);
                assert_eq!(serial_plain, plain, "plain {m}x{n} b={b} threads={threads}");
                let mut fused = Mat::zeros(m, b);
                pool.gemm_sign_scaled(&s, Some(&g), &x, Some(&h), &mut fused);
                assert_eq!(serial_fused, fused, "fused {m}x{n} b={b} threads={threads}");
            }
        }
    }

    /// More partitions than rows, more partitions than workers, and a
    /// single-row matrix all degrade gracefully.
    #[test]
    fn partition_edge_cases() {
        let (s, x, g, h) = random_setup(3, 70, 4, 62);
        let mut serial = Mat::zeros(3, 4);
        gemm_sign_scaled(&s, Some(&g), &x, Some(&h), &mut serial);
        let pool = SignPool::new(2);
        for parts in [1usize, 3, 64] {
            let mut y = Mat::zeros(3, 4);
            pool.run_gemm(&s, Some(&g), &x, Some(&h), y.as_mut_slice(), parts);
            assert_eq!(serial, y, "parts={parts}");
        }
        let (s1, x1, _, _) = random_setup(1, 70, 2, 63);
        let mut serial1 = Mat::zeros(1, 2);
        gemm_sign(&s1, &x1, &mut serial1);
        let mut y1 = Mat::zeros(1, 2);
        pool.gemm_sign(&s1, &x1, &mut y1);
        assert_eq!(serial1, y1);
    }

    /// The pooled GEMV path matches the serial GEMV bit-for-bit, scaled and
    /// plain.
    #[test]
    fn pooled_gemv_matches_serial() {
        let (s, xm, g, h) = random_setup(77, 190, 1, 64);
        let x = xm.col(0);
        let mut serial = vec![0.0f32; 77];
        gemv_sign(&s, &x, &mut serial);
        let pool = SignPool::new(3);
        let mut y = vec![0.0f32; 77];
        pool.run_gemv(&s, None, &x, None, &mut y, 3);
        for (a, c) in serial.iter().zip(&y) {
            assert_eq!(a.to_bits(), c.to_bits());
        }
        // Scaled: against the unfused composition.
        let xg: Vec<f32> = x.iter().zip(&g).map(|(a, b)| a * b).collect();
        let mut want = vec![0.0f32; 77];
        gemv_sign(&s, &xg, &mut want);
        for (w, &hi) in want.iter_mut().zip(&h) {
            *w *= hi;
        }
        let mut got = vec![0.0f32; 77];
        pool.run_gemv(&s, Some(&g), &x, Some(&h), &mut got, 7);
        for (a, c) in want.iter().zip(&got) {
            assert_eq!(a.to_bits(), c.to_bits());
        }
    }

    /// Concurrent dispatch from several caller threads onto ONE shared pool
    /// must keep every caller's result bit-exact (jobs interleave in the
    /// queue; acks are per-call).
    #[test]
    fn concurrent_callers_share_the_pool() {
        let pool = SignPool::new(4);
        let (s, x, g, h) = random_setup(48, 96, 6, 65);
        let mut serial = Mat::zeros(48, 6);
        gemm_sign_scaled(&s, Some(&g), &x, Some(&h), &mut serial);
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(|| {
                    for _ in 0..8 {
                        let mut y = Mat::zeros(48, 6);
                        pool.gemm_sign_scaled(&s, Some(&g), &x, Some(&h), &mut y);
                        assert_eq!(serial, y);
                    }
                });
            }
        });
    }

    /// Dropping a pool joins its workers without hanging.
    #[test]
    fn drop_shuts_down_cleanly() {
        let pool = SignPool::new(5);
        let (s, x, _, _) = random_setup(8, 64, 2, 66);
        let mut y = Mat::zeros(8, 2);
        pool.gemm_sign(&s, &x, &mut y);
        drop(pool); // must not deadlock
    }

    /// The global pool exists and reports at least one thread.
    #[test]
    fn global_pool_is_usable() {
        let pool = SignPool::global();
        assert!(pool.threads() >= 1);
        let (s, x, _, _) = random_setup(5, 30, 3, 67);
        let mut serial = Mat::zeros(5, 3);
        gemm_sign(&s, &x, &mut serial);
        let mut y = Mat::zeros(5, 3);
        pool.gemm_sign(&s, &x, &mut y);
        assert_eq!(serial, y);
    }
}
