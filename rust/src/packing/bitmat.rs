//! Bit-packed ±1 matrix with an aligned, padded word stride and
//! owned-or-mapped backing.

use crate::linalg::{AlignedU64, Mat};
use crate::sys::MappedWords;
use anyhow::{bail, Result};

/// Words per 32-byte block — the row-stride quantum.
const WORD_BLOCK: usize = crate::linalg::aligned::U64_BLOCK;

/// Row-major bit-packed sign matrix. Set bit = +1, clear bit = −1.
///
/// In memory each row occupies [`words_per_row`](BitMatrix::words_per_row)
/// `u64` words — the tight `⌈cols/64⌉` count rounded up to a 4-word
/// (32-byte) block — in a 32-byte-aligned buffer, so AVX2 loads of a row
/// are aligned and never straddle rows. **All** padding bits are kept
/// clear as a type invariant: the trailing bits of the last tight word
/// *and* every whole padding word (validated by
/// [`padding_is_clear`](BitMatrix::padding_is_clear), asserted at kernel
/// entry) — clear padding is load-bearing for the popcount and
/// whole-word-XOR kernels.
///
/// In a v1/v2 `.lb2` artifact the **tight** form is stored
/// ([`tight_words`](BitMatrix::tight_words)); [`from_words`] accepts that
/// tight form and re-strides on load, so the padded layout never changes a
/// serialized byte. A v3 "aligned" artifact stores the padded stride
/// verbatim, which lets [`from_mapped`](BitMatrix::from_mapped) borrow the
/// plane straight out of the file mapping — zero copies, same invariants
/// (the constructor validates clear padding before handing the matrix
/// out, exactly like the owned path).
#[derive(Clone, Debug)]
pub struct BitMatrix {
    rows: usize,
    cols: usize,
    /// Padded row stride: `⌈cols/64⌉` rounded up to a multiple of 4.
    words_per_row: usize,
    /// `rows * words_per_row` words, 32-byte aligned.
    words: Words,
}

/// The word buffer: owned aligned heap memory, or a borrowed window into
/// a shared artifact mapping. Both expose the identical padded layout —
/// every kernel and accessor is backing-agnostic.
#[derive(Clone, Debug)]
enum Words {
    Owned(AlignedU64),
    Mapped(MappedWords),
}

impl Words {
    #[inline]
    fn as_slice(&self) -> &[u64] {
        match self {
            Words::Owned(w) => w.as_slice(),
            Words::Mapped(m) => m.as_slice(),
        }
    }

    #[inline]
    fn len(&self) -> usize {
        match self {
            Words::Owned(w) => w.len(),
            Words::Mapped(m) => m.len(),
        }
    }
}

impl PartialEq for BitMatrix {
    /// Backing-agnostic equality: shape plus padded word contents (padding
    /// is clear by invariant on both sides, so comparing padded buffers is
    /// exact).
    fn eq(&self, other: &Self) -> bool {
        self.rows == other.rows
            && self.cols == other.cols
            && self.words.as_slice() == other.words.as_slice()
    }
}

/// Padded row stride (in words) for a logical width of `cols` bits.
#[inline]
fn padded_words_per_row(cols: usize) -> usize {
    cols.div_ceil(64).div_ceil(WORD_BLOCK) * WORD_BLOCK
}

impl BitMatrix {
    /// Pack the signs of a dense matrix (`x ≥ 0 → +1`, matching
    /// `Mat::signum`).
    pub fn from_dense(m: &Mat) -> Self {
        let (rows, cols) = m.shape();
        let words_per_row = padded_words_per_row(cols);
        let mut words = AlignedU64::zeros(rows * words_per_row);
        let w = words.as_mut_slice();
        for i in 0..rows {
            let row = m.row(i);
            let base = i * words_per_row;
            for (j, &v) in row.iter().enumerate() {
                if v >= 0.0 {
                    w[base + j / 64] |= 1u64 << (j % 64);
                }
            }
        }
        Self { rows, cols, words_per_row, words: Words::Owned(words) }
    }

    /// All-(+1) matrix.
    pub fn ones(rows: usize, cols: usize) -> Self {
        let m = Mat::from_fn(rows, cols, |_, _| 1.0);
        Self::from_dense(&m)
    }

    /// Rebuild from the **tight** packed word buffer (the `.lb2` artifact
    /// load path: `rows × ⌈cols/64⌉` words, exactly the bytes on disk),
    /// re-striding into the padded aligned layout. Fails with `Err` when
    /// the word count doesn't match or any padding bit past `cols` in a
    /// row's last tight word is set — the kernels rely on clear padding,
    /// so a corrupt buffer must be rejected here, loudly, not served.
    pub fn from_words(rows: usize, cols: usize, words: Vec<u64>) -> Result<Self> {
        let tight = cols.div_ceil(64);
        let expect = rows
            .checked_mul(tight)
            .ok_or_else(|| anyhow::anyhow!("bit-plane {rows}x{cols} overflows"))?;
        if words.len() != expect {
            bail!(
                "bit-plane word count mismatch: {rows}x{cols} needs {expect} words, got {}",
                words.len()
            );
        }
        if cols % 64 != 0 && tight > 0 {
            let pad_mask = !0u64 << (cols % 64);
            for i in 0..rows {
                let last = words[i * tight + tight - 1];
                if last & pad_mask != 0 {
                    bail!("bit-plane row {i} has set padding bits past column {cols}");
                }
            }
        }
        let words_per_row = padded_words_per_row(cols);
        let mut padded = AlignedU64::zeros(rows * words_per_row);
        let dst = padded.as_mut_slice();
        for i in 0..rows {
            dst[i * words_per_row..i * words_per_row + tight]
                .copy_from_slice(&words[i * tight..(i + 1) * tight]);
        }
        Ok(Self { rows, cols, words_per_row, words: Words::Owned(padded) })
    }

    /// Borrow a bit-plane straight out of a mapped artifact — the `.lb2`
    /// v3 zero-copy load path. The view must hold exactly
    /// `rows × words_per_row(cols)` words **in the padded in-memory
    /// stride** (that is what the aligned encoding stores), and every
    /// padding bit must be clear — the same invariant the owned
    /// constructors enforce, validated here before the matrix is handed
    /// out, because the kernels' whole-word popcount/XOR loops rely on it.
    pub fn from_mapped(rows: usize, cols: usize, mapped: MappedWords) -> Result<Self> {
        let words_per_row = padded_words_per_row(cols);
        let expect = rows
            .checked_mul(words_per_row)
            .ok_or_else(|| anyhow::anyhow!("bit-plane {rows}x{cols} overflows"))?;
        if mapped.len() != expect {
            bail!(
                "mapped bit-plane word count mismatch: {rows}x{cols} needs {expect} padded words, got {}",
                mapped.len()
            );
        }
        let m = Self { rows, cols, words_per_row, words: Words::Mapped(mapped) };
        if !m.padding_is_clear() {
            bail!("mapped bit-plane {rows}x{cols} has set padding bits");
        }
        Ok(m)
    }

    /// The padded in-memory word buffer, row-major
    /// (`rows × words_per_row` words, 32-byte aligned). Per-row words past
    /// [`tight_words_per_row`](Self::tight_words_per_row) are zero.
    #[inline]
    pub fn padded_words(&self) -> &[u64] {
        self.words.as_slice()
    }

    /// The tight `rows × ⌈cols/64⌉` words in row-major order — exactly
    /// what the `.lb2` artifact stores, byte-identical to the pre-padding
    /// layout's buffer.
    pub fn tight_words(&self) -> impl Iterator<Item = u64> + '_ {
        let tight = self.tight_words_per_row();
        (0..self.rows).flat_map(move |i| self.row_words(i)[..tight].iter().copied())
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Padded (allocated) words per row — a multiple of 4.
    #[inline]
    pub fn words_per_row(&self) -> usize {
        self.words_per_row
    }

    /// Words per row that carry data: `⌈cols/64⌉`.
    #[inline]
    pub fn tight_words_per_row(&self) -> usize {
        self.cols.div_ceil(64)
    }

    /// Padded row stride (in words) for a logical width of `cols` bits —
    /// the in-memory stride of every `BitMatrix`, and the on-disk stride
    /// of a `.lb2` v3 "aligned" bit-plane. Exposed so the artifact codec
    /// and the in-memory layout can never disagree.
    #[inline]
    pub fn padded_stride(cols: usize) -> usize {
        padded_words_per_row(cols)
    }

    /// The padded words of row `i` (length [`words_per_row`](Self::words_per_row),
    /// 32-byte aligned; trailing padding words are zero).
    #[inline]
    pub fn row_words(&self, i: usize) -> &[u64] {
        &self.words.as_slice()[i * self.words_per_row..(i + 1) * self.words_per_row]
    }

    /// Sign at (i, j) as ±1.0.
    #[inline]
    pub fn sign_at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        let w = self.words.as_slice()[i * self.words_per_row + j / 64];
        if (w >> (j % 64)) & 1 == 1 {
            1.0
        } else {
            -1.0
        }
    }

    /// Unpack to a dense ±1 matrix.
    pub fn to_dense(&self) -> Mat {
        Mat::from_fn(self.rows, self.cols, |i, j| self.sign_at(i, j))
    }

    /// True when every padding bit is clear: the trailing bits past `cols`
    /// in each row's last tight word, and every whole padding word beyond
    /// the tight count. The kernels `debug_assert!` this at entry — clear
    /// padding is what lets them stream whole words without column masks.
    pub fn padding_is_clear(&self) -> bool {
        let tight = self.tight_words_per_row();
        let tail_mask = if self.cols % 64 != 0 { !0u64 << (self.cols % 64) } else { 0 };
        (0..self.rows).all(|i| {
            let row = self.row_words(i);
            let tail_ok = tail_mask == 0 || tight == 0 || row[tight - 1] & tail_mask == 0;
            tail_ok && row[tight..].iter().all(|&w| w == 0)
        })
    }

    /// Transposed copy (used to turn `V_b` into `V_bᵀ` once at load time so
    /// the GEMV streams rows). Word-blocked: the matrix is processed as
    /// 64×64 bit tiles, each transposed in-register by the log-step
    /// delta-swap network (6 rounds of masked exchanges) instead of
    /// bit-at-a-time probing — the `.lb2` open-path cost this pays on
    /// every load.
    pub fn transpose(&self) -> BitMatrix {
        let (rows, cols) = (self.rows, self.cols);
        let wpr_out = padded_words_per_row(rows);
        let mut out = AlignedU64::zeros(cols * wpr_out);
        let dst = out.as_mut_slice();
        let tight_in = self.tight_words_per_row();
        // Tile (bi, bj) covers input rows 64·bi.. and input cols 64·bj..
        for bi in 0..rows.div_ceil(64) {
            let tile_rows = (rows - bi * 64).min(64);
            for bj in 0..tight_in {
                // Gather: word bj of 64 consecutive input rows; missing
                // rows stay zero (their transposed bits must be clear).
                let mut tile = [0u64; 64];
                for (r, t) in tile.iter_mut().enumerate().take(tile_rows) {
                    *t = self.row_words(bi * 64 + r)[bj];
                }
                transpose_64x64(&mut tile);
                // Scatter: tile row c is output row 64·bj + c, word bi.
                // Input-column padding bits (≥ cols) were clear, so the
                // out-of-range tile rows are zero and are simply skipped.
                let out_rows = (cols - bj * 64).min(64);
                for (c, &t) in tile.iter().enumerate().take(out_rows) {
                    dst[(bj * 64 + c) * wpr_out + bi] = t;
                }
            }
        }
        BitMatrix { rows: cols, cols: rows, words_per_row: wpr_out, words: Words::Owned(out) }
    }

    /// Storage in bytes of the **tight** packed form — what the artifact
    /// ships and what the sub-1-bit accounting counts (`rows·cols/8` plus
    /// sub-word padding). Alignment padding is a transient in-memory cost;
    /// see [`resident_bytes`](Self::resident_bytes).
    pub fn storage_bytes(&self) -> usize {
        self.rows * self.tight_words_per_row() * 8
    }

    /// Bytes of the padded buffer this **process's heap** holds: the full
    /// padded allocation for owned backing, 0 when the plane is borrowed
    /// from a page-cache mapping (those bytes are accounted by
    /// [`mapped_bytes`](Self::mapped_bytes) instead — never both, so
    /// summing the two over a stack never double-counts a plane).
    pub fn resident_bytes(&self) -> usize {
        match &self.words {
            Words::Owned(w) => w.len() * 8,
            // Borrowed from the heap-fallback backing: still RAM-resident.
            Words::Mapped(m) if !m.is_mapped() => m.len() * 8,
            Words::Mapped(_) => 0,
        }
    }

    /// Bytes of the padded buffer served from the page cache (0 for owned
    /// or heap-fallback backing).
    pub fn mapped_bytes(&self) -> usize {
        match &self.words {
            Words::Mapped(m) if m.is_mapped() => m.len() * 8,
            _ => 0,
        }
    }

    /// True when the plane is borrowed from a live file mapping.
    pub fn is_mapped(&self) -> bool {
        matches!(&self.words, Words::Mapped(m) if m.is_mapped())
    }

    /// True when the plane is borrowed (from a mapping or the aligned-heap
    /// fallback) rather than owned.
    pub fn is_borrowed(&self) -> bool {
        matches!(&self.words, Words::Mapped(_))
    }

    /// An owned copy of rows `range` — the tensor-parallel shard cut: a
    /// row shard's kernels see exactly the same per-row words as the full
    /// matrix (columns are untouched), so each output row's reduction
    /// order is unchanged and shard outputs concatenate bit-identically
    /// to the unsharded kernel.
    pub fn slice_rows(&self, range: std::ops::Range<usize>) -> Result<BitMatrix> {
        if range.start > range.end || range.end > self.rows {
            bail!(
                "row slice {}..{} out of bounds for a {}x{} bit-plane",
                range.start,
                range.end,
                self.rows,
                self.cols
            );
        }
        let tight = self.tight_words_per_row();
        let n = range.len();
        let mut words = Vec::with_capacity(n * tight);
        for i in range {
            words.extend_from_slice(&self.row_words(i)[..tight]);
        }
        Self::from_words(n, self.cols, words)
    }

    /// Fraction of +1 entries.
    pub fn density(&self) -> f64 {
        // Padding is clear by invariant, so the padded popcount is exact.
        let set: u64 = self.words.as_slice().iter().map(|w| w.count_ones() as u64).sum();
        set as f64 / (self.rows * self.cols) as f64
    }
}

/// In-place transpose of a 64×64 bit tile (`tile[i]` bit `j` ⇄ `tile[j]`
/// bit `i`): the classic recursive block-swap — exchange the off-diagonal
/// 32×32 blocks, then 16×16 within each half, … down to 1×1 — each round a
/// masked delta swap.
fn transpose_64x64(tile: &mut [u64; 64]) {
    // LSB-first variant (bit j = column j): each round exchanges the high
    // column half of the low row half with the low column half of the high
    // row half inside every 2j×2j block.
    let mut j = 32;
    let mut m: u64 = 0x0000_0000_FFFF_FFFF;
    while j != 0 {
        let mut k = 0;
        while k < 64 {
            let t = ((tile[k] >> j) ^ tile[k | j]) & m;
            tile[k] ^= t << j;
            tile[k | j] ^= t;
            k = ((k | j) + 1) & !j;
        }
        j >>= 1;
        m ^= m << j;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn pack_unpack_roundtrip() {
        let mut rng = Pcg64::seed(1);
        for (r, c) in [(3, 3), (7, 64), (5, 65), (16, 130)] {
            let m = Mat::gaussian(r, c, &mut rng).signum();
            let packed = BitMatrix::from_dense(&m);
            assert_eq!(packed.to_dense(), m, "{r}x{c}");
        }
    }

    /// The old bit-at-a-time transpose, kept as the oracle for the
    /// word-blocked 64×64 implementation.
    fn transpose_reference(b: &BitMatrix) -> Mat {
        Mat::from_fn(b.cols(), b.rows(), |i, j| b.sign_at(j, i))
    }

    #[test]
    fn transpose_matches_dense_transpose() {
        let mut rng = Pcg64::seed(2);
        let m = Mat::gaussian(37, 91, &mut rng).signum();
        let packed = BitMatrix::from_dense(&m);
        assert_eq!(packed.transpose().to_dense(), m.transpose());
    }

    /// Block-transpose bit-exactness across ragged tile geometries: square
    /// one-tile, sub-tile, exact multi-tile, and every % 64 edge class —
    /// identical bits to the per-element oracle, clear padding throughout.
    #[test]
    fn block_transpose_matches_reference_on_ragged_shapes() {
        let mut rng = Pcg64::seed(21);
        for (r, c) in
            [(1, 1), (64, 64), (63, 65), (65, 63), (128, 192), (130, 1), (1, 130), (100, 129)]
        {
            let m = Mat::gaussian(r, c, &mut rng).signum();
            let packed = BitMatrix::from_dense(&m);
            let t = packed.transpose();
            assert_eq!(t.to_dense(), transpose_reference(&packed), "{r}x{c}");
            assert!(t.padding_is_clear(), "{r}x{c}: transpose contaminated padding");
            // Double transpose is the identity, including word buffers.
            assert_eq!(t.transpose(), packed, "{r}x{c}");
        }
    }

    #[test]
    fn storage_is_one_bit_per_entry_plus_padding() {
        let b = BitMatrix::ones(128, 128);
        assert_eq!(b.storage_bytes(), 128 * 128 / 8);
        let b = BitMatrix::ones(10, 65);
        assert_eq!(b.storage_bytes(), 10 * 2 * 8); // 2 tight words per row
    }

    /// The aligned layout: stride is a 4-word multiple, the buffer is
    /// 32-byte aligned, and resident bytes exceed tight bytes only by the
    /// per-row block padding.
    #[test]
    fn padded_stride_geometry() {
        for (c, wpr) in [(1usize, 4usize), (64, 4), (256, 4), (257, 8), (130, 4)] {
            let b = BitMatrix::ones(3, c);
            assert_eq!(b.words_per_row(), wpr, "cols={c}");
            assert_eq!(b.padded_words().len(), 3 * wpr);
            assert_eq!(b.padded_words().as_ptr() as usize % 32, 0);
            assert_eq!(b.resident_bytes(), 3 * wpr * 8);
            assert_eq!(b.row_words(1).len(), wpr);
            assert!(b.padding_is_clear());
        }
    }

    /// `tight_words` strips the padding back to the serialized layout.
    #[test]
    fn tight_words_roundtrip_through_from_words() {
        let mut rng = Pcg64::seed(22);
        for (r, c) in [(3, 3), (7, 64), (5, 65), (16, 130), (2, 257)] {
            let m = Mat::gaussian(r, c, &mut rng).signum();
            let packed = BitMatrix::from_dense(&m);
            let tight: Vec<u64> = packed.tight_words().collect();
            assert_eq!(tight.len(), r * c.div_ceil(64), "{r}x{c}");
            let rebuilt = BitMatrix::from_words(r, c, tight).unwrap();
            assert_eq!(rebuilt, packed, "{r}x{c}");
        }
    }

    /// Row slices carry exactly the original rows' words (bit-identical
    /// per-row layout — the shard bit-identity precondition) and reject
    /// out-of-bounds ranges.
    #[test]
    fn slice_rows_preserves_row_words() {
        let mut rng = Pcg64::seed(23);
        for (r, c) in [(7, 64), (5, 65), (16, 130)] {
            let m = Mat::gaussian(r, c, &mut rng).signum();
            let full = BitMatrix::from_dense(&m);
            for range in [0..r, 0..1, r - 1..r, 1..r - 1] {
                let sliced = full.slice_rows(range.clone()).unwrap();
                assert_eq!(sliced.rows(), range.len(), "{r}x{c} {range:?}");
                assert_eq!(sliced.cols(), c);
                for (k, i) in range.clone().enumerate() {
                    assert_eq!(sliced.row_words(k), full.row_words(i), "{r}x{c} {range:?}");
                }
            }
            // Empty slice is legal (an empty shard).
            assert_eq!(full.slice_rows(2..2).unwrap().rows(), 0);
            assert!(full.slice_rows(0..r + 1).is_err());
            #[allow(clippy::reversed_empty_ranges)]
            {
                assert!(full.slice_rows(3..2).is_err());
            }
        }
    }

    #[test]
    fn density_of_signs_is_half() {
        let mut rng = Pcg64::seed(3);
        let m = Mat::gaussian(256, 256, &mut rng).signum();
        let d = BitMatrix::from_dense(&m).density();
        assert!((d - 0.5).abs() < 0.02, "density={d}");
    }

    #[test]
    fn from_words_roundtrips_verbatim() {
        let mut rng = Pcg64::seed(4);
        for (r, c) in [(3, 3), (7, 64), (5, 65), (16, 130)] {
            let m = Mat::gaussian(r, c, &mut rng).signum();
            let packed = BitMatrix::from_dense(&m);
            let rebuilt = BitMatrix::from_words(r, c, packed.tight_words().collect()).unwrap();
            assert_eq!(rebuilt, packed, "{r}x{c}");
        }
    }

    #[test]
    fn from_words_rejects_corruption() {
        let b = BitMatrix::from_dense(&Mat::from_fn(2, 65, |_, _| 1.0));
        let tight: Vec<u64> = b.tight_words().collect();
        // Wrong word count.
        assert!(BitMatrix::from_words(2, 65, tight[..3].to_vec()).is_err());
        assert!(BitMatrix::from_words(3, 65, tight.clone()).is_err());
        // Set padding bit past column 65.
        let mut words = tight;
        words[1] |= 1u64 << 7;
        assert!(BitMatrix::from_words(2, 65, words).is_err());
    }

    /// A plane borrowed from an artifact backing is indistinguishable from
    /// the owned original — same words, same equality — while flipping the
    /// resident/mapped accounting; corrupt padded planes are rejected.
    #[test]
    fn from_mapped_borrows_bit_identically() {
        use crate::sys::{MappedArtifact, MappedWords};
        let mut rng = Pcg64::seed(40);
        for (r, c) in [(3, 3), (7, 64), (5, 65), (16, 130)] {
            let m = Mat::gaussian(r, c, &mut rng).signum();
            let owned = BitMatrix::from_dense(&m);
            let bytes: Vec<u8> =
                owned.padded_words().iter().flat_map(|w| w.to_le_bytes()).collect();
            let art = MappedArtifact::from_bytes(&bytes);
            let view = MappedWords::new(&art, 0, owned.padded_words().len()).unwrap();
            let borrowed = BitMatrix::from_mapped(r, c, view).unwrap();
            assert_eq!(borrowed, owned, "{r}x{c}");
            assert_eq!(borrowed.to_dense(), m, "{r}x{c}");
            assert!(borrowed.is_borrowed());
            assert!(owned.resident_bytes() > 0 && owned.mapped_bytes() == 0);
            // Heap-fallback backing: borrowed but still resident.
            assert!(!borrowed.is_mapped());
            assert_eq!(borrowed.resident_bytes(), owned.resident_bytes(), "{r}x{c}");
        }
        // Wrong word count and dirty padding are rejected before handout.
        let owned = BitMatrix::ones(2, 65);
        let mut bytes: Vec<u8> =
            owned.padded_words().iter().flat_map(|w| w.to_le_bytes()).collect();
        let art = MappedArtifact::from_bytes(&bytes);
        assert!(BitMatrix::from_mapped(2, 65, MappedWords::new(&art, 0, 4).unwrap()).is_err());
        bytes[8] |= 0x02; // set bit 65 of row 0 — a padding bit
        let art = MappedArtifact::from_bytes(&bytes);
        let view = MappedWords::new(&art, 0, owned.padded_words().len()).unwrap();
        assert!(BitMatrix::from_mapped(2, 65, view).is_err());
    }

    #[test]
    fn padding_bits_stay_clear() {
        let m = Mat::from_fn(2, 65, |_, _| 1.0); // all +1, one spill bit
        let b = BitMatrix::from_dense(&m);
        for i in 0..2 {
            let last = b.row_words(i)[1];
            assert_eq!(last & !1u64, 0, "padding contaminated: {last:#x}");
            // Whole padding words (2 and 3 of the 4-word stride) are zero.
            assert_eq!(&b.row_words(i)[2..], &[0, 0]);
        }
        assert!(b.padding_is_clear());
    }
}
