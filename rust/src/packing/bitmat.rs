//! Bit-packed ±1 matrix.

use crate::linalg::Mat;
use anyhow::{bail, Result};

/// Row-major bit-packed sign matrix. Set bit = +1, clear bit = −1.
/// Each row occupies `words_per_row` u64 words; trailing padding bits in the
/// last word of each row are kept **clear** and must be ignored by kernels
/// (they are, via explicit column bounds).
#[derive(Clone, Debug, PartialEq)]
pub struct BitMatrix {
    rows: usize,
    cols: usize,
    words_per_row: usize,
    words: Vec<u64>,
}

impl BitMatrix {
    /// Pack the signs of a dense matrix (`x ≥ 0 → +1`, matching
    /// `Mat::signum`).
    pub fn from_dense(m: &Mat) -> Self {
        let (rows, cols) = m.shape();
        let words_per_row = cols.div_ceil(64);
        let mut words = vec![0u64; rows * words_per_row];
        for i in 0..rows {
            let row = m.row(i);
            let base = i * words_per_row;
            for (j, &v) in row.iter().enumerate() {
                if v >= 0.0 {
                    words[base + j / 64] |= 1u64 << (j % 64);
                }
            }
        }
        Self { rows, cols, words_per_row, words }
    }

    /// All-(+1) matrix.
    pub fn ones(rows: usize, cols: usize) -> Self {
        let m = Mat::from_fn(rows, cols, |_, _| 1.0);
        Self::from_dense(&m)
    }

    /// Rebuild from the packed word buffer verbatim (the `.lb2` artifact
    /// load path — no re-packing). Fails with `Err` when the word count
    /// doesn't match `rows × ⌈cols/64⌉` or any padding bit past `cols` in a
    /// row's last word is set — the kernels rely on clear padding, so a
    /// corrupt buffer must be rejected here, loudly, not served.
    pub fn from_words(rows: usize, cols: usize, words: Vec<u64>) -> Result<Self> {
        let words_per_row = cols.div_ceil(64);
        let expect = rows
            .checked_mul(words_per_row)
            .ok_or_else(|| anyhow::anyhow!("bit-plane {rows}x{cols} overflows"))?;
        if words.len() != expect {
            bail!(
                "bit-plane word count mismatch: {rows}x{cols} needs {expect} words, got {}",
                words.len()
            );
        }
        if cols % 64 != 0 && words_per_row > 0 {
            let pad_mask = !0u64 << (cols % 64);
            for i in 0..rows {
                let last = words[i * words_per_row + words_per_row - 1];
                if last & pad_mask != 0 {
                    bail!("bit-plane row {i} has set padding bits past column {cols}");
                }
            }
        }
        Ok(Self { rows, cols, words_per_row, words })
    }

    /// The packed word buffer, row-major (`rows × words_per_row` words) —
    /// what the `.lb2` artifact stores verbatim.
    #[inline]
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn words_per_row(&self) -> usize {
        self.words_per_row
    }

    #[inline]
    pub fn row_words(&self, i: usize) -> &[u64] {
        &self.words[i * self.words_per_row..(i + 1) * self.words_per_row]
    }

    /// Sign at (i, j) as ±1.0.
    #[inline]
    pub fn sign_at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        let w = self.words[i * self.words_per_row + j / 64];
        if (w >> (j % 64)) & 1 == 1 {
            1.0
        } else {
            -1.0
        }
    }

    /// Unpack to a dense ±1 matrix.
    pub fn to_dense(&self) -> Mat {
        Mat::from_fn(self.rows, self.cols, |i, j| self.sign_at(i, j))
    }

    /// Transposed copy (used to turn `V_b` into `V_bᵀ` once at load time so
    /// the GEMV streams rows).
    pub fn transpose(&self) -> BitMatrix {
        let mut out_words = vec![0u64; self.cols * self.rows.div_ceil(64)];
        let wpr_out = self.rows.div_ceil(64);
        for i in 0..self.rows {
            let base = i * self.words_per_row;
            for w in 0..self.words_per_row {
                let mut word = self.words[base + w];
                while word != 0 {
                    let b = word.trailing_zeros() as usize;
                    let j = w * 64 + b;
                    if j < self.cols {
                        out_words[j * wpr_out + i / 64] |= 1u64 << (i % 64);
                    }
                    word &= word - 1;
                }
            }
        }
        BitMatrix {
            rows: self.cols,
            cols: self.rows,
            words_per_row: wpr_out,
            words: out_words,
        }
    }

    /// Storage in bytes (the sub-1-bit story: `rows·cols/8` plus padding).
    pub fn storage_bytes(&self) -> usize {
        self.words.len() * 8
    }

    /// Fraction of +1 entries.
    pub fn density(&self) -> f64 {
        let set: u64 = self.words.iter().map(|w| w.count_ones() as u64).sum();
        set as f64 / (self.rows * self.cols) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn pack_unpack_roundtrip() {
        let mut rng = Pcg64::seed(1);
        for (r, c) in [(3, 3), (7, 64), (5, 65), (16, 130)] {
            let m = Mat::gaussian(r, c, &mut rng).signum();
            let packed = BitMatrix::from_dense(&m);
            assert_eq!(packed.to_dense(), m, "{r}x{c}");
        }
    }

    #[test]
    fn transpose_matches_dense_transpose() {
        let mut rng = Pcg64::seed(2);
        let m = Mat::gaussian(37, 91, &mut rng).signum();
        let packed = BitMatrix::from_dense(&m);
        assert_eq!(packed.transpose().to_dense(), m.transpose());
    }

    #[test]
    fn storage_is_one_bit_per_entry_plus_padding() {
        let b = BitMatrix::ones(128, 128);
        assert_eq!(b.storage_bytes(), 128 * 128 / 8);
        let b = BitMatrix::ones(10, 65);
        assert_eq!(b.storage_bytes(), 10 * 2 * 8); // 2 words per row
    }

    #[test]
    fn density_of_signs_is_half() {
        let mut rng = Pcg64::seed(3);
        let m = Mat::gaussian(256, 256, &mut rng).signum();
        let d = BitMatrix::from_dense(&m).density();
        assert!((d - 0.5).abs() < 0.02, "density={d}");
    }

    #[test]
    fn from_words_roundtrips_verbatim() {
        let mut rng = Pcg64::seed(4);
        for (r, c) in [(3, 3), (7, 64), (5, 65), (16, 130)] {
            let m = Mat::gaussian(r, c, &mut rng).signum();
            let packed = BitMatrix::from_dense(&m);
            let rebuilt = BitMatrix::from_words(r, c, packed.words().to_vec()).unwrap();
            assert_eq!(rebuilt, packed, "{r}x{c}");
        }
    }

    #[test]
    fn from_words_rejects_corruption() {
        let b = BitMatrix::from_dense(&Mat::from_fn(2, 65, |_, _| 1.0));
        // Wrong word count.
        assert!(BitMatrix::from_words(2, 65, b.words()[..3].to_vec()).is_err());
        assert!(BitMatrix::from_words(3, 65, b.words().to_vec()).is_err());
        // Set padding bit past column 65.
        let mut words = b.words().to_vec();
        words[1] |= 1u64 << 7;
        assert!(BitMatrix::from_words(2, 65, words).is_err());
    }

    #[test]
    fn padding_bits_stay_clear() {
        let m = Mat::from_fn(2, 65, |_, _| 1.0); // all +1, one spill bit
        let b = BitMatrix::from_dense(&m);
        for i in 0..2 {
            let last = b.row_words(i)[1];
            assert_eq!(last & !1u64, 0, "padding contaminated: {last:#x}");
        }
    }
}
