//! Batched sign-GEMM: the bit-packed MatMul-free kernel at batch > 1.
//!
//! [`gemv_sign`](super::gemv_sign) streams every 64-bit sign word of `S`
//! once *per request*; at batch `b` that is `b` full passes over the packed
//! weights. [`gemm_sign`] instead multiplies `S ∈ {±1}^{m×n}` against an
//! activation *block* `X ∈ R^{n×b}` (feature-major: column `t` is request
//! `t`), register-blocking over the batch dimension so each sign word is
//! loaded once per strip of 8 batch columns — weight traffic drops by the
//! strip width, which is what makes dynamic batching pay off on this
//! kernel (the "MatMul-free at batch size" story of §6.2).
//!
//! Per batch column the reduction runs on the same eight accumulators in
//! the same order as `gemv_sign`, so `gemm_sign` is **bit-exact** against
//! column-by-column GEMV — asserted by `gemm_matches_gemv_bit_exactly`
//! below and relied on by the serving tests.
//!
//! `*_mt` variants split output rows across `threads` std threads
//! (`std::thread::scope`; no external runtime). Row partitioning does not
//! change any per-row reduction order, so threaded results are bit-exact
//! against the serial kernels, too.

use super::gemv::gemv_sign_rows;
use super::BitMatrix;
use crate::linalg::Mat;

/// Batch columns processed per sign-word load. Eight f32 lanes × eight
/// reduction accumulators = 64 live scalars — two AVX2 register files'
/// worth, which the compiler keeps in registers on x86-64 and aarch64.
const COL_STRIP: usize = 8;

/// Sign-GEMM: `Y = S X` with `S ∈ {±1}^{m×n}` bit-packed, `X` feature-major
/// `n×b` (column `t` is batch item `t`), `Y` preallocated `m×b`.
///
/// Bit-exact against [`gemv_sign`](super::gemv_sign) applied column by
/// column, at a fraction of the weight traffic.
///
/// # Examples
///
/// ```
/// use littlebit2::linalg::Mat;
/// use littlebit2::packing::{gemm_sign, BitMatrix};
///
/// // All-(+1) signs: each output is the column sum of X.
/// let s = BitMatrix::ones(2, 3);
/// // X is 3×2 feature-major: batch item 0 = [1, 2, 3], item 1 = [4, 5, 6].
/// let x = Mat::from_vec(3, 2, vec![1.0, 4.0, 2.0, 5.0, 3.0, 6.0]);
/// let mut y = Mat::zeros(2, 2);
/// gemm_sign(&s, &x, &mut y);
/// assert_eq!(y.row(0), &[6.0, 15.0]);
/// assert_eq!(y.row(1), &[6.0, 15.0]);
/// ```
pub fn gemm_sign(s: &BitMatrix, x: &Mat, y: &mut Mat) {
    assert_eq!(s.cols(), x.rows(), "inner dims: S is m×n, X is n×b");
    assert_eq!(s.rows(), y.rows(), "output rows");
    assert_eq!(x.cols(), y.cols(), "batch width");
    let b = x.cols();
    if b == 0 || s.rows() == 0 {
        return;
    }
    gemm_sign_rows(s, x, y.as_mut_slice(), 0);
}

/// Row-parallel sign-GEMM: identical output to [`gemm_sign`] (bit-exact;
/// row partitioning changes no reduction order), with output rows split
/// across `threads` OS threads. `threads <= 1` falls through to the serial
/// kernel. This is the knob the batched serving pool turns — see
/// `coordinator::ServerConfig`.
pub fn gemm_sign_mt(s: &BitMatrix, x: &Mat, y: &mut Mat, threads: usize) {
    assert_eq!(s.cols(), x.rows(), "inner dims: S is m×n, X is n×b");
    assert_eq!(s.rows(), y.rows(), "output rows");
    assert_eq!(x.cols(), y.cols(), "batch width");
    let rows = s.rows();
    let b = x.cols();
    if b == 0 || rows == 0 {
        return;
    }
    let threads = threads.max(1).min(rows);
    if threads == 1 {
        gemm_sign_rows(s, x, y.as_mut_slice(), 0);
        return;
    }
    let chunk = rows.div_ceil(threads);
    let y_all = y.as_mut_slice();
    std::thread::scope(|scope| {
        for (ti, ys) in y_all.chunks_mut(chunk * b).enumerate() {
            scope.spawn(move || gemm_sign_rows(s, x, ys, ti * chunk));
        }
    });
}

/// Compute output rows `row0..row0 + ys.len()/b` of `S X` into `ys`.
///
/// Per output element the reduction mirrors `gemv_sign` exactly: eight
/// accumulators fed word-by-word, strip-by-strip, then summed in lane
/// order — the source of the bit-exactness guarantee.
fn gemm_sign_rows(s: &BitMatrix, x: &Mat, ys: &mut [f32], row0: usize) {
    let b = x.cols();
    let cols = s.cols();
    let full_words = cols / 64;
    let nrows = ys.len() / b;
    for di in 0..nrows {
        let words = s.row_words(row0 + di);
        let yrow = &mut ys[di * b..(di + 1) * b];
        let mut c0 = 0;
        while c0 < b {
            let cw = (b - c0).min(COL_STRIP);
            // acc[k][t] is gemv_sign's acc[k], replicated per batch column
            // t — the sign word is read once for all cw columns.
            let mut acc = [[0.0f32; COL_STRIP]; 8];
            for (c, &w) in words[..full_words].iter().enumerate() {
                for strip in 0..8 {
                    let bits = (w >> (strip * 8)) as u32;
                    for k in 0..8 {
                        let neg = ((bits >> k) & 1 ^ 1) << 31;
                        let xrow = &x.row(c * 64 + strip * 8 + k)[c0..c0 + cw];
                        let lane = &mut acc[k];
                        for t in 0..cw {
                            lane[t] += f32::from_bits(xrow[t].to_bits() ^ neg);
                        }
                    }
                }
            }
            if full_words < words.len() {
                let w = words[full_words];
                for (k, j) in (full_words * 64..cols).enumerate() {
                    let neg = (((w >> k) & 1) as u32 ^ 1) << 31;
                    let xrow = &x.row(j)[c0..c0 + cw];
                    let lane = &mut acc[k & 7];
                    for t in 0..cw {
                        lane[t] += f32::from_bits(xrow[t].to_bits() ^ neg);
                    }
                }
            }
            for t in 0..cw {
                let mut sum = 0.0f32;
                for lane in &acc {
                    sum += lane[t];
                }
                yrow[c0 + t] = sum;
            }
            c0 += cw;
        }
    }
}

/// Row-parallel sign-GEMV: identical output to
/// [`gemv_sign`](super::gemv_sign) (bit-exact), rows split across
/// `threads` OS threads. The single-request analogue of [`gemm_sign_mt`].
pub fn gemv_sign_mt(s: &BitMatrix, x: &[f32], y: &mut [f32], threads: usize) {
    assert_eq!(s.cols(), x.len());
    assert_eq!(s.rows(), y.len());
    let rows = s.rows();
    if rows == 0 {
        return;
    }
    let threads = threads.max(1).min(rows);
    if threads == 1 {
        gemv_sign_rows(s, x, y, 0);
        return;
    }
    let chunk = rows.div_ceil(threads);
    std::thread::scope(|scope| {
        for (ti, ys) in y.chunks_mut(chunk).enumerate() {
            scope.spawn(move || gemv_sign_rows(s, x, ys, ti * chunk));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::packing::gemv_sign;
    use crate::rng::Pcg64;

    fn random_block(rows: usize, cols: usize, rng: &mut Pcg64) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        rng.fill_normal(m.as_mut_slice());
        m
    }

    /// The acceptance contract: gemm_sign column t must equal gemv_sign on
    /// column t of X, to exact bit equality (same accumulators, same
    /// order).
    #[test]
    fn gemm_matches_gemv_bit_exactly() {
        let mut rng = Pcg64::seed(21);
        for (m, n, b) in [(4, 4, 1), (16, 64, 3), (33, 130, 8), (8, 200, 9), (7, 65, 32)] {
            let s = BitMatrix::from_dense(&Mat::gaussian(m, n, &mut rng).signum());
            let x = random_block(n, b, &mut rng);
            let mut y = Mat::zeros(m, b);
            gemm_sign(&s, &x, &mut y);
            for t in 0..b {
                let xt = x.col(t);
                let mut want = vec![0.0f32; m];
                gemv_sign(&s, &xt, &mut want);
                for i in 0..m {
                    assert_eq!(
                        y.at(i, t).to_bits(),
                        want[i].to_bits(),
                        "{m}x{n} b={b}: ({i},{t}) {} vs {}",
                        y.at(i, t),
                        want[i]
                    );
                }
            }
        }
    }

    #[test]
    fn gemm_mt_matches_serial_bit_exactly() {
        let mut rng = Pcg64::seed(22);
        let (m, n, b) = (61, 130, 12);
        let s = BitMatrix::from_dense(&Mat::gaussian(m, n, &mut rng).signum());
        let x = random_block(n, b, &mut rng);
        let mut serial = Mat::zeros(m, b);
        gemm_sign(&s, &x, &mut serial);
        for threads in [2, 3, 7, 64] {
            let mut mt = Mat::zeros(m, b);
            gemm_sign_mt(&s, &x, &mut mt, threads);
            assert_eq!(serial, mt, "threads={threads}");
        }
    }

    #[test]
    fn gemv_mt_matches_serial_bit_exactly() {
        let mut rng = Pcg64::seed(23);
        let (m, n) = (77, 190);
        let s = BitMatrix::from_dense(&Mat::gaussian(m, n, &mut rng).signum());
        let mut x = vec![0.0f32; n];
        rng.fill_normal(&mut x);
        let mut serial = vec![0.0f32; m];
        gemv_sign(&s, &x, &mut serial);
        for threads in [2, 5, 128] {
            let mut mt = vec![0.0f32; m];
            gemv_sign_mt(&s, &x, &mut mt, threads);
            for (a, c) in serial.iter().zip(&mt) {
                assert_eq!(a.to_bits(), c.to_bits(), "threads={threads}");
            }
        }
    }

    /// Numeric check against the dense product (catches systematic sign
    /// errors the bit-equality test cannot — both kernels could agree and
    /// be wrong together).
    #[test]
    fn gemm_matches_dense_product() {
        let mut rng = Pcg64::seed(24);
        let (m, n, b) = (19, 70, 5);
        let sd = Mat::gaussian(m, n, &mut rng).signum();
        let s = BitMatrix::from_dense(&sd);
        let x = random_block(n, b, &mut rng);
        let want = sd.matmul(&x);
        let mut got = Mat::zeros(m, b);
        gemm_sign(&s, &x, &mut got);
        for (a, c) in want.as_slice().iter().zip(got.as_slice()) {
            assert!((a - c).abs() < 1e-3 * (n as f32).sqrt(), "{a} vs {c}");
        }
    }

    #[test]
    fn empty_batch_is_a_no_op() {
        let mut rng = Pcg64::seed(25);
        let s = BitMatrix::from_dense(&Mat::gaussian(5, 9, &mut rng).signum());
        let x = Mat::zeros(9, 0);
        let mut y = Mat::zeros(5, 0);
        gemm_sign(&s, &x, &mut y);
        gemm_sign_mt(&s, &x, &mut y, 4);
    }
}
