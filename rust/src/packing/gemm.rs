//! Batched sign-GEMM: the bit-packed MatMul-free kernel at batch > 1,
//! in plain and **scale-fused** forms.
//!
//! [`gemv_sign`](super::gemv_sign) streams every 64-bit sign word of `S`
//! once *per request*; at batch `b` that is `b` full passes over the packed
//! weights. [`gemm_sign`] instead multiplies `S ∈ {±1}^{m×n}` against an
//! activation *block* `X ∈ R^{n×b}` (feature-major: column `t` is request
//! `t`), register-blocking over the batch dimension so each sign word is
//! loaded once per strip of 8 batch columns — weight traffic drops by the
//! strip width, which is what makes dynamic batching pay off on this
//! kernel (the "MatMul-free at batch size" story of §6.2).
//!
//! [`gemm_sign_scaled`] is the same kernel with the tri-scale layer's
//! element-wise scales folded in: the input scale is applied exactly once
//! per call into a reused thread-local block (never re-applied per row
//! range — pool jobs share the scaled block read-only), and the output
//! scale rides the final lane reduction. This removes the three separate
//! scale passes (and their intermediate `Mat` allocations) the PR 1
//! pipeline made per layer, and is bit-exact against that unfused
//! composition.
//!
//! Per batch column the reduction runs on the same eight accumulators in
//! the same order as `gemv_sign`, so both GEMMs are **bit-exact** against
//! column-by-column GEMV — asserted by `gemm_matches_gemv_bit_exactly`
//! below and relied on by the serving tests.
//!
//! `*_mt` variants split output rows into per-call range jobs executed on
//! the persistent [`SignPool`](super::SignPool) (no per-call thread spawns;
//! no external runtime). Row partitioning does not change any per-row
//! reduction order, so pooled results are bit-exact against the serial
//! kernels for every thread count. The PR 1 per-call `std::thread::scope`
//! path is kept as [`gemm_sign_mt_scoped`] — the measured baseline for
//! `benches/gemm_speedup.rs`.

use super::pool::SignPool;
use super::{simd, BitMatrix};
use crate::linalg::Mat;
use std::cell::RefCell;

/// Batch columns processed per sign-word load. Eight f32 lanes × eight
/// reduction accumulators = 64 live scalars — two AVX2 register files'
/// worth, which the compiler keeps in registers on x86-64 and aarch64.
pub(crate) const COL_STRIP: usize = 8;

/// Output rows per cache tile. The batch loop runs column strips outermost
/// within a tile of this many sign rows, so one activation strip
/// (`n × COL_STRIP` floats) is reused across the whole tile while the
/// tile's packed rows (`ROW_TILE × words_per_row` words) stay resident —
/// both comfortably under typical L2. Tiling only reorders *which*
/// (row, strip) block runs when; each block's reduction is self-contained,
/// so results stay bit-identical to the untiled loop.
pub(crate) const ROW_TILE: usize = 64;

thread_local! {
    /// Per-thread input-scaled activation block for the fused GEMM
    /// (`n × b` floats, grown in place and reused across calls). The
    /// dispatching caller fills it **once per call** — exactly the unfused
    /// `scale_rows` pass's multiplies, minus its allocation — and every
    /// row-range job then reads it like it would read `x`, so input-scale
    /// work never multiplies with the partition count.
    static XBLOCK: RefCell<Mat> = RefCell::new(Mat::default());
}

/// Run `f` against the thread-local input-scaled copy of `x`
/// (`row j ← in_scale[j] · x[j]`). The products are identical f32s to the
/// unfused `scale_rows` pass, formed once per call — the source of the
/// fused kernels' bit-exactness. Shared with `packing::pool`, which hoists
/// the scale here before dispatching row-range jobs.
pub(crate) fn with_scaled_block<R>(x: &Mat, in_scale: &[f32], f: impl FnOnce(&Mat) -> R) -> R {
    XBLOCK.with(|cell| {
        let xg = &mut *cell.borrow_mut();
        xg.resize(x.rows(), x.cols());
        for (i, &gi) in in_scale.iter().enumerate() {
            for (d, &v) in xg.row_mut(i).iter_mut().zip(x.row(i)) {
                *d = v * gi;
            }
        }
        f(xg)
    })
}

/// Sign-GEMM: `Y = S X` with `S ∈ {±1}^{m×n}` bit-packed, `X` feature-major
/// `n×b` (column `t` is batch item `t`), `Y` preallocated `m×b`.
///
/// Bit-exact against [`gemv_sign`](super::gemv_sign) applied column by
/// column, at a fraction of the weight traffic.
///
/// # Examples
///
/// ```
/// use littlebit2::linalg::Mat;
/// use littlebit2::packing::{gemm_sign, BitMatrix};
///
/// // All-(+1) signs: each output is the column sum of X.
/// let s = BitMatrix::ones(2, 3);
/// // X is 3×2 feature-major: batch item 0 = [1, 2, 3], item 1 = [4, 5, 6].
/// let x = Mat::from_vec(3, 2, vec![1.0, 4.0, 2.0, 5.0, 3.0, 6.0]);
/// let mut y = Mat::zeros(2, 2);
/// gemm_sign(&s, &x, &mut y);
/// assert_eq!(y.row(0), &[6.0, 15.0]);
/// assert_eq!(y.row(1), &[6.0, 15.0]);
/// ```
pub fn gemm_sign(s: &BitMatrix, x: &Mat, y: &mut Mat) {
    assert_eq!(s.cols(), x.rows(), "inner dims: S is m×n, X is n×b");
    assert_eq!(s.rows(), y.rows(), "output rows");
    assert_eq!(x.cols(), y.cols(), "batch width");
    let b = x.cols();
    if b == 0 || s.rows() == 0 {
        return;
    }
    let stride = y.stride();
    gemm_sign_rows(s, x, y.padded_mut(), stride, 0);
}

/// Scale-fused sign-GEMM:
/// `Y = diag(out_scale) · S · diag(in_scale) · X`, either scale optional.
///
/// The input scale is applied **once per call** into a reused thread-local
/// activation block (one multiply per element — exactly the unfused
/// `scale_rows` pass's products, minus its per-call allocation) which then
/// stays resident while every sign word streams over it; the output scale
/// folds into the final lane reduction (one multiply per output element).
/// Bit-exact against scale → [`gemm_sign`] → scale — asserted by
/// `gemm_scaled_matches_unfused_composition_bit_exactly` — with zero
/// separate output passes and zero per-call allocations after warm-up.
///
/// # Examples
///
/// ```
/// use littlebit2::linalg::Mat;
/// use littlebit2::packing::{gemm_sign_scaled, BitMatrix};
///
/// let s = BitMatrix::ones(2, 2);
/// let x = Mat::from_vec(2, 1, vec![1.0, 2.0]);
/// let mut y = Mat::zeros(2, 1);
/// // y = diag([10, 100]) · S · diag([3, 4]) · x = [110, 1100] scaled per row.
/// gemm_sign_scaled(&s, Some(&[3.0, 4.0]), &x, Some(&[10.0, 100.0]), &mut y);
/// assert_eq!(y.col(0), vec![110.0, 1100.0]);
/// ```
pub fn gemm_sign_scaled(
    s: &BitMatrix,
    in_scale: Option<&[f32]>,
    x: &Mat,
    out_scale: Option<&[f32]>,
    y: &mut Mat,
) {
    assert_eq!(s.cols(), x.rows(), "inner dims: S is m×n, X is n×b");
    assert_eq!(s.rows(), y.rows(), "output rows");
    assert_eq!(x.cols(), y.cols(), "batch width");
    if let Some(g) = in_scale {
        assert_eq!(g.len(), s.cols(), "in_scale length");
    }
    if let Some(h) = out_scale {
        assert_eq!(h.len(), s.rows(), "out_scale length");
    }
    let b = x.cols();
    if b == 0 || s.rows() == 0 {
        return;
    }
    let stride = y.stride();
    gemm_sign_scaled_rows(s, in_scale, x, out_scale, y.padded_mut(), stride, 0);
}

/// Row-parallel sign-GEMM: identical output to [`gemm_sign`] (bit-exact;
/// row partitioning changes no reduction order), with output rows split
/// into `threads` range jobs on the persistent process-wide
/// [`SignPool`](super::SignPool) — no per-call thread spawning. `threads
/// <= 1` falls through to the serial kernel. This is the knob the batched
/// serving pool turns — see `coordinator::ServerConfig`.
pub fn gemm_sign_mt(s: &BitMatrix, x: &Mat, y: &mut Mat, threads: usize) {
    assert_eq!(s.cols(), x.rows(), "inner dims: S is m×n, X is n×b");
    assert_eq!(s.rows(), y.rows(), "output rows");
    assert_eq!(x.cols(), y.cols(), "batch width");
    SignPool::for_threads(threads).run_gemm(s, None, x, None, y, threads);
}

/// The PR 1 row-parallel sign-GEMM, spawning `threads` OS threads per call
/// via `std::thread::scope`. Superseded on the hot path by the pool-backed
/// [`gemm_sign_mt`]; kept (and exported) as the measured baseline so
/// `benches/gemm_speedup.rs` can report pool-vs-scoped dispatch overhead.
/// Bit-exact against [`gemm_sign`] and [`gemm_sign_mt`].
pub fn gemm_sign_mt_scoped(s: &BitMatrix, x: &Mat, y: &mut Mat, threads: usize) {
    assert_eq!(s.cols(), x.rows(), "inner dims: S is m×n, X is n×b");
    assert_eq!(s.rows(), y.rows(), "output rows");
    assert_eq!(x.cols(), y.cols(), "batch width");
    let rows = s.rows();
    let b = x.cols();
    if b == 0 || rows == 0 {
        return;
    }
    let threads = threads.max(1).min(rows);
    let stride = y.stride();
    if threads == 1 {
        gemm_sign_rows(s, x, y.padded_mut(), stride, 0);
        return;
    }
    let chunk = rows.div_ceil(threads);
    let y_all = y.padded_mut();
    std::thread::scope(|scope| {
        for (ti, ys) in y_all.chunks_mut(chunk * stride).enumerate() {
            scope.spawn(move || gemm_sign_rows(s, x, ys, stride, ti * chunk));
        }
    });
}

/// Compute output rows `row0..row0 + ys.len()/ys_stride` of `S X` into
/// `ys`, whose rows live `ys_stride` floats apart (the output `Mat`'s
/// padded stride; only the leading `b` floats of each row are written).
///
/// Per output element the reduction mirrors `gemv_sign` exactly: eight
/// accumulators fed word-by-word, strip-by-strip, then summed in lane
/// order — the source of the bit-exactness guarantee.
pub(crate) fn gemm_sign_rows(s: &BitMatrix, x: &Mat, ys: &mut [f32], ys_stride: usize, row0: usize) {
    gemm_sign_out_rows(s, x, None, ys, ys_stride, row0);
}

/// The shared sign-GEMM row-range loop — [`gemm_sign_rows`]'s body with the
/// output scale (when present) folded into each row's final lane
/// reduction: one multiply on the reduced sum, the same rounding a
/// separate output pass would apply. This is the kernel every pool job
/// runs; input scaling happens once per *call* (not per job) via
/// [`with_scaled_block`] before rows are partitioned.
///
/// The range is walked in [`ROW_TILE`]-row cache tiles with the column
/// strips outermost inside each tile; every (row, strip) block dispatches
/// to the AVX2 strip kernel when available (full strips only) or to the
/// scalar oracle [`gemm_strip_scalar`]. Blocks are reduction-independent,
/// so tiling and dispatch change no rounding.
pub(crate) fn gemm_sign_out_rows(
    s: &BitMatrix,
    x: &Mat,
    out_scale: Option<&[f32]>,
    ys: &mut [f32],
    ys_stride: usize,
    row0: usize,
) {
    debug_assert!(s.padding_is_clear(), "sign-GEMM on corrupt bit-plane padding");
    let b = x.cols();
    let cols = s.cols();
    debug_assert!(ys_stride >= b && ys.len() % ys_stride == 0);
    let nrows = ys.len() / ys_stride;
    let avx2 = simd::use_avx2();
    let mut tile0 = 0;
    while tile0 < nrows {
        let tile_end = (tile0 + ROW_TILE).min(nrows);
        let mut c0 = 0;
        while c0 < b {
            let cw = (b - c0).min(COL_STRIP);
            for di in tile0..tile_end {
                let words = s.row_words(row0 + di);
                let sums = if avx2 && cw == COL_STRIP {
                    simd::gemm_row_strip_avx2(words, x, cols, c0)
                } else {
                    gemm_strip_scalar(words, x, cols, c0, cw)
                };
                let yrow = &mut ys[di * ys_stride..di * ys_stride + b];
                match out_scale.map(|h| h[row0 + di]) {
                    Some(hv) => {
                        for t in 0..cw {
                            yrow[c0 + t] = sums[t] * hv;
                        }
                    }
                    None => yrow[c0..c0 + cw].copy_from_slice(&sums[..cw]),
                }
            }
            c0 += cw;
        }
        tile0 = tile_end;
    }
}

/// One packed row × one strip of `cw ≤ 8` batch columns on the scalar lane
/// — the pre-SIMD kernel body kept verbatim as the bit-exactness oracle,
/// the ragged-strip path, and the non-x86 fallback. Returns the pre-scale
/// per-column sums.
pub(crate) fn gemm_strip_scalar(
    words: &[u64],
    x: &Mat,
    cols: usize,
    c0: usize,
    cw: usize,
) -> [f32; COL_STRIP] {
    let full_words = cols / 64;
    // acc[k][t] is gemv_sign's acc[k], replicated per batch column
    // t — the sign word is read once for all cw columns.
    let mut acc = [[0.0f32; COL_STRIP]; 8];
    for (c, &w) in words[..full_words].iter().enumerate() {
        for strip in 0..8 {
            let bits = (w >> (strip * 8)) as u32;
            for k in 0..8 {
                let neg = ((bits >> k) & 1 ^ 1) << 31;
                let xrow = &x.row(c * 64 + strip * 8 + k)[c0..c0 + cw];
                let lane = &mut acc[k];
                for t in 0..cw {
                    lane[t] += f32::from_bits(xrow[t].to_bits() ^ neg);
                }
            }
        }
    }
    if cols % 64 != 0 {
        let w = words[full_words];
        for (k, j) in (full_words * 64..cols).enumerate() {
            let neg = (((w >> k) & 1) as u32 ^ 1) << 31;
            let xrow = &x.row(j)[c0..c0 + cw];
            let lane = &mut acc[k & 7];
            for t in 0..cw {
                lane[t] += f32::from_bits(xrow[t].to_bits() ^ neg);
            }
        }
    }
    let mut sums = [0.0f32; COL_STRIP];
    for (t, sum) in sums.iter_mut().enumerate().take(cw) {
        for lane in &acc {
            *sum += lane[t];
        }
    }
    sums
}

/// Row-range form of the fused GEMM used by the serial entry: the input
/// scale is applied once into the thread-local block, then the plain
/// column-blocked loop streams it with the output scale folded into the
/// lane reduction. Bit-exactness: the block holds the same
/// `in_scale[j]·x[j][t]` products the unfused `scale_rows` pass would
/// produce (one f32 multiply each, formed once), the accumulation order is
/// identical to [`gemm_sign_rows`], and the output scale is one multiply
/// on the reduced sum.
fn gemm_sign_scaled_rows(
    s: &BitMatrix,
    in_scale: Option<&[f32]>,
    x: &Mat,
    out_scale: Option<&[f32]>,
    ys: &mut [f32],
    ys_stride: usize,
    row0: usize,
) {
    match in_scale {
        Some(g) => {
            with_scaled_block(x, g, |xg| gemm_sign_out_rows(s, xg, out_scale, ys, ys_stride, row0))
        }
        None => gemm_sign_out_rows(s, x, out_scale, ys, ys_stride, row0),
    }
}

/// Row-parallel sign-GEMV: identical output to
/// [`gemv_sign`](super::gemv_sign) (bit-exact), rows split into `threads`
/// range jobs on the persistent [`SignPool`](super::SignPool). The
/// single-request analogue of [`gemm_sign_mt`].
pub fn gemv_sign_mt(s: &BitMatrix, x: &[f32], y: &mut [f32], threads: usize) {
    assert_eq!(s.cols(), x.len());
    assert_eq!(s.rows(), y.len());
    SignPool::for_threads(threads).run_gemv(s, None, x, None, y, threads);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::packing::gemv_sign;
    use crate::rng::Pcg64;

    fn random_block(rows: usize, cols: usize, rng: &mut Pcg64) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        m.fill_normal(rng);
        m
    }

    /// The acceptance contract: gemm_sign column t must equal gemv_sign on
    /// column t of X, to exact bit equality (same accumulators, same
    /// order).
    #[test]
    fn gemm_matches_gemv_bit_exactly() {
        let mut rng = Pcg64::seed(21);
        for (m, n, b) in [(4, 4, 1), (16, 64, 3), (33, 130, 8), (8, 200, 9), (7, 65, 32)] {
            let s = BitMatrix::from_dense(&Mat::gaussian(m, n, &mut rng).signum());
            let x = random_block(n, b, &mut rng);
            let mut y = Mat::zeros(m, b);
            gemm_sign(&s, &x, &mut y);
            for t in 0..b {
                let xt = x.col(t);
                let mut want = vec![0.0f32; m];
                gemv_sign(&s, &xt, &mut want);
                for i in 0..m {
                    assert_eq!(
                        y.at(i, t).to_bits(),
                        want[i].to_bits(),
                        "{m}x{n} b={b}: ({i},{t}) {} vs {}",
                        y.at(i, t),
                        want[i]
                    );
                }
            }
        }
    }

    /// The fused-GEMM acceptance contract: folding both scales into the
    /// kernel must be bit-exact against the unfused
    /// scale_rows → gemm_sign → scale_rows composition, across ragged
    /// shapes (cols % 64 ≠ 0 spanning multiple words plus a tail), batch
    /// widths crossing the 8-column strip boundary, and every
    /// present/absent scale combination.
    #[test]
    fn gemm_scaled_matches_unfused_composition_bit_exactly() {
        let mut rng = Pcg64::seed(26);
        for (m, n, b) in [
            (4, 4, 1),
            (16, 64, 3),
            (33, 130, 8),
            (8, 200, 9),
            (7, 65, 32),
            (12, 63, 5),
            (9, 191, 13),
        ] {
            let s = BitMatrix::from_dense(&Mat::gaussian(m, n, &mut rng).signum());
            let x = random_block(n, b, &mut rng);
            let mut g = vec![0.0f32; n];
            let mut h = vec![0.0f32; m];
            rng.fill_uniform(&mut g, 0.2, 1.8);
            rng.fill_uniform(&mut h, 0.2, 1.8);

            for (ins, outs) in [
                (Some(g.as_slice()), Some(h.as_slice())),
                (Some(g.as_slice()), None),
                (None, Some(h.as_slice())),
                (None, None),
            ] {
                // Unfused reference: explicit scale passes around gemm_sign.
                let xin = match ins {
                    Some(gv) => x.scale_rows(gv),
                    None => x.clone(),
                };
                let mut want = Mat::zeros(m, b);
                gemm_sign(&s, &xin, &mut want);
                let want = match outs {
                    Some(hv) => want.scale_rows(hv),
                    None => want,
                };
                let mut got = Mat::zeros(m, b);
                gemm_sign_scaled(&s, ins, &x, outs, &mut got);
                for (i, (a, c)) in want.to_vec().iter().zip(got.to_vec()).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        c.to_bits(),
                        "{m}x{n} b={b} ins={} outs={} flat {i}: {a} vs {c}",
                        ins.is_some(),
                        outs.is_some()
                    );
                }
            }
        }
    }

    #[test]
    fn gemm_mt_matches_serial_bit_exactly() {
        let mut rng = Pcg64::seed(22);
        let (m, n, b) = (61, 130, 12);
        let s = BitMatrix::from_dense(&Mat::gaussian(m, n, &mut rng).signum());
        let x = random_block(n, b, &mut rng);
        let mut serial = Mat::zeros(m, b);
        gemm_sign(&s, &x, &mut serial);
        for threads in [2, 3, 7, 64] {
            let mut mt = Mat::zeros(m, b);
            gemm_sign_mt(&s, &x, &mut mt, threads);
            assert_eq!(serial, mt, "pooled threads={threads}");
            let mut scoped = Mat::zeros(m, b);
            gemm_sign_mt_scoped(&s, &x, &mut scoped, threads);
            assert_eq!(serial, scoped, "scoped threads={threads}");
        }
    }

    #[test]
    fn gemv_mt_matches_serial_bit_exactly() {
        let mut rng = Pcg64::seed(23);
        let (m, n) = (77, 190);
        let s = BitMatrix::from_dense(&Mat::gaussian(m, n, &mut rng).signum());
        let mut x = vec![0.0f32; n];
        rng.fill_normal(&mut x);
        let mut serial = vec![0.0f32; m];
        gemv_sign(&s, &x, &mut serial);
        for threads in [2, 5, 128] {
            let mut mt = vec![0.0f32; m];
            gemv_sign_mt(&s, &x, &mut mt, threads);
            for (a, c) in serial.iter().zip(&mt) {
                assert_eq!(a.to_bits(), c.to_bits(), "threads={threads}");
            }
        }
    }

    /// Numeric check against the dense product (catches systematic sign
    /// errors the bit-equality test cannot — both kernels could agree and
    /// be wrong together).
    #[test]
    fn gemm_matches_dense_product() {
        let mut rng = Pcg64::seed(24);
        let (m, n, b) = (19, 70, 5);
        let sd = Mat::gaussian(m, n, &mut rng).signum();
        let s = BitMatrix::from_dense(&sd);
        let x = random_block(n, b, &mut rng);
        let want = sd.matmul(&x);
        let mut got = Mat::zeros(m, b);
        gemm_sign(&s, &x, &mut got);
        for (a, c) in want.to_vec().iter().zip(got.to_vec()) {
            assert!((a - c).abs() < 1e-3 * (n as f32).sqrt(), "{a} vs {c}");
        }
    }

    /// Same systematic check for the fused kernel: scales folded in must
    /// track the dense diag(h)·S·diag(g) product numerically.
    #[test]
    fn gemm_scaled_matches_dense_product() {
        let mut rng = Pcg64::seed(27);
        let (m, n, b) = (19, 70, 5);
        let sd = Mat::gaussian(m, n, &mut rng).signum();
        let s = BitMatrix::from_dense(&sd);
        let x = random_block(n, b, &mut rng);
        let mut g = vec![0.0f32; n];
        let mut h = vec![0.0f32; m];
        rng.fill_uniform(&mut g, 0.2, 1.8);
        rng.fill_uniform(&mut h, 0.2, 1.8);
        let want = sd.scale_rows(&h).scale_cols(&g).matmul(&x);
        let mut got = Mat::zeros(m, b);
        gemm_sign_scaled(&s, Some(&g), &x, Some(&h), &mut got);
        for (a, c) in want.to_vec().iter().zip(got.to_vec()) {
            assert!((a - c).abs() < 2e-3 * (n as f32).sqrt(), "{a} vs {c}");
        }
    }

    #[test]
    fn empty_batch_is_a_no_op() {
        let mut rng = Pcg64::seed(25);
        let s = BitMatrix::from_dense(&Mat::gaussian(5, 9, &mut rng).signum());
        let x = Mat::zeros(9, 0);
        let mut y = Mat::zeros(5, 0);
        gemm_sign(&s, &x, &mut y);
        gemm_sign_mt(&s, &x, &mut y, 4);
        gemm_sign_scaled(&s, None, &x, None, &mut y);
        gemm_sign_mt_scoped(&s, &x, &mut y, 4);
    }
}
