//! Runtime-dispatched SIMD lanes for the sign kernels and the dense saxpy.
//!
//! Every hot kernel exists twice: the scalar path (in `gemv.rs` / `gemm.rs`
//! / `mat.rs`, unchanged from the pre-SIMD revisions — it is the
//! bit-exactness oracle and the non-x86 fallback) and an AVX2 path here.
//! Dispatch happens once per kernel call via [`use_avx2`]:
//! `is_x86_feature_detected!("avx2")` (cached by the standard library) AND
//! not forced off. The `LB2_FORCE_SCALAR=1` environment variable (read
//! once) or the programmatic [`force_scalar`] toggle pin the scalar lane —
//! CI runs the whole suite once per lane, and the benches flip the toggle
//! in-process to measure both.
//!
//! **Bit-exactness.** The AVX2 lanes are constructed to perform the exact
//! FP operations of their scalar oracles in the exact order, per output
//! element:
//!
//! * The sign-GEMV keeps the scalar's eight accumulators as the eight
//!   lanes of one `__m256`; each 64-bit sign word feeds eight 8-lane
//!   strips in strip order, so lane `k` sees the same additions in the
//!   same order as scalar `acc[k]`. The ragged tail (cols % 64) runs the
//!   verbatim scalar tail on the extracted lanes, and the final reduction
//!   is the same sequential lane-order sum.
//! * The sign-GEMM vectorizes across the **batch** dimension: scalar
//!   `acc[k][0..8]` becomes one `__m256` per `k`, updated in the same
//!   `(word, strip, k)` order. Partial strips (batch % 8) fall back to the
//!   scalar strip kernel.
//! * No FMA anywhere — `mul` then `add` keeps the scalar's two roundings.
//! * XNOR-popcount is integer (vpshufb nibble LUT + vpsadbw), exact by
//!   construction.
//! * `axpy` is element-wise (no reduction), so vectorization cannot
//!   reorder anything.
//!
//! All lanes tolerate (and exploit) the padded layouts: `BitMatrix` rows
//! are 4-word / 32-byte blocks with clear padding (asserted at kernel
//! entry), `Mat` rows are 8-float / 32-byte blocks with zero padding, so
//! 256-bit loads never straddle a row boundary. Where the base address is
//! provably 32-byte aligned the loads are the aligned forms (`vmovaps` in
//! the GEMM batch strips — `Mat` rows always are; `vmovdqa` in
//! XNOR-popcount after a per-call base check); loads from caller-supplied
//! `x` vectors and the dense saxpy stay unaligned, since plain `Vec<f32>`
//! carries no such guarantee.

use crate::linalg::Mat;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;

/// Which kernel implementation the dispatcher selects.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Lane {
    /// Portable scalar kernels — the bit-exactness oracle.
    Scalar,
    /// AVX2 256-bit kernels (x86-64 with runtime AVX2 support).
    Avx2,
}

impl Lane {
    /// Stable lowercase name, used by the bench JSON `lane` field.
    pub fn name(self) -> &'static str {
        match self {
            Lane::Scalar => "scalar",
            Lane::Avx2 => "avx2",
        }
    }
}

/// Parse a force-scalar environment value: "1", "true", "yes", "on"
/// (case-insensitive) engage the override; anything else (or unset)
/// leaves dispatch to hardware detection.
fn parse_force_scalar(v: Option<&str>) -> bool {
    matches!(
        v.map(|s| s.trim().to_ascii_lowercase()).as_deref(),
        Some("1" | "true" | "yes" | "on")
    )
}

/// The force-scalar flag: seeded once from `LB2_FORCE_SCALAR`, then
/// adjustable in-process via [`force_scalar`] (tests and benches exercise
/// both lanes without re-exec'ing under a different environment).
fn force_flag() -> &'static AtomicBool {
    static FLAG: OnceLock<AtomicBool> = OnceLock::new();
    FLAG.get_or_init(|| {
        AtomicBool::new(parse_force_scalar(std::env::var("LB2_FORCE_SCALAR").ok().as_deref()))
    })
}

/// Pin (or unpin) the scalar lane for this process, overriding hardware
/// detection. Takes effect on the next kernel call.
pub fn force_scalar(on: bool) {
    force_flag().store(on, Ordering::Relaxed);
}

/// True when the scalar lane is pinned (env var or [`force_scalar`]).
pub fn scalar_forced() -> bool {
    force_flag().load(Ordering::Relaxed)
}

/// True when kernel calls will take the AVX2 lane right now.
#[inline]
pub fn use_avx2() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        !scalar_forced() && std::arch::is_x86_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// The lane the next kernel call will run on.
pub fn active_lane() -> Lane {
    if use_avx2() {
        Lane::Avx2
    } else {
        Lane::Scalar
    }
}

/// `y[i] += a * x[i]` — the dense matmul's saxpy inner loop. Element-wise
/// (one mul + one add per element in both lanes), so the AVX2 path is
/// bit-identical to scalar.
#[inline]
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    #[cfg(target_arch = "x86_64")]
    if x.len() >= 8 && use_avx2() {
        unsafe { avx2::axpy(a, x, y) };
        return;
    }
    axpy_scalar(a, x, y);
}

#[inline]
pub(crate) fn axpy_scalar(a: f32, x: &[f32], y: &mut [f32]) {
    for (o, b) in y.iter_mut().zip(x) {
        *o += a * *b;
    }
}

/// AVX2 sign-GEMV over one packed row: returns the lane-order sum the
/// scalar `gemv_row_scalar` would produce, bit for bit. Caller guarantees
/// [`use_avx2`] (only reachable on x86-64).
#[cfg(target_arch = "x86_64")]
#[inline]
pub(crate) fn gemv_row_avx2(words: &[u64], x: &[f32], cols: usize) -> f32 {
    unsafe { avx2::gemv_row(words, x, cols) }
}

#[cfg(not(target_arch = "x86_64"))]
pub(crate) fn gemv_row_avx2(_words: &[u64], _x: &[f32], _cols: usize) -> f32 {
    unreachable!("AVX2 lane dispatched on non-x86 target")
}

/// AVX2 sign-GEMM strip: the per-(row, 8-column-strip) sums the scalar
/// strip kernel would produce for a **full** strip (`cw == 8`), bit for
/// bit. Caller guarantees [`use_avx2`] and `c0 + 8 <= x.cols()`.
#[cfg(target_arch = "x86_64")]
#[inline]
pub(crate) fn gemm_row_strip_avx2(words: &[u64], x: &Mat, cols: usize, c0: usize) -> [f32; 8] {
    unsafe { avx2::gemm_row_strip(words, x, cols, c0) }
}

#[cfg(not(target_arch = "x86_64"))]
pub(crate) fn gemm_row_strip_avx2(_words: &[u64], _x: &Mat, _cols: usize, _c0: usize) -> [f32; 8] {
    unreachable!("AVX2 lane dispatched on non-x86 target")
}

/// AVX2 XNOR-popcount over two equal-length padded rows (lengths are
/// 4-word multiples by the `BitMatrix` stride invariant). Integer-exact
/// against the scalar `count_ones` loop.
#[cfg(target_arch = "x86_64")]
#[inline]
pub(crate) fn xnor_row_popcount_avx2(a: &[u64], b: &[u64]) -> u32 {
    unsafe { avx2::xnor_row_popcount(a, b) }
}

#[cfg(not(target_arch = "x86_64"))]
pub(crate) fn xnor_row_popcount_avx2(_a: &[u64], _b: &[u64]) -> u32 {
    unreachable!("AVX2 lane dispatched on non-x86 target")
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use crate::linalg::Mat;
    use std::arch::x86_64::*;

    /// # Safety
    /// AVX2 must be available (dispatcher-checked); `x.len() == y.len()`.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
        let n = x.len();
        let av = _mm256_set1_ps(a);
        let mut i = 0;
        while i + 8 <= n {
            let xv = _mm256_loadu_ps(x.as_ptr().add(i));
            let yv = _mm256_loadu_ps(y.as_ptr().add(i));
            // mul + add, NOT fma: the scalar oracle rounds twice.
            _mm256_storeu_ps(y.as_mut_ptr().add(i), _mm256_add_ps(yv, _mm256_mul_ps(av, xv)));
            i += 8;
        }
        while i < n {
            *y.get_unchecked_mut(i) += a * *x.get_unchecked(i);
            i += 1;
        }
    }

    /// One packed sign row · `x`, `cols` logical columns (`x.len() ==
    /// cols`). The eight scalar accumulators live as the eight lanes of
    /// `accv`; strip order and the sequential lane-order reduction match
    /// the scalar kernel exactly.
    ///
    /// # Safety
    /// AVX2 available; `words` holds at least `⌈cols/64⌉` words.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn gemv_row(words: &[u64], x: &[f32], cols: usize) -> f32 {
        debug_assert_eq!(x.len(), cols);
        let full_words = cols / 64;
        // Lane k selects bit k of the strip byte.
        let bitsel = _mm256_setr_epi32(1, 2, 4, 8, 16, 32, 64, 128);
        let signbit = _mm256_set1_epi32(i32::MIN);
        let mut accv = _mm256_setzero_ps();
        for c in 0..full_words {
            let w = *words.get_unchecked(c);
            let base = x.as_ptr().add(c * 64);
            for strip in 0..8 {
                let bits = _mm256_set1_epi32(((w >> (strip * 8)) & 0xff) as i32);
                // Bit set ⇒ +1 ⇒ flip nothing; bit clear ⇒ xor the IEEE
                // sign bit — identical to the scalar `(bit̄) << 31` mask.
                let is_set = _mm256_cmpeq_epi32(_mm256_and_si256(bits, bitsel), bitsel);
                let neg = _mm256_andnot_si256(is_set, signbit);
                let xv = _mm256_loadu_ps(base.add(strip * 8));
                let signed =
                    _mm256_castsi256_ps(_mm256_xor_si256(_mm256_castps_si256(xv), neg));
                accv = _mm256_add_ps(accv, signed);
            }
        }
        let mut acc = [0.0f32; 8];
        _mm256_storeu_ps(acc.as_mut_ptr(), accv);
        // Ragged tail: verbatim scalar tail on the extracted lanes.
        if cols % 64 != 0 {
            let w = *words.get_unchecked(full_words);
            for (k, &xv) in x[full_words * 64..].iter().enumerate() {
                let neg = (((w >> k) & 1) as u32 ^ 1) << 31;
                acc[k & 7] += f32::from_bits(xv.to_bits() ^ neg);
            }
        }
        acc.iter().sum()
    }

    /// One packed sign row against a full 8-column batch strip of `x`
    /// (feature-major `n × b`): returns the eight per-column sums. Scalar
    /// `acc[k][t]` becomes `accv[k]` lane `t`, updated in identical
    /// `(word, strip, k)` order; the tail and the k-sequential final
    /// reduction run in scalar on the extracted lanes.
    ///
    /// # Safety
    /// AVX2 available; `c0 + 8 <= x.cols()`; `c0 % 8 == 0`; `words` holds
    /// at least `⌈cols/64⌉` words; `x.rows() == cols`.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn gemm_row_strip(
        words: &[u64],
        x: &Mat,
        cols: usize,
        c0: usize,
    ) -> [f32; 8] {
        // Every `Mat` row starts 32-byte aligned (AlignedF32 backing,
        // 8-float stride) and the strip kernel only ever gets c0 in whole
        // 8-column steps, so the strip loads below can be aligned loads.
        debug_assert_eq!(c0 % 8, 0);
        debug_assert_eq!(x.stride() % 8, 0);
        debug_assert_eq!(x.padded().as_ptr() as usize % 32, 0);
        let full_words = cols / 64;
        let mut accv = [_mm256_setzero_ps(); 8];
        for c in 0..full_words {
            let w = *words.get_unchecked(c);
            for strip in 0..8 {
                let bits = (w >> (strip * 8)) as u32;
                for k in 0..8 {
                    // One sign bit governs the whole batch strip: broadcast
                    // the scalar's `(bit̄) << 31` mask across all 8 lanes.
                    let neg = _mm256_set1_epi32(((((bits >> k) & 1) ^ 1) << 31) as i32);
                    let xrow = x.row(c * 64 + strip * 8 + k);
                    let xv = _mm256_load_ps(xrow.as_ptr().add(c0));
                    let signed =
                        _mm256_castsi256_ps(_mm256_xor_si256(_mm256_castps_si256(xv), neg));
                    accv[k] = _mm256_add_ps(accv[k], signed);
                }
            }
        }
        let mut acc = [[0.0f32; 8]; 8];
        for k in 0..8 {
            _mm256_storeu_ps(acc[k].as_mut_ptr(), accv[k]);
        }
        if cols % 64 != 0 {
            let w = *words.get_unchecked(full_words);
            for (k, j) in (full_words * 64..cols).enumerate() {
                let neg = (((w >> k) & 1) as u32 ^ 1) << 31;
                let xrow = &x.row(j)[c0..c0 + 8];
                let lane = &mut acc[k & 7];
                for t in 0..8 {
                    lane[t] += f32::from_bits(xrow[t].to_bits() ^ neg);
                }
            }
        }
        let mut out = [0.0f32; 8];
        for (t, o) in out.iter_mut().enumerate() {
            let mut sum = 0.0f32;
            for lane in &acc {
                sum += lane[t];
            }
            *o = sum;
        }
        out
    }

    /// popcount(a ⊕ b) over two equal-length rows via the vpshufb nibble
    /// LUT + vpsadbw reduction. Integer arithmetic — exact regardless of
    /// order. Rows are whole 4-word (32-byte) blocks by the stride
    /// invariant, so no scalar tail exists.
    ///
    /// Row bases are *usually* 32-byte aligned (AlignedU64 blocks and
    /// mmap'd v3 planes both are), but the slices arrive as plain `&[u64]`
    /// with no type-level guarantee, so alignment is checked once per call
    /// and the loop dispatches to `vmovdqa` or `vmovdqu` accordingly.
    ///
    /// # Safety
    /// AVX2 available; `a.len() == b.len()` and `len % 4 == 0`.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn xnor_row_popcount(a: &[u64], b: &[u64]) -> u32 {
        debug_assert_eq!(a.len(), b.len());
        debug_assert_eq!(a.len() % 4, 0);
        if (a.as_ptr() as usize | b.as_ptr() as usize) % 32 == 0 {
            xnor_row_popcount_body::<true>(a, b)
        } else {
            xnor_row_popcount_body::<false>(a, b)
        }
    }

    /// # Safety
    /// As [`xnor_row_popcount`]; `ALIGNED` additionally asserts both base
    /// pointers are 32-byte aligned.
    #[target_feature(enable = "avx2")]
    unsafe fn xnor_row_popcount_body<const ALIGNED: bool>(a: &[u64], b: &[u64]) -> u32 {
        #[rustfmt::skip]
        let lut = _mm256_setr_epi8(
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
        );
        let low = _mm256_set1_epi8(0x0f);
        let zero = _mm256_setzero_si256();
        let mut sums = _mm256_setzero_si256(); // four u64 partial counts
        let mut i = 0;
        while i < a.len() {
            let pa = a.as_ptr().add(i) as *const __m256i;
            let pb = b.as_ptr().add(i) as *const __m256i;
            let (va, vb) = if ALIGNED {
                (_mm256_load_si256(pa), _mm256_load_si256(pb))
            } else {
                (_mm256_loadu_si256(pa), _mm256_loadu_si256(pb))
            };
            let x = _mm256_xor_si256(va, vb);
            let lo = _mm256_shuffle_epi8(lut, _mm256_and_si256(x, low));
            let hi = _mm256_shuffle_epi8(lut, _mm256_and_si256(_mm256_srli_epi16::<4>(x), low));
            let cnt = _mm256_add_epi8(lo, hi); // per-byte popcounts, ≤ 8
            sums = _mm256_add_epi64(sums, _mm256_sad_epu8(cnt, zero));
            i += 4;
        }
        let mut parts = [0u64; 4];
        _mm256_storeu_si256(parts.as_mut_ptr() as *mut __m256i, sums);
        (parts[0] + parts[1] + parts[2] + parts[3]) as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn env_values_parse() {
        for on in ["1", "true", "yes", "on", " TRUE ", "On"] {
            assert!(parse_force_scalar(Some(on)), "{on:?} should force scalar");
        }
        for off in ["0", "false", "no", "off", "", "2", "avx2"] {
            assert!(!parse_force_scalar(Some(off)), "{off:?} should not force scalar");
        }
        assert!(!parse_force_scalar(None));
    }

    #[test]
    fn force_scalar_toggle_pins_the_lane() {
        let was = scalar_forced();
        force_scalar(true);
        assert_eq!(active_lane(), Lane::Scalar);
        assert!(!use_avx2());
        force_scalar(was);
    }

    #[test]
    fn lane_names_are_stable() {
        assert_eq!(Lane::Scalar.name(), "scalar");
        assert_eq!(Lane::Avx2.name(), "avx2");
    }

    #[test]
    fn axpy_lanes_are_bit_identical() {
        use crate::rng::Pcg64;
        let mut rng = Pcg64::seed(40);
        for n in [1usize, 7, 8, 9, 64, 65] {
            let mut x = vec![0.0f32; n];
            let mut y0 = vec![0.0f32; n];
            rng.fill_normal(&mut x);
            rng.fill_normal(&mut y0);
            let a = rng.normal_f32();
            let mut y1 = y0.clone();
            axpy_scalar(a, &x, &mut y0);
            axpy(a, &x, &mut y1); // whichever lane is active
            for (p, q) in y0.iter().zip(&y1) {
                assert_eq!(p.to_bits(), q.to_bits(), "n={n}");
            }
        }
    }

    /// The XNOR kernel picks `vmovdqa` vs `vmovdqu` per call from the row
    /// base addresses; all three cases (both aligned, both misaligned,
    /// mixed) must agree with the scalar popcount.
    #[test]
    #[cfg(target_arch = "x86_64")]
    fn xnor_avx2_aligned_and_unaligned_bases_agree() {
        if !std::arch::is_x86_feature_detected!("avx2") {
            return;
        }
        use crate::linalg::AlignedU64;
        let mut a = AlignedU64::zeros(16);
        let mut b = AlignedU64::zeros(16);
        for (i, w) in a.as_mut_slice().iter_mut().enumerate() {
            *w = (i as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        }
        for (i, w) in b.as_mut_slice().iter_mut().enumerate() {
            *w = (i as u64 + 17).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        }
        let (a, b) = (a.as_slice(), b.as_slice());
        // 12 words each: &s[..12] keeps the 32-byte base, &s[1..13] is
        // 8-byte offset — deterministically misaligned.
        for (sa, sb) in [(&a[..12], &b[..12]), (&a[1..13], &b[1..13]), (&a[..12], &b[1..13])] {
            let want: u32 = sa.iter().zip(sb.iter()).map(|(x, y)| (x ^ y).count_ones()).sum();
            assert_eq!(unsafe { avx2::xnor_row_popcount(sa, sb) }, want);
        }
    }

    /// The wider lane-vs-oracle suites live with the kernels; this checks
    /// the AVX2 axpy directly whenever the machine has it.
    #[test]
    #[cfg(target_arch = "x86_64")]
    fn axpy_avx2_matches_scalar_when_available() {
        if !std::arch::is_x86_feature_detected!("avx2") {
            return;
        }
        use crate::rng::Pcg64;
        let mut rng = Pcg64::seed(41);
        let mut x = vec![0.0f32; 100];
        let mut ys = vec![0.0f32; 100];
        rng.fill_normal(&mut x);
        rng.fill_normal(&mut ys);
        let mut ya = ys.clone();
        axpy_scalar(1.75, &x, &mut ys);
        unsafe { avx2::axpy(1.75, &x, &mut ya) };
        for (p, q) in ys.iter().zip(&ya) {
            assert_eq!(p.to_bits(), q.to_bits());
        }
    }
}
