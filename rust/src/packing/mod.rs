//! Bit-packed binary matrices and the MatMul-free GEMV hot path (§6.2).
//!
//! The deployed LittleBit layer stores the latent factors `U_b, V_b ∈ {±1}`
//! at 1 bit/entry and replaces the dense FP GEMV
//! `y = W x` (d_out·d_in MACs) with the tri-scale low-rank pipeline
//!
//! ```text
//! y = h ⊙ ( U_b · ( l ⊙ ( V_bᵀ · (g ⊙ x) ) ) )        (Eq. 1)
//! ```
//!
//! which costs `r·(d_in + d_out)` sign-adds plus three `O(d)` element-wise
//! scales — at 0.1 bpp this is >40× fewer operations and 32× less weight
//! traffic (1 bit vs 32). The paper reports 11.6× kernel-level speedup vs
//! cuBLAS FP16 on a 70B MLP; `benches/gemv_speedup.rs` reproduces the shape
//! of that claim on this CPU.
//!
//! Layout: bit j of word w = sign of column `64·w + j` (set bit ⇒ +1).
//! In memory each row is padded to a 4-word (32-byte) boundary and the
//! backing allocation is 32-byte aligned ([`BitMatrix::words_per_row`];
//! padding bits are always zero — an invariant the kernels assert); on
//! disk rows stay tight at ⌈cols/64⌉ words, byte-identical to the
//! pre-padding `.lb2` encoding. Sign application in the GEMV is a single
//! XOR on the IEEE sign bit; row reductions run on eight independent
//! accumulators to keep the FP-add chain off the critical path (§Perf).
//!
//! Every sign kernel dispatches at runtime between a scalar lane — the
//! original loop, kept verbatim as the bit-exactness oracle and non-x86
//! path — and an AVX2 lane gated on `is_x86_feature_detected!` (`simd`
//! module). The AVX2 lanes map the scalar code's eight accumulators onto
//! vector lanes without reassociating any reduction, so both lanes produce
//! identical bits; `LB2_FORCE_SCALAR=1` (or [`simd::force_scalar`]) pins
//! the scalar lane for A/B testing and CI.
//!
//! At batch > 1 the same weights are driven through the batched sign-GEMM
//! ([`gemm_sign`], `gemm` module): activations are handled as a feature-
//! major `d × b` block and each packed sign word is loaded once per strip
//! of 8 batch columns instead of once per request. The deployed tri-scale
//! pipeline runs the **scale-fused** kernels ([`gemv_sign_scaled`] /
//! [`gemm_sign_scaled`]): `g`/`l` fold into the sign-XOR loop and `h` into
//! the final lane reduction, eliminating every separate element-wise pass
//! — bit-exactly. Row-parallel `*_mt` variants split either kernel into
//! row-range jobs on the persistent [`SignPool`] (`pool` module; no
//! per-call thread spawning); batching, fusion, and threading are all
//! bit-exact against the serial GEMV. [`PackedResidual`] composes the
//! packed paths of one compressed layer for serving, and [`BatchScratch`]
//! carries the reusable latent/output blocks that make the batched forward
//! allocation-free across requests.

mod bitmat;
mod gemm;
mod gemv;
mod pool;
mod residual;
pub mod simd;

pub use bitmat::BitMatrix;
pub use simd::{active_lane, force_scalar, scalar_forced, Lane};
pub use gemm::{gemm_sign, gemm_sign_mt, gemm_sign_mt_scoped, gemm_sign_scaled, gemv_sign_mt};
pub use gemv::{
    gemv_dense, gemv_sign, gemv_sign_scaled, tri_scale_gemv, xnor_popcount_gemm,
    BatchScratch, Scratch, TriScaleLayer,
};
pub use pool::SignPool;
pub use residual::PackedResidual;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::rng::Pcg64;

    #[test]
    fn tri_scale_pipeline_matches_dense_reconstruction() {
        let mut rng = Pcg64::seed(5);
        let (d_out, d_in, r) = (96, 80, 16);
        let ub = Mat::gaussian(d_out, r, &mut rng).signum();
        let vb = Mat::gaussian(d_in, r, &mut rng).signum();
        let mut h = vec![0.0f32; d_out];
        let mut l = vec![0.0f32; r];
        let mut g = vec![0.0f32; d_in];
        rng.fill_uniform(&mut h, 0.5, 1.5);
        rng.fill_uniform(&mut l, 0.1, 1.0);
        rng.fill_uniform(&mut g, 0.5, 1.5);

        let layer = TriScaleLayer::new(&ub, &vb, h.clone(), l.clone(), g.clone());

        // Dense reference: diag(h)·Ub·diag(l)·Vbᵀ·diag(g).
        let w = ub
            .scale_rows(&h)
            .scale_cols(&l)
            .matmul_t(&vb.scale_rows(&g));
        let mut x = vec![0.0f32; d_in];
        rng.fill_normal(&mut x);
        let want = w.matvec(&x);
        let got = layer.forward(&x);
        for (a, b) in want.iter().zip(&got) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }
}
