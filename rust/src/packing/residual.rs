//! The deployed residual composition: every packed path of one compressed
//! linear layer, executed together (App. G's `Ŵ = Σ_p Ŵ_p` at the bit
//! level). This is what the serving stack holds per layer — packing once at
//! load time, then running single requests through the scratch-reusing GEMV
//! pipeline or whole batches through the sign-GEMM pipeline.

use super::{BatchScratch, Scratch, SignPool, TriScaleLayer};
use crate::linalg::Mat;

/// All packed paths of one compressed layer (the paper deploys 2).
///
/// Built via `littlebit::ResidualCompressed::pack`, or directly from
/// [`TriScaleLayer`] values.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedResidual {
    paths: Vec<TriScaleLayer>,
}

impl PackedResidual {
    /// Compose packed paths; all must share `d_in`/`d_out`.
    pub fn new(paths: Vec<TriScaleLayer>) -> Self {
        assert!(!paths.is_empty(), "at least one path");
        for p in &paths[1..] {
            assert_eq!(p.d_in(), paths[0].d_in(), "path d_in mismatch");
            assert_eq!(p.d_out(), paths[0].d_out(), "path d_out mismatch");
        }
        Self { paths }
    }

    /// Fallible [`new`](Self::new) for deserialization boundaries (the
    /// `.lb2` load path): malformed path sets return `Err` instead of
    /// panicking.
    pub fn try_new(paths: Vec<TriScaleLayer>) -> anyhow::Result<Self> {
        if paths.is_empty() {
            anyhow::bail!("residual layer needs at least one path");
        }
        for (k, p) in paths.iter().enumerate().skip(1) {
            if p.d_in() != paths[0].d_in() || p.d_out() != paths[0].d_out() {
                anyhow::bail!(
                    "path {k} is {}x{} but path 0 is {}x{}",
                    p.d_out(),
                    p.d_in(),
                    paths[0].d_out(),
                    paths[0].d_in()
                );
            }
        }
        Ok(Self { paths })
    }

    pub fn paths(&self) -> &[TriScaleLayer] {
        &self.paths
    }

    pub fn d_in(&self) -> usize {
        self.paths[0].d_in()
    }

    pub fn d_out(&self) -> usize {
        self.paths[0].d_out()
    }

    /// Total weight-storage bytes across paths.
    pub fn storage_bytes(&self) -> usize {
        self.paths.iter().map(|p| p.storage_bytes()).sum()
    }

    /// Heap-held weight bytes across all paths (0-contribution from
    /// mapped planes; see [`TriScaleLayer::resident_bytes`]).
    pub fn resident_bytes(&self) -> usize {
        self.paths.iter().map(|p| p.resident_bytes()).sum()
    }

    /// Page-cache-backed weight bytes across all paths.
    pub fn mapped_bytes(&self) -> usize {
        self.paths.iter().map(|p| p.mapped_bytes()).sum()
    }

    /// Total operation count of one forward: (sign-adds, fp-mults).
    pub fn op_counts(&self) -> (usize, usize) {
        self.paths.iter().fold((0, 0), |(a, m), p| {
            let (pa, pm) = p.op_counts();
            (a + pa, m + pm)
        })
    }

    /// Single-request forward: sum of path outputs.
    pub fn forward(&self, x: &[f32]) -> Vec<f32> {
        let mut scratch = Scratch::default();
        let mut out = vec![0.0f32; self.d_out()];
        self.forward_into(x, &mut out, &mut scratch);
        out
    }

    /// Allocation-free single-request forward for hot loops.
    pub fn forward_into(&self, x: &[f32], out: &mut [f32], scratch: &mut Scratch) {
        self.paths[0].forward_into(x, out, scratch);
        for p in &self.paths[1..] {
            p.forward_accumulate(x, out, scratch);
        }
    }

    /// Batched forward: `X` is `d_in × b` feature-major (column `t` is
    /// batch item `t`); returns `d_out × b`. Column `t` is bit-identical
    /// to [`forward`](Self::forward) on item `t`.
    pub fn forward_batch(&self, x: &Mat) -> Mat {
        self.forward_batch_mt(x, 1)
    }

    /// [`forward_batch`](Self::forward_batch) with the fused sign-GEMMs
    /// split into `threads` row ranges on the process-wide [`SignPool`].
    pub fn forward_batch_mt(&self, x: &Mat, threads: usize) -> Mat {
        let mut y = Mat::default();
        let mut scratch = BatchScratch::default();
        self.forward_batch_into(x, &mut y, &mut scratch, SignPool::for_threads(threads), threads);
        y
    }

    /// Allocation-free batched forward — the serving hot path. `y` is
    /// resized to `d_out × b` in place; every path runs the fused
    /// sign-GEMM pipeline through `scratch` (latent + per-path blocks,
    /// reused across calls), with row ranges executed on `pool`. Column
    /// `t` stays bit-identical to [`forward`](Self::forward) on item `t`.
    pub fn forward_batch_into(
        &self,
        x: &Mat,
        y: &mut Mat,
        scratch: &mut BatchScratch,
        pool: &SignPool,
        threads: usize,
    ) {
        self.paths[0].forward_batch_into(x, y, scratch, pool, threads);
        if self.paths.len() > 1 {
            // Reborrow dance (cf. forward_accumulate): the per-path output
            // block leaves the scratch while the scratch's latent block is
            // in use, then returns.
            let mut tmp = std::mem::take(&mut scratch.path_out);
            for p in &self.paths[1..] {
                p.forward_batch_into(x, &mut tmp, scratch, pool, threads);
                // Padded strides match (same shape), and padding is zero on
                // both sides, so accumulating over the padded backing keeps
                // logical values and padding exact alike.
                for (o, v) in y.padded_mut().iter_mut().zip(tmp.padded()) {
                    *o += v;
                }
            }
            scratch.path_out = tmp;
        }
    }

    /// The PR 1 batched engine verbatim — per-path unfused scale passes
    /// around plain sign-GEMMs on per-call `std::thread::scope` spawns —
    /// kept as the measured "before" baseline for `benches/gemm_speedup.rs`
    /// and `examples/serve.rs`. Bit-identical to
    /// [`forward_batch_mt`](Self::forward_batch_mt), just slower.
    pub fn forward_batch_scoped(&self, x: &Mat, threads: usize) -> Mat {
        let mut out = self.paths[0].forward_batch_scoped(x, threads);
        for p in &self.paths[1..] {
            let y = p.forward_batch_scoped(x, threads);
            for (o, v) in out.padded_mut().iter_mut().zip(y.padded()) {
                *o += v;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::littlebit::{compress, CompressionConfig};
    use crate::rng::Pcg64;
    use crate::spectral::{synth_weight, SynthSpec};

    fn packed_pair(seed: u64) -> (Mat, PackedResidual) {
        let mut rng = Pcg64::seed(seed);
        let spec = SynthSpec { rows: 72, cols: 56, gamma: 0.3, coherence: 0.6, scale: 1.0 };
        let w = synth_weight(&spec, &mut rng);
        let cfg = CompressionConfig { bpp: 1.0, ..Default::default() };
        let c = compress(&w, &cfg, &mut rng);
        let recon = c.reconstruct();
        (recon, c.pack())
    }

    #[test]
    fn forward_matches_dense_reconstruction() {
        let (recon, packed) = packed_pair(31);
        let mut rng = Pcg64::seed(32);
        let mut x = vec![0.0f32; packed.d_in()];
        rng.fill_normal(&mut x);
        let want = recon.matvec(&x);
        let got = packed.forward(&x);
        for (a, b) in want.iter().zip(&got) {
            assert!((a - b).abs() < 4e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn batch_matches_per_item_bit_exactly() {
        let (_, packed) = packed_pair(33);
        let mut rng = Pcg64::seed(34);
        let b = 9;
        let mut x = Mat::zeros(packed.d_in(), b);
        x.fill_normal(&mut rng);
        let batched = packed.forward_batch(&x);
        let threaded = packed.forward_batch_mt(&x, 3);
        assert_eq!(batched, threaded);
        for t in 0..b {
            let want = packed.forward(&x.col(t));
            for i in 0..packed.d_out() {
                assert_eq!(batched.at(i, t).to_bits(), want[i].to_bits(), "({i},{t})");
            }
        }
    }

    /// One worker's `BatchScratch` serving many batches of varying width
    /// must give bit-identical results to fresh-scratch runs — the
    /// allocation-free serving loop's correctness contract.
    #[test]
    fn forward_batch_into_scratch_reuse_is_clean() {
        let (_, packed) = packed_pair(37);
        let mut rng = Pcg64::seed(38);
        let mut scratch = BatchScratch::default();
        let mut y = Mat::default();
        let pool = SignPool::global();
        for b in [4usize, 1, 9, 2] {
            let mut x = Mat::zeros(packed.d_in(), b);
            x.fill_normal(&mut rng);
            packed.forward_batch_into(&x, &mut y, &mut scratch, pool, 2);
            assert_eq!(y, packed.forward_batch(&x), "b={b}");
            // The kept PR 1 engine must stay bit-identical to the fused
            // pool path at the residual-composition level, too.
            assert_eq!(y, packed.forward_batch_scoped(&x, 2), "scoped b={b}");
        }
    }

    #[test]
    #[should_panic(expected = "path d_in mismatch")]
    fn mismatched_paths_rejected() {
        let (_, a) = packed_pair(35);
        let mut rng = Pcg64::seed(36);
        let spec = SynthSpec { rows: 72, cols: 40, gamma: 0.3, coherence: 0.6, scale: 1.0 };
        let w = synth_weight(&spec, &mut rng);
        let cfg = CompressionConfig { bpp: 1.0, ..Default::default() };
        let b = compress(&w, &cfg, &mut rng).pack();
        let mut paths = a.paths().to_vec();
        paths.extend(b.paths().iter().cloned());
        let _ = PackedResidual::new(paths);
    }
}
