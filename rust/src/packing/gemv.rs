//! GEMV kernels: dense f32 baseline, sign-GEMV over packed bits, the fused
//! tri-scale low-rank forward (the deployed LittleBit layer), and an
//! XNOR-popcount GEMM for the binary-binary BOPs story.

use super::BitMatrix;
use crate::linalg::Mat;

/// Dense f32 GEMV baseline, `y = W x`. This is the cuBLAS stand-in for the
/// §6.2 speedup comparison — a straightforward row-major dot-product loop
/// the compiler vectorizes.
pub fn gemv_dense(w: &Mat, x: &[f32], y: &mut [f32]) {
    assert_eq!(w.cols(), x.len());
    assert_eq!(w.rows(), y.len());
    for (i, yi) in y.iter_mut().enumerate() {
        let row = w.row(i);
        // Eight independent accumulators break the FP-add dependency chain
        // (a single serial chain costs ~4 cycles/element; unrolled, the
        // loop is throughput-bound and auto-vectorizes).
        let mut acc = [0.0f32; 8];
        let chunks = row.len() / 8;
        for c in 0..chunks {
            let r = &row[c * 8..c * 8 + 8];
            let xs = &x[c * 8..c * 8 + 8];
            for k in 0..8 {
                acc[k] += r[k] * xs[k];
            }
        }
        let mut tail = 0.0f32;
        for j in chunks * 8..row.len() {
            tail += row[j] * x[j];
        }
        *yi = acc.iter().sum::<f32>() + tail;
    }
}

/// Sign-GEMV: `y = S x` with `S ∈ {±1}^{rows×cols}` bit-packed.
///
/// Per element the sign application is a single XOR on the IEEE sign bit
/// (`x ^ (bit̄ << 31)`) — no multiply — and the row reduction runs on eight
/// independent accumulators so the FP-add chain never serializes (§Perf:
/// this rewrite took the 2752×1024 MLP GEMV from 0.14× of dense to >1× at
/// 1 bpp; measured in EXPERIMENTS.md at the repository root). For batch > 1
/// use [`gemm_sign`](super::gemm_sign), which loads each sign word once per
/// strip of batch columns and is bit-exact against this kernel.
pub fn gemv_sign(s: &BitMatrix, x: &[f32], y: &mut [f32]) {
    assert_eq!(s.cols(), x.len());
    assert_eq!(s.rows(), y.len());
    gemv_sign_rows(s, x, y, 0);
}

/// Compute output rows `row0..row0 + y.len()` of `S x` into `y` — the
/// row-range core shared by [`gemv_sign`] and the threaded variant in
/// `packing::gemm` (each thread takes a disjoint row range, so results are
/// bit-identical to the serial kernel).
pub(crate) fn gemv_sign_rows(s: &BitMatrix, x: &[f32], y: &mut [f32], row0: usize) {
    let cols = s.cols();
    let full_words = cols / 64;
    for (i, yi) in y.iter_mut().enumerate() {
        let words = s.row_words(row0 + i);
        let mut acc = [0.0f32; 8];
        for (c, &w) in words[..full_words].iter().enumerate() {
            let xs = &x[c * 64..c * 64 + 64];
            // Eight 8-lane strips; clear bit ⇒ flip the sign bit.
            for strip in 0..8 {
                let bits = (w >> (strip * 8)) as u32;
                let xv = &xs[strip * 8..strip * 8 + 8];
                for k in 0..8 {
                    let neg = ((bits >> k) & 1 ^ 1) << 31;
                    acc[k] += f32::from_bits(xv[k].to_bits() ^ neg);
                }
            }
        }
        // Ragged tail: when r < 64 (typical for U_b at sub-1-bit ranks)
        // this path carries the WHOLE row, so it needs the same
        // multi-accumulator treatment as the full words.
        if full_words < words.len() {
            let w = words[full_words];
            for (k, &xv) in x[full_words * 64..].iter().enumerate() {
                let neg = (((w >> k) & 1) as u32 ^ 1) << 31;
                acc[k & 7] += f32::from_bits(xv.to_bits() ^ neg);
            }
        }
        *yi = acc.iter().sum::<f32>();
    }
}

/// The deployed LittleBit inference layer: packed binary factors plus the
/// three FP scales of Eq. 1, with `V_b` stored pre-transposed so both
/// binary stages stream rows.
#[derive(Clone, Debug)]
pub struct TriScaleLayer {
    /// `U_b` packed, `d_out × r`.
    ub: BitMatrix,
    /// `V_bᵀ` packed, `r × d_in`.
    vbt: BitMatrix,
    h: Vec<f32>,
    l: Vec<f32>,
    g: Vec<f32>,
}

impl TriScaleLayer {
    /// Build from dense ±1 factors (`ub: d_out×r`, `vb: d_in×r`) and scales.
    pub fn new(ub: &Mat, vb: &Mat, h: Vec<f32>, l: Vec<f32>, g: Vec<f32>) -> Self {
        assert_eq!(ub.rows(), h.len());
        assert_eq!(ub.cols(), l.len());
        assert_eq!(vb.rows(), g.len());
        assert_eq!(vb.cols(), l.len());
        Self {
            ub: BitMatrix::from_dense(ub),
            vbt: BitMatrix::from_dense(&vb.transpose()),
            h,
            l,
            g,
        }
    }

    pub fn d_out(&self) -> usize {
        self.ub.rows()
    }

    pub fn d_in(&self) -> usize {
        self.vbt.cols()
    }

    pub fn rank(&self) -> usize {
        self.l.len()
    }

    /// Weight-storage bytes: two packed bit matrices + three FP16 scale
    /// vectors (2 bytes each).
    pub fn storage_bytes(&self) -> usize {
        self.ub.storage_bytes()
            + self.vbt.storage_bytes()
            + 2 * (self.h.len() + self.l.len() + self.g.len())
    }

    /// `y = h ⊙ (U_b (l ⊙ (V_bᵀ (g ⊙ x))))` — two sign-GEMVs and three
    /// element-wise scales; zero FP multiplies against weights.
    pub fn forward(&self, x: &[f32]) -> Vec<f32> {
        let mut scratch = Scratch::default();
        let mut out = vec![0.0f32; self.d_out()];
        self.forward_into(x, &mut out, &mut scratch);
        out
    }

    /// Allocation-free forward for the serving hot loop: `out` must be
    /// `d_out` long; `scratch` is reused across calls (§Perf iteration 2).
    pub fn forward_into(&self, x: &[f32], out: &mut [f32], scratch: &mut Scratch) {
        debug_assert_eq!(out.len(), self.d_out());
        scratch.xg.clear();
        scratch.xg.extend(x.iter().zip(&self.g).map(|(a, b)| a * b));
        scratch.latent.resize(self.rank(), 0.0);
        gemv_sign(&self.vbt, &scratch.xg, &mut scratch.latent);
        for (v, &li) in scratch.latent.iter_mut().zip(&self.l) {
            *v *= li;
        }
        gemv_sign(&self.ub, &scratch.latent, out);
        for (v, &hi) in out.iter_mut().zip(&self.h) {
            *v *= hi;
        }
    }

    /// Batched forward: `X` is `d_in × b` **feature-major** (column `t` is
    /// batch item `t`), returns `d_out × b`. Runs the whole batch through
    /// two sign-GEMMs so every packed weight word is loaded once per
    /// 8-column strip instead of once per request; column `t` of the result
    /// is bit-identical to `forward` on item `t`.
    ///
    /// # Examples
    ///
    /// ```
    /// use littlebit2::linalg::Mat;
    /// use littlebit2::packing::TriScaleLayer;
    ///
    /// // All-(+1) factors with unit scales: W = U_b·V_bᵀ is all-ones 2×2.
    /// let ones = Mat::from_fn(2, 1, |_, _| 1.0);
    /// let layer = TriScaleLayer::new(&ones, &ones, vec![1.0; 2], vec![1.0], vec![1.0; 2]);
    /// // Two batch items, feature-major: item 0 = [1, 2], item 1 = [3, 4].
    /// let x = Mat::from_vec(2, 2, vec![1.0, 3.0, 2.0, 4.0]);
    /// let y = layer.forward_batch(&x);
    /// assert_eq!(y.row(0), &[3.0, 7.0]);
    /// assert_eq!(y.row(1), &[3.0, 7.0]);
    /// assert_eq!(y.col(0), layer.forward(&[1.0, 2.0]));
    /// ```
    pub fn forward_batch(&self, x: &Mat) -> Mat {
        self.forward_batch_mt(x, 1)
    }

    /// [`forward_batch`](Self::forward_batch) with both sign-GEMMs split
    /// row-parallel over `threads` OS threads (bit-identical output for any
    /// thread count).
    pub fn forward_batch_mt(&self, x: &Mat, threads: usize) -> Mat {
        assert_eq!(x.rows(), self.d_in(), "X must be d_in × b feature-major");
        let b = x.cols();
        let xg = x.scale_rows(&self.g);
        let mut latent = Mat::zeros(self.rank(), b);
        super::gemm_sign_mt(&self.vbt, &xg, &mut latent, threads);
        let latent = latent.scale_rows(&self.l);
        let mut out = Mat::zeros(self.d_out(), b);
        super::gemm_sign_mt(&self.ub, &latent, &mut out, threads);
        for (i, &hi) in self.h.iter().enumerate() {
            for v in out.row_mut(i) {
                *v *= hi;
            }
        }
        out
    }

    /// Accumulating forward: `out += layer(x)` — what the residual 2-path
    /// composition uses so path outputs never bounce through extra buffers.
    pub fn forward_accumulate(&self, x: &[f32], out: &mut [f32], scratch: &mut Scratch) {
        scratch.path_out.resize(self.d_out(), 0.0);
        // Reborrow dance: compute into path_out, then add.
        let mut tmp = std::mem::take(&mut scratch.path_out);
        self.forward_into(x, &mut tmp, scratch);
        for (o, v) in out.iter_mut().zip(&tmp) {
            *o += v;
        }
        scratch.path_out = tmp;
    }

    /// Operation count of one forward: (sign-adds, fp-mults).
    // (scratch type defined below)
    pub fn op_counts(&self) -> (usize, usize) {
        let sign_adds = self.rank() * (self.d_in() + self.d_out());
        let fp_mults = self.d_in() + self.rank() + self.d_out();
        (sign_adds, fp_mults)
    }
}

/// Reusable buffers for the allocation-free forward path.
#[derive(Clone, Debug, Default)]
pub struct Scratch {
    xg: Vec<f32>,
    latent: Vec<f32>,
    path_out: Vec<f32>,
}

/// XNOR-popcount GEMM for fully-binary operands (`A ∈ {±1}^{m×k}`,
/// `B ∈ {±1}^{k×n}` with `Bᵀ` packed): `C_ij = k − 2·popcount(a_i ⊕ b_j)`.
/// This is the BOPs primitive of §6.2 — 64 MACs per instruction pair.
pub fn xnor_popcount_gemm(a: &BitMatrix, bt: &BitMatrix) -> Mat {
    assert_eq!(a.cols(), bt.cols(), "inner dims (k) must match");
    let k = a.cols();
    let mut out = Mat::zeros(a.rows(), bt.rows());
    for i in 0..a.rows() {
        let arow = a.row_words(i);
        for j in 0..bt.rows() {
            let brow = bt.row_words(j);
            let mut diff = 0u32;
            for (wa, wb) in arow.iter().zip(brow) {
                diff += (wa ^ wb).count_ones();
            }
            *out.at_mut(i, j) = (k as i64 - 2 * diff as i64) as f32;
        }
    }
    out
}

/// Convenience: full tri-scale forward from dense factors (test/oracle path).
pub fn tri_scale_gemv(
    ub: &Mat,
    vb: &Mat,
    h: &[f32],
    l: &[f32],
    g: &[f32],
    x: &[f32],
) -> Vec<f32> {
    TriScaleLayer::new(ub, vb, h.to_vec(), l.to_vec(), g.to_vec()).forward(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn gemv_sign_matches_dense() {
        let mut rng = Pcg64::seed(1);
        for (m, n) in [(4, 4), (16, 64), (33, 130), (8, 200)] {
            let s = Mat::gaussian(m, n, &mut rng).signum();
            let packed = BitMatrix::from_dense(&s);
            let mut x = vec![0.0f32; n];
            rng.fill_normal(&mut x);
            let want = s.matvec(&x);
            let mut got = vec![0.0f32; m];
            gemv_sign(&packed, &x, &mut got);
            for (a, b) in want.iter().zip(&got) {
                assert!((a - b).abs() < 1e-3 * (n as f32).sqrt(), "{m}x{n}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn gemv_dense_basic() {
        let w = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let mut y = vec![0.0; 2];
        gemv_dense(&w, &[1., 0., -1.], &mut y);
        assert_eq!(y, vec![-2.0, -2.0]);
    }

    #[test]
    fn xnor_gemm_matches_dense_product() {
        let mut rng = Pcg64::seed(2);
        let a = Mat::gaussian(9, 70, &mut rng).signum();
        let b = Mat::gaussian(70, 11, &mut rng).signum();
        let want = a.matmul(&b);
        let got = xnor_popcount_gemm(
            &BitMatrix::from_dense(&a),
            &BitMatrix::from_dense(&b.transpose()),
        );
        assert_eq!(want.shape(), got.shape());
        for (x, y) in want.as_slice().iter().zip(got.as_slice()) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
    }

    #[test]
    fn tri_scale_storage_is_sub_one_bit_regime() {
        let mut rng = Pcg64::seed(3);
        let (d, r) = (1024, 64);
        let ub = Mat::gaussian(d, r, &mut rng).signum();
        let vb = Mat::gaussian(d, r, &mut rng).signum();
        let layer = TriScaleLayer::new(
            &ub,
            &vb,
            vec![1.0; d],
            vec![1.0; r],
            vec![1.0; d],
        );
        let bpp = layer.storage_bytes() as f64 * 8.0 / (d * d) as f64;
        // 2·r·d bits / d² + scales ⇒ ~0.125 bpp + ε at r=d/16.
        assert!(bpp < 0.2, "bpp={bpp}");
    }

    /// Batched forward must be bit-identical to the per-item forward: both
    /// paths share the same per-column reduction order by construction.
    #[test]
    fn forward_batch_matches_per_item_forward_bit_exactly() {
        let mut rng = Pcg64::seed(6);
        let (d_out, d_in, r, b) = (96, 80, 16, 11);
        let ub = Mat::gaussian(d_out, r, &mut rng).signum();
        let vb = Mat::gaussian(d_in, r, &mut rng).signum();
        let mut h = vec![0.0f32; d_out];
        let mut l = vec![0.0f32; r];
        let mut g = vec![0.0f32; d_in];
        rng.fill_uniform(&mut h, 0.5, 1.5);
        rng.fill_uniform(&mut l, 0.1, 1.0);
        rng.fill_uniform(&mut g, 0.5, 1.5);
        let layer = TriScaleLayer::new(&ub, &vb, h, l, g);

        let mut x = Mat::zeros(d_in, b);
        rng.fill_normal(x.as_mut_slice());
        let batched = layer.forward_batch(&x);
        let threaded = layer.forward_batch_mt(&x, 4);
        assert_eq!(batched, threaded, "threading changed the result");
        for t in 0..b {
            let want = layer.forward(&x.col(t));
            for i in 0..d_out {
                assert_eq!(
                    batched.at(i, t).to_bits(),
                    want[i].to_bits(),
                    "({i},{t}): {} vs {}",
                    batched.at(i, t),
                    want[i]
                );
            }
        }
    }

    #[test]
    fn op_counts_match_formula() {
        let mut rng = Pcg64::seed(4);
        let ub = Mat::gaussian(128, 16, &mut rng).signum();
        let vb = Mat::gaussian(96, 16, &mut rng).signum();
        let layer =
            TriScaleLayer::new(&ub, &vb, vec![1.0; 128], vec![1.0; 16], vec![1.0; 96]);
        let (adds, mults) = layer.op_counts();
        assert_eq!(adds, 16 * (128 + 96));
        assert_eq!(mults, 96 + 16 + 128);
    }
}
