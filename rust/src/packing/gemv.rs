//! GEMV kernels: dense f32 baseline, sign-GEMV over packed bits (plain and
//! scale-fused), the tri-scale low-rank forward (the deployed LittleBit
//! layer), and an XNOR-popcount GEMM for the binary-binary BOPs story.

use super::pool::SignPool;
use super::{simd, BitMatrix};
use crate::linalg::Mat;
use crate::sys::ScaleVec;
use std::cell::RefCell;

thread_local! {
    /// Per-thread pre-scaled activation buffer for the fused GEMV: the
    /// `in_scale ⊙ x` products are formed **once per call** (`n`
    /// multiplies — the unfused pass's exact cost and exact f32 results)
    /// and then reused by every output row, instead of being recomputed
    /// inside each row's XOR loop or once per pool job.
    static XSCALED: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

/// Run `f` against the thread-local pre-scaled copy of `x`
/// (`in_scale ⊙ x`, identical f32 products to the unfused pass, formed
/// once). Shared with `packing::pool`, which hoists the input scale here
/// before partitioning rows into jobs.
pub(crate) fn with_scaled_vec<R>(x: &[f32], in_scale: &[f32], f: impl FnOnce(&[f32]) -> R) -> R {
    XSCALED.with(|cell| {
        let xs = &mut *cell.borrow_mut();
        xs.clear();
        xs.extend(x.iter().zip(in_scale).map(|(a, b)| a * b));
        f(xs)
    })
}

/// Dense f32 GEMV baseline, `y = W x`. This is the cuBLAS stand-in for the
/// §6.2 speedup comparison — a straightforward row-major dot-product loop
/// the compiler vectorizes.
pub fn gemv_dense(w: &Mat, x: &[f32], y: &mut [f32]) {
    assert_eq!(w.cols(), x.len());
    assert_eq!(w.rows(), y.len());
    for (i, yi) in y.iter_mut().enumerate() {
        let row = w.row(i);
        // Eight independent accumulators break the FP-add dependency chain
        // (a single serial chain costs ~4 cycles/element; unrolled, the
        // loop is throughput-bound and auto-vectorizes).
        let mut acc = [0.0f32; 8];
        let chunks = row.len() / 8;
        for c in 0..chunks {
            let r = &row[c * 8..c * 8 + 8];
            let xs = &x[c * 8..c * 8 + 8];
            for k in 0..8 {
                acc[k] += r[k] * xs[k];
            }
        }
        let mut tail = 0.0f32;
        for j in chunks * 8..row.len() {
            tail += row[j] * x[j];
        }
        *yi = acc.iter().sum::<f32>() + tail;
    }
}

/// Sign-GEMV: `y = S x` with `S ∈ {±1}^{rows×cols}` bit-packed.
///
/// Per element the sign application is a single XOR on the IEEE sign bit
/// (`x ^ (bit̄ << 31)`) — no multiply — and the row reduction runs on eight
/// independent accumulators so the FP-add chain never serializes (§Perf:
/// this rewrite took the 2752×1024 MLP GEMV from 0.14× of dense to >1× at
/// 1 bpp; measured in EXPERIMENTS.md at the repository root). For batch > 1
/// use [`gemm_sign`](super::gemm_sign), which loads each sign word once per
/// strip of batch columns and is bit-exact against this kernel. For the
/// deployed tri-scale pipeline use [`gemv_sign_scaled`], which folds the
/// element-wise scale vectors of Eq. 1 into this same loop.
pub fn gemv_sign(s: &BitMatrix, x: &[f32], y: &mut [f32]) {
    assert_eq!(s.cols(), x.len());
    assert_eq!(s.rows(), y.len());
    gemv_sign_rows(s, x, y, 0);
}

/// Compute output rows `row0..row0 + y.len()` of `S x` into `y` — the
/// row-range core shared by [`gemv_sign`] and the pool-dispatched variant
/// in `packing::pool` (each job takes a disjoint row range, so results are
/// bit-identical to the serial kernel).
pub(crate) fn gemv_sign_rows(s: &BitMatrix, x: &[f32], y: &mut [f32], row0: usize) {
    gemv_sign_out_rows(s, x, None, y, row0);
}

/// The shared sign-GEMV row-range loop, with the output scale (when
/// present) folded into each row's final lane reduction — one multiply on
/// the reduced sum, the same rounding a separate output pass would apply.
/// This is the kernel every pool GEMV job runs; input scaling happens once
/// per call via [`with_scaled_vec`] before rows are partitioned.
///
/// Dispatch: the AVX2 lane of [`simd`] when available (the scalar
/// accumulators become vector lanes — bit-identical per-row sums), the
/// scalar oracle [`gemv_row_scalar`] otherwise. Clear bit-plane padding is
/// load-bearing (whole padded words stream through the XOR loop on the
/// SIMD side), so it is asserted here at kernel entry.
pub(crate) fn gemv_sign_out_rows(
    s: &BitMatrix,
    x: &[f32],
    out_scale: Option<&[f32]>,
    y: &mut [f32],
    row0: usize,
) {
    debug_assert!(s.padding_is_clear(), "sign-GEMV on corrupt bit-plane padding");
    let cols = s.cols();
    let avx2 = simd::use_avx2();
    for (i, yi) in y.iter_mut().enumerate() {
        let words = s.row_words(row0 + i);
        let sum = if avx2 {
            simd::gemv_row_avx2(words, x, cols)
        } else {
            gemv_row_scalar(words, x, cols)
        };
        *yi = match out_scale {
            Some(h) => sum * h[row0 + i],
            None => sum,
        };
    }
}

/// One packed row · `x` on the scalar lane — the pre-SIMD kernel body kept
/// verbatim as the bit-exactness oracle and non-x86 path. Eight
/// independent accumulators fed strip-by-strip, summed in lane order.
pub(crate) fn gemv_row_scalar(words: &[u64], x: &[f32], cols: usize) -> f32 {
    let full_words = cols / 64;
    let mut acc = [0.0f32; 8];
    for (c, &w) in words[..full_words].iter().enumerate() {
        let xs = &x[c * 64..c * 64 + 64];
        // Eight 8-lane strips; clear bit ⇒ flip the sign bit.
        for strip in 0..8 {
            let bits = (w >> (strip * 8)) as u32;
            let xv = &xs[strip * 8..strip * 8 + 8];
            for k in 0..8 {
                let neg = ((bits >> k) & 1 ^ 1) << 31;
                acc[k] += f32::from_bits(xv[k].to_bits() ^ neg);
            }
        }
    }
    // Ragged tail: when r < 64 (typical for U_b at sub-1-bit ranks)
    // this path carries the WHOLE row, so it needs the same
    // multi-accumulator treatment as the full words.
    if cols % 64 != 0 {
        let w = words[full_words];
        for (k, &xv) in x[full_words * 64..].iter().enumerate() {
            let neg = (((w >> k) & 1) as u32 ^ 1) << 31;
            acc[k & 7] += f32::from_bits(xv.to_bits() ^ neg);
        }
    }
    acc.iter().sum::<f32>()
}

/// Scale-fused sign-GEMV:
/// `y = diag(out_scale) · S · (in_scale ⊙ x)`, with either scale optional.
///
/// The input scale is applied once per call into a reused thread-local
/// buffer (`n` multiplies — the unfused pass's cost, with zero
/// allocations after warm-up) that every output row then streams, and the
/// output scale folds into the final lane reduction (`Σacc · out_scale[i]`
/// — one multiply per output element). This removes the two separate
/// element-wise passes (and their per-call temporaries) the unfused
/// composition scale → [`gemv_sign`] → scale makes over the activations,
/// and is **bit-exact** against it: the products and the reduction order
/// are unchanged, only the passes are fused (asserted by
/// `gemv_scaled_matches_unfused_composition_bit_exactly`).
pub fn gemv_sign_scaled(
    s: &BitMatrix,
    in_scale: Option<&[f32]>,
    x: &[f32],
    out_scale: Option<&[f32]>,
    y: &mut [f32],
) {
    assert_eq!(s.cols(), x.len());
    assert_eq!(s.rows(), y.len());
    if let Some(g) = in_scale {
        assert_eq!(g.len(), s.cols(), "in_scale length");
    }
    if let Some(h) = out_scale {
        assert_eq!(h.len(), s.rows(), "out_scale length");
    }
    gemv_sign_scaled_rows(s, in_scale, x, out_scale, y, 0);
}

/// Row-range form of [`gemv_sign_scaled`]: pre-scales the activations once
/// (same f32 products as the unfused pass — not once per row, not once per
/// job), then runs the exact [`gemv_sign_rows`] loop over them with the
/// output scale folded into each row's lane reduction. Reduction order
/// (and therefore every rounding) is identical to the unfused composition.
fn gemv_sign_scaled_rows(
    s: &BitMatrix,
    in_scale: Option<&[f32]>,
    x: &[f32],
    out_scale: Option<&[f32]>,
    y: &mut [f32],
    row0: usize,
) {
    match in_scale {
        Some(g) => with_scaled_vec(x, g, |xs| gemv_sign_out_rows(s, xs, out_scale, y, row0)),
        None => gemv_sign_out_rows(s, x, out_scale, y, row0),
    }
}

/// The deployed LittleBit inference layer: packed binary factors plus the
/// three FP scales of Eq. 1, with `V_b` stored pre-transposed so both
/// binary stages stream rows. All forward paths run the **scale-fused**
/// kernels ([`gemv_sign_scaled`] / [`super::gemm_sign_scaled`]): `g` and
/// `l` are applied exactly once per call into reused thread-local
/// buffers, `h` folds into the second kernel's lane reduction — zero
/// separate output passes, zero per-call allocations, and bit-identical
/// numbers to the unfused composition.
#[derive(Clone, Debug, PartialEq)]
pub struct TriScaleLayer {
    /// `U_b` packed, `d_out × r`.
    ub: BitMatrix,
    /// `V_bᵀ` packed, `r × d_in`.
    vbt: BitMatrix,
    h: ScaleVec,
    l: ScaleVec,
    g: ScaleVec,
}

impl TriScaleLayer {
    /// Build from dense ±1 factors (`ub: d_out×r`, `vb: d_in×r`) and scales.
    pub fn new(ub: &Mat, vb: &Mat, h: Vec<f32>, l: Vec<f32>, g: Vec<f32>) -> Self {
        assert_eq!(ub.rows(), h.len());
        assert_eq!(ub.cols(), l.len());
        assert_eq!(vb.rows(), g.len());
        assert_eq!(vb.cols(), l.len());
        Self {
            ub: BitMatrix::from_dense(ub),
            vbt: BitMatrix::from_dense(&vb.transpose()),
            h: h.into(),
            l: l.into(),
            g: g.into(),
        }
    }

    /// Rebuild from already-packed parts (the `.lb2` artifact load path:
    /// bit-planes arrive word-verbatim via [`BitMatrix::from_words`], or
    /// borrowed straight from a mapping via [`BitMatrix::from_mapped`] —
    /// scales likewise accept owned vectors or mapped views through
    /// [`ScaleVec`]). `ub` is `d_out × r`, `vbt` is the
    /// **pre-transposed** `V_bᵀ` (`r × d_in`). Shape mismatches return
    /// `Err` — this is a deserialization boundary, not a programmer-error
    /// assert.
    pub fn from_parts(
        ub: BitMatrix,
        vbt: BitMatrix,
        h: impl Into<ScaleVec>,
        l: impl Into<ScaleVec>,
        g: impl Into<ScaleVec>,
    ) -> anyhow::Result<Self> {
        let (h, l, g) = (h.into(), l.into(), g.into());
        if ub.rows() != h.len() {
            anyhow::bail!("h length {} != d_out {}", h.len(), ub.rows());
        }
        if ub.cols() != l.len() || vbt.rows() != l.len() {
            anyhow::bail!(
                "rank mismatch: |l|={}, ub cols={}, vbt rows={}",
                l.len(),
                ub.cols(),
                vbt.rows()
            );
        }
        if vbt.cols() != g.len() {
            anyhow::bail!("g length {} != d_in {}", g.len(), vbt.cols());
        }
        Ok(Self { ub, vbt, h, l, g })
    }

    pub fn d_out(&self) -> usize {
        self.ub.rows()
    }

    pub fn d_in(&self) -> usize {
        self.vbt.cols()
    }

    pub fn rank(&self) -> usize {
        self.l.len()
    }

    /// Packed `U_b` (`d_out × r`) — serialized verbatim by the artifact.
    pub fn ub_bits(&self) -> &BitMatrix {
        &self.ub
    }

    /// Packed pre-transposed `V_bᵀ` (`r × d_in`) — serialized verbatim.
    pub fn vbt_bits(&self) -> &BitMatrix {
        &self.vbt
    }

    /// Row scale `h ∈ R^{d_out}`.
    pub fn h(&self) -> &[f32] {
        &self.h
    }

    /// Central latent scale `l ∈ R^r`.
    pub fn l(&self) -> &[f32] {
        &self.l
    }

    /// Column scale `g ∈ R^{d_in}`.
    pub fn g(&self) -> &[f32] {
        &self.g
    }

    /// Weight-storage bytes: two packed bit matrices + three FP16 scale
    /// vectors (2 bytes each).
    pub fn storage_bytes(&self) -> usize {
        self.ub.storage_bytes()
            + self.vbt.storage_bytes()
            + 2 * (self.h.len() + self.l.len() + self.g.len())
    }

    /// Weight bytes this process's RAM actually holds: padded owned
    /// bit-planes plus owned scale vectors. Planes and scales borrowed
    /// from a live mapping contribute 0 here and appear in
    /// [`mapped_bytes`](Self::mapped_bytes) instead — the two never
    /// overlap, so eval's bpp audit can sum them without double-counting.
    pub fn resident_bytes(&self) -> usize {
        self.ub.resident_bytes()
            + self.vbt.resident_bytes()
            + self.h.resident_bytes()
            + self.l.resident_bytes()
            + self.g.resident_bytes()
    }

    /// Weight bytes served from the page cache through a mapping.
    pub fn mapped_bytes(&self) -> usize {
        self.ub.mapped_bytes()
            + self.vbt.mapped_bytes()
            + self.h.mapped_bytes()
            + self.l.mapped_bytes()
            + self.g.mapped_bytes()
    }

    /// `y = h ⊙ (U_b (l ⊙ (V_bᵀ (g ⊙ x))))` — two *fused* sign-GEMVs; zero
    /// FP multiplies against weights and zero separate scale passes.
    pub fn forward(&self, x: &[f32]) -> Vec<f32> {
        let mut scratch = Scratch::default();
        let mut out = vec![0.0f32; self.d_out()];
        self.forward_into(x, &mut out, &mut scratch);
        out
    }

    /// Allocation-free forward for the serving hot loop: `out` must be
    /// `d_out` long; `scratch` is reused across calls (§Perf iteration 2).
    /// Both stages run [`gemv_sign_scaled`] — `g` and `l` are each applied
    /// once into the kernel's reused buffer, `h` folds into the second
    /// stage's lane reduction (§Perf iteration 3: no separate scale
    /// passes, no per-call `xg` allocation).
    pub fn forward_into(&self, x: &[f32], out: &mut [f32], scratch: &mut Scratch) {
        debug_assert_eq!(out.len(), self.d_out());
        scratch.latent.resize(self.rank(), 0.0);
        gemv_sign_scaled(&self.vbt, Some(&self.g), x, None, &mut scratch.latent);
        gemv_sign_scaled(&self.ub, Some(&self.l), &scratch.latent, Some(&self.h), out);
    }

    /// Batched forward: `X` is `d_in × b` **feature-major** (column `t` is
    /// batch item `t`), returns `d_out × b`. Runs the whole batch through
    /// two fused sign-GEMMs so every packed weight word is loaded once per
    /// 8-column strip instead of once per request; column `t` of the result
    /// is bit-identical to `forward` on item `t`.
    ///
    /// # Examples
    ///
    /// ```
    /// use littlebit2::linalg::Mat;
    /// use littlebit2::packing::TriScaleLayer;
    ///
    /// // All-(+1) factors with unit scales: W = U_b·V_bᵀ is all-ones 2×2.
    /// let ones = Mat::from_fn(2, 1, |_, _| 1.0);
    /// let layer = TriScaleLayer::new(&ones, &ones, vec![1.0; 2], vec![1.0], vec![1.0; 2]);
    /// // Two batch items, feature-major: item 0 = [1, 2], item 1 = [3, 4].
    /// let x = Mat::from_vec(2, 2, vec![1.0, 3.0, 2.0, 4.0]);
    /// let y = layer.forward_batch(&x);
    /// assert_eq!(y.row(0), &[3.0, 7.0]);
    /// assert_eq!(y.row(1), &[3.0, 7.0]);
    /// assert_eq!(y.col(0), layer.forward(&[1.0, 2.0]));
    /// ```
    pub fn forward_batch(&self, x: &Mat) -> Mat {
        self.forward_batch_mt(x, 1)
    }

    /// [`forward_batch`](Self::forward_batch) with both sign-GEMMs split
    /// row-parallel into `threads` ranges on the process-wide
    /// [`SignPool`] (bit-identical output for any thread count — row
    /// partitioning changes no per-element reduction order).
    pub fn forward_batch_mt(&self, x: &Mat, threads: usize) -> Mat {
        let mut y = Mat::default();
        let mut scratch = BatchScratch::default();
        self.forward_batch_into(x, &mut y, &mut scratch, SignPool::for_threads(threads), threads);
        y
    }

    /// Allocation-free batched forward — the serving hot path. `y` is
    /// resized to `d_out × b` in place; the latent block lives in `scratch`
    /// and is reused across calls; both fused sign-GEMMs are split into
    /// `threads` row ranges executed on `pool` (1 = serial, no dispatch).
    /// Bit-identical to [`forward_batch`](Self::forward_batch) and to
    /// per-column [`forward`](Self::forward).
    pub fn forward_batch_into(
        &self,
        x: &Mat,
        y: &mut Mat,
        scratch: &mut BatchScratch,
        pool: &SignPool,
        threads: usize,
    ) {
        assert_eq!(x.rows(), self.d_in(), "X must be d_in × b feature-major");
        let b = x.cols();
        scratch.latent.resize(self.rank(), b);
        y.resize(self.d_out(), b);
        pool.run_gemm(&self.vbt, Some(&self.g), x, None, &mut scratch.latent, threads);
        pool.run_gemm(&self.ub, Some(&self.l), &scratch.latent, Some(&self.h), y, threads);
    }

    /// The pre-pool, pre-fusion batched forward kept as the measured
    /// baseline for `benches/gemm_speedup.rs`: three separate scale passes
    /// (each allocating an intermediate `Mat`) around two plain sign-GEMMs
    /// whose row ranges run on per-call `std::thread::scope` threads.
    /// Bit-identical to [`forward_batch_mt`](Self::forward_batch_mt) —
    /// asserted by `fused_pool_matches_scoped_unfused_bit_exactly` — just
    /// slower, which is exactly what the bench quantifies.
    pub fn forward_batch_scoped(&self, x: &Mat, threads: usize) -> Mat {
        assert_eq!(x.rows(), self.d_in(), "X must be d_in × b feature-major");
        let b = x.cols();
        let xg = x.scale_rows(&self.g);
        let mut latent = Mat::zeros(self.rank(), b);
        super::gemm_sign_mt_scoped(&self.vbt, &xg, &mut latent, threads);
        let latent = latent.scale_rows(&self.l);
        let mut out = Mat::zeros(self.d_out(), b);
        super::gemm_sign_mt_scoped(&self.ub, &latent, &mut out, threads);
        for (i, &hi) in self.h.iter().enumerate() {
            for v in out.row_mut(i) {
                *v *= hi;
            }
        }
        out
    }

    /// Accumulating forward: `out += layer(x)` — what the residual 2-path
    /// composition uses so path outputs never bounce through extra buffers.
    pub fn forward_accumulate(&self, x: &[f32], out: &mut [f32], scratch: &mut Scratch) {
        scratch.path_out.resize(self.d_out(), 0.0);
        // Reborrow dance: compute into path_out, then add.
        let mut tmp = std::mem::take(&mut scratch.path_out);
        self.forward_into(x, &mut tmp, scratch);
        for (o, v) in out.iter_mut().zip(&tmp) {
            *o += v;
        }
        scratch.path_out = tmp;
    }

    /// Operation count of one forward: (sign-adds, fp-mults).
    // (scratch types defined below)
    pub fn op_counts(&self) -> (usize, usize) {
        let sign_adds = self.rank() * (self.d_in() + self.d_out());
        let fp_mults = self.d_in() + self.rank() + self.d_out();
        (sign_adds, fp_mults)
    }
}

/// Reusable buffers for the allocation-free single-request forward path.
#[derive(Clone, Debug, Default)]
pub struct Scratch {
    latent: Vec<f32>,
    path_out: Vec<f32>,
}

/// Reusable buffers for the allocation-free **batched** forward path
/// ([`TriScaleLayer::forward_batch_into`] and the `PackedResidual` /
/// `PackedStack` equivalents): the latent block, the per-path accumulation
/// block, and the ping/pong activation blocks a layer chain bounces
/// between. All grow in place ([`Mat::resize`]) and are reused across
/// requests — one scratch per server worker serves every batch size.
#[derive(Clone, Debug, Default)]
pub struct BatchScratch {
    /// `r × b` latent activations between the two fused sign-GEMMs.
    pub(crate) latent: Mat,
    /// `d_out × b` per-path output, accumulated into the batch result by
    /// the residual composition.
    pub(crate) path_out: Mat,
    /// Ping/pong activation blocks for sequential layer chains.
    pub(crate) ping: Mat,
    pub(crate) pong: Mat,
}

/// XNOR-popcount GEMM for fully-binary operands (`A ∈ {±1}^{m×k}`,
/// `B ∈ {±1}^{k×n}` with `Bᵀ` packed): `C_ij = k − 2·popcount(a_i ⊕ b_j)`.
/// This is the BOPs primitive of §6.2 — 64 MACs per instruction pair.
pub fn xnor_popcount_gemm(a: &BitMatrix, bt: &BitMatrix) -> Mat {
    assert_eq!(a.cols(), bt.cols(), "inner dims (k) must match");
    debug_assert!(a.padding_is_clear(), "XNOR GEMM on corrupt bit-plane padding");
    debug_assert!(bt.padding_is_clear(), "XNOR GEMM on corrupt bit-plane padding");
    let k = a.cols();
    let avx2 = simd::use_avx2();
    let mut out = Mat::zeros(a.rows(), bt.rows());
    for i in 0..a.rows() {
        let arow = a.row_words(i);
        let orow = out.row_mut(i);
        for j in 0..bt.rows() {
            let brow = bt.row_words(j);
            // Clear padding means pad words XOR to 0 and add nothing to the
            // popcount on either lane — both are integer-exact.
            let diff = if avx2 {
                simd::xnor_row_popcount_avx2(arow, brow)
            } else {
                let mut d = 0u32;
                for (wa, wb) in arow.iter().zip(brow) {
                    d += (wa ^ wb).count_ones();
                }
                d
            };
            orow[j] = (k as i64 - 2 * diff as i64) as f32;
        }
    }
    out
}

/// Convenience: full tri-scale forward from dense factors (test/oracle path).
pub fn tri_scale_gemv(
    ub: &Mat,
    vb: &Mat,
    h: &[f32],
    l: &[f32],
    g: &[f32],
    x: &[f32],
) -> Vec<f32> {
    TriScaleLayer::new(ub, vb, h.to_vec(), l.to_vec(), g.to_vec()).forward(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn gemv_sign_matches_dense() {
        let mut rng = Pcg64::seed(1);
        for (m, n) in [(4, 4), (16, 64), (33, 130), (8, 200)] {
            let s = Mat::gaussian(m, n, &mut rng).signum();
            let packed = BitMatrix::from_dense(&s);
            let mut x = vec![0.0f32; n];
            rng.fill_normal(&mut x);
            let want = s.matvec(&x);
            let mut got = vec![0.0f32; m];
            gemv_sign(&packed, &x, &mut got);
            for (a, b) in want.iter().zip(&got) {
                assert!((a - b).abs() < 1e-3 * (n as f32).sqrt(), "{m}x{n}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn gemv_dense_basic() {
        let w = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let mut y = vec![0.0; 2];
        gemv_dense(&w, &[1., 0., -1.], &mut y);
        assert_eq!(y, vec![-2.0, -2.0]);
    }

    /// The fused-kernel acceptance contract at the GEMV level: folding the
    /// scales into the sign-XOR loop and lane reduction must be bit-exact
    /// against the unfused scale → gemv_sign → scale composition, for every
    /// combination of present/absent scales, on ragged shapes whose columns
    /// span multiple words plus a tail.
    #[test]
    fn gemv_scaled_matches_unfused_composition_bit_exactly() {
        let mut rng = Pcg64::seed(51);
        for (m, n) in [(4, 4), (16, 64), (33, 130), (8, 200), (7, 63), (5, 191), (9, 65)] {
            let s = BitMatrix::from_dense(&Mat::gaussian(m, n, &mut rng).signum());
            let mut x = vec![0.0f32; n];
            rng.fill_normal(&mut x);
            let mut g = vec![0.0f32; n];
            let mut h = vec![0.0f32; m];
            rng.fill_uniform(&mut g, 0.2, 1.8);
            rng.fill_uniform(&mut h, 0.2, 1.8);

            // Unfused reference: explicit passes.
            let xg: Vec<f32> = x.iter().zip(&g).map(|(a, b)| a * b).collect();
            let mut base = vec![0.0f32; m];
            gemv_sign(&s, &xg, &mut base);
            let scaled_out: Vec<f32> = base.iter().zip(&h).map(|(a, b)| a * b).collect();

            for (ins, outs) in [
                (Some(g.as_slice()), Some(h.as_slice())),
                (Some(g.as_slice()), None),
                (None, Some(h.as_slice())),
                (None, None),
            ] {
                let mut got = vec![0.0f32; m];
                gemv_sign_scaled(&s, ins, &x, outs, &mut got);
                let xin = if ins.is_some() { &xg } else { &x };
                let mut want = vec![0.0f32; m];
                gemv_sign(&s, xin, &mut want);
                if outs.is_some() {
                    for (w, &hi) in want.iter_mut().zip(&h) {
                        *w *= hi;
                    }
                }
                for (i, (a, b)) in want.iter().zip(&got).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "{m}x{n} ins={} outs={} row {i}: {a} vs {b}",
                        ins.is_some(),
                        outs.is_some()
                    );
                }
            }
            // And the both-scales case equals the fully composed reference.
            let mut got = vec![0.0f32; m];
            gemv_sign_scaled(&s, Some(&g), &x, Some(&h), &mut got);
            for (a, b) in scaled_out.iter().zip(&got) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn xnor_gemm_matches_dense_product() {
        let mut rng = Pcg64::seed(2);
        let a = Mat::gaussian(9, 70, &mut rng).signum();
        let b = Mat::gaussian(70, 11, &mut rng).signum();
        let want = a.matmul(&b);
        let got = xnor_popcount_gemm(
            &BitMatrix::from_dense(&a),
            &BitMatrix::from_dense(&b.transpose()),
        );
        assert_eq!(want.shape(), got.shape());
        for (x, y) in want.to_vec().iter().zip(got.to_vec()) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
    }

    #[test]
    fn tri_scale_storage_is_sub_one_bit_regime() {
        let mut rng = Pcg64::seed(3);
        let (d, r) = (1024, 64);
        let ub = Mat::gaussian(d, r, &mut rng).signum();
        let vb = Mat::gaussian(d, r, &mut rng).signum();
        let layer = TriScaleLayer::new(
            &ub,
            &vb,
            vec![1.0; d],
            vec![1.0; r],
            vec![1.0; d],
        );
        let bpp = layer.storage_bytes() as f64 * 8.0 / (d * d) as f64;
        // 2·r·d bits / d² + scales ⇒ ~0.125 bpp + ε at r=d/16.
        assert!(bpp < 0.2, "bpp={bpp}");
    }

    fn random_layer(d_out: usize, d_in: usize, r: usize, rng: &mut Pcg64) -> TriScaleLayer {
        let ub = Mat::gaussian(d_out, r, rng).signum();
        let vb = Mat::gaussian(d_in, r, rng).signum();
        let mut h = vec![0.0f32; d_out];
        let mut l = vec![0.0f32; r];
        let mut g = vec![0.0f32; d_in];
        rng.fill_uniform(&mut h, 0.5, 1.5);
        rng.fill_uniform(&mut l, 0.1, 1.0);
        rng.fill_uniform(&mut g, 0.5, 1.5);
        TriScaleLayer::new(&ub, &vb, h, l, g)
    }

    /// Batched forward must be bit-identical to the per-item forward: both
    /// paths share the same per-column reduction order by construction.
    #[test]
    fn forward_batch_matches_per_item_forward_bit_exactly() {
        let mut rng = Pcg64::seed(6);
        let (d_out, d_in, r, b) = (96, 80, 16, 11);
        let layer = random_layer(d_out, d_in, r, &mut rng);

        let mut x = Mat::zeros(d_in, b);
        x.fill_normal(&mut rng);
        let batched = layer.forward_batch(&x);
        let threaded = layer.forward_batch_mt(&x, 4);
        assert_eq!(batched, threaded, "threading changed the result");
        for t in 0..b {
            let want = layer.forward(&x.col(t));
            for i in 0..d_out {
                assert_eq!(
                    batched.at(i, t).to_bits(),
                    want[i].to_bits(),
                    "({i},{t}): {} vs {}",
                    batched.at(i, t),
                    want[i]
                );
            }
        }
    }

    /// The tentpole acceptance contract: the fused pool path must be
    /// bit-exact against the PR 1 scoped-spawn unfused path, at every
    /// thread count, including a ragged d_in spanning words plus a tail.
    #[test]
    fn fused_pool_matches_scoped_unfused_bit_exactly() {
        let mut rng = Pcg64::seed(7);
        for (d_out, d_in, r, b) in [(96, 80, 16, 11), (33, 130, 24, 8), (20, 200, 16, 5)] {
            let layer = random_layer(d_out, d_in, r, &mut rng);
            let mut x = Mat::zeros(d_in, b);
            x.fill_normal(&mut rng);
            for threads in [1usize, 2, 7, 64] {
                let scoped = layer.forward_batch_scoped(&x, threads);
                let fused = layer.forward_batch_mt(&x, threads);
                assert_eq!(scoped, fused, "{d_out}x{d_in} r={r} threads={threads}");
            }
        }
    }

    /// One `BatchScratch` must serve calls of varying batch size and layer
    /// shape without cross-talk: each call's output equals a fresh-scratch
    /// run, bit for bit.
    #[test]
    fn batch_scratch_reuse_across_shapes_is_clean() {
        let mut rng = Pcg64::seed(8);
        let wide = random_layer(48, 96, 12, &mut rng);
        let tall = random_layer(96, 48, 8, &mut rng);
        let mut scratch = BatchScratch::default();
        let mut y = Mat::default();
        let pool = SignPool::global();
        for (layer, b) in [(&wide, 9usize), (&tall, 3), (&wide, 1), (&tall, 12), (&wide, 5)] {
            let mut x = Mat::zeros(layer.d_in(), b);
            x.fill_normal(&mut rng);
            layer.forward_batch_into(&x, &mut y, &mut scratch, pool, 2);
            let fresh = layer.forward_batch(&x);
            assert_eq!(y, fresh, "b={b}");
        }
    }

    #[test]
    fn op_counts_match_formula() {
        let mut rng = Pcg64::seed(4);
        let ub = Mat::gaussian(128, 16, &mut rng).signum();
        let vb = Mat::gaussian(96, 16, &mut rng).signum();
        let layer =
            TriScaleLayer::new(&ub, &vb, vec![1.0; 128], vec![1.0; 16], vec![1.0; 96]);
        let (adds, mults) = layer.op_counts();
        assert_eq!(adds, 16 * (128 + 96));
        assert_eq!(mults, 96 + 16 + 128);
    }
}
