//! A cluster peer: loads its assigned slice of the chain and serves
//! activation frames.
//!
//! Lifecycle: bind a serve listener, dial the tracker, JOIN with the
//! serve address, then heartbeat on that registration connection and
//! reload whenever an ASSIGN arrives — a re-shard is just another ASSIGN
//! at a higher epoch. Shard loads go through the partial-load path
//! ([`MethodStack::load_range`]/[`load_range_mmap`]) in pipeline mode,
//! so a peer never decodes (or, mapped, never pages in) layers outside
//! its range; in row-shard mode the peer loads the stack once and keeps
//! only its [`MethodLayer::slice_rows`] cut per layer — row shards of a
//! mapped v3 artifact still share one page-cache copy of the input-side
//! planes.

use super::plan::{Assignment, ShardMode};
use super::wire::{split_act_aux, FrameStream};
use crate::model::{MethodLayer, MethodStack};
use crate::parallel::row_partition;
use crate::serving::frame::{err_code, payload_f32, Frame, FrameKind};
use anyhow::{Context, Result};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Peer configuration. `listen` defaults to an ephemeral loopback port —
/// the actual bound address is what JOIN registers.
#[derive(Clone, Debug)]
pub struct PeerConfig {
    /// Tracker address to register with.
    pub tracker: String,
    /// Serve listener bind address (`host:0` picks a free port).
    pub listen: String,
    /// The `.lb2` artifact this peer loads shards of.
    pub model: PathBuf,
    /// Map the artifact instead of reading it (v3 shards then serve
    /// straight from the page cache).
    pub mmap: bool,
    /// Heartbeat cadence on the registration connection. Must be
    /// comfortably under the tracker's heartbeat timeout.
    pub heartbeat_interval: Duration,
}

impl PeerConfig {
    pub fn new(tracker: impl Into<String>, model: impl Into<PathBuf>) -> Self {
        Self {
            tracker: tracker.into(),
            listen: "127.0.0.1:0".into(),
            model: model.into(),
            mmap: false,
            heartbeat_interval: Duration::from_millis(250),
        }
    }
}

/// What the peer currently serves (swapped whole on every ASSIGN).
struct ShardState {
    assignment: Assignment,
    /// Pipeline mode: the contiguous sub-chain (None when idle).
    stage: Option<MethodStack>,
    /// Row-shard mode: this shard's rows of each layer (None where the
    /// partition has fewer shards than peers).
    slices: Vec<Option<MethodLayer>>,
}

/// A running peer. Dropping the handle does NOT stop the peer — call
/// [`stop`](Self::stop) (abrupt, the kill-test path) or
/// [`wait`](Self::wait) (block until the tracker shuts it down).
pub struct Peer;

pub struct PeerHandle {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    state: Arc<Mutex<Option<ShardState>>>,
    threads: Vec<JoinHandle<()>>,
}

impl Peer {
    /// Bind the serve listener, then spawn the accept loop and the
    /// registration/heartbeat loop. Returns as soon as the listener is
    /// live; the JOIN/ASSIGN handshake completes in the background
    /// (query [`PeerHandle::epoch`] to observe it).
    pub fn start(cfg: PeerConfig) -> Result<PeerHandle> {
        let listener = TcpListener::bind(&cfg.listen)
            .with_context(|| format!("binding peer listener on {}", cfg.listen))?;
        let addr = listener.local_addr().context("peer listener local addr")?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let state: Arc<Mutex<Option<ShardState>>> = Arc::new(Mutex::new(None));

        let accept = {
            let (state, shutdown) = (state.clone(), shutdown.clone());
            std::thread::spawn(move || accept_loop(listener, state, shutdown))
        };
        let registration = {
            let (state, shutdown, cfg) = (state.clone(), shutdown.clone(), cfg);
            let serve_addr = addr.to_string();
            std::thread::spawn(move || registration_loop(cfg, serve_addr, state, shutdown))
        };

        Ok(PeerHandle { addr, shutdown, state, threads: vec![accept, registration] })
    }
}

impl PeerHandle {
    /// The serve address this peer registered with the tracker.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The epoch of the currently-served assignment (None before the
    /// first ASSIGN lands).
    pub fn epoch(&self) -> Option<u32> {
        self.state.lock().unwrap().as_ref().map(|s| s.assignment.epoch)
    }

    /// A copy of the current assignment, for tests and status prints.
    pub fn assignment(&self) -> Option<Assignment> {
        self.state.lock().unwrap().as_ref().map(|s| s.assignment.clone())
    }

    /// True until [`stop`](Self::stop) or a tracker-sent SHUTDOWN.
    pub fn running(&self) -> bool {
        !self.shutdown.load(Ordering::Relaxed)
    }

    /// Stop abruptly: threads exit at their next poll tick and the
    /// registration connection drops, which is exactly how the tracker
    /// notices the death — the kill test uses this as the failure
    /// injection.
    pub fn stop(self) {
        self.shutdown.store(true, Ordering::Relaxed);
        for t in self.threads {
            t.join().ok();
        }
    }

    /// Block until the peer exits (tracker shutdown or [`stop`](Self::stop)
    /// from another handle — there is none, so in practice: tracker
    /// shutdown).
    pub fn wait(self) {
        for t in self.threads {
            t.join().ok();
        }
    }
}

/// Build the serveable state for an assignment.
fn load_shard(cfg: &PeerConfig, a: Assignment) -> Result<ShardState> {
    match a.mode {
        ShardMode::Pipeline => {
            let stage = if a.is_idle() || a.lo == a.hi {
                None
            } else if cfg.mmap {
                Some(MethodStack::load_range_mmap(&cfg.model, a.layers())?)
            } else {
                Some(MethodStack::load_range(&cfg.model, a.layers())?)
            };
            Ok(ShardState { assignment: a, stage, slices: Vec::new() })
        }
        ShardMode::RowShard => {
            let full = if cfg.mmap {
                MethodStack::load_mmap(&cfg.model)?
            } else {
                MethodStack::load(&cfg.model)?
            };
            let mut slices = Vec::with_capacity(full.depth());
            for l in full.layers() {
                let ranges = row_partition(l.layer.d_out(), a.total as usize);
                slices.push(match ranges.get(a.index as usize) {
                    Some(r) => Some(l.layer.slice_rows(r.clone())?),
                    None => None,
                });
            }
            Ok(ShardState { assignment: a, stage: None, slices })
        }
    }
}

/// Dial the tracker, JOIN, then alternate heartbeats with ASSIGN/SHUTDOWN
/// reads. Reconnects (fresh JOIN) if the tracker connection drops.
fn registration_loop(
    cfg: PeerConfig,
    serve_addr: String,
    state: Arc<Mutex<Option<ShardState>>>,
    shutdown: Arc<AtomicBool>,
) {
    while !shutdown.load(Ordering::Relaxed) {
        let mut fs = match FrameStream::connect(&cfg.tracker, Duration::from_secs(2)) {
            Ok(fs) => fs,
            Err(_) => {
                std::thread::sleep(Duration::from_millis(300));
                continue;
            }
        };
        if fs.send(&Frame::join(0, &serve_addr)).is_err() {
            continue;
        }
        // The recv timeout doubles as the heartbeat cadence: one beat per
        // idle poll tick.
        fs.set_read_timeout(Some(cfg.heartbeat_interval)).ok();
        let mut beat: u64 = 0;
        while !shutdown.load(Ordering::Relaxed) {
            let epoch =
                state.lock().unwrap().as_ref().map(|s| s.assignment.epoch).unwrap_or(0);
            beat += 1;
            if fs.send(&Frame::heartbeat(beat, epoch)).is_err() {
                break;
            }
            match fs.recv_opt() {
                Ok(None) => {}
                Ok(Some(f)) => match f.kind {
                    FrameKind::Assign => match Assignment::decode(&f.payload)
                        .and_then(|a| load_shard(&cfg, a))
                    {
                        Ok(st) => {
                            eprintln!(
                                "[lb2-peer {serve_addr}] epoch {} assignment: {} {}..{} ({}/{})",
                                st.assignment.epoch,
                                st.assignment.mode.label(),
                                st.assignment.lo,
                                st.assignment.hi,
                                st.assignment.index,
                                st.assignment.total,
                            );
                            *state.lock().unwrap() = Some(st);
                        }
                        Err(e) => {
                            eprintln!("[lb2-peer {serve_addr}] assignment failed: {e:#}")
                        }
                    },
                    FrameKind::Shutdown => {
                        shutdown.store(true, Ordering::Relaxed);
                        return;
                    }
                    _ => {}
                },
                Err(_) => break, // tracker connection lost → re-dial and re-JOIN
            }
        }
    }
}

fn accept_loop(
    listener: TcpListener,
    state: Arc<Mutex<Option<ShardState>>>,
    shutdown: Arc<AtomicBool>,
) {
    listener.set_nonblocking(true).ok();
    let mut handlers: Vec<JoinHandle<()>> = Vec::new();
    while !shutdown.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _)) => {
                let (state, shutdown) = (state.clone(), shutdown.clone());
                handlers.push(std::thread::spawn(move || serve_conn(stream, state, shutdown)));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(20)),
        }
    }
    for h in handlers {
        h.join().ok();
    }
}

/// One serve connection: strictly request/response — ACT in, RESULT /
/// PART / ERROR out.
fn serve_conn(
    stream: TcpStream,
    state: Arc<Mutex<Option<ShardState>>>,
    shutdown: Arc<AtomicBool>,
) {
    stream.set_nonblocking(false).ok();
    let mut fs = FrameStream::over(stream);
    fs.set_read_timeout(Some(Duration::from_millis(200))).ok();
    // Pipeline stages keep one lazily-dialed connection to the next stage
    // per upstream connection; it is dropped (and re-dialed) on any
    // downstream error or address change.
    let mut downstream: Option<(String, FrameStream)> = None;
    while !shutdown.load(Ordering::Relaxed) {
        let frame = match fs.recv_opt() {
            Ok(None) => continue,
            Ok(Some(f)) => f,
            Err(_) => break,
        };
        match frame.kind {
            FrameKind::Act => handle_act(&mut fs, &mut downstream, frame, &state),
            FrameKind::Health => {
                let code = u32::from(state.lock().unwrap().is_none());
                let name = if code == 0 { "healthy" } else { "degraded" };
                let _ = fs.send(&Frame::health_report(frame.id, code, name));
            }
            _ => {
                let _ = fs.send(&Frame::error(
                    frame.id,
                    err_code::PROTOCOL,
                    "peers accept only ACT/HEALTH frames; clients connect to the tracker",
                ));
            }
        }
    }
}

/// The reply (or forwarding step) an ACT resolves to — computed under
/// the state lock, executed after it is released so a slow downstream
/// peer cannot block re-assignment.
enum Step {
    Reply(Frame),
    Forward { next: String, y: Vec<f32> },
}

fn handle_act(
    fs: &mut FrameStream,
    downstream: &mut Option<(String, FrameStream)>,
    frame: Frame,
    state: &Arc<Mutex<Option<ShardState>>>,
) {
    let (epoch16, layer) = split_act_aux(frame.aux);
    let x = match payload_f32(&frame.payload) {
        Ok(x) => x,
        Err(e) => {
            let _ = fs.send(&Frame::error(frame.id, err_code::BAD_REQUEST, &e.to_string()));
            return;
        }
    };
    let step = {
        let guard = state.lock().unwrap();
        match guard.as_ref() {
            None => Step::Reply(Frame::error(
                frame.id,
                err_code::BACKEND,
                "no shard assignment yet",
            )),
            Some(st) if (st.assignment.epoch & 0xFFFF) as u16 != epoch16 => {
                Step::Reply(Frame::error(
                    frame.id,
                    err_code::BACKEND,
                    &format!(
                        "stale epoch stamp {epoch16} (serving epoch {})",
                        st.assignment.epoch
                    ),
                ))
            }
            Some(st) => match st.assignment.mode {
                ShardMode::Pipeline => match st.stage.as_ref() {
                    None => Step::Reply(Frame::error(
                        frame.id,
                        err_code::BACKEND,
                        "stage is idle at this epoch",
                    )),
                    Some(stage) if x.len() != stage.d_in() => Step::Reply(Frame::error(
                        frame.id,
                        err_code::BAD_REQUEST,
                        &format!("input width {} != stage d_in {}", x.len(), stage.d_in()),
                    )),
                    Some(stage) => {
                        let y = stage.forward(&x);
                        if st.assignment.next.is_empty() {
                            Step::Reply(Frame::result(frame.id, &y, 1))
                        } else {
                            Step::Forward { next: st.assignment.next.clone(), y }
                        }
                    }
                },
                ShardMode::RowShard => match st.slices.get(layer as usize) {
                    None => Step::Reply(Frame::error(
                        frame.id,
                        err_code::BAD_REQUEST,
                        &format!("layer {layer} out of range"),
                    )),
                    // This shard holds no rows of this layer (partition
                    // shorter than the peer count): an empty PART keeps
                    // the tracker's gather loop uniform.
                    Some(None) => {
                        Step::Reply(Frame::part(frame.id, st.assignment.index, &[]))
                    }
                    Some(Some(slice)) if x.len() != slice.d_in() => {
                        Step::Reply(Frame::error(
                            frame.id,
                            err_code::BAD_REQUEST,
                            &format!(
                                "layer {layer} input width {} != d_in {}",
                                x.len(),
                                slice.d_in()
                            ),
                        ))
                    }
                    Some(Some(slice)) => Step::Reply(Frame::part(
                        frame.id,
                        st.assignment.index,
                        &slice.forward(&x),
                    )),
                },
            },
        }
    };
    match step {
        Step::Reply(reply) => {
            let _ = fs.send(&reply);
        }
        Step::Forward { next, y } => forward_downstream(fs, downstream, frame.id, frame.aux, next, &y),
    }
}

/// Send the stage output down the chain and relay the response (RESULT
/// or ERROR) back upstream unchanged — the terminal stage's RESULT rides
/// the chain back to the tracker through every intermediate relay.
fn forward_downstream(
    fs: &mut FrameStream,
    downstream: &mut Option<(String, FrameStream)>,
    id: u64,
    aux: u32,
    next: String,
    y: &[f32],
) {
    let stale = !matches!(downstream, Some((addr, _)) if *addr == next);
    if stale {
        match FrameStream::connect(&next, Duration::from_secs(1)) {
            Ok(conn) => {
                conn.set_read_timeout(Some(Duration::from_secs(10))).ok();
                *downstream = Some((next.clone(), conn));
            }
            Err(e) => {
                let _ = fs.send(&Frame::error(
                    id,
                    err_code::BACKEND,
                    &format!("dialing next stage {next}: {e:#}"),
                ));
                return;
            }
        }
    }
    let (_, conn) = downstream.as_mut().expect("dialed above");
    let relayed = conn.send(&Frame::act(id, aux, y)).and_then(|()| conn.recv());
    match relayed {
        Ok(resp)
            if resp.id == id
                && matches!(resp.kind, FrameKind::Result | FrameKind::Error) =>
        {
            let _ = fs.send(&resp);
        }
        Ok(resp) => {
            *downstream = None;
            let _ = fs.send(&Frame::error(
                id,
                err_code::BACKEND,
                &format!("desynced response from next stage: {:?} id {}", resp.kind, resp.id),
            ));
        }
        Err(e) => {
            *downstream = None;
            let _ = fs.send(&Frame::error(
                id,
                err_code::BACKEND,
                &format!("next stage {next} failed: {e:#}"),
            ));
        }
    }
}
