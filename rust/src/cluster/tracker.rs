//! The tracker: membership, shard planning, and the client-facing
//! front-end of a sharded cluster.
//!
//! The tracker loads only the artifact's **shape table**
//! ([`load_stack_shapes`]) — never a weight byte — and listens on one
//! socket. The first frame classifies each connection: JOIN makes it a
//! peer registration connection (assignment pushes + heartbeats ride it
//! for the peer's lifetime), anything else makes it a client connection
//! speaking the ordinary INFER/STATS/HEALTH/SHUTDOWN protocol, so the
//! stock [`WireClient`](crate::serving::WireClient) and the `client` CLI
//! work against a tracker unchanged.
//!
//! ## Plan state machine
//!
//! ```text
//!            JOIN (quorum not yet met)
//!   FORMING ──────────────────────────▶ FORMING   (epoch 0, no plan)
//!   FORMING ── quorum-th JOIN ────────▶ SERVING   (epoch 1: first plan)
//!   SERVING ── JOIN / peer death ─────▶ SERVING   (epoch += 1, re-cut
//!                                                  over alive peers,
//!                                                  ASSIGN pushed to all)
//!   SERVING ── last peer dies ────────▶ SERVING   (epoch += 1; drives
//!                                                  block until a peer
//!                                                  rejoins or deadline)
//!   any     ── SHUTDOWN frame ────────▶ DRAINING  (peers get SHUTDOWN)
//! ```
//!
//! Every accepted request is driven to exactly one reply: a failed
//! attempt (peer death mid-request, stale-epoch rejection, connection
//! loss) resets the drive connections and **replays** the request
//! against the current plan, so the [`ClusterStats`] ledger reconciles
//! (`accepted == served + failed + deadline_missed`) at every drain
//! point — the seeded kill test asserts exactly this.

use super::plan::{plan_assignments, Assignment, ShardMode};
use super::wire::{act_aux, FrameStream};
use super::ClusterStats;
use crate::artifact::{load_stack_shapes, StackShapes};
use crate::parallel::row_partition;
use crate::serving::frame::{err_code, payload_f32, Frame, FrameKind};
use anyhow::{bail, Context, Result};
use std::net::{SocketAddr, TcpListener};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct TrackerConfig {
    /// Bind address for the single tracker socket (`host:0` for tests).
    pub listen: String,
    /// The `.lb2` artifact — only its shape table is read here.
    pub model: PathBuf,
    pub mode: ShardMode,
    /// Peers to wait for before cutting the first plan.
    pub expect_peers: usize,
    /// Declare a peer dead after this long without any frame on its
    /// registration connection. Must comfortably exceed the peers'
    /// heartbeat interval.
    pub heartbeat_timeout: Duration,
    /// Drive attempts per request before giving up (each failed attempt
    /// re-snapshots the plan, so this bounds how many re-shards a single
    /// request can ride through).
    pub attempts: usize,
    /// Deadline for requests that do not carry one (INFER aux = 0).
    pub default_deadline_ms: u32,
}

impl TrackerConfig {
    pub fn new(model: impl Into<PathBuf>, mode: ShardMode) -> Self {
        Self {
            listen: "127.0.0.1:0".into(),
            model: model.into(),
            mode,
            expect_peers: 1,
            heartbeat_timeout: Duration::from_secs(2),
            attempts: 10,
            default_deadline_ms: 10_000,
        }
    }
}

struct PeerSlot {
    addr: String,
    alive: bool,
}

struct Membership {
    peers: Vec<PeerSlot>,
    /// 0 = FORMING (no plan yet); first plan is epoch 1.
    epoch: u32,
}

struct Shared {
    cfg: TrackerConfig,
    shapes: StackShapes,
    m: Mutex<Membership>,
    stats: ClusterStats,
    shutdown: AtomicBool,
}

impl Shared {
    /// Register a peer; cuts the first plan at quorum, re-cuts (epoch
    /// bump) when a peer joins a serving cluster.
    fn join(&self, addr: String) -> usize {
        let mut m = self.m.lock().unwrap();
        m.peers.push(PeerSlot { addr, alive: true });
        let alive = m.peers.iter().filter(|p| p.alive).count();
        if m.epoch > 0 {
            m.epoch += 1;
            ClusterStats::inc(&self.stats.reassignments);
        } else if alive >= self.cfg.expect_peers {
            m.epoch = 1;
        }
        m.peers.len() - 1
    }

    /// Mark a peer dead (EOF or heartbeat timeout on its registration
    /// connection) and re-cut the plan over the survivors.
    fn mark_dead(&self, slot: usize) {
        let mut m = self.m.lock().unwrap();
        if !m.peers[slot].alive {
            return;
        }
        m.peers[slot].alive = false;
        if m.epoch > 0 {
            m.epoch += 1;
            ClusterStats::inc(&self.stats.reassignments);
        }
    }

    /// The assignment `slot` should serve right now (None while FORMING
    /// or when the peer is dead). Deterministic in (epoch, membership):
    /// every registration thread pushing from the same epoch pushes
    /// slices of the same plan.
    fn assignment_for(&self, slot: usize) -> Option<(u32, Assignment)> {
        let m = self.m.lock().unwrap();
        if m.epoch == 0 || !m.peers[slot].alive {
            return None;
        }
        let alive: Vec<String> =
            m.peers.iter().filter(|p| p.alive).map(|p| p.addr.clone()).collect();
        let pos = m.peers[..slot].iter().filter(|p| p.alive).count();
        let plan = plan_assignments(self.cfg.mode, m.epoch, &alive, self.shapes.depth());
        Some((m.epoch, plan[pos].clone()))
    }

    /// Current (epoch, alive peer addrs) when a plan exists and at least
    /// one peer survives.
    fn plan_snapshot(&self) -> Option<PlanSnapshot> {
        let m = self.m.lock().unwrap();
        if m.epoch == 0 {
            return None;
        }
        let peers: Vec<String> =
            m.peers.iter().filter(|p| p.alive).map(|p| p.addr.clone()).collect();
        if peers.is_empty() {
            return None;
        }
        Some(PlanSnapshot { epoch: m.epoch, peers })
    }

    fn counts(&self) -> (u32, usize, usize) {
        let m = self.m.lock().unwrap();
        (m.epoch, m.peers.iter().filter(|p| p.alive).count(), m.peers.len())
    }

    fn render_stats(&self) -> String {
        let (epoch, alive, members) = self.counts();
        self.stats.render(self.cfg.mode, epoch, alive, members)
    }

    fn health(&self) -> (u32, &'static str) {
        if self.shutdown.load(Ordering::Relaxed) {
            (2, "draining")
        } else {
            let (epoch, alive, _) = self.counts();
            if epoch > 0 && alive > 0 {
                (0, "healthy")
            } else {
                (1, "degraded")
            }
        }
    }
}

struct PlanSnapshot {
    epoch: u32,
    peers: Vec<String>,
}

/// Per-client-connection connections into the current plan: one to stage
/// 0 (pipeline) or one per shard peer (row-shard), re-dialed whenever the
/// epoch moves or an attempt fails.
#[derive(Default)]
struct DriveConns {
    epoch: u32,
    pipeline: Option<FrameStream>,
    shards: Vec<FrameStream>,
}

impl DriveConns {
    fn reset(&mut self) {
        self.epoch = 0;
        self.pipeline = None;
        self.shards.clear();
    }

    fn ensure(&mut self, mode: ShardMode, snap: &PlanSnapshot) -> Result<()> {
        let ready = self.epoch == snap.epoch
            && match mode {
                ShardMode::Pipeline => self.pipeline.is_some(),
                ShardMode::RowShard => self.shards.len() == snap.peers.len(),
            };
        if ready {
            return Ok(());
        }
        self.reset();
        match mode {
            ShardMode::Pipeline => {
                let conn = FrameStream::connect(&snap.peers[0], Duration::from_secs(1))?;
                conn.set_read_timeout(Some(Duration::from_secs(10)))?;
                self.pipeline = Some(conn);
            }
            ShardMode::RowShard => {
                for addr in &snap.peers {
                    let conn = FrameStream::connect(addr, Duration::from_secs(1))?;
                    conn.set_read_timeout(Some(Duration::from_secs(10)))?;
                    self.shards.push(conn);
                }
            }
        }
        self.epoch = snap.epoch;
        Ok(())
    }
}

pub struct Tracker;

pub struct TrackerHandle {
    addr: SocketAddr,
    shared: Arc<Shared>,
    thread: Option<JoinHandle<()>>,
}

impl Tracker {
    /// Read the shape table, bind the socket, and spawn the accept loop.
    /// Returns as soon as the socket is live; peers and clients connect
    /// from here on.
    pub fn start(cfg: TrackerConfig) -> Result<TrackerHandle> {
        let shapes = load_stack_shapes(&cfg.model)
            .with_context(|| format!("reading shard plan shapes from {}", cfg.model.display()))?;
        if shapes.depth() == 0 {
            bail!("{} holds an empty chain; nothing to shard", cfg.model.display());
        }
        let listener = TcpListener::bind(&cfg.listen)
            .with_context(|| format!("binding tracker on {}", cfg.listen))?;
        let addr = listener.local_addr().context("tracker local addr")?;
        let shared = Arc::new(Shared {
            cfg,
            shapes,
            m: Mutex::new(Membership { peers: Vec::new(), epoch: 0 }),
            stats: ClusterStats::default(),
            shutdown: AtomicBool::new(false),
        });
        let thread = {
            let shared = shared.clone();
            std::thread::spawn(move || accept_loop(listener, shared))
        };
        Ok(TrackerHandle { addr, shared, thread: Some(thread) })
    }
}

impl TrackerHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn stats(&self) -> &ClusterStats {
        &self.shared.stats
    }

    pub fn epoch(&self) -> u32 {
        self.shared.counts().0
    }

    pub fn alive_peers(&self) -> usize {
        self.shared.counts().1
    }

    /// The `lb2_cluster_*` exposition (same text a STATS frame returns).
    pub fn stats_text(&self) -> String {
        self.shared.render_stats()
    }

    /// Block until the first plan is cut (quorum reached), up to
    /// `timeout`. Returns whether a plan exists.
    pub fn wait_for_plan(&self, timeout: Duration) -> bool {
        let start = Instant::now();
        while start.elapsed() < timeout {
            if self.epoch() > 0 {
                return true;
            }
            std::thread::sleep(Duration::from_millis(20));
        }
        self.epoch() > 0
    }

    pub fn is_shutting_down(&self) -> bool {
        self.shared.shutdown.load(Ordering::Relaxed)
    }

    /// Initiate shutdown (peers get SHUTDOWN on their registration
    /// connections), join every tracker thread, and report the settled
    /// ledger — mirrors [`TcpFrontend::shutdown`](crate::serving::TcpFrontend::shutdown).
    pub fn shutdown(mut self) -> ClusterSummary {
        self.shared.shutdown.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            t.join().ok();
        }
        let stats = &self.shared.stats;
        ClusterSummary {
            stats_text: self.shared.render_stats(),
            reconciled: stats.reconciled(),
            accepted: stats.accepted(),
            served: stats.served(),
            failed: stats.failed(),
            deadline_missed: stats.deadline_missed(),
            reassignments: stats.reassignments(),
        }
    }
}

/// The settled ledger a tracker reports after its threads drain.
#[derive(Clone, Debug)]
pub struct ClusterSummary {
    /// The final `lb2_cluster_*` exposition.
    pub stats_text: String,
    /// `accepted == served + failed + deadline_missed` — must hold at
    /// every drain point.
    pub reconciled: bool,
    pub accepted: u64,
    pub served: u64,
    pub failed: u64,
    pub deadline_missed: u64,
    pub reassignments: u64,
}

impl Drop for TrackerHandle {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            t.join().ok();
        }
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    listener.set_nonblocking(true).ok();
    let mut handlers: Vec<JoinHandle<()>> = Vec::new();
    while !shared.shutdown.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _)) => {
                stream.set_nonblocking(false).ok();
                let shared = shared.clone();
                handlers.push(std::thread::spawn(move || conn_entry(stream, shared)));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(20)),
        }
    }
    for h in handlers {
        h.join().ok();
    }
}

/// Classify a fresh connection by its first frame: JOIN → peer
/// registration; anything else → client protocol.
fn conn_entry(stream: std::net::TcpStream, shared: Arc<Shared>) {
    let mut fs = FrameStream::over(stream);
    fs.set_read_timeout(Some(Duration::from_secs(5))).ok();
    let first = match fs.recv() {
        Ok(f) => f,
        Err(_) => return,
    };
    match first.kind {
        FrameKind::Join => {
            let addr = match std::str::from_utf8(&first.payload) {
                Ok(a) if !a.is_empty() => a.to_string(),
                _ => {
                    let _ = fs.send(&Frame::error(
                        first.id,
                        err_code::BAD_REQUEST,
                        "JOIN payload must be a non-empty ASCII serve address",
                    ));
                    return;
                }
            };
            let slot = shared.join(addr);
            registration_conn(fs, shared, slot)
        }
        _ => client_conn(fs, shared, first),
    }
}

/// A peer's registration connection: push ASSIGNs whenever the epoch
/// moves past what this peer last saw, read heartbeats, and declare the
/// peer dead on EOF or a silent heartbeat window.
fn registration_conn(mut fs: FrameStream, shared: Arc<Shared>, slot: usize) {
    fs.set_read_timeout(Some(Duration::from_millis(100))).ok();
    let mut sent_epoch = 0u32;
    let mut last_seen = Instant::now();
    loop {
        if shared.shutdown.load(Ordering::Relaxed) {
            let _ = fs.send(&Frame::shutdown(0));
            return;
        }
        if let Some((epoch, a)) = shared.assignment_for(slot) {
            if epoch != sent_epoch {
                if fs.send(&Frame::assign(0, epoch, a.encode())).is_err() {
                    shared.mark_dead(slot);
                    return;
                }
                sent_epoch = epoch;
            }
        }
        match fs.recv_opt() {
            Ok(None) => {
                if last_seen.elapsed() > shared.cfg.heartbeat_timeout {
                    shared.mark_dead(slot);
                    return;
                }
            }
            Ok(Some(_)) => last_seen = Instant::now(),
            Err(_) => {
                // EOF or transport error: the fast death path — a killed
                // peer's socket closes long before its heartbeats stop
                // arriving.
                shared.mark_dead(slot);
                return;
            }
        }
    }
}

/// A client connection: the ordinary serving protocol, with INFER driven
/// through the cluster.
fn client_conn(mut fs: FrameStream, shared: Arc<Shared>, first: Frame) {
    fs.set_read_timeout(Some(Duration::from_millis(200))).ok();
    let mut conns = DriveConns::default();
    let mut pending = Some(first);
    while !shared.shutdown.load(Ordering::Relaxed) {
        let frame = match pending.take() {
            Some(f) => f,
            None => match fs.recv_opt() {
                Ok(None) => continue,
                Ok(Some(f)) => f,
                Err(_) => return,
            },
        };
        match frame.kind {
            FrameKind::Infer => {
                let reply = handle_infer(&shared, &mut conns, frame);
                if fs.send(&reply).is_err() {
                    return;
                }
            }
            FrameKind::Stats => {
                let _ = fs.send(&Frame::stats_text(frame.id, &shared.render_stats()));
            }
            FrameKind::Health => {
                let (code, name) = shared.health();
                let _ = fs.send(&Frame::health_report(frame.id, code, name));
            }
            FrameKind::Shutdown => {
                let _ = fs.send(&Frame::shutdown_ack(frame.id));
                shared.shutdown.store(true, Ordering::Relaxed);
                return;
            }
            _ => {
                let _ = fs.send(&Frame::error(
                    frame.id,
                    err_code::PROTOCOL,
                    "tracker accepts INFER/STATS/HEALTH/SHUTDOWN from clients",
                ));
            }
        }
    }
    // Shutdown mid-conversation: tell the client rather than just closing.
    let _ = fs.send(&Frame::error(0, err_code::SHUTTING_DOWN, "tracker is shutting down"));
}

/// Admit, drive (with replays), and settle one INFER into exactly one
/// reply frame and exactly one ledger outcome.
fn handle_infer(shared: &Shared, conns: &mut DriveConns, frame: Frame) -> Frame {
    ClusterStats::inc(&shared.stats.accepted);
    let x = match payload_f32(&frame.payload) {
        Ok(x) => x,
        Err(e) => {
            ClusterStats::inc(&shared.stats.failed);
            return Frame::error(frame.id, err_code::BAD_REQUEST, &e.to_string());
        }
    };
    if x.len() != shared.shapes.d_in() {
        ClusterStats::inc(&shared.stats.failed);
        return Frame::error(
            frame.id,
            err_code::BAD_REQUEST,
            &format!("input width {} != model d_in {}", x.len(), shared.shapes.d_in()),
        );
    }
    let deadline_ms =
        if frame.aux == 0 { shared.cfg.default_deadline_ms } else { frame.aux };
    let deadline = Duration::from_millis(u64::from(deadline_ms));
    let start = Instant::now();
    let mut attempts = 0usize;
    loop {
        if shared.shutdown.load(Ordering::Relaxed) {
            ClusterStats::inc(&shared.stats.failed);
            return Frame::error(frame.id, err_code::SHUTTING_DOWN, "tracker is shutting down");
        }
        if start.elapsed() >= deadline {
            ClusterStats::inc(&shared.stats.deadline_missed);
            return Frame::error(
                frame.id,
                err_code::DEADLINE,
                &format!("deadline passed after {attempts} attempts"),
            );
        }
        let Some(snap) = shared.plan_snapshot() else {
            // FORMING, or every peer is dead: wait for membership to
            // recover, bounded by the deadline.
            std::thread::sleep(Duration::from_millis(25));
            continue;
        };
        attempts += 1;
        match execute(shared, conns, &snap, frame.id, &x) {
            Ok(y) => {
                ClusterStats::inc(&shared.stats.served);
                return Frame::result(frame.id, &y, 1);
            }
            Err(e) => {
                conns.reset();
                if attempts >= shared.cfg.attempts {
                    ClusterStats::inc(&shared.stats.failed);
                    return Frame::error(
                        frame.id,
                        err_code::BACKEND,
                        &format!("failed after {attempts} attempts: {e:#}"),
                    );
                }
                // Replay against the (possibly re-cut) plan after a short
                // settle — re-shards land within a couple of ticks.
                ClusterStats::inc(&shared.stats.replays);
                std::thread::sleep(Duration::from_millis(25 * attempts.min(10) as u64));
            }
        }
    }
}

/// One drive attempt against one plan snapshot. Any `Err` here is
/// retryable — the caller resets the connections and replays.
fn execute(
    shared: &Shared,
    conns: &mut DriveConns,
    snap: &PlanSnapshot,
    id: u64,
    x: &[f32],
) -> Result<Vec<f32>> {
    conns.ensure(shared.cfg.mode, snap)?;
    let y = match shared.cfg.mode {
        ShardMode::Pipeline => {
            let conn = conns.pipeline.as_mut().expect("ensured");
            let act = Frame::act(id, act_aux(snap.epoch, 0), x);
            ClusterStats::add(&shared.stats.bytes_forward, act.payload.len() as u64);
            let t = Instant::now();
            conn.send(&act)?;
            let resp = conn.recv()?;
            ClusterStats::add(&shared.stats.stage_ns, t.elapsed().as_nanos() as u64);
            ClusterStats::inc(&shared.stats.stage_calls);
            match resp.kind {
                FrameKind::Result if resp.id == id => {
                    ClusterStats::add(&shared.stats.bytes_back, resp.payload.len() as u64);
                    payload_f32(&resp.payload).map_err(|e| anyhow::anyhow!(e))?
                }
                FrameKind::Error => bail!(
                    "stage error: {}",
                    String::from_utf8_lossy(&resp.payload)
                ),
                other => bail!("unexpected {other:?} (id {}) from stage 0", resp.id),
            }
        }
        ShardMode::RowShard => {
            let mut cur = x.to_vec();
            for (layer, &(_, d_out, _)) in shared.shapes.shapes.iter().enumerate() {
                let ranges = row_partition(d_out, snap.peers.len());
                let act = Frame::act(id, act_aux(snap.epoch, layer), &cur);
                let t = Instant::now();
                // Scatter to every shard that owns rows of this layer...
                for shard in 0..ranges.len() {
                    conns.shards[shard].send(&act)?;
                    ClusterStats::add(&shared.stats.bytes_forward, act.payload.len() as u64);
                }
                // ...then gather the slices back into partition order.
                let mut out = vec![0.0f32; d_out];
                for (shard, range) in ranges.iter().enumerate() {
                    let resp = conns.shards[shard].recv()?;
                    match resp.kind {
                        FrameKind::Part if resp.id == id && resp.aux == shard as u32 => {
                            let part = payload_f32(&resp.payload)
                                .map_err(|e| anyhow::anyhow!(e))?;
                            if part.len() != range.len() {
                                bail!(
                                    "shard {shard} returned {} rows of layer {layer}, expected {} — plan skew",
                                    part.len(),
                                    range.len()
                                );
                            }
                            ClusterStats::add(
                                &shared.stats.bytes_back,
                                resp.payload.len() as u64,
                            );
                            out[range.clone()].copy_from_slice(&part);
                        }
                        FrameKind::Error => bail!(
                            "shard {shard} error on layer {layer}: {}",
                            String::from_utf8_lossy(&resp.payload)
                        ),
                        other => {
                            bail!("unexpected {other:?} (id {}) from shard {shard}", resp.id)
                        }
                    }
                }
                ClusterStats::add(&shared.stats.stage_ns, t.elapsed().as_nanos() as u64);
                ClusterStats::inc(&shared.stats.stage_calls);
                cur = out;
            }
            cur
        }
    };
    if y.len() != shared.shapes.d_out() {
        bail!("cluster produced {} outputs, model d_out is {}", y.len(), shared.shapes.d_out());
    }
    Ok(y)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shared(mode: ShardMode, expect: usize, depth: usize) -> Shared {
        Shared {
            cfg: TrackerConfig {
                expect_peers: expect,
                ..TrackerConfig::new("unused.lb2", mode)
            },
            shapes: StackShapes {
                version: 2,
                shapes: (0..depth).map(|_| (8, 8, 1)).collect(),
            },
            m: Mutex::new(Membership { peers: Vec::new(), epoch: 0 }),
            stats: ClusterStats::default(),
            shutdown: AtomicBool::new(false),
        }
    }

    /// FORMING → SERVING at quorum; joins and deaths bump the epoch and
    /// re-cut over the alive peers in join order.
    #[test]
    fn membership_epoch_transitions() {
        let s = shared(ShardMode::Pipeline, 2, 4);
        let a = s.join("127.0.0.1:1".into());
        assert_eq!(s.counts(), (0, 1, 1), "below quorum: still FORMING");
        assert!(s.assignment_for(a).is_none());
        let b = s.join("127.0.0.1:2".into());
        assert_eq!(s.counts().0, 1, "quorum cuts the first plan");
        let (_, aa) = s.assignment_for(a).unwrap();
        let (_, ab) = s.assignment_for(b).unwrap();
        assert_eq!((aa.lo, aa.hi, aa.next.as_str()), (0, 2, "127.0.0.1:2"));
        assert_eq!((ab.lo, ab.hi, ab.next.as_str()), (2, 4, ""));

        // Kill the first stage: survivor owns the whole chain at epoch 2.
        s.mark_dead(a);
        assert_eq!(s.counts(), (2, 1, 2));
        assert!(s.assignment_for(a).is_none(), "dead peers get nothing");
        let (_, ab) = s.assignment_for(b).unwrap();
        assert_eq!((ab.index, ab.lo, ab.hi, ab.next.as_str()), (0, 0, 4, ""));
        assert_eq!(s.stats.reassignments(), 1);
        // Idempotent: a second death report of the same slot is a no-op.
        s.mark_dead(a);
        assert_eq!(s.counts().0, 2);

        // A late joiner re-cuts again (epoch 3) and lands after the
        // survivor in join order.
        let c = s.join("127.0.0.1:3".into());
        assert_eq!(s.counts(), (3, 2, 3));
        let (_, ab) = s.assignment_for(b).unwrap();
        let (_, ac) = s.assignment_for(c).unwrap();
        assert_eq!((ab.lo, ab.hi, ab.next.as_str()), (0, 2, "127.0.0.1:3"));
        assert_eq!((ac.lo, ac.hi), (2, 4));

        // No plan snapshot once everyone is gone.
        s.mark_dead(b);
        s.mark_dead(c);
        assert!(s.plan_snapshot().is_none());
        assert_eq!(s.counts().0, 5);
    }

    #[test]
    fn health_tracks_plan_and_drain() {
        let s = shared(ShardMode::RowShard, 1, 2);
        assert_eq!(s.health(), (1, "degraded"), "FORMING is degraded");
        let a = s.join("127.0.0.1:1".into());
        assert_eq!(s.health(), (0, "healthy"));
        s.mark_dead(a);
        assert_eq!(s.health(), (1, "degraded"), "no alive peers");
        s.shutdown.store(true, Ordering::Relaxed);
        assert_eq!(s.health(), (2, "draining"));
    }
}
