//! Raw frame transport for cluster connections.
//!
//! [`crate::serving::WireClient`] deliberately accepts only server→client
//! kinds, so tracker↔peer and peer↔peer links — which exchange
//! JOIN/ASSIGN/ACT/PART/HEARTBEAT both ways — get their own thin stream
//! wrapper over the same [`crate::serving::frame`] codec: one
//! `write_all` per frame out, header-then-payload with CRC verification
//! in, any kind accepted. Liveness loops use [`FrameStream::recv_opt`],
//! which peeks with the socket read timeout so an idle wait returns
//! `None` without consuming partial frames.

use crate::serving::frame::{
    frame_crc, parse_header, Frame, CRC_OFFSET, DEFAULT_MAX_PAYLOAD, HEADER_LEN,
};
use anyhow::{anyhow, bail, Context, Result};
use std::io::{Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

/// Pack an ACT frame's aux field: plan epoch (low 16 bits) in the high
/// half, layer index in the low half. The epoch stamp is what stops a
/// stage still serving an old plan from contributing to a fresh request
/// with a plausibly-shaped but wrong activation (uniform-width chains
/// would not catch the mix-up by width alone).
pub fn act_aux(epoch: u32, layer: usize) -> u32 {
    ((epoch & 0xFFFF) << 16) | (layer as u32 & 0xFFFF)
}

/// Split an ACT aux back into `(epoch_low16, layer)`.
pub fn split_act_aux(aux: u32) -> (u16, u16) {
    ((aux >> 16) as u16, (aux & 0xFFFF) as u16)
}

/// A frame-at-a-time TCP stream that accepts every [`Frame`] kind.
pub struct FrameStream {
    stream: TcpStream,
    max_payload: usize,
}

impl FrameStream {
    /// Dial `addr` with a connect timeout; `TCP_NODELAY` is set (frames
    /// are small and latency-bound).
    pub fn connect(addr: &str, timeout: Duration) -> Result<Self> {
        let sock = addr
            .to_socket_addrs()
            .with_context(|| format!("resolving {addr}"))?
            .next()
            .ok_or_else(|| anyhow!("{addr} resolved to no addresses"))?;
        let stream = TcpStream::connect_timeout(&sock, timeout)
            .with_context(|| format!("connecting to {addr}"))?;
        Ok(Self::over(stream))
    }

    /// Wrap an accepted connection.
    pub fn over(stream: TcpStream) -> Self {
        stream.set_nodelay(true).ok();
        Self { stream, max_payload: DEFAULT_MAX_PAYLOAD }
    }

    /// Socket read timeout for [`recv`](Self::recv)/[`recv_opt`](Self::recv_opt).
    pub fn set_read_timeout(&self, d: Option<Duration>) -> Result<()> {
        self.stream.set_read_timeout(d).context("setting read timeout")?;
        Ok(())
    }

    pub fn send(&mut self, frame: &Frame) -> Result<()> {
        self.stream.write_all(&frame.encode()).context("writing frame")?;
        Ok(())
    }

    /// Read one frame of any kind, verifying magic/version/length cap
    /// before allocation and the CRC after. Blocks (up to the socket read
    /// timeout) until a full frame arrives; a timeout mid-frame is an
    /// error — on a connection that only ever carries whole `write_all`'d
    /// frames that means the sender died, and the caller treats the
    /// connection as lost.
    pub fn recv(&mut self) -> Result<Frame> {
        let mut header = [0u8; HEADER_LEN];
        self.stream.read_exact(&mut header).context("reading frame header")?;
        let h = parse_header(&header, self.max_payload).context("parsing frame header")?;
        let mut payload = vec![0u8; h.len];
        self.stream.read_exact(&mut payload).context("reading frame payload")?;
        let got = frame_crc(&header[..CRC_OFFSET], &payload);
        if got != h.crc {
            bail!("frame CRC mismatch: expected {:08x}, got {got:08x}", h.crc);
        }
        Ok(Frame { kind: h.kind, id: h.id, aux: h.aux, payload })
    }

    /// Like [`recv`](Self::recv), but an idle read timeout returns
    /// `Ok(None)` instead of an error: a 1-byte `peek` absorbs the wait
    /// without consuming stream bytes, so the subsequent frame read only
    /// runs when at least the start of a frame has arrived. EOF (peer
    /// closed) and transport errors are `Err`.
    pub fn recv_opt(&mut self) -> Result<Option<Frame>> {
        let mut probe = [0u8; 1];
        match self.stream.peek(&mut probe) {
            Ok(0) => bail!("connection closed"),
            Ok(_) => self.recv().map(Some),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                Ok(None)
            }
            Err(e) => Err(anyhow!(e).context("polling connection")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    #[test]
    fn act_aux_packs_epoch_and_layer() {
        assert_eq!(act_aux(0, 0), 0);
        assert_eq!(split_act_aux(act_aux(3, 7)), (3, 7));
        // Epoch truncates to 16 bits; the stamp still distinguishes
        // adjacent epochs, which is all staleness detection needs.
        assert_eq!(split_act_aux(act_aux(0x1_0005, 2)), (5, 2));
    }

    /// Frames of every direction cross a real socket; recv_opt times out
    /// cleanly while idle and detects EOF.
    #[test]
    fn frame_stream_roundtrip_timeout_and_eof() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            let mut fs = FrameStream::over(s);
            let f = fs.recv().unwrap();
            assert_eq!(f.kind, crate::serving::FrameKind::Act);
            fs.send(&Frame::part(f.id, 1, &[2.5, -0.5])).unwrap();
            // Leave the connection open briefly so the client can observe
            // an idle timeout before the drop-induced EOF.
            std::thread::sleep(Duration::from_millis(120));
        });
        let mut fs =
            FrameStream::connect(&addr.to_string(), Duration::from_secs(2)).unwrap();
        fs.send(&Frame::act(9, act_aux(1, 0), &[1.0, 2.0])).unwrap();
        let back = fs.recv().unwrap();
        assert_eq!(back.id, 9);
        assert_eq!(back.aux, 1);
        fs.set_read_timeout(Some(Duration::from_millis(30))).unwrap();
        assert!(fs.recv_opt().unwrap().is_none(), "idle read should time out to None");
        server.join().unwrap();
        // Server side is gone: recv_opt must now surface the EOF as Err.
        fs.set_read_timeout(Some(Duration::from_millis(500))).unwrap();
        assert!(fs.recv_opt().is_err());
    }
}
