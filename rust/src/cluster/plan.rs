//! Shard plans: who serves which slice of the chain, encoded for the
//! ASSIGN frame.
//!
//! A plan is a pure function of `(mode, epoch, alive peers in join order,
//! chain depth)` — no hidden state, so the tracker and every peer agree
//! on the partition from the assignment alone, and a re-shard is just the
//! same function over the survivors at the next epoch. Row shards reuse
//! [`crate::parallel::row_partition`] — the exact split the in-process
//! row kernels use — which is what makes shard outputs concatenate
//! bit-identically to single-process serving.

use crate::parallel::row_partition;
use anyhow::{bail, Result};
use std::ops::Range;

/// How the chain is cut across peers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardMode {
    /// Contiguous layer ranges: peer k runs layers `lo..hi` and forwards
    /// its activations to peer k+1 (the tracker drives stage 0 and reads
    /// the final result back through the chain).
    Pipeline,
    /// Deterministic row shards of **every** layer: each peer holds rows
    /// `row_partition(d_out, total)[index]` of each layer; the tracker
    /// broadcasts each layer input and concatenates the PART slices in
    /// partition order.
    RowShard,
}

impl ShardMode {
    /// Wire code (the first byte of an encoded [`Assignment`]).
    pub fn code(self) -> u8 {
        match self {
            ShardMode::Pipeline => 1,
            ShardMode::RowShard => 2,
        }
    }

    pub fn from_code(c: u8) -> Result<Self> {
        Ok(match c {
            1 => ShardMode::Pipeline,
            2 => ShardMode::RowShard,
            other => bail!("unknown shard mode code {other}"),
        })
    }

    /// CLI spelling (`--mode pipeline|rowshard`).
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "pipeline" => ShardMode::Pipeline,
            "rowshard" | "row-shard" => ShardMode::RowShard,
            other => bail!("unknown shard mode {other:?} (expected pipeline or rowshard)"),
        })
    }

    pub fn label(self) -> &'static str {
        match self {
            ShardMode::Pipeline => "pipeline",
            ShardMode::RowShard => "rowshard",
        }
    }
}

/// One peer's slice of the plan — the ASSIGN frame payload.
///
/// ## Byte layout (little-endian)
///
/// | offset | size | field                                          |
/// |--------|------|------------------------------------------------|
/// | 0      | 1    | mode code (1 = pipeline, 2 = rowshard)         |
/// | 1      | 4    | epoch                                          |
/// | 5      | 4    | index (stage / shard position)                 |
/// | 9      | 4    | total (stages in plan / shards per layer)      |
/// | 13     | 4    | lo (first layer, pipeline; 0 otherwise)        |
/// | 17     | 4    | hi (one-past-last layer, pipeline; depth)      |
/// | 21     | 2    | next-address length `n`                        |
/// | 23     | n    | next stage's serve address, ASCII (pipeline    |
/// |        |      | only; empty for the last stage and rowshard)   |
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Assignment {
    pub mode: ShardMode,
    /// Plan generation; bumped on every membership change. Activation
    /// frames are stamped with it (low 16 bits) so a stale stage can
    /// never contribute to a fresh request.
    pub epoch: u32,
    /// This peer's position: pipeline stage number, or row-shard index.
    pub index: u32,
    /// Stages in the plan (pipeline) or shards per layer (rowshard).
    /// `index >= total` means the peer is idle at this epoch (more peers
    /// than layers).
    pub total: u32,
    /// Pipeline: the layer range `lo..hi` this stage serves. RowShard:
    /// `0..depth` (every peer touches every layer).
    pub lo: u32,
    pub hi: u32,
    /// Pipeline: the next stage's serve address (empty for the last
    /// stage). Always empty in rowshard mode.
    pub next: String,
}

impl Assignment {
    /// True when this peer serves nothing at this epoch.
    pub fn is_idle(&self) -> bool {
        self.index >= self.total
    }

    /// The layer range as a `Range`.
    pub fn layers(&self) -> Range<usize> {
        self.lo as usize..self.hi as usize
    }

    pub fn encode(&self) -> Vec<u8> {
        let next = self.next.as_bytes();
        let mut out = Vec::with_capacity(23 + next.len());
        out.push(self.mode.code());
        out.extend_from_slice(&self.epoch.to_le_bytes());
        out.extend_from_slice(&self.index.to_le_bytes());
        out.extend_from_slice(&self.total.to_le_bytes());
        out.extend_from_slice(&self.lo.to_le_bytes());
        out.extend_from_slice(&self.hi.to_le_bytes());
        out.extend_from_slice(&(next.len() as u16).to_le_bytes());
        out.extend_from_slice(next);
        out
    }

    pub fn decode(b: &[u8]) -> Result<Self> {
        if b.len() < 23 {
            bail!("ASSIGN payload is {} bytes; need at least 23", b.len());
        }
        let mode = ShardMode::from_code(b[0])?;
        let u32_at =
            |at: usize| u32::from_le_bytes(b[at..at + 4].try_into().expect("4 bytes"));
        let epoch = u32_at(1);
        let index = u32_at(5);
        let total = u32_at(9);
        let lo = u32_at(13);
        let hi = u32_at(17);
        if lo > hi {
            bail!("ASSIGN layer range {lo}..{hi} is inverted");
        }
        let next_len = u16::from_le_bytes([b[21], b[22]]) as usize;
        if b.len() != 23 + next_len {
            bail!("ASSIGN payload is {} bytes but declares a {next_len}-byte address", b.len());
        }
        let next = b[23..].to_vec();
        if !next.iter().all(|c| c.is_ascii_graphic()) {
            bail!("ASSIGN next-address contains non-printable bytes");
        }
        Ok(Self {
            mode,
            epoch,
            index,
            total,
            lo,
            hi,
            next: String::from_utf8(next).expect("ASCII validated"),
        })
    }
}

/// The full plan for one epoch: one [`Assignment`] per alive peer, in
/// join order. Pure in `(mode, epoch, peers, depth)`.
///
/// Pipeline mode cuts `depth` layers into `row_partition(depth,
/// peers.len())` contiguous ranges — stage k serves range k and forwards
/// to stage k+1's address; surplus peers (more peers than layers) get an
/// idle assignment and become re-shard spares. RowShard gives every peer
/// the same `0..depth` range with its shard position; the per-layer row
/// split is recomputed peer-side from `(index, total)`.
pub fn plan_assignments(
    mode: ShardMode,
    epoch: u32,
    peers: &[String],
    depth: usize,
) -> Vec<Assignment> {
    match mode {
        ShardMode::Pipeline => {
            let ranges = row_partition(depth, peers.len());
            (0..peers.len())
                .map(|i| {
                    let (lo, hi) = ranges
                        .get(i)
                        .map(|r| (r.start as u32, r.end as u32))
                        .unwrap_or((0, 0));
                    let next = if i + 1 < ranges.len() {
                        peers[i + 1].clone()
                    } else {
                        String::new()
                    };
                    Assignment {
                        mode,
                        epoch,
                        index: i as u32,
                        total: ranges.len() as u32,
                        lo,
                        hi,
                        next,
                    }
                })
                .collect()
        }
        ShardMode::RowShard => (0..peers.len())
            .map(|i| Assignment {
                mode,
                epoch,
                index: i as u32,
                total: peers.len() as u32,
                lo: 0,
                hi: depth as u32,
                next: String::new(),
            })
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn addrs(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("127.0.0.1:{}", 42000 + i)).collect()
    }

    #[test]
    fn assignment_roundtrips() {
        for a in [
            Assignment {
                mode: ShardMode::Pipeline,
                epoch: 7,
                index: 1,
                total: 3,
                lo: 2,
                hi: 4,
                next: "127.0.0.1:42002".into(),
            },
            Assignment {
                mode: ShardMode::RowShard,
                epoch: 1,
                index: 0,
                total: 2,
                lo: 0,
                hi: 6,
                next: String::new(),
            },
        ] {
            let back = Assignment::decode(&a.encode()).unwrap();
            assert_eq!(back, a);
        }
        // Truncation, bad mode, inverted range, and trailing garbage are
        // all rejected.
        let good = Assignment {
            mode: ShardMode::Pipeline,
            epoch: 1,
            index: 0,
            total: 1,
            lo: 0,
            hi: 2,
            next: String::new(),
        }
        .encode();
        assert!(Assignment::decode(&good[..22]).is_err());
        let mut bad = good.clone();
        bad[0] = 9;
        assert!(Assignment::decode(&bad).is_err());
        let mut inv = good.clone();
        inv[13..17].copy_from_slice(&5u32.to_le_bytes()); // lo = 5 > hi = 2
        assert!(Assignment::decode(&inv).is_err());
        let mut long = good;
        long.push(b'x');
        assert!(Assignment::decode(&long).is_err());
    }

    /// Pipeline plans tile the chain contiguously and chain the next
    /// addresses; surplus peers go idle.
    #[test]
    fn pipeline_plan_tiles_the_chain() {
        let peers = addrs(3);
        let plan = plan_assignments(ShardMode::Pipeline, 4, &peers, 5);
        assert_eq!(plan.len(), 3);
        assert_eq!((plan[0].lo, plan[0].hi, plan[0].next.as_str()), (0, 2, peers[1].as_str()));
        assert_eq!((plan[1].lo, plan[1].hi, plan[1].next.as_str()), (2, 4, peers[2].as_str()));
        assert_eq!((plan[2].lo, plan[2].hi, plan[2].next.as_str()), (4, 5, ""));
        assert!(plan.iter().all(|a| a.epoch == 4 && a.total == 3 && !a.is_idle()));

        // 4 peers, 2 layers: two stages, two idle spares.
        let peers = addrs(4);
        let plan = plan_assignments(ShardMode::Pipeline, 1, &peers, 2);
        assert_eq!(plan.len(), 4);
        assert!(!plan[0].is_idle() && !plan[1].is_idle());
        assert!(plan[2].is_idle() && plan[3].is_idle());
        assert_eq!(plan[1].next, "");

        // One survivor owns the whole chain — the re-shard degenerate.
        let plan = plan_assignments(ShardMode::Pipeline, 9, &addrs(1), 6);
        assert_eq!((plan[0].lo, plan[0].hi), (0, 6));
        assert_eq!(plan[0].next, "");
    }

    #[test]
    fn rowshard_plan_gives_every_peer_every_layer() {
        let plan = plan_assignments(ShardMode::RowShard, 2, &addrs(3), 4);
        for (i, a) in plan.iter().enumerate() {
            assert_eq!(a.index as usize, i);
            assert_eq!(a.total, 3);
            assert_eq!(a.layers(), 0..4);
            assert!(a.next.is_empty());
        }
    }
}
