//! Sharded tracker/peer serving: one `.lb2` chain split across
//! processes.
//!
//! The **tracker** ([`Tracker`]) is the only process a client talks to.
//! It loads nothing but the artifact's shape table
//! ([`crate::artifact::load_stack_shapes`]), waits for **peers**
//! ([`Peer`]) to JOIN, and hands each an [`Assignment`] cut by
//! [`plan_assignments`] in one of two modes:
//!
//! * [`ShardMode::Pipeline`] — peer k loads layers `lo..hi` via
//!   [`MethodStack::load_range`](crate::model::MethodStack::load_range)
//!   and forwards its activations to peer k+1; the tracker drives stage
//!   0 and relays the final RESULT to the client.
//! * [`ShardMode::RowShard`] — every peer holds output-row shard
//!   `row_partition(d_out, total)[index]` of **every** layer
//!   ([`MethodLayer::slice_rows`](crate::model::MethodLayer::slice_rows));
//!   the tracker broadcasts each layer input and concatenates the PART
//!   slices in partition order.
//!
//! Both cuts reuse [`crate::parallel::row_partition`] — the exact split
//! the in-process row kernels use — so cluster outputs are
//! **bit-identical** to a single-process
//! [`MethodStack::forward`](crate::model::MethodStack::forward).
//!
//! ## Membership and failure
//!
//! Peers register over a persistent connection and heartbeat on it; EOF
//! or a missed-heartbeat window marks the peer dead, bumps the plan
//! epoch, and re-cuts the chain over the survivors (the tracker pushes
//! fresh ASSIGNs down every surviving registration connection).
//! In-flight requests are **replayed** against the new plan by the
//! tracker's per-connection drive loop — each accepted request gets
//! exactly one reply, and the [`ClusterStats`] counters reconcile as
//! `accepted == served + failed + deadline_missed` at every drain point.
//! Activation frames carry an epoch stamp ([`act_aux`]) so a stage still
//! serving the old plan rejects them instead of contributing a
//! plausibly-shaped but wrong activation.
//!
//! Frames ride the [`crate::serving::frame`] codec (kinds 11–15) over
//! plain `std::net` — same discipline as the single-process front-end,
//! no async runtime.

mod peer;
mod plan;
mod tracker;
mod wire;

pub use peer::{Peer, PeerConfig, PeerHandle};
pub use plan::{plan_assignments, Assignment, ShardMode};
pub use tracker::{ClusterSummary, Tracker, TrackerConfig, TrackerHandle};
pub use wire::{act_aux, split_act_aux, FrameStream};

use std::sync::atomic::{AtomicU64, Ordering};

/// Tracker-side counters behind the `lb2_cluster_*` exposition. All
/// relaxed atomics: the counters order nothing, they only count.
#[derive(Debug, Default)]
pub struct ClusterStats {
    accepted: AtomicU64,
    served: AtomicU64,
    failed: AtomicU64,
    deadline_missed: AtomicU64,
    replays: AtomicU64,
    reassignments: AtomicU64,
    bytes_forward: AtomicU64,
    bytes_back: AtomicU64,
    stage_ns: AtomicU64,
    stage_calls: AtomicU64,
}

impl ClusterStats {
    pub(crate) fn inc(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn add(counter: &AtomicU64, n: u64) {
        counter.fetch_add(n, Ordering::Relaxed);
    }

    pub fn accepted(&self) -> u64 {
        self.accepted.load(Ordering::Relaxed)
    }
    pub fn served(&self) -> u64 {
        self.served.load(Ordering::Relaxed)
    }
    pub fn failed(&self) -> u64 {
        self.failed.load(Ordering::Relaxed)
    }
    pub fn deadline_missed(&self) -> u64 {
        self.deadline_missed.load(Ordering::Relaxed)
    }
    pub fn replays(&self) -> u64 {
        self.replays.load(Ordering::Relaxed)
    }
    pub fn reassignments(&self) -> u64 {
        self.reassignments.load(Ordering::Relaxed)
    }
    pub fn bytes_forward(&self) -> u64 {
        self.bytes_forward.load(Ordering::Relaxed)
    }
    pub fn bytes_back(&self) -> u64 {
        self.bytes_back.load(Ordering::Relaxed)
    }

    /// The exactly-once ledger: every accepted request must end in
    /// exactly one of served / failed / deadline-missed. True whenever no
    /// request is in flight.
    pub fn reconciled(&self) -> bool {
        self.accepted() == self.served() + self.failed() + self.deadline_missed()
    }

    /// Prometheus-style exposition, matching the single-process
    /// [`ServerStats`](crate::coordinator::ServerStats) text style.
    pub fn render(&self, mode: ShardMode, epoch: u32, alive: usize, members: usize) -> String {
        use std::fmt::Write as _;
        let mut s = String::with_capacity(640);
        let _ = writeln!(s, "lb2_cluster_mode{{mode=\"{}\"}} 1", mode.label());
        let _ = writeln!(s, "lb2_cluster_epoch {epoch}");
        let _ = writeln!(s, "lb2_cluster_peers_alive {alive}");
        let _ = writeln!(s, "lb2_cluster_peers_total {members}");
        let _ = writeln!(s, "lb2_cluster_reassignments_total {}", self.reassignments());
        let _ = writeln!(s, "lb2_cluster_accepted_total {}", self.accepted());
        let _ = writeln!(s, "lb2_cluster_served_total {}", self.served());
        let _ = writeln!(s, "lb2_cluster_failed_total {}", self.failed());
        let _ = writeln!(s, "lb2_cluster_deadline_missed_total {}", self.deadline_missed());
        let _ = writeln!(s, "lb2_cluster_replays_total {}", self.replays());
        let _ = writeln!(s, "lb2_cluster_bytes_forward_total {}", self.bytes_forward());
        let _ = writeln!(s, "lb2_cluster_bytes_back_total {}", self.bytes_back());
        let stage_ns = self.stage_ns.load(Ordering::Relaxed);
        let stage_calls = self.stage_calls.load(Ordering::Relaxed);
        let _ = writeln!(s, "lb2_cluster_stage_ns_total {stage_ns}");
        let _ = writeln!(s, "lb2_cluster_stage_calls_total {stage_calls}");
        let mean_us = if stage_calls == 0 {
            0.0
        } else {
            stage_ns as f64 / stage_calls as f64 / 1_000.0
        };
        let _ = writeln!(s, "lb2_cluster_stage_mean_us {mean_us:.2}");
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_render_and_reconcile() {
        let st = ClusterStats::default();
        assert!(st.reconciled(), "empty ledger reconciles");
        ClusterStats::add(&st.accepted, 5);
        ClusterStats::add(&st.served, 3);
        ClusterStats::inc(&st.failed);
        assert!(!st.reconciled(), "one request still in flight");
        ClusterStats::inc(&st.deadline_missed);
        assert!(st.reconciled());
        ClusterStats::add(&st.bytes_forward, 1024);
        ClusterStats::add(&st.stage_ns, 4_000);
        ClusterStats::add(&st.stage_calls, 2);
        let text = st.render(ShardMode::RowShard, 3, 2, 3);
        for needle in [
            "lb2_cluster_mode{mode=\"rowshard\"} 1",
            "lb2_cluster_epoch 3",
            "lb2_cluster_peers_alive 2",
            "lb2_cluster_peers_total 3",
            "lb2_cluster_accepted_total 5",
            "lb2_cluster_served_total 3",
            "lb2_cluster_failed_total 1",
            "lb2_cluster_deadline_missed_total 1",
            "lb2_cluster_bytes_forward_total 1024",
            "lb2_cluster_stage_mean_us 2.00",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
    }
}
