//! Method-generic sequential model execution: a chain of
//! [`MethodLayer`]s — each possibly produced by a *different* quantizer —
//! run end to end on whole batches, persisted as a `.lb2` v2 artifact.
//!
//! [`MethodStack`] is the generalization of [`PackedStack`]: the serving
//! spine (server backends, streaming compression jobs, the artifact
//! reader/writer) consumes this type, so every baseline of the paper's
//! Table 1 — not just LittleBit-2 — flows through the real
//! compress → save → load → serve pipeline. Activations stay
//! feature-major (`d × b`) across the whole chain, exactly like
//! `PackedStack`, and the batch never deinterleaves.

use super::method::MethodLayer;
use super::PackedStack;
use crate::linalg::Mat;
use crate::packing::{BatchScratch, SignPool};

/// One chained layer: the [`MethodLayer`] plus the name of the method
/// that produced it (the `.lb2` v2 METHOD tag, e.g. `"onebit"`).
#[derive(Clone, Debug, PartialEq)]
pub struct MethodStackLayer {
    pub method: String,
    pub layer: MethodLayer,
}

/// A chain of method-generic layers with matching inner dimensions.
#[derive(Clone, Debug, PartialEq)]
pub struct MethodStack {
    layers: Vec<MethodStackLayer>,
}

impl MethodStack {
    /// Compose layers; panics on a broken chain (programmer error).
    pub fn new(layers: Vec<MethodStackLayer>) -> Self {
        Self::try_new(layers).expect("valid method chain")
    }

    /// Fallible [`new`](Self::new) for deserialization boundaries: a
    /// malformed chain is `Err`, never a panic.
    pub fn try_new(layers: Vec<MethodStackLayer>) -> anyhow::Result<Self> {
        if layers.is_empty() {
            anyhow::bail!("stack needs at least one layer");
        }
        for k in 1..layers.len() {
            if layers[k - 1].layer.d_out() != layers[k].layer.d_in() {
                anyhow::bail!(
                    "chain mismatch: layer {} emits {} features but layer {k} consumes {}",
                    k - 1,
                    layers[k - 1].layer.d_out(),
                    layers[k].layer.d_in()
                );
            }
        }
        Ok(Self { layers })
    }

    /// Uniform-method convenience: every layer tagged with `method`.
    pub fn uniform(method: &str, layers: Vec<MethodLayer>) -> anyhow::Result<Self> {
        Self::try_new(
            layers
                .into_iter()
                .map(|layer| MethodStackLayer { method: method.to_string(), layer })
                .collect(),
        )
    }

    pub fn depth(&self) -> usize {
        self.layers.len()
    }

    pub fn d_in(&self) -> usize {
        self.layers[0].layer.d_in()
    }

    pub fn d_out(&self) -> usize {
        self.layers[self.layers.len() - 1].layer.d_out()
    }

    pub fn layers(&self) -> &[MethodStackLayer] {
        &self.layers
    }

    /// `"littlebit2"` when every layer shares one method, `"mixed"`
    /// otherwise — the serve-time banner label.
    pub fn method_summary(&self) -> &str {
        let first = self.layers[0].method.as_str();
        if self.layers.iter().all(|l| l.method == first) {
            first
        } else {
            "mixed"
        }
    }

    /// Total serving-form weight bytes across the chain.
    pub fn storage_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.layer.storage_bytes()).sum()
    }

    /// Total declared App. H storage bits across the chain.
    pub fn declared_bits(&self) -> u64 {
        self.layers.iter().map(|l| l.layer.declared_bits()).sum()
    }

    /// Weight bytes held on this process's heap. Disjoint from
    /// [`mapped_bytes`](Self::mapped_bytes) by construction, so the eval
    /// bpp audit can add the two without double-counting.
    pub fn resident_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.layer.resident_bytes()).sum()
    }

    /// Weight bytes served from the page cache through a live `.lb2`
    /// mapping (0 after an eager [`load`](Self::load)).
    pub fn mapped_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.layer.mapped_bytes()).sum()
    }

    /// True when any layer borrows its planes/scales from a live mapping.
    pub fn is_mapped(&self) -> bool {
        self.layers.iter().any(|l| l.layer.mapped_bytes() > 0)
    }

    /// Persist as a `.lb2` **format v2** artifact (per-layer METHOD tags;
    /// see [`crate::artifact`] for the byte layout). Round-trips
    /// bit-exactly through [`load`](Self::load).
    pub fn save(&self, path: impl AsRef<std::path::Path>) -> anyhow::Result<()> {
        crate::artifact::save_method_stack(self, path)
    }

    /// Persist as a `.lb2` **format v3** "aligned" artifact: bit-planes at
    /// the padded in-memory row stride, every plane and section payload
    /// 32-byte aligned in the file, so [`load_mmap`](Self::load_mmap) can
    /// serve the mapped bytes directly as kernel operands.
    pub fn save_aligned(&self, path: impl AsRef<std::path::Path>) -> anyhow::Result<()> {
        crate::artifact::save_method_stack_aligned(self, path)
    }

    /// Load a `.lb2` artifact — **either** format version: v2 loads each
    /// layer under its METHOD tag; a v1 artifact (PR 3/4 era) decodes as
    /// an all-`Packed` `littlebit2` stack with bit-identical forwards.
    pub fn load(path: impl AsRef<std::path::Path>) -> anyhow::Result<Self> {
        crate::artifact::load_method_stack(path)
    }

    /// Load by mapping the file instead of reading it: bit-planes and
    /// scale vectors of a v3 aligned artifact borrow the mapping (the
    /// kernel operands live in the page cache, shared across processes);
    /// v1/v2 or misaligned payloads fall back to copy-and-restride, so
    /// the result forwards bit-identically to [`load`](Self::load) on the
    /// same file either way. The mapping stays alive for as long as any
    /// layer borrows from it.
    pub fn load_mmap(path: impl AsRef<std::path::Path>) -> anyhow::Result<Self> {
        crate::artifact::load_method_stack_mmap(path)
    }

    /// Load only layers `range` (half-open, chain order) of a `.lb2`
    /// artifact — the pipeline-parallel shard load: the returned stack is
    /// the contiguous sub-chain, bit-identical to those layers inside the
    /// full stack, and out-of-range payloads are never decoded.
    pub fn load_range(
        path: impl AsRef<std::path::Path>,
        range: std::ops::Range<usize>,
    ) -> anyhow::Result<Self> {
        let path = path.as_ref();
        let bytes = std::fs::read(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        crate::artifact::read_method_stack_range(&bytes, range)
            .map_err(|e| e.context(format!("loading {}", path.display())))
    }

    /// [`load_range`](Self::load_range) via mmap: in-range v3 payloads
    /// borrow the mapping, so a peer pages in only its shard's weights —
    /// skipped layers cost zero resident bytes *and* zero page-ins.
    pub fn load_range_mmap(
        path: impl AsRef<std::path::Path>,
        range: std::ops::Range<usize>,
    ) -> anyhow::Result<Self> {
        let path = path.as_ref();
        let art = crate::sys::MappedArtifact::open(path)
            .map_err(|e| e.context(format!("mapping {}", path.display())))?;
        crate::artifact::read_method_stack_range_mapped(&art, range)
            .map_err(|e| e.context(format!("loading {}", path.display())))
    }

    /// Serialize to v2 container bytes (in-memory [`save`](Self::save)).
    pub fn to_artifact_bytes(&self) -> anyhow::Result<Vec<u8>> {
        crate::artifact::write_method_stack(self, Vec::new())
    }

    /// Deserialize from container bytes, v1 or v2 (in-memory
    /// [`load`](Self::load)).
    pub fn from_artifact_bytes(bytes: &[u8]) -> anyhow::Result<Self> {
        crate::artifact::read_method_stack(bytes)
    }

    /// Collapse into a [`PackedStack`] when every layer is a packed
    /// tri-scale composition; `Err` naming the offending layer otherwise.
    pub fn try_into_packed(self) -> anyhow::Result<PackedStack> {
        let mut packed = Vec::with_capacity(self.layers.len());
        for (k, l) in self.layers.into_iter().enumerate() {
            match l.layer {
                MethodLayer::Packed(p) => packed.push(p),
                other => anyhow::bail!(
                    "layer {k} uses method {:?} ({} serving form); load it as a MethodStack",
                    l.method,
                    other.variant_label()
                ),
            }
        }
        PackedStack::try_new(packed)
    }

    /// Single-request forward through the whole chain.
    pub fn forward(&self, x: &[f32]) -> Vec<f32> {
        let mut cur = x.to_vec();
        for l in &self.layers {
            cur = l.layer.forward(&cur);
        }
        cur
    }

    /// Batched forward (serial kernels): `X` is `d_in × b` feature-major.
    pub fn forward_batch(&self, x: &Mat) -> Mat {
        let mut y = Mat::default();
        let mut scratch = BatchScratch::default();
        self.forward_batch_into(x, &mut y, &mut scratch, SignPool::serial(), 1);
        y
    }

    /// Allocation-free batched forward through the whole chain — the
    /// serving hot path, identical in structure to
    /// [`PackedStack::forward_batch_into`]: `y` is resized in place and
    /// activations ping-pong between the two blocks carried by `scratch`.
    pub fn forward_batch_into(
        &self,
        x: &Mat,
        y: &mut Mat,
        scratch: &mut BatchScratch,
        pool: &SignPool,
        threads: usize,
    ) {
        let n = self.layers.len();
        if n == 1 {
            self.layers[0].layer.forward_batch_into(x, y, scratch, pool, threads);
            return;
        }
        let mut cur = std::mem::take(&mut scratch.ping);
        let mut nxt = std::mem::take(&mut scratch.pong);
        self.layers[0].layer.forward_batch_into(x, &mut cur, scratch, pool, threads);
        for l in &self.layers[1..n - 1] {
            l.layer.forward_batch_into(&cur, &mut nxt, scratch, pool, threads);
            std::mem::swap(&mut cur, &mut nxt);
        }
        self.layers[n - 1].layer.forward_batch_into(&cur, y, scratch, pool, threads);
        scratch.ping = cur;
        scratch.pong = nxt;
    }
}

impl From<PackedStack> for MethodStack {
    /// Every LittleBit-2 deployment is a method stack: the lossless view
    /// that lets legacy packed chains flow through the generic spine.
    fn from(stack: PackedStack) -> Self {
        // PackedStack already validated the chain.
        Self {
            layers: stack
                .into_layers()
                .into_iter()
                .map(|l| MethodStackLayer {
                    method: "littlebit2".to_string(),
                    layer: MethodLayer::Packed(l),
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::littlebit::CompressionConfig;
    use crate::rng::Pcg64;
    use crate::spectral::{synth_weight, SynthSpec};

    fn packed_chain(dims: &[usize], seed: u64) -> PackedStack {
        let mut rng = Pcg64::seed(seed);
        let weights: Vec<Mat> = dims
            .windows(2)
            .map(|w| {
                let spec =
                    SynthSpec { rows: w[1], cols: w[0], gamma: 0.3, coherence: 0.6, scale: 1.0 };
                synth_weight(&spec, &mut rng)
            })
            .collect();
        let cfg = CompressionConfig { bpp: 1.0, ..Default::default() };
        PackedStack::compress_chain(&weights, &cfg, &mut rng)
    }

    /// A packed stack viewed as a method stack must forward bit-identically
    /// through the generic spine.
    #[test]
    fn packed_view_forwards_bit_identically() {
        let packed = packed_chain(&[40, 56, 40], 11);
        let generic = MethodStack::from(packed.clone());
        assert_eq!(generic.depth(), 2);
        assert_eq!(generic.method_summary(), "littlebit2");
        assert_eq!(generic.storage_bytes(), packed.storage_bytes());

        let mut rng = Pcg64::seed(12);
        let b = 5;
        let mut x = Mat::zeros(40, b);
        x.fill_normal(&mut rng);
        let want = packed.forward_batch(&x);
        let got = generic.forward_batch(&x);
        assert_eq!(want, got);
        // And back again, losslessly.
        let roundtrip = generic.try_into_packed().unwrap();
        assert_eq!(roundtrip, packed);
    }

    /// Mixed-method chains compose and report "mixed"; broken chains and
    /// non-packed downcasts are `Err`.
    #[test]
    fn mixed_chain_composes_and_downcast_fails() {
        use crate::model::method::DenseScaledLayer;
        let packed = packed_chain(&[40, 56], 21);
        let mut rng = Pcg64::seed(22);
        let dense = MethodLayer::DenseScaled(
            DenseScaledLayer::try_new(Mat::gaussian(32, 56, &mut rng), 100).unwrap(),
        );
        let stack = MethodStack::try_new(vec![
            MethodStackLayer {
                method: "littlebit2".into(),
                layer: MethodLayer::Packed(packed.layers()[0].clone()),
            },
            MethodStackLayer { method: "rtn".into(), layer: dense.clone() },
        ])
        .unwrap();
        assert_eq!(stack.method_summary(), "mixed");
        assert_eq!((stack.d_in(), stack.d_out()), (40, 32));
        // Chain forward: batch column equals composed per-layer forwards.
        let mut x = Mat::zeros(40, 3);
        x.fill_normal(&mut rng);
        let y = stack.forward_batch(&x);
        for t in 0..3 {
            let want = stack.forward(&x.col(t));
            for (i, w) in want.iter().enumerate() {
                assert_eq!(y.at(i, t).to_bits(), w.to_bits(), "({i},{t})");
            }
        }
        assert!(stack.try_into_packed().is_err());

        // Broken chain rejected.
        let bad = MethodStack::try_new(vec![
            MethodStackLayer {
                method: "littlebit2".into(),
                layer: MethodLayer::Packed(packed.layers()[0].clone()),
            },
            MethodStackLayer { method: "rtn".into(), layer: {
                let w = Mat::gaussian(32, 55, &mut rng);
                MethodLayer::DenseScaled(DenseScaledLayer::try_new(w, 1).unwrap())
            } },
        ]);
        assert!(bad.unwrap_err().to_string().contains("chain mismatch"));
    }

    /// One scratch serving varying widths and depths stays bit-clean —
    /// the server worker reuse contract, generic-spine edition.
    #[test]
    fn scratch_reuse_is_clean() {
        let stack = MethodStack::from(packed_chain(&[40, 56, 48, 40], 31));
        let single = MethodStack::from(packed_chain(&[40, 56], 32));
        let mut rng = Pcg64::seed(33);
        let mut scratch = BatchScratch::default();
        let mut y = Mat::default();
        for b in [4usize, 1, 7] {
            let mut x = Mat::zeros(40, b);
            x.fill_normal(&mut rng);
            stack.forward_batch_into(&x, &mut y, &mut scratch, SignPool::global(), 2);
            assert_eq!(y, stack.forward_batch(&x), "depth-3 b={b}");
            single.forward_batch_into(&x, &mut y, &mut scratch, SignPool::global(), 2);
            assert_eq!(y, single.forward_batch(&x), "depth-1 b={b}");
        }
    }
}
