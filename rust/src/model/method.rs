//! Method-generic runtime layers: one uniform forward contract for every
//! compression method the repo reproduces.
//!
//! The paper's headline comparison (Table 1) pits LittleBit-2 against the
//! 1-bit baselines — OneBit-style ALS sign matrices, BiLLM-style salient
//! splits, plain RTN, and FP16 truncated SVD. Before this module those
//! baselines were dead-end dense reconstructions (`quant::QuantResult`)
//! that could never be packed, saved, or served. [`MethodLayer`] is the
//! runtime-layer enum that makes every method a first-class serving
//! workload, with a uniform allocation-free
//! [`forward_batch_into`](MethodLayer::forward_batch_into) so
//! [`MethodStack`](crate::model::MethodStack), the server backends, and
//! the `.lb2` v2 artifact all treat methods interchangeably:
//!
//! * [`MethodLayer::Packed`] — the tri-scale residual sign-GEMM layer
//!   (LittleBit / LittleBit-2), exactly the PR 1-4 hot path.
//! * [`MethodLayer::SignScaled`] — one-level sign-GEMM with row/column
//!   scales, `y = row ⊙ (S · (col ⊙ x))`: the OneBit / ARB-LLM family,
//!   served through the same fused [`SignPool`] kernels (1 bit/weight on
//!   disk plus two scale vectors).
//! * [`MethodLayer::DenseScaled`] — a dense f32 reconstruction carrying
//!   its method's *declared* App. H storage bits (RTN, BiLLM): fidelity-
//!   faithful and servable, but persisted as the reconstruction (see the
//!   bpp audit in EXPERIMENTS.md §Artifact).
//! * [`MethodLayer::LowRankFp`] — FP16-rounded truncated-SVD factors
//!   (Strategy A / `tiny_rank_fp16`), served as two thin dense GEMMs.
//!
//! Every variant validates its shape invariants at the deserialization
//! boundary (`try_new`) and is bit-exactly reproducible across pool sizes:
//! the sign variants inherit the fused-kernel guarantee, the dense
//! variants run the fixed-k-order blocked kernels of `linalg::Mat`.

use crate::linalg::Mat;
use crate::packing::{gemv_sign_scaled, BatchScratch, BitMatrix, PackedResidual, SignPool};
use crate::parallel::Pool;
use crate::sys::ScaleVec;
use anyhow::{bail, Result};

/// One-level sign-GEMM layer: `y = row ⊙ (S · (col ⊙ x))` with
/// `S ∈ {±1}^{d_out×d_in}` bit-packed — the deployed form of the
/// OneBit / ARB-LLM `diag(a)·sign(W)·diag(b)` family. Runs on the same
/// scale-fused sign kernels as the tri-scale path (one fused GEMM per
/// batch instead of two), so serving is MatMul-free at ~1 bit per weight.
#[derive(Clone, Debug, PartialEq)]
pub struct SignScaledLayer {
    /// `sign(W)` packed, `d_out × d_in`.
    bits: BitMatrix,
    /// Row scale `a ∈ R^{d_out}` (FP16-rounded).
    row: ScaleVec,
    /// Column scale `b ∈ R^{d_in}` (FP16-rounded).
    col: ScaleVec,
    /// The method's declared App. H storage bits (e.g. Eq. 22 for OneBit).
    declared_bits: u64,
}

impl SignScaledLayer {
    /// Build from packed signs and scales — owned vectors or mapped views
    /// ([`ScaleVec`]); shape mismatches are `Err` (this doubles as the
    /// `.lb2` decode boundary).
    pub fn try_new(
        bits: BitMatrix,
        row: impl Into<ScaleVec>,
        col: impl Into<ScaleVec>,
        declared_bits: u64,
    ) -> Result<Self> {
        let (row, col) = (row.into(), col.into());
        if bits.rows() != row.len() {
            bail!("row scale length {} != d_out {}", row.len(), bits.rows());
        }
        if bits.cols() != col.len() {
            bail!("col scale length {} != d_in {}", col.len(), bits.cols());
        }
        Ok(Self { bits, row, col, declared_bits })
    }

    pub fn d_out(&self) -> usize {
        self.bits.rows()
    }

    pub fn d_in(&self) -> usize {
        self.bits.cols()
    }

    /// Packed `sign(W)` — serialized verbatim by the v2 artifact.
    pub fn bits(&self) -> &BitMatrix {
        &self.bits
    }

    pub fn row_scale(&self) -> &[f32] {
        &self.row
    }

    pub fn col_scale(&self) -> &[f32] {
        &self.col
    }

    pub fn declared_bits(&self) -> u64 {
        self.declared_bits
    }

    /// Serving-form bytes: packed sign words + two FP16-accounted scales.
    pub fn storage_bytes(&self) -> usize {
        self.bits.storage_bytes() + 2 * (self.row.len() + self.col.len())
    }

    /// Heap-held weight bytes (0-contribution from mapped backing).
    pub fn resident_bytes(&self) -> usize {
        self.bits.resident_bytes() + self.row.resident_bytes() + self.col.resident_bytes()
    }

    /// Page-cache-backed weight bytes.
    pub fn mapped_bytes(&self) -> usize {
        self.bits.mapped_bytes() + self.row.mapped_bytes() + self.col.mapped_bytes()
    }

    fn forward_into(&self, x: &[f32], out: &mut [f32]) {
        gemv_sign_scaled(&self.bits, Some(&self.col), x, Some(&self.row), out);
    }

    fn forward_batch_into(&self, x: &Mat, y: &mut Mat, pool: &SignPool, threads: usize) {
        assert_eq!(x.rows(), self.d_in(), "X must be d_in × b feature-major");
        y.resize(self.d_out(), x.cols());
        pool.run_gemm(&self.bits, Some(&self.col), x, Some(&self.row), y, threads);
    }

    fn reconstruct_on(&self, pool: &Pool) -> Mat {
        let _ = pool;
        self.bits.to_dense().scale_rows(&self.row).scale_cols(&self.col)
    }
}

/// Dense f32 reconstruction with its method's declared App. H storage —
/// the serving form of the reconstruction-level baselines (RTN groups,
/// BiLLM salient splits) whose codebook layouts have no packed kernel
/// here. Fidelity and the batched forward are exactly the method's; the
/// *on-disk* size is the f32 reconstruction (32 bpp), reconciled against
/// `declared_bits` in EXPERIMENTS.md §Artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct DenseScaledLayer {
    /// The dequantized weight, `d_out × d_in`.
    w: Mat,
    declared_bits: u64,
}

impl DenseScaledLayer {
    pub fn try_new(w: Mat, declared_bits: u64) -> Result<Self> {
        if w.rows() == 0 || w.cols() == 0 {
            bail!("degenerate dense layer {}x{}", w.rows(), w.cols());
        }
        Ok(Self { w, declared_bits })
    }

    pub fn d_out(&self) -> usize {
        self.w.rows()
    }

    pub fn d_in(&self) -> usize {
        self.w.cols()
    }

    pub fn weight(&self) -> &Mat {
        &self.w
    }

    pub fn declared_bits(&self) -> u64 {
        self.declared_bits
    }

    pub fn storage_bytes(&self) -> usize {
        self.w.rows() * self.w.cols() * 4
    }

    /// Dense reconstructions are always owned: resident = the padded
    /// in-memory buffer (v3 maps bit-planes and scales only).
    pub fn resident_bytes(&self) -> usize {
        self.w.padded().len() * 4
    }
}

/// FP16-rounded truncated-SVD factors (`Ŵ = U · Vᵀ` with the singular
/// values folded in): Strategy A / `tiny_rank_fp16`. Served as two thin
/// dense GEMMs through the latent block, `r·(d_in + d_out)` MACs per
/// token instead of `d_in·d_out`.
#[derive(Clone, Debug, PartialEq)]
pub struct LowRankFpLayer {
    /// `U`, `d_out × r` (singular values split across both factors).
    u: Mat,
    /// `Vᵀ`, `r × d_in` (pre-transposed so both GEMMs stream rows).
    vt: Mat,
    declared_bits: u64,
}

impl LowRankFpLayer {
    pub fn try_new(u: Mat, vt: Mat, declared_bits: u64) -> Result<Self> {
        if u.cols() != vt.rows() {
            bail!("rank mismatch: U has {} cols, Vᵀ has {} rows", u.cols(), vt.rows());
        }
        if u.rows() == 0 || vt.cols() == 0 || u.cols() == 0 {
            bail!("degenerate low-rank layer {}x{} rank {}", u.rows(), vt.cols(), u.cols());
        }
        Ok(Self { u, vt, declared_bits })
    }

    pub fn d_out(&self) -> usize {
        self.u.rows()
    }

    pub fn d_in(&self) -> usize {
        self.vt.cols()
    }

    pub fn rank(&self) -> usize {
        self.u.cols()
    }

    pub fn u(&self) -> &Mat {
        &self.u
    }

    pub fn vt(&self) -> &Mat {
        &self.vt
    }

    pub fn declared_bits(&self) -> u64 {
        self.declared_bits
    }

    /// Resident serving-form bytes: the factors are held (and persisted)
    /// as f32 — 4 bytes/element. The FP16 storage *accounting* of App. H
    /// lives in [`declared_bits`](Self::declared_bits), not here.
    pub fn storage_bytes(&self) -> usize {
        4 * (self.u.rows() * self.u.cols() + self.vt.rows() * self.vt.cols())
    }

    /// Low-rank factors are always owned (v3 maps bit-planes and scales
    /// only): resident = the padded in-memory buffers.
    pub fn resident_bytes(&self) -> usize {
        4 * (self.u.padded().len() + self.vt.padded().len())
    }
}

/// The method-generic runtime layer: what [`crate::quant::Compressor`]
/// produces, what [`crate::model::MethodStack`] chains, and what the
/// `.lb2` v2 artifact persists per layer (with a METHOD tag naming the
/// quantizer that produced it).
#[derive(Clone, Debug, PartialEq)]
pub enum MethodLayer {
    /// Tri-scale residual sign-GEMM composition (LittleBit / LittleBit-2).
    Packed(PackedResidual),
    /// One-level sign-GEMM with row/column scales (OneBit, ARB-LLM).
    SignScaled(SignScaledLayer),
    /// Dense reconstruction with declared storage (RTN, BiLLM).
    DenseScaled(DenseScaledLayer),
    /// FP16 truncated-SVD factors (Strategy A).
    LowRankFp(LowRankFpLayer),
}

impl MethodLayer {
    pub fn d_in(&self) -> usize {
        match self {
            MethodLayer::Packed(l) => l.d_in(),
            MethodLayer::SignScaled(l) => l.d_in(),
            MethodLayer::DenseScaled(l) => l.d_in(),
            MethodLayer::LowRankFp(l) => l.d_in(),
        }
    }

    pub fn d_out(&self) -> usize {
        match self {
            MethodLayer::Packed(l) => l.d_out(),
            MethodLayer::SignScaled(l) => l.d_out(),
            MethodLayer::DenseScaled(l) => l.d_out(),
            MethodLayer::LowRankFp(l) => l.d_out(),
        }
    }

    /// Latent rank where the variant has one (`Packed` reports path 0's),
    /// 0 for full-matrix variants.
    pub fn rank(&self) -> usize {
        match self {
            MethodLayer::Packed(l) => l.paths()[0].rank(),
            MethodLayer::LowRankFp(l) => l.rank(),
            MethodLayer::SignScaled(_) | MethodLayer::DenseScaled(_) => 0,
        }
    }

    /// Short variant label (the serving-form family, not the method name —
    /// e.g. both `onebit` and `arb` are `sign-scaled`).
    pub fn variant_label(&self) -> &'static str {
        match self {
            MethodLayer::Packed(_) => "packed",
            MethodLayer::SignScaled(_) => "sign-scaled",
            MethodLayer::DenseScaled(_) => "dense-scaled",
            MethodLayer::LowRankFp(_) => "lowrank-fp",
        }
    }

    /// The method's declared App. H storage bits — what bpp accounting
    /// uses. For `Packed` this equals `ResidualCompressed::storage_bits`:
    /// both charge [`crate::memory::littlebit_path_bits`] per path.
    pub fn declared_bits(&self) -> u64 {
        match self {
            MethodLayer::Packed(l) => l
                .paths()
                .iter()
                .map(|p| crate::memory::littlebit_path_bits(p.d_in(), p.d_out(), p.rank()))
                .sum(),
            MethodLayer::SignScaled(l) => l.declared_bits(),
            MethodLayer::DenseScaled(l) => l.declared_bits(),
            MethodLayer::LowRankFp(l) => l.declared_bits(),
        }
    }

    /// Declared bits-per-parameter.
    pub fn bpp(&self) -> f64 {
        self.declared_bits() as f64 / (self.d_in() * self.d_out()) as f64
    }

    /// Serving-form weight bytes (what the process actually holds).
    pub fn storage_bytes(&self) -> usize {
        match self {
            MethodLayer::Packed(l) => l.storage_bytes(),
            MethodLayer::SignScaled(l) => l.storage_bytes(),
            MethodLayer::DenseScaled(l) => l.storage_bytes(),
            MethodLayer::LowRankFp(l) => l.storage_bytes(),
        }
    }

    /// Weight bytes held on this process's heap. For an eager load this is
    /// the whole padded serving form; for an mmap load of a v3 artifact
    /// the sign-family planes/scales move to [`mapped_bytes`](Self::mapped_bytes)
    /// and only the dense/low-rank variants (always copied) remain here.
    /// The two sums are disjoint by construction — the bpp audit adds
    /// them without double-counting.
    pub fn resident_bytes(&self) -> usize {
        match self {
            MethodLayer::Packed(l) => l.resident_bytes(),
            MethodLayer::SignScaled(l) => l.resident_bytes(),
            MethodLayer::DenseScaled(l) => l.resident_bytes(),
            MethodLayer::LowRankFp(l) => l.resident_bytes(),
        }
    }

    /// Weight bytes served from the page cache through a live mapping
    /// (0 for eager loads and for the dense/low-rank variants).
    pub fn mapped_bytes(&self) -> usize {
        match self {
            MethodLayer::Packed(l) => l.mapped_bytes(),
            MethodLayer::SignScaled(l) => l.mapped_bytes(),
            MethodLayer::DenseScaled(_) | MethodLayer::LowRankFp(_) => 0,
        }
    }

    /// Borrow the packed tri-scale composition, when this is one.
    pub fn as_packed(&self) -> Option<&PackedResidual> {
        match self {
            MethodLayer::Packed(l) => Some(l),
            _ => None,
        }
    }

    /// Unwrap into the packed tri-scale composition; `Err` (with the
    /// actual variant named) otherwise.
    pub fn into_packed(self) -> Result<PackedResidual> {
        match self {
            MethodLayer::Packed(l) => Ok(l),
            other => bail!(
                "layer is a {} method layer, not a packed tri-scale composition",
                other.variant_label()
            ),
        }
    }

    /// Single-request forward (convenience path; hot loops batch).
    /// Bit-identical to column `t` of [`forward_batch`](Self::forward_batch)
    /// on a batch containing the request: the sign variants inherit the
    /// gemv/gemm kernel equivalence, and the dense variants run the SAME
    /// blocked matmul on a 1-column matrix (`Mat::matvec` reduces in f64
    /// while the batched kernel reduces in f32 — going through the batch
    /// kernel keeps the serve path's bit-exactness contract).
    pub fn forward(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.d_in(), "input width");
        match self {
            MethodLayer::Packed(l) => l.forward(x),
            MethodLayer::SignScaled(l) => {
                let mut out = vec![0.0f32; l.d_out()];
                l.forward_into(x, &mut out);
                out
            }
            MethodLayer::DenseScaled(_) | MethodLayer::LowRankFp(_) => {
                let xm = Mat::from_vec(x.len(), 1, x.to_vec());
                self.forward_batch(&xm).col(0)
            }
        }
    }

    /// The uniform batched forward — the serving hot path for every
    /// method. `x` is `d_in × b` feature-major; `y` is resized to
    /// `d_out × b` in place; `scratch` carries the reusable latent /
    /// per-path blocks. Sign variants split row ranges on `pool`
    /// (`threads` ranges, bit-exact for any count); dense variants run
    /// the fixed-order blocked kernels on the pool's backing workers.
    pub fn forward_batch_into(
        &self,
        x: &Mat,
        y: &mut Mat,
        scratch: &mut BatchScratch,
        pool: &SignPool,
        threads: usize,
    ) {
        assert_eq!(x.rows(), self.d_in(), "X must be d_in × b feature-major");
        match self {
            MethodLayer::Packed(l) => l.forward_batch_into(x, y, scratch, pool, threads),
            MethodLayer::SignScaled(l) => l.forward_batch_into(x, y, pool, threads),
            // Dense arms honor the same `threads` partition budget as the
            // sign kernels (bit-identical for any count; the knob only
            // bounds how much of the shared pool one worker occupies).
            MethodLayer::DenseScaled(l) => {
                l.w.matmul_into_parts_on(x, y, pool.backing(), threads)
            }
            MethodLayer::LowRankFp(l) => {
                // Latent block reuses the tri-scale scratch: vt·x, then u·t.
                let mut latent = std::mem::take(&mut scratch.latent);
                l.vt.matmul_into_parts_on(x, &mut latent, pool.backing(), threads);
                l.u.matmul_into_parts_on(&latent, y, pool.backing(), threads);
                scratch.latent = latent;
            }
        }
    }

    /// Allocating batched forward (serial kernels) — test/oracle path.
    pub fn forward_batch(&self, x: &Mat) -> Mat {
        let mut y = Mat::default();
        let mut scratch = BatchScratch::default();
        self.forward_batch_into(x, &mut y, &mut scratch, SignPool::serial(), 1);
        y
    }

    /// The output-row shard `range` of this layer — the tensor-parallel
    /// cut: the returned layer computes exactly output rows
    /// `range.start..range.end` of the full layer, **bit-identically**,
    /// because slicing output rows of every serving form leaves each
    /// surviving row's operands and reduction order untouched:
    ///
    /// * `Packed`: per path, slice `U_b`'s rows and the row scale `h`;
    ///   `V_bᵀ`, `l`, `g` (input-side) are kept whole. A clone of a
    ///   mapped `V_bᵀ` still borrows the mapping, so row shards of an
    ///   mmap-loaded stack share one page-cache copy of the big plane.
    /// * `SignScaled`: slice the sign plane's rows and the row scale;
    ///   the column scale is kept whole.
    /// * `DenseScaled`: slice `W`'s rows.
    /// * `LowRankFp`: slice `U`'s rows; `Vᵀ` is kept whole (the latent
    ///   projection is identical across shards).
    ///
    /// `declared_bits` is prorated by row count — shard accounting sums
    /// back to within rounding of the full layer. An empty or
    /// out-of-bounds range is an `Err` (empty shards are represented by
    /// *absence* of a layer, not a degenerate one).
    pub fn slice_rows(&self, range: std::ops::Range<usize>) -> Result<MethodLayer> {
        let d_out = self.d_out();
        if range.start >= range.end || range.end > d_out {
            bail!(
                "row shard {}..{} is invalid for a layer with {d_out} output rows",
                range.start,
                range.end
            );
        }
        let prorated =
            |bits: u64| bits * range.len() as u64 / d_out as u64;
        Ok(match self {
            MethodLayer::Packed(l) => {
                let mut paths = Vec::with_capacity(l.paths().len());
                for p in l.paths() {
                    let ub = p.ub_bits().slice_rows(range.clone())?;
                    let h = p.h()[range.clone()].to_vec();
                    paths.push(crate::packing::TriScaleLayer::from_parts(
                        ub,
                        p.vbt_bits().clone(),
                        h,
                        p.l().to_vec(),
                        p.g().to_vec(),
                    )?);
                }
                MethodLayer::Packed(PackedResidual::try_new(paths)?)
            }
            MethodLayer::SignScaled(l) => MethodLayer::SignScaled(SignScaledLayer::try_new(
                l.bits().slice_rows(range.clone())?,
                l.row_scale()[range.clone()].to_vec(),
                l.col_scale().to_vec(),
                prorated(l.declared_bits()),
            )?),
            MethodLayer::DenseScaled(l) => {
                let w = l.weight();
                let mut data = Vec::with_capacity(range.len() * w.cols());
                for i in range.clone() {
                    data.extend_from_slice(w.row(i));
                }
                MethodLayer::DenseScaled(DenseScaledLayer::try_new(
                    Mat::from_vec(range.len(), w.cols(), data),
                    prorated(l.declared_bits()),
                )?)
            }
            MethodLayer::LowRankFp(l) => {
                let u = l.u();
                let mut data = Vec::with_capacity(range.len() * u.cols());
                for i in range.clone() {
                    data.extend_from_slice(u.row(i));
                }
                MethodLayer::LowRankFp(LowRankFpLayer::try_new(
                    Mat::from_vec(range.len(), u.cols(), data),
                    l.vt().clone(),
                    prorated(l.declared_bits()),
                )?)
            }
        })
    }

    /// Dense reconstruction `Ŵ` of this layer — the fidelity-scoring
    /// oracle (`‖W − Ŵ‖²`), pool-parallel and bit-identical for any pool.
    pub fn reconstruct_on(&self, pool: &Pool) -> Mat {
        match self {
            MethodLayer::Packed(l) => {
                let mut acc: Option<Mat> = None;
                for p in l.paths() {
                    let part = p
                        .ub_bits()
                        .to_dense()
                        .scale_rows(p.h())
                        .scale_cols(p.l())
                        .matmul_on(&p.vbt_bits().to_dense(), pool)
                        .scale_cols(p.g());
                    acc = Some(match acc {
                        Some(a) => a.add(&part),
                        None => part,
                    });
                }
                acc.expect("at least one path")
            }
            MethodLayer::SignScaled(l) => l.reconstruct_on(pool),
            MethodLayer::DenseScaled(l) => l.w.clone(),
            MethodLayer::LowRankFp(l) => l.u.matmul_on(&l.vt, pool),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn sign_layer(seed: u64, d_out: usize, d_in: usize) -> SignScaledLayer {
        let mut rng = Pcg64::seed(seed);
        let s = Mat::gaussian(d_out, d_in, &mut rng).signum();
        let mut row = vec![0.0f32; d_out];
        let mut col = vec![0.0f32; d_in];
        rng.fill_uniform(&mut row, 0.2, 1.5);
        rng.fill_uniform(&mut col, 0.2, 1.5);
        SignScaledLayer::try_new(BitMatrix::from_dense(&s), row, col, 1234).unwrap()
    }

    /// The sign-scaled batch path must be bit-identical to the per-item
    /// GEMV — the same kernel contract the tri-scale layer has.
    #[test]
    fn sign_scaled_batch_matches_per_item_bit_exactly() {
        // 70 columns ⇒ a ragged tail word, the harder packing case.
        let layer = MethodLayer::SignScaled(sign_layer(3, 48, 70));
        let mut rng = Pcg64::seed(4);
        let b = 7;
        let mut x = Mat::zeros(70, b);
        x.fill_normal(&mut rng);
        let batched = layer.forward_batch(&x);
        for t in 0..b {
            let want = layer.forward(&x.col(t));
            for i in 0..48 {
                assert_eq!(batched.at(i, t).to_bits(), want[i].to_bits(), "({i},{t})");
            }
        }
    }

    /// Sign-scaled forward equals the dense reconstruction product.
    #[test]
    fn sign_scaled_matches_reconstruction() {
        let sl = sign_layer(5, 33, 40);
        let layer = MethodLayer::SignScaled(sl);
        let recon = layer.reconstruct_on(Pool::serial());
        let mut rng = Pcg64::seed(6);
        let mut x = vec![0.0f32; 40];
        rng.fill_normal(&mut x);
        let want = recon.matvec(&x);
        let got = layer.forward(&x);
        for (a, b) in want.iter().zip(&got) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    /// Dense and low-rank variants: batch forward equals the serial
    /// matmul against the reconstruction, and scratch reuse is clean.
    #[test]
    fn dense_and_lowrank_batch_forwards() {
        let mut rng = Pcg64::seed(7);
        let w = Mat::gaussian(24, 30, &mut rng);
        let dense = MethodLayer::DenseScaled(DenseScaledLayer::try_new(w.clone(), 99).unwrap());
        let u = Mat::gaussian(24, 4, &mut rng);
        let vt = Mat::gaussian(4, 30, &mut rng);
        let lowrank =
            MethodLayer::LowRankFp(LowRankFpLayer::try_new(u.clone(), vt.clone(), 77).unwrap());

        let mut scratch = BatchScratch::default();
        let mut y = Mat::default();
        for b in [3usize, 1, 5] {
            let mut x = Mat::zeros(30, b);
            x.fill_normal(&mut rng);
            dense.forward_batch_into(&x, &mut y, &mut scratch, SignPool::serial(), 1);
            assert_eq!(y, w.matmul(&x), "dense b={b}");
            lowrank.forward_batch_into(&x, &mut y, &mut scratch, SignPool::serial(), 1);
            assert_eq!(y, u.matmul(&vt.matmul(&x)), "lowrank b={b}");
        }
        assert_eq!(lowrank.rank(), 4);
        assert_eq!(dense.rank(), 0);
    }

    /// Declared bits for a packed layer must equal the FP-side
    /// `ResidualCompressed::storage_bits` accounting.
    #[test]
    fn packed_declared_bits_match_compressed_accounting() {
        use crate::littlebit::{compress, CompressionConfig};
        use crate::spectral::{synth_weight, SynthSpec};
        let mut rng = Pcg64::seed(8);
        let spec = SynthSpec { rows: 64, cols: 48, gamma: 0.3, coherence: 0.6, scale: 1.0 };
        let w = synth_weight(&spec, &mut rng);
        let cfg = CompressionConfig { bpp: 1.0, ..Default::default() };
        let c = compress(&w, &cfg, &mut rng);
        let layer = MethodLayer::Packed(c.pack());
        assert_eq!(layer.declared_bits(), c.storage_bits());
        assert!((layer.bpp() - c.bpp()).abs() < 1e-12);
    }

    /// Row shards forward bit-identically to the corresponding rows of
    /// the full layer, for every serving form — the tensor-parallel
    /// correctness contract. Concatenating the shard outputs in
    /// `row_partition` order must reproduce the full output exactly.
    #[test]
    fn slice_rows_is_bit_identical_per_variant() {
        use crate::littlebit::{compress, CompressionConfig};
        use crate::parallel::row_partition;
        use crate::spectral::{synth_weight, SynthSpec};
        let mut rng = Pcg64::seed(9);
        let spec = SynthSpec { rows: 48, cols: 40, gamma: 0.3, coherence: 0.6, scale: 1.0 };
        let w = synth_weight(&spec, &mut rng);
        let cfg = CompressionConfig { bpp: 1.0, ..Default::default() };
        let packed = MethodLayer::Packed(compress(&w, &cfg, &mut rng).pack());
        let sign = MethodLayer::SignScaled(sign_layer(10, 48, 40));
        let dense = MethodLayer::DenseScaled(
            DenseScaledLayer::try_new(Mat::gaussian(48, 40, &mut rng), 99).unwrap(),
        );
        let lowrank = MethodLayer::LowRankFp(
            LowRankFpLayer::try_new(
                Mat::gaussian(48, 5, &mut rng),
                Mat::gaussian(5, 40, &mut rng),
                77,
            )
            .unwrap(),
        );
        for layer in [packed, sign, dense, lowrank] {
            let mut x = Mat::zeros(40, 3);
            x.fill_normal(&mut rng);
            let full = layer.forward_batch(&x);
            for parts in [1usize, 2, 3, 5] {
                for range in row_partition(layer.d_out(), parts) {
                    let shard = layer.slice_rows(range.clone()).unwrap();
                    assert_eq!(shard.d_out(), range.len());
                    assert_eq!(shard.d_in(), 40);
                    let got = shard.forward_batch(&x);
                    for (k, i) in range.clone().enumerate() {
                        for t in 0..3 {
                            assert_eq!(
                                got.at(k, t).to_bits(),
                                full.at(i, t).to_bits(),
                                "{} rows {range:?} ({i},{t})",
                                layer.variant_label()
                            );
                        }
                    }
                }
            }
            // Degenerate ranges are rejected.
            assert!(layer.slice_rows(0..0).is_err());
            assert!(layer.slice_rows(0..layer.d_out() + 1).is_err());
        }
    }

    #[test]
    fn try_new_rejects_mismatched_shapes() {
        let bits = BitMatrix::ones(4, 6);
        assert!(SignScaledLayer::try_new(bits.clone(), vec![1.0; 3], vec![1.0; 6], 1).is_err());
        assert!(SignScaledLayer::try_new(bits, vec![1.0; 4], vec![1.0; 5], 1).is_err());
        assert!(DenseScaledLayer::try_new(Mat::zeros(0, 4), 1).is_err());
        assert!(LowRankFpLayer::try_new(Mat::zeros(4, 2), Mat::zeros(3, 5), 1).is_err());
    }
}
