//! Model architecture descriptors and the synthetic-LLM zoo.
//!
//! [`ArchSpec`] encodes the public shapes of the evaluation models (Llama-2
//! 7B/13B, Llama-3 8B, Gemma-3 27B) so the App. H memory aggregation
//! reproduces Table 1's Mem columns exactly. The [`zoo`] submodule fabricates
//! synthetic per-layer weights whose spectral statistics match the paper's
//! Fig. 11/12 measurements — the checkpoint substitute for every
//! fidelity experiment. The [`stack`] submodule chains the packed layers
//! into a batched sequential model ([`PackedStack`]) so whole request
//! batches flow through every layer without per-request dispatch; the
//! [`method`] and [`method_stack`] submodules generalize that chain to
//! every registered compression method ([`MethodLayer`] /
//! [`MethodStack`]) — the serving spine behind `.lb2` v2 artifacts and
//! the Table 1 baseline comparisons.

pub mod method;
pub mod method_stack;
pub mod stack;
pub mod zoo;

pub use method::{DenseScaledLayer, LowRankFpLayer, MethodLayer, SignScaledLayer};
pub use method_stack::{MethodStack, MethodStackLayer};
pub use stack::PackedStack;

/// One linear projection inside a transformer block.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Proj {
    Q,
    K,
    V,
    O,
    Gate,
    Up,
    Down,
}

impl Proj {
    pub const ALL: [Proj; 7] = [
        Proj::Q,
        Proj::K,
        Proj::V,
        Proj::O,
        Proj::Gate,
        Proj::Up,
        Proj::Down,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Proj::Q => "q_proj",
            Proj::K => "k_proj",
            Proj::V => "v_proj",
            Proj::O => "o_proj",
            Proj::Gate => "gate_proj",
            Proj::Up => "up_proj",
            Proj::Down => "down_proj",
        }
    }
}

/// Transformer architecture description (decoder-only, SwiGLU MLP, optional
/// grouped-query attention).
#[derive(Clone, Debug)]
pub struct ArchSpec {
    pub name: &'static str,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    /// Whether input embedding and LM head share weights.
    pub tied_embeddings: bool,
}

impl ArchSpec {
    pub fn llama2_7b() -> Self {
        Self {
            name: "llama2-7b",
            vocab: 32_000,
            d_model: 4096,
            n_layers: 32,
            n_heads: 32,
            n_kv_heads: 32,
            head_dim: 128,
            d_ff: 11_008,
            tied_embeddings: false,
        }
    }

    pub fn llama2_13b() -> Self {
        Self {
            name: "llama2-13b",
            vocab: 32_000,
            d_model: 5120,
            n_layers: 40,
            n_heads: 40,
            n_kv_heads: 40,
            head_dim: 128,
            d_ff: 13_824,
            tied_embeddings: false,
        }
    }

    pub fn llama3_8b() -> Self {
        Self {
            name: "llama3-8b",
            vocab: 128_256,
            d_model: 4096,
            n_layers: 32,
            n_heads: 32,
            n_kv_heads: 8,
            head_dim: 128,
            d_ff: 14_336,
            tied_embeddings: false,
        }
    }

    pub fn gemma3_27b() -> Self {
        Self {
            name: "gemma3-27b",
            vocab: 262_144,
            d_model: 5376,
            n_layers: 62,
            n_heads: 32,
            n_kv_heads: 16,
            head_dim: 128,
            d_ff: 21_504,
            tied_embeddings: true,
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "llama2-7b" => Some(Self::llama2_7b()),
            "llama2-13b" => Some(Self::llama2_13b()),
            "llama3-8b" => Some(Self::llama3_8b()),
            "gemma3-27b" => Some(Self::gemma3_27b()),
            _ => None,
        }
    }

    pub const KNOWN: [&'static str; 4] =
        ["llama2-7b", "llama2-13b", "llama3-8b", "gemma3-27b"];

    /// `(d_out, d_in)` of a projection.
    pub fn proj_shape(&self, p: Proj) -> (usize, usize) {
        let q_dim = self.n_heads * self.head_dim;
        let kv_dim = self.n_kv_heads * self.head_dim;
        match p {
            Proj::Q => (q_dim, self.d_model),
            Proj::K | Proj::V => (kv_dim, self.d_model),
            Proj::O => (self.d_model, q_dim),
            Proj::Gate | Proj::Up => (self.d_ff, self.d_model),
            Proj::Down => (self.d_model, self.d_ff),
        }
    }

    /// Iterate every linear layer of the model body:
    /// `(block index, projection, d_out, d_in)`.
    pub fn body_layers(&self) -> impl Iterator<Item = (usize, Proj, usize, usize)> + '_ {
        (0..self.n_layers).flat_map(move |b| {
            Proj::ALL.into_iter().map(move |p| {
                let (o, i) = self.proj_shape(p);
                (b, p, o, i)
            })
        })
    }

    /// Parameter count of the body's linear layers.
    pub fn body_params(&self) -> u64 {
        self.body_layers().map(|(_, _, o, i)| (o * i) as u64).sum()
    }

    /// Embedding parameters (input embedding table).
    pub fn embedding_params(&self) -> u64 {
        (self.vocab * self.d_model) as u64
    }

    /// LM head parameters (0 when tied with the embedding).
    pub fn head_params(&self) -> u64 {
        if self.tied_embeddings {
            0
        } else {
            (self.vocab * self.d_model) as u64
        }
    }

    /// Norm/bias parameters: per-block 2 RMSNorm vectors + final norm.
    pub fn norm_params(&self) -> u64 {
        ((2 * self.n_layers + 1) * self.d_model) as u64
    }

    /// Total parameters.
    pub fn total_params(&self) -> u64 {
        self.body_params() + self.embedding_params() + self.head_params() + self.norm_params()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama2_7b_param_count_matches_published() {
        let a = ArchSpec::llama2_7b();
        let total = a.total_params() as f64 / 1e9;
        assert!((total - 6.74).abs() < 0.05, "total={total}B");
    }

    #[test]
    fn llama3_8b_param_count_matches_published() {
        let a = ArchSpec::llama3_8b();
        let total = a.total_params() as f64 / 1e9;
        assert!((total - 8.03).abs() < 0.08, "total={total}B");
    }

    #[test]
    fn llama2_13b_param_count_matches_published() {
        let a = ArchSpec::llama2_13b();
        let total = a.total_params() as f64 / 1e9;
        assert!((total - 13.02).abs() < 0.1, "total={total}B");
    }

    #[test]
    fn gqa_shapes() {
        let a = ArchSpec::llama3_8b();
        assert_eq!(a.proj_shape(Proj::Q), (4096, 4096));
        assert_eq!(a.proj_shape(Proj::K), (1024, 4096));
        assert_eq!(a.proj_shape(Proj::Down), (4096, 14336));
    }

    #[test]
    fn body_layer_count() {
        let a = ArchSpec::llama2_7b();
        assert_eq!(a.body_layers().count(), 32 * 7);
    }

    #[test]
    fn by_name_roundtrip() {
        for n in ArchSpec::KNOWN {
            assert_eq!(ArchSpec::by_name(n).unwrap().name, n);
        }
        assert!(ArchSpec::by_name("gpt-5").is_none());
    }
}
