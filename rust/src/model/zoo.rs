//! The synthetic-LLM zoo: checkpoint substitutes with realistic spectra.
//!
//! Fig. 11 reports per-model γ distributions with medians in [0.26, 0.33];
//! Fig. 12 shows the per-module-type spread (V/O/Down heavier-tailed than
//! Q/K). This module fabricates miniature stand-ins whose per-layer γ are
//! drawn from those measured statistics, so γ-distribution analyses
//! (Fig. 6 bottom, Fig. 11, Fig. 12) and reconstruction sweeps (Fig. 10)
//! run against weight populations with paper-faithful spectral shape.

use super::{ArchSpec, Proj};
use crate::linalg::Mat;
use crate::rng::Pcg64;
use crate::spectral::{synth_weight, SynthSpec};

/// Measured γ statistics per module type, digitized from Fig. 12:
/// `(mean, std)` of the decay rate for each projection.
pub fn gamma_profile(p: Proj) -> (f64, f64) {
    match p {
        Proj::Q => (0.32, 0.05),
        Proj::K => (0.34, 0.05),
        Proj::V => (0.24, 0.04),
        Proj::O => (0.25, 0.04),
        Proj::Gate => (0.29, 0.03),
        Proj::Up => (0.28, 0.03),
        Proj::Down => (0.24, 0.04),
    }
}

/// One fabricated layer: where it lives and its weight.
pub struct ZooLayer {
    pub block: usize,
    pub proj: Proj,
    pub gamma: f64,
    pub weight: Mat,
}

/// Fabricate a miniature zoo model: the *architecture ratio* of `arch` is
/// preserved (GQA, SwiGLU widths) but every dimension is divided by
/// `shrink` so the population fits CPU experiments. γ per layer is sampled
/// from the Fig. 12 profile of its module type; singular-vector coherence is
/// sampled in the spiky regime observed in §4.2.
pub fn fabricate(
    arch: &ArchSpec,
    shrink: usize,
    n_blocks: usize,
    seed: u64,
) -> Vec<ZooLayer> {
    let mut rng = Pcg64::seed(seed);
    let mut layers = Vec::new();
    for block in 0..n_blocks {
        for proj in Proj::ALL {
            let (d_out, d_in) = arch.proj_shape(proj);
            let rows = (d_out / shrink).max(32);
            let cols = (d_in / shrink).max(32);
            let (mu, sd) = gamma_profile(proj);
            let gamma = (mu + sd * rng.normal()).clamp(0.12, 0.8);
            let coherence = 0.55 + 0.3 * rng.uniform();
            let spec = SynthSpec { rows, cols, gamma, coherence, scale: 0.02 };
            layers.push(ZooLayer {
                block,
                proj,
                gamma,
                weight: synth_weight(&spec, &mut rng),
            });
        }
    }
    layers
}

/// Fabricate one SwiGLU FFN as a *chainable* weight pair
/// `(up: d_ff×d_model, down: d_model×d_ff)` at the Fig. 12 γ profile of the
/// respective projections — the minimal zoo unit whose layers compose, used
/// to exercise the batched `model::PackedStack` path on weights with
/// paper-faithful spectra.
pub fn fabricate_ffn_chain(arch: &ArchSpec, shrink: usize, seed: u64) -> Vec<Mat> {
    let mut rng = Pcg64::seed(seed);
    [Proj::Up, Proj::Down]
        .into_iter()
        .map(|proj| {
            let (d_out, d_in) = arch.proj_shape(proj);
            let rows = (d_out / shrink).max(32);
            let cols = (d_in / shrink).max(32);
            let (mu, sd) = gamma_profile(proj);
            let gamma = (mu + sd * rng.normal()).clamp(0.12, 0.8);
            let coherence = 0.55 + 0.3 * rng.uniform();
            let spec = SynthSpec { rows, cols, gamma, coherence, scale: 0.02 };
            synth_weight(&spec, &mut rng)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spectral::estimate_gamma;

    #[test]
    fn zoo_layers_have_expected_shapes() {
        let arch = ArchSpec::llama3_8b();
        let zoo = fabricate(&arch, 32, 2, 1);
        assert_eq!(zoo.len(), 14);
        let q = zoo.iter().find(|l| l.proj == Proj::Q).unwrap();
        assert_eq!(q.weight.shape(), (128, 128));
        let k = zoo.iter().find(|l| l.proj == Proj::K).unwrap();
        assert_eq!(k.weight.shape(), (32, 128)); // GQA preserved
    }

    #[test]
    fn zoo_gammas_match_paper_range() {
        let arch = ArchSpec::llama2_7b();
        let zoo = fabricate(&arch, 32, 4, 2);
        let mut gs: Vec<f64> = zoo.iter().map(|l| l.gamma).collect();
        gs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = gs[gs.len() / 2];
        // Fig. 11: medians within [0.26, 0.33]; allow sampling slack.
        assert!((0.2..0.4).contains(&median), "median={median}");
    }

    #[test]
    fn fabricated_spectrum_is_measurable() {
        let arch = ArchSpec::llama2_7b();
        let zoo = fabricate(&arch, 32, 1, 3);
        let layer = &zoo[0];
        let mut rng = Pcg64::seed(9);
        let svd = crate::linalg::svd_randomized(&layer.weight, 96, 10, 3, &mut rng);
        let fit = estimate_gamma(&svd.s);
        assert!(
            (fit.gamma - layer.gamma).abs() < 0.1,
            "target={} got={}",
            layer.gamma,
            fit.gamma
        );
    }

    #[test]
    fn ffn_chain_dims_compose() {
        let arch = ArchSpec::llama2_7b();
        let chain = fabricate_ffn_chain(&arch, 32, 5);
        assert_eq!(chain.len(), 2);
        // up: d_ff×d_model, down: d_model×d_ff — chainable in sequence.
        assert_eq!(chain[0].cols(), 128); // d_model / 32
        assert_eq!(chain[0].rows(), chain[1].cols()); // d_ff / 32
        assert_eq!(chain[1].rows(), 128);
    }

    #[test]
    fn deterministic_given_seed() {
        let arch = ArchSpec::llama2_7b();
        let a = fabricate(&arch, 64, 1, 42);
        let b = fabricate(&arch, 64, 1, 42);
        assert_eq!(a[0].weight, b[0].weight);
    }
}
