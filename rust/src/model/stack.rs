//! Sequential packed-model execution: a chain of compressed linear layers
//! run end to end on a whole batch.
//!
//! The serving story of §6.2 needs more than one layer: a request flows
//! through every projection of the model without per-request dispatch in
//! between. [`PackedStack`] holds the packed residual composition of each
//! layer and keeps activations **feature-major** (`d × b`, column `t` is
//! request `t`) across the entire chain, so each layer is exactly one
//! batched sign-GEMM pipeline and the batch never deinterleaves.

use crate::linalg::Mat;
use crate::littlebit::{compress, CompressionConfig};
use crate::packing::{BatchScratch, PackedResidual, Scratch, SignPool};
use crate::rng::Pcg64;

/// A chain of packed layers with matching inner dimensions
/// (`layer[k].d_out() == layer[k+1].d_in()`).
#[derive(Clone, Debug, PartialEq)]
pub struct PackedStack {
    layers: Vec<PackedResidual>,
}

impl PackedStack {
    /// Compose packed layers; panics if the chain dimensions don't line up.
    pub fn new(layers: Vec<PackedResidual>) -> Self {
        assert!(!layers.is_empty(), "at least one layer");
        for k in 1..layers.len() {
            assert_eq!(
                layers[k - 1].d_out(),
                layers[k].d_in(),
                "chain mismatch between layer {} and {}",
                k - 1,
                k
            );
        }
        Self { layers }
    }

    /// Fallible [`new`](Self::new) for deserialization boundaries (the
    /// `.lb2` load path): a malformed chain returns `Err` instead of
    /// panicking.
    pub fn try_new(layers: Vec<PackedResidual>) -> anyhow::Result<Self> {
        if layers.is_empty() {
            anyhow::bail!("stack needs at least one layer");
        }
        for k in 1..layers.len() {
            if layers[k - 1].d_out() != layers[k].d_in() {
                anyhow::bail!(
                    "chain mismatch: layer {} emits {} features but layer {k} consumes {}",
                    k - 1,
                    layers[k - 1].d_out(),
                    layers[k].d_in()
                );
            }
        }
        Ok(Self { layers })
    }

    /// Persist as a versioned `.lb2` artifact — the quantize-once /
    /// serve-from-many deployment contract. See [`crate::artifact`] for
    /// the byte layout; [`load`](Self::load) round-trips bit-exactly.
    pub fn save(&self, path: impl AsRef<std::path::Path>) -> anyhow::Result<()> {
        crate::artifact::save_stack(self, path)
    }

    /// Load a `.lb2` artifact written by [`save`](Self::save). Bit-planes
    /// are copied word-verbatim (no re-packing), so every forward of the
    /// loaded stack is bit-identical to the saved one. Corrupt, truncated,
    /// or mis-shaped artifacts return `Err` — never panic.
    pub fn load(path: impl AsRef<std::path::Path>) -> anyhow::Result<Self> {
        crate::artifact::load_stack(path)
    }

    /// Persist as a `.lb2` **format v3** "aligned" artifact (planes at the
    /// padded in-memory stride, payloads 32-byte aligned) so
    /// [`load_mmap`](Self::load_mmap) can borrow them in place.
    pub fn save_aligned(&self, path: impl AsRef<std::path::Path>) -> anyhow::Result<()> {
        crate::artifact::save_stack_aligned(self, path)
    }

    /// Load by mapping the file: a v3 aligned artifact's bit-planes and
    /// scales borrow the mapping (zero weight copies, page cache shared
    /// across processes); v1/v2 or misaligned payloads fall back to
    /// copy-and-restride. Forwards are bit-identical to
    /// [`load`](Self::load) either way.
    pub fn load_mmap(path: impl AsRef<std::path::Path>) -> anyhow::Result<Self> {
        crate::artifact::load_stack_mmap(path)
    }

    /// Weight bytes held on this process's heap (disjoint from
    /// [`mapped_bytes`](Self::mapped_bytes)).
    pub fn resident_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.resident_bytes()).sum()
    }

    /// Weight bytes served from the page cache through a live mapping.
    pub fn mapped_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.mapped_bytes()).sum()
    }

    /// Serialize to `.lb2` container bytes (the in-memory form of
    /// [`save`](Self::save)).
    pub fn to_artifact_bytes(&self) -> anyhow::Result<Vec<u8>> {
        crate::artifact::write_stack(self, Vec::new())
    }

    /// Deserialize from `.lb2` container bytes (the in-memory form of
    /// [`load`](Self::load)).
    pub fn from_artifact_bytes(bytes: &[u8]) -> anyhow::Result<Self> {
        crate::artifact::read_stack(bytes)
    }

    /// Compress each weight of a chain at the given config and pack the
    /// results — the one-call path from a dense model to a deployable
    /// batched stack.
    pub fn compress_chain(weights: &[Mat], cfg: &CompressionConfig, rng: &mut Pcg64) -> Self {
        Self::new(weights.iter().map(|w| compress(w, cfg, rng).pack()).collect())
    }

    pub fn depth(&self) -> usize {
        self.layers.len()
    }

    pub fn d_in(&self) -> usize {
        self.layers[0].d_in()
    }

    pub fn d_out(&self) -> usize {
        self.layers[self.layers.len() - 1].d_out()
    }

    pub fn layers(&self) -> &[PackedResidual] {
        &self.layers
    }

    /// Consume the stack into its layers (the
    /// [`MethodStack`](crate::model::MethodStack) conversion path — no
    /// clone of the packed bit-planes).
    pub fn into_layers(self) -> Vec<PackedResidual> {
        self.layers
    }

    /// Total weight-storage bytes across the chain.
    pub fn storage_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.storage_bytes()).sum()
    }

    /// Single-request forward through the whole chain.
    pub fn forward(&self, x: &[f32]) -> Vec<f32> {
        let mut scratch = Scratch::default();
        let mut cur = x.to_vec();
        for layer in &self.layers {
            let mut next = vec![0.0f32; layer.d_out()];
            layer.forward_into(&cur, &mut next, &mut scratch);
            cur = next;
        }
        cur
    }

    /// Batched forward: `X` is `d_in × b` feature-major; returns
    /// `d_out × b`. The batch stays interleaved through every layer —
    /// one sign-GEMM pipeline per layer, no per-request dispatch.
    pub fn forward_batch(&self, x: &Mat) -> Mat {
        self.forward_batch_mt(x, 1)
    }

    /// [`forward_batch`](Self::forward_batch) with each layer's fused
    /// sign-GEMMs split into `threads` row ranges on the process-wide
    /// [`SignPool`].
    pub fn forward_batch_mt(&self, x: &Mat, threads: usize) -> Mat {
        let mut y = Mat::default();
        let mut scratch = BatchScratch::default();
        self.forward_batch_into(x, &mut y, &mut scratch, SignPool::for_threads(threads), threads);
        y
    }

    /// Allocation-free batched forward through the whole chain: `y` is
    /// resized to `d_out × b` in place and the batch ping-pongs between the
    /// two activation blocks carried by `scratch` — after warm-up, a chain
    /// forward performs **zero** heap allocations regardless of depth.
    /// Bit-identical to [`forward_batch`](Self::forward_batch).
    pub fn forward_batch_into(
        &self,
        x: &Mat,
        y: &mut Mat,
        scratch: &mut BatchScratch,
        pool: &SignPool,
        threads: usize,
    ) {
        let n = self.layers.len();
        if n == 1 {
            self.layers[0].forward_batch_into(x, y, scratch, pool, threads);
            return;
        }
        // The ping/pong blocks leave the scratch while the layers use its
        // latent/path blocks, then return (same dance as the residual path).
        let mut cur = std::mem::take(&mut scratch.ping);
        let mut nxt = std::mem::take(&mut scratch.pong);
        self.layers[0].forward_batch_into(x, &mut cur, scratch, pool, threads);
        for layer in &self.layers[1..n - 1] {
            layer.forward_batch_into(&cur, &mut nxt, scratch, pool, threads);
            std::mem::swap(&mut cur, &mut nxt);
        }
        self.layers[n - 1].forward_batch_into(&cur, y, scratch, pool, threads);
        scratch.ping = cur;
        scratch.pong = nxt;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::littlebit::InitStrategy;
    use crate::spectral::{synth_weight, SynthSpec};

    fn chain_weights(dims: &[usize], rng: &mut Pcg64) -> Vec<Mat> {
        dims.windows(2)
            .map(|w| {
                let spec = SynthSpec {
                    rows: w[1],
                    cols: w[0],
                    gamma: 0.3,
                    coherence: 0.6,
                    scale: 1.0,
                };
                synth_weight(&spec, rng)
            })
            .collect()
    }

    fn quick_cfg() -> CompressionConfig {
        CompressionConfig {
            bpp: 1.0,
            strategy: InitStrategy::JointItq { iters: 10 },
            residual: true,
            ..Default::default()
        }
    }

    #[test]
    fn batched_chain_matches_per_item_bit_exactly() {
        let mut rng = Pcg64::seed(41);
        let weights = chain_weights(&[48, 96, 48], &mut rng);
        let stack = PackedStack::compress_chain(&weights, &quick_cfg(), &mut rng);
        assert_eq!(stack.depth(), 2);
        assert_eq!((stack.d_in(), stack.d_out()), (48, 48));

        let b = 7;
        let mut x = Mat::zeros(48, b);
        x.fill_normal(&mut rng);
        let batched = stack.forward_batch(&x);
        let threaded = stack.forward_batch_mt(&x, 3);
        assert_eq!(batched, threaded);
        for t in 0..b {
            let want = stack.forward(&x.col(t));
            for i in 0..48 {
                assert_eq!(batched.at(i, t).to_bits(), want[i].to_bits(), "({i},{t})");
            }
        }
    }

    /// The allocation-free chain forward must match the allocating one bit
    /// for bit while one scratch serves batches of varying width (and a
    /// depth-1 chain, which writes straight into `y`).
    #[test]
    fn chain_forward_batch_into_scratch_reuse_is_clean() {
        let mut rng = Pcg64::seed(45);
        let weights = chain_weights(&[48, 96, 64, 48], &mut rng);
        let stack = PackedStack::compress_chain(&weights, &quick_cfg(), &mut rng);
        let single = PackedStack::new(vec![stack.layers()[0].clone()]);
        let mut scratch = BatchScratch::default();
        let mut y = Mat::default();
        let pool = SignPool::global();
        for b in [5usize, 1, 8] {
            let mut x = Mat::zeros(48, b);
            x.fill_normal(&mut rng);
            stack.forward_batch_into(&x, &mut y, &mut scratch, pool, 2);
            assert_eq!(y, stack.forward_batch(&x), "depth-3 b={b}");
            single.forward_batch_into(&x, &mut y, &mut scratch, pool, 2);
            assert_eq!(y, single.forward_batch(&x), "depth-1 b={b}");
        }
    }

    #[test]
    fn chain_tracks_dense_composition() {
        let mut rng = Pcg64::seed(42);
        let weights = chain_weights(&[40, 80, 40], &mut rng);
        let mut crng = Pcg64::seed(43);
        // Reconstruct the same compressed layers the stack packs, so the
        // comparison isolates the packed execution (not compression error).
        let recons: Vec<Mat> = weights
            .iter()
            .map(|w| compress(w, &quick_cfg(), &mut crng).reconstruct())
            .collect();
        let mut srng = Pcg64::seed(43);
        let stack = PackedStack::compress_chain(&weights, &quick_cfg(), &mut srng);

        let mut x = vec![0.0f32; 40];
        rng.fill_normal(&mut x);
        let mut want = x.clone();
        for r in &recons {
            want = r.matvec(&want);
        }
        let got = stack.forward(&x);
        for (a, b) in want.iter().zip(&got) {
            // Two layers of f32 sign-GEMV vs dense matvec: loose bound.
            let tol = 1e-2 * a.abs().max(1.0);
            assert!((a - b).abs() < tol, "{a} vs {b}");
        }
    }

    #[test]
    #[should_panic(expected = "chain mismatch")]
    fn dimension_mismatch_rejected() {
        let mut rng = Pcg64::seed(44);
        let a = chain_weights(&[32, 64], &mut rng);
        let b = chain_weights(&[48, 32], &mut rng);
        let cfg = quick_cfg();
        let la = compress(&a[0], &cfg, &mut rng).pack();
        let lb = compress(&b[0], &cfg, &mut rng).pack();
        let _ = PackedStack::new(vec![la, lb]);
    }
}
