//! # LittleBit-2: sub-1-bit LLM compression via Latent Geometry Alignment
//!
//! Production-quality reproduction of *"LittleBit-2: Maximizing the Spectral
//! Energy Gain in Sub-1-Bit LLMs via Latent Geometry Alignment"* (Lee & Kim,
//! 2026) as a three-layer rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — compression coordinator, QAT training driver,
//!   the batched multi-worker serving loop (dynamic batching onto the
//!   scale-fused sign-GEMM kernels, row ranges on a persistent
//!   `packing::SignPool`), and the complete numerics substrate (SVD, QR,
//!   Joint-ITQ, all quantization baselines, the spectral break-even theory,
//!   bit-packed MatMul-free inference kernels — GEMV and batched GEMM —
//!   memory accounting).
//! * **L2 (`python/compile/model.py`)** — JAX transformer with LittleBit
//!   tri-scale linear layers, AOT-lowered to HLO text at build time.
//! * **L1 (`python/compile/kernels/`)** — Pallas kernels for the fused
//!   tri-scale matmul, binarization, and the Joint-ITQ step; validated
//!   against pure-jnp oracles.
//!
//! Python never runs on the request path: `make artifacts` lowers the L2/L1
//! graph once; the rust binary loads `artifacts/*.hlo.txt` through PJRT
//! ([`runtime`]) and owns everything else.
//!
//! ## Quick tour
//!
//! ```no_run
//! use littlebit2::rng::Pcg64;
//! use littlebit2::spectral::{synth_weight, SynthSpec};
//! use littlebit2::littlebit::{compress, CompressionConfig, InitStrategy};
//!
//! let mut rng = Pcg64::seed(0);
//! let w = synth_weight(&SynthSpec::default(), &mut rng);
//! let cfg = CompressionConfig {
//!     bpp: 0.55,
//!     strategy: InitStrategy::JointItq { iters: 50 },
//!     residual: true,
//!     ..Default::default()
//! };
//! let compressed = compress(&w, &cfg, &mut rng);
//! println!("MSE = {:.3e}", compressed.reconstruct().mse(&w));
//! // Deployment: pack once, then serve single requests or whole batches.
//! let packed = compressed.pack();
//! let y = packed.forward(&vec![0.0; 512]);
//! assert_eq!(y.len(), 512);
//! ```
//!
//! See README.md for the repository tour, ARCHITECTURE.md for the module
//! map and layer contract, and EXPERIMENTS.md for measured results and the
//! bench methodology.

pub mod artifact;
pub mod cluster;
pub mod coordinator;
pub mod data;
pub mod faults;
pub mod linalg;
pub mod littlebit;
pub mod memory;
pub mod model;
pub mod packing;
pub mod parallel;
pub mod quant;
pub mod rng;
pub mod runtime;
pub mod serving;
pub mod spectral;
pub mod sys;

/// Crate version, reported by the CLI and stamped into experiment logs.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
